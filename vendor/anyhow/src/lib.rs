//! Minimal in-tree stand-in for the `anyhow` crate.
//!
//! The build image has no registry access, so the workspace vendors the
//! small slice of anyhow's API the coordinator uses: the dynamic
//! [`Error`] type, the [`Result`] alias, the `anyhow!` / `bail!` /
//! `ensure!` macros, and the [`Context`] extension trait. Semantics
//! mirror upstream: `Display` prints the outermost message, `{:#}`
//! prints the whole context chain separated by `": "`, and any
//! `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::fmt;

/// Dynamic error: an outermost message plus the chain of underlying
/// causes (outermost first), each flattened to a string.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The cause chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // upstream prints the message plus a caused-by list
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        for cause in self.chain.iter().skip(1) {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to fallible values, like upstream anyhow.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ",
                                               stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err::<(), _>(io_err())?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn bail_and_ensure_short_circuit() {
        fn b() -> Result<()> {
            bail!("boom {}", 7);
        }
        assert_eq!(b().unwrap_err().to_string(), "boom 7");
        fn e(ok: bool) -> Result<u8> {
            ensure!(ok, "not ok");
            Ok(1)
        }
        assert_eq!(e(true).unwrap(), 1);
        assert_eq!(e(false).unwrap_err().to_string(), "not ok");
    }

    #[test]
    fn option_context() {
        let r: Result<u8> = None.context("missing");
        assert_eq!(r.unwrap_err().to_string(), "missing");
    }
}
