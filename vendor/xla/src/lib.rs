//! Stub of the PJRT/XLA binding surface `runtime::client` consumes.
//!
//! The real backend (xla_extension + its C++ runtime) is not present in
//! this image, so this crate keeps the `runtime` layer *compiling* while
//! making the unavailability explicit at the only entry point:
//! [`PjRtClient::cpu`] returns an error, which `Runtime::open` surfaces
//! as "PJRT CPU client: …". Every caller in the tree already handles
//! that error path (the CLI falls back to host-only output, the benches
//! skip their PJRT sections, and the artifact-gated integration tests
//! skip). When a real binding is installed, point the workspace `xla`
//! dependency at it instead — the API below matches the subset used.

use std::fmt;

/// Error type for every stubbed operation.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: xla stub (no PJRT backend in this build; \
         install xla_extension and swap the workspace `xla` dependency)"))
}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Element types the runtime layer inspects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S32,
    S64,
    Pred,
}

/// Array shape: dimensions of one tensor literal.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Shape of a literal: an array or a tuple of shapes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// A host-side literal. The stub holds no data: literals are only ever
/// constructed on the way into `execute`, which the stub never reaches
/// because client construction fails first.
#[derive(Debug, Default)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal::default()
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn shape(&self) -> Result<Shape> {
        Err(unavailable("Literal::shape"))
    }

    pub fn ty(&self) -> Result<ElementType> {
        Err(unavailable("Literal::ty"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (text interchange format).
#[derive(Debug, Default)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug, Default)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation::default()
    }
}

/// Device buffer handle returned by an execution.
#[derive(Debug, Default)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
#[derive(Debug, Default)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client. The stub's only honest operation: refusing to start.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla stub"));
    }

    #[test]
    fn shape_accessors_compile_and_match() {
        let s = Shape::Array(ArrayShape { dims: vec![2, 3] });
        match s {
            Shape::Array(a) => assert_eq!(a.dims(), &[2, 3]),
            Shape::Tuple(_) => panic!("wrong variant"),
        }
    }
}
