"""§6 tiling decomposition: every tiled pass equals its untiled oracle,
including remainder tiles and degenerate tile sizes."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, tiling


def _rand(rng, *shape):
    return rng.standard_normal(shape, dtype=np.float32)


def _problem(rng, s=2, f=2, fo=3, h=16, w=16, kh=3, kw=3):
    x = jnp.asarray(_rand(rng, s, f, h, w))
    wei = jnp.asarray(_rand(rng, fo, f, kh, kw))
    go = jnp.asarray(_rand(rng, s, fo, h - kh + 1, w - kw + 1))
    return x, wei, go


@pytest.mark.parametrize("d", [3, 4, 6, 7, 14, 20])
def test_fprop_tiled_any_tile_size(rng, d):
    """Divisible, remainder-producing, and larger-than-output tile sizes
    all reduce to the same answer."""
    x, wei, _ = _problem(rng)
    want = ref.conv_fprop_ref(x, wei)
    got = tiling.conv_fprop_tiled(x, wei, d)
    np.testing.assert_allclose(got, want, atol=1e-3)


@pytest.mark.parametrize("d", [3, 5, 14])
def test_bprop_tiled_overlap_add(rng, d):
    x, wei, go = _problem(rng)
    want = ref.conv_bprop_ref(go, wei, 16, 16)
    got = tiling.conv_bprop_tiled(go, wei, d, 16, 16)
    np.testing.assert_allclose(got, want, atol=1e-3)


@pytest.mark.parametrize("d", [3, 5, 14])
def test_accgrad_tiled_sum_identity(rng, d):
    x, wei, go = _problem(rng)
    want = ref.conv_accgrad_ref(go, x, 3, 3)
    got = tiling.conv_accgrad_tiled(go, x, d, 3, 3)
    np.testing.assert_allclose(got, want, atol=2e-3)


@given(
    d=st.integers(2, 10),
    h=st.integers(8, 20),
    kh=st.sampled_from([3, 5]),
)
@settings(max_examples=10)
def test_fprop_tiled_random(d, h, kh):
    rng = np.random.default_rng(hash((d, h, kh)) % 2**32)
    x, wei, _ = _problem(rng, s=1, f=2, fo=2, h=h, w=h, kh=kh, kw=kh)
    want = ref.conv_fprop_ref(x, wei)
    got = tiling.conv_fprop_tiled(x, wei, d)
    np.testing.assert_allclose(got, want, atol=2e-3)


def test_tile_fft_size_is_small():
    """The whole point of §6: the per-tile basis depends on d and k, not
    on the input size — with d ≈ k the transforms stay in fbfft's 8–64
    sweet spot regardless of h."""
    assert tiling.tile_fft_size(3, 3, 3) == 8
    assert tiling.tile_fft_size(8, 3, 3) == 16
    assert tiling.tile_fft_size(8, 11, 11) == 32
    for d, k in [(3, 3), (8, 5), (16, 11)]:
        assert tiling.tile_fft_size(d, k, k) <= 64


def test_tile_ranges_cover_exactly():
    for total in [1, 5, 12, 13]:
        for d in [1, 3, 5, 20]:
            spans = tiling._tile_ranges(total, d)
            covered = []
            for a, sz in spans:
                assert sz > 0
                covered.extend(range(a, a + sz))
            assert covered == list(range(total))
