"""Layer-2 model: strategy agreement, custom-VJP gradients, CNN training."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, specs

from .conftest import tolerance


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


SPEC = specs.ConvSpec("t", 2, 3, 4, 12, 12, 3, 3)


class TestStrategyAgreement:
    """Every strategy × pass computes the same function (vendor = truth)."""

    @pytest.mark.parametrize("strategy", [s for s in model.STRATEGIES
                                          if s != "vendor"])
    def test_fprop(self, rng, strategy):
        x = _rand(rng, SPEC.s, SPEC.f, SPEC.h, SPEC.w)
        w = _rand(rng, SPEC.fo, SPEC.f, SPEC.kh, SPEC.kw)
        want = model.fprop(SPEC, "vendor", x, w)
        got = model.fprop(SPEC, strategy, x, w)
        np.testing.assert_allclose(got, want, atol=tolerance(256, SPEC.f))

    @pytest.mark.parametrize("strategy", [s for s in model.STRATEGIES
                                          if s != "vendor"])
    def test_bprop(self, rng, strategy):
        go = _rand(rng, SPEC.s, SPEC.fo, SPEC.yh, SPEC.yw)
        w = _rand(rng, SPEC.fo, SPEC.f, SPEC.kh, SPEC.kw)
        want = model.bprop(SPEC, "vendor", go, w)
        got = model.bprop(SPEC, strategy, go, w)
        np.testing.assert_allclose(got, want, atol=tolerance(256, SPEC.fo))

    @pytest.mark.parametrize("strategy", [s for s in model.STRATEGIES
                                          if s != "vendor"])
    def test_accgrad(self, rng, strategy):
        go = _rand(rng, SPEC.s, SPEC.fo, SPEC.yh, SPEC.yw)
        x = _rand(rng, SPEC.s, SPEC.f, SPEC.h, SPEC.w)
        want = model.accgrad(SPEC, "vendor", go, x)
        got = model.accgrad(SPEC, strategy, go, x)
        np.testing.assert_allclose(got, want, atol=tolerance(256, SPEC.s))

    def test_strided_layers_are_vendor_only(self, rng):
        strided = specs.ConvSpec("s", 1, 1, 1, 9, 9, 3, 3, stride=2)
        x = _rand(rng, 1, 1, 9, 9)
        w = _rand(rng, 1, 1, 3, 3)
        y = model.fprop(strided, "vendor", x, w)
        assert y.shape == (1, 1, 4, 4)
        with pytest.raises(ValueError):
            model.fprop(strided, "fbfft", x, w)


class TestCustomVjp:
    """fbfft_conv's hand-wired backward (the paper's bprop/accGrad
    kernels) must equal autodiff of the vendor forward."""

    def test_grads_match_autodiff(self, rng):
        x = _rand(rng, 2, 2, 10, 10)
        w = _rand(rng, 3, 2, 3, 3)

        def loss_fbfft(x, w):
            return jnp.sum(model.fbfft_conv(x, w, 16) ** 2)

        def loss_vendor(x, w):
            from compile.kernels import ref
            return jnp.sum(ref.conv_fprop_ref(x, w) ** 2)

        gx1, gw1 = jax.grad(loss_fbfft, argnums=(0, 1))(x, w)
        gx2, gw2 = jax.grad(loss_vendor, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gx1, gx2, atol=2e-2, rtol=1e-3)
        np.testing.assert_allclose(gw1, gw2, atol=2e-2, rtol=1e-3)


class TestCnnTraining:
    def test_loss_decreases(self, rng):
        cfg = model.TrainConfig(s=8, hw=16)
        params = model.cnn_init(cfg, jax.random.PRNGKey(0))
        step = jax.jit(lambda p, x, y: model.train_step(cfg, p, x, y))
        losses = []
        for i in range(30):
            x = _rand(rng, cfg.s, cfg.c, cfg.hw, cfg.hw)
            # learnable rule: label = quadrant of the mean-dominant corner
            y = jnp.asarray(
                (np.asarray(x)[:, 0, :8, :8].mean((1, 2)) >
                 np.asarray(x)[:, 0, 8:, 8:].mean((1, 2))).astype(np.int32))
            params, loss = step(params, x, y)
            losses.append(float(loss))
        assert losses[-1] < losses[0], f"{losses[0]} -> {losses[-1]}"
        assert all(np.isfinite(losses))

    def test_logits_shape(self, rng):
        cfg = model.TrainConfig()
        params = model.cnn_init(cfg, jax.random.PRNGKey(1))
        x = _rand(rng, cfg.s, cfg.c, cfg.hw, cfg.hw)
        logits = model.cnn_apply(cfg, params, x)
        assert logits.shape == (cfg.s, cfg.classes)


class TestSpecs:
    def test_table2_grid_is_8232(self):
        assert sum(1 for _ in specs.table2_grid()) == 8232

    def test_table4_layers_match_paper(self):
        l2 = specs.TABLE4_LAYERS[1]
        assert (l2.s, l2.f, l2.fo, l2.h, l2.kh) == (128, 64, 64, 64, 9)

    def test_scale_preserves_spatial(self):
        s = specs.scale(specs.TABLE4_LAYERS[0], planes=8, batch=8)
        assert (s.h, s.w, s.kh) == (128, 128, 11)
        assert s.f == 1 and s.fo == 12  # 3//8 -> 1 (floor), 96/8

    def test_reductions_formula(self):
        sp = specs.ConvSpec("x", 2, 3, 4, 9, 9, 3, 3)
        assert sp.reductions == 2 * 3 * 4 * 9 * 49

    def test_rejects_bad_spec(self):
        with pytest.raises(ValueError):
            specs.ConvSpec("bad", 1, 1, 1, 3, 3, 5, 5)
