"""The fbfft convolution pipeline vs time-domain ground truth, all three
passes + adjoint identities + agreement with the vendor-FFT oracle."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv_fft, pointwise, fbfft, ref

from .conftest import tolerance


def _rand(rng, *shape):
    return rng.standard_normal(shape, dtype=np.float32)


def _problem(rng, s, f, fo, h, w, kh, kw):
    x = jnp.asarray(_rand(rng, s, f, h, w))
    wei = jnp.asarray(_rand(rng, fo, f, kh, kw))
    go = jnp.asarray(_rand(rng, s, fo, h - kh + 1, w - kw + 1))
    return x, wei, go


CASES = [
    # (S, f, f', h, w, kh, kw) — paper-flavored corners
    (1, 1, 1, 8, 8, 3, 3),       # minimal
    (2, 3, 4, 9, 9, 3, 3),       # odd input
    (2, 2, 2, 13, 13, 3, 3),     # §5.4 size x=13
    (1, 4, 2, 16, 16, 5, 5),     # exact power of two
    (2, 1, 3, 11, 15, 5, 7),     # rectangular input + kernel
    (1, 2, 2, 16, 16, 11, 11),   # big kernel (FFT's best case)
    (4, 2, 2, 7, 7, 7, 7),       # kernel == input (1x1 output)
]


class TestConvFprop:
    @pytest.mark.parametrize("case", CASES)
    def test_vs_time_domain(self, rng, case):
        s, f, fo, h, w, kh, kw = case
        x, wei, _ = _problem(rng, *case)
        n = conv_fft.min_fft_size(h, w)
        got = conv_fft.conv_fprop(x, wei, n)
        want = ref.conv_fprop_ref(x, wei)
        assert got.shape == (s, fo, h - kh + 1, w - kw + 1)
        np.testing.assert_allclose(got, want, atol=tolerance(n * n, f))

    @given(data=st.data())
    @settings(max_examples=15)
    def test_random_shapes(self, data):
        s = data.draw(st.integers(1, 3), "S")
        f = data.draw(st.integers(1, 4), "f")
        fo = data.draw(st.integers(1, 4), "f'")
        kh = data.draw(st.sampled_from([3, 5]), "kh")
        kw = data.draw(st.sampled_from([3, 5]), "kw")
        h = data.draw(st.integers(kh, 14), "h")
        w = data.draw(st.integers(kw, 14), "w")
        rng = np.random.default_rng(hash((s, f, fo, h, w, kh, kw)) % 2**32)
        x, wei, _ = _problem(rng, s, f, fo, h, w, kh, kw)
        n = conv_fft.min_fft_size(h, w)
        got = conv_fft.conv_fprop(x, wei, n)
        want = ref.conv_fprop_ref(x, wei)
        np.testing.assert_allclose(got, want, atol=tolerance(n * n, f))

    def test_oversized_basis_is_equivalent(self, rng):
        """Interpolating on a larger-than-minimal basis (the autotuner's
        search axis) must not change the result."""
        x, wei, _ = _problem(rng, 2, 2, 2, 9, 9, 3, 3)
        y16 = conv_fft.conv_fprop(x, wei, 16)
        y32 = conv_fft.conv_fprop(x, wei, 32)
        np.testing.assert_allclose(y16, y32, atol=tolerance(32 * 32, 2))


class TestConvBprop:
    @pytest.mark.parametrize("case", CASES)
    def test_vs_time_domain(self, rng, case):
        s, f, fo, h, w, kh, kw = case
        _, wei, go = _problem(rng, *case)
        n = conv_fft.min_fft_size(h, w)
        got = conv_fft.conv_bprop(go, wei, n, h, w)
        want = ref.conv_bprop_ref(go, wei, h, w)
        assert got.shape == (s, f, h, w)
        np.testing.assert_allclose(got, want, atol=tolerance(n * n, fo))


class TestConvAccGrad:
    @pytest.mark.parametrize("case", CASES)
    def test_vs_time_domain(self, rng, case):
        s, f, fo, h, w, kh, kw = case
        x, _, go = _problem(rng, *case)
        n = conv_fft.min_fft_size(h, w)
        got = conv_fft.conv_accgrad(go, x, n, kh, kw)
        want = ref.conv_accgrad_ref(go, x, kh, kw)
        assert got.shape == (fo, f, kh, kw)
        np.testing.assert_allclose(got, want, atol=tolerance(n * n, s))


class TestAdjointIdentities:
    """The three passes are algebraically one trilinear form:
    ⟨y(x,w), go⟩ = ⟨x, gx(go,w)⟩ = ⟨w, gw(go,x)⟩. Catching a conjugation
    or clipping bug in any single pass breaks the chain."""

    def test_trilinear_chain(self, rng):
        s, f, fo, h, w, kh, kw = 2, 3, 2, 10, 10, 3, 3
        x, wei, go = _problem(rng, s, f, fo, h, w, kh, kw)
        n = conv_fft.min_fft_size(h, w)
        y = conv_fft.conv_fprop(x, wei, n)
        gx = conv_fft.conv_bprop(go, wei, n, h, w)
        gw = conv_fft.conv_accgrad(go, x, n, kh, kw)
        a = float(jnp.vdot(y, go))
        b = float(jnp.vdot(x, gx))
        c = float(jnp.vdot(wei, gw))
        assert a == pytest.approx(b, rel=1e-3)
        assert a == pytest.approx(c, rel=1e-3)


class TestPointwiseStage:
    """CGEMM stage in isolation against dense einsum on complex numbers."""

    def _planes(self, rng, nf, n, r, c):
        return (jnp.asarray(_rand(rng, nf, n, r, c)),
                jnp.asarray(_rand(rng, nf, n, r, c)))

    def test_fprop_bin_products(self, rng):
        nf, n, s, f, fo = 5, 8, 3, 4, 2
        xf = self._planes(rng, nf, n, s, f)
        wf = self._planes(rng, nf, n, fo, f)
        re, im = pointwise.cgemm_fprop(xf, wf)
        xc = xf[0] + 1j * xf[1]
        wc = wf[0] + 1j * wf[1]
        want = jnp.einsum("qnsf,qnjf->qnsj", xc, jnp.conj(wc))
        np.testing.assert_allclose(re, jnp.real(want), atol=1e-4)
        np.testing.assert_allclose(im, jnp.imag(want), atol=1e-4)

    def test_bprop_bin_products(self, rng):
        nf, n, s, f, fo = 5, 8, 3, 4, 2
        gf = self._planes(rng, nf, n, s, fo)
        wf = self._planes(rng, nf, n, fo, f)
        re, im = pointwise.cgemm_bprop(gf, wf)
        gc = gf[0] + 1j * gf[1]
        wc = wf[0] + 1j * wf[1]
        want = jnp.einsum("qnsj,qnjf->qnsf", gc, wc)
        np.testing.assert_allclose(re, jnp.real(want), atol=1e-4)
        np.testing.assert_allclose(im, jnp.imag(want), atol=1e-4)

    def test_accgrad_bin_products(self, rng):
        nf, n, s, f, fo = 5, 8, 3, 4, 2
        gf = self._planes(rng, nf, n, s, fo)
        xf = self._planes(rng, nf, n, s, f)
        re, im = pointwise.cgemm_accgrad(gf, xf)
        gc = gf[0] + 1j * gf[1]
        xc = xf[0] + 1j * xf[1]
        want = jnp.einsum("qnsj,qnsf->qnjf", jnp.conj(gc), xc)
        np.testing.assert_allclose(re, jnp.real(want), atol=1e-4)
        np.testing.assert_allclose(im, jnp.imag(want), atol=1e-4)


class TestVendorFftOracle:
    """The jnp.fft strategy (cuFFT analogue) agrees with time domain on
    non-power-of-two bases — the autotuner's 2^a3^b5^c7^d search space."""

    @pytest.mark.parametrize("n_fft", [9, 12, 14, 15, 18, 20, 21])
    def test_mixed_radix_bases(self, rng, n_fft):
        s, f, fo, h, w, kh, kw = 1, 2, 2, 9, 9, 3, 3
        x, wei, go = _problem(rng, s, f, fo, h, w, kh, kw)
        np.testing.assert_allclose(
            ref.conv_fprop_fft_ref(x, wei, n_fft),
            ref.conv_fprop_ref(x, wei), atol=tolerance(n_fft * n_fft, f))
        np.testing.assert_allclose(
            ref.conv_bprop_fft_ref(go, wei, n_fft, h, w),
            ref.conv_bprop_ref(go, wei, h, w),
            atol=tolerance(n_fft * n_fft, fo))
        np.testing.assert_allclose(
            ref.conv_accgrad_fft_ref(go, x, n_fft, kh, kw),
            ref.conv_accgrad_ref(go, x, kh, kw),
            atol=tolerance(n_fft * n_fft, s))
