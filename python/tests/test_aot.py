"""AOT pipeline: HLO text emission contract + manifest round trip.

These guard the exact bugs the bring-up hit: elided large constants
(``constant({...})`` parses as ZEROS in xla_extension 0.5.1) and
manifest/shape drift between the layers.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, specs


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    b = aot.Builder(str(out))
    aot.build_quickstart(b)
    aot.build_train(b)
    b.finish()
    return out


def test_hlo_text_never_elides_constants(built):
    for p in built.glob("*.hlo.txt"):
        text = p.read_text()
        assert "constant({...})" not in text, (
            f"{p.name}: elided constant — xla_extension 0.5.1 would load "
            "it as zeros")


def test_hlo_text_is_parsable_hlo(built):
    for p in built.glob("*.hlo.txt"):
        text = p.read_text()
        assert text.startswith("HloModule"), p.name
        assert "ENTRY" in text, p.name


def test_manifest_round_trip(built):
    man = json.loads((built / "manifest.json").read_text())
    assert man["version"] == 1
    names = {e["name"] for e in man["entries"]}
    assert "conv.quickstart.fbfft.fprop" in names
    assert "train.step" in names
    for e in man["entries"]:
        assert (built / e["hlo"]).exists(), e["name"]
        for t in e["inputs"] + e["outputs"]:
            assert t["dtype"] in ("f32", "s32")
            assert all(isinstance(d, int) and d >= 0 for d in t["shape"])


def test_conv_entry_shapes_match_spec(built):
    man = json.loads((built / "manifest.json").read_text())
    e = next(x for x in man["entries"]
             if x["name"] == "conv.quickstart.fbfft.fprop")
    sp = specs.ConvSpec.from_json(e["meta"]["spec"])
    assert e["inputs"][0]["shape"] == [sp.s, sp.f, sp.h, sp.w]
    assert e["inputs"][1]["shape"] == [sp.fo, sp.f, sp.kh, sp.kw]
    assert e["outputs"][0]["shape"] == [sp.s, sp.fo, sp.yh, sp.yw]


def test_train_init_tensors_match_python_init(built):
    cfg = model.TrainConfig()
    params = model.cnn_init(cfg, jax.random.PRNGKey(0xFB))
    for k in aot.PARAM_ORDER:
        data = np.fromfile(built / f"train.init.{k}.bin", "<f4")
        np.testing.assert_allclose(
            data, np.asarray(params[k]).ravel(), atol=0)


def test_train_step_entry_has_param_order(built):
    man = json.loads((built / "manifest.json").read_text())
    e = next(x for x in man["entries"] if x["name"] == "train.step")
    assert e["meta"]["param_order"] == list(aot.PARAM_ORDER)
    # 4 params + x + y inputs; 4 params + loss outputs
    assert len(e["inputs"]) == 6
    assert len(e["outputs"]) == 5


def test_filter_only(tmp_path):
    b = aot.Builder(str(tmp_path), only="vendor")
    aot.build_quickstart(b)
    b.finish()
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert all("vendor" in e["name"] for e in man["entries"])
    assert len(man["entries"]) == 1
