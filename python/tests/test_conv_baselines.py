"""Time-domain baseline kernels (direct, im2col) vs ground truth."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv_direct, conv_im2col, ref


def _rand(rng, *shape):
    return rng.standard_normal(shape, dtype=np.float32)


CASES = [
    (1, 1, 1, 5, 5, 3, 3),
    (2, 3, 4, 9, 9, 3, 3),
    (3, 2, 2, 12, 12, 5, 5),
    (1, 4, 4, 8, 10, 3, 5),
    (2, 1, 1, 7, 7, 7, 7),
]


@pytest.mark.parametrize("case", CASES)
def test_direct_matches_ref(rng, case):
    s, f, fo, h, w, kh, kw = case
    x = jnp.asarray(_rand(rng, s, f, h, w))
    wei = jnp.asarray(_rand(rng, fo, f, kh, kw))
    got = conv_direct.conv_direct_fprop(x, wei)
    np.testing.assert_allclose(
        got, ref.conv_fprop_ref(x, wei), atol=1e-3)


@pytest.mark.parametrize("case", CASES)
def test_im2col_matches_ref(rng, case):
    s, f, fo, h, w, kh, kw = case
    x = jnp.asarray(_rand(rng, s, f, h, w))
    wei = jnp.asarray(_rand(rng, fo, f, kh, kw))
    got = conv_im2col.conv_im2col_fprop(x, wei)
    np.testing.assert_allclose(
        got, ref.conv_fprop_ref(x, wei), atol=1e-3)


@given(data=st.data())
@settings(max_examples=15)
def test_direct_and_im2col_agree(data):
    """The two time-domain baselines are independent implementations of the
    same contract; they must agree with each other bit-for-nearly-bit."""
    s = data.draw(st.integers(1, 3), "S")
    f = data.draw(st.integers(1, 3), "f")
    fo = data.draw(st.integers(1, 3), "f'")
    kh = data.draw(st.sampled_from([1, 3, 5]), "kh")
    kw = data.draw(st.sampled_from([1, 3, 5]), "kw")
    h = data.draw(st.integers(kh, 12), "h")
    w = data.draw(st.integers(kw, 12), "w")
    rng = np.random.default_rng(hash((s, f, fo, h, w, kh, kw)) % 2**32)
    x = jnp.asarray(_rand(rng, s, f, h, w))
    wei = jnp.asarray(_rand(rng, fo, f, kh, kw))
    a = conv_direct.conv_direct_fprop(x, wei)
    b = conv_im2col.conv_im2col_fprop(x, wei)
    np.testing.assert_allclose(a, b, atol=1e-4)


def test_identity_kernel_direct(rng):
    """1x1 identity-plane kernel reproduces the input."""
    x = jnp.asarray(_rand(rng, 2, 3, 6, 6))
    wei = jnp.zeros((3, 3, 1, 1))
    for i in range(3):
        wei = wei.at[i, i, 0, 0].set(1.0)
    np.testing.assert_allclose(
        conv_direct.conv_direct_fprop(x, wei), x, atol=1e-5)
