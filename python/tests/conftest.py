"""Shared pytest fixtures/strategies for the Layer-1 kernel suite.

Interpret-mode Pallas is CPU-numpy speed, so hypothesis profiles keep
example counts modest and deadlines off; shapes stay in the paper's
deep-learning regime (transforms 8–64, planes/batches small multiples).
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import HealthCheck, settings

settings.register_profile(
    "kernels",
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("kernels")


@pytest.fixture(scope="session")
def rng() -> np.random.Generator:
    return np.random.default_rng(0xFBF)


def tolerance(n_fft: int, reduce_dim: int = 1) -> float:
    """Absolute tolerance scaled to accumulated-roundoff growth: DFT error
    grows ~sqrt(n·log n)·eps on unit-variance data; the reduction over
    planes/batch adds another sqrt factor."""
    return 2e-4 * float(np.sqrt(n_fft * max(1, reduce_dim)))
