"""fbfft 1-D forward/inverse kernels vs the jnp.fft oracle + FFT axioms."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import dft, fbfft, fbifft, ref

from .conftest import tolerance

POW2 = [8, 16, 32, 64, 128, 256]


def _rand(rng, *shape):
    return rng.standard_normal(shape, dtype=np.float32)


class TestFbfft1d:
    @pytest.mark.parametrize("n_fft", POW2)
    def test_matches_rfft_full_input(self, rng, n_fft):
        x = jnp.asarray(_rand(rng, 6, n_fft))
        re, im = fbfft.fbfft1d(x, n_fft)
        rr, ri = ref.rfft1d_ref(x, n_fft)
        np.testing.assert_allclose(re, rr, atol=tolerance(n_fft))
        np.testing.assert_allclose(im, ri, atol=tolerance(n_fft))

    @given(
        b=st.integers(1, 9),
        n_fft=st.sampled_from(POW2[:4]),
        frac=st.floats(0.2, 1.0),
    )
    def test_implicit_padding_equals_explicit(self, b, n_fft, frac):
        """The sliced-basis implicit pad must equal rfft of the explicitly
        zero-padded signal — the paper's zero-copy padding contract."""
        n_in = max(1, int(n_fft * frac))
        rng = np.random.default_rng(b * 1000 + n_in)
        x = jnp.asarray(_rand(rng, b, n_in))
        re, im = fbfft.fbfft1d(x, n_fft)
        xp = jnp.pad(x, ((0, 0), (0, n_fft - n_in)))
        rr, ri = ref.rfft1d_ref(xp, n_fft)
        np.testing.assert_allclose(re, rr, atol=tolerance(n_fft))
        np.testing.assert_allclose(im, ri, atol=tolerance(n_fft))

    def test_dc_bin_is_sum(self, rng):
        x = jnp.asarray(_rand(rng, 4, 32))
        re, im = fbfft.fbfft1d(x, 32)
        np.testing.assert_allclose(re[:, 0], jnp.sum(x, axis=1), rtol=1e-4)
        np.testing.assert_allclose(im[:, 0], 0.0, atol=1e-4)

    def test_linearity(self, rng):
        x = jnp.asarray(_rand(rng, 3, 24))
        y = jnp.asarray(_rand(rng, 3, 24))
        a, b = 0.7, -1.3
        re1, im1 = fbfft.fbfft1d(a * x + b * y, 32)
        rex, imx = fbfft.fbfft1d(x, 32)
        rey, imy = fbfft.fbfft1d(y, 32)
        np.testing.assert_allclose(re1, a * rex + b * rey, atol=tolerance(32))
        np.testing.assert_allclose(im1, a * imx + b * imy, atol=tolerance(32))

    def test_parseval(self, rng):
        """Σ|x|² == (1/n)·Σ m_k·|X_k|² with Hermitian fold weights."""
        n = 64
        x = jnp.asarray(_rand(rng, 5, n))
        re, im = fbfft.fbfft1d(x, n)
        m = jnp.asarray(dft.hermitian_weights(n))
        lhs = jnp.sum(x * x, axis=1)
        rhs = jnp.sum(m * (re * re + im * im), axis=1) / n
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4)

    def test_impulse_is_flat(self):
        x = jnp.zeros((1, 16)).at[0, 0].set(1.0)
        re, im = fbfft.fbfft1d(x, 16)
        np.testing.assert_allclose(re, 1.0, atol=1e-5)
        np.testing.assert_allclose(im, 0.0, atol=1e-5)

    def test_batch_not_multiple_of_panel(self, rng):
        """Batch padding must be invisible: rows past the logical batch are
        dropped, and each row's transform is independent."""
        x = jnp.asarray(_rand(rng, 130, 16))
        re, im = fbfft.fbfft1d(x, 16)
        re1, im1 = fbfft.fbfft1d(x[129:130], 16)
        np.testing.assert_allclose(re[129:130], re1, atol=1e-5)
        np.testing.assert_allclose(im[129:130], im1, atol=1e-5)
        assert re.shape == (130, 9)

    def test_rejects_oversized_input(self):
        with pytest.raises(ValueError):
            fbfft.fbfft1d(jnp.zeros((2, 33)), 32)


class TestFourStep:
    @pytest.mark.parametrize("n_fft", [16, 32, 64, 128, 256])
    def test_matches_dense_path(self, rng, n_fft):
        """The factorized Cooley–Tukey schedule and the dense MXU-DFT are
        the same transform."""
        x = jnp.asarray(_rand(rng, 4, n_fft))
        re_d, im_d = fbfft.fbfft1d(x, n_fft)
        re_f, im_f = fbfft.fbfft1d_fourstep(x, n_fft)
        np.testing.assert_allclose(re_f, re_d, atol=tolerance(n_fft))
        np.testing.assert_allclose(im_f, im_d, atol=tolerance(n_fft))

    @given(n_fft=st.sampled_from([16, 32, 64]), n_in_frac=st.floats(0.3, 1.0))
    def test_implicit_padding(self, n_fft, n_in_frac):
        n_in = max(2, int(n_fft * n_in_frac))
        rng = np.random.default_rng(n_fft + n_in)
        x = jnp.asarray(_rand(rng, 3, n_in))
        re_f, im_f = fbfft.fbfft1d_fourstep(x, n_fft)
        rr, ri = ref.rfft1d_ref(x, n_fft)
        np.testing.assert_allclose(re_f, rr, atol=tolerance(n_fft))
        np.testing.assert_allclose(im_f, ri, atol=tolerance(n_fft))

    def test_factorization_balanced(self):
        for n in [8, 16, 32, 64, 128, 256, 512, 1024]:
            n1, n2 = dft.factor_fourstep(n)
            assert n1 * n2 == n
            assert n1 <= 32 and n2 <= 32

    def test_digit_reverse_is_permutation(self):
        for n1, n2 in [(2, 4), (4, 4), (8, 16), (16, 16)]:
            p = dft.digit_reverse_perm(n1, n2)
            assert sorted(p.tolist()) == list(range(n1 * n2))


class TestFbifft1d:
    @given(
        n_fft=st.sampled_from(POW2[:4]),
        b=st.integers(1, 6),
        clip_frac=st.floats(0.2, 1.0),
    )
    def test_round_trip_with_clip(self, n_fft, b, clip_frac):
        clip = max(1, int(n_fft * clip_frac))
        rng = np.random.default_rng(n_fft * b + clip)
        x = jnp.asarray(_rand(rng, b, n_fft))
        re, im = fbfft.fbfft1d(x, n_fft)
        back = fbifft.fbifft1d(re, im, n_fft, clip=clip)
        np.testing.assert_allclose(back, x[:, :clip], atol=tolerance(n_fft))

    @pytest.mark.parametrize("n_fft", POW2[:4])
    def test_matches_irfft_oracle(self, rng, n_fft):
        nf = n_fft // 2 + 1
        re = jnp.asarray(_rand(rng, 4, nf))
        im = jnp.asarray(_rand(rng, 4, nf))
        # a physical half-spectrum has real DC/Nyquist; zero them for the
        # comparison to be exact (irfft discards them too)
        im = im.at[:, 0].set(0.0).at[:, -1].set(0.0)
        got = fbifft.fbifft1d(re, im, n_fft)
        want = ref.irfft1d_ref(re, im, n_fft, n_fft)
        np.testing.assert_allclose(got, want, atol=tolerance(n_fft))

    def test_rejects_bad_clip(self):
        with pytest.raises(ValueError):
            fbifft.fbifft1d(jnp.zeros((1, 9)), jnp.zeros((1, 9)), 16, clip=17)

    def test_rejects_bad_spectrum_width(self):
        with pytest.raises(ValueError):
            fbifft.fbifft1d(jnp.zeros((1, 8)), jnp.zeros((1, 8)), 16)
