"""Problem-shape vocabulary shared by Layer 2 and the AOT manifest.

Single source of truth for every workload the evaluation uses:

* ``ConvSpec`` — the paper's 5-D problem domain {S, f, f', n, k} extended
  to rectangular shapes;
* Table 4's representative layers L1–L5 (exact paper parameters);
* Table 2's 8,232-configuration sweep grid (Figures 1–6);
* AlexNet / OverFeat-fast convolutional layer tables (Table 3), using the
  2014 convnet-benchmarks shapes the paper's Torch harness ran;
* the §5.4 fbfft-vs-cuFFT convolution comparison grid;
* ``scale()`` — plane/batch reduction used when executing the big CNN
  shapes on the CPU-PJRT testbed (documented substitution, DESIGN.md §3).

The Rust side (rust/src/trace/) re-derives the same tables natively; the
AOT manifest carries serialized specs so the two can cross-check.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

__all__ = [
    "ConvSpec", "TABLE4_LAYERS", "alexnet_layers", "overfeat_fast_layers",
    "table2_grid", "sec54_grid", "scale",
]


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One convolutional-layer problem (paper §2 notation).

    ``h, w`` are the *padded* input sizes (paper fn. 3 folds p into the
    operand); valid-only outputs are ``yh × yw``. ``stride > 1`` marks
    layers the FFT path does not serve (paper §2: strided FFT out of
    scope) — the scheduler routes those to the vendor strategy.
    """

    name: str
    s: int        # minibatch S
    f: int        # input planes
    fo: int       # output planes f'
    h: int        # (padded) input height
    w: int        # (padded) input width
    kh: int       # kernel height
    kw: int       # kernel width
    stride: int = 1

    def __post_init__(self):
        if self.kh > self.h or self.kw > self.w:
            raise ValueError(f"{self.name}: kernel exceeds input")
        if min(self.s, self.f, self.fo, self.stride) < 1:
            raise ValueError(f"{self.name}: non-positive dimension")

    @property
    def yh(self) -> int:
        return (self.h - self.kh) // self.stride + 1

    @property
    def yw(self) -> int:
        return (self.w - self.kw) // self.stride + 1

    @property
    def problem_size(self) -> int:
        """The y-axis of Figures 1–6: S·f·f'."""
        return self.s * self.f * self.fo

    @property
    def reductions(self) -> int:
        """Time-domain multiply-adds of one fprop — the numerator of the
        paper's TRED/s metric (Table 4 col. 7)."""
        return self.s * self.f * self.fo * self.kh * self.kw * self.yh * self.yw

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "ConvSpec":
        return ConvSpec(**d)


def scale(spec: ConvSpec, planes: int = 8, batch: int | None = 8) -> ConvSpec:
    """Reduce plane counts (and optionally the minibatch) by integer
    factors for CPU-PJRT execution, preserving the spatial shape and
    therefore the FFT-vs-time-domain character of the layer."""
    return dataclasses.replace(
        spec,
        name=f"{spec.name}@/{planes}",
        s=min(spec.s, batch) if batch else spec.s,
        f=max(1, spec.f // planes),
        fo=max(1, spec.fo // planes),
    )


# ---------------------------------------------------------------------------
# Table 4 — representative layers (exact paper parameters, S = 128)
# ---------------------------------------------------------------------------

TABLE4_LAYERS: tuple[ConvSpec, ...] = (
    # L1: f=3, f'=96, h=w=128, k=11
    ConvSpec("T4.L1", 128, 3, 96, 128, 128, 11, 11),
    # L2: f=64, f'=64, h=w=64, k=9
    ConvSpec("T4.L2", 128, 64, 64, 64, 64, 9, 9),
    # L3: f=128, f'=128, h=w=32, k=9
    ConvSpec("T4.L3", 128, 128, 128, 32, 32, 9, 9),
    # L4: f=128, f'=128, h=w=16, k=7
    ConvSpec("T4.L4", 128, 128, 128, 16, 16, 7, 7),
    # L5: f=384, f'=384, h=w=13, k=3
    ConvSpec("T4.L5", 128, 384, 384, 13, 13, 3, 3),
)


# ---------------------------------------------------------------------------
# Table 3 — whole-CNN layer tables (2014 convnet-benchmarks shapes)
# ---------------------------------------------------------------------------


def alexnet_layers(s: int = 128) -> tuple[ConvSpec, ...]:
    """AlexNet (Krizhevsky 2012) convolutional layers; conv1 is strided
    and is served by the vendor path in the paper's Table 3 runs too."""
    return (
        ConvSpec("alexnet.conv1", s, 3, 64, 224, 224, 11, 11, stride=4),
        ConvSpec("alexnet.conv2", s, 64, 192, 31, 31, 5, 5),    # 27 + 2·2 pad
        ConvSpec("alexnet.conv3", s, 192, 384, 15, 15, 3, 3),   # 13 + 2·1 pad
        ConvSpec("alexnet.conv4", s, 384, 256, 15, 15, 3, 3),
        ConvSpec("alexnet.conv5", s, 256, 256, 15, 15, 3, 3),
    )


def overfeat_fast_layers(s: int = 128) -> tuple[ConvSpec, ...]:
    """OverFeat *fast* (Sermanet 2014) convolutional layers."""
    return (
        ConvSpec("overfeat.conv1", s, 3, 96, 231, 231, 11, 11, stride=4),
        ConvSpec("overfeat.conv2", s, 96, 256, 28, 28, 5, 5),
        ConvSpec("overfeat.conv3", s, 256, 512, 14, 14, 3, 3),  # 12 + 2·1 pad
        ConvSpec("overfeat.conv4", s, 512, 1024, 14, 14, 3, 3),
        ConvSpec("overfeat.conv5", s, 1024, 1024, 14, 14, 3, 3),
    )


# ---------------------------------------------------------------------------
# Table 2 — the 8,232-configuration sweep behind Figures 1–6
# ---------------------------------------------------------------------------

TABLE2_S = (1, 16, 64, 128)
TABLE2_F = (1, 4, 16, 64, 96, 128, 256)
TABLE2_FO = (1, 4, 16, 64, 96, 128, 256)
TABLE2_K = (3, 5, 7, 9, 11, 13)
TABLE2_Y = (1, 2, 4, 8, 16, 32, 64)


def table2_grid() -> Iterator[ConvSpec]:
    """All 4·7·7·6·7 = 8,232 configurations of Table 2. Parameterized on
    output size y, so h = y + k - 1 (paper fn. 8)."""
    for s in TABLE2_S:
        for f in TABLE2_F:
            for fo in TABLE2_FO:
                for k in TABLE2_K:
                    for y in TABLE2_Y:
                        n = y + k - 1
                        yield ConvSpec(
                            f"sweep.S{s}.f{f}.fo{fo}.k{k}.y{y}",
                            s, f, fo, n, n, k, k)


# ---------------------------------------------------------------------------
# §5.4 — fbfft-conv vs cuFFT-conv comparison grid
# ---------------------------------------------------------------------------


def sec54_grid() -> Iterator[ConvSpec]:
    """3×3-kernel experiments over x = h = w ∈ {13,16,27,32,57,64} and
    p = S = f = f' ∈ {16,32,64,128} (paper §5.4: mean speedup 1.51×)."""
    for x in (13, 16, 27, 32, 57, 64):
        for p in (16, 32, 64, 128):
            yield ConvSpec(f"s54.x{x}.p{p}", p, p, p, x, x, 3, 3)
