"""AOT lowering: every computation the Rust coordinator executes.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out ../artifacts

Emits, per manifest entry, an HLO **text** module (NOT a serialized
HloModuleProto: jax ≥ 0.5 emits 64-bit instruction ids that the crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids — see
/opt/xla-example/README.md) plus ``manifest.json`` describing shapes,
dtypes and workload metadata, and raw little-endian f32 ``.bin`` tensors
for the e2e CNN's initial parameters.

The artifact set covers every experiment in DESIGN.md §5:

* ``conv.*``   — (spec × strategy × pass) modules for Tables 3/4/5, the
  Figure-1–6 measured sweep subset and the §5.4 comparison grid, at the
  documented CPU scale (specs.scale);
* ``fft1d.*`` / ``fft2d.*`` — Figure-7/8 transform subjects;
* ``train.*``  — the e2e CNN train step and its initial parameters.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, specs
from .kernels import conv_fft
from .specs import ConvSpec

DTYPES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "s32"}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big literals as ``constant({...})``, which xla_extension
    0.5.1's text parser silently turns into *zeros* — the DFT basis
    matrices the fbfft kernels close over would vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    opts = xc._xla.HloPrintOptions.short_parsable()
    opts.print_large_constants = True
    return comp.as_hlo_module().to_string(opts)


@dataclasses.dataclass
class Entry:
    """One manifest entry; mirrors rust/src/runtime/manifest.rs."""

    name: str
    kind: str                      # conv | fft1d | fft2d | train_step | tensor
    hlo: str | None
    inputs: list[dict]
    outputs: list[dict]
    meta: dict


def _sds(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io(structs) -> list[dict]:
    out = []
    for s in structs:
        out.append({"shape": list(s.shape), "dtype": DTYPES[s.dtype]})
    return out


class Builder:
    """Accumulates lowered artifacts + manifest entries under --out."""

    def __init__(self, out_dir: str, only: str | None = None):
        self.out = out_dir
        self.only = only
        self.entries: list[Entry] = []
        os.makedirs(out_dir, exist_ok=True)

    def want(self, name: str) -> bool:
        return self.only is None or self.only in name

    def lower(self, name: str, kind: str, fn: Callable,
              args: Sequence[jax.ShapeDtypeStruct], meta: dict):
        if not self.want(name):
            return
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out, fname), "w") as f:
            f.write(text)
        out_shapes = lowered.out_info
        flat, _ = jax.tree.flatten(out_shapes)
        self.entries.append(Entry(
            name=name, kind=kind, hlo=fname,
            inputs=_io(args),
            outputs=[{"shape": [int(d) for d in o.shape],
                      "dtype": DTYPES[jnp.dtype(o.dtype)]} for o in flat],
            meta=meta))
        print(f"  {fname}: {len(text)} chars, {len(flat)} outputs")

    def tensor(self, name: str, arr: np.ndarray, meta: dict):
        """Raw little-endian tensor artifact (initial parameters etc.)."""
        if not self.want(name):
            return
        arr = np.ascontiguousarray(arr, dtype="<f4")
        fname = f"{name}.bin"
        arr.tofile(os.path.join(self.out, fname))
        self.entries.append(Entry(
            name=name, kind="tensor", hlo=fname,
            inputs=[], outputs=[{"shape": list(arr.shape), "dtype": "f32"}],
            meta=meta))
        print(f"  {fname}: {arr.size * 4} bytes")

    def finish(self):
        man = {
            "version": 1,
            "entries": [dataclasses.asdict(e) for e in self.entries],
        }
        with open(os.path.join(self.out, "manifest.json"), "w") as f:
            json.dump(man, f, indent=1)
        print(f"manifest: {len(self.entries)} entries")


# ---------------------------------------------------------------------------
# Convolution artifacts
# ---------------------------------------------------------------------------

PASSES = ("fprop", "bprop", "accgrad")


def conv_entry(b: Builder, spec: ConvSpec, strategy: str, pas: str,
               origin: str, paper_spec: ConvSpec | None = None):
    """Lower one (spec, strategy, pass) conv module."""
    name = f"conv.{spec.name}.{strategy}.{pas}".replace("/", "_")
    meta = {
        "origin": origin, "strategy": strategy, "pass": pas,
        "spec": spec.to_json(),
        "paper_spec": (paper_spec or spec).to_json(),
        "n_fft": (None if strategy in ("vendor", "direct", "im2col")
                  else conv_fft.min_fft_size(spec.h, spec.w)),
        "reductions": spec.reductions,
    }
    x = _sds(spec.s, spec.f, spec.h, spec.w)
    wei = _sds(spec.fo, spec.f, spec.kh, spec.kw)
    go = _sds(spec.s, spec.fo, spec.yh, spec.yw)
    if pas == "fprop":
        b.lower(name, "conv",
                lambda xx, ww: model.fprop(spec, strategy, xx, ww),
                (x, wei), meta)
    elif pas == "bprop":
        b.lower(name, "conv",
                lambda gg, ww: model.bprop(spec, strategy, gg, ww),
                (go, wei), meta)
    else:
        b.lower(name, "conv",
                lambda gg, xx: model.accgrad(spec, strategy, gg, xx),
                (go, x), meta)


def build_table4(b: Builder):
    """Table 4/5: L1–L5 at documented scale × 3 strategies × 3 passes."""
    print("== table4 ==")
    for paper in specs.TABLE4_LAYERS:
        sp = specs.scale(paper, planes=8, batch=8)
        for strat in ("vendor", "vendor_fft", "fbfft"):
            for pas in PASSES:
                conv_entry(b, sp, strat, pas, "table4", paper)


def build_table3(b: Builder):
    """Table 3: AlexNet + OverFeat-fast layers at scale. Three kernels as
    in the paper: vendor (cuDNN analogue), fbfft, direct (ccn2 analogue —
    cuda-convnet2's direct time-domain approach). Strided conv1 is
    vendor-only, as in the paper's runs."""
    print("== table3 ==")
    for net in (specs.alexnet_layers(), specs.overfeat_fast_layers()):
        for paper in net:
            sp = specs.scale(paper, planes=8, batch=4)
            strats = (("vendor",) if sp.stride != 1
                      else ("vendor", "fbfft", "direct"))
            for strat in strats:
                for pas in PASSES:
                    conv_entry(b, sp, strat, pas, "table3", paper)


def build_sweep(b: Builder):
    """Figures 1–6 measured subset: k × y grid at fixed S=f=f'=16; the
    full 8,232-point plane is filled by the Rust cost model anchored on
    these measurements (DESIGN.md §3)."""
    print("== sweep ==")
    for k in (3, 5, 9, 13):
        for y in (4, 8, 16, 32):
            n = y + k - 1
            paper = ConvSpec(f"swp.k{k}.y{y}", 16, 16, 16, n, n, k, k)
            for strat in ("vendor", "fbfft"):
                conv_entry(b, paper, strat, "fprop", "sweep")


def build_sec54(b: Builder):
    """§5.4: fbfft-conv vs vendor-fft-conv, 3×3 kernels. All three passes
    for the small sizes, fprop for the large ones."""
    print("== sec54 ==")
    for x in (13, 16, 27, 32, 57, 64):
        paper = ConvSpec(f"s54.x{x}", 16, 16, 16, x, x, 3, 3)
        passes = PASSES if x <= 32 else ("fprop",)
        for strat in ("vendor_fft", "fbfft"):
            for pas in passes:
                conv_entry(b, paper, strat, pas, "sec54")


def build_quickstart(b: Builder):
    print("== quickstart ==")
    sp = ConvSpec("quickstart", 2, 4, 4, 16, 16, 3, 3)
    for strat in ("vendor", "fbfft"):
        conv_entry(b, sp, strat, "fprop", "quickstart")


def build_tiling(b: Builder):
    """§6: tiled vs untiled fbfft conv on a large-input / small-kernel
    layer (the regime the decomposition targets)."""
    print("== tiling ==")
    paper = ConvSpec("tile.x57", 8, 16, 16, 57, 57, 3, 3)
    conv_entry(b, paper, "fbfft", "fprop", "tiling")
    name = "conv.tile.x57.fbfft_tiled.fprop"
    x = _sds(paper.s, paper.f, paper.h, paper.w)
    wei = _sds(paper.fo, paper.f, paper.kh, paper.kw)
    for d in (4, 8, 16):
        b.lower(f"{name}.d{d}", "conv",
                lambda xx, ww, dd=d: model.fprop(paper, "fbfft_tiled",
                                                 xx, ww, tile=dd),
                (x, wei),
                {"origin": "tiling", "strategy": "fbfft_tiled",
                 "pass": "fprop", "tile": d, "spec": paper.to_json(),
                 "paper_spec": paper.to_json(), "n_fft": None,
                 "reductions": paper.reductions})


# ---------------------------------------------------------------------------
# Transform artifacts (Figures 7–8)
# ---------------------------------------------------------------------------


def build_fft(b: Builder):
    print("== fft ==")
    for n in (8, 32, 64, 128, 256):
        batch = 4096
        x = _sds(batch, n)
        for which, fn in (("fbfft", model.fft1d_fbfft),
                          ("vendor", model.fft1d_vendor)):
            b.lower(f"fft1d.n{n}.b{batch}.{which}", "fft1d",
                    lambda xx, nn=n, f=fn: f(xx, nn), (x,),
                    {"n": n, "batch": batch, "which": which, "dim": 1})
    for n in (8, 16, 32, 64):
        batch = 256
        x = _sds(batch, n, n)
        for which, fn in (("fbfft", model.fft2d_fbfft),
                          ("vendor", model.fft2d_vendor)):
            b.lower(f"fft2d.n{n}.b{batch}.{which}", "fft2d",
                    lambda xx, nn=n, f=fn: f(xx, nn), (x,),
                    {"n": n, "batch": batch, "which": which, "dim": 2})


# ---------------------------------------------------------------------------
# Train-step artifacts (e2e example)
# ---------------------------------------------------------------------------

PARAM_ORDER = ("conv1", "conv2", "dense_w", "dense_b")


def build_train(b: Builder):
    print("== train ==")
    cfg = model.TrainConfig()
    params = model.cnn_init(cfg, jax.random.PRNGKey(0xFB))

    def step_flat(c1, c2, dw, db, x, y):
        p = {"conv1": c1, "conv2": c2, "dense_w": dw, "dense_b": db}
        new, loss = model.train_step(cfg, p, x, y)
        return tuple(new[k] for k in PARAM_ORDER) + (loss,)

    args = tuple(_sds(*params[k].shape) for k in PARAM_ORDER) + (
        _sds(cfg.s, cfg.c, cfg.hw, cfg.hw),
        _sds(cfg.s, dtype=jnp.int32),
    )
    b.lower("train.step", "train_step", step_flat, args,
            {"config": cfg.to_json(), "param_order": list(PARAM_ORDER)})

    def logits_flat(c1, c2, dw, db, x):
        p = {"conv1": c1, "conv2": c2, "dense_w": dw, "dense_b": db}
        return (model.cnn_apply(cfg, p, x),)

    b.lower("train.logits", "train_step", logits_flat, args[:-1],
            {"config": cfg.to_json(), "param_order": list(PARAM_ORDER)})

    for k in PARAM_ORDER:
        b.tensor(f"train.init.{k}", np.asarray(params[k]),
                 {"param": k, "config": cfg.to_json()})


BUILDERS = {
    "quickstart": build_quickstart,
    "table4": build_table4,
    "table3": build_table3,
    "sweep": build_sweep,
    "sec54": build_sec54,
    "tiling": build_tiling,
    "fft": build_fft,
    "train": build_train,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    ap.add_argument("--groups", default=",".join(BUILDERS),
                    help="comma list of artifact groups")
    ns = ap.parse_args()
    b = Builder(ns.out, ns.only)
    for g in ns.groups.split(","):
        BUILDERS[g.strip()](b)
    b.finish()


if __name__ == "__main__":
    main()
