"""Pure-jnp correctness oracles for every Layer-1 kernel.

Two independent families:

* ``lax.conv_general_dilated``-based time-domain convolutions — the
  'vendor black box' analogue of cuDNN (DESIGN.md §3) and the ground
  truth for all three training passes;
* ``jnp.fft``-based frequency-domain convolutions — the 'vendor FFT'
  analogue of cuFFT, validating the conv-theorem plumbing (conjugation
  sides, clip windows) separately from the Pallas transform kernels.

Everything here is also *used at Layer 2* as the two vendor strategies the
paper benchmarks against, so these oracles are production code paths, not
test-only helpers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "rfft1d_ref", "irfft1d_ref", "rfft2d_ref_transposed",
    "conv_fprop_ref", "conv_bprop_ref", "conv_accgrad_ref",
    "conv_fprop_fft_ref", "conv_bprop_fft_ref", "conv_accgrad_fft_ref",
]


# ---------------------------------------------------------------------------
# FFT oracles
# ---------------------------------------------------------------------------


def rfft1d_ref(x: jax.Array, n_fft: int):
    """(re, im) planes of ``rfft`` with zero padding to ``n_fft``."""
    f = jnp.fft.rfft(x, n=n_fft, axis=-1)
    return jnp.real(f).astype(jnp.float32), jnp.imag(f).astype(jnp.float32)


def irfft1d_ref(re: jax.Array, im: jax.Array, n_fft: int, clip: int):
    """Real inverse of half-spectrum planes, clipped."""
    x = jnp.fft.irfft(re + 1j * im, n=n_fft, axis=-1)
    return x[..., :clip].astype(jnp.float32)


def rfft2d_ref_transposed(x: jax.Array, n_fft: int):
    """(re, im) planes in fbfft's transposed layout ``(nf, n, B)`` for a
    batch ``(B, h, w)`` — the oracle for ``fbfft2d``'s fused transpose."""
    f = jnp.fft.rfft2(x, s=(n_fft, n_fft), axes=(-2, -1))   # (B, n, nf)
    ft = jnp.transpose(f, (2, 1, 0))                         # (nf, kh, B)
    return (jnp.real(ft).astype(jnp.float32),
            jnp.imag(ft).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Time-domain convolution oracles (the cuDNN-analogue vendor path)
# ---------------------------------------------------------------------------


@jax.jit
def conv_fprop_ref(x: jax.Array, wei: jax.Array) -> jax.Array:
    """Valid cross-correlation ``y[s,j] = Σ_i x[s,i] ⋆ w[j,i]``.

    XLA's ``conv_general_dilated`` already cross-correlates (no kernel
    flip), matching Torch forward-pass semantics (paper fn. 1).
    """
    return lax.conv_general_dilated(
        x, wei,
        window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


@functools.partial(jax.jit, static_argnums=(2, 3))
def conv_bprop_ref(go: jax.Array, wei: jax.Array, h: int, w: int) -> jax.Array:
    """Full convolution ``gx[s,i] = Σ_j go[s,j] * w[j,i]``: transposed-conv
    identity — pad the gradient by k-1 and cross-correlate with the
    *flipped* kernel (XLA correlates, so the flip realizes convolution)
    with in/out planes swapped."""
    kh, kw = wei.shape[-2], wei.shape[-1]
    del h, w  # implied: y_h + kh - 1, y_w + kw - 1
    return lax.conv_general_dilated(
        go, jnp.flip(jnp.transpose(wei, (1, 0, 2, 3)), (-2, -1)),
        window_strides=(1, 1),
        padding=((kh - 1, kh - 1), (kw - 1, kw - 1)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


@functools.partial(jax.jit, static_argnums=(2, 3))
def conv_accgrad_ref(go: jax.Array, x: jax.Array, kh: int, kw: int) -> jax.Array:
    """Weight gradient ``gw[j,i] = Σ_s go[s,j] ⋆ x[s,i]`` via the
    batch-as-reduction trick: correlate x (planes as batch) against go
    (batch as planes), then swap back."""
    # x: (S, f, h, w) -> (f, S, h, w); go: (S, f', yh, yw) -> (f', S, yh, yw)
    xt = jnp.transpose(x, (1, 0, 2, 3))
    got = jnp.transpose(go, (1, 0, 2, 3))
    # valid correlation of xt with got as the kernel -> (f, f', kh, kw)
    gw = lax.conv_general_dilated(
        xt, got,
        window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    del kh, kw  # implied by shapes
    return jnp.transpose(gw, (1, 0, 2, 3))


# ---------------------------------------------------------------------------
# jnp.fft convolution oracles (the cuFFT-analogue vendor path)
# ---------------------------------------------------------------------------


def _freq(x: jax.Array, n: int) -> jax.Array:
    return jnp.fft.rfft2(x, s=(n, n), axes=(-2, -1))


@functools.partial(jax.jit, static_argnums=(2,))
def conv_fprop_fft_ref(x: jax.Array, wei: jax.Array, n_fft: int) -> jax.Array:
    """fprop by the convolution theorem: ``IFFT(X ∘ conj(W))`` reduced over
    input planes, clipped to the valid window. Arbitrary ``n_fft >= h`` —
    this is the path on which the autotuner's 2^a3^b5^c7^d basis search
    operates (paper §3.4)."""
    s, f, h, w = x.shape
    fo, _, kh, kw = wei.shape
    xf = _freq(x, n_fft)                       # (S, f, n, nf)
    wf = _freq(wei, n_fft)                     # (f', f, n, nf)
    of = jnp.einsum("sfnk,jfnk->sjnk", xf, jnp.conj(wf))
    y = jnp.fft.irfft2(of, s=(n_fft, n_fft), axes=(-2, -1))
    return y[:, :, : h - kh + 1, : w - kw + 1].astype(jnp.float32)


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def conv_bprop_fft_ref(go: jax.Array, wei: jax.Array, n_fft: int,
                       h: int, w: int) -> jax.Array:
    """bprop by the convolution theorem: plain product, no conjugation."""
    gof = _freq(go, n_fft)
    wf = _freq(wei, n_fft)
    gxf = jnp.einsum("sjnk,jfnk->sfnk", gof, wf)
    gx = jnp.fft.irfft2(gxf, s=(n_fft, n_fft), axes=(-2, -1))
    return gx[:, :, :h, :w].astype(jnp.float32)


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def conv_accgrad_fft_ref(go: jax.Array, x: jax.Array, n_fft: int,
                         kh: int, kw: int) -> jax.Array:
    """accGrad by the convolution theorem: conjugate the output gradient,
    reduce over the minibatch."""
    gof = _freq(go, n_fft)
    xf = _freq(x, n_fft)
    gwf = jnp.einsum("sjnk,sfnk->jfnk", jnp.conj(gof), xf)
    gw = jnp.fft.irfft2(gwf, s=(n_fft, n_fft), axes=(-2, -1))
    return gw[:, :, :kh, :kw].astype(jnp.float32)
