"""Matrix-unrolling (im2col + GEMM) convolution as a Pallas kernel.

The strategy behind cuDNN's general-purpose path (Chellapilla et al. 2006,
paper §2): unroll input windows into a patch matrix so the convolution
becomes one large matrix multiplication — 'a well-tuned linear algebra
primitive available on virtually any platform'. On TPU the GEMM *is* the
MXU's native operation, so this is the strongest time-domain baseline.

Schedule: one grid step per sample. The unroll is built in VMEM from
k·k statically-shifted views (no HBM-side duplication — the k²×
memory blowup of classical im2col never leaves the tile), then a single
``(y_h·y_w, f·kh·kw) @ (f·kh·kw, f')`` MXU contraction produces every
output plane of the sample at once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["conv_im2col_fprop"]


def _im2col_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int):
    x = x_ref[...]                     # (1, f, h, w)
    wei = w_ref[...]                   # (f', f, kh, kw)
    f = x.shape[1]
    h, w = x.shape[2], x.shape[3]
    fo = wei.shape[0]
    yh, yw = h - kh + 1, w - kw + 1
    # unroll: patches[p, (i,u,v)] with p = spatial output index
    cols = []
    for u in range(kh):
        for v in range(kw):
            cols.append(x[0, :, u:u + yh, v:v + yw].reshape(f, yh * yw))
    # (kh·kw, f, yh·yw) -> (yh·yw, f·kh·kw) with (i,u,v) fastest on taps
    patches = jnp.stack(cols).reshape(kh * kw, f, yh * yw)
    patches = jnp.transpose(patches, (2, 1, 0)).reshape(yh * yw, f * kh * kw)
    wmat = wei.reshape(fo, f * kh * kw)
    out = jnp.dot(patches, wmat.T, preferred_element_type=jnp.float32)
    o_ref[...] = out.T.reshape(1, fo, yh, yw)


@jax.jit
def conv_im2col_fprop(x: jax.Array, wei: jax.Array) -> jax.Array:
    """im2col+GEMM valid cross-correlation, same contract as
    :func:`kernels.conv_direct.conv_direct_fprop`."""
    s, f, h, w = x.shape
    fo, f2, kh, kw = wei.shape
    assert f == f2, f"plane mismatch: {f} vs {f2}"
    yh, yw = h - kh + 1, w - kw + 1
    kern = functools.partial(_im2col_kernel, kh=kh, kw=kw)
    return pl.pallas_call(
        kern,
        grid=(s,),
        in_specs=[
            pl.BlockSpec((1, f, h, w), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((fo, f, kh, kw), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, fo, yh, yw), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((s, fo, yh, yw), jnp.float32),
        interpret=True,
    )(x, wei)
