"""fbfft forward transforms as Pallas kernels (Layer 1).

Batched 1-D and 2-D real-to-complex FFTs specialized for the deep-learning
regime the paper targets: transform sizes 8–256, batch counts in the
thousands-to-millions. Three of the paper's key ideas survive the GPU→TPU
translation intact (DESIGN.md §2):

* **implicit zero-copy padding** — inputs shorter than the Fourier basis
  are never padded in memory; the DFT matrices are sliced to the logical
  input length instead (see ``kernels.dft``);
* **fused transpose** — the 2-D kernel writes its output directly in the
  frequency-transposed ``(nf, n, batch)`` layout the downstream CGEMM
  stage consumes, eliding the separate transposition pass the cuFFT
  pipeline pays for (paper Table 5 'TRANS.' columns);
* **Hermitian symmetry** — only ``n//2 + 1`` bins are produced along the
  halved axis.

Each transform batch-panel is resident in a single VMEM tile for its whole
lifetime: load once from HBM, two MXU contractions (+ optional twiddle
stage), store once. ``interpret=True`` everywhere — the CPU PJRT client
cannot execute Mosaic custom calls; real-TPU performance is estimated
analytically (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import dft

__all__ = ["fbfft1d", "fbfft1d_fourstep", "fbfft2d", "DEFAULT_PANEL"]

# Rows of a batch panel processed by one grid step. 128 matches the MXU
# lane width; smaller batches are padded up by the wrappers below.
DEFAULT_PANEL = 128


def _eff_panel(b: int, panel: int) -> int:
    """Shrink the panel for small batches so padding waste stays bounded
    (a batch of 4 should not be padded to 128 rows)."""
    return min(panel, dft.next_pow2(max(8, b)))


def _pad_batch(x: jax.Array, panel: int) -> tuple[jax.Array, int]:
    """Pad the leading (batch) dim up to a multiple of ``panel``."""
    b = x.shape[0]
    rem = (-b) % panel
    if rem:
        x = jnp.pad(x, [(0, rem)] + [(0, 0)] * (x.ndim - 1))
    return x, b


# ---------------------------------------------------------------------------
# 1-D R2C, dense MXU-DFT path (the default for n <= 256)
# ---------------------------------------------------------------------------


def _fbfft1d_kernel(x_ref, c_ref, s_ref, re_ref, im_ref):
    """One batch panel: (panel, n_in) @ (n_in, nf) on the MXU, twice."""
    x = x_ref[...]
    re_ref[...] = jnp.dot(x, c_ref[...], preferred_element_type=jnp.float32)
    im_ref[...] = jnp.dot(x, s_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnums=(1, 2))
def fbfft1d(x: jax.Array, n_fft: int, panel: int = DEFAULT_PANEL):
    """Batched 1-D R2C FFT of a real array ``x`` of shape ``(B, n_in)`` on a
    Fourier basis of size ``n_fft >= n_in`` (implicit zero padding).

    Returns ``(re, im)``, each ``(B, n_fft//2 + 1)`` float32 — equal to
    ``jnp.fft.rfft(x, n_fft)`` split into planes.
    """
    b_logical, n_in = x.shape
    if n_in > n_fft:
        raise ValueError(f"input length {n_in} exceeds fft size {n_fft}")
    nf = n_fft // 2 + 1
    c, s = dft.rfft_basis(n_in, n_fft)
    panel = _eff_panel(b_logical, panel)
    x, _ = _pad_batch(x, panel)
    b = x.shape[0]
    grid = (b // panel,)
    re, im = pl.pallas_call(
        _fbfft1d_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((panel, n_in), lambda i: (i, 0)),
            pl.BlockSpec((n_in, nf), lambda i: (0, 0)),
            pl.BlockSpec((n_in, nf), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((panel, nf), lambda i: (i, 0)),
            pl.BlockSpec((panel, nf), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nf), jnp.float32),
            jax.ShapeDtypeStruct((b, nf), jnp.float32),
        ],
        interpret=True,
    )(x, jnp.asarray(c), jnp.asarray(s))
    return re[:b_logical], im[:b_logical]


# ---------------------------------------------------------------------------
# 1-D R2C, four-step Cooley–Tukey path (n = n1·n2, the paper's §5.3 regime)
# ---------------------------------------------------------------------------


def _fourstep_kernel(x_ref, c1_ref, s1_ref, tc_ref, ts_ref, c2_ref, s2_ref,
                     perm_ref, re_ref, im_ref, *, n1: int, n2: int, nf: int):
    """Four-step FFT of one batch panel, fully VMEM-resident.

    Stage 1: column DFTs of the (n1, n2) reshape — an MXU contraction over
    j1.  Stage 2: twiddle plane on the VPU.  Stage 3: row DFTs — a second
    MXU contraction over j2.  Stage 4: digit-reversal gather restoring
    natural bin order (the paper's cross-register bit reversal, §5.3,
    becomes a static permutation folded into the store).
    """
    n = n1 * n2
    x = x_ref[...]                      # (panel, n_in), real
    panel = x.shape[0]
    # zero-extend logical input to n inside VMEM (free relative to HBM);
    # shorter inputs arrive already truncated by the BlockSpec.
    if x.shape[1] < n:
        x = jnp.pad(x, ((0, 0), (0, n - x.shape[1])))
    # j = j1*n2 + j2  →  reshape to (panel, n1[j1], n2[j2])
    a = x.reshape(panel, n1, n2)
    # Stage 1: Y[k1, j2] = Σ_j1 a[j1, j2]·W_{n1}^{j1·k1}   (real input)
    yr = jnp.einsum("bjt,jk->bkt", a, c1_ref[...])
    yi = jnp.einsum("bjt,jk->bkt", a, s1_ref[...])
    # Stage 2: twiddle by W_n^{k1·j2}
    tc = tc_ref[...][None, :, :]
    ts = ts_ref[...][None, :, :]
    zr = yr * tc - yi * ts
    zi = yr * ts + yi * tc
    # Stage 3: X[k1, k2] = Σ_j2 Z[k1, j2]·W_{n2}^{j2·k2}
    xr = jnp.einsum("bkt,tm->bkm", zr, c2_ref[...]) - jnp.einsum(
        "bkt,tm->bkm", zi, s2_ref[...])
    xi = jnp.einsum("bkt,tm->bkm", zr, s2_ref[...]) + jnp.einsum(
        "bkt,tm->bkm", zi, c2_ref[...])
    # Stage 4: natural order k = k2·n1 + k1 via static gather, keep the
    # Hermitian half only.
    perm = perm_ref[...]
    xr = xr.reshape(panel, n)[:, perm]
    xi = xi.reshape(panel, n)[:, perm]
    re_ref[...] = xr[:, :nf]
    im_ref[...] = xi[:, :nf]


@functools.partial(jax.jit, static_argnums=(1, 2))
def fbfft1d_fourstep(x: jax.Array, n_fft: int, panel: int = DEFAULT_PANEL):
    """Batched 1-D R2C FFT via the four-step n = n1·n2 decomposition.

    Numerically identical to :func:`fbfft1d`; exists to reproduce the
    paper's Cooley–Tukey register decomposition in TPU form and to let the
    benches compare the dense-DFT and factorized schedules.
    """
    b_logical, n_in = x.shape
    if n_in > n_fft:
        raise ValueError(f"input length {n_in} exceeds fft size {n_fft}")
    n1, n2 = dft.factor_fourstep(n_fft)
    nf = n_fft // 2 + 1
    c1, s1 = dft.cfft_basis(n1, n1)
    tc, ts = dft.twiddle(n1, n2)
    c2, s2 = dft.cfft_basis(n2, n2)
    perm = dft.digit_reverse_perm(n1, n2)
    panel = _eff_panel(b_logical, panel)
    x, _ = _pad_batch(x, panel)
    b = x.shape[0]
    kern = functools.partial(_fourstep_kernel, n1=n1, n2=n2, nf=nf)
    re, im = pl.pallas_call(
        kern,
        grid=(b // panel,),
        in_specs=[
            pl.BlockSpec((panel, n_in), lambda i: (i, 0)),
            pl.BlockSpec((n1, n1), lambda i: (0, 0)),
            pl.BlockSpec((n1, n1), lambda i: (0, 0)),
            pl.BlockSpec((n1, n2), lambda i: (0, 0)),
            pl.BlockSpec((n1, n2), lambda i: (0, 0)),
            pl.BlockSpec((n2, n2), lambda i: (0, 0)),
            pl.BlockSpec((n2, n2), lambda i: (0, 0)),
            pl.BlockSpec((n_fft,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((panel, nf), lambda i: (i, 0)),
            pl.BlockSpec((panel, nf), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nf), jnp.float32),
            jax.ShapeDtypeStruct((b, nf), jnp.float32),
        ],
        interpret=True,
    )(x, jnp.asarray(c1), jnp.asarray(s1), jnp.asarray(tc), jnp.asarray(ts),
      jnp.asarray(c2), jnp.asarray(s2), jnp.asarray(perm))
    return re[:b_logical], im[:b_logical]


# ---------------------------------------------------------------------------
# 2-D R2C with fused transpose (the convolution building block)
# ---------------------------------------------------------------------------


def _fbfft2d_kernel(x_ref, cw_ref, sw_ref, ch_ref, sh_ref, re_ref, im_ref):
    """2-D R2C FFT of one batch panel, output frequency-transposed.

    Row–column decomposition, both passes MXU contractions on the same
    VMEM-resident panel:

      1. width axis (R2C, halved):  G[b, h, kw] = Σ_w x[b, h, w]·W^{w·kw}
      2. height axis (C2C, full),  *written transposed*:
         FT[kw, kh, b] = Σ_h G[b, h, kw]·W^{h·kh}

    The output tile is ``(nf, n, panel)`` — the 'HWBD' layout of the
    paper's Table 1, produced directly instead of via a Cgeam transpose
    pass. The einsum output ordering performs the in-VMEM transpose, the
    analogue of the paper's in-SMEM warp transpose (§5.2).
    """
    x = x_ref[...]                      # (panel, h_in, w_in)
    gr = jnp.einsum("bhw,wk->bhk", x, cw_ref[...])
    gi = jnp.einsum("bhw,wk->bhk", x, sw_ref[...])
    ch, sh = ch_ref[...], sh_ref[...]
    # contraction over h; output axes ordered (kw, kh, b) = fused transpose
    re_ref[...] = (jnp.einsum("bhk,hm->kmb", gr, ch)
                   - jnp.einsum("bhk,hm->kmb", gi, sh))
    im_ref[...] = (jnp.einsum("bhk,hm->kmb", gr, sh)
                   + jnp.einsum("bhk,hm->kmb", gi, ch))


@functools.partial(jax.jit, static_argnums=(1, 2))
def fbfft2d(x: jax.Array, n_fft: int, panel: int = DEFAULT_PANEL):
    """Batched 2-D R2C FFT with fused frequency transpose.

    ``x``: real ``(B, h_in, w_in)`` with ``h_in, w_in <= n_fft``; the basis
    is square ``n_fft × n_fft`` (fbfft supports square power-of-two
    transforms, paper §6).

    Returns ``(re, im)`` of shape ``(n_fft//2 + 1, n_fft, B)``:
    ``out[kw, kh, b] == jnp.fft.rfft2(pad(x[b]))[kh, kw]`` — note the
    transposed (kw, kh) frequency layout *and* batch-innermost ordering,
    ready for the per-bin CGEMM stage with zero intermediate transposes.
    """
    b_logical, h_in, w_in = x.shape
    if h_in > n_fft or w_in > n_fft:
        raise ValueError(f"input {h_in}x{w_in} exceeds fft size {n_fft}")
    nf = n_fft // 2 + 1
    cw, sw = dft.rfft_basis(w_in, n_fft)
    ch, sh = dft.cfft_basis(h_in, n_fft)
    panel = _eff_panel(b_logical, panel)
    x, _ = _pad_batch(x, panel)
    b = x.shape[0]
    re, im = pl.pallas_call(
        _fbfft2d_kernel,
        grid=(b // panel,),
        in_specs=[
            pl.BlockSpec((panel, h_in, w_in), lambda i: (i, 0, 0)),
            pl.BlockSpec((w_in, nf), lambda i: (0, 0)),
            pl.BlockSpec((w_in, nf), lambda i: (0, 0)),
            pl.BlockSpec((h_in, n_fft), lambda i: (0, 0)),
            pl.BlockSpec((h_in, n_fft), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((nf, n_fft, panel), lambda i: (0, 0, i)),
            pl.BlockSpec((nf, n_fft, panel), lambda i: (0, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nf, n_fft, b), jnp.float32),
            jax.ShapeDtypeStruct((nf, n_fft, b), jnp.float32),
        ],
        interpret=True,
    )(x, jnp.asarray(cw), jnp.asarray(sw), jnp.asarray(ch), jnp.asarray(sh))
    return re[:, :, :b_logical], im[:, :, :b_logical]
