"""Layer-1 Pallas kernels for the fbfft reproduction.

Modules:
  dft         — DFT basis construction (shared constants)
  fbfft       — forward batched 1-D/2-D R2C transforms
  fbifft      — inverse C2R transforms with fused clipping
  pointwise   — per-frequency-bin CGEMM stage (all three passes)
  conv_fft    — the composed frequency-domain convolution pipeline
  conv_direct — time-domain direct convolution kernel
  conv_im2col — matrix-unrolling convolution kernel (cuDNN-style)
  tiling      — §6 tiled decomposition of large inputs
  ref         — pure-jnp oracles (also the two 'vendor' strategies)
"""
