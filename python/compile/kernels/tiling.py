"""§6 tiling: decompose a large convolution into many small fbfft ones.

fbfft provides its largest gains over the vendor FFT at transform sizes
8–64 (paper §5.4), and those sizes depend on the *kernel*, not the input:
when k ≪ h the input can be cut into tiles of size ``d + k - 1`` with
``d ≈ k``, dropping the FFT cost from O(n·log n) to O(n·log w) per the
paper's derivation, while every per-tile transform lands in fbfft's sweet
spot.

Three decompositions, exactly the paper's:

* **fprop** — overlap-save: output tile ``y[a:a+d] = x[a:a+d+k-1] ⋆ c``;
  tiles read overlapping input windows and write disjoint outputs.
* **bprop** — overlap-add: full convolution is linear in the gradient, so
  each gradient tile scatters its ``d+k-1``-wide contribution additively.
* **accGrad** — the paper's §6 identity: the big correlation against the
  (n-w+1)-sized gradient 'kernel' splits into a sum of tile-local
  correlations, one term per tile (plus the remainder tile).

Every per-tile convolution goes through the ordinary fbfft pipeline
(`conv_fft`) on the small basis ``next_pow2(d + k - 1)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import dft
from . import conv_fft

__all__ = ["conv_fprop_tiled", "conv_bprop_tiled", "conv_accgrad_tiled",
           "tile_fft_size"]


def tile_fft_size(d: int, kh: int, kw: int) -> int:
    """Fourier basis for a tile: covers the (d+k-1)-sized input window."""
    return dft.next_pow2(max(d + kh - 1, d + kw - 1))


def _tile_ranges(total: int, d: int):
    """(offset, size) pairs covering ``range(total)`` in steps of ``d``;
    the last tile may be short (the paper's remainder term)."""
    out = []
    a = 0
    while a < total:
        out.append((a, min(d, total - a)))
        a += d
    return out


@functools.partial(jax.jit, static_argnums=(2,))
def conv_fprop_tiled(x: jax.Array, wei: jax.Array, d: int) -> jax.Array:
    """Tiled forward pass (overlap-save), tile output size ``d``.

    Equivalent to :func:`conv_fft.conv_fprop` on the full plane; each tile
    runs the fbfft pipeline at basis ``tile_fft_size`` instead of
    ``next_pow2(h)``.
    """
    s, f, h, w = x.shape
    fo, _, kh, kw = wei.shape
    yh, yw = h - kh + 1, w - kw + 1
    n_t = tile_fft_size(d, kh, kw)
    y = jnp.zeros((s, fo, yh, yw), dtype=jnp.float32)
    for (ah, dh) in _tile_ranges(yh, d):
        for (aw, dw) in _tile_ranges(yw, d):
            xt = x[:, :, ah:ah + dh + kh - 1, aw:aw + dw + kw - 1]
            yt = conv_fft.conv_fprop(xt, wei, n_t)
            y = y.at[:, :, ah:ah + dh, aw:aw + dw].set(yt)
    return y


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def conv_bprop_tiled(go: jax.Array, wei: jax.Array, d: int,
                     h: int, w: int) -> jax.Array:
    """Tiled backward-by-data (overlap-add).

    Each gradient tile of size ``d`` contributes a ``d+k-1`` window to the
    input gradient; contributions overlap by ``k-1`` and are summed.
    """
    s, fo, yh, yw = go.shape
    _, f, kh, kw = wei.shape
    n_t = tile_fft_size(d, kh, kw)
    gx = jnp.zeros((s, f, h, w), dtype=jnp.float32)
    for (ah, dh) in _tile_ranges(yh, d):
        for (aw, dw) in _tile_ranges(yw, d):
            got = go[:, :, ah:ah + dh, aw:aw + dw]
            gxt = conv_fft.conv_bprop(got, wei, n_t,
                                      dh + kh - 1, dw + kw - 1)
            gx = gx.at[:, :, ah:ah + dh + kh - 1,
                       aw:aw + dw + kw - 1].add(gxt)
    return gx


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def conv_accgrad_tiled(go: jax.Array, x: jax.Array, d: int,
                       kh: int, kw: int) -> jax.Array:
    """Tiled weight gradient — the paper's §6 sum of tile correlations:

        ∂L/∂c = Σ_t  x[t·d : (t+1)·d + k - 1] ⋆ z[t·d : (t+1)·d]

    (2-D over both spatial axes, remainder tiles included).
    """
    s, fo, yh, yw = go.shape
    n_t = tile_fft_size(d, kh, kw)
    gw = None
    for (ah, dh) in _tile_ranges(yh, d):
        for (aw, dw) in _tile_ranges(yw, d):
            got = go[:, :, ah:ah + dh, aw:aw + dw]
            xt = x[:, :, ah:ah + dh + kh - 1, aw:aw + dw + kw - 1]
            gwt = conv_fft.conv_accgrad(got, xt, n_t, kh, kw)
            gw = gwt if gw is None else gw + gwt
    return gw
