"""fbfft frequency-domain convolution — the paper's full pipeline (L1+L2).

Composes the three Pallas stages exactly as the paper's Table 1 does,
minus the two transpose passes that fbfft's fused layouts eliminate:

    FFT2D (fused transpose) → per-bin CGEMM → IFFT2D (fused clip)

All three passes of convolutional-layer training are provided (paper §2):
``fprop`` (valid cross-correlation), ``bprop`` (full convolution of the
output gradient), ``accgrad`` (kernel-gradient correlation with the
minibatch as the reduction dimension).

The Fourier basis size ``n_fft`` must satisfy ``n_fft >= h`` (the largest
operand — input and bprop output are both h×w; fbfft interpolates to the
next power of two, paper §5.4/§6). Staged variants return per-stage
results so the Table-5 breakdown bench can time each step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import dft
from .fbfft import fbfft2d
from .fbifft import fbifft2d
from . import pointwise

__all__ = [
    "conv_fprop", "conv_bprop", "conv_accgrad",
    "fft_stage", "ifft_stage", "min_fft_size",
]


def min_fft_size(h: int, w: int) -> int:
    """Smallest fbfft-legal (power-of-two, square) basis covering an
    h×w signal: circular convolution at this size equals the linear one on
    every index the pipeline ever clips out."""
    return dft.next_pow2(max(h, w))


def fft_stage(x: jax.Array, n_fft: int):
    """Forward transform of a 4-D BDHW tensor ``(rows, cols, h, w)`` into
    frequency-major planes ``(nf, n, rows, cols)``.

    This is one 'FFT2D' box of Table 1; the fused transpose inside
    ``fbfft2d`` makes its output directly consumable by the CGEMM stage.
    """
    r, c, h, w = x.shape
    re, im = fbfft2d(x.reshape(r * c, h, w), n_fft)
    nf = n_fft // 2 + 1
    return (re.reshape(nf, n_fft, r, c), im.reshape(nf, n_fft, r, c))


def ifft_stage(planes, n_fft: int, clip: tuple[int, int]):
    """Inverse transform of frequency planes ``(nf, n, rows, cols)`` back
    to a clipped BDHW tensor ``(rows, cols, clip_h, clip_w)`` — the
    'IFFT2D' box of Table 1 with the final clipping fused in."""
    re, im = planes
    nf, n, r, c = re.shape
    out = fbifft2d(re.reshape(nf, n, r * c), im.reshape(nf, n, r * c),
                   n_fft, clip)
    return out.reshape(r, c, clip[0], clip[1])


@functools.partial(jax.jit, static_argnums=(2,))
def conv_fprop(x: jax.Array, wei: jax.Array, n_fft: int) -> jax.Array:
    """Forward pass: ``y[s,j] = Σ_i x[s,i] ⋆ w[j,i]`` (valid correlation).

    ``x``: ``(S, f, h, w)``; ``wei``: ``(f', f, kh, kw)``. Returns
    ``(S, f', h-kh+1, w-kw+1)``. ``n_fft >= max(h, w)``, power of two.
    """
    s, f, h, w = x.shape
    fo, f2, kh, kw = wei.shape
    assert f == f2, f"plane mismatch: input f={f}, weight f={f2}"
    xf = fft_stage(x, n_fft)
    wf = fft_stage(wei, n_fft)
    of = pointwise.cgemm_fprop(xf, wf)
    return ifft_stage(of, n_fft, (h - kh + 1, w - kw + 1))


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def conv_bprop(go: jax.Array, wei: jax.Array, n_fft: int,
               h: int, w: int) -> jax.Array:
    """Backward-by-data: ``gx[s,i] = Σ_j go[s,j] * w[j,i]`` (full conv).

    ``go``: ``(S, f', y_h, y_w)``; ``wei``: ``(f', f, kh, kw)``. Returns
    ``(S, f, h, w)`` where ``h = y_h + kh - 1``. Circular wrap-around is
    harmless because ``n_fft >= h`` and we clip to the leading h×w window.
    """
    gof = fft_stage(go, n_fft)
    wf = fft_stage(wei, n_fft)
    gxf = pointwise.cgemm_bprop(gof, wf)
    return ifft_stage(gxf, n_fft, (h, w))


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def conv_accgrad(go: jax.Array, x: jax.Array, n_fft: int,
                 kh: int, kw: int) -> jax.Array:
    """Weight gradient: ``gw[j,i] = Σ_s go[s,j] ⋆ x[s,i]`` clipped to the
    kernel window.

    ``go``: ``(S, f', y_h, y_w)``; ``x``: ``(S, f, h, w)``. Returns
    ``(f', f, kh, kw)``. A large 'kernel' (the h×w input) is essentially
    free in the Fourier domain — the property behind the paper's
    observation that all three passes cost roughly the same (§4.1).
    """
    gof = fft_stage(go, n_fft)
    xf = fft_stage(x, n_fft)
    gwf = pointwise.cgemm_accgrad(gof, xf)
    return ifft_stage(gwf, n_fft, (kh, kw))
