"""DFT basis construction shared by the fbfft Pallas kernels.

The paper's fbfft computes warp-level butterflies with register shuffles;
the TPU adaptation (DESIGN.md §2) replaces the shuffle network with dense
DFT-matrix contractions on the MXU. All complex arithmetic is carried as
split (real, imag) float32 planes so every contraction is a real matmul,
which is what the systolic array natively executes.

Implicit zero-copy padding (paper §5.1 "clipping") falls out of the matrix
formulation: to transform an input of logical length ``n_in`` on a Fourier
basis of size ``n_fft`` we simply *slice the DFT matrix to its first
``n_in`` rows* — the remaining rows would only ever multiply zeros, so the
padding is never materialized and costs zero FLOPs and zero bytes.

All matrices are built eagerly with numpy at trace time and closed over by
the kernels; XLA constant-folds them into the lowered module.
"""

from __future__ import annotations

import functools

import numpy as np

__all__ = [
    "rfft_basis",
    "cfft_basis",
    "irfft_basis_w",
    "irfft_basis_h",
    "twiddle",
    "hermitian_weights",
    "digit_reverse_perm",
    "factor_fourstep",
    "next_pow2",
]


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (fbfft supports power-of-two sizes only,
    paper §6: 'fbfft only supports square convolutions whose size is a
    power of 2')."""
    if n < 1:
        raise ValueError(f"next_pow2 requires n >= 1, got {n}")
    return 1 << (n - 1).bit_length()


@functools.lru_cache(maxsize=None)
def rfft_basis(n_in: int, n_fft: int) -> tuple[np.ndarray, np.ndarray]:
    """Real-to-complex forward DFT basis, implicitly zero-padded.

    Returns ``(C, S)`` with shape ``(n_in, n_fft // 2 + 1)`` such that for a
    real row-vector ``x`` of length ``n_in``::

        X_re = x @ C            X_im = x @ S

    equals ``rfft(pad(x, n_fft))``. Hermitian symmetry means only
    ``n_fft//2 + 1`` output bins are computed — the paper's 'half the
    computation' optimization (§5.3), realized here as matrix width.
    """
    if n_in > n_fft:
        raise ValueError(f"n_in={n_in} exceeds basis size n_fft={n_fft}")
    nf = n_fft // 2 + 1
    j = np.arange(n_in)[:, None]
    k = np.arange(nf)[None, :]
    ang = -2.0 * np.pi * j * k / n_fft
    return (
        np.cos(ang).astype(np.float32),
        np.sin(ang).astype(np.float32),
    )


@functools.lru_cache(maxsize=None)
def cfft_basis(n_in: int, n_fft: int) -> tuple[np.ndarray, np.ndarray]:
    """Complex-to-complex forward DFT basis ``(C, S)``, shape
    ``(n_in, n_fft)``, implicitly zero-padded like :func:`rfft_basis`.

    For complex input ``x = xr + i·xi`` (row vector)::

        X_re = xr @ C - xi @ S        X_im = xr @ S + xi @ C
    """
    if n_in > n_fft:
        raise ValueError(f"n_in={n_in} exceeds basis size n_fft={n_fft}")
    j = np.arange(n_in)[:, None]
    k = np.arange(n_fft)[None, :]
    ang = -2.0 * np.pi * j * k / n_fft
    return (
        np.cos(ang).astype(np.float32),
        np.sin(ang).astype(np.float32),
    )


@functools.lru_cache(maxsize=None)
def hermitian_weights(n_fft: int) -> np.ndarray:
    """Per-bin multiplicity for reconstructing a real signal from its
    half-spectrum: 1.0 for the self-conjugate DC and Nyquist bins, 2.0 for
    every bin whose mirror image is folded away."""
    nf = n_fft // 2 + 1
    w = np.full(nf, 2.0, dtype=np.float32)
    w[0] = 1.0
    if n_fft % 2 == 0:
        w[-1] = 1.0
    return w


@functools.lru_cache(maxsize=None)
def irfft_basis_w(n_fft: int) -> tuple[np.ndarray, np.ndarray]:
    """Inverse basis along the halved (width) axis.

    Returns ``(EC, ES)`` of shape ``(n_fft//2 + 1, n_fft)`` embedding the
    Hermitian fold weights, such that for a half-spectrum row ``Fr + i·Fi``
    the *complex* partial inverse along this axis is::

        G_re = Fr @ EC - Fi @ ES      G_im = Fr @ ES + Fi @ EC

    (exponent sign +, weights folded in; the final 1/n² scale lives in
    :func:`irfft_basis_h`).
    """
    nf = n_fft // 2 + 1
    k = np.arange(nf)[:, None]
    t = np.arange(n_fft)[None, :]
    ang = 2.0 * np.pi * k * t / n_fft
    m = hermitian_weights(n_fft)[:, None]
    return (
        (m * np.cos(ang)).astype(np.float32),
        (m * np.sin(ang)).astype(np.float32),
    )


@functools.lru_cache(maxsize=None)
def irfft_basis_h(n_fft: int) -> tuple[np.ndarray, np.ndarray]:
    """Inverse basis along the full (height) axis, carrying the 1/n² scale
    of the 2-D inverse transform. Shape ``(n_fft, n_fft)``.

    Only the real part of the final inverse is ever needed (the output of a
    real convolution is real), so consumers compute just
    ``X_re = G_re @ HC - G_im @ HS`` — the imaginary half of the last stage
    is elided entirely, mirroring the paper's Hermitian-symmetry saving.
    """
    k = np.arange(n_fft)[:, None]
    t = np.arange(n_fft)[None, :]
    ang = 2.0 * np.pi * k * t / n_fft
    scale = 1.0 / (n_fft * n_fft)
    return (
        (scale * np.cos(ang)).astype(np.float32),
        (scale * np.sin(ang)).astype(np.float32),
    )


@functools.lru_cache(maxsize=None)
def irfft_basis_1d(n_fft: int) -> tuple[np.ndarray, np.ndarray]:
    """1-D C2R inverse basis ``(EC, ES)`` of shape ``(n_fft//2+1, n_fft)``
    with fold weights and the 1/n scale, producing the real part only::

        x = F_re @ EC - F_im @ ES
    """
    nf = n_fft // 2 + 1
    k = np.arange(nf)[:, None]
    t = np.arange(n_fft)[None, :]
    ang = 2.0 * np.pi * k * t / n_fft
    m = hermitian_weights(n_fft)[:, None] / n_fft
    return (
        (m * np.cos(ang)).astype(np.float32),
        (m * np.sin(ang)).astype(np.float32),
    )


def factor_fourstep(n: int) -> tuple[int, int]:
    """Pick the balanced factorization n = n1·n2 used by the four-step
    decomposition (n1 is the column-DFT size, n2 the row-DFT size); both
    stay <= 32 for every supported n <= 1024, matching the paper's use of a
    32-wide building block ('With size 32 as our building block', §5.3)."""
    if n & (n - 1) != 0 or n < 4:
        raise ValueError(f"four-step factorization requires a power of two >= 4, got {n}")
    lg = n.bit_length() - 1
    l1 = lg // 2
    return 1 << l1, 1 << (lg - l1)


@functools.lru_cache(maxsize=None)
def twiddle(n1: int, n2: int) -> tuple[np.ndarray, np.ndarray]:
    """Four-step twiddle factors ``W_n^{k1·j2}``, shape ``(n1, n2)``,
    split (cos, sin) with the forward (negative) exponent sign.

    The paper distributes these across warp registers and re-balances them
    with register-to-register copies (§5.2); here they are a constant plane
    multiplied on the VPU between the two MXU stages.
    """
    n = n1 * n2
    k1 = np.arange(n1)[:, None]
    j2 = np.arange(n2)[None, :]
    ang = -2.0 * np.pi * k1 * j2 / n
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


@functools.lru_cache(maxsize=None)
def digit_reverse_perm(n1: int, n2: int) -> np.ndarray:
    """Output permutation of the four-step transform.

    The two-stage decomposition produces coefficients indexed ``[k1, k2]``
    whereas the natural order is ``k = k2·n1 + k1``; flattening ``[k1, k2]``
    row-major yields index ``k1·n2 + k2``, so the gather below restores
    natural order. This is the generalization of the radix-2 bit reversal
    the paper implements in SMEM (§5.3) — folded here into a static gather
    that the output BlockSpec absorbs.
    """
    n = n1 * n2
    perm = np.empty(n, dtype=np.int32)
    for k2 in range(n2):
        for k1 in range(n1):
            perm[k2 * n1 + k1] = k1 * n2 + k2
    return perm
