"""Frequency-domain pointwise CGEMM stage as a Pallas kernel (Layer 1).

This is the paper's Cgemm step (Table 1): after both operands are in the
frequency domain, each of the ``(n/2+1)·n`` bins carries an independent
small complex matrix product whose contraction dimension depends on the
pass (paper §2):

=========  =========================  ==================  ===========
pass       product                    reduction           conjugation
=========  =========================  ==================  ===========
fprop      Out[s,j] = Σ_i X[s,i]·W̄[j,i]   input planes f      weight
bprop      Gx[s,i]  = Σ_j Go[s,j]·W[j,i]   output planes f'    none
accGrad    Gw[j,i]  = Σ_s Ḡo[s,j]·X[s,i]   minibatch S         gradOutput
=========  =========================  ==================  ===========

The operands arrive in the frequency-major ``(nf, n, rows, cols)`` layout
produced by ``fbfft2d``'s fused transpose, so the bins are already the
leading (grid) dimension — the cuFFT pipeline's two Cgeam transposes
simply do not exist here. Complex products are expanded into four real
einsum contractions per output plane; each maps to an MXU matmul batched
over the ``n`` bins resident in the block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cgemm_fprop", "cgemm_bprop", "cgemm_accgrad"]


def _fprop_kernel(xr_ref, xi_ref, wr_ref, wi_ref, or_ref, oi_ref):
    """Out = X · conj(W)ᵀ over the plane dim, batched over bins."""
    xr, xi = xr_ref[...], xi_ref[...]          # (1, n, S, f)
    wr, wi = wr_ref[...], wi_ref[...]          # (1, n, f', f)
    or_ref[...] = (jnp.einsum("qnsf,qnjf->qnsj", xr, wr)
                   + jnp.einsum("qnsf,qnjf->qnsj", xi, wi))
    oi_ref[...] = (jnp.einsum("qnsf,qnjf->qnsj", xi, wr)
                   - jnp.einsum("qnsf,qnjf->qnsj", xr, wi))


def _bprop_kernel(gr_ref, gi_ref, wr_ref, wi_ref, or_ref, oi_ref):
    """Gx = Go · W (no conjugation), batched over bins."""
    gr, gi = gr_ref[...], gi_ref[...]          # (1, n, S, f')
    wr, wi = wr_ref[...], wi_ref[...]          # (1, n, f', f)
    or_ref[...] = (jnp.einsum("qnsj,qnjf->qnsf", gr, wr)
                   - jnp.einsum("qnsj,qnjf->qnsf", gi, wi))
    oi_ref[...] = (jnp.einsum("qnsj,qnjf->qnsf", gr, wi)
                   + jnp.einsum("qnsj,qnjf->qnsf", gi, wr))


def _accgrad_kernel(gr_ref, gi_ref, xr_ref, xi_ref, or_ref, oi_ref):
    """Gw = conj(Go)ᵀ · X over the minibatch dim, batched over bins."""
    gr, gi = gr_ref[...], gi_ref[...]          # (1, n, S, f')
    xr, xi = xr_ref[...], xi_ref[...]          # (1, n, S, f)
    or_ref[...] = (jnp.einsum("qnsj,qnsf->qnjf", gr, xr)
                   + jnp.einsum("qnsj,qnsf->qnjf", gi, xi))
    oi_ref[...] = (jnp.einsum("qnsj,qnsf->qnjf", gr, xi)
                   - jnp.einsum("qnsj,qnsf->qnjf", gi, xr))


def _binwise(kernel, a_planes, b_planes, out_rows: int, out_cols: int):
    """Launch ``kernel`` on a grid over the ``nf`` frequency rows.

    ``a_planes``/``b_planes`` are (re, im) pairs shaped
    ``(nf, n, rows, cols)``; one grid step owns one frequency row — a
    block of ``n`` bins — so block sizes stay MXU-friendly while the grid
    provides the bin-level parallelism of the paper's batched Cgemm.
    """
    ar, ai = a_planes
    br, bi = b_planes
    nf, n = ar.shape[0], ar.shape[1]
    a_rows, a_cols = ar.shape[2], ar.shape[3]
    b_rows, b_cols = br.shape[2], br.shape[3]
    re, im = pl.pallas_call(
        kernel,
        grid=(nf,),
        in_specs=[
            pl.BlockSpec((1, n, a_rows, a_cols), lambda q: (q, 0, 0, 0)),
            pl.BlockSpec((1, n, a_rows, a_cols), lambda q: (q, 0, 0, 0)),
            pl.BlockSpec((1, n, b_rows, b_cols), lambda q: (q, 0, 0, 0)),
            pl.BlockSpec((1, n, b_rows, b_cols), lambda q: (q, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n, out_rows, out_cols), lambda q: (q, 0, 0, 0)),
            pl.BlockSpec((1, n, out_rows, out_cols), lambda q: (q, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nf, n, out_rows, out_cols), jnp.float32),
            jax.ShapeDtypeStruct((nf, n, out_rows, out_cols), jnp.float32),
        ],
        interpret=True,
    )(ar, ai, br, bi)
    return re, im


@jax.jit
def cgemm_fprop(xf, wf):
    """Per-bin ``Out[s,j] = Σ_i X[s,i]·conj(W[j,i])``.

    ``xf``: (re, im) of shape ``(nf, n, S, f)``; ``wf``: (re, im) of shape
    ``(nf, n, f', f)``. Returns (re, im) of shape ``(nf, n, S, f')``.
    """
    s, fo = xf[0].shape[2], wf[0].shape[2]
    return _binwise(_fprop_kernel, xf, wf, s, fo)


@jax.jit
def cgemm_bprop(gof, wf):
    """Per-bin ``Gx[s,i] = Σ_j Go[s,j]·W[j,i]``.

    ``gof``: planes ``(nf, n, S, f')``; ``wf``: planes ``(nf, n, f', f)``.
    Returns planes ``(nf, n, S, f)``.
    """
    s, f = gof[0].shape[2], wf[0].shape[3]
    return _binwise(_bprop_kernel, gof, wf, s, f)


@jax.jit
def cgemm_accgrad(gof, xf):
    """Per-bin ``Gw[j,i] = Σ_s conj(Go[s,j])·X[s,i]``.

    ``gof``: planes ``(nf, n, S, f')``; ``xf``: planes ``(nf, n, S, f)``.
    Returns planes ``(nf, n, f', f)``.
    """
    fo, f = gof[0].shape[3], xf[0].shape[3]
    return _binwise(_accgrad_kernel, gof, xf, fo, f)
