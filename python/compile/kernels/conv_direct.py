"""Time-domain direct convolution as a Pallas kernel (Layer 1).

The straightforward O(S·f·f'·k²·y²) computation the paper's Figures 1–6
use as the mental baseline: for small kernels and small problem sizes the
time domain wins, and the crossover against the frequency-domain pipeline
is exactly what the sweep benches chart. Built from scratch per the
reproduction rule — the baseline is part of the system.

Schedule: one grid step per minibatch sample; the sample's full input
block ``(f, h, w)`` and the whole weight tensor are VMEM-resident, and the
k·k taps are unrolled statically — each tap is a rank-1 update
``out[j,·,·] += w[j,i,u,v] · x[i,·+u,·+v]`` expressed as an einsum over
planes so the tap loop carries MXU contractions, not scalar code.

``bprop``/``accGrad`` for the direct strategy are algebraic reuses of this
same kernel (transposed-conv and batch-as-reduction identities); see
``compile.model``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["conv_direct_fprop"]


def _direct_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int):
    """Valid cross-correlation of one sample, taps statically unrolled."""
    x = x_ref[...]                     # (1, f, h, w)
    wei = w_ref[...]                   # (f', f, kh, kw)
    h, w = x.shape[2], x.shape[3]
    yh, yw = h - kh + 1, w - kw + 1
    acc = jnp.zeros((1, wei.shape[0], yh, yw), dtype=jnp.float32)
    for u in range(kh):
        for v in range(kw):
            # window of every input plane under tap (u, v)
            win = x[:, :, u:u + yh, v:v + yw]          # (1, f, yh, yw)
            tap = wei[:, :, u, v]                      # (f', f)
            acc = acc + jnp.einsum("bfhw,jf->bjhw", win, tap)
    o_ref[...] = acc


@jax.jit
def conv_direct_fprop(x: jax.Array, wei: jax.Array) -> jax.Array:
    """Direct valid cross-correlation ``y[s,j] = Σ_i x[s,i] ⋆ w[j,i]``.

    ``x``: ``(S, f, h, w)``; ``wei``: ``(f', f, kh, kw)`` →
    ``(S, f', h-kh+1, w-kw+1)``. Grid over S.
    """
    s, f, h, w = x.shape
    fo, f2, kh, kw = wei.shape
    assert f == f2, f"plane mismatch: {f} vs {f2}"
    yh, yw = h - kh + 1, w - kw + 1
    kern = functools.partial(_direct_kernel, kh=kh, kw=kw)
    return pl.pallas_call(
        kern,
        grid=(s,),
        in_specs=[
            pl.BlockSpec((1, f, h, w), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((fo, f, kh, kw), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, fo, yh, yw), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((s, fo, yh, yw), jnp.float32),
        interpret=True,
    )(x, wei)
