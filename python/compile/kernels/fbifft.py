"""fbfft inverse transforms (C2R) as Pallas kernels (Layer 1).

Inverse counterparts of ``kernels.fbfft``. Two fbfft ideas matter here:

* the input arrives in the frequency-transposed ``(nf, n, batch)`` layout
  the CGEMM stage emits, so no pre-transposition pass is needed;
* **fused clipping** — the convolution pipeline only ever needs a
  ``(clip_h, clip_w)`` corner of the full ``n × n`` inverse (valid-conv
  output, gradInput, or kernel-gradient window, paper §3.1), so the kernel
  computes the inverse and stores just that window. The clipped store is
  the inverse-side analogue of implicit zero padding: bytes for the
  discarded region never touch HBM.

Only the real part of the final stage is computed (the imaginary part of
a real signal's inverse is identically zero) — half the last-stage FLOPs,
the paper's Hermitian-symmetry saving applied to the IFFT.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import dft
from .fbfft import DEFAULT_PANEL

__all__ = ["fbifft1d", "fbifft2d"]


def _fbifft1d_kernel(re_ref, im_ref, ec_ref, es_ref, out_ref):
    """One panel: real part of the inverse, a pair of MXU contractions."""
    out_ref[...] = (
        jnp.dot(re_ref[...], ec_ref[...], preferred_element_type=jnp.float32)
        - jnp.dot(im_ref[...], es_ref[...], preferred_element_type=jnp.float32)
    )


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def fbifft1d(re: jax.Array, im: jax.Array, n_fft: int,
             clip: int | None = None, panel: int = DEFAULT_PANEL):
    """Batched 1-D C2R inverse FFT.

    ``re, im``: ``(B, n_fft//2 + 1)`` half-spectrum planes. Returns the
    real inverse ``(B, clip)`` (``clip`` defaults to ``n_fft``) — equal to
    ``jnp.fft.irfft(re + i·im, n_fft)[:, :clip]``.
    """
    clip = n_fft if clip is None else clip
    if clip > n_fft:
        raise ValueError(f"clip={clip} exceeds n_fft={n_fft}")
    b_logical, nf = re.shape
    if nf != n_fft // 2 + 1:
        raise ValueError(f"spectrum width {nf} != n_fft//2+1 = {n_fft // 2 + 1}")
    ec, es = dft.irfft_basis_1d(n_fft)
    # fused clip: slice the basis columns instead of the result
    ec, es = ec[:, :clip], es[:, :clip]
    panel = min(panel, dft.next_pow2(max(8, b_logical)))
    rem = (-b_logical) % panel
    if rem:
        re = jnp.pad(re, ((0, rem), (0, 0)))
        im = jnp.pad(im, ((0, rem), (0, 0)))
    b = re.shape[0]
    out = pl.pallas_call(
        _fbifft1d_kernel,
        grid=(b // panel,),
        in_specs=[
            pl.BlockSpec((panel, nf), lambda i: (i, 0)),
            pl.BlockSpec((panel, nf), lambda i: (i, 0)),
            pl.BlockSpec((nf, clip), lambda i: (0, 0)),
            pl.BlockSpec((nf, clip), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((panel, clip), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, clip), jnp.float32),
        interpret=True,
    )(re, im, jnp.asarray(ec), jnp.asarray(es))
    return out[:b_logical]


def _fbifft2d_kernel(re_ref, im_ref, ecw_ref, esw_ref, ech_ref, esh_ref,
                     out_ref):
    """2-D C2R inverse of one panel from the transposed layout.

    Input tile ``(nf, n, panel)`` holds ``FT[kw, kh, b] = F[kh, kw]``.

      1. width axis first (it is the halved one): fold Hermitian weights,
         complex result  G[b, kh, w] = Σ_kw FT[kw, kh, b]·E[kw, w]
      2. height axis, real part only, directly in (b, h, w) order with the
         clip window applied by basis slicing before the kernel.

    Both stages are MXU contractions; the layout change back from
    frequency-transposed to batch-major happens inside the einsums — the
    second fused transpose of the pipeline.
    """
    fr = re_ref[...]                    # (nf, n, panel)
    fi = im_ref[...]
    ecw, esw = ecw_ref[...], esw_ref[...]
    gr = (jnp.einsum("knb,kw->bnw", fr, ecw)
          - jnp.einsum("knb,kw->bnw", fi, esw))
    gi = (jnp.einsum("knb,kw->bnw", fr, esw)
          + jnp.einsum("knb,kw->bnw", fi, ecw))
    ech, esh = ech_ref[...], esh_ref[...]
    # real part only: Re{(gr + i·gi)·(ech + i·esh)} contracted over kh (=n)
    out_ref[...] = (jnp.einsum("bnw,nh->bhw", gr, ech)
                    - jnp.einsum("bnw,nh->bhw", gi, esh))


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def fbifft2d(re: jax.Array, im: jax.Array, n_fft: int,
             clip: tuple[int, int] | None = None,
             panel: int = DEFAULT_PANEL):
    """Batched 2-D C2R inverse FFT from the frequency-transposed layout.

    ``re, im``: ``(n_fft//2 + 1, n_fft, B)`` planes as produced by
    :func:`kernels.fbfft.fbfft2d` / the CGEMM stage. Returns real
    ``(B, clip_h, clip_w)`` equal to
    ``jnp.fft.irfft2(F, (n_fft, n_fft))[:, :clip_h, :clip_w]`` where
    ``F[b, kh, kw] = re[kw, kh, b] + i·im[kw, kh, b]``.
    """
    ch, cw = (n_fft, n_fft) if clip is None else clip
    if ch > n_fft or cw > n_fft:
        raise ValueError(f"clip {ch}x{cw} exceeds n_fft={n_fft}")
    nf, n, b_logical = re.shape
    if nf != n_fft // 2 + 1 or n != n_fft:
        raise ValueError(f"spectrum {re.shape} inconsistent with n_fft={n_fft}")
    ecw, esw = dft.irfft_basis_w(n_fft)       # (nf, n) with fold weights
    ech, esh = dft.irfft_basis_h(n_fft)       # (n, n) with 1/n² scale
    ecw, esw = ecw[:, :cw], esw[:, :cw]       # fused clip, width
    ech, esh = ech[:, :ch], esh[:, :ch]       # fused clip, height
    panel = min(panel, dft.next_pow2(max(8, b_logical)))
    rem = (-b_logical) % panel
    if rem:
        re = jnp.pad(re, ((0, 0), (0, 0), (0, rem)))
        im = jnp.pad(im, ((0, 0), (0, 0), (0, rem)))
    b = re.shape[2]
    out = pl.pallas_call(
        _fbifft2d_kernel,
        grid=(b // panel,),
        in_specs=[
            pl.BlockSpec((nf, n_fft, panel), lambda i: (0, 0, i)),
            pl.BlockSpec((nf, n_fft, panel), lambda i: (0, 0, i)),
            pl.BlockSpec((nf, cw), lambda i: (0, 0)),
            pl.BlockSpec((nf, cw), lambda i: (0, 0)),
            pl.BlockSpec((n_fft, ch), lambda i: (0, 0)),
            pl.BlockSpec((n_fft, ch), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((panel, ch, cw), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, ch, cw), jnp.float32),
        interpret=True,
    )(re, im, jnp.asarray(ecw), jnp.asarray(esw), jnp.asarray(ech),
      jnp.asarray(esh))
    return out[:b_logical]
