"""Layer 2 — the JAX compute graphs lowered to HLO for the coordinator.

Everything the Rust runtime executes is defined here as a pure jax
function over concrete shapes:

* the six convolution strategies × three training passes, dispatching to
  the Layer-1 Pallas kernels (`fbfft`, `fbfft_tiled`, `direct`, `im2col`)
  or to the two vendor black boxes (`vendor` = XLA's native conv, the
  cuDNN analogue; `vendor_fft` = jnp.fft, the cuFFT analogue);
* standalone batched FFT transforms for the Figure-7/8 benches;
* a small trainable CNN (fbfft convolutions wired through ``custom_vjp``
  so *all three* paper passes run on the Pallas pipeline) with an SGD
  train step for the end-to-end example.

Python runs once at build time (`make artifacts`); the lowered HLO text is
the only thing that crosses to the request path.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import conv_direct, conv_fft, conv_im2col, dft, fbfft, ref, tiling
from .specs import ConvSpec

__all__ = [
    "STRATEGIES", "fprop", "bprop", "accgrad",
    "fft1d_fbfft", "fft1d_vendor", "fft2d_fbfft", "fft2d_vendor",
    "fbfft_conv", "cnn_init", "cnn_apply", "cnn_loss", "train_step",
    "TrainConfig",
]

STRATEGIES = ("vendor", "vendor_fft", "fbfft", "fbfft_tiled", "direct",
              "im2col")


# ---------------------------------------------------------------------------
# Strategy dispatch — three passes
# ---------------------------------------------------------------------------


def _n_fft_for(spec: ConvSpec, n_fft: int | None) -> int:
    """fbfft interpolates to the next power of two covering the largest
    operand (paper §5.4); an explicit n_fft (from the autotuner) wins."""
    return n_fft if n_fft is not None else conv_fft.min_fft_size(spec.h, spec.w)


def fprop(spec: ConvSpec, strategy: str, x: jax.Array, wei: jax.Array,
          n_fft: int | None = None, tile: int | None = None) -> jax.Array:
    """Forward pass ``y[s,j] = Σ_i x[s,i] ⋆ w[j,i]`` under ``strategy``."""
    if spec.stride != 1 and strategy != "vendor":
        raise ValueError(
            f"{spec.name}: strided convolution is vendor-only (paper §2)")
    if strategy == "vendor":
        if spec.stride == 1:
            return ref.conv_fprop_ref(x, wei)
        return jax.lax.conv_general_dilated(
            x, wei, window_strides=(spec.stride, spec.stride),
            padding="VALID", dimension_numbers=("NCHW", "OIHW", "NCHW"))
    if strategy == "vendor_fft":
        return ref.conv_fprop_fft_ref(x, wei, _n_fft_for(spec, n_fft))
    if strategy == "fbfft":
        return conv_fft.conv_fprop(x, wei, _n_fft_for(spec, n_fft))
    if strategy == "fbfft_tiled":
        return tiling.conv_fprop_tiled(x, wei, tile or max(spec.kh, spec.kw))
    if strategy == "direct":
        return conv_direct.conv_direct_fprop(x, wei)
    if strategy == "im2col":
        return conv_im2col.conv_im2col_fprop(x, wei)
    raise ValueError(f"unknown strategy {strategy!r}")


def bprop(spec: ConvSpec, strategy: str, go: jax.Array, wei: jax.Array,
          n_fft: int | None = None, tile: int | None = None) -> jax.Array:
    """Gradient w.r.t. the input (full convolution of go with w)."""
    if strategy == "vendor":
        return ref.conv_bprop_ref(go, wei, spec.h, spec.w)
    if strategy == "vendor_fft":
        return ref.conv_bprop_fft_ref(go, wei, _n_fft_for(spec, n_fft),
                                      spec.h, spec.w)
    if strategy == "fbfft":
        return conv_fft.conv_bprop(go, wei, _n_fft_for(spec, n_fft),
                                   spec.h, spec.w)
    if strategy == "fbfft_tiled":
        return tiling.conv_bprop_tiled(go, wei,
                                       tile or max(spec.kh, spec.kw),
                                       spec.h, spec.w)
    if strategy in ("direct", "im2col"):
        # transposed-conv identity: pad the gradient by k-1, correlate with
        # the flipped kernel, planes swapped — reuses the fprop kernel.
        kh, kw = spec.kh, spec.kw
        gop = jnp.pad(go, ((0, 0), (0, 0), (kh - 1, kh - 1),
                           (kw - 1, kw - 1)))
        wt = jnp.flip(jnp.transpose(wei, (1, 0, 2, 3)), (-2, -1))
        fn = (conv_direct.conv_direct_fprop if strategy == "direct"
              else conv_im2col.conv_im2col_fprop)
        return fn(gop, wt)
    raise ValueError(f"unknown strategy {strategy!r}")


def accgrad(spec: ConvSpec, strategy: str, go: jax.Array, x: jax.Array,
            n_fft: int | None = None, tile: int | None = None) -> jax.Array:
    """Gradient w.r.t. the weights (minibatch is the reduction dim)."""
    if strategy == "vendor":
        return ref.conv_accgrad_ref(go, x, spec.kh, spec.kw)
    if strategy == "vendor_fft":
        return ref.conv_accgrad_fft_ref(go, x, _n_fft_for(spec, n_fft),
                                        spec.kh, spec.kw)
    if strategy == "fbfft":
        return conv_fft.conv_accgrad(go, x, _n_fft_for(spec, n_fft),
                                     spec.kh, spec.kw)
    if strategy == "fbfft_tiled":
        return tiling.conv_accgrad_tiled(go, x,
                                         tile or max(spec.kh, spec.kw),
                                         spec.kh, spec.kw)
    if strategy in ("direct", "im2col"):
        # batch-as-reduction identity on the fprop kernel
        xt = jnp.transpose(x, (1, 0, 2, 3))
        got = jnp.transpose(go, (1, 0, 2, 3))
        fn = (conv_direct.conv_direct_fprop if strategy == "direct"
              else conv_im2col.conv_im2col_fprop)
        return jnp.transpose(fn(xt, got), (1, 0, 2, 3))
    raise ValueError(f"unknown strategy {strategy!r}")


# ---------------------------------------------------------------------------
# Standalone transforms (Figures 7–8 subjects)
# ---------------------------------------------------------------------------


def fft1d_fbfft(x: jax.Array, n_fft: int):
    """Batched 1-D fbfft (Pallas). Figure-7 subject."""
    return fbfft.fbfft1d(x, n_fft)


def fft1d_vendor(x: jax.Array, n_fft: int):
    """Batched 1-D vendor FFT (XLA's native Rfft — the cuFFT analogue)."""
    return ref.rfft1d_ref(x, n_fft)


def fft2d_fbfft(x: jax.Array, n_fft: int):
    """Batched 2-D fbfft with fused transpose. Figure-8 subject."""
    return fbfft.fbfft2d(x, n_fft)


def fft2d_vendor(x: jax.Array, n_fft: int):
    """Batched 2-D vendor FFT *plus* the explicit transposition the cuFFT
    pipeline needs before its CGEMM (paper Table 1) — the honest
    like-for-like comparison for Figure 8."""
    return ref.rfft2d_ref_transposed(x, n_fft)


# ---------------------------------------------------------------------------
# End-to-end CNN: fbfft convolutions with custom VJP (all three passes on
# the Pallas pipeline), SGD train step
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def fbfft_conv(x: jax.Array, wei: jax.Array, n_fft: int) -> jax.Array:
    """Differentiable fbfft convolution layer: forward = conv_fprop,
    backward = (conv_bprop, conv_accgrad) — the exact three-kernel split
    of paper §2 instead of XLA's autodiff of the forward graph."""
    return conv_fft.conv_fprop(x, wei, n_fft)


def _fbfft_conv_fwd(x, wei, n_fft):
    return conv_fft.conv_fprop(x, wei, n_fft), (x, wei)


def _fbfft_conv_bwd(n_fft, res, go):
    x, wei = res
    h, w = x.shape[2], x.shape[3]
    kh, kw = wei.shape[2], wei.shape[3]
    return (conv_fft.conv_bprop(go, wei, n_fft, h, w),
            conv_fft.conv_accgrad(go, x, n_fft, kh, kw))


fbfft_conv.defvjp(_fbfft_conv_fwd, _fbfft_conv_bwd)


class TrainConfig:
    """Static architecture of the e2e demo CNN (examples/train_cnn.rs).

    input (S, c, hw, hw) → conv1(c→p1, k) → relu → conv2(p1→p2, k) → relu
    → global average pool → dense(p2→classes) → softmax CE. Both convs run
    the full fbfft pipeline in fwd *and* bwd via ``fbfft_conv``.
    """

    def __init__(self, s=16, c=1, hw=16, k=3, p1=8, p2=16, classes=4,
                 lr=0.05):
        self.s, self.c, self.hw, self.k = s, c, hw, k
        self.p1, self.p2, self.classes, self.lr = p1, p2, classes, lr
        self.h1 = hw - k + 1           # after conv1
        self.h2 = self.h1 - k + 1      # after conv2
        self.n1 = dft.next_pow2(hw)
        self.n2 = dft.next_pow2(self.h1)

    def to_json(self) -> dict:
        return {k: getattr(self, k) for k in
                ("s", "c", "hw", "k", "p1", "p2", "classes", "lr")}


def cnn_init(cfg: TrainConfig, key: jax.Array) -> dict[str, jax.Array]:
    """He-initialized parameter pytree (a flat dict, stable order)."""
    k1, k2, k3 = jax.random.split(key, 3)
    fan1 = cfg.c * cfg.k * cfg.k
    fan2 = cfg.p1 * cfg.k * cfg.k
    return {
        "conv1": jax.random.normal(k1, (cfg.p1, cfg.c, cfg.k, cfg.k),
                                   jnp.float32) * (2.0 / fan1) ** 0.5,
        "conv2": jax.random.normal(k2, (cfg.p2, cfg.p1, cfg.k, cfg.k),
                                   jnp.float32) * (2.0 / fan2) ** 0.5,
        "dense_w": jax.random.normal(k3, (cfg.p2, cfg.classes),
                                     jnp.float32) * (1.0 / cfg.p2) ** 0.5,
        "dense_b": jnp.zeros((cfg.classes,), jnp.float32),
    }


def cnn_apply(cfg: TrainConfig, params: dict, x: jax.Array) -> jax.Array:
    """Logits for a batch ``(S, c, hw, hw)``."""
    h = jax.nn.relu(fbfft_conv(x, params["conv1"], cfg.n1))
    h = jax.nn.relu(fbfft_conv(h, params["conv2"], cfg.n2))
    h = jnp.mean(h, axis=(2, 3))                     # global average pool
    return h @ params["dense_w"] + params["dense_b"]


def cnn_loss(cfg: TrainConfig, params: dict, x: jax.Array,
             y: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logits = cnn_apply(cfg, params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def train_step(cfg: TrainConfig, params: dict, x: jax.Array, y: jax.Array):
    """One SGD step; returns (new_params, loss). Lowered as a single HLO
    module and iterated from Rust — Python never sees the training loop."""
    loss, grads = jax.value_and_grad(
        lambda p: cnn_loss(cfg, p, x, y))(params)
    new = {k: params[k] - cfg.lr * grads[k] for k in params}
    return new, loss
