"""Build-time Python for the fbfft reproduction (Layers 1+2).

Never imported at runtime: `make artifacts` lowers everything under
compile/ to HLO text in artifacts/, and the Rust coordinator is
self-contained from there.
"""
