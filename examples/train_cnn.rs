//! End-to-end training driver (the DESIGN.md §5 'E2E' experiment).
//!
//! Trains the demo CNN — two fbfft convolution layers whose forward AND
//! backward passes run the paper's three-kernel frequency pipeline via
//! `custom_vjp` — for a few hundred SGD steps on synthetic labeled data,
//! entirely from Rust: the training loop is repeated PJRT executions of
//! the single AOT-compiled `train.step` module. Python never runs.
//!
//! ```sh
//! make artifacts && cargo run --release --example train_cnn [steps]
//! ```

use fbfft_repro::reports::trainer;
use fbfft_repro::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let rt = Runtime::open("artifacts")?;
    println!("training the fbfft CNN for {steps} steps \
              (16-sample batches, synthetic 4-class data)...");
    let (log, acc) = trainer::train_and_eval(&rt, steps, 0xE2E)?;
    println!("\nloss curve:");
    println!("{}", log.render_curve(24));
    println!("steps/s: {:.1}   loss {:.4} -> {:.4}   eval accuracy {:.1}%",
             log.steps_per_sec(), log.first(), log.last(), acc * 100.0);
    anyhow::ensure!(log.last() < log.first(),
                    "training did not reduce the loss");
    anyhow::ensure!(acc > 0.5, "accuracy did not beat chance (25%)");
    println!("train_cnn OK");
    Ok(())
}
