//! Whole-network inference through the bulk-synchronous scheduler:
//! AlexNet's convolutional stack (CPU scale) with the paper's routing —
//! strided conv1 on the vendor path, deeper layers on fbfft — plus a
//! side-by-side against the all-vendor configuration.
//!
//! ```sh
//! make artifacts && cargo run --release --example cnn_inference
//! ```

use fbfft_repro::coordinator::{NetworkScheduler, Pass, Strategy};
use fbfft_repro::reports::cnn::plans;
use fbfft_repro::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    for (strategy, label) in [(Strategy::Fbfft, "fbfft (conv1 vendor)"),
                              (Strategy::Vendor, "all-vendor")] {
        let mut sched = NetworkScheduler::new(&rt, plans("alexnet",
                                                         strategy));
        sched.check_artifacts(&[Pass::Fprop])?;
        sched.warm(&[Pass::Fprop])?; // compile outside the timed region
        let t = sched.fprop()?;
        println!("AlexNet fprop, {label}:");
        for (layer, d) in &t.per_layer {
            println!("  {:24} {:>8.3} ms", layer,
                     d.as_secs_f64() * 1e3);
        }
        println!("  {:24} {:>8.3} ms\n", "TOTAL",
                 t.total().as_secs_f64() * 1e3);
    }
    println!("cnn_inference OK");
    Ok(())
}
