//! Quickstart: load one AOT-compiled fbfft convolution, run it through
//! the PJRT runtime, and verify the numerics against the in-tree
//! time-domain engine.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use fbfft_repro::conv::{direct, ConvProblem};
use fbfft_repro::runtime::{HostTensor, Runtime};
use fbfft_repro::util::Rng;

fn main() -> anyhow::Result<()> {
    // 1. open the artifacts directory (PJRT CPU client + manifest)
    let rt = Runtime::open("artifacts")?;
    println!("manifest: {} artifacts", rt.manifest().entries.len());

    // 2. the quickstart problem: S=2, f=f'=4, 16x16 input, 3x3 kernel
    let p = ConvProblem::square(2, 4, 4, 16, 3);
    let mut rng = Rng::new(1);
    let x = rng.normal_vec(p.input_len());
    let w = rng.normal_vec(p.weight_len());

    // 3. run the Pallas fbfft pipeline (FFT -> CGEMM -> IFFT, with the
    //    paper's implicit padding and fused transposes) via PJRT
    let t0 = std::time::Instant::now();
    let (y, shape) = rt.execute_1f32(
        "conv.quickstart.fbfft.fprop",
        &[HostTensor::f32(x.clone(), &[p.s, p.f, p.h, p.w]),
          HostTensor::f32(w.clone(), &[p.fo, p.f, p.kh, p.kw])])?;
    println!("fbfft fprop: output {shape:?} in {:?} (incl. compile)",
             t0.elapsed());

    // 4. verify against the host time-domain oracle
    let want = direct::fprop(&p, &x, &w);
    let err = y.iter().zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("max |fbfft - direct| = {err:.2e}");
    assert!(err < 1e-3, "numerics mismatch");

    // 5. warm executions are what the serving path sees
    let t1 = std::time::Instant::now();
    for _ in 0..10 {
        rt.execute_1f32(
            "conv.quickstart.fbfft.fprop",
            &[HostTensor::f32(x.clone(), &[p.s, p.f, p.h, p.w]),
              HostTensor::f32(w.clone(), &[p.fo, p.f, p.kh, p.kw])])?;
    }
    println!("warm: {:.3} ms/exec", t1.elapsed().as_secs_f64() * 100.0);
    println!("quickstart OK");
    Ok(())
}
