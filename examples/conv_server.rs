//! Serving example: a convolution service behind the dynamic batcher.
//!
//! A Poisson request trace (mixed request sizes) is replayed against a
//! `ConvService` that owns the PJRT runtime on a worker thread; the
//! batcher flushes on capacity or deadline, amortizing each executable
//! launch over several requests — the 'large batches' economics the
//! paper's regime is about, applied at serving time.
//!
//! ```sh
//! make artifacts && cargo run --release --example conv_server [requests]
//! ```

use std::time::{Duration, Instant};

use fbfft_repro::conv::ConvProblem;
use fbfft_repro::coordinator::batcher::BatcherConfig;
use fbfft_repro::coordinator::service::{Completion, ConvService,
                                        ServeRequest};
use fbfft_repro::metrics::Histogram;
use fbfft_repro::trace;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let p = ConvProblem::square(2, 4, 4, 16, 3);
    let svc = ConvService::start(
        "artifacts".into(),
        "conv.quickstart.fbfft.fprop".into(),
        p,
        BatcherConfig { capacity: p.s, max_wait: Duration::from_millis(2) },
    )?;
    println!("replaying {n} requests at ~400 req/s...");
    let reqs = trace::request_trace(n, 400.0, 0x5E);
    let (tx, rx) = std::sync::mpsc::channel::<Completion>();
    let t0 = Instant::now();
    for r in &reqs {
        std::thread::sleep(
            Duration::from_secs_f64(r.arrival_s)
                .saturating_sub(t0.elapsed()));
        svc.submit(ServeRequest { id: r.id, images: r.images.min(p.s),
                                  reply: tx.clone() });
    }
    drop(tx);
    let mut hist = Histogram::new();
    let mut batch_factor = 0usize;
    let mut done = 0usize;
    while done < reqs.len() {
        let Ok(c) = rx.recv_timeout(Duration::from_secs(10)) else { break };
        hist.record(c.latency.as_secs_f64());
        batch_factor += c.batch_images;
        done += 1;
    }
    let wall = t0.elapsed();
    let report = svc.shutdown();
    println!("completed {done}/{} requests ({} images) in {:.2}s",
             reqs.len(), report.images, wall.as_secs_f64());
    println!("launches: {} ({} full flushes, {} deadline flushes), \
              mean batch factor {:.2}",
             report.launches, report.flushes_full, report.flushes_timeout,
             batch_factor as f64 / done.max(1) as f64);
    println!("throughput: {:.0} images/s",
             report.images as f64 / wall.as_secs_f64());
    println!("latency ms: p50 {:.2}  p95 {:.2}  p99 {:.2}  max {:.2}",
             hist.percentile(50.0) * 1e3, hist.percentile(95.0) * 1e3,
             hist.percentile(99.0) * 1e3, hist.max() * 1e3);
    println!("service busy {:.1}% of wall clock",
             report.busy.as_secs_f64() / wall.as_secs_f64() * 100.0);
    anyhow::ensure!(done == reqs.len(), "dropped requests");
    println!("conv_server OK");
    Ok(())
}
