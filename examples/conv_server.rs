//! Serving example: the sharded multi-worker engine behind the
//! deadline-aware dynamic batcher.
//!
//! A Poisson request trace (mixed request sizes) is replayed against a
//! `ServeEngine`: admission checks each request's deadline against the
//! strategy cache's launch estimate, routes it to the least-loaded
//! shard, and each shard worker batches and launches independently —
//! the 'large batches' economics the paper's regime is about, applied
//! at serving time across a worker pool.
//!
//! With `make artifacts` and a real PJRT backend each worker owns its
//! own runtime; otherwise the engine serves through the in-tree host
//! engines picked per flush shape by the persistent autotune cache, so
//! the example runs everywhere:
//!
//! ```sh
//! cargo run --release --example conv_server [requests] [shards]
//! ```

use std::time::{Duration, Instant};

use fbfft_repro::conv::ConvProblem;
use fbfft_repro::coordinator::service::{Backend, Completion,
                                        EngineConfig, ServeEngine,
                                        ServeRequest};
use fbfft_repro::coordinator::NetPlan;
use fbfft_repro::reports;
use fbfft_repro::trace;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let shards: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let cfg = |capacity: usize| {
        EngineConfig::builder()
            .shards(shards)
            .capacity(capacity)
            .max_wait(Duration::from_millis(2))
            .default_deadline(Duration::from_millis(500))
            .build()
            .expect("example config is valid")
    };
    let pj = ConvProblem::square(2, 4, 4, 16, 3);
    let (engine, capacity) = match ServeEngine::start_pjrt(
        "artifacts".into(),
        "conv.quickstart.fbfft.fprop".into(),
        pj,
        cfg(pj.s))
    {
        Ok(e) => (e, pj.s),
        Err(e) => {
            eprintln!("note: PJRT serving unavailable ({e:#}); \
                       serving the AlexNet-style chain on the \
                       host-engine backend");
            let net = NetPlan::alexnet_small(8);
            let cap = net.batch();
            (ServeEngine::start(Backend::Host, net, cfg(cap))?, cap)
        }
    };
    // the Ticket API covers the simple submit-and-wait case: one warm
    // request up front, awaited synchronously
    let warm = engine
        .submit_images(1, None)
        .map_err(|e| anyhow::anyhow!("warm request rejected: {e}"))?;
    let c = warm
        .wait_timeout(Duration::from_secs(10))
        .map_err(|e| anyhow::anyhow!("warm request lost: {e}"))?;
    println!("warm request {} served by shard {} in {:.2} ms",
             c.id, c.shard, c.latency.as_secs_f64() * 1e3);
    println!("replaying {n} requests at ~400 req/s over {shards} shards...");
    let reqs = trace::request_trace(n, 400.0, 0x5E);
    let (tx, rx) = std::sync::mpsc::channel::<Completion>();
    let t0 = Instant::now();
    let mut accepted = 0usize;
    let mut tight = 0usize;
    for r in &reqs {
        std::thread::sleep(
            Duration::from_secs_f64(r.arrival_s)
                .saturating_sub(t0.elapsed()));
        // unlike the CLI demo, exercise explicit SLAs: every 4th
        // request carries a tight 10 ms reply-by deadline (the engine
        // both batches it sooner — flush-by = min(max_wait, SLA) — and
        // reports whether the reply beat it)
        let deadline = (r.id % 4 == 0)
            .then(|| Instant::now() + Duration::from_millis(10));
        tight += deadline.is_some() as usize;
        if engine.submit(ServeRequest { id: r.id,
                                        images: r.images.min(capacity),
                                        deadline,
                                        reply: tx.clone() }).is_ok() {
            accepted += 1;
        }
    }
    drop(tx);
    let mut done = 0usize;
    let mut met = 0usize;
    while done < accepted {
        let Ok(c) = rx.recv_timeout(Duration::from_secs(10)) else { break };
        done += 1;
        met += c.deadline_met as usize;
    }
    let wall = t0.elapsed();
    let report = engine.shutdown();
    let json = reports::serve_json(&report, "open", false, wall);
    println!("{}", reports::serve_table(&json));
    println!("completed {done}/{accepted} accepted requests \
              ({met} within deadline; {tight} carried tight SLAs) \
              in {:.2}s", wall.as_secs_f64());
    anyhow::ensure!(done == accepted, "dropped requests");
    println!("conv_server OK");
    Ok(())
}
