//! Bench target for Table 5: per-stage pipeline breakdown (host engines),
//! plus the Sec 5.4 comparison when artifacts are present.
use fbfft_repro::reports::{sweep::sec54_report, table5_report};
use fbfft_repro::runtime::Runtime;

fn main() {
    println!("{}", table5_report());
    if let Ok(rt) = Runtime::open("artifacts") {
        match sec54_report(&rt) {
            Ok(r) => println!("{r}"),
            Err(e) => eprintln!("sec54 failed: {e:#}"),
        }
    }
}
