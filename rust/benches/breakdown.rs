//! Bench target for Table 5: per-stage pipeline breakdown (host engines)
//! plus the machine-readable `BENCH_fftconv.json` perf artifact, and the
//! Sec 5.4 comparison when artifacts are present.
//!
//! One measurement pass feeds both outputs: the JSON is written first
//! and the Table-5 text is rendered from its entries (so the table and
//! the artifact can never disagree). `cargo bench --bench breakdown --
//! --smoke` runs the fixed acceptance configs (accept32 plus the
//! large-input oaa144 shape) with one rep (the CI smoke gate) and still
//! writes the JSON. `--mode <vendor|fbfft|fbfft_scalar|oaa>` restricts
//! the printed rows to one pipeline mode; the measurement set and the
//! JSON are unaffected. Every run prints the
//! `oaa speedup vs full-pad fbfft` line the CI perf gate thresholds.
use fbfft_repro::metrics::Table;
use fbfft_repro::reports::{breakdown_json, sweep::sec54_report};
use fbfft_repro::runtime::Runtime;
use fbfft_repro::util::Json;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mode_filter = {
        let mut args = std::env::args();
        let mut m = None;
        while let Some(a) = args.next() {
            if a == "--mode" {
                m = args.next();
            }
        }
        m
    };
    let json = breakdown_json(smoke);
    std::fs::write("BENCH_fftconv.json", json.to_string())
        .expect("write BENCH_fftconv.json");
    eprintln!("wrote BENCH_fftconv.json (smoke={smoke})");
    // the acceptance criterion: every run names the dispatch tier its
    // numbers were measured under (CI greps this line in the smoke leg)
    let host = json.get("host");
    let hs = |k: &str| {
        host.and_then(|h| h.get(k))
            .and_then(Json::as_str)
            .unwrap_or("?")
    };
    println!("simd dispatch tier: {} (detected {}, threads {})",
             hs("simd_tier"), hs("simd_detected"),
             host.and_then(|h| h.get("threads"))
                 .and_then(Json::as_f64)
                 .unwrap_or(f64::NAN));
    let entries = json
        .get("entries")
        .and_then(Json::as_arr)
        .unwrap_or(&[]);
    let s = |e: &Json, k: &str| {
        e.get(k).and_then(Json::as_str).unwrap_or("?").to_string()
    };
    let g = |e: &Json, k: &str| {
        e.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN)
    };
    let ms = |e: &Json, k: &str| format!("{:.3}", g(e, k) / 1e6);
    let keep = |e: &&Json| {
        mode_filter
            .as_deref()
            .map_or(true, |m| e.get("mode").and_then(Json::as_str)
                    == Some(m))
    };
    // the OaA acceptance ratio: overlap-add vs full-pad fbfft on the
    // large-input smoke shape, from the same document (CI thresholds
    // the fprop line at 1.2x)
    let total = |mode: &str, pass: &str| {
        entries
            .iter()
            .find(|e| s(e, "layer") == "oaa144" && s(e, "mode") == mode
                  && s(e, "pass") == pass)
            .map(|e| g(e, "total_ns"))
    };
    for pass in ["fprop", "bprop", "accgrad"] {
        if let (Some(full), Some(oaa)) =
            (total("fbfft", pass), total("oaa", pass))
        {
            println!("oaa speedup vs full-pad fbfft (oaa144 {pass}): \
                      {:.2}x", full / oaa);
        }
    }
    if smoke {
        // surface the acceptance ratios without a JSON reader: the
        // cgemm speedup gate plus the SoA proof points (fft_ns beating
        // the scalar path, pack_ns == 0 under fbfft)
        for e in entries.iter().filter(keep) {
            println!(
                "{} {} {}: fft {:.0} ns, pack {:.0} ns, cgemm {:.0} ns, \
                 naive {:.0} ns, speedup {:.2}x",
                s(e, "layer"), s(e, "mode"), s(e, "pass"),
                g(e, "fft_ns"), g(e, "pack_ns"), g(e, "cgemm_ns"),
                g(e, "cgemm_naive_ns"), g(e, "cgemm_speedup"));
        }
        return;
    }
    let mut t = Table::new(&[
        "layer", "pass", "mode", "FFT A", "TRANS A", "FFT B", "TRANS B",
        "CGEMM", "TRANS C", "IFFT C", "FFT Σ", "PACK Σ", "total ms",
        "cgemm speedup"]);
    for e in entries.iter().filter(keep) {
        t.row(vec![
            s(e, "layer"), s(e, "pass"), s(e, "mode"),
            ms(e, "fft_a_ns"), ms(e, "trans_a_ns"), ms(e, "fft_b_ns"),
            ms(e, "trans_b_ns"), ms(e, "cgemm_ns"), ms(e, "trans_c_ns"),
            ms(e, "ifft_c_ns"), ms(e, "fft_ns"), ms(e, "pack_ns"),
            ms(e, "total_ns"),
            format!("{:.2}x", g(e, "cgemm_speedup")),
        ]);
    }
    println!(
        "Table 5: frequency-pipeline stage breakdown \
         (host engines, planes/16, S=4; from BENCH_fftconv.json):\n{}",
        t.render());
    if let Ok(rt) = Runtime::open("artifacts") {
        match sec54_report(&rt) {
            Ok(r) => println!("{r}"),
            Err(e) => eprintln!("sec54 failed: {e:#}"),
        }
    }
}
