//! Bench target for Figure 8: batched 2-D FFT with transposed output.
use fbfft_repro::reports::fig8_report;
use fbfft_repro::runtime::Runtime;

fn main() {
    let rt = Runtime::open("artifacts").ok();
    match fig8_report(rt.as_ref()) {
        Ok(r) => println!("{r}"),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
