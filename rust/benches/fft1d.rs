//! Bench target for Figure 7: batched 1-D FFT, fbfft vs vendor.
//! `cargo bench --bench fft1d` (PJRT section included when artifacts exist).
use fbfft_repro::reports::fig7_report;
use fbfft_repro::runtime::Runtime;

fn main() {
    let rt = Runtime::open("artifacts").ok();
    match fig7_report(rt.as_ref()) {
        Ok(r) => println!("{r}"),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
