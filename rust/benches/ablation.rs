//! Ablation bench for the design choices DESIGN.md §5/§8 calls out:
//!
//! 1. §5.2 pairwise real packing — fbfft with vs without packing two
//!    real rows into one complex transform;
//! 2. §8.2 bit-reversal elision — DIF→(pointwise)→DIT round trip vs the
//!    permuting DIT→DIT baseline;
//! 3. L1 schedule choice — dense MXU-DFT vs four-step factorization is a
//!    structural choice at the Pallas layer; its host proxy (direct
//!    matrix product vs two-stage butterfly) is measured here as the
//!    flop-vs-locality trade;
//! 4. §6 memory model — printed footprints for vendor / fbfft / tiled.

use std::time::Duration;

use fbfft_repro::conv::ConvProblem;
use fbfft_repro::cost::memory;
use fbfft_repro::fft::{fbfft_host, real::rfft_len, C32};
use fbfft_repro::metrics::{bench, Table};
use fbfft_repro::util::Rng;

const MIN_TIME: Duration = Duration::from_millis(60);

/// Unpaired variant of rfft_batch (packing ablation): one real row per
/// complex transform, imaginary lane wasted.
fn rfft_batch_unpaired(plan: &fbfft_host::FbfftPlan, input: &[f32],
                       n: usize, batch: usize, out: &mut [C32]) {
    let nf = rfft_len(n);
    let mut buf = [C32::ZERO; fbfft_host::MAX_N];
    for b in 0..batch {
        for j in 0..n {
            buf[j] = C32::new(input[b * n + j], 0.0);
        }
        plan.cfft_in_place(&mut buf[..n], false);
        for k in 0..nf {
            let zk = buf[k];
            let zc = buf[(n - k) % n].conj();
            out[b * nf + k] = (zk + zc).scale(0.5);
        }
    }
}

fn main() {
    let mut rng = Rng::new(0xAB);

    // -- 1. pairwise packing --------------------------------------------
    let mut t = Table::new(&["n", "batch", "unpaired ms", "paired ms",
                             "packing gain"]);
    for n in [16usize, 64, 256] {
        let batch = 4096;
        let x = rng.normal_vec(batch * n);
        let plan = fbfft_host::cached(n);
        let mut out = vec![C32::ZERO; batch * rfft_len(n)];
        let ru = bench(|| {
            rfft_batch_unpaired(&plan, &x, n, batch, &mut out);
            std::hint::black_box(&out);
        }, MIN_TIME);
        let rp = bench(|| {
            plan.rfft_batch(&x, n, batch, &mut out);
            std::hint::black_box(&out);
        }, MIN_TIME);
        t.row(vec![n.to_string(), batch.to_string(),
                   format!("{:.3}", ru.secs_per_iter() * 1e3),
                   format!("{:.3}", rp.secs_per_iter() * 1e3),
                   format!("{:.2}x",
                           ru.secs_per_iter() / rp.secs_per_iter())]);
    }
    println!("Ablation 1 — §5.2 two-reals-in-one-complex packing:\n{}",
             t.render());

    // -- 2. bit-reversal elision ------------------------------------------
    let mut t = Table::new(&["n", "with bitrev ms", "DIF/DIT ms",
                             "elision gain"]);
    for n in [16usize, 64, 256] {
        let reps = 4096usize;
        let plan = fbfft_host::cached(n);
        let sig: Vec<C32> = (0..n)
            .map(|_| C32::new(rng.normal(), rng.normal())).collect();
        let mut buf = sig.clone();
        let rb = bench(|| {
            for _ in 0..reps {
                buf.copy_from_slice(&sig);
                plan.cfft_in_place(&mut buf, false);
                plan.cfft_in_place(&mut buf, true);
            }
            std::hint::black_box(&buf);
        }, MIN_TIME);
        let rd = bench(|| {
            for _ in 0..reps {
                buf.copy_from_slice(&sig);
                plan.cfft_dif_bitrev_out(&mut buf, false);
                plan.cfft_dit_bitrev_in(&mut buf, true);
            }
            std::hint::black_box(&buf);
        }, MIN_TIME);
        t.row(vec![n.to_string(),
                   format!("{:.3}", rb.secs_per_iter() * 1e3),
                   format!("{:.3}", rd.secs_per_iter() * 1e3),
                   format!("{:.2}x",
                           rb.secs_per_iter() / rd.secs_per_iter())]);
    }
    println!("Ablation 2 — §8.2 bit-reversal elision (fwd+inv round \
              trip, x4096):\n{}", t.render());

    // -- 3. memory model ---------------------------------------------------
    let mut t = Table::new(&["config", "freq MB", "trans MB", "padded MB",
                             "total MB"]);
    let p = ConvProblem::square(128, 64, 64, 64, 9); // Table-4 L2
    let mb = |b: usize| format!("{:.1}", b as f64 / (1 << 20) as f64);
    for (label, f) in [
        ("vendor (cuFFT)", memory::vendor_footprint(&p, 64, false)),
        ("vendor + in-place CGEMM", memory::vendor_footprint(&p, 64, true)),
        ("fbfft", memory::fbfft_footprint(&p, 64)),
        ("fbfft tiled d=8 (4 par)", memory::tiled_footprint(&p, 8, 4)),
    ] {
        t.row(vec![label.into(), mb(f.freq_buffers),
                   mb(f.transpose_buffers), mb(f.padded_copies),
                   mb(f.total())]);
    }
    println!("Ablation 3 — §6 temporary-memory model (Table-4 L2):\n{}",
             t.render());
}
