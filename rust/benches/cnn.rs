//! Bench target for Table 3: AlexNet + OverFeat-fast whole-CNN totals.
use fbfft_repro::reports::table3_report;
use fbfft_repro::runtime::Runtime;

fn main() {
    match Runtime::open("artifacts").and_then(|rt| table3_report(&rt)) {
        Ok(r) => println!("{r}"),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
