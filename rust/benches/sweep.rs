//! Bench target for Figures 1-6: the 8,232-configuration sweep (model
//! plane + measured PJRT anchor subset).
use fbfft_repro::reports::{fig16_report, sweep::fig16_measured};
use fbfft_repro::runtime::Runtime;

fn main() {
    println!("{}", fig16_report());
    match Runtime::open("artifacts") {
        Ok(rt) => match fig16_measured(&rt) {
            Ok(r) => println!("{r}"),
            Err(e) => eprintln!("measured subset failed: {e:#}"),
        },
        Err(e) => eprintln!("(no artifacts: {e:#}; model plane only)"),
    }
}
