//! Bench target for Table 4: representative layers L1-L5.
use fbfft_repro::reports::table4_report;
use fbfft_repro::runtime::Runtime;

fn main() {
    let rt = Runtime::open("artifacts").ok();
    match table4_report(rt.as_ref()) {
        Ok(r) => println!("{r}"),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
