//! Closed-loop load bench for the sharded serving engine, plus the
//! machine-readable `BENCH_serve.json` perf artifact (CI's serve-smoke
//! gate reads it; `reports::serve` renders the human table from the
//! same document so the two can never disagree).
//!
//! Two load models:
//!
//! * `--mode closed` (default): N client threads, each submits one
//!   request, waits for its completion, submits the next — the classic
//!   closed loop whose offered load self-regulates to the engine's
//!   capacity (throughput-oriented).
//! * `--mode open`: replays a Poisson arrival trace at a fixed rate
//!   regardless of completions — the latency-under-load view (arrival
//!   bursts pile onto the batcher exactly as §3.3's bulk-synchronous
//!   regime expects).
//!
//! `cargo bench --bench serve -- --smoke` runs a fixed small closed-loop
//! config (4 shards, host backend) and still writes the JSON.
//!
//! Smoke mode also runs the open-loop *overload knee* probe: fresh
//! small engines replay Poisson traces at escalating rates and the
//! first rate whose client-side p99 blows past 2x the base rate's p99
//! is the knee — committed into the JSON as the `overload` block so
//! the carrying capacity is a tracked artifact key.
//!
//! The default workload is the Table-4 AlexNet-style layer chain
//! (`--net alexnet`): every admitted image traverses all layers behind
//! one admission decision, and the report's `states_per_sec` is the
//! paper's whole-CNN rate. `--net single` reproduces the old one-layer
//! workload.
//!
//! Flags: `--smoke`, `--mode open|closed`, `--net alexnet|single`,
//! `--requests N`, `--shards N`, `--clients N`, `--capacity N`,
//! `--rate R` (open mode, req/s),
//! `--faults SPEC` (deterministic chaos script, see `testkit::faults`),
//! `--out FILE` (default `BENCH_serve.json`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fbfft_repro::conv::ConvProblem;
use fbfft_repro::coordinator::service::{Backend, Completion,
                                        EngineClient, EngineConfig,
                                        ServeEngine, ServeRequest};
use fbfft_repro::coordinator::{NetPlan, Strategy};
use fbfft_repro::metrics::Histogram;
use fbfft_repro::reports::{serve_json, serve_table};
use fbfft_repro::testkit::faults::FaultPlan;
use fbfft_repro::trace;
use fbfft_repro::util::{Json, Rng};

struct BenchArgs {
    smoke: bool,
    mode: String,
    net: String,
    requests: usize,
    shards: usize,
    clients: usize,
    capacity: usize,
    rate: f64,
    faults: Option<Arc<FaultPlan>>,
    out: String,
}

fn parse() -> BenchArgs {
    let argv: Vec<String> = std::env::args().collect();
    let flag = |name: &str| argv.iter().any(|a| a == name);
    let val = |name: &str| {
        argv.iter()
            .position(|a| a == name)
            .and_then(|i| argv.get(i + 1))
            .cloned()
    };
    let smoke = flag("--smoke");
    let faults = val("--faults").map(|spec| {
        match FaultPlan::parse(&spec) {
            Ok(p) => Arc::new(p),
            Err(e) => {
                eprintln!("bad --faults: {e}");
                std::process::exit(2);
            }
        }
    });
    let mut a = BenchArgs {
        smoke,
        mode: val("--mode").unwrap_or_else(|| "closed".into()),
        net: val("--net").unwrap_or_else(|| "alexnet".into()),
        requests: if smoke { 200 } else { 2000 },
        shards: 4,
        clients: if smoke { 8 } else { 16 },
        capacity: if smoke { 8 } else { 16 },
        rate: 400.0,
        faults,
        out: val("--out").unwrap_or_else(|| "BENCH_serve.json".into()),
    };
    let usize_of = |s: Option<String>, d: usize| {
        s.and_then(|v| v.parse().ok()).unwrap_or(d)
    };
    a.requests = usize_of(val("--requests"), a.requests);
    a.shards = usize_of(val("--shards"), a.shards).max(1);
    a.clients = usize_of(val("--clients"), a.clients).max(1);
    a.capacity = usize_of(val("--capacity"), a.capacity).max(1);
    a.rate = val("--rate")
        .and_then(|v| v.parse().ok())
        .unwrap_or(a.rate);
    a
}

/// Each client thread drives its own request stream through the
/// [`Ticket`](fbfft_repro::coordinator::Ticket) API: submit → wait →
/// submit, sharing one global request budget.
fn run_closed(client: &EngineClient, a: &BenchArgs) -> usize {
    let budget = Arc::new(AtomicUsize::new(a.requests));
    let completed = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|scope| {
        for c in 0..a.clients {
            let client = client.clone();
            let budget = budget.clone();
            let completed = completed.clone();
            let capacity = a.capacity;
            scope.spawn(move || {
                let mut rng = Rng::new(0x10AD ^ c as u64);
                loop {
                    let slot = budget.fetch_update(
                        Ordering::Relaxed, Ordering::Relaxed,
                        |v| v.checked_sub(1));
                    if slot.is_err() {
                        break; // budget exhausted
                    }
                    // the serving trace's request-size mixture
                    let images = match rng.below(10) {
                        0..=5 => 1,
                        6..=7 => 2,
                        8 => 4,
                        _ => 8,
                    }
                    .min(capacity);
                    let ticket = match client.submit_images(images, None)
                    {
                        Ok(t) => t,
                        // rejected: counted by the engine
                        Err(_) => continue,
                    };
                    if ticket
                        .wait_timeout(Duration::from_secs(60))
                        .is_ok()
                    {
                        completed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    completed.load(Ordering::Relaxed)
}

/// Replay a Poisson trace at a fixed rate; completions drain on a
/// collector channel.
fn run_open(client: &EngineClient, a: &BenchArgs) -> usize {
    let reqs = trace::request_trace(a.requests, a.rate, 0x5E);
    let (tx, rx) = mpsc::channel::<Completion>();
    let t0 = Instant::now();
    let mut accepted = 0usize;
    for r in &reqs {
        std::thread::sleep(
            Duration::from_secs_f64(r.arrival_s)
                .saturating_sub(t0.elapsed()));
        if client
            .submit(ServeRequest {
                id: r.id,
                images: r.images.min(a.capacity),
                deadline: None,
                reply: tx.clone(),
            })
            .is_ok()
        {
            accepted += 1;
        }
    }
    drop(tx);
    let mut done = 0usize;
    while done < accepted {
        if rx.recv_timeout(Duration::from_secs(60)).is_err() {
            break;
        }
        done += 1;
    }
    done
}

/// Deterministic weight-spectrum cache probe: a fresh single-shard
/// engine forced onto the fbfft path serves two back-to-back
/// full-capacity flushes. The first pays the weight FFT (spectrum
/// miss), the second must hit the cache and spend **zero** weight-FFT
/// time — the `second_weight_fft_ns == 0` statement CI gates on.
fn spectra_probe(a: &BenchArgs) -> Json {
    let problem = ConvProblem::square(a.capacity, 2, 2, 8, 3);
    let cfg = EngineConfig::builder()
        .shards(1)
        .capacity(a.capacity)
        .max_wait(Duration::from_millis(2))
        .default_deadline(Duration::from_secs(30))
        .warm(false)
        .force_strategy(Strategy::Fbfft)
        .build()
        .expect("probe config is valid");
    let engine =
        ServeEngine::start(Backend::Host, NetPlan::single(problem), cfg)
            .expect("probe engine starts");
    let (tx, rx) = mpsc::channel::<Completion>();
    for flush in 0..2u64 {
        // a full-capacity request flushes immediately and alone, and
        // the blocking recv serializes the two flushes
        assert!(engine
            .submit(ServeRequest {
                id: flush,
                images: a.capacity,
                deadline: None,
                reply: tx.clone(),
            })
            .is_ok());
        rx.recv_timeout(Duration::from_secs(60))
            .expect("probe flush completes");
    }
    let report = engine.shutdown();
    let wfft = report.weight_fft();
    let (sum_ns, last_ns) = (wfft.sum() * 1e9, wfft.last() * 1e9);
    assert_eq!(report.launches(), 2, "probe must flush exactly twice");
    assert_eq!(report.spectra_misses(), 1, "first flush transforms");
    assert_eq!(report.spectra_hits(), 1, "second flush must hit");
    assert_eq!(last_ns, 0.0,
               "steady-state flush must skip the weight FFT");
    Json::obj(vec![
        ("launches", Json::num(report.launches() as f64)),
        ("spectra_hits", Json::num(report.spectra_hits() as f64)),
        ("spectra_misses", Json::num(report.spectra_misses() as f64)),
        ("first_weight_fft_ns", Json::num(sum_ns - last_ns)),
        ("second_weight_fft_ns", Json::num(last_ns)),
    ])
}

/// Open-loop overload probe: replay short Poisson traces at escalating
/// rates against fresh small engines and record the client-side p99 at
/// each. The knee is the first rate whose p99 exceeds 2x the base
/// rate's p99 (or the top rate when the engine never saturates) — the
/// carrying-capacity artifact key CI tracks run over run.
fn overload_knee(a: &BenchArgs) -> Json {
    let rates = [200.0f64, 400.0, 800.0, 1600.0];
    let mut p99s = Vec::with_capacity(rates.len());
    for (i, rate) in rates.iter().enumerate() {
        let problem = ConvProblem::square(a.capacity, 2, 2, 8, 3);
        let cfg = EngineConfig::builder()
            .shards(2)
            .capacity(a.capacity)
            .max_wait(Duration::from_millis(2))
            .default_deadline(Duration::from_secs(30))
            .warm(false)
            .force_strategy(Strategy::Direct)
            .build()
            .expect("knee config is valid");
        let engine = ServeEngine::start(Backend::Host,
                                        NetPlan::single(problem), cfg)
            .expect("knee engine starts");
        let reqs = trace::request_trace(60, *rate, 0x5E ^ i as u64);
        let (tx, rx) = mpsc::channel::<Completion>();
        let t0 = Instant::now();
        let mut accepted = 0usize;
        for r in &reqs {
            std::thread::sleep(
                Duration::from_secs_f64(r.arrival_s)
                    .saturating_sub(t0.elapsed()));
            if engine
                .submit(ServeRequest {
                    id: r.id,
                    images: r.images.min(a.capacity),
                    deadline: None,
                    reply: tx.clone(),
                })
                .is_ok()
            {
                accepted += 1;
            }
        }
        drop(tx);
        let mut lat = Histogram::new();
        for _ in 0..accepted {
            match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(c) => lat.record(c.latency.as_secs_f64()),
                Err(_) => break,
            }
        }
        engine.shutdown();
        p99s.push(lat.summary().p99 * 1e3);
    }
    let base = p99s[0].max(1e-6);
    let knee = rates
        .iter()
        .zip(&p99s)
        .find(|(_, p)| **p > 2.0 * base)
        .map(|(r, _)| *r)
        .unwrap_or(rates[rates.len() - 1]);
    Json::obj(vec![
        ("rates_req_s",
         Json::Arr(rates.iter().map(|r| Json::num(*r)).collect())),
        ("p99_ms",
         Json::Arr(p99s.iter().map(|p| Json::num(*p)).collect())),
        ("knee_req_s", Json::num(knee)),
    ])
}

fn main() {
    let a = parse();
    // host backend: the bench must run on any checkout (the PJRT path
    // is exercised by the artifact-gated integration tier)
    let net = match a.net.as_str() {
        // the Table-4 whole-CNN regime: the AlexNet-style chain (the
        // smoke tier runs the proportionally shrunk variant)
        "alexnet" => {
            if a.smoke {
                NetPlan::alexnet_small(a.capacity)
            } else {
                NetPlan::alexnet(a.capacity)
            }
        }
        "single" => NetPlan::single(if a.smoke {
            ConvProblem::square(a.capacity, 2, 2, 8, 3)
        } else {
            ConvProblem::square(a.capacity, 8, 8, 16, 3)
        }),
        n => {
            eprintln!("unknown --net {n} (alexnet|single)");
            std::process::exit(2);
        }
    };
    let mut builder = EngineConfig::builder()
        .shards(a.shards)
        .capacity(a.capacity)
        .max_wait(Duration::from_millis(2))
        // generous SLA: the bench measures latency, it does not shed
        // load (zero rejections is a smoke-gate assertion)
        .default_deadline(Duration::from_secs(if a.smoke {
            30
        } else {
            5
        }));
    // chaos script (--faults): only the main engine sees it — the
    // probe engines below run fault-free
    if let Some(plan) = &a.faults {
        builder = builder.faults(plan.clone());
    }
    let cfg = builder.build().expect("bench config is valid");
    let engine = ServeEngine::start(Backend::Host, net, cfg)
        .expect("host serve engine starts");
    let client = engine.client();
    let t0 = Instant::now();
    let done = match a.mode.as_str() {
        "open" => run_open(&client, &a),
        "closed" => run_closed(&client, &a),
        m => {
            eprintln!("unknown --mode {m} (open|closed)");
            std::process::exit(2);
        }
    };
    let wall = t0.elapsed();
    let report = engine.shutdown();
    assert_eq!(done, report.requests(),
               "every accepted request completes exactly once");
    let json = serve_json(&report, &a.mode, a.smoke, wall);
    let probe = spectra_probe(&a);
    let json = match json {
        Json::Obj(mut doc) => {
            doc.insert("spectra_probe".into(), probe);
            if a.smoke {
                doc.insert("overload".into(), overload_knee(&a));
            }
            Json::Obj(doc)
        }
        _ => unreachable!("serve_json builds an object"),
    };
    std::fs::write(&a.out, json.to_string())
        .unwrap_or_else(|e| panic!("write {}: {e}", a.out));
    eprintln!("wrote {} (mode={}, net={}, smoke={})", a.out, a.mode,
              a.net, a.smoke);
    println!("{}", serve_table(&json));
}
