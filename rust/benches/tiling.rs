//! Bench target for Sec 6: tiled vs untiled decomposition, plus the
//! autotuner demonstration (Sec 3.4).
use fbfft_repro::reports::tables::{autotune_report, tiling_report};
use fbfft_repro::runtime::Runtime;

fn main() {
    let rt = Runtime::open("artifacts").ok();
    match tiling_report(rt.as_ref()) {
        Ok(r) => println!("{r}"),
        Err(e) => eprintln!("tiling failed: {e:#}"),
    }
    println!();
    println!("Sec 3.4 autotuner:\n{}", autotune_report());
}
