//! Workload generation: every problem set the paper evaluates, plus the
//! request traces the serving example drives through the coordinator.
//!
//! Mirrors `python/compile/specs.py` (the AOT manifest carries the same
//! specs; `runtime::manifest` cross-checks the two).

use crate::conv::ConvProblem;
use crate::util::Rng;

/// Table 2's axes (Figures 1–6).
pub const TABLE2_S: [usize; 4] = [1, 16, 64, 128];
pub const TABLE2_F: [usize; 7] = [1, 4, 16, 64, 96, 128, 256];
pub const TABLE2_FO: [usize; 7] = [1, 4, 16, 64, 96, 128, 256];
pub const TABLE2_K: [usize; 6] = [3, 5, 7, 9, 11, 13];
pub const TABLE2_Y: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// All 8,232 configurations of Table 2 (h = y + k - 1, paper fn. 8).
pub fn table2_grid() -> Vec<ConvProblem> {
    let mut v = Vec::with_capacity(8232);
    for &s in &TABLE2_S {
        for &f in &TABLE2_F {
            for &fo in &TABLE2_FO {
                for &k in &TABLE2_K {
                    for &y in &TABLE2_Y {
                        v.push(ConvProblem::square(s, f, fo, y + k - 1, k));
                    }
                }
            }
        }
    }
    v
}

/// Uniformly sample one Table-2 configuration (one point of the same
/// space `table2_grid` enumerates; `testkit::cases` rejection-samples
/// this under a CPU work budget for the conformance matrix).
pub fn table2_sample(rng: &mut Rng) -> ConvProblem {
    let s = *rng.choice(&TABLE2_S);
    let f = *rng.choice(&TABLE2_F);
    let fo = *rng.choice(&TABLE2_FO);
    let k = *rng.choice(&TABLE2_K);
    let y = *rng.choice(&TABLE2_Y);
    ConvProblem::square(s, f, fo, y + k - 1, k)
}

/// Table 4's representative layers L1–L5 (exact paper parameters).
pub fn table4_layers() -> Vec<(&'static str, ConvProblem)> {
    vec![
        ("L1", ConvProblem::square(128, 3, 96, 128, 11)),
        ("L2", ConvProblem::square(128, 64, 64, 64, 9)),
        ("L3", ConvProblem::square(128, 128, 128, 32, 9)),
        ("L4", ConvProblem::square(128, 128, 128, 16, 7)),
        ("L5", ConvProblem::square(128, 384, 384, 13, 3)),
    ]
}

/// Plane/batch reduction for CPU execution (documented substitution,
/// DESIGN.md §3) — spatial shape preserved, so the FFT-vs-time-domain
/// character of each layer is preserved.
pub fn scale(p: &ConvProblem, planes: usize, batch: usize) -> ConvProblem {
    let mut q = *p;
    q.s = p.s.min(batch);
    q.f = (p.f / planes).max(1);
    q.fo = (p.fo / planes).max(1);
    q
}

/// AlexNet convolutional layers (Krizhevsky 2012; 2014 convnet-benchmarks
/// shapes, padded inputs). conv1 is strided → vendor-only (paper §4.2).
pub fn alexnet_layers(s: usize) -> Vec<(&'static str, ConvProblem)> {
    let mut c1 = ConvProblem::square(s, 3, 64, 224, 11);
    c1.stride = 4;
    vec![
        ("conv1", c1),
        ("conv2", ConvProblem::square(s, 64, 192, 31, 5)),
        ("conv3", ConvProblem::square(s, 192, 384, 15, 3)),
        ("conv4", ConvProblem::square(s, 384, 256, 15, 3)),
        ("conv5", ConvProblem::square(s, 256, 256, 15, 3)),
    ]
}

/// OverFeat *fast* convolutional layers (Sermanet 2014).
pub fn overfeat_fast_layers(s: usize) -> Vec<(&'static str, ConvProblem)> {
    let mut c1 = ConvProblem::square(s, 3, 96, 231, 11);
    c1.stride = 4;
    vec![
        ("conv1", c1),
        ("conv2", ConvProblem::square(s, 96, 256, 28, 5)),
        ("conv3", ConvProblem::square(s, 256, 512, 14, 3)),
        ("conv4", ConvProblem::square(s, 512, 1024, 14, 3)),
        ("conv5", ConvProblem::square(s, 1024, 1024, 14, 3)),
    ]
}

/// §5.4's comparison grid: x = h = w ∈ {13,16,27,32,57,64},
/// p = S = f = f' ∈ {16,32,64,128}, k = 3.
pub fn sec54_grid() -> Vec<ConvProblem> {
    let mut v = Vec::new();
    for x in [13usize, 16, 27, 32, 57, 64] {
        for p in [16usize, 32, 64, 128] {
            v.push(ConvProblem::square(p, p, p, x, 3));
        }
    }
    v
}

/// One inference request for the serving example: a client asks for a
/// forward convolution of `images` samples against the layer loaded by
/// the server. Arrival times are Poisson.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub arrival_s: f64,
    pub images: usize,
}

/// Poisson request trace with geometric-ish size mix (mostly single
/// images with occasional small bursts — a serving-shaped load).
pub fn request_trace(n: usize, rate_per_s: f64, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let mut t = 0f64;
    (0..n as u64)
        .map(|id| {
            t += rng.exponential(1.0 / rate_per_s as f32) as f64;
            let images = match rng.below(10) {
                0..=5 => 1,
                6..=7 => 2,
                8 => 4,
                _ => 8,
            };
            Request { id, arrival_s: t, images }
        })
        .collect()
}

/// Synthetic labeled dataset for the e2e training example: class k is a
/// blurred directional pattern + noise; linearly separable enough that a
/// healthy training loop visibly reduces the loss within ~100 steps.
pub fn synthetic_batch(rng: &mut Rng, s: usize, c: usize, hw: usize,
                       classes: usize) -> (Vec<f32>, Vec<i32>) {
    let mut x = vec![0f32; s * c * hw * hw];
    let mut y = vec![0i32; s];
    for b in 0..s {
        let class = rng.below(classes);
        y[b] = class as i32;
        let (fx, fy) = match class % 4 {
            0 => (1.0, 0.0),
            1 => (0.0, 1.0),
            2 => (1.0, 1.0),
            _ => (1.0, -1.0),
        };
        for ch in 0..c {
            for r in 0..hw {
                for q in 0..hw {
                    let phase = (fx * q as f32 + fy * r as f32)
                        * std::f32::consts::PI * 2.0 / hw as f32
                        * (1.0 + class as f32 * 0.5);
                    x[((b * c + ch) * hw + r) * hw + q] =
                        phase.sin() + 0.3 * rng.normal();
                }
            }
        }
    }
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_exactly_8232_configs() {
        let g = table2_grid();
        assert_eq!(g.len(), 8232); // 4·7·7·6·7, the paper's count
        // parameterized on output size: y = h - k + 1 hits the grid
        for p in &g {
            assert!(TABLE2_Y.contains(&p.yh()));
            assert!(TABLE2_K.contains(&p.kh));
        }
    }

    #[test]
    fn table2_sample_stays_on_the_grid() {
        let mut rng = Rng::new(0x7AB);
        let grid = table2_grid();
        for _ in 0..50 {
            let p = table2_sample(&mut rng);
            assert!(grid.contains(&p), "{p:?} not a Table-2 point");
        }
    }

    #[test]
    fn table4_matches_paper_parameters() {
        let t = table4_layers();
        assert_eq!(t[1].1, ConvProblem::square(128, 64, 64, 64, 9));
        assert_eq!(t[4].1.kh, 3);
        assert_eq!(t[0].1.f, 3);
    }

    #[test]
    fn scaling_preserves_spatial_shape() {
        let (_, l2) = &table4_layers()[1];
        let s = scale(l2, 8, 8);
        assert_eq!((s.h, s.w, s.kh), (l2.h, l2.w, l2.kh));
        assert_eq!(s.f, 8);
        assert_eq!(s.s, 8);
    }

    #[test]
    fn cnn_tables_have_strided_conv1_only() {
        for layers in [alexnet_layers(128), overfeat_fast_layers(128)] {
            assert_eq!(layers[0].1.stride, 4);
            for (_, p) in &layers[1..] {
                assert_eq!(p.stride, 1);
            }
        }
    }

    #[test]
    fn sec54_grid_is_24_points() {
        assert_eq!(sec54_grid().len(), 24);
    }

    #[test]
    fn request_trace_is_sorted_and_deterministic() {
        let a = request_trace(100, 50.0, 7);
        let b = request_trace(100, 50.0, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
        }
        for w in a.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
    }

    #[test]
    fn synthetic_batch_is_labeled_and_bounded() {
        let mut rng = Rng::new(1);
        let (x, y) = synthetic_batch(&mut rng, 8, 1, 16, 4);
        assert_eq!(x.len(), 8 * 256);
        assert!(y.iter().all(|l| (0..4).contains(l)));
        assert!(x.iter().all(|v| v.abs() < 10.0));
    }
}
