//! The K40m timing model: cuDNN (unrolled GEMM) vs cuFFT-conv vs
//! fbfft-conv, calibrated against the paper's published numbers.
//!
//! Constants come from two sources: the hardware the paper names (Tesla
//! K40m: 4.29 Tflop/s single-precision peak — quoted verbatim in §4.2 —
//! and 288 GB/s memory bandwidth) and stage efficiencies fitted to the
//! Table-4 / Table-5 rows (see `tests::calibration_*`). The model is used
//! to fill the 8,232-point plane of Figures 1–6; its purpose is the
//! *shape* — who wins, by roughly what factor, where the crossovers sit —
//! not ms-exact prediction (DESIGN.md §3).

use crate::conv::ConvProblem;
use crate::util::SimdTier;

use super::{cgemm_bytes, direct_flops, pipeline_cost};

/// NVIDIA Tesla K40m (the paper's testbed).
#[derive(Clone, Copy, Debug)]
pub struct K40m {
    /// single-precision peak, FLOP/s (paper §4.2: 4.29 Tflop/s)
    pub peak_flops: f64,
    /// device memory bandwidth, B/s
    pub mem_bw: f64,
    /// per-kernel-launch latency, s
    pub launch: f64,
}

impl Default for K40m {
    fn default() -> Self {
        K40m { peak_flops: 4.29e12, mem_bw: 288e9, launch: 8e-6 }
    }
}

/// cuDNN 1.0 model: matrix-unrolled convolution at a sustained fraction
/// of peak, degraded when the implied GEMM is skinny (small reduction or
/// output dims — the latency-sensitive regime of Figures 1–6 where cuDNN
/// still wins).
#[derive(Clone, Copy, Debug)]
pub struct CudnnModel {
    pub hw: K40m,
    /// sustained fraction of peak on well-shaped problems (Table-4 fit:
    /// observed 0.17–0.35 across L1–L5)
    pub eff: f64,
}

impl Default for CudnnModel {
    fn default() -> Self {
        CudnnModel { hw: K40m::default(), eff: 0.25 }
    }
}

impl CudnnModel {
    /// Predicted seconds for one pass (passes are symmetric in FLOPs).
    pub fn time(&self, p: &ConvProblem) -> f64 {
        // GEMM shape: (S·y²) × (f·k²) → f'; efficiency saturates with
        // both the output-pixel count and the reduction length.
        let pixels = (p.s * p.yh() * p.yw()) as f64;
        let redux = (p.f * p.kh * p.kw) as f64;
        let shape_eff = (pixels / (pixels + 4096.0))
            * (redux / (redux + 48.0));
        let eff = (self.eff * shape_eff.max(0.02)).max(1e-3);
        direct_flops(p) / (self.hw.peak_flops * eff)
            + 2.0 * self.hw.launch
            + self.bytes(p) / self.hw.mem_bw
    }

    fn bytes(&self, p: &ConvProblem) -> f64 {
        4.0 * (p.input_len() + p.weight_len() + p.output_len()) as f64
    }
}

/// Frequency-domain convolution model: Table-1 stages with the fitted
/// per-stage efficiencies, vendor (cuFFT) or fbfft mode.
#[derive(Clone, Copy, Debug)]
pub struct CufftConvModel {
    pub hw: K40m,
    /// FFT stages: fraction of memory bandwidth sustained (they are
    /// bandwidth-bound; Table-5 fit ≈ 0.3–0.6)
    pub fft_mem_eff: f64,
    /// CGEMM: fraction of peak (Table-5 fit ≈ 0.23–0.63 by plane count)
    pub gemm_eff: f64,
    /// transposes: fraction of bandwidth (Table-5 fit ≈ 0.9)
    pub trans_mem_eff: f64,
    /// true = fbfft: implicit padding (kernel transforms read k², not n²),
    /// fused transposes (elided), fewer launches, §5.4's measured ≥1.4×
    /// transform-level gain folded into the FFT stages
    pub fbfft: bool,
    /// Batch-lane SIMD width the transform kernels exploit — the §5
    /// mapping puts one transform per warp with the batch across the 32
    /// lanes, so a scalar transform stream sustains 1/32 of the machine.
    /// The FFT stages gain a lane-scaled compute-roofline term
    /// `flops / (peak · fft_lanes/32)` alongside the bandwidth term;
    /// on the Table-4/5 regimes bandwidth still binds (the fitted
    /// defaults leave those predictions untouched) but scalar-lane
    /// transforms (`fft_lanes = 1`, the pre-SoA host baseline) go
    /// compute-bound at small bases, which is exactly the regime the
    /// SoA rewrite targets. The host twin's width is
    /// [`crate::fft::soa::LANES`].
    pub fft_lanes: f64,
    /// FMA lane width of the CGEMM engine, relative to the 32-lane warp
    /// the calibration anchors to: the GEMM compute roofline is scaled
    /// by `gemm_lanes/32` exactly like the transform term. The paper's
    /// GPU ctors keep the full warp (32 — predictions unchanged); the
    /// host-tier ctors substitute the *dispatched* SIMD tier's FMA
    /// width ([`SimdTier::fma_lanes`]: 1 scalar / 8 AVX2 / 16 AVX-512),
    /// so the model explains why a forced-scalar run's CGEMM goes
    /// compute-bound an order of magnitude earlier.
    pub gemm_lanes: f64,
}

impl CufftConvModel {
    pub fn vendor() -> Self {
        CufftConvModel {
            hw: K40m::default(),
            fft_mem_eff: 0.40,
            gemm_eff: 0.35,
            trans_mem_eff: 0.90,
            fbfft: false,
            // the planner's internal vectorization, fitted — well short
            // of the full warp but never scalar
            fft_lanes: 4.0,
            // cuBLAS CGEMM drives full warps
            gemm_lanes: 32.0,
        }
    }

    pub fn fbfft() -> Self {
        CufftConvModel {
            // §5: 'reaches up to 78% efficiency'; §5.4: ≥1.4× over cuFFT
            fft_mem_eff: 0.60,
            fbfft: true,
            // one transform per warp, batch across all 32 lanes (§5)
            fft_lanes: 32.0,
            ..Self::vendor()
        }
    }

    /// The fbfft model re-anchored to a *host* SIMD dispatch tier: same
    /// stage structure and fitted efficiencies, with both the
    /// transform-lane and CGEMM compute terms scaled to the tier's FMA
    /// width. The paper-calibrated [`CufftConvModel::vendor`] /
    /// [`CufftConvModel::fbfft`] stay untouched; this twin exists so
    /// reports and the autotuner can sanity-check *measured* tier
    /// speedups against the roofline shape (a forced-scalar run should
    /// slow by roughly the compute-bound fraction, not 8×).
    pub fn host_tier(tier: SimdTier) -> Self {
        let lanes = tier.fma_lanes() as f64;
        CufftConvModel {
            fft_lanes: lanes.min(crate::fft::soa::LANES as f64),
            gemm_lanes: lanes,
            ..Self::fbfft()
        }
    }

    /// [`CufftConvModel::host_tier`] at the tier runtime dispatch
    /// actually selected (detection ∧ `FBFFT_SIMD`).
    pub fn host() -> Self {
        Self::host_tier(crate::util::simd::tier())
    }

    /// Basis the engine would use for `p` (fbfft: next pow2; vendor: the
    /// caller/autotuner supplies a smooth size — default h here).
    pub fn default_basis(&self, p: &ConvProblem) -> usize {
        let n = p.h.max(p.w);
        if self.fbfft {
            n.next_power_of_two()
        } else {
            n
        }
    }

    /// Bytes touched by one FFT stage over `count` transforms: one read
    /// of the (padded or, for fbfft, logical) input + one write of the
    /// half-spectrum, times two row/column passes.
    fn fft_bytes(&self, count: f64, n: usize, in_h: usize, in_w: usize)
                 -> f64 {
        let nf = (n / 2 + 1) as f64;
        let read = if self.fbfft {
            // implicit zero-copy padding: only the logical data is read
            (in_h * in_w) as f64 * 4.0
        } else {
            // vendor: the padded duplicate is materialized and re-read
            2.0 * (n * n) as f64 * 4.0
        };
        count * (read + 2.0 * nf * n as f64 * 8.0)
    }

    /// Predicted seconds for one pass on basis `n`.
    pub fn time(&self, p: &ConvProblem, n: usize) -> f64 {
        let c = pipeline_cost(p, n, !self.fbfft);
        let t_in = (p.s * p.f) as f64;
        let t_wei = (p.fo * p.f) as f64;
        let t_out = (p.s * p.fo) as f64;
        let bw = self.hw.mem_bw * self.fft_mem_eff;
        // each transform stage is a roofline: bandwidth-bound on the
        // fitted regimes, compute-bound when the lane utilization drops
        // (fft_lanes → 1 models the scalar-transform baseline)
        let fft_rate =
            self.hw.peak_flops * (self.fft_lanes / 32.0).min(1.0);
        let fft_a = (self.fft_bytes(t_in, n, p.h, p.w) / bw)
            .max(c.fft_a / fft_rate);
        let fft_b = (self.fft_bytes(t_wei, n, p.kh, p.kw) / bw)
            .max(c.fft_b / fft_rate);
        let ifft = (self.fft_bytes(t_out, n, n, n) / bw)
            .max(c.ifft_c / fft_rate);
        // CGEMM: roofline on the blocked engine's arithmetic intensity —
        // compute-bound once the reduction plane count saturates the
        // efficiency term, bandwidth-bound in the skinny-f regime where
        // the panels barely get re-used (cost::cgemm_intensity)
        let geff = self.gemm_eff * (p.f as f64 / (p.f as f64 + 16.0))
            .max(0.05);
        let gemm_rate =
            self.hw.peak_flops * (self.gemm_lanes / 32.0).min(1.0);
        let gemm_compute = c.cgemm / (gemm_rate * geff);
        let gemm_memory =
            cgemm_bytes(p, n) / (self.hw.mem_bw * self.trans_mem_eff);
        let gemm = gemm_compute.max(gemm_memory);
        let trans = c.trans_bytes / (self.hw.mem_bw * self.trans_mem_eff);
        fft_a + fft_b + ifft + gemm + trans + c.launches * self.hw.launch
    }

    /// Predicted seconds for one pass run Overlap-and-Add at output-tile
    /// edge `tile`: the tile grid over the stride-1 output extent is
    /// batched into the inner problem's batch axis (the engine's
    /// tile-group execution), so the cost is the full-pad pipeline on
    /// the equivalent `s·T`-batch window problem at the small fixed
    /// basis, plus the gather/scatter staging traffic (one read + one
    /// write of the window copies on both ends).
    pub fn oaa_time(&self, p: &ConvProblem, tile: usize) -> f64 {
        let (yh1, yw1) = (p.h - p.kh + 1, p.w - p.kw + 1);
        let tiles = yh1.div_ceil(tile) * yw1.div_ceil(tile);
        let (th, tw) = (tile + p.kh - 1, tile + p.kw - 1);
        let q = ConvProblem::new(p.s * tiles, p.f, p.fo, th, tw,
                                 p.kh, p.kw);
        let n = crate::conv::tiled::tile_fft_size(tile, p.kh, p.kw);
        let stage_bytes =
            8.0 * (q.input_len() + q.output_len()) as f64;
        self.time(&q, n)
            + stage_bytes / (self.hw.mem_bw * self.trans_mem_eff)
    }

    /// Best OaA time over the autotuner's tile candidates
    /// ([`crate::conv::oaa::tile_candidates`]); infinite when the sweep
    /// is empty (OaA out of its regime — the full-pad engines keep the
    /// problem).
    pub fn oaa_autotuned_time(&self, p: &ConvProblem) -> f64 {
        crate::conv::oaa::tile_candidates(p)
            .into_iter()
            .map(|t| self.oaa_time(p, t))
            .fold(f64::INFINITY, f64::min)
    }

    /// The full three-regime prediction: the fastest of the full-pad
    /// basis sweep and the OaA tile sweep. Charts where the third
    /// regime takes over — large non-pow2 inputs with small kernels,
    /// where full-pad pays the next-power-of-two round-up on every
    /// stage while OaA's tiles stay at a small fixed basis, and long
    /// 1-D signals whose square full-pad basis is out of the question.
    pub fn three_regime_time(&self, p: &ConvProblem) -> f64 {
        self.autotuned_time(p).min(self.oaa_autotuned_time(p))
    }

    /// Best time over the autotuner's smooth basis candidates (§3.4) —
    /// what the paper's cuFFT implementation reports after tuning.
    pub fn autotuned_time(&self, p: &ConvProblem) -> f64 {
        let lo = p.h.max(p.w);
        let hi = lo.next_power_of_two() * 2;
        let mut best = f64::INFINITY;
        for n in lo..=hi {
            let ok = if self.fbfft {
                n.is_power_of_two()
            } else {
                crate::fft::is_smooth(n)
            };
            if ok {
                best = best.min(self.time(p, n));
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table4() -> Vec<(ConvProblem, f64, f64)> {
        // (problem, paper cuDNN fprop ms, paper cuFFT fprop ms)
        vec![
            (ConvProblem::square(128, 3, 96, 128, 11), 125.11, 81.24),
            (ConvProblem::square(128, 64, 64, 64, 9), 354.83, 46.44),
            (ConvProblem::square(128, 128, 128, 32, 9), 130.89, 17.77),
            (ConvProblem::square(128, 128, 128, 16, 7), 15.13, 4.88),
            (ConvProblem::square(128, 384, 384, 13, 3), 39.82, 21.35),
        ]
    }

    #[test]
    fn calibration_cudnn_within_2x_of_table4() {
        let m = CudnnModel::default();
        for (p, paper_ms, _) in table4() {
            let got = m.time(&p) * 1e3;
            let ratio = got / paper_ms;
            assert!((0.5..2.0).contains(&ratio),
                    "{p:?}: model {got:.1} ms vs paper {paper_ms} ms");
        }
    }

    #[test]
    fn calibration_cufft_within_3x_of_table4() {
        let m = CufftConvModel::vendor();
        for (p, _, paper_ms) in table4() {
            let got = m.autotuned_time(&p) * 1e3;
            let ratio = got / paper_ms;
            assert!((0.33..3.0).contains(&ratio),
                    "{p:?}: model {got:.1} ms vs paper {paper_ms} ms");
        }
    }

    #[test]
    fn speedup_ordering_matches_table4() {
        // the *shape*: FFT wins most at L3 (big planes, k=9, small image),
        // least at L1/L5 (tiny plane counts or tiny kernels)
        let dnn = CudnnModel::default();
        let fft = CufftConvModel::vendor();
        let sp: Vec<f64> = table4()
            .iter()
            .map(|(p, _, _)| dnn.time(p) / fft.autotuned_time(p))
            .collect();
        // L2/L3 speedups dominate L1 and L5
        assert!(sp[1] > sp[0] && sp[2] > sp[0], "{sp:?}");
        assert!(sp[1] > sp[4] && sp[2] > sp[4], "{sp:?}");
        // and FFT indeed wins everywhere on Table 4's layers
        for (i, s) in sp.iter().enumerate() {
            assert!(*s > 1.0, "layer {i}: speedup {s}");
        }
    }

    #[test]
    fn small_kernel_small_problem_prefers_cudnn() {
        // Figure 1's upper-left region: 3×3 kernels, tiny problem sizes
        let p = ConvProblem::square(1, 4, 4, 18, 3);
        let dnn = CudnnModel::default();
        let fft = CufftConvModel::vendor();
        assert!(dnn.time(&p) < fft.autotuned_time(&p));
    }

    #[test]
    fn large_kernel_always_prefers_fft() {
        // Figure 6's regime: 13×13 kernels
        let p = ConvProblem::square(64, 96, 96, 32, 13);
        let dnn = CudnnModel::default();
        let fft = CufftConvModel::vendor();
        let sp = dnn.time(&p) / fft.autotuned_time(&p);
        assert!(sp > 4.0, "speedup {sp}");
    }

    #[test]
    fn fbfft_beats_vendor_at_small_sizes() {
        // §5.4: mean 1.51× over the cuFFT implementation at x∈13..64, k=3
        let mut ratios = Vec::new();
        for x in [13usize, 16, 27, 32, 57, 64] {
            for pl in [16usize, 32, 64, 128] {
                let p = ConvProblem::square(pl, pl, pl, x, 3);
                let v = CufftConvModel::vendor().autotuned_time(&p);
                let f = CufftConvModel::fbfft().autotuned_time(&p);
                ratios.push(v / f);
            }
        }
        let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!(mean > 1.2 && mean < 2.2, "mean fbfft speedup {mean}");
        for r in &ratios {
            assert!(*r > 1.0, "fbfft slower somewhere: {r}");
        }
    }

    #[test]
    fn fft_lanes_term_penalizes_scalar_transforms() {
        // the §5 regime the SoA rewrite targets: small basis, plane-heavy
        let p = ConvProblem::square(64, 16, 16, 13, 3);
        let base = CufftConvModel::fbfft();
        let mut scalar = base;
        scalar.fft_lanes = 1.0;
        let mut mid = base;
        mid.fft_lanes = 8.0;
        // scalar-lane transforms go compute-bound → strictly slower
        assert!(scalar.time(&p, 16) > base.time(&p, 16),
                "scalar {} vs lanes=32 {}", scalar.time(&p, 16),
                base.time(&p, 16));
        // and the term is monotone in lane width
        assert!(mid.time(&p, 16) <= scalar.time(&p, 16));
        assert!(base.time(&p, 16) <= mid.time(&p, 16));
    }

    #[test]
    fn host_tier_roofline_is_monotone_in_fma_width() {
        use crate::util::SimdTier;
        // CGEMM-heavy regime: plane counts large enough that the
        // compute term binds, where tier width must show up
        let p = ConvProblem::square(128, 128, 128, 32, 9);
        let t_scalar =
            CufftConvModel::host_tier(SimdTier::Scalar).time(&p, 32);
        let t_avx2 =
            CufftConvModel::host_tier(SimdTier::Avx2).time(&p, 32);
        let t_avx512 =
            CufftConvModel::host_tier(SimdTier::Avx512).time(&p, 32);
        assert!(t_scalar > t_avx2, "scalar {t_scalar} vs avx2 {t_avx2}");
        assert!(t_avx2 >= t_avx512,
                "avx2 {t_avx2} vs avx512 {t_avx512}");
        // the narrow tier is compute-bound: within the 8× lane ratio
        // but meaningfully above the wide tier, not bandwidth-flat
        assert!(t_scalar / t_avx2 > 2.0,
                "scalar/avx2 ratio {}", t_scalar / t_avx2);
        assert!(t_scalar / t_avx2 <= 8.0 + 1e-9);
    }

    #[test]
    fn host_model_resolves_without_panicking() {
        // host() snapshots the live dispatch tier — just exercise it
        let p = ConvProblem::square(16, 16, 16, 32, 5);
        let t = CufftConvModel::host().autotuned_time(&p);
        assert!(t.is_finite() && t > 0.0);
    }

    #[test]
    fn oaa_term_wins_beyond_the_round_up_and_sits_out_inside_it() {
        let m = CufftConvModel::fbfft();
        // large non-pow2 input, small kernel: full-pad pays the 512
        // round-up on every stage, OaA runs 64-basis tiles
        let big = ConvProblem::square(8, 16, 16, 260, 3);
        let oaa = m.oaa_autotuned_time(&big);
        let full = m.autotuned_time(&big);
        assert!(oaa < full, "oaa {oaa} vs full-pad {full}");
        assert_eq!(m.three_regime_time(&big), oaa);
        // near-extent kernels empty the sweep: the full-pad prediction
        // stands untouched
        let small = ConvProblem::square(8, 16, 16, 16, 5);
        assert!(m.oaa_autotuned_time(&small).is_infinite());
        assert_eq!(m.three_regime_time(&small),
                   m.autotuned_time(&small));
        // and every candidate tile yields a finite, positive term
        for t in crate::conv::oaa::tile_candidates(&big) {
            let s = m.oaa_time(&big, t);
            assert!(s.is_finite() && s > 0.0, "tile {t}: {s}");
        }
    }

    #[test]
    fn autotuner_prefers_smooth_over_pow2_sometimes() {
        // L5's padded size 14 = 2·7 beat 16 in the paper (Table 4 note)
        let p = ConvProblem::square(128, 384, 384, 13, 3);
        let m = CufftConvModel::vendor();
        let t14 = m.time(&p, 14);
        let t16 = m.time(&p, 16);
        assert!(t14 < t16, "14: {t14}, 16: {t16}");
    }
}
