//! Analytical performance model — the substitution for the paper's K40m
//! testbed (DESIGN.md §3).
//!
//! Two roles:
//!
//! 1. **Metric definitions** the benches share: FLOP counts for the
//!    direct and Table-1 frequency pipelines, and the paper's TRED/s
//!    ('trillion equivalent time-domain reductions per second', Table 4
//!    col. 7) which compares efficiency across problem and padding sizes.
//! 2. **The K40m model** that fills the full 8,232-configuration plane of
//!    Figures 1–6: a roofline-plus-overhead model of the cuDNN unrolled
//!    GEMM and the cuFFT convolution pipeline, anchored on the paper's
//!    published hardware constants and calibrated against its Table-4
//!    rows. The measured PJRT subset anchors the *shape*; the model
//!    extrapolates where running 8k XLA compiles is infeasible.

use crate::conv::ConvProblem;

pub mod memory;
pub mod model;

pub use model::{CudnnModel, CufftConvModel, K40m};

/// Multiply-add count of a direct (time-domain) fprop — one reduction is
/// one fused multiply-add, so FLOPs = 2·reductions.
pub fn direct_flops(p: &ConvProblem) -> f64 {
    2.0 * p.reductions() as f64
}

/// FLOPs of one complex 1-D FFT of size n (the standard 5·n·log2 n).
pub fn cfft_flops(n: usize) -> f64 {
    5.0 * n as f64 * (n as f64).log2().max(1.0)
}

/// FLOPs of one 2-D R2C/C2R FFT on an n×n basis: n real rows at half the
/// complex cost plus n/2+1 complex columns.
pub fn rfft2_flops(n: usize) -> f64 {
    let rows = n as f64 * 0.5 * cfft_flops(n);
    let cols = (n as f64 / 2.0 + 1.0) * cfft_flops(n);
    rows + cols
}

/// Per-stage FLOP/byte counts of the Table-1 pipeline for one pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelineCost {
    pub fft_a: f64,
    pub fft_b: f64,
    pub cgemm: f64,
    pub ifft_c: f64,
    /// bytes moved by the explicit transposes (vendor mode only)
    pub trans_bytes: f64,
    /// number of distinct kernel launches (latency term)
    pub launches: f64,
}

impl PipelineCost {
    pub fn flops(&self) -> f64 {
        self.fft_a + self.fft_b + self.cgemm + self.ifft_c
    }
}

/// Cost of the frequency pipeline for fprop on basis `n` (bprop/accGrad
/// are symmetric up to which operand pair is transformed — the property
/// behind the paper's 'all three passes roughly equal', §4.1).
pub fn pipeline_cost(p: &ConvProblem, n: usize, vendor: bool) -> PipelineCost {
    let nf = (n / 2 + 1) as f64;
    let bins = nf * n as f64;
    let t_in = (p.s * p.f) as f64;
    let t_wei = (p.fo * p.f) as f64;
    let t_out = (p.s * p.fo) as f64;
    PipelineCost {
        fft_a: t_in * rfft2_flops(n),
        fft_b: t_wei * rfft2_flops(n),
        // complex MAC = 8 real flops, reduction over f per bin
        cgemm: 8.0 * bins * (p.s * p.f * p.fo) as f64,
        ifft_c: t_out * rfft2_flops(n),
        trans_bytes: if vendor {
            // each of the three tensors transposed once, 8 B/complex, r+w
            16.0 * bins * (t_in + t_wei + t_out)
        } else {
            0.0
        },
        launches: if vendor { 7.0 } else { 3.0 },
    }
}

/// Bytes the bin-major CGEMM stage moves under the `conv::cgemm`
/// blocking (fprop shape `m=S, k=f, n=f'`; the passes are symmetric up
/// to operand roles): per bin, the A panels are re-read once per NC
/// column block, B is packed once, and C is written once per KC depth
/// block (read+write beyond the first), at 8 B per `C32`.
pub fn cgemm_bytes(p: &ConvProblem, n: usize) -> f64 {
    use crate::conv::cgemm::{KC, NC};
    let nf = (n / 2 + 1) as f64;
    let bins = nf * n as f64;
    let (m, k, cols) = (p.s as f64, p.f as f64, p.fo as f64);
    let n_blocks = (cols / NC as f64).ceil().max(1.0);
    let k_blocks = (k / KC as f64).ceil().max(1.0);
    bins * 8.0 * (m * k * n_blocks + k * cols + 2.0 * m * cols * k_blocks)
}

/// Arithmetic intensity (FLOP/byte) of the blocked CGEMM stage — the
/// quantity the roofline term in `model::CufftConvModel` turns into a
/// compute- vs bandwidth-bound verdict. Grows with the reduction depth
/// `f` (deeper reductions amortize the panel traffic), which is exactly
/// why Table 5's CGEMM efficiency climbs with plane count.
pub fn cgemm_intensity(p: &ConvProblem, n: usize) -> f64 {
    pipeline_cost(p, n, false).cgemm / cgemm_bytes(p, n)
}

/// The paper's TRED/s metric in units of 10¹² reductions per second.
pub fn tred_per_sec(p: &ConvProblem, seconds: f64) -> f64 {
    p.reductions() as f64 / seconds / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn direct_flops_match_paper_formula() {
        // L2 of Table 4: S=128, f=f'=64, h=w=64, k=9 → y=56
        let p = ConvProblem::square(128, 64, 64, 64, 9);
        let red = 128f64 * 64.0 * 64.0 * 81.0 * 56.0 * 56.0;
        assert_eq!(direct_flops(&p), 2.0 * red);
    }

    #[test]
    fn tred_reproduces_table4_order_of_magnitude() {
        // paper: L2 fprop 46.44 ms → reported 7.49 TRED/s. The printed
        // formula (S·f·f'·k²·y², §4.2) at the printed time gives 2.87 —
        // the paper's own rows are internally inconsistent by ~2×, so we
        // pin our implementation to the *formula* and assert the order of
        // magnitude of the reported value.
        let p = ConvProblem::square(128, 64, 64, 64, 9);
        let tred = tred_per_sec(&p, 46.44e-3);
        assert!((2.86..2.88).contains(&tred), "tred={tred}");
        assert!(tred > 1.0 && tred < 15.0);
    }

    #[test]
    fn fft_cost_grows_nlogn() {
        let r = rfft2_flops(64) / rfft2_flops(32);
        // n² log n scaling: 4·(6/5) = 4.8
        assert!((r - 4.8).abs() < 0.1, "ratio={r}");
    }

    #[test]
    fn pipeline_kernel_size_independence() {
        // the frequency pipeline's cost must NOT depend on k (the paper's
        // central asymmetry: big kernels are free in Fourier space)
        let a = pipeline_cost(&ConvProblem::square(16, 16, 16, 32, 3), 32,
                              false);
        let b = pipeline_cost(&ConvProblem::square(16, 16, 16, 32, 13), 32,
                              false);
        assert_eq!(a.flops(), b.flops());
    }

    #[test]
    fn cgemm_intensity_grows_with_reduction_depth() {
        // deeper reductions amortize panel traffic (§4's efficiency
        // climb with plane count)
        let a = cgemm_intensity(&ConvProblem::square(16, 4, 16, 32, 5), 32);
        let b = cgemm_intensity(&ConvProblem::square(16, 64, 16, 32, 5), 32);
        assert!(b > a, "I(f=64)={b} should beat I(f=4)={a}");
        // and both are a handful of FLOP/byte — the stage sits near the
        // roofline ridge, which is why blocking matters at all
        assert!(a > 0.1 && b < 1e3);
    }

    #[test]
    fn vendor_pays_transposes_and_launches() {
        let p = ConvProblem::square(16, 16, 16, 32, 5);
        let v = pipeline_cost(&p, 32, true);
        let f = pipeline_cost(&p, 32, false);
        assert!(v.trans_bytes > 0.0 && f.trans_bytes == 0.0);
        assert!(v.launches > f.launches);
        assert_eq!(v.flops(), f.flops());
    }
}
