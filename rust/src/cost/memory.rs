//! Temporary-memory model — the paper's §6 accounting of frequency-domain
//! convolution's buffer overhead:
//!
//! * per tensor role (input/output/weight): one frequency buffer and one
//!   complex-transposed buffer (until the in-place transposed CGEMM
//!   removes the latter — the paper mentions having built it; we model
//!   both states);
//! * the weight-tensor buffer dominates and is minibatch-independent;
//! * cuFFT additionally needs the **explicitly padded duplicates** of all
//!   three tensors plus plan workspace; fbfft needs none of that below
//!   size 64 ('with fbfft padding is implicit and no temporary memory
//!   buffer is needed until we reach size 64');
//! * tiling shrinks scratch further by limiting concurrent tiles.

use crate::conv::ConvProblem;

/// Bytes of temporary memory for one frequency-domain conv layer.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MemoryFootprint {
    /// frequency-domain buffers (re+im or complex), all three roles
    pub freq_buffers: usize,
    /// transposed duplicates for the CGEMM (0 with in-place transpose)
    pub transpose_buffers: usize,
    /// explicit zero-padded input/weight/output duplicates (vendor only)
    pub padded_copies: usize,
    /// FFT plan workspace (vendor only; Bluestein-style scratch)
    pub plan_workspace: usize,
}

impl MemoryFootprint {
    pub fn total(&self) -> usize {
        self.freq_buffers + self.transpose_buffers + self.padded_copies
            + self.plan_workspace
    }
}

const C64: usize = 8; // bytes per complex f32 bin
const F32: usize = 4;

fn freq_elems(p: &ConvProblem, n: usize) -> (usize, usize, usize) {
    let bins = (n / 2 + 1) * n;
    (p.s * p.f * bins, p.fo * p.f * bins, p.s * p.fo * bins)
}

/// Vendor (cuFFT-style) footprint on basis `n`.
pub fn vendor_footprint(p: &ConvProblem, n: usize,
                        in_place_cgemm: bool) -> MemoryFootprint {
    let (fi, fw, fo) = freq_elems(p, n);
    MemoryFootprint {
        freq_buffers: (fi + fw + fo) * C64,
        transpose_buffers: if in_place_cgemm {
            0
        } else {
            (fi + fw + fo) * C64
        },
        // padded duplicates of the real tensors, each on the n×n basis
        padded_copies: ((p.s * p.f + p.fo * p.f + p.s * p.fo) * n * n) * F32,
        // cufftPlan workspace ≈ one extra transform-sized buffer per
        // batched call (three calls live at once in the pipeline)
        plan_workspace: 3 * n * n * C64,
    }
}

/// fbfft footprint on basis `n`: implicit padding (no duplicates), fused
/// transposes (no transpose buffers); above size 64 the paper's
/// implementation starts needing per-call scratch, modeled as one
/// transform panel.
pub fn fbfft_footprint(p: &ConvProblem, n: usize) -> MemoryFootprint {
    let (fi, fw, fo) = freq_elems(p, n);
    MemoryFootprint {
        freq_buffers: (fi + fw + fo) * C64,
        transpose_buffers: 0,
        padded_copies: 0,
        plan_workspace: if n >= 64 { n * n * C64 } else { 0 },
    }
}

/// Tiled-fbfft footprint with output tile `d` and `parallel_tiles` tiles
/// resident at once ('just the tiles which do run in parallel need their
/// scratch space', §6).
pub fn tiled_footprint(p: &ConvProblem, d: usize,
                       parallel_tiles: usize) -> MemoryFootprint {
    let n_t = (d + p.kh.max(p.kw) - 1).next_power_of_two();
    let mut tile_p = *p;
    tile_p.h = d + p.kh - 1;
    tile_p.w = d + p.kw - 1;
    let one = fbfft_footprint(&tile_p, n_t);
    MemoryFootprint {
        freq_buffers: one.freq_buffers * parallel_tiles,
        transpose_buffers: 0,
        padded_copies: 0,
        plan_workspace: one.plan_workspace * parallel_tiles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l5() -> ConvProblem {
        ConvProblem::square(128, 384, 384, 13, 3)
    }

    #[test]
    fn weight_buffer_dominates_and_is_batch_independent() {
        // paper §6: 'generally limited by the weight tensor which is
        // independent of the mini-batch size'
        let p = l5();
        let n = 16;
        let (fi, fw, fo) = freq_elems(&p, n);
        assert!(fw > fi && fw > fo); // 384·384 > 128·384
        let mut small_batch = p;
        small_batch.s = 1;
        let (_, fw2, _) = freq_elems(&small_batch, n);
        assert_eq!(fw, fw2);
    }

    #[test]
    fn fbfft_needs_no_padding_or_transpose_memory() {
        let p = l5();
        let v = vendor_footprint(&p, 16, false);
        let f = fbfft_footprint(&p, 16);
        assert_eq!(f.padded_copies, 0);
        assert_eq!(f.transpose_buffers, 0);
        assert!(v.padded_copies > 0 && v.transpose_buffers > 0);
        assert!(f.total() < v.total());
        // below 64: zero scratch beyond the frequency buffers themselves
        assert_eq!(f.plan_workspace, 0);
        assert!(fbfft_footprint(&p, 64).plan_workspace > 0);
    }

    #[test]
    fn in_place_cgemm_removes_the_transpose_buffers() {
        // the paper's 'in-place transposed batched CGEMM' improvement
        let p = l5();
        let with = vendor_footprint(&p, 16, false);
        let without = vendor_footprint(&p, 16, true);
        assert_eq!(with.total() - without.total(), with.transpose_buffers);
    }

    #[test]
    fn tiling_bounds_scratch_by_parallelism() {
        // big image, small kernel: tiles of d=8 with 4 resident tiles use
        // far less scratch than the untiled 64-basis pipeline
        let p = ConvProblem::square(32, 64, 64, 57, 3);
        let untiled = fbfft_footprint(&p, 64);
        let tiled = tiled_footprint(&p, 8, 4);
        assert!(tiled.total() < untiled.total(),
                "{} vs {}", tiled.total(), untiled.total());
        // and it scales linearly in resident tiles
        assert_eq!(tiled_footprint(&p, 8, 8).freq_buffers,
                   2 * tiled.freq_buffers);
    }

    #[test]
    fn footprints_are_megabyte_scale_at_paper_sizes() {
        // sanity: L2 of Table 4 on a 64-basis needs hundreds of MB in
        // vendor mode — consistent with the paper's 'memory pressure'
        // failures (black areas of Figures 1-6)
        let p = ConvProblem::square(128, 64, 64, 64, 9);
        let v = vendor_footprint(&p, 64, false);
        assert!(v.total() > 500 << 20, "{}", v.total());
    }
}
