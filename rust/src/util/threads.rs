//! Unified host thread-count configuration (one knob for every engine).
//!
//! All multithreaded stages — the direct engine, the SGEMM substrate, the
//! frequency-domain CGEMM and the parallel FFT/transpose loops — size
//! their `std::thread::scope` fan-out from this single helper, so one
//! `FBFFT_THREADS` environment override steers the whole pipeline (the
//! benches want stable, reproducible numbers more than max throughput).

use std::sync::OnceLock;

/// Worker count: `FBFFT_THREADS` if set to a positive integer (clamped to
/// 64), else `available_parallelism` clamped to 16. Resolved once per
/// process — the engines call this on every pass, so it must stay cheap.
pub fn threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("FBFFT_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    return n.min(64);
                }
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16)
    })
}

/// Split `n` items into at most `parts` contiguous `(start, len)` ranges,
/// allocation-free (the per-pass hot paths must not touch the heap).
pub fn chunk_ranges(n: usize, parts: usize)
                    -> impl Iterator<Item = (usize, usize)> {
    let parts = parts.min(n.max(1)).max(1);
    let base = n / parts;
    let extra = n % parts;
    (0..parts).map(move |i| {
        let len = base + usize::from(i < extra);
        let start = i * base + i.min(extra);
        (start, len)
    })
}

/// [`chunk_ranges`] with every boundary (except the final end) aligned to
/// a multiple of `group`: the SoA frequency pipeline fans the inverse
/// transform out over *batch groups* so each worker's lane count stays a
/// multiple of the SIMD width ([`crate::fft::soa::LANES`]) — only the
/// very last chunk carries the scalar tail. Degenerates to one chunk when
/// `n < parts·group` would leave empty workers.
pub fn chunk_ranges_grouped(n: usize, parts: usize, group: usize)
                            -> impl Iterator<Item = (usize, usize)> {
    let group = group.max(1);
    let groups = n.div_ceil(group);
    let parts = parts.min(groups.max(1)).max(1);
    let base = groups / parts;
    let extra = groups % parts;
    (0..parts).map(move |i| {
        let g_len = base + usize::from(i < extra);
        let g_start = i * base + i.min(extra);
        let start = (g_start * group).min(n);
        let end = ((g_start + g_len) * group).min(n);
        (start, end - start)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_count_is_positive_and_bounded() {
        let n = threads();
        assert!(n >= 1 && n <= 64);
        // cached: a second call must agree
        assert_eq!(threads(), n);
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        for (n, parts) in [(10, 3), (3, 10), (16, 4), (1, 1), (7, 7),
                           (0, 4), (100, 16)] {
            let ranges: Vec<(usize, usize)> =
                chunk_ranges(n, parts).collect();
            let mut next = 0usize;
            for (start, len) in &ranges {
                assert_eq!(*start, next, "n={n} parts={parts}");
                next += len;
            }
            assert_eq!(next, n, "n={n} parts={parts}");
            assert!(ranges.len() <= parts.max(1));
        }
    }

    #[test]
    fn chunk_ranges_balanced() {
        let lens: Vec<usize> =
            chunk_ranges(10, 3).map(|(_, l)| l).collect();
        assert_eq!(lens, vec![4, 3, 3]);
    }

    #[test]
    fn grouped_ranges_cover_exactly_and_align() {
        for (n, parts, group) in [(35usize, 3usize, 8usize), (8, 4, 8),
                                  (16, 2, 8), (7, 3, 8), (100, 16, 8),
                                  (0, 4, 8), (9, 2, 1), (24, 5, 8)] {
            let ranges: Vec<(usize, usize)> =
                chunk_ranges_grouped(n, parts, group).collect();
            let mut next = 0usize;
            for (i, (start, len)) in ranges.iter().enumerate() {
                assert_eq!(*start, next, "n={n} parts={parts}");
                assert_eq!(start % group, 0,
                           "n={n}: chunk {i} start unaligned");
                if i + 1 < ranges.len() {
                    assert_eq!((start + len) % group, 0,
                               "n={n}: interior boundary unaligned");
                }
                next += len;
            }
            assert_eq!(next, n, "n={n} parts={parts} group={group}");
            assert!(ranges.len() <= parts.max(1));
        }
    }

    #[test]
    fn grouped_ranges_only_tail_is_ragged() {
        let ranges: Vec<(usize, usize)> =
            chunk_ranges_grouped(35, 3, 8).collect();
        // 5 groups of 8 → split 2/2/1 groups → 16/16/3 lanes
        assert_eq!(ranges, vec![(0, 16), (16, 16), (32, 3)]);
    }
}
