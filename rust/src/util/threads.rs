//! Unified host thread-count configuration (one knob for every engine).
//!
//! All multithreaded stages — the direct engine, the SGEMM substrate, the
//! frequency-domain CGEMM and the parallel FFT/transpose loops — size
//! their `std::thread::scope` fan-out from this single helper, so one
//! `FBFFT_THREADS` environment override steers the whole pipeline (the
//! benches want stable, reproducible numbers more than max throughput).

use std::sync::OnceLock;

/// Worker count: `FBFFT_THREADS` if set to a positive integer (clamped to
/// 64), else `available_parallelism` clamped to 16. Resolved once per
/// process — the engines call this on every pass, so it must stay cheap.
pub fn threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("FBFFT_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    return n.min(64);
                }
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16)
    })
}

/// Split `n` items into at most `parts` contiguous `(start, len)` ranges,
/// allocation-free (the per-pass hot paths must not touch the heap).
pub fn chunk_ranges(n: usize, parts: usize)
                    -> impl Iterator<Item = (usize, usize)> {
    let parts = parts.min(n.max(1)).max(1);
    let base = n / parts;
    let extra = n % parts;
    (0..parts).map(move |i| {
        let len = base + usize::from(i < extra);
        let start = i * base + i.min(extra);
        (start, len)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_count_is_positive_and_bounded() {
        let n = threads();
        assert!(n >= 1 && n <= 64);
        // cached: a second call must agree
        assert_eq!(threads(), n);
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        for (n, parts) in [(10, 3), (3, 10), (16, 4), (1, 1), (7, 7),
                           (0, 4), (100, 16)] {
            let ranges: Vec<(usize, usize)> =
                chunk_ranges(n, parts).collect();
            let mut next = 0usize;
            for (start, len) in &ranges {
                assert_eq!(*start, next, "n={n} parts={parts}");
                next += len;
            }
            assert_eq!(next, n, "n={n} parts={parts}");
            assert!(ranges.len() <= parts.max(1));
        }
    }

    #[test]
    fn chunk_ranges_balanced() {
        let lens: Vec<usize> =
            chunk_ranges(10, 3).map(|(_, l)| l).collect();
        assert_eq!(lens, vec![4, 3, 3]);
    }
}
