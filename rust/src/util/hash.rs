//! FNV-1a 64-bit hash — stable across runs and platforms (unlike
//! `std::hash`'s randomized `DefaultHasher`), so seeds derived from
//! names (testkit case seeds, artifact shard keys) are reproducible.

/// FNV-1a over a byte string.
pub fn hash64(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xCBF29CE484222325;
    const PRIME: u64 = 0x100000001B3;
    let mut h = OFFSET;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // canonical FNV-1a test vectors
        assert_eq!(hash64(b""), 0xCBF29CE484222325);
        assert_eq!(hash64(b"a"), 0xAF63DC4C8601EC8C);
        assert_eq!(hash64(b"foobar"), 0x85944171F73967E8);
    }

    #[test]
    fn distinct_names_distinct_seeds() {
        assert_ne!(hash64(b"adv-prime-11"), hash64(b"adv-prime-13"));
        assert_ne!(hash64(b"x"), hash64(b"y"));
    }
}
