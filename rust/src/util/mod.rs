//! In-tree utility substrates (no registry access in this image, so the
//! usual crates — serde_json, rand, rayon, criterion, proptest — are
//! replaced by small, tested, purpose-built implementations).

pub mod f16;
pub mod hash;
pub mod json;
pub mod rng;
pub mod simd;
pub mod threads;

pub use hash::hash64;
pub use json::Json;
pub use rng::Rng;
pub use simd::SimdTier;
pub use threads::{chunk_ranges, chunk_ranges_grouped, threads};
