//! Software IEEE 754 binary16 ("half") conversion — no `half` crate in
//! this image, and the spectrum cache only needs storage conversion, not
//! arithmetic: slabs are encoded once per weight version and decoded
//! lane-wise inside the CGEMM packing path.
//!
//! Encoding is round-to-nearest-even (the hardware default), with
//! correct subnormal, infinity and NaN handling; decoding uses the
//! shift-and-rescale trick (one multiply renormalizes subnormals), so
//! the hot path is branch-free except for the inf/NaN fixup.

/// Relative precision of a binary16 normal: one half-ULP, `2^-11`.
pub const EPS16: f32 = 4.8828125e-4;

/// Convert one f32 to IEEE binary16 bits with round-to-nearest-even.
/// Overflow saturates to ±inf; NaN payloads keep their top mantissa
/// bits (quieted so the result is never mistaken for inf).
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // inf stays inf; NaN keeps payload with the quiet bit forced
        return if man == 0 {
            sign | 0x7C00
        } else {
            sign | 0x7C00 | 0x0200 | ((man >> 13) as u16 & 0x03FF)
        };
    }
    let e = exp - 127 + 15; // rebias toward the 5-bit exponent
    if e >= 0x1F {
        return sign | 0x7C00; // overflow → ±inf
    }
    if e <= 0 {
        // subnormal target: value = m16 · 2^-24 with m16 = RNE(m24 >> s)
        if e < -10 {
            return sign; // below half the smallest subnormal → ±0
        }
        let m24 = man | 0x0080_0000; // restore the implicit bit
        let s = (14 - e) as u32; // s ∈ [14, 24]
        let kept = m24 >> s;
        let rem = m24 & ((1u32 << s) - 1);
        let half = 1u32 << (s - 1);
        let round_up = rem > half || (rem == half && (kept & 1) == 1);
        // a carry out of the 10-bit mantissa lands on exponent 1 — the
        // adjacent normal — which is exactly the right answer
        return sign | (kept + round_up as u32) as u16;
    }
    // normal target: 13 mantissa bits shift out
    let kept = man >> 13;
    let rem = man & 0x1FFF;
    let round_up = rem > 0x1000 || (rem == 0x1000 && (kept & 1) == 1);
    // mantissa carry ripples into the exponent (and to inf on overflow)
    sign | (((e as u32) << 10 | kept) + round_up as u32) as u16
}

/// Convert IEEE binary16 bits back to f32 (exact — every half value is
/// representable in f32).
#[inline(always)]
pub fn f16_to_f32(h: u16) -> f32 {
    const SHIFTED_EXP: u32 = 0x7C00 << 13;
    // the f16 subnormal scale as an f32: 2^-14 with a zero mantissa
    const MAGIC: u32 = 113 << 23;
    let sign = ((h & 0x8000) as u32) << 16;
    let mut bits = ((h & 0x7FFF) as u32) << 13; // exp+man in f32 position
    let exp = bits & SHIFTED_EXP;
    bits += (127 - 15) << 23; // rebias
    if exp == SHIFTED_EXP {
        bits += (128 - 16) << 23; // inf/NaN: push exponent to 0xFF
    } else if exp == 0 {
        // zero/subnormal: renormalize through one f32 subtract
        bits += 1 << 23;
        bits = (f32::from_bits(bits) - f32::from_bits(MAGIC)).to_bits();
    }
    f32::from_bits(bits | sign)
}

/// Encode a slab of f32 lanes into f16 bits (spectrum-cache storage).
pub fn encode_slab(src: &[f32]) -> Vec<u16> {
    src.iter().map(|&x| f32_to_f16(x)).collect()
}

/// Decode a slab of f16 bits back into f32 lanes (test/debug path — the
/// CGEMM packers decode lane-wise without materializing this).
pub fn decode_slab(src: &[u16]) -> Vec<f32> {
    src.iter().map(|&h| f16_to_f32(h)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_cases_round_trip() {
        for &(x, h) in &[
            (0.0f32, 0x0000u16),
            (-0.0, 0x8000),
            (1.0, 0x3C00),
            (-2.0, 0xC000),
            (0.5, 0x3800),
            (65504.0, 0x7BFF),             // largest finite half
            (6.103_515_6e-5, 0x0400),      // smallest normal half
            (5.960_464_5e-8, 0x0001),      // smallest subnormal half
            (f32::INFINITY, 0x7C00),
            (f32::NEG_INFINITY, 0xFC00),
        ] {
            assert_eq!(f32_to_f16(x), h, "encode {x}");
            assert_eq!(f16_to_f32(h).to_bits(), x.to_bits(), "decode {h:#06x}");
        }
    }

    #[test]
    fn round_to_nearest_even_at_ties() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next half;
        // RNE keeps the even mantissa (1.0). One ULP above rounds up.
        let half_ulp = f32::from_bits(0x3F80_1000);
        assert_eq!(f32_to_f16(half_ulp), 0x3C00);
        let above = f32::from_bits(0x3F80_1001);
        assert_eq!(f32_to_f16(above), 0x3C01);
        // 1 + 3·2^-11 is halfway between mantissas 1 and 2 → even (2)
        let tie_up = f32::from_bits(0x3F80_3000);
        assert_eq!(f32_to_f16(tie_up), 0x3C02);
    }

    #[test]
    fn saturation_and_underflow() {
        assert_eq!(f32_to_f16(65520.0), 0x7C00, "overflow → inf");
        assert_eq!(f32_to_f16(-1e9), 0xFC00);
        assert_eq!(f32_to_f16(1e-9), 0x0000, "deep underflow → 0");
        // exactly half the smallest subnormal ties to even zero
        assert_eq!(f32_to_f16(2.980_232_2e-8), 0x0000);
        // just above it rounds to the smallest subnormal
        assert_eq!(f32_to_f16(3.0e-8), 0x0001);
    }

    #[test]
    fn nan_stays_nan() {
        let h = f32_to_f16(f32::NAN);
        assert_eq!(h & 0x7C00, 0x7C00);
        assert_ne!(h & 0x03FF, 0, "NaN mantissa must stay nonzero");
        assert!(f16_to_f32(h).is_nan());
    }

    #[test]
    fn all_half_values_round_trip_bitwise() {
        // decode→encode is the identity for every non-NaN half pattern —
        // the strongest statement that both directions are faithful
        for h in 0..=u16::MAX {
            let exp = h & 0x7C00;
            let man = h & 0x03FF;
            if exp == 0x7C00 && man != 0 {
                continue; // NaN payloads are canonicalized, not preserved
            }
            let x = f16_to_f32(h);
            assert_eq!(f32_to_f16(x), h, "h={h:#06x} x={x}");
        }
    }

    #[test]
    fn relative_error_stays_inside_eps16() {
        let mut rng = crate::util::Rng::new(0xF16);
        for _ in 0..10_000 {
            let x = rng.normal() * 8.0;
            let y = f16_to_f32(f32_to_f16(x));
            let err = (y - x).abs();
            let bound = EPS16 * x.abs().max(6.2e-5);
            assert!(err <= bound, "x={x} y={y} err={err} bound={bound}");
        }
    }

    #[test]
    fn slab_helpers_match_scalar_path() {
        let src = [0.0f32, 1.5, -3.25, 1e-6, 7.0e4, -0.125];
        let enc = encode_slab(&src);
        let dec = decode_slab(&enc);
        for (i, &x) in src.iter().enumerate() {
            assert_eq!(enc[i], f32_to_f16(x));
            assert_eq!(dec[i].to_bits(), f16_to_f32(enc[i]).to_bits());
        }
    }
}
