//! Runtime SIMD dispatch — the single knob selecting which microkernel
//! tier the hot paths run (paper §5's thesis, transplanted: fbfft's edge
//! over the vendor path comes from hand-shaped kernels, so the CPU
//! reproduction needs explicit FMA-width kernels, not autovectorization
//! hope).
//!
//! Three tiers:
//!
//! * [`SimdTier::Scalar`] — the reference implementations, bit-identical
//!   to the pre-dispatch tree. Always available; the conformance anchor.
//! * [`SimdTier::Avx2`] — hand-written AVX2+FMA kernels (256-bit, 8×f32
//!   FMA lanes), plus F16C hardware dequant for the f16 spectrum slabs.
//! * [`SimdTier::Avx512`] — 512-bit kernels (16×f32 FMA lanes). Runtime
//!   detection *and* a toolchain gate (`fbfft_avx512`, see `build.rs`):
//!   on toolchains older than 1.89 the tier caps at `avx2`.
//!
//! Resolution order: the process-wide test override (integration tests
//! forcing a tier) → the `FBFFT_SIMD=scalar|avx2|avx512` environment
//! override (requests above the detected capability downgrade with a
//! warning, never crash) → the best detected tier. The selected tier is
//! resolved once and then surfaced everywhere perf is recorded:
//! `StageTimings`, the `BENCH_*.json` host block, the autotuner's
//! persisted cache header, and the cost model's roofline compute term.
//!
//! Exactness contract: packing-style helpers here ([`f16_dequant`],
//! [`copy_signed`]) are **bitwise identical** across tiers (copies, sign
//! flips and IEEE-exact f16→f32 conversion). The FMA kernels in
//! `conv::cgemm` / `fft::soa` are not — fused contraction changes
//! rounding — and are tolerance-gated against the scalar tier instead.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Dispatch tier, ordered by capability (so `min`/`max` cap requests).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord,
         Hash)]
pub enum SimdTier {
    /// Reference tier: no `std::arch` intrinsics, bitwise-stable.
    #[default]
    Scalar,
    /// AVX2 + FMA (+ F16C dequant): 8 f32 lanes per FMA.
    Avx2,
    /// AVX-512F: 16 f32 lanes per FMA (needs rustc ≥ 1.89 to compile).
    Avx512,
}

impl SimdTier {
    /// Stable lowercase tag — the `FBFFT_SIMD` vocabulary, the BENCH
    /// host-metadata value and the autotuner cache header field.
    pub fn tag(self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Avx2 => "avx2",
            SimdTier::Avx512 => "avx512",
        }
    }

    /// Parse a [`SimdTier::tag`] string (the `FBFFT_SIMD` values).
    pub fn from_tag(s: &str) -> Option<SimdTier> {
        match s {
            "scalar" => Some(SimdTier::Scalar),
            "avx2" => Some(SimdTier::Avx2),
            "avx512" => Some(SimdTier::Avx512),
            _ => None,
        }
    }

    /// f32 lanes per fused multiply-add at this tier — the cost model's
    /// compute-width term. The scalar tier reports 1: it makes no width
    /// promise (whatever autovectorization happens is a bonus).
    pub fn fma_lanes(self) -> usize {
        match self {
            SimdTier::Scalar => 1,
            SimdTier::Avx2 => 8,
            SimdTier::Avx512 => 16,
        }
    }
}

impl std::fmt::Display for SimdTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.tag())
    }
}

/// What the host CPU (and toolchain) actually offer.
struct Caps {
    /// Best runnable tier: detection capped by the `fbfft_avx512` gate.
    best: SimdTier,
    /// F16C available (hardware f16→f32 dequant for the spectrum slabs).
    f16c: bool,
    /// Detected feature tags, for BENCH host provenance.
    features: Vec<&'static str>,
}

fn caps() -> &'static Caps {
    static CAPS: OnceLock<Caps> = OnceLock::new();
    CAPS.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            let avx2 = is_x86_feature_detected!("avx2");
            let fma = is_x86_feature_detected!("fma");
            let f16c = is_x86_feature_detected!("f16c");
            let avx512f = is_x86_feature_detected!("avx512f");
            let mut features = Vec::new();
            for (on, tag) in [(avx2, "avx2"), (fma, "fma"),
                              (f16c, "f16c"), (avx512f, "avx512f")] {
                if on {
                    features.push(tag);
                }
            }
            let best = if avx512f && avx2 && fma && cfg!(fbfft_avx512) {
                SimdTier::Avx512
            } else if avx2 && fma {
                SimdTier::Avx2
            } else {
                SimdTier::Scalar
            };
            // the F16C fast path is only wired into the AVX tiers
            Caps { best, f16c: f16c && best >= SimdTier::Avx2, features }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            Caps { best: SimdTier::Scalar, f16c: false,
                   features: Vec::new() }
        }
    })
}

/// Best tier the host can actually run (detection ∩ toolchain gate),
/// ignoring overrides — the ceiling for every request.
pub fn detected() -> SimdTier {
    caps().best
}

/// Detected CPU feature tags (BENCH host-metadata provenance).
pub fn detected_features() -> &'static [&'static str] {
    &caps().features
}

/// Hardware F16C dequant available at the active capability level.
pub fn has_f16c() -> bool {
    caps().f16c
}

/// The `FBFFT_SIMD` + detection resolution, cached once per process.
fn resolved() -> SimdTier {
    static TIER: OnceLock<SimdTier> = OnceLock::new();
    *TIER.get_or_init(|| {
        let best = caps().best;
        let Ok(v) = std::env::var("FBFFT_SIMD") else {
            return best;
        };
        match SimdTier::from_tag(v.trim()) {
            Some(req) if req <= best => req,
            Some(req) => {
                eprintln!("FBFFT_SIMD={}: tier unavailable on this \
                           host/toolchain, running {}",
                          req.tag(), best.tag());
                best
            }
            None => {
                eprintln!("FBFFT_SIMD={v}: unknown tier (expected \
                           scalar|avx2|avx512), running {}", best.tag());
                best
            }
        }
    })
}

/// Process-wide forced tier for the forced-dispatch test sweeps:
/// 0 = no override, else `tier as u8 + 1`.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Force (or clear) the dispatch tier, capped at [`detected`]. Test-only
/// plumbing for the forced-dispatch conformance sweeps — it is global
/// process state, so tests that use it must serialize themselves (the
/// in-tree users share one mutex per test binary). Production code
/// configures tiers via `FBFFT_SIMD` instead.
#[doc(hidden)]
pub fn set_tier_override(t: Option<SimdTier>) {
    let v = match t {
        None => 0,
        Some(req) => req.min(detected()) as u8 + 1,
    };
    OVERRIDE.store(v, Ordering::SeqCst);
}

/// The active dispatch tier. Cheap (one atomic load + cached caps), so
/// the kernel entry points resolve it per call; worker threads inherit
/// the value from their spawning entry point, not by re-resolving.
pub fn tier() -> SimdTier {
    match OVERRIDE.load(Ordering::Relaxed) {
        0 => resolved(),
        1 => SimdTier::Scalar,
        2 => SimdTier::Avx2,
        _ => SimdTier::Avx512,
    }
}

/// `dst = src` (or `-src`) — the planar pack's conjugation copy. Exact
/// at every tier (sign flip only), so the planar-vs-interleaved bitwise
/// gates hold regardless of dispatch.
#[inline]
pub fn copy_signed(src: &[f32], dst: &mut [f32], negate: bool) {
    if negate {
        for (d, s) in dst.iter_mut().zip(src) {
            *d = -s;
        }
    } else {
        dst.copy_from_slice(src);
    }
}

/// Dequantize f16 bits into f32 (optionally negated — the CGEMM pack's
/// conjugation sign), dispatching to hardware F16C when the active tier
/// allows. Bitwise identical to `util::f16::f16_to_f32` for every
/// non-NaN pattern at every tier: both routes are IEEE-exact.
pub fn f16_dequant(src: &[u16], dst: &mut [f32], negate: bool) {
    assert_eq!(src.len(), dst.len());
    #[cfg(target_arch = "x86_64")]
    if tier() >= SimdTier::Avx2 && has_f16c() {
        // SAFETY: avx + f16c presence established by `caps()` detection.
        unsafe { f16_dequant_f16c(src, dst, negate) };
        return;
    }
    f16_dequant_scalar(src, dst, negate);
}

fn f16_dequant_scalar(src: &[u16], dst: &mut [f32], negate: bool) {
    let sign = if negate { -1.0f32 } else { 1.0 };
    for (d, &h) in dst.iter_mut().zip(src) {
        *d = sign * crate::util::f16::f16_to_f32(h);
    }
}

/// Hardware dequant: `vcvtph2ps` eight halves per step, sign-flip via
/// xor with `-0.0` (bitwise the same as multiplying by ±1.0 for every
/// non-NaN value). Tail elements take the scalar path.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx,f16c")]
unsafe fn f16_dequant_f16c(src: &[u16], dst: &mut [f32], negate: bool) {
    use std::arch::x86_64::*;
    let flip = _mm256_set1_ps(if negate { -0.0 } else { 0.0 });
    let n = src.len();
    let mut i = 0;
    while i + 8 <= n {
        let h = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
        let v = _mm256_xor_ps(_mm256_cvtph_ps(h), flip);
        _mm256_storeu_ps(dst.as_mut_ptr().add(i), v);
        i += 8;
    }
    f16_dequant_scalar(&src[i..], &mut dst[i..], negate);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_round_trip_and_order_is_capability() {
        for t in [SimdTier::Scalar, SimdTier::Avx2, SimdTier::Avx512] {
            assert_eq!(SimdTier::from_tag(t.tag()), Some(t));
            assert_eq!(format!("{t}"), t.tag());
        }
        assert_eq!(SimdTier::from_tag("neon"), None);
        assert!(SimdTier::Scalar < SimdTier::Avx2);
        assert!(SimdTier::Avx2 < SimdTier::Avx512);
        assert!(SimdTier::Scalar.fma_lanes()
                < SimdTier::Avx2.fma_lanes());
        assert!(SimdTier::Avx2.fma_lanes()
                < SimdTier::Avx512.fma_lanes());
        assert_eq!(SimdTier::default(), SimdTier::Scalar);
    }

    #[test]
    fn active_tier_is_within_detected_capability() {
        // no override mutation here (lib tests share the process): just
        // the resolution invariants
        assert!(tier() <= detected());
        assert_eq!(tier(), tier(), "resolution must be stable");
        if detected() >= SimdTier::Avx2 {
            assert!(detected_features().contains(&"avx2"));
            assert!(detected_features().contains(&"fma"));
        }
    }

    #[test]
    fn copy_signed_is_exact_both_signs() {
        let src = [1.5f32, -0.0, 3.25e-7, -9.0, f32::MIN_POSITIVE];
        let mut plus = [0f32; 5];
        let mut minus = [0f32; 5];
        copy_signed(&src, &mut plus, false);
        copy_signed(&src, &mut minus, true);
        for i in 0..src.len() {
            assert_eq!(plus[i].to_bits(), src[i].to_bits());
            assert_eq!(minus[i].to_bits(), (-src[i]).to_bits());
        }
    }

    #[test]
    fn f16_dequant_is_bitwise_the_software_decoder() {
        // every non-NaN half pattern, both signs, ragged length (tail
        // path) — the dispatched route must match the software decoder
        // exactly, whatever tier this host runs
        let src: Vec<u16> = (0..=u16::MAX)
            .filter(|h| {
                let (exp, man) = (h & 0x7C00, h & 0x03FF);
                !(exp == 0x7C00 && man != 0) // hardware quiets sNaNs
            })
            .collect();
        for negate in [false, true] {
            let sign = if negate { -1.0f32 } else { 1.0 };
            let mut dst = vec![0f32; src.len()];
            f16_dequant(&src, &mut dst, negate);
            for (h, d) in src.iter().zip(&dst) {
                let want = sign * crate::util::f16::f16_to_f32(*h);
                assert_eq!(d.to_bits(), want.to_bits(),
                           "h={h:#06x} negate={negate}");
            }
        }
        // odd-length slab: exercises the scalar tail after the 8-wide
        // body on the hardware path
        let ragged = [0x3C00u16, 0x0001, 0xC000];
        let mut out = [0f32; 3];
        f16_dequant(&ragged, &mut out, true);
        assert_eq!(out, [-1.0, -crate::util::f16::f16_to_f32(0x0001),
                         2.0]);
    }
}
