//! Minimal JSON parser/printer for the artifact manifest and the
//! autotuner's persisted cache (serde is unavailable offline; the
//! manifest grammar is plain RFC 8259 without extensions).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ----- construction helpers ------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ----- parsing --------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])
                                .map_err(|e| e.to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|e| e.to_string())?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => {
                            return Err(format!("bad escape \\{}", other as char))
                        }
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    self.i += len;
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    /// Compact canonical printing (object keys already sorted by BTreeMap).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => {
                            write!(f, "\\u{:04x}", c as u32)?
                        }
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(),
                   Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(
            r#"{"entries":[{"name":"x","shape":[1,2,3],"ok":true}],"v":1}"#)
            .unwrap();
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("x"));
        let shape: Vec<usize> = e
            .get("shape").unwrap().as_arr().unwrap()
            .iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![1, 2, 3]);
    }

    #[test]
    fn whitespace_and_unicode() {
        let j = Json::parse(" { \"k\" : \"π≈3\" , \"u\": \"\\u0041\" } ")
            .unwrap();
        assert_eq!(j.get("k").unwrap().as_str(), Some("π≈3"));
        assert_eq!(j.get("u").unwrap().as_str(), Some("A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn round_trip_display_parse() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":false}}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
    }

    #[test]
    fn real_manifest_shape() {
        // exactly the structure aot.py emits
        let src = r#"{"version":1,"entries":[
          {"name":"conv.q.fbfft.fprop","kind":"conv","hlo":"f.hlo.txt",
           "inputs":[{"shape":[2,4,16,16],"dtype":"f32"}],
           "outputs":[{"shape":[2,4,14,14],"dtype":"f32"}],
           "meta":{"n_fft":16,"strategy":"fbfft"}}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("meta").unwrap().get("n_fft").unwrap().as_usize(),
                   Some(16));
    }
}
