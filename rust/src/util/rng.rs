//! Deterministic PRNG (xoshiro256**) for workload generation, property
//! tests and synthetic data. Seeded explicitly everywhere — every bench
//! and test in the repo is bit-reproducible.

/// xoshiro256** (Blackman & Vigna). Passes BigCrush; more than enough for
/// synthetic tensors and property-test case generation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the state vector
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = move || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt()
            * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Vector of standard normals (synthetic tensors).
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Pick one element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Exponentially-distributed value with the given mean (request
    /// inter-arrival times in the serving trace).
    pub fn exponential(&mut self, mean: f32) -> f32 {
        -mean * self.uniform().max(1e-12).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..4).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval_with_plausible_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0f64;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().map(|x| *x as f64).sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (*x as f64 - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn int_in_covers_bounds() {
        let mut r = Rng::new(3);
        let mut seen = [false; 5];
        for _ in 0..200 {
            seen[r.int_in(0, 4)] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 30_000;
        let mean = (0..n).map(|_| r.exponential(2.0) as f64).sum::<f64>()
            / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
    }
}
