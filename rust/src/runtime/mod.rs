//! The PJRT bridge: load AOT-compiled HLO-text artifacts and execute them
//! from the coordinator's hot path. Python never appears here — the
//! artifacts directory is the entire interface between the layers.
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod client;
pub mod manifest;

pub use client::{HostTensor, Runtime, RuntimeStats};
pub use manifest::{Entry, Manifest, TensorSpec};
