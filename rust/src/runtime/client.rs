//! PJRT client wrapper: compile-on-demand executable cache + typed
//! execution helpers. One `Runtime` owns the CPU client, the manifest
//! and every compiled executable (the paper's 'one compiled executable
//! per model variant', kept warm across requests).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{Entry, Manifest};

/// A host-side tensor crossing the PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::F32(data, shape.to_vec())
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::I32(data, shape.to_vec())
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => bail!("tensor is not f32"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32(d, s) => {
                let dims: Vec<i64> = s.iter().map(|d| *d as i64).collect();
                xla::Literal::vec1(d).reshape(&dims)?
            }
            HostTensor::I32(d, s) => {
                let dims: Vec<i64> = s.iter().map(|d| *d as i64).collect();
                xla::Literal::vec1(d).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = match lit.shape()? {
            xla::Shape::Array(a) => {
                a.dims().iter().map(|d| *d as usize).collect::<Vec<_>>()
            }
            other => bail!("unexpected non-array output shape {other:?}"),
        };
        match lit.ty()? {
            xla::ElementType::F32 => {
                Ok(HostTensor::F32(lit.to_vec::<f32>()?, shape))
            }
            xla::ElementType::S32 => {
                Ok(HostTensor::I32(lit.to_vec::<i32>()?, shape))
            }
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

/// Compile statistics (the autotuner reports these).
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_time: Duration,
    pub executions: usize,
    pub execute_time: Duration,
}

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    exes: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<RuntimeStats>,
}

impl Runtime {
    /// Open the artifacts directory (CPU PJRT client).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            exes: Mutex::new(HashMap::new()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RuntimeStats {
        *self.stats.lock().expect("stats lock")
    }

    /// Compile (or fetch cached) the named artifact.
    pub fn executable(&self, name: &str)
                      -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.lock().expect("exe lock").get(name) {
            return Ok(e.clone());
        }
        let entry = self.manifest.require(name)?;
        let path = self.dir.join(&entry.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?)
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = Arc::new(exe);
        {
            let mut st = self.stats.lock().expect("stats lock");
            st.compiles += 1;
            st.compile_time += t0.elapsed();
        }
        self.exes
            .lock()
            .expect("exe lock")
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a family of artifacts (warm start for serving).
    pub fn warm(&self, prefix: &str) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .with_prefix(prefix)
            .filter(|e| e.file.ends_with(".hlo.txt"))
            .map(|e| e.name.clone())
            .collect();
        for n in &names {
            self.executable(n)?;
        }
        Ok(names.len())
    }

    /// Execute an artifact on host tensors; returns the flattened tuple
    /// outputs. Validates shapes against the manifest before launch.
    pub fn execute(&self, name: &str, inputs: &[HostTensor])
                   -> Result<Vec<HostTensor>> {
        let entry = self.manifest.require(name)?;
        self.check_inputs(entry, inputs)?;
        let exe = self.executable(name)?;
        let lits = inputs
            .iter()
            .map(HostTensor::to_literal)
            .collect::<Result<Vec<_>>>()?;
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        {
            let mut st = self.stats.lock().expect("stats lock");
            st.executions += 1;
            st.execute_time += t0.elapsed();
        }
        // aot.py lowers with return_tuple=True: always a tuple literal
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of {name}: {e:?}"))?;
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Execute expecting a single f32 output (the common conv case).
    pub fn execute_1f32(&self, name: &str, inputs: &[HostTensor])
                        -> Result<(Vec<f32>, Vec<usize>)> {
        let mut out = self.execute(name, inputs)?;
        if out.len() != 1 {
            bail!("{name}: expected 1 output, got {}", out.len());
        }
        match out.pop().unwrap() {
            HostTensor::F32(d, s) => Ok((d, s)),
            _ => bail!("{name}: output is not f32"),
        }
    }

    fn check_inputs(&self, entry: &Entry, inputs: &[HostTensor])
                    -> Result<()> {
        if entry.inputs.len() != inputs.len() {
            bail!("{}: expected {} inputs, got {}", entry.name,
                  entry.inputs.len(), inputs.len());
        }
        for (i, (spec, got)) in entry.inputs.iter().zip(inputs).enumerate() {
            if spec.shape != got.shape() {
                bail!("{} input {i}: expected shape {:?}, got {:?}",
                      entry.name, spec.shape, got.shape());
            }
        }
        Ok(())
    }

    /// Load a raw `.bin` tensor artifact (little-endian f32).
    pub fn load_tensor(&self, name: &str) -> Result<HostTensor> {
        let entry = self.manifest.require(name)?;
        if entry.kind != "tensor" {
            bail!("{name} is not a tensor artifact");
        }
        let path = self.dir.join(&entry.file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {path:?}"))?;
        if bytes.len() % 4 != 0 {
            bail!("{name}: byte length {} not a multiple of 4", bytes.len());
        }
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let shape = entry.outputs[0].shape.clone();
        if data.len() != shape.iter().product::<usize>() {
            bail!("{name}: {} elements but shape {:?}", data.len(), shape);
        }
        Ok(HostTensor::F32(data, shape))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checks() {
        let t = HostTensor::f32(vec![0.0; 6], &[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert!(t.as_f32().is_ok());
        let i = HostTensor::i32(vec![1, 2], &[2]);
        assert!(i.as_f32().is_err());
    }

    #[test]
    #[should_panic]
    fn host_tensor_rejects_shape_mismatch() {
        HostTensor::f32(vec![0.0; 5], &[2, 3]);
    }
}
