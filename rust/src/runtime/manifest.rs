//! The artifact manifest: `artifacts/manifest.json` written by
//! `python/compile/aot.py`, describing every HLO module and raw tensor
//! the coordinator may load (shapes, dtypes, workload metadata).

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::conv::ConvProblem;
use crate::util::Json;

/// Shape + dtype of one executable input/output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "s32"
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor spec missing dtype"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One manifest entry (HLO module or raw tensor).
#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub kind: String,
    /// file name under the artifacts dir (.hlo.txt or .bin)
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

impl Entry {
    /// The conv problem this entry serves, if it is a conv artifact.
    pub fn problem(&self) -> Option<ConvProblem> {
        ConvProblem::from_json(self.meta.get("spec")?)
    }

    pub fn strategy(&self) -> Option<&str> {
        self.meta.get("strategy")?.as_str()
    }

    pub fn pass(&self) -> Option<&str> {
        self.meta.get("pass")?.as_str()
    }

    pub fn origin(&self) -> Option<&str> {
        self.meta.get("origin")?.as_str()
    }
}

/// Parsed manifest with name-keyed lookup.
#[derive(Debug, Default)]
pub struct Manifest {
    pub entries: Vec<Entry>,
    by_name: BTreeMap<String, usize>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let version = j.get("version").and_then(Json::as_usize);
        if version != Some(1) {
            bail!("unsupported manifest version {version:?}");
        }
        let mut m = Manifest::default();
        for ej in j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
        {
            let name = ej
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("entry missing name"))?
                .to_string();
            let kind = ej
                .get("kind")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string();
            let file = ej
                .get("hlo")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("entry {name} missing file"))?
                .to_string();
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                ej.get(key)
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            let entry = Entry {
                inputs: parse_specs("inputs")?,
                outputs: parse_specs("outputs")?,
                meta: ej.get("meta").cloned().unwrap_or(Json::Null),
                name,
                kind,
                file,
            };
            m.by_name.insert(entry.name.clone(), m.entries.len());
            m.entries.push(entry);
        }
        Ok(m)
    }

    pub fn get(&self, name: &str) -> Option<&Entry> {
        self.by_name.get(name).map(|i| &self.entries[*i])
    }

    pub fn require(&self, name: &str) -> Result<&Entry> {
        self.get(name).ok_or_else(|| {
            anyhow!("artifact {name:?} not in manifest — re-run `make artifacts`")
        })
    }

    /// All entries whose name starts with `prefix` (artifact families).
    pub fn with_prefix<'a>(&'a self, prefix: &'a str)
                           -> impl Iterator<Item = &'a Entry> {
        self.entries.iter().filter(move |e| e.name.starts_with(prefix))
    }

    /// Find the conv artifact for (origin spec name, strategy, pass).
    pub fn conv(&self, spec_name: &str, strategy: &str, pass: &str)
                -> Option<&Entry> {
        let want = format!("conv.{spec_name}.{strategy}.{pass}");
        self.get(&want)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "entries": [
        {"name": "conv.q.fbfft.fprop", "kind": "conv",
         "hlo": "conv.q.fbfft.fprop.hlo.txt",
         "inputs": [{"shape": [2,4,16,16], "dtype": "f32"},
                     {"shape": [4,4,3,3], "dtype": "f32"}],
         "outputs": [{"shape": [2,4,14,14], "dtype": "f32"}],
         "meta": {"strategy": "fbfft", "pass": "fprop", "origin": "q",
                  "spec": {"name":"q","s":2,"f":4,"fo":4,"h":16,"w":16,
                            "kh":3,"kw":3,"stride":1}}},
        {"name": "train.init.conv1", "kind": "tensor",
         "hlo": "train.init.conv1.bin",
         "inputs": [], "outputs": [{"shape": [8,1,3,3], "dtype": "f32"}],
         "meta": {"param": "conv1"}}
      ]
    }"#;

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.get("conv.q.fbfft.fprop").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].elems(), 2 * 4 * 16 * 16);
        assert_eq!(e.strategy(), Some("fbfft"));
        assert_eq!(e.pass(), Some("fprop"));
        let p = e.problem().unwrap();
        assert_eq!((p.s, p.f, p.fo, p.h), (2, 4, 4, 16));
    }

    #[test]
    fn conv_lookup_by_triple() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.conv("q", "fbfft", "fprop").is_some());
        assert!(m.conv("q", "vendor", "fprop").is_none());
    }

    #[test]
    fn prefix_family() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.with_prefix("train.").count(), 1);
        assert_eq!(m.with_prefix("conv.").count(), 1);
    }

    #[test]
    fn rejects_wrong_version() {
        assert!(Manifest::parse(r#"{"version":2,"entries":[]}"#).is_err());
    }

    #[test]
    fn require_gives_actionable_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let err = m.require("nope").unwrap_err().to_string();
        assert!(err.contains("make artifacts"));
    }
}
