//! Matrix-unrolling convolution (Chellapilla 2006) on the in-tree SGEMM —
//! the cuDNN-analogue engine (paper §2: 'the strategy followed by many
//! implementors'). All three passes; bprop and accGrad reuse the fprop
//! machinery through the transposed-conv and batch-as-reduction
//! identities, the same algebra `compile/model.py` uses at Layer 2.

use super::gemm::sgemm;
use super::problem::ConvProblem;

/// Unroll one sample's input planes into the patch matrix
/// `(yh·yw) × (f·kh·kw)`, taps fastest (i, u, v) to match the
/// `(fo) × (f·kh·kw)` weight matrix layout.
fn unroll(p: &ConvProblem, xs: &[f32], patches: &mut [f32]) {
    let (yh, yw) = (p.yh(), p.yw());
    let cols = p.f * p.kh * p.kw;
    debug_assert_eq!(patches.len(), yh * yw * cols);
    for a in 0..yh {
        for b in 0..yw {
            let row = &mut patches[(a * yw + b) * cols..][..cols];
            let mut c = 0;
            for i in 0..p.f {
                let plane = &xs[i * p.h * p.w..];
                for u in 0..p.kh {
                    let src = &plane[(a * p.stride + u) * p.w
                        + b * p.stride..][..p.kw];
                    row[c..c + p.kw].copy_from_slice(src);
                    c += p.kw;
                }
            }
        }
    }
}

/// fprop via unroll + GEMM: per sample,
/// `out(fo × yh·yw) = W(fo × f·k²) · patchesᵀ` — computed as
/// `patches · Wᵀ` then written transposed to keep BDHW output layout.
pub fn fprop(p: &ConvProblem, x: &[f32], wei: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), p.input_len());
    assert_eq!(wei.len(), p.weight_len());
    let (yh, yw) = (p.yh(), p.yw());
    let cols = p.f * p.kh * p.kw;
    let pixels = yh * yw;
    // W transposed once: (f·k²) × fo
    let mut wt = vec![0f32; cols * p.fo];
    for j in 0..p.fo {
        for c in 0..cols {
            wt[c * p.fo + j] = wei[j * cols + c];
        }
    }
    let mut out = vec![0f32; p.output_len()];
    let mut patches = vec![0f32; pixels * cols];
    let mut prod = vec![0f32; pixels * p.fo];
    for s in 0..p.s {
        unroll(p, &x[s * p.f * p.h * p.w..][..p.f * p.h * p.w],
               &mut patches);
        sgemm(pixels, cols, p.fo, &patches, &wt, &mut prod, false);
        // transpose (pixels × fo) -> (fo × pixels)
        let os = &mut out[s * p.fo * pixels..][..p.fo * pixels];
        for px in 0..pixels {
            for j in 0..p.fo {
                os[j * pixels + px] = prod[px * p.fo + j];
            }
        }
    }
    out
}

/// bprop by the transposed-conv identity: pad the gradient by k-1,
/// correlate with the flipped, plane-swapped kernel.
pub fn bprop(p: &ConvProblem, go: &[f32], wei: &[f32]) -> Vec<f32> {
    assert_eq!(p.stride, 1, "strided bprop is vendor-only (paper §2)");
    let (yh, yw) = (p.yh(), p.yw());
    let (ph, pw) = (yh + 2 * (p.kh - 1), yw + 2 * (p.kw - 1));
    // padded gradient, planes f' as "input planes"
    let mut gop = vec![0f32; p.s * p.fo * ph * pw];
    for s in 0..p.s {
        for j in 0..p.fo {
            for a in 0..yh {
                let dst = ((s * p.fo + j) * ph + a + p.kh - 1) * pw
                    + (p.kw - 1);
                let src = ((s * p.fo + j) * yh + a) * yw;
                gop[dst..dst + yw].copy_from_slice(&go[src..src + yw]);
            }
        }
    }
    // flipped kernel with (j,i) swapped: wf[i,j,u,v] = w[j,i,kh-1-u,kw-1-v]
    let mut wf = vec![0f32; p.weight_len()];
    for j in 0..p.fo {
        for i in 0..p.f {
            for u in 0..p.kh {
                for v in 0..p.kw {
                    wf[((i * p.fo + j) * p.kh + u) * p.kw + v] = wei
                        [((j * p.f + i) * p.kh + (p.kh - 1 - u)) * p.kw
                            + (p.kw - 1 - v)];
                }
            }
        }
    }
    let q = ConvProblem::new(p.s, p.fo, p.f, ph, pw, p.kh, p.kw);
    fprop(&q, &gop, &wf)
}

/// accGrad by batch-as-reduction: planes of x become the batch, the
/// gradient becomes the kernel; swap output back to (fo, f, kh, kw).
pub fn accgrad(p: &ConvProblem, go: &[f32], x: &[f32]) -> Vec<f32> {
    assert_eq!(p.stride, 1, "strided accGrad is vendor-only (paper §2)");
    let (yh, yw) = (p.yh(), p.yw());
    // xt: (f, S, h, w); got: (fo, S, yh, yw)
    let mut xt = vec![0f32; x.len()];
    for s in 0..p.s {
        for i in 0..p.f {
            let src = (s * p.f + i) * p.h * p.w;
            let dst = (i * p.s + s) * p.h * p.w;
            xt[dst..dst + p.h * p.w].copy_from_slice(&x[src..src + p.h * p.w]);
        }
    }
    let mut got = vec![0f32; go.len()];
    for s in 0..p.s {
        for j in 0..p.fo {
            let src = (s * p.fo + j) * yh * yw;
            let dst = (j * p.s + s) * yh * yw;
            got[dst..dst + yh * yw].copy_from_slice(&go[src..src + yh * yw]);
        }
    }
    let q = ConvProblem::new(p.f, p.s, p.fo, p.h, p.w, yh, yw);
    let g = fprop(&q, &xt, &got); // (f, fo, kh, kw)
    let mut gw = vec![0f32; p.weight_len()];
    for i in 0..p.f {
        for j in 0..p.fo {
            let src = (i * p.fo + j) * p.kh * p.kw;
            let dst = (j * p.f + i) * p.kh * p.kw;
            gw[dst..dst + p.kh * p.kw]
                .copy_from_slice(&g[src..src + p.kh * p.kw]);
        }
    }
    gw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Pass;
    use crate::testkit::{assert_close, assert_close_oracle, oracle,
                         tolerance};
    use crate::util::Rng;

    #[test]
    fn fprop_matches_f64_oracle() {
        let mut rng = Rng::new(10);
        for p in [ConvProblem::square(2, 3, 4, 9, 3),
                  ConvProblem::new(1, 2, 3, 8, 11, 5, 3),
                  ConvProblem::square(3, 1, 1, 6, 6)] {
            let x = rng.normal_vec(p.input_len());
            let wei = rng.normal_vec(p.weight_len());
            assert_close_oracle(&fprop(&p, &x, &wei),
                                &oracle::fprop64(&p, &x, &wei),
                                tolerance::time_domain(&p, Pass::Fprop));
        }
    }

    #[test]
    fn strided_fprop_matches_f64_oracle() {
        let mut p = ConvProblem::square(2, 2, 2, 9, 3);
        p.stride = 2;
        let mut rng = Rng::new(11);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        assert_close_oracle(&fprop(&p, &x, &wei),
                            &oracle::fprop64(&p, &x, &wei),
                            tolerance::time_domain(&p, Pass::Fprop));
    }

    #[test]
    fn bprop_matches_oracle_and_direct() {
        let p = ConvProblem::square(2, 3, 2, 8, 3);
        let mut rng = Rng::new(12);
        let go = rng.normal_vec(p.output_len());
        let wei = rng.normal_vec(p.weight_len());
        let got = bprop(&p, &go, &wei);
        let tol = tolerance::time_domain(&p, Pass::Bprop);
        assert_close_oracle(&got, &oracle::bprop64(&p, &go, &wei), tol);
        assert_close(&got, &crate::conv::direct::bprop(&p, &go, &wei),
                     2.0 * tol);
    }

    #[test]
    fn accgrad_matches_oracle_and_direct() {
        let p = ConvProblem::new(3, 2, 2, 7, 9, 3, 5);
        let mut rng = Rng::new(13);
        let go = rng.normal_vec(p.output_len());
        let x = rng.normal_vec(p.input_len());
        let got = accgrad(&p, &go, &x);
        let tol = tolerance::time_domain(&p, Pass::AccGrad);
        assert_close_oracle(&got, &oracle::accgrad64(&p, &go, &x), tol);
        assert_close(&got, &crate::conv::direct::accgrad(&p, &go, &x),
                     2.0 * tol);
    }
}
