//! §6 tiling on the host engine: decompose a large convolution into
//! many small fbfft convolutions so every transform lands in the 8–64
//! sweet spot (cost O(n·log n) → O(n·log w), paper §6).
//!
//! Same three decompositions as `python/compile/kernels/tiling.py`:
//! overlap-save fprop, overlap-add bprop, tile-sum accGrad.

use super::fft_conv::{FftConvEngine, FftMode, StageTimings};
use super::problem::ConvProblem;

/// Fourier basis for a tile of output size `d` under a `kh × kw` kernel.
pub fn tile_fft_size(d: usize, kh: usize, kw: usize) -> usize {
    (d + kh.max(kw) - 1).next_power_of_two()
}

fn ranges(total: usize, d: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut a = 0;
    while a < total {
        out.push((a, d.min(total - a)));
        a += d;
    }
    out
}

/// Gather an input window `[h0, h0+hh) × [w0, w0+ww)` of every (s, i)
/// plane into a dense BDHW tensor.
fn gather(p: &ConvProblem, x: &[f32], h0: usize, hh: usize, w0: usize,
          ww: usize) -> Vec<f32> {
    let mut out = vec![0f32; p.s * p.f * hh * ww];
    for b in 0..p.s * p.f {
        for r in 0..hh {
            let src = (b * p.h + h0 + r) * p.w + w0;
            let dst = (b * hh + r) * ww;
            out[dst..dst + ww].copy_from_slice(&x[src..src + ww]);
        }
    }
    out
}

/// Tiled fprop (overlap-save): output tiles are disjoint, input windows
/// overlap by k-1.
pub fn fprop(p: &ConvProblem, x: &[f32], wei: &[f32], d: usize)
             -> (Vec<f32>, StageTimings) {
    assert!(d >= 1);
    let (yh, yw) = (p.yh(), p.yw());
    let n_t = tile_fft_size(d, p.kh, p.kw);
    let eng = FftConvEngine::new(FftMode::Fbfft, n_t);
    let mut out = vec![0f32; p.output_len()];
    let mut total = StageTimings::default();
    for (ah, dh) in ranges(yh, d) {
        for (aw, dw) in ranges(yw, d) {
            let (th, tw) = (dh + p.kh - 1, dw + p.kw - 1);
            let xt = gather(p, x, ah, th, aw, tw);
            let q = ConvProblem::new(p.s, p.f, p.fo, th, tw, p.kh, p.kw);
            let (yt, t) = eng.fprop(&q, &xt, wei);
            total.add(&t);
            for b in 0..p.s * p.fo {
                for r in 0..dh {
                    let src = (b * dh + r) * dw;
                    let dst = (b * yh + ah + r) * yw + aw;
                    out[dst..dst + dw].copy_from_slice(&yt[src..src + dw]);
                }
            }
        }
    }
    (out, total)
}

/// Tiled bprop (overlap-add): each gradient tile scatters a d+k-1 window
/// additively into the input gradient.
pub fn bprop(p: &ConvProblem, go: &[f32], wei: &[f32], d: usize)
             -> (Vec<f32>, StageTimings) {
    let (yh, yw) = (p.yh(), p.yw());
    let n_t = tile_fft_size(d, p.kh, p.kw);
    let eng = FftConvEngine::new(FftMode::Fbfft, n_t);
    let mut out = vec![0f32; p.input_len()];
    let mut total = StageTimings::default();
    for (ah, dh) in ranges(yh, d) {
        for (aw, dw) in ranges(yw, d) {
            // gather the gradient tile
            let mut got = vec![0f32; p.s * p.fo * dh * dw];
            for b in 0..p.s * p.fo {
                for r in 0..dh {
                    let src = (b * yh + ah + r) * yw + aw;
                    let dst = (b * dh + r) * dw;
                    got[dst..dst + dw].copy_from_slice(&go[src..src + dw]);
                }
            }
            let (th, tw) = (dh + p.kh - 1, dw + p.kw - 1);
            let q = ConvProblem::new(p.s, p.f, p.fo, th, tw, p.kh, p.kw);
            let (gxt, t) = eng.bprop(&q, &got, wei);
            total.add(&t);
            for b in 0..p.s * p.f {
                for r in 0..th {
                    let src = (b * th + r) * tw;
                    let dst = (b * p.h + ah + r) * p.w + aw;
                    for c in 0..tw {
                        out[dst + c] += gxt[src + c];
                    }
                }
            }
        }
    }
    (out, total)
}

/// Tiled accGrad: the paper's §6 sum of tile-local correlations.
pub fn accgrad(p: &ConvProblem, go: &[f32], x: &[f32], d: usize)
               -> (Vec<f32>, StageTimings) {
    let (yh, yw) = (p.yh(), p.yw());
    let n_t = tile_fft_size(d, p.kh, p.kw);
    let eng = FftConvEngine::new(FftMode::Fbfft, n_t);
    let mut out = vec![0f32; p.weight_len()];
    let mut total = StageTimings::default();
    for (ah, dh) in ranges(yh, d) {
        for (aw, dw) in ranges(yw, d) {
            let mut got = vec![0f32; p.s * p.fo * dh * dw];
            for b in 0..p.s * p.fo {
                for r in 0..dh {
                    let src = (b * yh + ah + r) * yw + aw;
                    let dst = (b * dh + r) * dw;
                    got[dst..dst + dw].copy_from_slice(&go[src..src + dw]);
                }
            }
            let (th, tw) = (dh + p.kh - 1, dw + p.kw - 1);
            let xt = gather(p, x, ah, th, aw, tw);
            let q = ConvProblem::new(p.s, p.f, p.fo, th, tw, p.kh, p.kw);
            let (gwt, t) = eng.accgrad(&q, &got, &xt);
            total.add(&t);
            for (o, g) in out.iter_mut().zip(&gwt) {
                *o += *g;
            }
        }
    }
    (out, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Pass;
    use crate::testkit::{assert_close_oracle, oracle, tolerance};
    use crate::util::Rng;

    #[test]
    fn tiled_fprop_matches_oracle_all_tile_sizes() {
        let p = ConvProblem::square(2, 2, 3, 16, 3);
        let mut rng = Rng::new(30);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let want = oracle::fprop64(&p, &x, &wei);
        for d in [3usize, 4, 6, 7, 14, 20] {
            let (got, _) = fprop(&p, &x, &wei, d);
            assert_close_oracle(&got, &want,
                                tolerance::tiled(&p, Pass::Fprop, d));
        }
    }

    #[test]
    fn tiled_bprop_matches_oracle() {
        let p = ConvProblem::square(2, 2, 2, 16, 5);
        let mut rng = Rng::new(31);
        let go = rng.normal_vec(p.output_len());
        let wei = rng.normal_vec(p.weight_len());
        let want = oracle::bprop64(&p, &go, &wei);
        for d in [3usize, 5, 12] {
            let (got, _) = bprop(&p, &go, &wei, d);
            assert_close_oracle(&got, &want,
                                tolerance::tiled(&p, Pass::Bprop, d));
        }
    }

    #[test]
    fn tiled_accgrad_matches_oracle() {
        let p = ConvProblem::square(2, 2, 2, 14, 3);
        let mut rng = Rng::new(32);
        let go = rng.normal_vec(p.output_len());
        let x = rng.normal_vec(p.input_len());
        let want = oracle::accgrad64(&p, &go, &x);
        for d in [4usize, 5, 12] {
            let (got, _) = accgrad(&p, &go, &x, d);
            assert_close_oracle(&got, &want,
                                tolerance::tiled(&p, Pass::AccGrad, d));
        }
    }

    #[test]
    fn tile_basis_depends_on_kernel_not_input() {
        assert_eq!(tile_fft_size(3, 3, 3), 8);
        assert_eq!(tile_fft_size(8, 3, 3), 16);
        assert_eq!(tile_fft_size(8, 11, 11), 32);
    }

    #[test]
    fn rectangular_problem_tiles() {
        let p = ConvProblem::new(1, 2, 2, 13, 17, 3, 5);
        let mut rng = Rng::new(33);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let want = oracle::fprop64(&p, &x, &wei);
        let (got, _) = fprop(&p, &x, &wei, 6);
        assert_close_oracle(&got, &want,
                            tolerance::tiled(&p, Pass::Fprop, 6));
    }
}
