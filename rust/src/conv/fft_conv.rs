//! The frequency-domain convolution pipeline of Table 1, staged exactly
//! as the paper stages it so the Table-5 breakdown can be measured:
//!
//! ```text
//!   FFT A → TRANS A → FFT B → TRANS B → CGEMM → TRANS C → IFFT C
//! ```
//!
//! Two modes:
//!
//! * [`FftMode::Vendor`] — the cuFFT-based implementation of §3: the
//!   operands are **explicitly copied into zero-padded buffers** (§5.1:
//!   'one may need to allocate a duplicate, larger memory region and copy
//!   data from non-padded tensors to padded tensors'), transformed with
//!   the general planner, then **explicitly transposed** BDHW→HWBD for
//!   the per-bin CGEMM and back (the Cgeam steps of Table 1).
//! * [`FftMode::Fbfft`] — the §5 implementation: implicit zero-copy
//!   padding inside `fbfft_host`, output *born* in the HWBD bin-major
//!   layout (fused transpose) and clipped on the way out (fused clip), so
//!   the three TRANS stages identically vanish.
//!
//! All three passes share the bin-major CGEMM with the conjugation
//! pattern of §2 (fprop: conj W; bprop: none; accGrad: conj Go, reduce S).

use std::time::{Duration, Instant};

use crate::fft::fbfft_host;
use crate::fft::fft2d::{irfft2, rfft2};
use crate::fft::real::rfft_len;
use crate::fft::C32;

use super::problem::ConvProblem;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FftMode {
    /// cuFFT-analogue: explicit padding, planner FFTs, explicit transposes.
    Vendor,
    /// fbfft: implicit padding, fused transpose + clip, power-of-two only.
    Fbfft,
}

/// Wall-clock per Table-1 stage (Table 5's columns). Stages elided by
/// fbfft's fused layouts report zero.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimings {
    pub fft_a: Duration,
    pub trans_a: Duration,
    pub fft_b: Duration,
    pub trans_b: Duration,
    pub cgemm: Duration,
    pub trans_c: Duration,
    pub ifft_c: Duration,
}

impl StageTimings {
    pub fn total(&self) -> Duration {
        self.fft_a + self.trans_a + self.fft_b + self.trans_b + self.cgemm
            + self.trans_c + self.ifft_c
    }

    pub fn add(&mut self, o: &StageTimings) {
        self.fft_a += o.fft_a;
        self.trans_a += o.trans_a;
        self.fft_b += o.fft_b;
        self.trans_b += o.trans_b;
        self.cgemm += o.cgemm;
        self.trans_c += o.trans_c;
        self.ifft_c += o.ifft_c;
    }
}

/// Frequency tensor in **bin-major** layout: `bins × rows`, one small
/// matrix slab per frequency bin (`rows` = S·f etc.). `bins = nf·n`.
struct FreqTensor {
    data: Vec<C32>,
    bins: usize,
    rows: usize,
}

pub struct FftConvEngine {
    pub mode: FftMode,
    pub n_fft: usize,
}

impl FftConvEngine {
    pub fn new(mode: FftMode, n_fft: usize) -> Self {
        if mode == FftMode::Fbfft {
            assert!(n_fft.is_power_of_two() && n_fft <= fbfft_host::MAX_N,
                    "fbfft basis must be a power of two <= 256, got {n_fft}");
        }
        FftConvEngine { mode, n_fft }
    }

    /// fbfft's default basis for a problem (next pow2 covering the input).
    pub fn fbfft_for(p: &ConvProblem) -> Self {
        Self::new(FftMode::Fbfft, p.h.max(p.w).next_power_of_two())
    }

    fn bins(&self) -> usize {
        rfft_len(self.n_fft) * self.n_fft
    }

    // ---- forward transforms -------------------------------------------

    /// Transform `count` planes of `h_in × w_in` into bin-major frequency
    /// layout. Vendor mode pays the explicit pad + transpose; fbfft mode
    /// emits bin-major directly.
    fn forward(&self, planes: &[f32], h_in: usize, w_in: usize,
               count: usize, fft_t: &mut Duration, trans_t: &mut Duration)
               -> FreqTensor {
        let n = self.n_fft;
        let nf = rfft_len(n);
        let bins = self.bins();
        match self.mode {
            FftMode::Fbfft => {
                let t0 = Instant::now();
                let plan = fbfft_host::cached(n);
                let mut data = vec![C32::ZERO; bins * count];
                plan.rfft2_batch_transposed(planes, h_in, w_in, count,
                                            &mut data);
                *fft_t += t0.elapsed();
                // fused transpose: TRANS stage does not exist
                FreqTensor { data, bins, rows: count }
            }
            FftMode::Vendor => {
                let t0 = Instant::now();
                // the duplicate padded tensor cuFFT forces (§5.1)
                let mut padded = vec![0f32; count * n * n];
                for b in 0..count {
                    for r in 0..h_in {
                        let dst = (b * n + r) * n;
                        let src = (b * h_in + r) * w_in;
                        padded[dst..dst + w_in]
                            .copy_from_slice(&planes[src..src + w_in]);
                    }
                }
                // plane-major transforms (BDHW frequency layout)
                let mut plane_major = vec![C32::ZERO; count * bins];
                for b in 0..count {
                    let f = rfft2(&padded[b * n * n..(b + 1) * n * n],
                                  n, n, n);
                    plane_major[b * bins..(b + 1) * bins]
                        .copy_from_slice(&f);
                }
                *fft_t += t0.elapsed();
                // explicit BDHW -> HWBD transposition (the Cgeam step)
                let t1 = Instant::now();
                let mut data = vec![C32::ZERO; bins * count];
                for b in 0..count {
                    let src = &plane_major[b * bins..(b + 1) * bins];
                    for q in 0..bins {
                        data[q * count + b] = src[q];
                    }
                }
                *trans_t += t1.elapsed();
                let _ = nf;
                FreqTensor { data, bins, rows: count }
            }
        }
    }

    /// Inverse-transform a bin-major frequency tensor of `count` planes,
    /// clipping each to `clip_h × clip_w`.
    fn inverse(&self, freq: &FreqTensor, clip_h: usize, clip_w: usize,
               trans_t: &mut Duration, ifft_t: &mut Duration) -> Vec<f32> {
        let n = self.n_fft;
        let nf = rfft_len(n);
        let count = freq.rows;
        match self.mode {
            FftMode::Fbfft => {
                let t0 = Instant::now();
                let plan = fbfft_host::cached(n);
                let mut out = vec![0f32; count * clip_h * clip_w];
                plan.irfft2_batch_transposed(&freq.data, count, clip_h,
                                             clip_w, &mut out);
                *ifft_t += t0.elapsed();
                out
            }
            FftMode::Vendor => {
                // explicit HWBD -> BDHW transposition first
                let t0 = Instant::now();
                let mut plane_major = vec![C32::ZERO; count * freq.bins];
                for q in 0..freq.bins {
                    for b in 0..count {
                        plane_major[b * freq.bins + q] =
                            freq.data[q * count + b];
                    }
                }
                *trans_t += t0.elapsed();
                let t1 = Instant::now();
                let mut out = vec![0f32; count * clip_h * clip_w];
                for b in 0..count {
                    // vendor bins are (kh, kw) row-major — exactly the
                    // layout irfft2 consumes (rfft2 produced them)
                    let src = &plane_major[b * freq.bins..(b + 1) * freq.bins];
                    let img = irfft2(src, n, clip_h, clip_w);
                    out[b * clip_h * clip_w..(b + 1) * clip_h * clip_w]
                        .copy_from_slice(&img);
                }
                *ifft_t += t1.elapsed();
                let _ = nf;
                out
            }
        }
    }

    // ---- the three passes ----------------------------------------------

    /// fprop: `Out_q = In_q · conj(W_q)ᵀ` per bin, clip to (yh, yw).
    pub fn fprop(&self, p: &ConvProblem, x: &[f32], wei: &[f32])
                 -> (Vec<f32>, StageTimings) {
        assert_eq!(p.stride, 1, "strided FFT conv out of scope (paper §2)");
        let mut t = StageTimings::default();
        let xf = self.forward(x, p.h, p.w, p.s * p.f,
                              &mut t.fft_a, &mut t.trans_a);
        let wf = self.forward(wei, p.kh, p.kw, p.fo * p.f,
                              &mut t.fft_b, &mut t.trans_b);
        let t0 = Instant::now();
        let mut of = FreqTensor {
            data: vec![C32::ZERO; self.bins() * p.s * p.fo],
            bins: self.bins(),
            rows: p.s * p.fo,
        };
        for q in 0..self.bins() {
            let inq = &xf.data[q * xf.rows..][..xf.rows];       // S×f
            let wq = &wf.data[q * wf.rows..][..wf.rows];        // fo×f
            let oq = &mut of.data[q * p.s * p.fo..][..p.s * p.fo];
            for s in 0..p.s {
                let xrow = &inq[s * p.f..][..p.f];
                for j in 0..p.fo {
                    let wrow = &wq[j * p.f..][..p.f];
                    let mut acc = C32::ZERO;
                    for i in 0..p.f {
                        acc = acc.mul_add(xrow[i], wrow[i].conj());
                    }
                    oq[s * p.fo + j] = acc;
                }
            }
        }
        t.cgemm += t0.elapsed();
        let out = self.inverse(&of, p.yh(), p.yw(),
                               &mut t.trans_c, &mut t.ifft_c);
        (out, t)
    }

    /// bprop: `Gx_q = Go_q · W_q` per bin (no conjugation), clip (h, w).
    pub fn bprop(&self, p: &ConvProblem, go: &[f32], wei: &[f32])
                 -> (Vec<f32>, StageTimings) {
        assert_eq!(p.stride, 1, "strided FFT conv out of scope (paper §2)");
        let mut t = StageTimings::default();
        let gof = self.forward(go, p.yh(), p.yw(), p.s * p.fo,
                               &mut t.fft_a, &mut t.trans_a);
        let wf = self.forward(wei, p.kh, p.kw, p.fo * p.f,
                              &mut t.fft_b, &mut t.trans_b);
        let t0 = Instant::now();
        let mut gxf = FreqTensor {
            data: vec![C32::ZERO; self.bins() * p.s * p.f],
            bins: self.bins(),
            rows: p.s * p.f,
        };
        for q in 0..self.bins() {
            let gq = &gof.data[q * gof.rows..][..gof.rows];     // S×fo
            let wq = &wf.data[q * wf.rows..][..wf.rows];        // fo×f
            let oq = &mut gxf.data[q * p.s * p.f..][..p.s * p.f];
            for s in 0..p.s {
                let grow = &gq[s * p.fo..][..p.fo];
                let orow = &mut oq[s * p.f..][..p.f];
                for (j, g) in grow.iter().enumerate() {
                    let wrow = &wq[j * p.f..][..p.f];
                    for i in 0..p.f {
                        orow[i] = orow[i].mul_add(*g, wrow[i]);
                    }
                }
            }
        }
        t.cgemm += t0.elapsed();
        let out = self.inverse(&gxf, p.h, p.w, &mut t.trans_c, &mut t.ifft_c);
        (out, t)
    }

    /// accGrad: `Gw_q = conj(Go_q)ᵀ · X_q` per bin (minibatch reduced),
    /// clip (kh, kw).
    pub fn accgrad(&self, p: &ConvProblem, go: &[f32], x: &[f32])
                   -> (Vec<f32>, StageTimings) {
        assert_eq!(p.stride, 1, "strided FFT conv out of scope (paper §2)");
        let mut t = StageTimings::default();
        let gof = self.forward(go, p.yh(), p.yw(), p.s * p.fo,
                               &mut t.fft_a, &mut t.trans_a);
        let xf = self.forward(x, p.h, p.w, p.s * p.f,
                              &mut t.fft_b, &mut t.trans_b);
        let t0 = Instant::now();
        let mut gwf = FreqTensor {
            data: vec![C32::ZERO; self.bins() * p.fo * p.f],
            bins: self.bins(),
            rows: p.fo * p.f,
        };
        for q in 0..self.bins() {
            let gq = &gof.data[q * gof.rows..][..gof.rows];     // S×fo
            let xq = &xf.data[q * xf.rows..][..xf.rows];        // S×f
            let oq = &mut gwf.data[q * p.fo * p.f..][..p.fo * p.f];
            for s in 0..p.s {
                let grow = &gq[s * p.fo..][..p.fo];
                let xrow = &xq[s * p.f..][..p.f];
                for (j, g) in grow.iter().enumerate() {
                    let gc = g.conj();
                    let orow = &mut oq[j * p.f..][..p.f];
                    for i in 0..p.f {
                        orow[i] = orow[i].mul_add(gc, xrow[i]);
                    }
                }
            }
        }
        t.cgemm += t0.elapsed();
        let out = self.inverse(&gwf, p.kh, p.kw,
                               &mut t.trans_c, &mut t.ifft_c);
        (out, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Pass;
    use crate::testkit::{assert_close, assert_close_oracle, oracle,
                         tolerance};
    use crate::util::Rng;

    fn problems() -> Vec<ConvProblem> {
        vec![
            ConvProblem::square(2, 3, 4, 9, 3),
            ConvProblem::new(1, 2, 2, 13, 11, 5, 3),
            ConvProblem::square(3, 1, 1, 8, 8),
        ]
    }

    #[test]
    fn fbfft_fprop_matches_oracle() {
        let mut rng = Rng::new(20);
        for p in problems() {
            let eng = FftConvEngine::fbfft_for(&p);
            let x = rng.normal_vec(p.input_len());
            let wei = rng.normal_vec(p.weight_len());
            let (got, timings) = eng.fprop(&p, &x, &wei);
            assert_close_oracle(
                &got, &oracle::fprop64(&p, &x, &wei),
                tolerance::frequency(&p, Pass::Fprop, eng.n_fft));
            // fbfft elides every TRANS stage
            assert_eq!(timings.trans_a, Duration::ZERO);
            assert_eq!(timings.trans_b, Duration::ZERO);
            assert_eq!(timings.trans_c, Duration::ZERO);
        }
    }

    #[test]
    fn vendor_fprop_matches_oracle_pow2_and_smooth() {
        let mut rng = Rng::new(21);
        let p = ConvProblem::square(2, 2, 3, 9, 3);
        for n in [16usize, 12, 10] {
            // vendor path supports arbitrary smooth bases >= h
            let eng = FftConvEngine::new(FftMode::Vendor, n);
            let x = rng.normal_vec(p.input_len());
            let wei = rng.normal_vec(p.weight_len());
            let (got, _) = eng.fprop(&p, &x, &wei);
            assert_close_oracle(&got, &oracle::fprop64(&p, &x, &wei),
                                tolerance::frequency(&p, Pass::Fprop, n));
        }
    }

    #[test]
    fn both_modes_bprop_match_oracle() {
        let mut rng = Rng::new(22);
        for p in problems() {
            let go = rng.normal_vec(p.output_len());
            let wei = rng.normal_vec(p.weight_len());
            let want = oracle::bprop64(&p, &go, &wei);
            let eng = FftConvEngine::fbfft_for(&p);
            let (a, _) = eng.bprop(&p, &go, &wei);
            assert_close_oracle(
                &a, &want, tolerance::frequency(&p, Pass::Bprop, eng.n_fft));
            let n = p.h.max(p.w).next_power_of_two();
            let (b, _) = FftConvEngine::new(FftMode::Vendor, n)
                .bprop(&p, &go, &wei);
            assert_close_oracle(
                &b, &want, tolerance::frequency(&p, Pass::Bprop, n));
        }
    }

    #[test]
    fn both_modes_accgrad_match_oracle() {
        let mut rng = Rng::new(23);
        for p in problems() {
            let go = rng.normal_vec(p.output_len());
            let x = rng.normal_vec(p.input_len());
            let want = oracle::accgrad64(&p, &go, &x);
            let eng = FftConvEngine::fbfft_for(&p);
            let (a, _) = eng.accgrad(&p, &go, &x);
            assert_close_oracle(
                &a, &want,
                tolerance::frequency(&p, Pass::AccGrad, eng.n_fft));
            let n = p.h.max(p.w).next_power_of_two();
            let (b, _) = FftConvEngine::new(FftMode::Vendor, n)
                .accgrad(&p, &go, &x);
            assert_close_oracle(
                &b, &want, tolerance::frequency(&p, Pass::AccGrad, n));
        }
    }

    #[test]
    fn oversized_basis_equivalent() {
        let p = ConvProblem::square(1, 2, 2, 9, 3);
        let mut rng = Rng::new(24);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let (a, _) = FftConvEngine::new(FftMode::Fbfft, 16).fprop(&p, &x, &wei);
        let (b, _) = FftConvEngine::new(FftMode::Fbfft, 32).fprop(&p, &x, &wei);
        assert_close(&a, &b,
                     2.0 * tolerance::frequency(&p, Pass::Fprop, 32));
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn fbfft_rejects_non_pow2_basis() {
        FftConvEngine::new(FftMode::Fbfft, 12);
    }
}
