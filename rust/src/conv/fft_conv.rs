//! The frequency-domain convolution pipeline of Table 1, staged exactly
//! as the paper stages it so the Table-5 breakdown can be measured:
//!
//! ```text
//!   FFT A → TRANS A → FFT B → TRANS B → CGEMM → TRANS C → IFFT C
//! ```
//!
//! Three modes:
//!
//! * [`FftMode::Vendor`] — the cuFFT-based implementation of §3: the
//!   operands are **explicitly copied into zero-padded buffers** (§5.1:
//!   'one may need to allocate a duplicate, larger memory region and copy
//!   data from non-padded tensors to padded tensors'), transformed with
//!   the general planner, then **explicitly transposed** BDHW→HWBD for
//!   the per-bin CGEMM and back (the Cgeam steps of Table 1).
//! * [`FftMode::FbfftScalar`] — the §5 design points, one scalar
//!   transform at a time: implicit zero-copy padding inside `fbfft_host`,
//!   output *born* in the HWBD bin-major layout (fused transpose) and
//!   clipped on the way out (fused clip), so the three TRANS stages
//!   identically vanish. Kept as the measurable baseline for the SoA
//!   rewrite below (the `fbfft_scalar` rows of `BENCH_fftconv.json`).
//! * [`FftMode::Fbfft`] — the production fbfft path: the same fused
//!   layouts, executed by the **split-complex batch-lane kernels** of
//!   [`crate::fft::soa`] (batch mapped across SIMD lanes — the CPU image
//!   of the paper's one-transform-per-warp §5 mapping). The spectra are
//!   born as *planar* re/im `f32` slabs in bin-major order and flow into
//!   [`super::cgemm::batched_planar`] untouched, so the
//!   interleaved→planar PACK stage the other modes pay also vanishes.
//!
//! The CGEMM core is planar either way; Vendor and FbfftScalar bridge
//! into it through an explicit, separately-timed PACK conversion
//! ([`StageTimings::pack_a`]/`pack_b`/`pack_c` — zero in `Fbfft` mode by
//! construction). All three passes run the blocked multithreaded
//! bin-major CGEMM with the conjugation pattern of §2 (fprop: conj W;
//! bprop: none; accGrad: conj Go, reduce S). Per-plane transforms,
//! transposes and CGEMM all fan out over [`crate::util::threads`]
//! (the SoA inverse by LANES-aligned batch groups), and every
//! intermediate tensor comes from the caller's [`Workspace`] pool — the
//! `*_into` entry points allocate nothing in steady state (the
//! `fprop`/`bprop`/`accgrad` wrappers keep the old allocating signature
//! for the tuner, the §6 tiled engine and the tests).

use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::Pass;
use crate::fft::fbfft_host;
use crate::fft::fft2d::{self, irfft2_into, rfft2_into};
use crate::fft::real::rfft_len;
use crate::fft::soa::{self, LANES};
use crate::fft::C32;
use crate::util::{chunk_ranges, chunk_ranges_grouped, threads, SimdTier};

use super::cgemm::{self, Workspace};
use super::problem::ConvProblem;
use super::spectra::{SpectrumPrecision, SpectrumSlabs, WeightSpectrum};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FftMode {
    /// cuFFT-analogue: explicit padding, planner FFTs, explicit transposes.
    Vendor,
    /// fbfft, SoA batch-lane kernels: implicit padding, fused transpose +
    /// clip, planar spectra (no PACK stage), power-of-two only.
    Fbfft,
    /// fbfft, one scalar transform at a time — the pre-SoA baseline.
    FbfftScalar,
}

/// Wall-clock per Table-1 stage (Table 5's columns), plus the PACK
/// conversions between interleaved staging and the planar CGEMM layout.
/// Stages elided by fbfft's fused layouts report zero; the SoA mode's
/// planar handoff zeroes all three PACK cells too.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimings {
    pub fft_a: Duration,
    pub trans_a: Duration,
    pub pack_a: Duration,
    pub fft_b: Duration,
    pub trans_b: Duration,
    pub pack_b: Duration,
    pub cgemm: Duration,
    pub trans_c: Duration,
    pub pack_c: Duration,
    pub ifft_c: Duration,
    /// Time attributable to transforming the **weight** operand (the
    /// B-side `fft_b + trans_b + pack_b` when B is the weight tensor —
    /// fprop and bprop; zero for accGrad, whose B is the activation).
    /// The spec-path entry points feed cached spectra instead, so this
    /// is identically zero on a weight-spectrum-cache hit — the
    /// `weight_fft_ns == 0` statement `BENCH_serve.json` gates on. An
    /// attribution alias of the B stages, not a new stage: excluded
    /// from [`StageTimings::total`].
    pub weight_fft: Duration,
    /// The SIMD dispatch tier the measured pass executed under
    /// ([`crate::util::simd::tier`] at entry) — timings from different
    /// tiers are not comparable, so every report row carries this.
    pub simd_tier: SimdTier,
}

impl StageTimings {
    pub fn total(&self) -> Duration {
        self.fft_a + self.trans_a + self.pack_a + self.fft_b + self.trans_b
            + self.pack_b + self.cgemm + self.trans_c + self.pack_c
            + self.ifft_c
    }

    /// Combined transform time (FFT A + FFT B + IFFT C) — the
    /// `fft_ns` column of `BENCH_fftconv.json`.
    pub fn fft_total(&self) -> Duration {
        self.fft_a + self.fft_b + self.ifft_c
    }

    /// Combined layout-conversion time (PACK A + PACK B + PACK C) — the
    /// `pack_ns` column; identically zero in SoA fbfft mode.
    pub fn pack_total(&self) -> Duration {
        self.pack_a + self.pack_b + self.pack_c
    }

    pub fn add(&mut self, o: &StageTimings) {
        self.fft_a += o.fft_a;
        self.trans_a += o.trans_a;
        self.pack_a += o.pack_a;
        self.fft_b += o.fft_b;
        self.trans_b += o.trans_b;
        self.pack_b += o.pack_b;
        self.cgemm += o.cgemm;
        self.trans_c += o.trans_c;
        self.pack_c += o.pack_c;
        self.ifft_c += o.ifft_c;
        self.weight_fft += o.weight_fft;
        // accumulation only ever merges same-process runs; keep the
        // higher tier if an override flipped mid-aggregate
        self.simd_tier = self.simd_tier.max(o.simd_tier);
    }
}

/// Threads for a per-plane stage (pad / FFT / IFFT / transpose): stay on
/// the caller's thread when the stage is small — the §6 tiled engine and
/// the autotuner's tiny candidates issue thousands of these calls.
fn plane_workers(count: usize, n: usize) -> usize {
    if count * n * n < 1 << 14 {
        1
    } else {
        threads().min(count)
    }
}

/// Transpose tile edge: a 32×32 `C32` tile (8 KB in + 8 KB out) keeps
/// both the gather and scatter sides L1-resident.
const TRANS_TILE: usize = 32;

/// Tile-blocked transposed copy of the `c0..c0+cn` source-column range:
/// `dst_chunk[(c-c0)·rows + r] = src[r·cols + c]`. Writes are contiguous
/// per destination row; the tiling keeps the strided reads in cache.
fn transpose_chunk(src: &[C32], rows: usize, cols: usize, c0: usize,
                   cn: usize, dst_chunk: &mut [C32]) {
    let mut ct = c0;
    while ct < c0 + cn {
        let ce = (ct + TRANS_TILE).min(c0 + cn);
        let mut rt = 0;
        while rt < rows {
            let re = (rt + TRANS_TILE).min(rows);
            for c in ct..ce {
                let drow = &mut dst_chunk[(c - c0) * rows..][..rows];
                for r in rt..re {
                    drow[r] = src[r * cols + c];
                }
            }
            rt = re;
        }
        ct = ce;
    }
}

/// `dst = srcᵀ` for a `rows × cols` row-major `src` — both Table-1 Cgeam
/// transposes (BDHW→HWBD and back) are instances of this. Tile-blocked
/// and threaded over destination-row chunks.
fn transpose(src: &[C32], rows: usize, cols: usize, dst: &mut [C32]) {
    assert_eq!(src.len(), rows * cols);
    assert_eq!(dst.len(), rows * cols);
    let nw = if rows * cols < 1 << 14 { 1 } else { threads().min(cols) };
    if nw <= 1 {
        transpose_chunk(src, rows, cols, 0, cols, dst);
        return;
    }
    thread::scope(|scope| {
        let mut rem: &mut [C32] = dst;
        for (c0, cn) in chunk_ranges(cols, nw) {
            let (head, tail) = rem.split_at_mut(cn * rows);
            rem = tail;
            scope.spawn(move || {
                transpose_chunk(src, rows, cols, c0, cn, head)
            });
        }
    });
}

/// Threaded interleaved→planar split — the PACK stage the staging modes
/// pay on the way into the planar CGEMM (and the SoA mode elides).
fn split_complex_mt(src: &[C32], re: &mut [f32], im: &mut [f32]) {
    let len = src.len();
    let nw = if len < 1 << 15 { 1 } else { threads() };
    if nw <= 1 {
        soa::split_complex(src, re, im);
        return;
    }
    thread::scope(|scope| {
        let mut re_rem: &mut [f32] = re;
        let mut im_rem: &mut [f32] = im;
        for (start, cn) in chunk_ranges(len, nw) {
            let (re_h, re_t) = re_rem.split_at_mut(cn);
            re_rem = re_t;
            let (im_h, im_t) = im_rem.split_at_mut(cn);
            im_rem = im_t;
            let s = &src[start..start + cn];
            scope.spawn(move || soa::split_complex(s, re_h, im_h));
        }
    });
}

/// Threaded planar→interleaved merge (the inverse-side PACK conversion).
fn interleave_complex_mt(re: &[f32], im: &[f32], dst: &mut [C32]) {
    let len = dst.len();
    let nw = if len < 1 << 15 { 1 } else { threads() };
    if nw <= 1 {
        soa::interleave_complex(re, im, dst);
        return;
    }
    thread::scope(|scope| {
        let mut d_rem: &mut [C32] = dst;
        for (start, cn) in chunk_ranges(len, nw) {
            let (d_h, d_t) = d_rem.split_at_mut(cn);
            d_rem = d_t;
            let r = &re[start..start + cn];
            let i = &im[start..start + cn];
            scope.spawn(move || soa::interleave_complex(r, i, d_h));
        }
    });
}

/// Copy `h_in × w_in` planes into the top-left corner of zeroed `n × n`
/// planes — the §5.1 duplicate padded tensor the vendor path must
/// materialize. `dst` covers `src.len() / (h_in·w_in)` planes, pre-zeroed.
fn pad_planes(src: &[f32], h_in: usize, w_in: usize, n: usize,
              dst: &mut [f32]) {
    let count = src.len() / (h_in * w_in);
    debug_assert_eq!(dst.len(), count * n * n);
    for b in 0..count {
        for r in 0..h_in {
            let d = (b * n + r) * n;
            let s = (b * h_in + r) * w_in;
            dst[d..d + w_in].copy_from_slice(&src[s..s + w_in]);
        }
    }
}

/// The B-side source of one [`FftConvEngine::run`] call: raw planes
/// (weights for fprop/bprop, activations for accGrad) transformed on
/// the spot, or a cached [`WeightSpectrum`] that skips the weight FFT
/// entirely (the serving tier's steady state).
pub enum BOperand<'a> {
    Planes(&'a [f32]),
    Spectrum(&'a WeightSpectrum),
}

/// Borrowed operand bundle of one [`FftConvEngine::run`] call. What
/// `a`/`b`/`out` mean is pass-typed (see [`FftConvEngine::run`]'s
/// table); lengths are asserted against `problem` at entry.
pub struct Operands<'a> {
    pub problem: &'a ConvProblem,
    /// activations (fprop) or output gradient (bprop/accGrad)
    pub a: &'a [f32],
    /// weights (fprop/bprop) or activations (accGrad)
    pub b: BOperand<'a>,
    pub out: &'a mut [f32],
}

pub struct FftConvEngine {
    pub mode: FftMode,
    pub n_fft: usize,
}

impl FftConvEngine {
    pub fn new(mode: FftMode, n_fft: usize) -> Self {
        if matches!(mode, FftMode::Fbfft | FftMode::FbfftScalar) {
            // match FbfftPlan's domain exactly so an unsupported basis
            // fails here, at construction, not mid-transform
            assert!(n_fft.is_power_of_two()
                        && (2..=fbfft_host::MAX_N).contains(&n_fft),
                    "fbfft basis must be a power of two in 2..=256, \
                     got {n_fft}");
        }
        FftConvEngine { mode, n_fft }
    }

    /// fbfft's default basis for a problem (next pow2 covering the input).
    pub fn fbfft_for(p: &ConvProblem) -> Self {
        Self::new(FftMode::Fbfft, p.h.max(p.w).next_power_of_two())
    }

    fn bins(&self) -> usize {
        rfft_len(self.n_fft) * self.n_fft
    }

    // ---- forward transforms -------------------------------------------

    /// Transform `count` planes of `h_in × w_in` into a bin-major
    /// **planar** frequency slab (re/im planes of `bins × count` each)
    /// checked out of `ws` under `role` (the caller puts it back after
    /// the CGEMM consumes it). Vendor mode pays the explicit pad +
    /// transpose + PACK split; scalar fbfft pays only the PACK split;
    /// SoA fbfft emits bin-major planar directly.
    #[allow(clippy::too_many_arguments)]
    fn forward(&self, planes: &[f32], h_in: usize, w_in: usize,
               count: usize, role: &str, ws: &mut Workspace,
               fft_t: &mut Duration, trans_t: &mut Duration,
               pack_t: &mut Duration) -> (Vec<f32>, Vec<f32>) {
        let n = self.n_fft;
        let nf = rfft_len(n);
        let bins = self.bins();
        let (mut re, mut im) = ws.pool.take_planar_raw(role, bins * count);
        let nw = plane_workers(count, n);
        match self.mode {
            FftMode::Fbfft => {
                let t0 = Instant::now();
                let plan = fbfft_host::cached(n);
                // scratch roles are distinct per operand (A vs B counts
                // differ) and per direction (forward vs inverse sizes
                // differ): take_planar_raw zero-fills regrowth, so a
                // shared role would re-memset the size gap every pass
                let (rows_role, work_role) = if role == "freq.a" {
                    ("soa.rows.a", "soa.fwork.a")
                } else {
                    ("soa.rows.b", "soa.fwork.b")
                };
                let (mut rows_re, mut rows_im) =
                    ws.pool.take_planar_raw(rows_role, count * n * nf);
                // phase 1: batched row-pair transforms (§5.2 pack across
                // image rows, all `count` planes in lanes), chunked over
                // row pairs — each chunk's rows block is contiguous
                let pairs = n / 2;
                let nw1 = nw.min(pairs);
                let (mut work_re, mut work_im) =
                    ws.pool.take_planar_raw(work_role, nw1 * n * count);
                thread::scope(|scope| {
                    let mut rr: &mut [f32] = &mut rows_re;
                    let mut ri: &mut [f32] = &mut rows_im;
                    let mut wr_rem: &mut [f32] = &mut work_re;
                    let mut wi_rem: &mut [f32] = &mut work_im;
                    for (rp0, rpn) in chunk_ranges(pairs, nw1) {
                        let (rr_h, rr_t) =
                            rr.split_at_mut(2 * rpn * nf * count);
                        rr = rr_t;
                        let (ri_h, ri_t) =
                            ri.split_at_mut(2 * rpn * nf * count);
                        ri = ri_t;
                        let (wr_h, wr_t) = wr_rem.split_at_mut(n * count);
                        wr_rem = wr_t;
                        let (wi_h, wi_t) = wi_rem.split_at_mut(n * count);
                        wi_rem = wi_t;
                        let plan = &plan;
                        let worker = move || {
                            plan.rfft2_rows_soa(planes, h_in, w_in, count,
                                                rp0, rpn, rr_h, ri_h,
                                                wr_h, wi_h)
                        };
                        if nw1 <= 1 {
                            // below the fan-out threshold: run inline
                            let mut run_now = worker;
                            run_now();
                        } else {
                            scope.spawn(worker);
                        }
                    }
                });
                // phase 2: batched column transforms, chunked over kw —
                // contiguous in the fused-transposed planar output
                let nw2 = if nw <= 1 { 1 } else { threads().min(nf) };
                thread::scope(|scope| {
                    let mut or: &mut [f32] = &mut re;
                    let mut oi: &mut [f32] = &mut im;
                    let rows_re = &rows_re;
                    let rows_im = &rows_im;
                    for (kw0, kwn) in chunk_ranges(nf, nw2) {
                        let (or_h, or_t) =
                            or.split_at_mut(kwn * n * count);
                        or = or_t;
                        let (oi_h, oi_t) =
                            oi.split_at_mut(kwn * n * count);
                        oi = oi_t;
                        let plan = &plan;
                        let worker = move || {
                            plan.rfft2_cols_soa(rows_re, rows_im, count,
                                                kw0, kwn, or_h, oi_h)
                        };
                        if nw2 <= 1 {
                            let mut run_now = worker;
                            run_now();
                        } else {
                            scope.spawn(worker);
                        }
                    }
                });
                ws.pool.put_planar(rows_role, (rows_re, rows_im));
                ws.pool.put_planar(work_role, (work_re, work_im));
                *fft_t += t0.elapsed();
                // fused transpose + planar birth: no TRANS, no PACK
            }
            FftMode::FbfftScalar => {
                let t0 = Instant::now();
                let plan = fbfft_host::cached(n);
                let mut data = ws.pool.take_c32_raw(role, bins * count);
                let mut rows_all =
                    ws.pool.take_c32_raw("fbfft.rows", count * n * nf);
                if nw <= 1 {
                    plan.rfft2_rows(planes, h_in, w_in, count,
                                    &mut rows_all);
                    plan.rfft2_cols_transposed(&rows_all, count, 0, nf,
                                               &mut data);
                } else {
                    // pass 1: row transforms, image chunks
                    let in_stride = h_in * w_in;
                    thread::scope(|scope| {
                        let mut rem: &mut [C32] = &mut rows_all;
                        for (start, len) in chunk_ranges(count, nw) {
                            let (head, tail) =
                                rem.split_at_mut(len * n * nf);
                            rem = tail;
                            let src = &planes[start * in_stride
                                ..(start + len) * in_stride];
                            let plan = &plan;
                            scope.spawn(move || {
                                plan.rfft2_rows(src, h_in, w_in, len, head)
                            });
                        }
                    });
                    // pass 2: column transforms, kw chunks (contiguous
                    // in the fused-transposed output)
                    let nw2 = threads().min(nf);
                    thread::scope(|scope| {
                        let mut rem: &mut [C32] = &mut data;
                        let rows_all = &rows_all;
                        for (kw0, kwn) in chunk_ranges(nf, nw2) {
                            let (head, tail) =
                                rem.split_at_mut(kwn * n * count);
                            rem = tail;
                            let plan = &plan;
                            scope.spawn(move || {
                                plan.rfft2_cols_transposed(
                                    rows_all, count, kw0, kwn, head)
                            });
                        }
                    });
                }
                ws.pool.put_c32("fbfft.rows", rows_all);
                *fft_t += t0.elapsed();
                // fused transpose: TRANS does not exist — but the scalar
                // path's interleaved spectrum must still be split for
                // the planar CGEMM (the PACK the SoA path elides)
                let t1 = Instant::now();
                split_complex_mt(&data, &mut re, &mut im);
                *pack_t += t1.elapsed();
                ws.pool.put_c32(role, data);
            }
            FftMode::Vendor => {
                let t0 = Instant::now();
                let mut data = ws.pool.take_c32_raw(role, bins * count);
                // the duplicate padded tensor cuFFT forces (§5.1)
                let mut padded = ws.pool.take("vendor.pad", count * n * n);
                let in_stride = h_in * w_in;
                if nw <= 1 {
                    pad_planes(planes, h_in, w_in, n, &mut padded);
                } else {
                    thread::scope(|scope| {
                        let mut rem: &mut [f32] = &mut padded;
                        for (start, len) in chunk_ranges(count, nw) {
                            let (head, tail) = rem.split_at_mut(len * n * n);
                            rem = tail;
                            let src = &planes[start * in_stride
                                ..(start + len) * in_stride];
                            scope.spawn(move || {
                                pad_planes(src, h_in, w_in, n, head)
                            });
                        }
                    });
                }
                // plane-major transforms (BDHW frequency layout), one
                // planner scratch region per worker
                let mut pm = ws.pool.take_c32_raw("vendor.pm", count * bins);
                let sl = fft2d::scratch_len(n);
                let mut scratch =
                    ws.pool.take_c32_raw("vendor.fft_scratch", nw * sl);
                if nw <= 1 {
                    let sc = &mut scratch[..sl];
                    for b in 0..count {
                        rfft2_into(&padded[b * n * n..(b + 1) * n * n],
                                   n, n, n,
                                   &mut pm[b * bins..(b + 1) * bins], sc);
                    }
                } else {
                    thread::scope(|scope| {
                        let mut pm_rem: &mut [C32] = &mut pm;
                        let mut sc_rem: &mut [C32] = &mut scratch;
                        let padded: &[f32] = &padded;
                        for (start, len) in chunk_ranges(count, nw) {
                            let (pm_head, pm_tail) =
                                pm_rem.split_at_mut(len * bins);
                            pm_rem = pm_tail;
                            let (sc_head, sc_tail) =
                                sc_rem.split_at_mut(sl);
                            sc_rem = sc_tail;
                            scope.spawn(move || {
                                for bi in 0..len {
                                    let b = start + bi;
                                    rfft2_into(
                                        &padded[b * n * n..(b + 1) * n * n],
                                        n, n, n,
                                        &mut pm_head[bi * bins
                                            ..(bi + 1) * bins],
                                        sc_head);
                                }
                            });
                        }
                    });
                }
                ws.pool.put("vendor.pad", padded);
                ws.pool.put_c32("vendor.fft_scratch", scratch);
                *fft_t += t0.elapsed();
                // explicit BDHW → HWBD transposition (the Cgeam step)
                let t1 = Instant::now();
                transpose(&pm, count, bins, &mut data);
                *trans_t += t1.elapsed();
                ws.pool.put_c32("vendor.pm", pm);
                // PACK: split for the planar CGEMM
                let t2 = Instant::now();
                split_complex_mt(&data, &mut re, &mut im);
                *pack_t += t2.elapsed();
                ws.pool.put_c32(role, data);
            }
        }
        (re, im)
    }

    /// Inverse-transform a planar bin-major frequency slab of `count`
    /// planes, clipping each to `clip_h × clip_w`, into `out`.
    #[allow(clippy::too_many_arguments)]
    fn inverse(&self, freq_re: &[f32], freq_im: &[f32], count: usize,
               clip_h: usize, clip_w: usize, out: &mut [f32],
               ws: &mut Workspace, trans_t: &mut Duration,
               ifft_t: &mut Duration, pack_t: &mut Duration) {
        let n = self.n_fft;
        let nf = rfft_len(n);
        let bins = self.bins();
        assert_eq!(freq_re.len(), bins * count);
        assert_eq!(freq_im.len(), bins * count);
        assert_eq!(out.len(), count * clip_h * clip_w);
        let nw = plane_workers(count, n);
        let clip = clip_h * clip_w;
        match self.mode {
            FftMode::Fbfft => {
                // SoA inverse straight off the planar product — no PACK,
                // threaded over LANES-aligned batch groups with
                // per-group scratch carved out of two pooled planes
                let t0 = Instant::now();
                let plan = fbfft_host::cached(n);
                let (mut rows_re, mut rows_im) = ws.pool.take_planar_raw(
                    "soa.irows", clip_h * nf * count);
                let (mut work_re, mut work_im) =
                    ws.pool.take_planar_raw("soa.iwork", n * count);
                thread::scope(|scope| {
                    let mut o_rem: &mut [f32] = out;
                    let mut rr_rem: &mut [f32] = &mut rows_re;
                    let mut ri_rem: &mut [f32] = &mut rows_im;
                    let mut wr_rem: &mut [f32] = &mut work_re;
                    let mut wi_rem: &mut [f32] = &mut work_im;
                    for (b0, bn) in chunk_ranges_grouped(count, nw, LANES) {
                        let (o_h, o_t) = o_rem.split_at_mut(bn * clip);
                        o_rem = o_t;
                        let (rr_h, rr_t) =
                            rr_rem.split_at_mut(clip_h * nf * bn);
                        rr_rem = rr_t;
                        let (ri_h, ri_t) =
                            ri_rem.split_at_mut(clip_h * nf * bn);
                        ri_rem = ri_t;
                        let (wr_h, wr_t) = wr_rem.split_at_mut(n * bn);
                        wr_rem = wr_t;
                        let (wi_h, wi_t) = wi_rem.split_at_mut(n * bn);
                        wi_rem = wi_t;
                        let plan = &plan;
                        let worker = move || {
                            plan.irfft2_soa_chunk(freq_re, freq_im, count,
                                                  b0, bn, clip_h, clip_w,
                                                  rr_h, ri_h, wr_h, wi_h,
                                                  o_h)
                        };
                        if nw <= 1 {
                            let mut run_now = worker;
                            run_now();
                        } else {
                            scope.spawn(worker);
                        }
                    }
                });
                ws.pool.put_planar("soa.irows", (rows_re, rows_im));
                ws.pool.put_planar("soa.iwork", (work_re, work_im));
                *ifft_t += t0.elapsed();
            }
            FftMode::FbfftScalar => {
                // PACK: merge the planar product back to interleaved for
                // the scalar inverse path
                let t0 = Instant::now();
                let mut stage =
                    ws.pool.take_c32_raw("stage.inv", bins * count);
                interleave_complex_mt(freq_re, freq_im, &mut stage);
                *pack_t += t0.elapsed();
                let t1 = Instant::now();
                let plan = fbfft_host::cached(n);
                let mut rows =
                    ws.pool.take_c32_raw("fbfft.irows", nw * n * nf);
                if nw <= 1 {
                    let rs = &mut rows[..n * nf];
                    for b in 0..count {
                        plan.irfft2_one_transposed(
                            &stage, count, b, clip_h, clip_w, rs,
                            &mut out[b * clip..(b + 1) * clip]);
                    }
                } else {
                    thread::scope(|scope| {
                        let mut o_rem: &mut [f32] = out;
                        let mut r_rem: &mut [C32] = &mut rows;
                        let stage: &[C32] = &stage;
                        for (start, len) in chunk_ranges(count, nw) {
                            let (o_head, o_tail) =
                                o_rem.split_at_mut(len * clip);
                            o_rem = o_tail;
                            let (r_head, r_tail) =
                                r_rem.split_at_mut(n * nf);
                            r_rem = r_tail;
                            let plan = &plan;
                            scope.spawn(move || {
                                for bi in 0..len {
                                    plan.irfft2_one_transposed(
                                        stage, count, start + bi, clip_h,
                                        clip_w, &mut r_head[..],
                                        &mut o_head[bi * clip
                                            ..(bi + 1) * clip]);
                                }
                            });
                        }
                    });
                }
                ws.pool.put_c32("fbfft.irows", rows);
                ws.pool.put_c32("stage.inv", stage);
                *ifft_t += t1.elapsed();
            }
            FftMode::Vendor => {
                // PACK: interleave, then the explicit HWBD → BDHW
                // transposition (tile-blocked, writes contiguous)
                let t0 = Instant::now();
                let mut stage =
                    ws.pool.take_c32_raw("stage.inv", bins * count);
                interleave_complex_mt(freq_re, freq_im, &mut stage);
                *pack_t += t0.elapsed();
                let t1 = Instant::now();
                let mut pm = ws.pool.take_c32_raw("vendor.ipm", count * bins);
                transpose(&stage, bins, count, &mut pm);
                *trans_t += t1.elapsed();
                ws.pool.put_c32("stage.inv", stage);
                let t2 = Instant::now();
                let sl = fft2d::scratch_len(n);
                let mut scratch =
                    ws.pool.take_c32_raw("vendor.fft_scratch", nw * sl);
                if nw <= 1 {
                    let sc = &mut scratch[..sl];
                    for b in 0..count {
                        // vendor bins are (kh, kw) row-major — exactly
                        // the layout irfft2 consumes (rfft2 made them)
                        irfft2_into(&pm[b * bins..(b + 1) * bins], n,
                                    clip_h, clip_w,
                                    &mut out[b * clip..(b + 1) * clip],
                                    sc);
                    }
                } else {
                    thread::scope(|scope| {
                        let mut o_rem: &mut [f32] = out;
                        let mut sc_rem: &mut [C32] = &mut scratch;
                        let pm: &[C32] = &pm;
                        for (start, len) in chunk_ranges(count, nw) {
                            let (o_head, o_tail) =
                                o_rem.split_at_mut(len * clip);
                            o_rem = o_tail;
                            let (sc_head, sc_tail) =
                                sc_rem.split_at_mut(sl);
                            sc_rem = sc_tail;
                            scope.spawn(move || {
                                for bi in 0..len {
                                    let b = start + bi;
                                    irfft2_into(
                                        &pm[b * bins..(b + 1) * bins],
                                        n, clip_h, clip_w,
                                        &mut o_head[bi * clip
                                            ..(bi + 1) * clip],
                                        sc_head);
                                }
                            });
                        }
                    });
                }
                *ifft_t += t2.elapsed();
                ws.pool.put_c32("vendor.ipm", pm);
                ws.pool.put_c32("vendor.fft_scratch", scratch);
            }
        }
    }

    // ---- the unified pass surface --------------------------------------

    /// One pipeline for every (pass, B-source) combination — the body
    /// the six historical entry points collapsed into. Geometry is
    /// pass-typed:
    ///
    /// | pass    | A operand       | B operand        | out clips to |
    /// |---------|-----------------|------------------|--------------|
    /// | fprop   | x (h×w)         | weights (kh×kw)  | yh × yw      |
    /// | bprop   | go (yh×yw)      | weights (kh×kw)  | h × w        |
    /// | accGrad | go (yh×yw)      | x (h×w)          | kh × kw      |
    ///
    /// The B side is either raw planes (transformed in place, timed as
    /// the B stages) or a cached [`WeightSpectrum`]
    /// ([`BOperand::Spectrum`], fprop/bprop only — accGrad's B is the
    /// activation, which is never cached), in which case the B stages
    /// and therefore [`StageTimings::weight_fft`] are identically zero.
    /// Steady-state zero-allocation: every intermediate comes from the
    /// caller's [`Workspace`] pool.
    pub fn run(&self, pass: Pass, ops: Operands<'_>, ws: &mut Workspace)
               -> StageTimings {
        let p = ops.problem;
        assert_eq!(p.stride, 1, "strided FFT conv out of scope (paper §2)");
        let (a_h, a_w, a_count, a_len) = match pass {
            Pass::Fprop => (p.h, p.w, p.s * p.f, p.input_len()),
            Pass::Bprop | Pass::AccGrad => {
                (p.yh(), p.yw(), p.s * p.fo, p.output_len())
            }
        };
        let (c_count, clip_h, clip_w, out_len) = match pass {
            Pass::Fprop => (p.s * p.fo, p.yh(), p.yw(), p.output_len()),
            Pass::Bprop => (p.s * p.f, p.h, p.w, p.input_len()),
            Pass::AccGrad => (p.fo * p.f, p.kh, p.kw, p.weight_len()),
        };
        assert_eq!(ops.a.len(), a_len);
        assert_eq!(ops.out.len(), out_len);
        let mut t = StageTimings {
            simd_tier: crate::util::simd::tier(),
            ..StageTimings::default()
        };
        let (ar, ai) = self.forward(ops.a, a_h, a_w, a_count, "freq.a",
                                    ws, &mut t.fft_a, &mut t.trans_a,
                                    &mut t.pack_a);
        let bins = self.bins();
        let (or, oi) = match ops.b {
            BOperand::Planes(b) => {
                let (b_h, b_w, b_count, b_len) = match pass {
                    Pass::Fprop | Pass::Bprop => {
                        (p.kh, p.kw, p.fo * p.f, p.weight_len())
                    }
                    Pass::AccGrad => (p.h, p.w, p.s * p.f, p.input_len()),
                };
                assert_eq!(b.len(), b_len);
                let (br, bi) = self.forward(b, b_h, b_w, b_count,
                                            "freq.b", ws, &mut t.fft_b,
                                            &mut t.trans_b, &mut t.pack_b);
                let t0 = Instant::now();
                let (mut or, mut oi) =
                    ws.pool.take_planar_raw("freq.c", bins * c_count);
                cgemm::batched_planar(pass, bins, p.s, p.f, p.fo, &ar,
                                      &ai, &br, &bi, &mut or, &mut oi,
                                      ws);
                t.cgemm += t0.elapsed();
                ws.pool.put_planar("freq.b", (br, bi));
                (or, oi)
            }
            BOperand::Spectrum(spec) => {
                assert!(!matches!(pass, Pass::AccGrad),
                        "accGrad's B operand is the activation — \
                         no cached spectrum applies");
                self.check_spec(p, spec);
                let t0 = Instant::now();
                let (mut or, mut oi) =
                    ws.pool.take_planar_raw("freq.c", bins * c_count);
                self.spec_cgemm(pass, p, &ar, &ai, spec, &mut or,
                                &mut oi, ws);
                t.cgemm += t0.elapsed();
                (or, oi)
            }
        };
        ws.pool.put_planar("freq.a", (ar, ai));
        self.inverse(&or, &oi, c_count, clip_h, clip_w, ops.out, ws,
                     &mut t.trans_c, &mut t.ifft_c, &mut t.pack_c);
        ws.pool.put_planar("freq.c", (or, oi));
        if !matches!(pass, Pass::AccGrad) {
            // B is the weight tensor for fprop/bprop — attribute it
            // (zero by construction on the spectrum path)
            t.weight_fft = t.fft_b + t.trans_b + t.pack_b;
        }
        t
    }

    // ---- historical entry points (thin wrappers over `run`) ------------

    /// fprop: `Out_q = In_q · conj(W_q)ᵀ` per bin, clip to (yh, yw).
    /// Steady-state zero-allocation entry point; `out` must be
    /// `p.output_len()` long.
    #[inline]
    pub fn fprop_into(&self, p: &ConvProblem, x: &[f32], wei: &[f32],
                      out: &mut [f32], ws: &mut Workspace)
                      -> StageTimings {
        self.run(Pass::Fprop,
                 Operands { problem: p, a: x,
                            b: BOperand::Planes(wei), out },
                 ws)
    }

    /// bprop: `Gx_q = Go_q · W_q` per bin (no conjugation), clip (h, w).
    #[inline]
    pub fn bprop_into(&self, p: &ConvProblem, go: &[f32], wei: &[f32],
                      out: &mut [f32], ws: &mut Workspace)
                      -> StageTimings {
        self.run(Pass::Bprop,
                 Operands { problem: p, a: go,
                            b: BOperand::Planes(wei), out },
                 ws)
    }

    /// accGrad: `Gw_q = conj(Go_q)ᵀ · X_q` per bin (minibatch reduced),
    /// clip (kh, kw).
    #[inline]
    pub fn accgrad_into(&self, p: &ConvProblem, go: &[f32], x: &[f32],
                        out: &mut [f32], ws: &mut Workspace)
                        -> StageTimings {
        self.run(Pass::AccGrad,
                 Operands { problem: p, a: go,
                            b: BOperand::Planes(x), out },
                 ws)
    }

    // ---- cached-weight-spectrum (spec) entry points --------------------

    /// Transform a weight tensor into an owned [`WeightSpectrum`] —
    /// the miss path of the serving tier's spectrum cache. Identical
    /// transform to the `"freq.b"` forward of [`fprop_into`] /
    /// [`bprop_into`] (both passes share it), copied out of the pooled
    /// slab into owned storage at the requested precision.
    ///
    /// [`fprop_into`]: FftConvEngine::fprop_into
    /// [`bprop_into`]: FftConvEngine::bprop_into
    pub fn weight_spectrum(&self, p: &ConvProblem, wei: &[f32],
                           version: u64, precision: SpectrumPrecision,
                           ws: &mut Workspace) -> WeightSpectrum {
        assert_eq!(wei.len(), p.weight_len());
        let mut sink = Duration::ZERO;
        let (wr, wi) = self.forward(wei, p.kh, p.kw, p.fo * p.f, "freq.b",
                                    ws, &mut sink, &mut sink, &mut sink);
        let slabs = match precision {
            SpectrumPrecision::F32 => {
                SpectrumSlabs::F32 { re: wr.clone(), im: wi.clone() }
            }
            SpectrumPrecision::F16 => SpectrumSlabs::F16 {
                re: crate::util::f16::encode_slab(&wr),
                im: crate::util::f16::encode_slab(&wi),
            },
        };
        ws.pool.put_planar("freq.b", (wr, wi));
        WeightSpectrum { n_fft: self.n_fft, mode: self.mode,
                         count: p.fo * p.f, version, slabs }
    }

    /// [`fprop_into`](FftConvEngine::fprop_into) against a cached weight
    /// spectrum: the weight pad+FFT stages are skipped entirely, so
    /// `fft_b`/`trans_b`/`pack_b` — and therefore `weight_fft` — are
    /// identically zero. With an f32 spectrum the output is bitwise
    /// identical to the uncached pass; with f16 it stays inside the
    /// testkit's `frequency_f16` tolerance.
    #[inline]
    pub fn fprop_spec_into(&self, p: &ConvProblem, x: &[f32],
                           spec: &WeightSpectrum, out: &mut [f32],
                           ws: &mut Workspace) -> StageTimings {
        self.run(Pass::Fprop,
                 Operands { problem: p, a: x,
                            b: BOperand::Spectrum(spec), out },
                 ws)
    }

    /// [`bprop_into`](FftConvEngine::bprop_into) against a cached weight
    /// spectrum — the same spectrum
    /// [`fprop_spec_into`](FftConvEngine::fprop_spec_into) consumes,
    /// since both passes
    /// transform the weights identically (§2: the conjugation patterns
    /// differ only inside the CGEMM).
    #[inline]
    pub fn bprop_spec_into(&self, p: &ConvProblem, go: &[f32],
                           spec: &WeightSpectrum, out: &mut [f32],
                           ws: &mut Workspace) -> StageTimings {
        self.run(Pass::Bprop,
                 Operands { problem: p, a: go,
                            b: BOperand::Spectrum(spec), out },
                 ws)
    }

    fn check_spec(&self, p: &ConvProblem, spec: &WeightSpectrum) {
        assert_eq!(spec.mode, self.mode, "spectrum mode mismatch");
        assert_eq!(spec.n_fft, self.n_fft, "spectrum basis mismatch");
        assert_eq!(spec.count, p.fo * p.f, "spectrum plane count");
        assert_eq!(spec.len(), self.bins() * p.fo * p.f,
                   "spectrum slab length");
    }

    /// Dispatch the planar CGEMM over a cached spectrum's storage: f32
    /// slabs run the exact planar path, f16 slabs the lane-dequantizing
    /// one.
    #[allow(clippy::too_many_arguments)]
    fn spec_cgemm(&self, pass: Pass, p: &ConvProblem, a_re: &[f32],
                  a_im: &[f32], spec: &WeightSpectrum, c_re: &mut [f32],
                  c_im: &mut [f32], ws: &mut Workspace) {
        let bins = self.bins();
        match &spec.slabs {
            SpectrumSlabs::F32 { re, im } => {
                cgemm::batched_planar(pass, bins, p.s, p.f, p.fo, a_re,
                                      a_im, re, im, c_re, c_im, ws);
            }
            SpectrumSlabs::F16 { re, im } => {
                cgemm::batched_planar_f16b(pass, bins, p.s, p.f, p.fo,
                                           a_re, a_im, re, im, c_re,
                                           c_im, ws);
            }
        }
    }

    /// [`FftConvEngine::fprop_into`] with a one-shot workspace and owned
    /// output (the tuner / tiled / test-matrix convenience signature).
    pub fn fprop(&self, p: &ConvProblem, x: &[f32], wei: &[f32])
                 -> (Vec<f32>, StageTimings) {
        let mut ws = Workspace::new();
        let mut out = vec![0f32; p.output_len()];
        let t = self.fprop_into(p, x, wei, &mut out, &mut ws);
        (out, t)
    }

    /// [`FftConvEngine::bprop_into`] with a one-shot workspace.
    pub fn bprop(&self, p: &ConvProblem, go: &[f32], wei: &[f32])
                 -> (Vec<f32>, StageTimings) {
        let mut ws = Workspace::new();
        let mut out = vec![0f32; p.input_len()];
        let t = self.bprop_into(p, go, wei, &mut out, &mut ws);
        (out, t)
    }

    /// [`FftConvEngine::accgrad_into`] with a one-shot workspace.
    pub fn accgrad(&self, p: &ConvProblem, go: &[f32], x: &[f32])
                   -> (Vec<f32>, StageTimings) {
        let mut ws = Workspace::new();
        let mut out = vec![0f32; p.weight_len()];
        let t = self.accgrad_into(p, go, x, &mut out, &mut ws);
        (out, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{assert_close, assert_close_oracle, oracle,
                         tolerance};
    use crate::util::Rng;

    fn problems() -> Vec<ConvProblem> {
        vec![
            ConvProblem::square(2, 3, 4, 9, 3),
            ConvProblem::new(1, 2, 2, 13, 11, 5, 3),
            ConvProblem::square(3, 1, 1, 8, 8),
        ]
    }

    #[test]
    fn fbfft_fprop_matches_oracle() {
        let mut rng = Rng::new(20);
        for p in problems() {
            let eng = FftConvEngine::fbfft_for(&p);
            let x = rng.normal_vec(p.input_len());
            let wei = rng.normal_vec(p.weight_len());
            let (got, timings) = eng.fprop(&p, &x, &wei);
            assert_close_oracle(
                &got, &oracle::fprop64(&p, &x, &wei),
                tolerance::frequency(&p, Pass::Fprop, eng.n_fft));
            // fbfft elides every TRANS stage, and the SoA planar handoff
            // elides every PACK stage too
            assert_eq!(timings.trans_a, Duration::ZERO);
            assert_eq!(timings.trans_b, Duration::ZERO);
            assert_eq!(timings.trans_c, Duration::ZERO);
            assert_eq!(timings.pack_total(), Duration::ZERO);
        }
    }

    #[test]
    fn fbfft_scalar_fprop_matches_oracle() {
        let mut rng = Rng::new(27);
        for p in problems() {
            let n = p.h.max(p.w).next_power_of_two();
            let eng = FftConvEngine::new(FftMode::FbfftScalar, n);
            let x = rng.normal_vec(p.input_len());
            let wei = rng.normal_vec(p.weight_len());
            let (got, timings) = eng.fprop(&p, &x, &wei);
            assert_close_oracle(&got, &oracle::fprop64(&p, &x, &wei),
                                tolerance::frequency(&p, Pass::Fprop, n));
            // scalar fbfft still fuses the transposes away
            assert_eq!(timings.trans_a, Duration::ZERO);
            assert_eq!(timings.trans_c, Duration::ZERO);
        }
    }

    #[test]
    fn vendor_fprop_matches_oracle_pow2_and_smooth() {
        let mut rng = Rng::new(21);
        let p = ConvProblem::square(2, 2, 3, 9, 3);
        for n in [16usize, 12, 10] {
            // vendor path supports arbitrary smooth bases >= h
            let eng = FftConvEngine::new(FftMode::Vendor, n);
            let x = rng.normal_vec(p.input_len());
            let wei = rng.normal_vec(p.weight_len());
            let (got, _) = eng.fprop(&p, &x, &wei);
            assert_close_oracle(&got, &oracle::fprop64(&p, &x, &wei),
                                tolerance::frequency(&p, Pass::Fprop, n));
        }
    }

    #[test]
    fn all_modes_bprop_match_oracle() {
        let mut rng = Rng::new(22);
        for p in problems() {
            let go = rng.normal_vec(p.output_len());
            let wei = rng.normal_vec(p.weight_len());
            let want = oracle::bprop64(&p, &go, &wei);
            let n = p.h.max(p.w).next_power_of_two();
            for mode in [FftMode::Fbfft, FftMode::FbfftScalar,
                         FftMode::Vendor] {
                let (a, _) = FftConvEngine::new(mode, n).bprop(&p, &go, &wei);
                assert_close_oracle(
                    &a, &want, tolerance::frequency(&p, Pass::Bprop, n));
            }
        }
    }

    #[test]
    fn all_modes_accgrad_match_oracle() {
        let mut rng = Rng::new(23);
        for p in problems() {
            let go = rng.normal_vec(p.output_len());
            let x = rng.normal_vec(p.input_len());
            let want = oracle::accgrad64(&p, &go, &x);
            let n = p.h.max(p.w).next_power_of_two();
            for mode in [FftMode::Fbfft, FftMode::FbfftScalar,
                         FftMode::Vendor] {
                let (a, _) = FftConvEngine::new(mode, n).accgrad(&p, &go, &x);
                assert_close_oracle(
                    &a, &want, tolerance::frequency(&p, Pass::AccGrad, n));
            }
        }
    }

    #[test]
    fn oversized_basis_equivalent() {
        let p = ConvProblem::square(1, 2, 2, 9, 3);
        let mut rng = Rng::new(24);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let (a, _) = FftConvEngine::new(FftMode::Fbfft, 16).fprop(&p, &x, &wei);
        let (b, _) = FftConvEngine::new(FftMode::Fbfft, 32).fprop(&p, &x, &wei);
        assert_close(&a, &b,
                     2.0 * tolerance::frequency(&p, Pass::Fprop, 32));
    }

    #[test]
    fn soa_and_scalar_fbfft_agree_closely() {
        // same transforms up to §5.2 pairing order — the two fbfft paths
        // must agree much tighter than either's oracle budget
        let p = ConvProblem::square(3, 4, 5, 12, 3);
        let mut rng = Rng::new(28);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let (a, _) = FftConvEngine::new(FftMode::Fbfft, 16)
            .fprop(&p, &x, &wei);
        let (b, _) = FftConvEngine::new(FftMode::FbfftScalar, 16)
            .fprop(&p, &x, &wei);
        assert_close(&a, &b, tolerance::frequency(&p, Pass::Fprop, 16));
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn fbfft_rejects_non_pow2_basis() {
        FftConvEngine::new(FftMode::Fbfft, 12);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn fbfft_scalar_rejects_non_pow2_basis() {
        FftConvEngine::new(FftMode::FbfftScalar, 12);
    }

    #[test]
    fn reused_workspace_reproduces_fresh_results_bitwise() {
        // dirty pooled buffers must never leak into a later pass — run
        // all three passes twice through one workspace and compare with
        // fresh-workspace runs
        let p = ConvProblem::square(2, 3, 2, 12, 3);
        let mut rng = Rng::new(25);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let go = rng.normal_vec(p.output_len());
        for mode in [FftMode::Fbfft, FftMode::FbfftScalar, FftMode::Vendor] {
            let eng = FftConvEngine::new(mode, 16);
            let mut ws = Workspace::new();
            let mut y = vec![0f32; p.output_len()];
            let mut gx = vec![0f32; p.input_len()];
            let mut gw = vec![0f32; p.weight_len()];
            for round in 0..2 {
                eng.fprop_into(&p, &x, &wei, &mut y, &mut ws);
                eng.bprop_into(&p, &go, &wei, &mut gx, &mut ws);
                eng.accgrad_into(&p, &go, &x, &mut gw, &mut ws);
                let (fy, _) = eng.fprop(&p, &x, &wei);
                let (fgx, _) = eng.bprop(&p, &go, &wei);
                let (fgw, _) = eng.accgrad(&p, &go, &x);
                assert_eq!(y, fy, "{mode:?} fprop round {round}");
                assert_eq!(gx, fgx, "{mode:?} bprop round {round}");
                assert_eq!(gw, fgw, "{mode:?} accgrad round {round}");
            }
        }
    }

    #[test]
    fn weight_fft_attributes_the_b_stages() {
        let p = ConvProblem::square(2, 3, 4, 9, 3);
        let mut rng = Rng::new(0x30);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let go = rng.normal_vec(p.output_len());
        for mode in [FftMode::Fbfft, FftMode::FbfftScalar, FftMode::Vendor] {
            let eng = FftConvEngine::new(mode, 16);
            let (_, tf) = eng.fprop(&p, &x, &wei);
            assert_eq!(tf.weight_fft, tf.fft_b + tf.trans_b + tf.pack_b,
                       "{mode:?} fprop weight_fft aliases the B stages");
            assert!(tf.weight_fft > Duration::ZERO);
            let (_, tb) = eng.bprop(&p, &go, &wei);
            assert_eq!(tb.weight_fft, tb.fft_b + tb.trans_b + tb.pack_b);
            // accGrad's B operand is the activation, never cached
            let (_, ta) = eng.accgrad(&p, &go, &x);
            assert_eq!(ta.weight_fft, Duration::ZERO);
        }
    }

    #[test]
    fn spec_path_f32_is_bitwise_the_uncached_pass() {
        // same forward, same CGEMM, same inverse — an f32 spectrum must
        // reproduce fprop_into/bprop_into exactly, with zero B-side time
        let p = ConvProblem::square(2, 3, 4, 9, 3);
        let mut rng = Rng::new(0x31);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let go = rng.normal_vec(p.output_len());
        for mode in [FftMode::Fbfft, FftMode::FbfftScalar, FftMode::Vendor] {
            let eng = FftConvEngine::new(mode, 16);
            let mut ws = Workspace::new();
            let spec = eng.weight_spectrum(&p, &wei, 7,
                                           SpectrumPrecision::F32,
                                           &mut ws);
            let mut y = vec![0f32; p.output_len()];
            let t = eng.fprop_spec_into(&p, &x, &spec, &mut y, &mut ws);
            let (want, _) = eng.fprop(&p, &x, &wei);
            assert_eq!(y, want, "{mode:?} fprop spec path");
            assert_eq!(t.fft_b + t.trans_b + t.pack_b, Duration::ZERO,
                       "{mode:?}: cached spectrum skips the weight FFT");
            assert_eq!(t.weight_fft, Duration::ZERO);
            let mut gx = vec![0f32; p.input_len()];
            eng.bprop_spec_into(&p, &go, &spec, &mut gx, &mut ws);
            let (gwant, _) = eng.bprop(&p, &go, &wei);
            assert_eq!(gx, gwant, "{mode:?} bprop shares the spectrum");
        }
    }

    #[test]
    fn spec_path_f16_stays_inside_the_oracle_budget() {
        let mut rng = Rng::new(0x32);
        for p in problems() {
            let eng = FftConvEngine::fbfft_for(&p);
            let x = rng.normal_vec(p.input_len());
            let wei = rng.normal_vec(p.weight_len());
            let mut ws = Workspace::new();
            let spec = eng.weight_spectrum(&p, &wei, 1,
                                           SpectrumPrecision::F16,
                                           &mut ws);
            let mut y = vec![0f32; p.output_len()];
            eng.fprop_spec_into(&p, &x, &spec, &mut y, &mut ws);
            assert_close_oracle(
                &y, &oracle::fprop64(&p, &x, &wei),
                tolerance::frequency_f16(&p, Pass::Fprop, eng.n_fft));
        }
    }

    #[test]
    #[should_panic(expected = "basis mismatch")]
    fn spec_path_rejects_wrong_basis() {
        let p = ConvProblem::square(1, 2, 2, 8, 3);
        let mut rng = Rng::new(0x33);
        let wei = rng.normal_vec(p.weight_len());
        let x = rng.normal_vec(p.input_len());
        let mut ws = Workspace::new();
        let spec = FftConvEngine::new(FftMode::Fbfft, 8)
            .weight_spectrum(&p, &wei, 1, SpectrumPrecision::F16, &mut ws);
        let mut y = vec![0f32; p.output_len()];
        FftConvEngine::new(FftMode::Fbfft, 16)
            .fprop_spec_into(&p, &x, &spec, &mut y, &mut ws);
    }

    #[test]
    fn transpose_round_trips_ragged_sizes() {
        // both Cgeam transposes share this kernel; exercise tile edges
        let mut rng = Rng::new(26);
        for (rows, cols) in [(1usize, 1usize), (3, 70), (33, 33),
                             (64, 31), (130, 5)] {
            let src: Vec<C32> = (0..rows * cols)
                .map(|_| C32::new(rng.normal(), rng.normal()))
                .collect();
            let mut t = vec![C32::ZERO; rows * cols];
            transpose(&src, rows, cols, &mut t);
            for r in 0..rows {
                for c in 0..cols {
                    assert_eq!(t[c * rows + r], src[r * cols + c]);
                }
            }
            let mut back = vec![C32::ZERO; rows * cols];
            transpose(&t, cols, rows, &mut back);
            assert_eq!(back, src);
        }
    }
}
