//! In-tree SGEMM / complex GEMM — the cuBLAS-analogue substrate.
//!
//! Cache-blocked, threaded over row panels. Not trying to beat MKL; it
//! needs to be a *credible* tuned-library stand-in so the im2col engine
//! and the frequency-domain CGEMM stage (Table 1) have the same pipeline
//! position they have in the paper.

use std::thread;

use crate::fft::C32;
use crate::util::threads;

/// Row-major `C[m×n] += A[m×k] · B[k×n]` (or `C = A·B` if `accumulate` is
/// false), blocked for L1/L2 residency.
pub fn sgemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32],
             c: &mut [f32], accumulate: bool) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if !accumulate {
        c.fill(0.0);
    }
    const MC: usize = 64;   // rows per panel
    const KC: usize = 128;  // depth per panel
    let nthreads = threads();
    let panels: Vec<usize> = (0..m).step_by(MC).collect();
    thread::scope(|scope| {
        let mut rem: &mut [f32] = c;
        let mut consumed = 0usize;
        for chunk in panels.chunks(panels.len().div_ceil(nthreads)) {
            let first = chunk[0];
            let last_end = (chunk[chunk.len() - 1] + MC).min(m);
            let take = last_end * n - consumed;
            let (head, tail) = rem.split_at_mut(take);
            consumed = last_end * n;
            rem = tail;
            let head_base = first * n - (last_end * n - take);
            debug_assert_eq!(head_base, first * n - (last_end * n - take));
            scope.spawn(move || {
                for &i0 in chunk {
                    let i1 = (i0 + MC).min(m);
                    for p0 in (0..k).step_by(KC) {
                        let p1 = (p0 + KC).min(k);
                        for i in i0..i1 {
                            let crow =
                                &mut head[(i - first) * n..][..n];
                            let arow = &a[i * k..];
                            for p in p0..p1 {
                                let aip = arow[p];
                                if aip == 0.0 {
                                    continue;
                                }
                                let brow = &b[p * n..][..n];
                                for (j, bv) in brow.iter().enumerate() {
                                    crow[j] += aip * *bv;
                                }
                            }
                        }
                    }
                }
            });
        }
    });
}

/// Row-major complex GEMM `C = A·op(B)` where `op` optionally conjugates
/// B's elements and/or uses Bᵀ. Scalar reference only — the hot
/// frequency-domain Cgemm of Table 1 lives in [`super::cgemm`], which
/// packs to planar re/im panels, blocks for cache and threads over bins;
/// this one stays as the simple single-matrix utility.
pub fn cgemm(m: usize, k: usize, n: usize, a: &[C32], conj_a: bool,
             b: &[C32], conj_b: bool, trans_b: bool, c: &mut [C32],
             accumulate: bool) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n, "b must be k×n (pre-transposed view)");
    assert_eq!(c.len(), m * n);
    if !accumulate {
        c.fill(C32::ZERO);
    }
    for i in 0..m {
        for p in 0..k {
            let mut av = a[i * k + p];
            if conj_a {
                av = av.conj();
            }
            let crow = &mut c[i * n..][..n];
            if trans_b {
                // b stored n×k: column p is strided
                for (j, cv) in crow.iter_mut().enumerate() {
                    let mut bv = b[j * k + p];
                    if conj_b {
                        bv = bv.conj();
                    }
                    *cv = cv.mul_add(av, bv);
                }
            } else {
                let brow = &b[p * n..][..n];
                for (j, cv) in crow.iter_mut().enumerate() {
                    let mut bv = brow[j];
                    if conj_b {
                        bv = bv.conj();
                    }
                    *cv = cv.mul_add(av, bv);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sgemm_naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32])
                   -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn matches_naive_various_shapes() {
        let mut rng = Rng::new(2);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (65, 7, 9), (128, 130, 33),
                          (200, 64, 64)] {
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            let mut c = vec![0f32; m * n];
            sgemm(m, k, n, &a, &b, &mut c, false);
            let want = sgemm_naive(m, k, n, &a, &b);
            for (g, w) in c.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3 * (k as f32).sqrt());
            }
        }
    }

    #[test]
    fn accumulate_adds() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (4, 6, 5);
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(k * n);
        let mut c = vec![1f32; m * n];
        sgemm(m, k, n, &a, &b, &mut c, true);
        let want = sgemm_naive(m, k, n, &a, &b);
        for (g, w) in c.iter().zip(&want) {
            assert!((g - (w + 1.0)).abs() < 1e-4);
        }
    }

    #[test]
    fn cgemm_conjugation_flags() {
        let mut rng = Rng::new(4);
        let (m, k, n) = (3, 4, 2);
        let a: Vec<C32> = (0..m * k)
            .map(|_| C32::new(rng.normal(), rng.normal())).collect();
        let b: Vec<C32> = (0..k * n)
            .map(|_| C32::new(rng.normal(), rng.normal())).collect();
        for (ca, cb) in [(false, false), (true, false), (false, true),
                         (true, true)] {
            let mut c = vec![C32::ZERO; m * n];
            cgemm(m, k, n, &a, ca, &b, cb, false, &mut c, false);
            for i in 0..m {
                for j in 0..n {
                    let mut want = C32::ZERO;
                    for p in 0..k {
                        let av = if ca { a[i * k + p].conj() } else { a[i * k + p] };
                        let bv = if cb { b[p * n + j].conj() } else { b[p * n + j] };
                        want += av * bv;
                    }
                    assert!((c[i * n + j] - want).abs() < 1e-4);
                }
            }
        }
    }

    #[test]
    fn cgemm_transposed_b() {
        let mut rng = Rng::new(5);
        let (m, k, n) = (2, 3, 4);
        let a: Vec<C32> = (0..m * k)
            .map(|_| C32::new(rng.normal(), rng.normal())).collect();
        // b stored as n×k (i.e. Bᵀ layout)
        let bt: Vec<C32> = (0..n * k)
            .map(|_| C32::new(rng.normal(), rng.normal())).collect();
        let mut c = vec![C32::ZERO; m * n];
        // note: with trans_b the length check wants k*n which holds
        cgemm(m, k, n, &a, false, &bt, false, true, &mut c, false);
        for i in 0..m {
            for j in 0..n {
                let mut want = C32::ZERO;
                for p in 0..k {
                    want += a[i * k + p] * bt[j * k + p];
                }
                assert!((c[i * n + j] - want).abs() < 1e-4);
            }
        }
    }
}
