//! Overlap-and-Add fbfft (Highlander & Rodriguez, 1601.06815): the
//! large-input/small-kernel engine the full-pad paths can't serve.
//!
//! Every full-pad FFT engine transforms the whole input at
//! `n_fft = next_pow2(max(h, w))` — which explodes past
//! [`fbfft_host::MAX_N`] at 512²-scale images and pays `O(W² log W)`
//! on the padded extent `W` even below it. OaA instead tiles the
//! stride-1 output grid into `tile × tile` patches and convolves each
//! patch's `(tile+k-1)`-sized input window at the **small fixed basis**
//! `n_fft = next_pow2(tile + k - 1)`, overlap-adding partial results:
//!
//! * **fprop** — overlap-save: output tiles are disjoint, input windows
//!   overlap by `k-1`; strided outputs subsample the stride-1 tile grid
//!   on the way out (the one FFT engine that serves `stride > 1`).
//! * **bprop** — overlap-add proper: each gradient tile scatters a
//!   `(tile+k-1)`-sized window *additively* into the input gradient
//!   (the transposed overlap).
//! * **accGrad** — tile-sum: per-tile weight-gradient correlations
//!   accumulate into one `kh × kw` gradient.
//!
//! Tiles do not run one-by-one: same-shape tiles (at most four shapes —
//! interior, right edge, bottom edge, corner) are **batched into the
//! inner engine's batch dimension**, so each pass issues at most four
//! [`FftConvEngine`] calls whose batch `s' = tiles · s` is large enough
//! to light up the fbfft batch lanes, the threaded batch-group fan-out
//! and the CGEMM bin threading — a per-tile loop would starve all three
//! below their serial-fallback thresholds.
//!
//! Unlike the allocating §6 [`tiled`](super::tiled) decomposition this
//! engine is steady-state zero-allocation: gather/scatter staging comes
//! from the caller's [`Workspace`] pool under `oaa.*` roles, and the
//! tile-group pipeline is the pooled [`FftConvEngine`] spec path. The
//! weight spectrum is computed **once per call** (raw-weights form) or
//! **never** (spec form, fed from the per-shard
//! [`SpectrumCache`](super::spectra::SpectrumCache) — the spectrum key
//! is `(f, f', kh, kw, n_fft, mode)`, independent of `h × w`, so one
//! small cached spectrum serves every tile of every image size).

use std::time::Instant;

use crate::coordinator::Pass;
use crate::fft::fbfft_host;

use super::cgemm::Workspace;
use super::fft_conv::{BOperand, FftConvEngine, FftMode, Operands,
                      StageTimings};
use super::problem::ConvProblem;
use super::spectra::{SpectrumPrecision, WeightSpectrum};
use super::tiled::tile_fft_size;

/// The largest output tile whose FFT window exactly fills `basis`
/// (`tile + kmax - 1 == basis`) — the zero-rounding-waste sweet spot
/// the autotuner sweeps alongside the power-of-two tiles.
pub fn basis_filling_tile(basis: usize, kh: usize, kw: usize) -> usize {
    let kmax = kh.max(kw);
    assert!(basis >= kmax, "basis {basis} below kernel {kmax}");
    basis - kmax + 1
}

/// Does an OaA engine with this tile exist for this kernel? (The tile
/// basis must stay inside the fbfft plan domain.)
pub fn tile_supported(tile: usize, kh: usize, kw: usize) -> bool {
    tile >= 1 && tile_fft_size(tile, kh, kw) <= fbfft_host::MAX_N
}

/// The tile candidates the autotuner (and the cost model) sweep for a
/// problem: the power-of-two output tiles {16, 32, 64} plus the
/// basis-filling tiles of the small bases {32, 64, 128}. Empty when OaA
/// is not worth considering: kernels near the input extent (the
/// full-pad engines already fit), tiles at or past the stride-1 output
/// extent (degenerate full-pad), or tile bases outside the fbfft plan
/// domain. 1-D signals gate on the *long* axis — their short axis is 1
/// by construction.
pub fn tile_candidates(p: &ConvProblem) -> Vec<usize> {
    let kmax = p.kh.max(p.kw);
    let one_d = p.h == 1 || p.w == 1;
    let ext = if one_d { p.h.max(p.w) } else { p.h.min(p.w) };
    if kmax * 4 >= ext {
        return Vec::new();
    }
    let y_ext = (p.h - p.kh + 1).max(p.w - p.kw + 1);
    let mut tiles = vec![16, 32, 64];
    for basis in [32, 64, 128] {
        if basis >= kmax {
            tiles.push(basis_filling_tile(basis, p.kh, p.kw));
        }
    }
    tiles.sort_unstable();
    tiles.dedup();
    tiles.retain(|&t| tile_supported(t, p.kh, p.kw) && t < y_ext);
    tiles
}

/// The tile spans of one axis: `(origin, extent)` pairs with extent `d`
/// except a ragged tail.
fn spans(total: usize, d: usize) -> Vec<(usize, usize)> {
    (0..total).step_by(d).map(|a| (a, d.min(total - a))).collect()
}

/// The `tile × tile` grid over a `yh_ext × yw_ext` output extent,
/// grouped by tile shape `(dh, dw)` — at most four groups (interior,
/// right edge, bottom edge, corner), each listing its tiles' `(ah, aw)`
/// origins. Same-shape tiles batch into **one** inner-engine call (the
/// tiles ride the batch dimension), so the fbfft batch lanes, the
/// threaded batch-group fan-out and the CGEMM bin threading all see one
/// large problem instead of per-tile slivers — and accGrad's tile-sum
/// falls out of the inner batch reduction for free.
fn tile_groups(yh_ext: usize, yw_ext: usize, d: usize)
               -> Vec<((usize, usize), Vec<(usize, usize)>)> {
    let rows = spans(yh_ext, d);
    let cols = spans(yw_ext, d);
    let mut groups: Vec<((usize, usize), Vec<(usize, usize)>)> =
        Vec::new();
    for &(ah, dh) in &rows {
        for &(aw, dw) in &cols {
            match groups.iter_mut().find(|(k, _)| *k == (dh, dw)) {
                Some((_, v)) => v.push((ah, aw)),
                None => groups.push(((dh, dw), vec![(ah, aw)])),
            }
        }
    }
    groups
}

pub struct OaaEngine {
    /// Output-tile edge on the stride-1 grid.
    pub tile: usize,
    /// The small fixed-basis fbfft pipeline every tile runs through.
    inner: FftConvEngine,
}

impl OaaEngine {
    /// OaA at output-tile edge `tile` for a `kh × kw` kernel; the tile
    /// basis `next_pow2(tile + max(kh, kw) - 1)` must stay inside the
    /// fbfft domain (≤ [`fbfft_host::MAX_N`]).
    pub fn new(tile: usize, kh: usize, kw: usize) -> Self {
        assert!(tile >= 1, "empty OaA tile");
        let n = tile_fft_size(tile, kh, kw);
        OaaEngine { tile, inner: FftConvEngine::new(FftMode::Fbfft, n) }
    }

    /// [`OaaEngine::new`] keyed off a problem's kernel.
    pub fn for_problem(p: &ConvProblem, tile: usize) -> Self {
        Self::new(tile, p.kh, p.kw)
    }

    /// The fixed tile basis.
    pub fn n_fft(&self) -> usize {
        self.inner.n_fft
    }

    /// The per-tile pipeline — hand this to
    /// [`SpectrumCache::ensure`](super::spectra::SpectrumCache::ensure)
    /// so the cached spectrum is keyed at the **tile** basis (one small
    /// spectrum per layer, shared by every tile and image size).
    pub fn inner(&self) -> &FftConvEngine {
        &self.inner
    }

    /// The batched sub-problem of one tile *group*: `tiles` same-shape
    /// `(th × tw)` windows stacked tile-major on the batch axis
    /// (`s' = tiles · s`; always stride 1 — striding is applied at
    /// scatter time). Batch entries are independent through the whole
    /// inner pipeline, so the group call computes every tile's partial
    /// result in one threaded sweep.
    fn sub(&self, p: &ConvProblem, tiles: usize, th: usize, tw: usize)
           -> ConvProblem {
        ConvProblem::builder()
            .batch(tiles * p.s)
            .planes(p.f, p.fo)
            .hw(th, tw)
            .kernel(p.kh, p.kw)
            .build()
    }

    fn check(&self, p: &ConvProblem) {
        assert_eq!(tile_fft_size(self.tile, p.kh, p.kw), self.inner.n_fft,
                   "OaA engine built for a different kernel size");
    }

    // ---- the unified pass surface --------------------------------------

    /// The OaA mirror of [`FftConvEngine::run`]: one pass-typed entry
    /// point over the same [`Operands`] vocabulary. fprop accepts any
    /// stride ≥ 1; bprop/accGrad are stride-1 (paper §2 scope). `out`
    /// is fully overwritten (fprop) or zeroed-then-accumulated
    /// (bprop/accGrad).
    pub fn run(&self, pass: Pass, ops: Operands<'_>, ws: &mut Workspace)
               -> StageTimings {
        let p = ops.problem;
        self.check(p);
        match (pass, ops.b) {
            (Pass::Fprop, BOperand::Planes(wei)) => {
                self.with_once_spectrum(p, wei, ws, |me, spec, ws| {
                    me.fprop_spec_into(p, ops.a, spec, ops.out, ws)
                })
            }
            (Pass::Fprop, BOperand::Spectrum(spec)) => {
                self.fprop_spec_into(p, ops.a, spec, ops.out, ws)
            }
            (Pass::Bprop, BOperand::Planes(wei)) => {
                self.with_once_spectrum(p, wei, ws, |me, spec, ws| {
                    me.bprop_spec_into(p, ops.a, spec, ops.out, ws)
                })
            }
            (Pass::Bprop, BOperand::Spectrum(spec)) => {
                self.bprop_spec_into(p, ops.a, spec, ops.out, ws)
            }
            (Pass::AccGrad, BOperand::Planes(x)) => {
                self.accgrad_into(p, ops.a, x, ops.out, ws)
            }
            (Pass::AccGrad, BOperand::Spectrum(_)) => {
                panic!("accGrad's B operand is the activation — \
                        no cached spectrum applies")
            }
        }
    }

    /// Transform the weights once at the tile basis (the cache-miss
    /// path), run `body` against the spectrum, and attribute the
    /// one-time transform to the B/weight stages.
    fn with_once_spectrum<F>(&self, p: &ConvProblem, wei: &[f32],
                             ws: &mut Workspace, body: F) -> StageTimings
    where
        F: FnOnce(&Self, &WeightSpectrum, &mut Workspace) -> StageTimings,
    {
        let t0 = Instant::now();
        let spec = self.inner.weight_spectrum(
            p, wei, 0, SpectrumPrecision::F32, ws);
        let wdur = t0.elapsed();
        let mut t = body(self, &spec, ws);
        t.fft_b += wdur;
        t.weight_fft += wdur;
        t
    }

    // ---- fprop (overlap-save, stride-aware scatter) --------------------

    /// fprop against a cached tile-basis weight spectrum — the serving
    /// steady state: zero weight-FFT time, zero allocations.
    pub fn fprop_spec_into(&self, p: &ConvProblem, x: &[f32],
                           spec: &WeightSpectrum, out: &mut [f32],
                           ws: &mut Workspace) -> StageTimings {
        self.check(p);
        assert_eq!(x.len(), p.input_len());
        assert_eq!(out.len(), p.output_len());
        let d = self.tile;
        // tile the *stride-1* output grid; striding subsamples at
        // scatter time, so every strided position lands exactly once
        let (yh1, yw1) = (p.h - p.kh + 1, p.w - p.kw + 1);
        let (yh, yw) = (p.yh(), p.yw());
        let st = p.stride;
        let mut total = StageTimings {
            simd_tier: crate::util::simd::tier(),
            ..StageTimings::default()
        };
        for ((dh, dw), tiles) in tile_groups(yh1, yw1, d) {
            let (th, tw) = (dh + p.kh - 1, dw + p.kw - 1);
            let q = self.sub(p, tiles.len(), th, tw);
            let (in_blk, out_blk) = (p.s * p.f * th * tw,
                                     p.s * p.fo * dh * dw);
            let mut xt = ws.pool.take_raw("oaa.a", q.input_len());
            for (t, &(ah, aw)) in tiles.iter().enumerate() {
                gather_planes(x, p.s * p.f, p.h, p.w, ah, th, aw, tw,
                              &mut xt[t * in_blk..(t + 1) * in_blk]);
            }
            let mut yt = ws.pool.take_raw("oaa.c", q.output_len());
            let t = self.inner.fprop_spec_into(&q, &xt, spec, &mut yt,
                                               ws);
            total.add(&t);
            for (t, &(ah, aw)) in tiles.iter().enumerate() {
                let base = t * out_blk;
                for b in 0..p.s * p.fo {
                    for r in 0..dh {
                        let gr = ah + r;
                        if gr % st != 0 {
                            continue;
                        }
                        let src = base + (b * dh + r) * dw;
                        let dst = (b * yh + gr / st) * yw;
                        if st == 1 {
                            out[dst + aw..dst + aw + dw]
                                .copy_from_slice(&yt[src..src + dw]);
                        } else {
                            for c in 0..dw {
                                let gc = aw + c;
                                if gc % st == 0 {
                                    out[dst + gc / st] = yt[src + c];
                                }
                            }
                        }
                    }
                }
            }
            ws.pool.put("oaa.a", xt);
            ws.pool.put("oaa.c", yt);
        }
        total
    }

    /// fprop from raw weights: one weight FFT at the tile basis, then
    /// the spec path over every tile.
    pub fn fprop_into(&self, p: &ConvProblem, x: &[f32], wei: &[f32],
                      out: &mut [f32], ws: &mut Workspace)
                      -> StageTimings {
        self.with_once_spectrum(p, wei, ws, |me, spec, ws| {
            me.fprop_spec_into(p, x, spec, out, ws)
        })
    }

    // ---- bprop (transposed overlap-add) --------------------------------

    /// bprop against a cached spectrum: each gradient tile's
    /// `(tile+k-1)`-window back-projection overlap-adds into `out`
    /// (which is zeroed first).
    pub fn bprop_spec_into(&self, p: &ConvProblem, go: &[f32],
                           spec: &WeightSpectrum, out: &mut [f32],
                           ws: &mut Workspace) -> StageTimings {
        self.check(p);
        assert_eq!(p.stride, 1, "strided FFT conv out of scope (paper §2)");
        assert_eq!(go.len(), p.output_len());
        assert_eq!(out.len(), p.input_len());
        let d = self.tile;
        let (yh, yw) = (p.yh(), p.yw());
        out.fill(0.0);
        let mut total = StageTimings {
            simd_tier: crate::util::simd::tier(),
            ..StageTimings::default()
        };
        for ((dh, dw), tiles) in tile_groups(yh, yw, d) {
            let (th, tw) = (dh + p.kh - 1, dw + p.kw - 1);
            let q = self.sub(p, tiles.len(), th, tw);
            let (out_blk, in_blk) = (p.s * p.fo * dh * dw,
                                     p.s * p.f * th * tw);
            let mut got = ws.pool.take_raw("oaa.a", q.output_len());
            for (t, &(ah, aw)) in tiles.iter().enumerate() {
                gather_planes(go, p.s * p.fo, yh, yw, ah, dh, aw, dw,
                              &mut got[t * out_blk..(t + 1) * out_blk]);
            }
            let mut gxt = ws.pool.take_raw("oaa.c", q.input_len());
            let t = self.inner.bprop_spec_into(&q, &got, spec, &mut gxt,
                                               ws);
            total.add(&t);
            // the transposed overlap: windows of adjacent tiles share
            // k-1 rows/cols, so scatter is additive
            for (t, &(ah, aw)) in tiles.iter().enumerate() {
                let base = t * in_blk;
                for b in 0..p.s * p.f {
                    for r in 0..th {
                        let src = base + (b * th + r) * tw;
                        let dst = (b * p.h + ah + r) * p.w + aw;
                        for c in 0..tw {
                            out[dst + c] += gxt[src + c];
                        }
                    }
                }
            }
            ws.pool.put("oaa.a", got);
            ws.pool.put("oaa.c", gxt);
        }
        total
    }

    /// bprop from raw weights (one weight FFT, then the spec path).
    pub fn bprop_into(&self, p: &ConvProblem, go: &[f32], wei: &[f32],
                      out: &mut [f32], ws: &mut Workspace)
                      -> StageTimings {
        self.with_once_spectrum(p, wei, ws, |me, spec, ws| {
            me.bprop_spec_into(p, go, spec, out, ws)
        })
    }

    // ---- accGrad (tile-sum) --------------------------------------------

    /// accGrad: per-tile weight-gradient correlations at the tile basis
    /// summed into `out` (zeroed first). B is the activation, so there
    /// is no spectrum form.
    pub fn accgrad_into(&self, p: &ConvProblem, go: &[f32], x: &[f32],
                        out: &mut [f32], ws: &mut Workspace)
                        -> StageTimings {
        self.check(p);
        assert_eq!(p.stride, 1, "strided FFT conv out of scope (paper §2)");
        assert_eq!(go.len(), p.output_len());
        assert_eq!(x.len(), p.input_len());
        assert_eq!(out.len(), p.weight_len());
        let d = self.tile;
        let (yh, yw) = (p.yh(), p.yw());
        out.fill(0.0);
        let mut total = StageTimings {
            simd_tier: crate::util::simd::tier(),
            ..StageTimings::default()
        };
        for ((dh, dw), tiles) in tile_groups(yh, yw, d) {
            let (th, tw) = (dh + p.kh - 1, dw + p.kw - 1);
            let q = self.sub(p, tiles.len(), th, tw);
            let (out_blk, in_blk) = (p.s * p.fo * dh * dw,
                                     p.s * p.f * th * tw);
            let mut got = ws.pool.take_raw("oaa.a", q.output_len());
            let mut xt = ws.pool.take_raw("oaa.b", q.input_len());
            for (t, &(ah, aw)) in tiles.iter().enumerate() {
                gather_planes(go, p.s * p.fo, yh, yw, ah, dh, aw, dw,
                              &mut got[t * out_blk..(t + 1) * out_blk]);
                gather_planes(x, p.s * p.f, p.h, p.w, ah, th, aw, tw,
                              &mut xt[t * in_blk..(t + 1) * in_blk]);
            }
            // accGrad reduces over the sub-problem's batch axis — which
            // now carries the tiles, so the group result arrives
            // already tile-summed
            let mut gwt = ws.pool.take_raw("oaa.gw", q.weight_len());
            let t = self.inner.accgrad_into(&q, &got, &xt, &mut gwt, ws);
            total.add(&t);
            for (o, g) in out.iter_mut().zip(gwt.iter()) {
                *o += *g;
            }
            ws.pool.put("oaa.a", got);
            ws.pool.put("oaa.b", xt);
            ws.pool.put("oaa.gw", gwt);
        }
        total
    }

    // ---- allocating conveniences (tuner / test-matrix signatures) ------

    pub fn fprop(&self, p: &ConvProblem, x: &[f32], wei: &[f32])
                 -> (Vec<f32>, StageTimings) {
        let mut ws = Workspace::new();
        let mut out = vec![0f32; p.output_len()];
        let t = self.fprop_into(p, x, wei, &mut out, &mut ws);
        (out, t)
    }

    pub fn bprop(&self, p: &ConvProblem, go: &[f32], wei: &[f32])
                 -> (Vec<f32>, StageTimings) {
        let mut ws = Workspace::new();
        let mut out = vec![0f32; p.input_len()];
        let t = self.bprop_into(p, go, wei, &mut out, &mut ws);
        (out, t)
    }

    pub fn accgrad(&self, p: &ConvProblem, go: &[f32], x: &[f32])
                   -> (Vec<f32>, StageTimings) {
        let mut ws = Workspace::new();
        let mut out = vec![0f32; p.weight_len()];
        let t = self.accgrad_into(p, go, x, &mut out, &mut ws);
        (out, t)
    }
}

/// Gather the `[h0, h0+hh) × [w0, w0+ww)` window of `count` row-major
/// `src_h × src_w` planes into the dense `dst` (`count · hh · ww`).
fn gather_planes(src: &[f32], count: usize, src_h: usize, src_w: usize,
                 h0: usize, hh: usize, w0: usize, ww: usize,
                 dst: &mut [f32]) {
    debug_assert!(h0 + hh <= src_h && w0 + ww <= src_w);
    debug_assert_eq!(dst.len(), count * hh * ww);
    for b in 0..count {
        for r in 0..hh {
            let s = (b * src_h + h0 + r) * src_w + w0;
            let d = (b * hh + r) * ww;
            dst[d..d + ww].copy_from_slice(&src[s..s + ww]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{assert_close_oracle, oracle, tolerance};
    use crate::util::Rng;

    #[test]
    fn all_passes_match_oracle_on_a_tile_boundary_shape() {
        // 37 is not a multiple of the tile: ragged boundary tiles on
        // both axes
        let p = ConvProblem::square(2, 2, 3, 37, 3);
        let eng = OaaEngine::for_problem(&p, 8);
        let mut rng = Rng::new(0x0a1);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let go = rng.normal_vec(p.output_len());
        let (y, t) = eng.fprop(&p, &x, &wei);
        assert_close_oracle(&y, &oracle::fprop64(&p, &x, &wei),
                            tolerance::oaa(&p, Pass::Fprop, 8));
        assert!(t.weight_fft > std::time::Duration::ZERO,
                "raw path pays the one-time weight FFT");
        let (gx, _) = eng.bprop(&p, &go, &wei);
        assert_close_oracle(&gx, &oracle::bprop64(&p, &go, &wei),
                            tolerance::oaa(&p, Pass::Bprop, 8));
        let (gw, _) = eng.accgrad(&p, &go, &x);
        assert_close_oracle(&gw, &oracle::accgrad64(&p, &go, &x),
                            tolerance::oaa(&p, Pass::AccGrad, 8));
    }

    #[test]
    fn spec_path_reuses_one_spectrum_with_zero_weight_fft() {
        let p = ConvProblem::square(2, 3, 2, 33, 5);
        let eng = OaaEngine::for_problem(&p, 8);
        let mut rng = Rng::new(0x0a2);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let mut ws = Workspace::new();
        let spec = eng.inner().weight_spectrum(
            &p, &wei, 1, SpectrumPrecision::F32, &mut ws);
        let mut y = vec![0f32; p.output_len()];
        let t = eng.fprop_spec_into(&p, &x, &spec, &mut y, &mut ws);
        assert_eq!(t.weight_fft, std::time::Duration::ZERO);
        let (want, _) = eng.fprop(&p, &x, &wei);
        assert_eq!(y, want, "f32 spectrum path is bitwise the raw path");
    }

    #[test]
    fn one_d_signal_shape_runs_all_passes() {
        let p = ConvProblem::builder()
            .batch(2)
            .planes(2, 2)
            .hw(1, 300)
            .kernel(1, 7)
            .build();
        let eng = OaaEngine::for_problem(&p, 16);
        let mut rng = Rng::new(0x0a3);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let go = rng.normal_vec(p.output_len());
        let (y, _) = eng.fprop(&p, &x, &wei);
        assert_close_oracle(&y, &oracle::fprop64(&p, &x, &wei),
                            tolerance::oaa(&p, Pass::Fprop, 16));
        let (gx, _) = eng.bprop(&p, &go, &wei);
        assert_close_oracle(&gx, &oracle::bprop64(&p, &go, &wei),
                            tolerance::oaa(&p, Pass::Bprop, 16));
        let (gw, _) = eng.accgrad(&p, &go, &x);
        assert_close_oracle(&gw, &oracle::accgrad64(&p, &go, &x),
                            tolerance::oaa(&p, Pass::AccGrad, 16));
    }

    #[test]
    fn reused_workspace_reproduces_fresh_results_bitwise() {
        let p = ConvProblem::square(1, 2, 2, 21, 3);
        let eng = OaaEngine::for_problem(&p, 6);
        let mut rng = Rng::new(0x0a4);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let go = rng.normal_vec(p.output_len());
        let mut ws = Workspace::new();
        let mut y = vec![0f32; p.output_len()];
        let mut gx = vec![0f32; p.input_len()];
        let mut gw = vec![0f32; p.weight_len()];
        for round in 0..2 {
            eng.fprop_into(&p, &x, &wei, &mut y, &mut ws);
            eng.bprop_into(&p, &go, &wei, &mut gx, &mut ws);
            eng.accgrad_into(&p, &go, &x, &mut gw, &mut ws);
            assert_eq!(y, eng.fprop(&p, &x, &wei).0, "fprop r{round}");
            assert_eq!(gx, eng.bprop(&p, &go, &wei).0, "bprop r{round}");
            assert_eq!(gw, eng.accgrad(&p, &go, &x).0, "accgrad r{round}");
        }
    }

    #[test]
    fn steady_state_is_allocation_free() {
        let p = ConvProblem::square(1, 2, 2, 40, 3);
        let eng = OaaEngine::for_problem(&p, 16);
        let mut rng = Rng::new(0x0a5);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let mut ws = Workspace::new();
        let spec = eng.inner().weight_spectrum(
            &p, &wei, 1, SpectrumPrecision::F32, &mut ws);
        let mut y = vec![0f32; p.output_len()];
        eng.fprop_spec_into(&p, &x, &spec, &mut y, &mut ws);
        ws.pool.reset_counters();
        eng.fprop_spec_into(&p, &x, &spec, &mut y, &mut ws);
        assert_eq!(ws.pool.allocations, 0,
                   "warm OaA fprop must not allocate");
        assert_eq!(ws.pool.expansions, 0,
                   "warm OaA fprop must not regrow pooled buffers");
    }

    #[test]
    fn tile_covering_input_degenerates_to_full_pad_bitwise() {
        // one tile spans the whole output: OaA is exactly the full-pad
        // engine at the same basis (spec path is bitwise the raw path)
        let p = ConvProblem::square(2, 2, 2, 14, 3);
        let tile = 16; // >= yh1 = 12
        let eng = OaaEngine::for_problem(&p, tile);
        let full = FftConvEngine::new(FftMode::Fbfft, eng.n_fft());
        let mut rng = Rng::new(0x0a6);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let (a, _) = eng.fprop(&p, &x, &wei);
        let (b, _) = full.fprop(&p, &x, &wei);
        assert_eq!(a, b, "degenerate OaA must be bitwise full-pad");
    }

    #[test]
    fn strided_fprop_matches_oracle() {
        let p = ConvProblem::builder()
            .batch(2)
            .planes(2, 2)
            .hw(23, 23)
            .kernel(3, 3)
            .stride(2)
            .build();
        let eng = OaaEngine::for_problem(&p, 8);
        let mut rng = Rng::new(0x0a7);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let (y, _) = eng.fprop(&p, &x, &wei);
        assert_close_oracle(&y, &oracle::fprop64(&p, &x, &wei),
                            tolerance::oaa(&p, Pass::Fprop, 8));
    }

    #[test]
    #[should_panic(expected = "strided FFT conv out of scope")]
    fn strided_bprop_rejected() {
        let p = ConvProblem::builder()
            .hw(16, 16)
            .kernel(3, 3)
            .stride(2)
            .build();
        let eng = OaaEngine::for_problem(&p, 8);
        let mut out = vec![0f32; p.input_len()];
        let go = vec![0f32; p.output_len()];
        let wei = vec![0f32; p.weight_len()];
        eng.bprop_into(&p, &go, &wei, &mut out, &mut Workspace::new());
    }

    #[test]
    fn tile_groups_cover_the_grid_in_at_most_four_shapes() {
        let groups = tile_groups(37, 21, 8);
        assert!(groups.len() <= 4);
        let tiles: usize = groups.iter().map(|(_, v)| v.len()).sum();
        assert_eq!(tiles, 5 * 3);
        let area: usize = groups.iter()
            .map(|&((dh, dw), ref v)| dh * dw * v.len())
            .sum();
        assert_eq!(area, 37 * 21);
        // exact division leaves only the interior shape
        assert_eq!(tile_groups(32, 32, 8).len(), 1);
        // 1-D grids degenerate to at most two shapes
        assert!(tile_groups(1, 300, 16).len() <= 2);
    }

    #[test]
    fn basis_filling_tile_fills_the_basis() {
        assert_eq!(basis_filling_tile(64, 3, 3), 62);
        assert_eq!(tile_fft_size(62, 3, 3), 64);
        assert_eq!(basis_filling_tile(32, 5, 5), 28);
        assert_eq!(tile_fft_size(28, 5, 5), 32);
    }
}
