//! Direct time-domain convolution — the straightforward O(S·f·f'·k²·y²)
//! computation, multithreaded over the pass's natural parallel dimension.
//! This is the ccn2-analogue baseline of Table 3 and the ground-truth
//! oracle every other engine is tested against.

use std::thread;

use crate::util::chunk_ranges as chunks;

use super::problem::ConvProblem;

/// Threads used by the host engines — delegates to the process-wide
/// [`crate::util::threads`] helper so the `FBFFT_THREADS` override steers
/// every engine uniformly.
pub fn threads() -> usize {
    crate::util::threads()
}

/// fprop: `y[s,j] = Σ_i x[s,i] ⋆ w[j,i]` (valid cross-correlation).
/// Parallel over the minibatch.
pub fn fprop(p: &ConvProblem, x: &[f32], wei: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), p.input_len());
    assert_eq!(wei.len(), p.weight_len());
    let (yh, yw) = (p.yh(), p.yw());
    let (f, fo, h, w, kh, kw, st) =
        (p.f, p.fo, p.h, p.w, p.kh, p.kw, p.stride);
    let mut out = vec![0f32; p.output_len()];
    let sample = move |xs: &[f32], os: &mut [f32]| {
        for j in 0..fo {
            for i in 0..f {
                let wp = &wei[(j * f + i) * kh * kw..][..kh * kw];
                let xp = &xs[i * h * w..][..h * w];
                for a in 0..yh {
                    for b in 0..yw {
                        let mut acc = 0f32;
                        for u in 0..kh {
                            let xrow = &xp[(a * st + u) * w + b * st..];
                            let wrow = &wp[u * kw..][..kw];
                            for (v, wv) in wrow.iter().enumerate() {
                                acc += xrow[v] * *wv;
                            }
                        }
                        os[(j * yh + a) * yw + b] += acc;
                    }
                }
            }
        }
    };
    let in_stride = f * h * w;
    let out_stride = fo * yh * yw;
    thread::scope(|scope| {
        let mut rem: &mut [f32] = &mut out;
        for (start, len) in chunks(p.s, threads()) {
            let (head, tail) = rem.split_at_mut(len * out_stride);
            rem = tail;
            let x = &x;
            let sample = &sample;
            scope.spawn(move || {
                for si in 0..len {
                    sample(&x[(start + si) * in_stride..][..in_stride],
                           &mut head[si * out_stride..][..out_stride]);
                }
            });
        }
    });
    out
}

/// bprop: `gx[s,i] = Σ_j go[s,j] * w[j,i]` (full convolution).
/// Parallel over the minibatch.
pub fn bprop(p: &ConvProblem, go: &[f32], wei: &[f32]) -> Vec<f32> {
    assert_eq!(p.stride, 1, "strided bprop is vendor-only (paper §2)");
    assert_eq!(go.len(), p.output_len());
    assert_eq!(wei.len(), p.weight_len());
    let (yh, yw) = (p.yh(), p.yw());
    let (f, fo, h, w, kh, kw) = (p.f, p.fo, p.h, p.w, p.kh, p.kw);
    let mut out = vec![0f32; p.input_len()];
    let go_stride = fo * yh * yw;
    let gx_stride = f * h * w;
    thread::scope(|scope| {
        let mut rem: &mut [f32] = &mut out;
        for (start, len) in chunks(p.s, threads()) {
            let (head, tail) = rem.split_at_mut(len * gx_stride);
            rem = tail;
            let go = &go;
            scope.spawn(move || {
                for si in 0..len {
                    let gos = &go[(start + si) * go_stride..][..go_stride];
                    let gxs = &mut head[si * gx_stride..][..gx_stride];
                    for i in 0..f {
                        let gxp = &mut gxs[i * h * w..][..h * w];
                        for j in 0..fo {
                            let gop = &gos[j * yh * yw..][..yh * yw];
                            let wp = &wei[(j * f + i) * kh * kw..][..kh * kw];
                            // scatter: each gradient pixel spreads over k²
                            for a in 0..yh {
                                for b in 0..yw {
                                    let g = gop[a * yw + b];
                                    if g == 0.0 {
                                        continue;
                                    }
                                    for u in 0..kh {
                                        let row = &mut gxp[(a + u) * w + b..];
                                        for (v, wv) in
                                            wp[u * kw..][..kw].iter().enumerate()
                                        {
                                            row[v] += g * *wv;
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            });
        }
    });
    out
}

/// accGrad: `gw[j,i] = Σ_s go[s,j] ⋆ x[s,i]` (minibatch reduced).
/// Parallel over output planes j.
pub fn accgrad(p: &ConvProblem, go: &[f32], x: &[f32]) -> Vec<f32> {
    assert_eq!(p.stride, 1, "strided accGrad is vendor-only (paper §2)");
    assert_eq!(go.len(), p.output_len());
    assert_eq!(x.len(), p.input_len());
    let (yh, yw) = (p.yh(), p.yw());
    let (f, fo, h, w, kh, kw, s) = (p.f, p.fo, p.h, p.w, p.kh, p.kw, p.s);
    let mut out = vec![0f32; p.weight_len()];
    let gw_stride = f * kh * kw;
    thread::scope(|scope| {
        let mut rem: &mut [f32] = &mut out;
        for (start, len) in chunks(fo, threads()) {
            let (head, tail) = rem.split_at_mut(len * gw_stride);
            rem = tail;
            let (go, x) = (&go, &x);
            scope.spawn(move || {
                for jj in 0..len {
                    let j = start + jj;
                    let gwj = &mut head[jj * gw_stride..][..gw_stride];
                    for si in 0..s {
                        let gop = &go[(si * fo + j) * yh * yw..][..yh * yw];
                        for i in 0..f {
                            let xp = &x[(si * f + i) * h * w..][..h * w];
                            let gwp = &mut gwj[i * kh * kw..][..kh * kw];
                            for u in 0..kh {
                                for v in 0..kw {
                                    let mut acc = 0f32;
                                    for a in 0..yh {
                                        let xrow = &xp[(a + u) * w + v..];
                                        let grow = &gop[a * yw..][..yw];
                                        for (b, g) in grow.iter().enumerate() {
                                            acc += xrow[b] * *g;
                                        }
                                    }
                                    gwp[u * kw + v] += acc;
                                }
                            }
                        }
                    }
                }
            });
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Pass;
    use crate::testkit::{assert_close_oracle, oracle, tolerance};
    use crate::util::Rng;

    #[test]
    fn fprop_matches_f64_oracle() {
        let mut rng = Rng::new(1);
        for p in [ConvProblem::square(2, 3, 4, 9, 3),
                  ConvProblem::new(1, 2, 2, 8, 10, 3, 5),
                  ConvProblem::square(33, 1, 1, 5, 5)] {
            let x = rng.normal_vec(p.input_len());
            let wei = rng.normal_vec(p.weight_len());
            let got = fprop(&p, &x, &wei);
            let want = oracle::fprop64(&p, &x, &wei);
            assert_close_oracle(&got, &want,
                                tolerance::time_domain(&p, Pass::Fprop));
        }
    }

    #[test]
    fn bprop_and_accgrad_match_f64_oracle() {
        let p = ConvProblem::new(2, 3, 2, 8, 9, 3, 5);
        let mut rng = Rng::new(2);
        let go = rng.normal_vec(p.output_len());
        let wei = rng.normal_vec(p.weight_len());
        let x = rng.normal_vec(p.input_len());
        assert_close_oracle(&bprop(&p, &go, &wei),
                            &oracle::bprop64(&p, &go, &wei),
                            tolerance::time_domain(&p, Pass::Bprop));
        assert_close_oracle(&accgrad(&p, &go, &x),
                            &oracle::accgrad64(&p, &go, &x),
                            tolerance::time_domain(&p, Pass::AccGrad));
    }

    #[test]
    fn strided_fprop() {
        let mut p = ConvProblem::square(1, 1, 1, 7, 3);
        p.stride = 2;
        assert_eq!((p.yh(), p.yw()), (3, 3));
        let x: Vec<f32> = (0..49).map(|i| i as f32).collect();
        let wei = vec![0., 0., 0., 0., 1., 0., 0., 0., 0.]; // center tap
        let y = fprop(&p, &x, &wei);
        // center of window at (2a+1, 2b+1)
        assert_eq!(y, vec![8., 10., 12., 22., 24., 26., 36., 38., 40.]);
    }

    #[test]
    fn adjoint_fprop_bprop() {
        // ⟨fprop(x,w), go⟩ == ⟨x, bprop(go,w)⟩
        let p = ConvProblem::square(2, 3, 2, 8, 3);
        let mut rng = Rng::new(5);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let go = rng.normal_vec(p.output_len());
        let y = fprop(&p, &x, &wei);
        let gx = bprop(&p, &go, &wei);
        let a: f64 = y.iter().zip(&go).map(|(u, v)| (*u * *v) as f64).sum();
        let b: f64 = x.iter().zip(&gx).map(|(u, v)| (*u * *v) as f64).sum();
        assert!((a - b).abs() < 1e-2 * a.abs().max(1.0), "{a} vs {b}");
    }

    #[test]
    fn adjoint_fprop_accgrad() {
        // ⟨fprop(x,w), go⟩ == ⟨w, accgrad(go,x)⟩
        let p = ConvProblem::square(3, 2, 2, 7, 3);
        let mut rng = Rng::new(6);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let go = rng.normal_vec(p.output_len());
        let y = fprop(&p, &x, &wei);
        let gw = accgrad(&p, &go, &x);
        let a: f64 = y.iter().zip(&go).map(|(u, v)| (*u * *v) as f64).sum();
        let b: f64 = wei.iter().zip(&gw).map(|(u, v)| (*u * *v) as f64).sum();
        assert!((a - b).abs() < 1e-2 * a.abs().max(1.0), "{a} vs {b}");
    }

    #[test]
    fn identity_kernel_fprop() {
        let p = ConvProblem::square(1, 2, 2, 5, 1);
        let mut rng = Rng::new(7);
        let x = rng.normal_vec(p.input_len());
        // w[j,i,0,0] = δ_{ij}
        let mut wei = vec![0f32; p.weight_len()];
        wei[0] = 1.0;
        wei[3] = 1.0;
        let y = fprop(&p, &x, &wei);
        for (g, w) in y.iter().zip(&x) {
            assert!((g - w).abs() < 1e-6);
        }
    }
}
