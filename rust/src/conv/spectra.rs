//! The per-shard **weight-spectrum cache** — the serving tier's answer
//! to the reuse argument of Mathieu et al. (1312.5851): inference
//! weights change rarely, so their forward transform is computed once
//! per `(weight shape, basis, mode, weights_version)` and every later
//! flush skips the weight pad+FFT stages entirely
//! ([`crate::conv::FftConvEngine::fprop_spec_into`] /
//! [`bprop_spec_into`](crate::conv::FftConvEngine::bprop_spec_into) —
//! both passes transform the weights identically, so one cached
//! spectrum serves both; accGrad's B operand is the activation and is
//! never cached).
//!
//! Cached slabs default to **f16 planar storage** ([`crate::util::f16`],
//! no external deps): the bandwidth-bound CGEMM reads half the bytes,
//! dequantizing lane-wise inside the packing path. The accuracy cost is
//! gated per Table-2 case by `testkit::tolerance::frequency_f16`, and
//! `FBFFT_SPECTRA=f32` (or [`SpectrumPrecision::F32`] in config) is the
//! escape hatch back to exact f32 slabs.
//!
//! Versioned invalidation: every entry records the `weights_version` it
//! was built from. [`SpectrumCache::bump`] drops the bumped weight
//! shape's stale entries eagerly (and only those — other problems'
//! spectra survive), while `ensure` lazily rebuilds on any version
//! mismatch, so a new version serves correct spectra from its first
//! flush with zero downtime.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::fft_conv::{FftConvEngine, FftMode};
use super::problem::ConvProblem;
use crate::conv::cgemm::Workspace;

/// Storage precision for cached weight spectra.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpectrumPrecision {
    /// Exact f32 planes — bitwise identical to the uncached pipeline.
    F32,
    /// IEEE binary16 planes — half the CGEMM B-operand traffic, error
    /// bounded by the testkit's `frequency_f16` tolerance model.
    F16,
}

impl SpectrumPrecision {
    /// The configured default: f16 unless `FBFFT_SPECTRA=f32` asks for
    /// the exact-storage escape hatch.
    pub fn from_env() -> Self {
        match std::env::var("FBFFT_SPECTRA").as_deref() {
            Ok("f32") => SpectrumPrecision::F32,
            _ => SpectrumPrecision::F16,
        }
    }
}

impl Default for SpectrumPrecision {
    fn default() -> Self {
        Self::from_env()
    }
}

/// Owned planar frequency slabs of one weight tensor (`bins × fo·f`
/// lanes per plane, bin-major — exactly what `forward("freq.b")`
/// produces).
#[derive(Clone, Debug)]
pub enum SpectrumSlabs {
    F32 { re: Vec<f32>, im: Vec<f32> },
    F16 { re: Vec<u16>, im: Vec<u16> },
}

/// One cached weight spectrum: the slabs plus the identity they were
/// computed under, so the spec-path entry points can assert a match
/// instead of silently convolving with the wrong basis.
#[derive(Clone, Debug)]
pub struct WeightSpectrum {
    pub n_fft: usize,
    pub mode: FftMode,
    /// planes in the slab (`fo · f`)
    pub count: usize,
    /// the `weights_version` the slabs were transformed from
    pub version: u64,
    pub slabs: SpectrumSlabs,
}

impl WeightSpectrum {
    /// Total f32-lane count per plane (re and im each).
    pub fn len(&self) -> usize {
        match &self.slabs {
            SpectrumSlabs::F32 { re, .. } => re.len(),
            SpectrumSlabs::F16 { re, .. } => re.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident bytes of both planes — halved by f16 storage.
    pub fn bytes(&self) -> usize {
        match &self.slabs {
            SpectrumSlabs::F32 { re, im } => 4 * (re.len() + im.len()),
            SpectrumSlabs::F16 { re, im } => 2 * (re.len() + im.len()),
        }
    }

    pub fn precision(&self) -> SpectrumPrecision {
        match self.slabs {
            SpectrumSlabs::F32 { .. } => SpectrumPrecision::F32,
            SpectrumSlabs::F16 { .. } => SpectrumPrecision::F16,
        }
    }
}

/// Cache key: the weight-tensor shape plus the transform identity. The
/// batch size is deliberately absent — a weight spectrum is independent
/// of `s`, so one entry serves every flush shape of a problem (that is
/// the whole win: ragged serve batches re-tune CGEMM strategies per
/// shape but share the weight spectrum).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SpectrumKey {
    pub f: usize,
    pub fo: usize,
    pub kh: usize,
    pub kw: usize,
    pub n_fft: usize,
    pub mode: FftMode,
}

impl SpectrumKey {
    pub fn of(eng: &FftConvEngine, p: &ConvProblem) -> Self {
        SpectrumKey { f: p.f, fo: p.fo, kh: p.kh, kw: p.kw,
                      n_fft: eng.n_fft, mode: eng.mode }
    }
}

/// Counter snapshot for reports (`BENCH_serve.json`'s `spectra_*` keys).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpectrumStats {
    pub entries: usize,
    pub hits: usize,
    pub misses: usize,
    pub invalidated: usize,
}

/// The versioned weight-spectrum cache. One per shard worker: entries
/// are plain owned slabs (no locking — the worker thread owns it), and
/// the hit/miss/invalidation counters feed the shard report.
#[derive(Debug, Default)]
pub struct SpectrumCache {
    precision: SpectrumPrecision,
    entries: HashMap<SpectrumKey, WeightSpectrum>,
    pub hits: usize,
    pub misses: usize,
    pub invalidated: usize,
}

impl SpectrumCache {
    pub fn new(precision: SpectrumPrecision) -> Self {
        SpectrumCache { precision, ..Default::default() }
    }

    pub fn precision(&self) -> SpectrumPrecision {
        self.precision
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn stats(&self) -> SpectrumStats {
        SpectrumStats { entries: self.entries.len(), hits: self.hits,
                        misses: self.misses,
                        invalidated: self.invalidated }
    }

    /// Return the cached spectrum for `(p, eng, version)`, transforming
    /// the weights on a miss (or on a version mismatch — the lazy half
    /// of invalidation). The returned `Duration` is the weight-FFT time
    /// actually spent: zero on a hit, which is exactly the
    /// `weight_fft_ns == 0` statement the serve report gates on.
    pub fn ensure(&mut self, eng: &FftConvEngine, p: &ConvProblem,
                  weights: &[f32], version: u64, ws: &mut Workspace)
                  -> (&WeightSpectrum, Duration) {
        let key = SpectrumKey::of(eng, p);
        let cached = self.entries.get(&key).map(|e| e.version);
        if cached == Some(version) {
            self.hits += 1;
            return (&self.entries[&key], Duration::ZERO);
        }
        if cached.is_some() {
            self.invalidated += 1; // stale version replaced in place
        }
        self.misses += 1;
        let t0 = Instant::now();
        let spec =
            eng.weight_spectrum(p, weights, version, self.precision, ws);
        let took = t0.elapsed();
        self.entries.insert(key, spec);
        (&self.entries[&key], took)
    }

    /// Eager half of a `weights_version` bump: drop every entry of this
    /// problem's weight shape built from an older version, and only
    /// those — spectra of other problems (different weight shapes)
    /// survive untouched. Returns the number of entries dropped.
    pub fn bump(&mut self, p: &ConvProblem, new_version: u64) -> usize {
        let before = self.entries.len();
        self.entries.retain(|k, e| {
            !(k.f == p.f && k.fo == p.fo && k.kh == p.kh && k.kw == p.kw
              && e.version < new_version)
        });
        let dropped = before - self.entries.len();
        self.invalidated += dropped;
        dropped
    }

    /// Drop every cached spectrum while keeping the hit/miss/invalidated
    /// counters. Supervised shard restarts call this (via a rebuild) so
    /// a crash mid-transform can never leave a half-written spectrum
    /// serving traffic; the counters survive so reports still account
    /// for the pre-crash work.
    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

/// Per-chain-position spectrum caches for net-level serving: one
/// [`SpectrumCache`] per layer of a
/// [`NetPlan`](crate::coordinator::NetPlan), indexed by chain position
/// rather than pooled behind the shape key. Two layers with identical
/// weight *shapes* (common in the conv4/conv5 tail of AlexNet-style
/// nets) carry different weight *values*, so sharing a shape-keyed
/// cache between them would alias their spectra; positional caches keep
/// each layer's slabs and version lineage independent while the
/// summed counters still feed one shard report.
#[derive(Debug)]
pub struct LayerSpectra {
    caches: Vec<SpectrumCache>,
}

impl LayerSpectra {
    pub fn new(layers: usize, precision: SpectrumPrecision) -> Self {
        LayerSpectra {
            caches: (0..layers)
                .map(|_| SpectrumCache::new(precision))
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.caches.len()
    }

    pub fn is_empty(&self) -> bool {
        self.caches.is_empty()
    }

    /// Chain position `i`'s own cache.
    pub fn layer(&mut self, i: usize) -> &mut SpectrumCache {
        &mut self.caches[i]
    }

    /// Eagerly invalidate layer `i`'s entries for `p` below
    /// `new_version` — other layers' spectra are untouched even when
    /// their weight shapes collide.
    pub fn bump(&mut self, i: usize, p: &ConvProblem,
                new_version: u64) -> usize {
        self.caches[i].bump(p, new_version)
    }

    /// Drop every layer's cached slabs while keeping all counters
    /// (the shard-restart rebuild path).
    pub fn clear(&mut self) {
        for c in &mut self.caches {
            c.clear();
        }
    }

    pub fn hits(&self) -> usize {
        self.caches.iter().map(|c| c.hits).sum()
    }

    pub fn misses(&self) -> usize {
        self.caches.iter().map(|c| c.misses).sum()
    }

    pub fn invalidated(&self) -> usize {
        self.caches.iter().map(|c| c.invalidated).sum()
    }

    /// Counters for chain position `i` alone.
    pub fn layer_stats(&self, i: usize) -> SpectrumStats {
        self.caches[i].stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn hit_returns_zero_weight_fft_and_shared_across_batch_sizes() {
        let p = ConvProblem::square(4, 2, 3, 8, 3);
        let eng = FftConvEngine::fbfft_for(&p);
        let mut rng = Rng::new(0x5CA1E);
        let wei = rng.normal_vec(p.weight_len());
        let mut ws = Workspace::new();
        let mut cache = SpectrumCache::new(SpectrumPrecision::F16);
        let (_, d0) = cache.ensure(&eng, &p, &wei, 1, &mut ws);
        assert!(d0 > Duration::ZERO, "miss spends weight-FFT time");
        // a different batch size is the same weight tensor — still a hit
        let q = ConvProblem { s: 9, ..p };
        let (_, d1) = cache.ensure(&eng, &q, &wei, 1, &mut ws);
        assert_eq!(d1, Duration::ZERO, "hit skips the weight FFT");
        assert_eq!(cache.stats(),
                   SpectrumStats { entries: 1, hits: 1, misses: 1,
                                   invalidated: 0 });
    }

    #[test]
    fn version_mismatch_rebuilds_lazily() {
        let p = ConvProblem::square(2, 2, 2, 8, 3);
        let eng = FftConvEngine::fbfft_for(&p);
        let mut rng = Rng::new(0xBEEF);
        let w1 = rng.normal_vec(p.weight_len());
        let w2 = rng.normal_vec(p.weight_len());
        let mut ws = Workspace::new();
        let mut cache = SpectrumCache::new(SpectrumPrecision::F32);
        let (s1, _) = cache.ensure(&eng, &p, &w1, 1, &mut ws);
        let v1_slab = match &s1.slabs {
            SpectrumSlabs::F32 { re, .. } => re.clone(),
            _ => unreachable!(),
        };
        let (s2, d2) = cache.ensure(&eng, &p, &w2, 2, &mut ws);
        assert_eq!(s2.version, 2);
        assert!(d2 > Duration::ZERO, "stale entry must be rebuilt");
        let v2_slab = match &s2.slabs {
            SpectrumSlabs::F32 { re, .. } => re.clone(),
            _ => unreachable!(),
        };
        assert_ne!(v1_slab, v2_slab, "new weights, new spectrum");
        let st = cache.stats();
        assert_eq!((st.misses, st.invalidated), (2, 1));
    }

    #[test]
    fn bump_drops_exactly_the_bumped_problems_entries() {
        let pa = ConvProblem::square(2, 2, 2, 8, 3);
        let pb = ConvProblem::square(2, 3, 4, 8, 5); // different weights
        let ea = FftConvEngine::fbfft_for(&pa);
        let eb = FftConvEngine::fbfft_for(&pb);
        let mut rng = Rng::new(0xD1FF);
        let wa = rng.normal_vec(pa.weight_len());
        let wb = rng.normal_vec(pb.weight_len());
        let mut ws = Workspace::new();
        let mut cache = SpectrumCache::new(SpectrumPrecision::F16);
        cache.ensure(&ea, &pa, &wa, 1, &mut ws);
        cache.ensure(&eb, &pb, &wb, 1, &mut ws);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.bump(&pa, 2), 1, "only pa's entry dropped");
        assert_eq!(cache.len(), 1);
        // pb's spectrum survived: still a hit at its version
        let (_, d) = cache.ensure(&eb, &pb, &wb, 1, &mut ws);
        assert_eq!(d, Duration::ZERO);
        // a same-or-newer entry is never dropped by a stale bump
        cache.ensure(&ea, &pa, &wa, 2, &mut ws);
        assert_eq!(cache.bump(&pa, 2), 0);
    }

    #[test]
    fn layer_spectra_isolates_identical_weight_shapes() {
        // two chain positions with the same weight shape but different
        // values: a shape-keyed shared cache would alias them
        let p = ConvProblem::square(2, 2, 2, 8, 3);
        let eng = FftConvEngine::fbfft_for(&p);
        let mut rng = Rng::new(0xA11A5);
        let w0 = rng.normal_vec(p.weight_len());
        let w1 = rng.normal_vec(p.weight_len());
        let mut ws = Workspace::new();
        let mut ls = LayerSpectra::new(2, SpectrumPrecision::F32);
        let s0 = {
            let (s, d) = ls.layer(0).ensure(&eng, &p, &w0, 1, &mut ws);
            assert!(d > Duration::ZERO);
            match &s.slabs {
                SpectrumSlabs::F32 { re, .. } => re.clone(),
                _ => unreachable!(),
            }
        };
        let s1 = {
            let (s, d) = ls.layer(1).ensure(&eng, &p, &w1, 1, &mut ws);
            assert!(d > Duration::ZERO, "layer 1 is its own miss");
            match &s.slabs {
                SpectrumSlabs::F32 { re, .. } => re.clone(),
                _ => unreachable!(),
            }
        };
        assert_ne!(s0, s1, "positional caches must not alias");
        assert_eq!((ls.hits(), ls.misses()), (0, 2));
        // bumping layer 0 leaves layer 1's same-shaped entry intact
        assert_eq!(ls.bump(0, &p, 2), 1);
        let (_, d) = ls.layer(1).ensure(&eng, &p, &w1, 1, &mut ws);
        assert_eq!(d, Duration::ZERO, "layer 1 still hits");
        assert_eq!(ls.invalidated(), 1);
        ls.clear();
        assert_eq!(ls.misses(), 2, "clear keeps counters");
    }

    #[test]
    fn f16_storage_halves_resident_bytes() {
        let p = ConvProblem::square(2, 4, 4, 8, 3);
        let eng = FftConvEngine::fbfft_for(&p);
        let mut rng = Rng::new(0xB17E5);
        let wei = rng.normal_vec(p.weight_len());
        let mut ws = Workspace::new();
        let h = eng.weight_spectrum(&p, &wei, 1, SpectrumPrecision::F16,
                                    &mut ws);
        let f = eng.weight_spectrum(&p, &wei, 1, SpectrumPrecision::F32,
                                    &mut ws);
        assert_eq!(h.len(), f.len());
        assert_eq!(2 * h.bytes(), f.bytes());
        assert_eq!(h.precision(), SpectrumPrecision::F16);
    }
}
