//! Blocked, multithreaded frequency-domain CGEMM — the per-bin batched
//! complex GEMM engine behind Table 1's `CGEMM` stage.
//!
//! Table 5 shows that once batch and feature counts grow, this stage —
//! not the transforms — dominates FFT-convolution runtime, and Zlateski
//! et al. (1809.07851) make the same point for CPU reproductions: FFT
//! conv wins only when the frequency-domain GEMM is cache-blocked and
//! vectorized. Design, per the batched formulation of Mathieu et al.
//! (1312.5851):
//!
//! * **one shape vocabulary** ([`BinShape`]) covering the three
//!   conjugation patterns of §2 — fprop `X·conj(W)ᵀ`, bprop `Go·W`,
//!   accGrad `conj(Go)ᵀ·X` (minibatch reduction) — as stride + conjugate
//!   flags, so packing and the microkernel are written once;
//! * **interleaved→planar packing**: operand panels are repacked from
//!   interleaved `C32` into separate re/im `f32` planes (conjugation
//!   becomes a sign flip at pack time, transposition a stride);
//! * **register-blocked microkernel** on split re/im accumulators, with
//!   the tile geometry chosen per [`SimdTier`] ([`Kernel`]): the scalar
//!   reference runs the legacy 4×8 tile bit-for-bit, the AVX2+FMA
//!   kernel a 6×8 tile (12 ymm accumulators), the AVX-512 kernel an
//!   8×16 tile (16 zmm accumulators), all fed by [`KC`]/[`MC`]/[`NC`]-
//!   blocked panels so the working set stays cache-resident;
//! * **`std::thread::scope` parallelism over bin ranges** (bins are
//!   independent small GEMMs; the output is bin-major so per-thread
//!   chunks are contiguous), sized by [`crate::util::threads`];
//! * **zero steady-state allocation**: packing panels come from the
//!   [`Workspace`] pool and are returned after each call.
//!
//! Exactness across tiers: packing is pure data movement (copies, sign
//! flips, IEEE-exact f16 dequant), so identical panels reach the
//! microkernel whatever the storage path — the planar-vs-interleaved and
//! f16-vs-f32 bitwise gates hold at every tier. The FMA microkernels
//! contract rounding differently from the scalar tile, so *cross-tier*
//! comparison is tolerance-gated, with the scalar tier as the anchor.

use std::thread;

use crate::coordinator::{BufferPool, Pass};
use crate::fft::C32;
use crate::util::simd::{self, SimdTier};
use crate::util::{chunk_ranges, threads};

/// Scalar-tier microkernel tile rows (the legacy reference geometry —
/// MR·NR·2 accumulators must fit the register file).
pub const MR: usize = 4;
/// Scalar-tier microkernel tile columns.
pub const NR: usize = 8;
/// AVX2 tile: 6 rows × one ymm column group = 12 accumulator registers
/// (+2 operand broadcasts + 2 B rows ≈ the full 16-reg ymm file).
const A2_MR: usize = 6;
const A2_NR: usize = 8;
/// AVX-512 tile: 8 rows × one zmm column group = 16 of 32 zmm
/// accumulators, leaving room for operands and loop state.
#[cfg(all(target_arch = "x86_64", fbfft_avx512))]
const A5_MR: usize = 8;
#[cfg(all(target_arch = "x86_64", fbfft_avx512))]
const A5_NR: usize = 16;
/// Upper bounds over every tier's tile geometry — the accumulator
/// scratch is sized once for the worst case.
const MAX_MR: usize = 8;
const MAX_NR: usize = 16;
const MAX_ACC: usize = MAX_MR * MAX_NR;
/// Reduction-depth panel: one packed A panel of `mr×KC` and B panel of
/// `KC×nr` stream through L1 per microkernel call.
pub const KC: usize = 256;
/// Row block: the packed A block (`MC×KC` re + im planes) targets L2.
pub const MC: usize = 64;
/// Column block: the packed B block (`KC×NC` re + im planes) targets L2.
pub const NC: usize = 128;

/// Below this many complex MACs per call the thread fan-out costs more
/// than it buys (the §6 tiled engine issues thousands of tiny calls);
/// run single-threaded on the caller's thread instead.
const PARALLEL_MACS: usize = 1 << 17;

/// The reusable buffer arena threaded through the frequency-convolution
/// pipeline (`forward` / CGEMM / `inverse`): a role-keyed [`BufferPool`]
/// with both `f32` and `C32` planes. After one warmup pass per problem
/// shape, every checkout is a reuse — steady-state pass execution
/// performs zero heap allocation (asserted via the pool counters in
/// `tests/workspace_alloc.rs`).
#[derive(Debug, Default)]
pub struct Workspace {
    pub pool: BufferPool,
}

impl Workspace {
    pub fn new() -> Self {
        Workspace { pool: BufferPool::new() }
    }
}

/// One frequency bin's GEMM, `C[m×n] (+)= op(A)·op(B)` with the reduction
/// over `k`, expressed as strides into the bin-major slabs plus
/// conjugation flags. `of()` maps each training pass of §2 onto it.
#[derive(Clone, Copy, Debug)]
pub struct BinShape {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// elements per bin in the A / B / C slabs
    pub a_len: usize,
    pub b_len: usize,
    pub c_len: usize,
    /// `A[m,k]` lives at `m·a_mstride + k·a_kstride`
    pub a_mstride: usize,
    pub a_kstride: usize,
    pub conj_a: bool,
    /// `B[k,n]` lives at `n·b_nstride + k·b_kstride`
    pub b_nstride: usize,
    pub b_kstride: usize,
    pub conj_b: bool,
}

impl BinShape {
    /// The three conjugation patterns of §2 on the bin-major layout
    /// (A-slab rows are `S×f` or `S×f'`, B-slab rows `f'×f` or `S×f`):
    ///
    /// * fprop:   `Out[s,j] = Σ_i X[s,i]·conj(W[j,i])`
    /// * bprop:   `Gx[s,i]  = Σ_j Go[s,j]·W[j,i]`
    /// * accGrad: `Gw[j,i]  = Σ_s conj(Go[s,j])·X[s,i]`
    pub fn of(pass: Pass, s: usize, f: usize, fo: usize) -> BinShape {
        match pass {
            // A = X (S×f), B = W (f'×f), C = Out (S×f')
            Pass::Fprop => BinShape {
                m: s, n: fo, k: f,
                a_len: s * f, b_len: fo * f, c_len: s * fo,
                a_mstride: f, a_kstride: 1, conj_a: false,
                b_nstride: f, b_kstride: 1, conj_b: true,
            },
            // A = Go (S×f'), B = W (f'×f), C = Gx (S×f)
            Pass::Bprop => BinShape {
                m: s, n: f, k: fo,
                a_len: s * fo, b_len: fo * f, c_len: s * f,
                a_mstride: fo, a_kstride: 1, conj_a: false,
                b_nstride: 1, b_kstride: f, conj_b: false,
            },
            // A = Go (S×f', k-major), B = X (S×f, k-major), C = Gw (f'×f)
            Pass::AccGrad => BinShape {
                m: fo, n: f, k: s,
                a_len: s * fo, b_len: s * f, c_len: fo * f,
                a_mstride: 1, a_kstride: fo, conj_a: true,
                b_nstride: 1, b_kstride: f, conj_b: false,
            },
        }
    }
}

/// Round `x` up to a multiple of `to`.
fn round_up(x: usize, to: usize) -> usize {
    x.div_ceil(to) * to
}

/// One tier's microkernel geometry + dispatch handle. Constructing a
/// non-scalar kernel asserts nothing by itself; the safety invariant —
/// the tier never exceeds [`simd::detected`] — is upheld by
/// [`Kernel::active`] (which resolves through `simd::tier()`) and by
/// the tier-explicit test entries, which guard on detection.
#[derive(Clone, Copy, Debug)]
struct Kernel {
    tier: SimdTier,
    mr: usize,
    nr: usize,
}

impl Kernel {
    fn for_tier(tier: SimdTier) -> Kernel {
        match tier {
            SimdTier::Scalar => Kernel { tier, mr: MR, nr: NR },
            SimdTier::Avx2 => Kernel { tier, mr: A2_MR, nr: A2_NR },
            SimdTier::Avx512 => {
                #[cfg(all(target_arch = "x86_64", fbfft_avx512))]
                {
                    Kernel { tier, mr: A5_MR, nr: A5_NR }
                }
                #[cfg(not(all(target_arch = "x86_64", fbfft_avx512)))]
                {
                    // toolchain gate off: the tier is never detected,
                    // but a forced request degrades to the AVX2 shape
                    Kernel { tier: SimdTier::Avx2, mr: A2_MR, nr: A2_NR }
                }
            }
        }
    }

    /// The kernel for the active dispatch tier.
    fn active() -> Kernel {
        Kernel::for_tier(simd::tier())
    }

    /// Run one `mr×nr` tile over a `kc`-deep packed panel pair, leaving
    /// the products in the flat accumulator scratch (row stride `nr`).
    /// Every tier fully (re)writes rows `0..mr` — callers never zero.
    #[allow(clippy::too_many_arguments)]
    #[inline(always)]
    fn run(&self, kc: usize, apr: &[f32], api: &[f32], bpr: &[f32],
           bpi: &[f32], acc_re: &mut [f32; MAX_ACC],
           acc_im: &mut [f32; MAX_ACC]) {
        match self.tier {
            #[cfg(target_arch = "x86_64")]
            SimdTier::Avx2 => {
                // SAFETY: Avx2 kernels are only constructed when runtime
                // detection confirmed avx2+fma (see the type-level
                // invariant above).
                unsafe {
                    microkernel_avx2(kc, apr, api, bpr, bpi, acc_re,
                                     acc_im)
                }
            }
            #[cfg(all(target_arch = "x86_64", fbfft_avx512))]
            SimdTier::Avx512 => {
                // SAFETY: as above, with detected avx512f.
                unsafe {
                    microkernel_avx512(kc, apr, api, bpr, bpi, acc_re,
                                       acc_im)
                }
            }
            _ => microkernel_scalar(kc, self.mr, self.nr, apr, api, bpr,
                                    bpi, acc_re, acc_im),
        }
    }
}

/// Read-only complex operand view: the packing kernels are written once
/// and monomorphize over the storage — interleaved `C32` slabs (vendor /
/// scalar-fbfft staging) or the split-complex re/im planes the SoA fbfft
/// transforms emit natively ([`batched_planar`]'s *pack-from-planar*
/// path: no interleave shuffle ever runs between the FFT and the FMAs).
trait CMat {
    fn load(&self, idx: usize) -> (f32, f32);

    /// Unit-stride pack run: `out[t] = element idx+t` with the im plane
    /// scaled by `sign` (±1, the conjugation flag). The storage types
    /// override this with SIMD-exact bulk moves; results are bitwise
    /// identical to the element loop at every tier.
    fn load_run(&self, idx: usize, len: usize, sign: f32,
                out_re: &mut [f32], out_im: &mut [f32]) {
        for t in 0..len {
            let (vr, vi) = self.load(idx + t);
            out_re[t] = vr;
            out_im[t] = sign * vi;
        }
    }

    /// True when the storage wants k-major pack runs even at the cost of
    /// a scatter through a stack strip (the f16 slabs: hardware dequant
    /// is 8 halves per instruction, so contiguous runs pay for the extra
    /// copy).
    fn prefers_k_runs(&self) -> bool {
        false
    }
}

struct InterMat<'a>(&'a [C32]);

impl CMat for InterMat<'_> {
    #[inline(always)]
    fn load(&self, idx: usize) -> (f32, f32) {
        let v = self.0[idx];
        (v.re, v.im)
    }
}

struct PlanarMat<'a> {
    re: &'a [f32],
    im: &'a [f32],
}

impl CMat for PlanarMat<'_> {
    #[inline(always)]
    fn load(&self, idx: usize) -> (f32, f32) {
        (self.re[idx], self.im[idx])
    }

    #[inline]
    fn load_run(&self, idx: usize, len: usize, sign: f32,
                out_re: &mut [f32], out_im: &mut [f32]) {
        out_re[..len].copy_from_slice(&self.re[idx..idx + len]);
        simd::copy_signed(&self.im[idx..idx + len], &mut out_im[..len],
                          sign < 0.0);
    }
}

/// Split-complex planes stored as IEEE binary16 bits — the serving
/// tier's cached weight spectra ([`crate::conv::spectra`]). Dequantizing
/// here, inside the `pack_b` element load, means the f16 slabs go
/// straight into the packed panels: the B operand's memory traffic is
/// halved and no intermediate f32 copy of the spectrum ever exists. The
/// run path rides [`simd::f16_dequant`] (hardware F16C on the AVX
/// tiers, bitwise the software decoder).
struct F16PlanarMat<'a> {
    re: &'a [u16],
    im: &'a [u16],
}

impl CMat for F16PlanarMat<'_> {
    #[inline(always)]
    fn load(&self, idx: usize) -> (f32, f32) {
        (crate::util::f16::f16_to_f32(self.re[idx]),
         crate::util::f16::f16_to_f32(self.im[idx]))
    }

    #[inline]
    fn load_run(&self, idx: usize, len: usize, sign: f32,
                out_re: &mut [f32], out_im: &mut [f32]) {
        simd::f16_dequant(&self.re[idx..idx + len], &mut out_re[..len],
                          false);
        simd::f16_dequant(&self.im[idx..idx + len], &mut out_im[..len],
                          sign < 0.0);
    }

    fn prefers_k_runs(&self) -> bool {
        true
    }
}

/// Mutable complex output view — the writeback twin of [`CMat`].
/// [`batched_planar`]'s *store-planar* side keeps the product planar so
/// the SoA inverse transform consumes it without re-interleaving.
trait CSink {
    fn store(&mut self, idx: usize, re: f32, im: f32, first: bool);
}

struct InterSink<'a>(&'a mut [C32]);

impl CSink for InterSink<'_> {
    #[inline(always)]
    fn store(&mut self, idx: usize, re: f32, im: f32, first: bool) {
        let v = C32::new(re, im);
        if first {
            self.0[idx] = v;
        } else {
            self.0[idx] += v;
        }
    }
}

struct PlanarSink<'a> {
    re: &'a mut [f32],
    im: &'a mut [f32],
}

impl CSink for PlanarSink<'_> {
    #[inline(always)]
    fn store(&mut self, idx: usize, re: f32, im: f32, first: bool) {
        if first {
            self.re[idx] = re;
            self.im[idx] = im;
        } else {
            self.re[idx] += re;
            self.im[idx] += im;
        }
    }
}

/// Pack an `mc×kc` block of A into planar re/im panels of `mr` rows:
/// element `(ir·mr+mi, kk)` lands at `(ir·kc + kk)·mr + mi`, rows beyond
/// `mc` zero-padded so the microkernel never branches on ragged edges.
/// Conjugation folds into the imaginary plane's sign. Full tiles of a
/// unit-`m`-stride operand (accGrad's A) take the bulk `load_run` path —
/// same bits, fewer address computations.
#[allow(clippy::too_many_arguments)]
fn pack_a<A: CMat>(sh: &BinShape, a: &A, mr: usize, m0: usize, mc: usize,
                   p0: usize, kc: usize, out_re: &mut [f32],
                   out_im: &mut [f32]) {
    let sign = if sh.conj_a { -1.0f32 } else { 1.0 };
    for ir in 0..mc.div_ceil(mr) {
        let base = ir * kc * mr;
        let full = (ir + 1) * mr <= mc;
        if full && sh.a_mstride == 1 {
            for kk in 0..kc {
                let ks = (p0 + kk) * sh.a_kstride;
                let row = base + kk * mr;
                a.load_run(m0 + ir * mr + ks, mr, sign,
                           &mut out_re[row..row + mr],
                           &mut out_im[row..row + mr]);
            }
            continue;
        }
        for kk in 0..kc {
            let ks = (p0 + kk) * sh.a_kstride;
            for mi in 0..mr {
                let idx = base + kk * mr + mi;
                let mrow = ir * mr + mi;
                if mrow < mc {
                    let (vr, vi) = a.load((m0 + mrow) * sh.a_mstride + ks);
                    out_re[idx] = vr;
                    out_im[idx] = sign * vi;
                } else {
                    out_re[idx] = 0.0;
                    out_im[idx] = 0.0;
                }
            }
        }
    }
}

/// Pack a `kc×nc` block of B into planar re/im panels of `nr` columns
/// (mirror of [`pack_a`]). Two bulk paths: unit-`n`-stride operands
/// (bprop/accGrad B) run across the tile columns; unit-`k`-stride f16
/// slabs (fprop's cached weight spectrum) dequantize whole `kc` runs
/// through a stack strip and scatter — the hardware-dequant fast path of
/// [`batched_planar_f16b`]. All paths emit bit-identical panels.
#[allow(clippy::too_many_arguments)]
fn pack_b<B: CMat>(sh: &BinShape, b: &B, nr: usize, p0: usize, kc: usize,
                   n0: usize, nc: usize, out_re: &mut [f32],
                   out_im: &mut [f32]) {
    let sign = if sh.conj_b { -1.0f32 } else { 1.0 };
    for jr in 0..nc.div_ceil(nr) {
        let base = jr * kc * nr;
        let full = (jr + 1) * nr <= nc;
        if full && sh.b_nstride == 1 {
            for kk in 0..kc {
                let ks = (p0 + kk) * sh.b_kstride;
                let row = base + kk * nr;
                b.load_run(n0 + jr * nr + ks, nr, sign,
                           &mut out_re[row..row + nr],
                           &mut out_im[row..row + nr]);
            }
            continue;
        }
        if sh.b_kstride == 1 && b.prefers_k_runs() {
            debug_assert!(kc <= KC);
            let mut strip_re = [0f32; KC];
            let mut strip_im = [0f32; KC];
            for ni in 0..nr {
                let ncol = jr * nr + ni;
                if ncol < nc {
                    b.load_run((n0 + ncol) * sh.b_nstride + p0, kc, sign,
                               &mut strip_re[..kc], &mut strip_im[..kc]);
                    for kk in 0..kc {
                        out_re[base + kk * nr + ni] = strip_re[kk];
                        out_im[base + kk * nr + ni] = strip_im[kk];
                    }
                } else {
                    for kk in 0..kc {
                        out_re[base + kk * nr + ni] = 0.0;
                        out_im[base + kk * nr + ni] = 0.0;
                    }
                }
            }
            continue;
        }
        for kk in 0..kc {
            let ks = (p0 + kk) * sh.b_kstride;
            for ni in 0..nr {
                let idx = base + kk * nr + ni;
                let ncol = jr * nr + ni;
                if ncol < nc {
                    let (vr, vi) = b.load((n0 + ncol) * sh.b_nstride + ks);
                    out_re[idx] = vr;
                    out_im[idx] = sign * vi;
                } else {
                    out_re[idx] = 0.0;
                    out_im[idx] = 0.0;
                }
            }
        }
    }
}

/// The scalar reference microkernel, geometry-generic: `mr×nr` split
/// re/im accumulators (flat, row stride `nr`), rank-1 updated per
/// reduction step from one packed A column and one packed B row. At the
/// scalar tier's 4×8 tile this is op-for-op the pre-dispatch kernel —
/// separate mul/sub, no fused contraction — so the scalar tier stays
/// bit-identical to the legacy tree.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn microkernel_scalar(kc: usize, mr: usize, nr: usize, apr: &[f32],
                      api: &[f32], bpr: &[f32], bpi: &[f32],
                      acc_re: &mut [f32; MAX_ACC],
                      acc_im: &mut [f32; MAX_ACC]) {
    acc_re[..mr * nr].fill(0.0);
    acc_im[..mr * nr].fill(0.0);
    for kk in 0..kc {
        let b_re = &bpr[kk * nr..kk * nr + nr];
        let b_im = &bpi[kk * nr..kk * nr + nr];
        let a_re = &apr[kk * mr..kk * mr + mr];
        let a_im = &api[kk * mr..kk * mr + mr];
        for mi in 0..mr {
            let ar = a_re[mi];
            let ai = a_im[mi];
            let cr = &mut acc_re[mi * nr..mi * nr + nr];
            let ci = &mut acc_im[mi * nr..mi * nr + nr];
            for ni in 0..nr {
                cr[ni] += ar * b_re[ni] - ai * b_im[ni];
                ci[ni] += ar * b_im[ni] + ai * b_re[ni];
            }
        }
    }
}

/// AVX2+FMA microkernel, 6×8 tile: 12 ymm accumulators live across the
/// whole `kc` loop, one broadcast pair per A element, the complex MAC as
/// an `fmadd`/`fnmadd`/`fmadd`/`fmadd` quartet — the §5-style
/// "hand-shaped" kernel the paper's thesis calls for, on host FMA width.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn microkernel_avx2(kc: usize, apr: &[f32], api: &[f32],
                           bpr: &[f32], bpi: &[f32],
                           acc_re: &mut [f32; MAX_ACC],
                           acc_im: &mut [f32; MAX_ACC]) {
    use std::arch::x86_64::*;
    debug_assert!(apr.len() >= kc * A2_MR && api.len() >= kc * A2_MR);
    debug_assert!(bpr.len() >= kc * A2_NR && bpi.len() >= kc * A2_NR);
    let mut cr = [_mm256_setzero_ps(); A2_MR];
    let mut ci = [_mm256_setzero_ps(); A2_MR];
    let (ap, aip) = (apr.as_ptr(), api.as_ptr());
    let (bp, bip) = (bpr.as_ptr(), bpi.as_ptr());
    for kk in 0..kc {
        let br = _mm256_loadu_ps(bp.add(kk * A2_NR));
        let bi = _mm256_loadu_ps(bip.add(kk * A2_NR));
        for mi in 0..A2_MR {
            let ar = _mm256_set1_ps(*ap.add(kk * A2_MR + mi));
            let ai = _mm256_set1_ps(*aip.add(kk * A2_MR + mi));
            cr[mi] = _mm256_fmadd_ps(ar, br, cr[mi]);
            cr[mi] = _mm256_fnmadd_ps(ai, bi, cr[mi]);
            ci[mi] = _mm256_fmadd_ps(ar, bi, ci[mi]);
            ci[mi] = _mm256_fmadd_ps(ai, br, ci[mi]);
        }
    }
    for mi in 0..A2_MR {
        _mm256_storeu_ps(acc_re.as_mut_ptr().add(mi * A2_NR), cr[mi]);
        _mm256_storeu_ps(acc_im.as_mut_ptr().add(mi * A2_NR), ci[mi]);
    }
}

/// AVX-512F microkernel, 8×16 tile: 16 zmm accumulators, same complex
/// MAC structure as the AVX2 kernel at double width.
#[cfg(all(target_arch = "x86_64", fbfft_avx512))]
#[target_feature(enable = "avx512f")]
unsafe fn microkernel_avx512(kc: usize, apr: &[f32], api: &[f32],
                             bpr: &[f32], bpi: &[f32],
                             acc_re: &mut [f32; MAX_ACC],
                             acc_im: &mut [f32; MAX_ACC]) {
    use std::arch::x86_64::*;
    debug_assert!(apr.len() >= kc * A5_MR && api.len() >= kc * A5_MR);
    debug_assert!(bpr.len() >= kc * A5_NR && bpi.len() >= kc * A5_NR);
    let mut cr = [_mm512_setzero_ps(); A5_MR];
    let mut ci = [_mm512_setzero_ps(); A5_MR];
    let (ap, aip) = (apr.as_ptr(), api.as_ptr());
    let (bp, bip) = (bpr.as_ptr(), bpi.as_ptr());
    for kk in 0..kc {
        let br = _mm512_loadu_ps(bp.add(kk * A5_NR));
        let bi = _mm512_loadu_ps(bip.add(kk * A5_NR));
        for mi in 0..A5_MR {
            let ar = _mm512_set1_ps(*ap.add(kk * A5_MR + mi));
            let ai = _mm512_set1_ps(*aip.add(kk * A5_MR + mi));
            cr[mi] = _mm512_fmadd_ps(ar, br, cr[mi]);
            cr[mi] = _mm512_fnmadd_ps(ai, bi, cr[mi]);
            ci[mi] = _mm512_fmadd_ps(ar, bi, ci[mi]);
            ci[mi] = _mm512_fmadd_ps(ai, br, ci[mi]);
        }
    }
    for mi in 0..A5_MR {
        _mm512_storeu_ps(acc_re.as_mut_ptr().add(mi * A5_NR), cr[mi]);
        _mm512_storeu_ps(acc_im.as_mut_ptr().add(mi * A5_NR), ci[mi]);
    }
}

/// Store one accumulator tile (flat, row stride `nr`) into the
/// row-major output view, clipping ragged edges. `first` selects store
/// vs accumulate (the k-block loop's semantics).
#[allow(clippy::too_many_arguments)]
fn writeback<S: CSink>(acc_re: &[f32], acc_im: &[f32], nr: usize,
                       c: &mut S, m0: usize, mr_eff: usize, n0: usize,
                       nr_eff: usize, ldc: usize, first: bool) {
    for mi in 0..mr_eff {
        let base = (m0 + mi) * ldc + n0;
        let row = mi * nr;
        for ni in 0..nr_eff {
            c.store(base + ni, acc_re[row + ni], acc_im[row + ni],
                    first);
        }
    }
}

/// One bin's blocked GEMM over pre-split packing planes.
#[allow(clippy::too_many_arguments)]
fn bin_gemm<A: CMat, B: CMat, S: CSink>(
    kern: Kernel, sh: &BinShape, a: &A, b: &B, c: &mut S, ar: &mut [f32],
    ai: &mut [f32], br: &mut [f32], bi: &mut [f32]) {
    let (m, n, k) = (sh.m, sh.n, sh.k);
    let (mr, nr) = (kern.mr, kern.nr);
    let mut acc_re = [0f32; MAX_ACC];
    let mut acc_im = [0f32; MAX_ACC];
    let mut p0 = 0;
    while p0 < k {
        let kc = KC.min(k - p0);
        let first = p0 == 0;
        let mut n0 = 0;
        while n0 < n {
            let nc = NC.min(n - n0);
            pack_b(sh, b, nr, p0, kc, n0, nc, br, bi);
            let mut m0 = 0;
            while m0 < m {
                let mc = MC.min(m - m0);
                pack_a(sh, a, mr, m0, mc, p0, kc, ar, ai);
                let mut jr = 0;
                while jr * nr < nc {
                    let nr_eff = nr.min(nc - jr * nr);
                    let bpr = &br[jr * kc * nr..][..kc * nr];
                    let bpi = &bi[jr * kc * nr..][..kc * nr];
                    let mut ir = 0;
                    while ir * mr < mc {
                        let mr_eff = mr.min(mc - ir * mr);
                        let apr = &ar[ir * kc * mr..][..kc * mr];
                        let api = &ai[ir * kc * mr..][..kc * mr];
                        kern.run(kc, apr, api, bpr, bpi, &mut acc_re,
                                 &mut acc_im);
                        writeback(&acc_re, &acc_im, nr, c, m0 + ir * mr,
                                  mr_eff, n0 + jr * nr, nr_eff, n,
                                  first);
                        ir += 1;
                    }
                    jr += 1;
                }
                m0 += mc;
            }
            n0 += nc;
        }
        p0 += kc;
    }
}

/// Batched per-bin complex GEMM over `bins` frequency bins in bin-major
/// slabs: `a` is `bins × a_len`, `b` is `bins × b_len`, `c` (overwritten)
/// is `bins × c_len`, with the per-bin shapes of [`BinShape::of`].
/// Threads over contiguous bin ranges; packing panels come from `ws` so
/// the steady state allocates nothing. The microkernel tier is resolved
/// once here ([`Kernel::active`]) and inherited by the workers.
#[allow(clippy::too_many_arguments)]
pub fn batched(pass: Pass, bins: usize, s: usize, f: usize, fo: usize,
               a: &[C32], b: &[C32], c: &mut [C32], ws: &mut Workspace) {
    batched_with(Kernel::active(), pass, bins, s, f, fo, a, b, c, ws);
}

#[allow(clippy::too_many_arguments)]
fn batched_with(kern: Kernel, pass: Pass, bins: usize, s: usize,
                f: usize, fo: usize, a: &[C32], b: &[C32], c: &mut [C32],
                ws: &mut Workspace) {
    let sh = BinShape::of(pass, s, f, fo);
    assert_eq!(a.len(), bins * sh.a_len, "A slab length");
    assert_eq!(b.len(), bins * sh.b_len, "B slab length");
    assert_eq!(c.len(), bins * sh.c_len, "C slab length");
    if bins == 0 {
        return;
    }
    let kc_max = sh.k.min(KC);
    let a_sz = round_up(sh.m.min(MC), kern.mr) * kc_max;
    let b_sz = round_up(sh.n.min(NC), kern.nr) * kc_max;
    let per_thread = 2 * (a_sz + b_sz);
    let macs = bins * sh.m * sh.n * sh.k;
    let nthreads = if macs < PARALLEL_MACS {
        1
    } else {
        threads().min(bins)
    };
    let mut pack = ws.pool.take_raw("cgemm.pack", nthreads * per_thread);
    thread::scope(|scope| {
        let mut c_rem: &mut [C32] = c;
        let mut p_rem: &mut [f32] = &mut pack;
        for (start, len) in chunk_ranges(bins, nthreads) {
            let (c_head, c_tail) = c_rem.split_at_mut(len * sh.c_len);
            c_rem = c_tail;
            let (p_head, p_tail) = p_rem.split_at_mut(per_thread);
            p_rem = p_tail;
            let worker = move || {
                let (ar, rest) = p_head.split_at_mut(a_sz);
                let (ai, rest) = rest.split_at_mut(a_sz);
                let (br, bi) = rest.split_at_mut(b_sz);
                for (qi, cq) in c_head.chunks_mut(sh.c_len).enumerate() {
                    let q = start + qi;
                    bin_gemm(kern, &sh,
                             &InterMat(&a[q * sh.a_len..][..sh.a_len]),
                             &InterMat(&b[q * sh.b_len..][..sh.b_len]),
                             &mut InterSink(cq), ar, ai, br, bi);
                }
            };
            if nthreads == 1 {
                // below the fan-out threshold: run on the caller's thread
                let mut run_now = worker;
                run_now();
            } else {
                scope.spawn(worker);
            }
        }
    });
    ws.pool.put("cgemm.pack", pack);
}

/// [`batched`] over split-complex operands: the slabs arrive and leave as
/// separate re/im `f32` planes (`bins × len` each), exactly the layout
/// the SoA fbfft transforms produce — so in fbfft mode the
/// interleaved→planar pack/unpack conversions that used to sit between
/// the transforms and the microkernel are **elided entirely**; panel
/// packing reads planar (`pack_from_planar`) and writeback stores planar.
/// Arithmetic is identical to [`batched`] (same packed panels, same
/// microkernel, same order) — the two entry points agree bitwise.
#[allow(clippy::too_many_arguments)]
pub fn batched_planar(pass: Pass, bins: usize, s: usize, f: usize,
                      fo: usize, a_re: &[f32], a_im: &[f32], b_re: &[f32],
                      b_im: &[f32], c_re: &mut [f32], c_im: &mut [f32],
                      ws: &mut Workspace) {
    batched_planar_with(Kernel::active(), pass, bins, s, f, fo, a_re,
                        a_im, b_re, b_im, c_re, c_im, ws);
}

#[allow(clippy::too_many_arguments)]
fn batched_planar_with(kern: Kernel, pass: Pass, bins: usize, s: usize,
                       f: usize, fo: usize, a_re: &[f32], a_im: &[f32],
                       b_re: &[f32], b_im: &[f32], c_re: &mut [f32],
                       c_im: &mut [f32], ws: &mut Workspace) {
    let sh = BinShape::of(pass, s, f, fo);
    assert_eq!(b_re.len(), bins * sh.b_len, "B re plane length");
    assert_eq!(b_im.len(), bins * sh.b_len, "B im plane length");
    planar_driver(kern, &sh, bins, a_re, a_im,
                  &|q| PlanarMat {
                      re: &b_re[q * sh.b_len..][..sh.b_len],
                      im: &b_im[q * sh.b_len..][..sh.b_len],
                  },
                  c_re, c_im, ws);
}

/// [`batched_planar`] with the B operand held as f16 bit planes — the
/// cached-weight-spectrum fast path of the serving tier. The A operand
/// (the per-flush activations) and the product stay f32; only the cached
/// spectrum is reduced precision, dequantized lane-wise in `pack_b` via
/// [`F16PlanarMat`] (hardware F16C on the AVX tiers). Arithmetic order
/// is identical to [`batched_planar`] on the dequantized values (same
/// panels, same microkernel), so the two agree bitwise when the f32 B
/// operand is exactly f16-representable.
#[allow(clippy::too_many_arguments)]
pub fn batched_planar_f16b(pass: Pass, bins: usize, s: usize, f: usize,
                           fo: usize, a_re: &[f32], a_im: &[f32],
                           b_re: &[u16], b_im: &[u16], c_re: &mut [f32],
                           c_im: &mut [f32], ws: &mut Workspace) {
    let kern = Kernel::active();
    let sh = BinShape::of(pass, s, f, fo);
    assert_eq!(b_re.len(), bins * sh.b_len, "B re plane length");
    assert_eq!(b_im.len(), bins * sh.b_len, "B im plane length");
    planar_driver(kern, &sh, bins, a_re, a_im,
                  &|q| F16PlanarMat {
                      re: &b_re[q * sh.b_len..][..sh.b_len],
                      im: &b_im[q * sh.b_len..][..sh.b_len],
                  },
                  c_re, c_im, ws);
}

/// The shared planar-GEMM body: blocked/threaded exactly like
/// [`batched`], with the B operand abstracted as a per-bin [`CMat`]
/// factory so the f32 and f16 storage paths monomorphize from one
/// implementation.
#[allow(clippy::too_many_arguments)]
fn planar_driver<BV, FB>(kern: Kernel, sh: &BinShape, bins: usize,
                         a_re: &[f32], a_im: &[f32], b_of: &FB,
                         c_re: &mut [f32], c_im: &mut [f32],
                         ws: &mut Workspace)
where
    BV: CMat,
    FB: Fn(usize) -> BV + Sync,
{
    assert_eq!(a_re.len(), bins * sh.a_len, "A re plane length");
    assert_eq!(a_im.len(), bins * sh.a_len, "A im plane length");
    assert_eq!(c_re.len(), bins * sh.c_len, "C re plane length");
    assert_eq!(c_im.len(), bins * sh.c_len, "C im plane length");
    if bins == 0 {
        return;
    }
    let kc_max = sh.k.min(KC);
    let a_sz = round_up(sh.m.min(MC), kern.mr) * kc_max;
    let b_sz = round_up(sh.n.min(NC), kern.nr) * kc_max;
    let per_thread = 2 * (a_sz + b_sz);
    let macs = bins * sh.m * sh.n * sh.k;
    let nthreads = if macs < PARALLEL_MACS {
        1
    } else {
        threads().min(bins)
    };
    let mut pack = ws.pool.take_raw("cgemm.pack", nthreads * per_thread);
    thread::scope(|scope| {
        let mut cr_rem: &mut [f32] = c_re;
        let mut ci_rem: &mut [f32] = c_im;
        let mut p_rem: &mut [f32] = &mut pack;
        for (start, len) in chunk_ranges(bins, nthreads) {
            let (cr_head, cr_tail) = cr_rem.split_at_mut(len * sh.c_len);
            cr_rem = cr_tail;
            let (ci_head, ci_tail) = ci_rem.split_at_mut(len * sh.c_len);
            ci_rem = ci_tail;
            let (p_head, p_tail) = p_rem.split_at_mut(per_thread);
            p_rem = p_tail;
            let worker = move || {
                let (ar, rest) = p_head.split_at_mut(a_sz);
                let (ai, rest) = rest.split_at_mut(a_sz);
                let (br, bi) = rest.split_at_mut(b_sz);
                for qi in 0..len {
                    let q = start + qi;
                    let aq = PlanarMat {
                        re: &a_re[q * sh.a_len..][..sh.a_len],
                        im: &a_im[q * sh.a_len..][..sh.a_len],
                    };
                    let bq = b_of(q);
                    let mut cq = PlanarSink {
                        re: &mut cr_head[qi * sh.c_len..][..sh.c_len],
                        im: &mut ci_head[qi * sh.c_len..][..sh.c_len],
                    };
                    bin_gemm(kern, sh, &aq, &bq, &mut cq, ar, ai, br,
                             bi);
                }
            };
            if nthreads == 1 {
                let mut run_now = worker;
                run_now();
            } else {
                scope.spawn(worker);
            }
        }
    });
    ws.pool.put("cgemm.pack", pack);
}

/// The pre-blocking reference: the naive scalar `C32` triple loop the
/// engine replaced, kept verbatim as the conformance baseline for the
/// microkernel tests and the `BENCH_fftconv.json` speedup denominator.
#[allow(clippy::too_many_arguments)]
pub fn batched_naive(pass: Pass, bins: usize, s: usize, f: usize,
                     fo: usize, a: &[C32], b: &[C32], c: &mut [C32]) {
    let sh = BinShape::of(pass, s, f, fo);
    assert_eq!(a.len(), bins * sh.a_len, "A slab length");
    assert_eq!(b.len(), bins * sh.b_len, "B slab length");
    assert_eq!(c.len(), bins * sh.c_len, "C slab length");
    c.fill(C32::ZERO);
    for q in 0..bins {
        let aq = &a[q * sh.a_len..][..sh.a_len];
        let bq = &b[q * sh.b_len..][..sh.b_len];
        let cq = &mut c[q * sh.c_len..][..sh.c_len];
        for mi in 0..sh.m {
            for kk in 0..sh.k {
                let mut av = aq[mi * sh.a_mstride + kk * sh.a_kstride];
                if sh.conj_a {
                    av = av.conj();
                }
                let crow = &mut cq[mi * sh.n..][..sh.n];
                for (ni, cv) in crow.iter_mut().enumerate() {
                    let mut bv =
                        bq[ni * sh.b_nstride + kk * sh.b_kstride];
                    if sh.conj_b {
                        bv = bv.conj();
                    }
                    *cv = cv.mul_add(av, bv);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cvec(rng: &mut Rng, len: usize) -> Vec<C32> {
        (0..len).map(|_| C32::new(rng.normal(), rng.normal())).collect()
    }

    fn check(pass: Pass, bins: usize, s: usize, f: usize, fo: usize,
             seed: u64) {
        let sh = BinShape::of(pass, s, f, fo);
        let mut rng = Rng::new(seed);
        let a = cvec(&mut rng, bins * sh.a_len);
        let b = cvec(&mut rng, bins * sh.b_len);
        let mut got = vec![C32::ZERO; bins * sh.c_len];
        let mut want = vec![C32::ZERO; bins * sh.c_len];
        let mut ws = Workspace::new();
        batched(pass, bins, s, f, fo, &a, &b, &mut got, &mut ws);
        batched_naive(pass, bins, s, f, fo, &a, &b, &mut want);
        // naive accumulates with fused mul_add, the microkernel with
        // separate mul/add (or FMA quartets on the AVX tiers) — all
        // within O(√k·eps) of exact, so the gate scales with reduction
        // depth (index/conjugation bugs are O(1))
        let tol = 1e-3 * (sh.k as f32).sqrt().max(1.0);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((*g - *w).abs() < tol,
                    "{pass:?} bins={bins} s={s} f={f} fo={fo} \
                     elem {i}: {g:?} vs {w:?}");
        }
    }

    #[test]
    fn all_passes_match_naive_on_table2_shape() {
        for pass in Pass::ALL {
            check(pass, 5, 16, 16, 16, 0x11);
        }
    }

    #[test]
    fn ragged_sizes_not_multiples_of_blocks() {
        // S, f, f' straddle every tier's mr/nr boundaries (4/6/8 rows,
        // 8/16 columns) in every way
        for pass in Pass::ALL {
            check(pass, 3, 3, 5, 7, 0x22);
            check(pass, 2, 5, 9, 17, 0x23);
            check(pass, 1, 7, 33, 12, 0x24);
        }
    }

    #[test]
    fn degenerate_one_by_one_features() {
        for pass in Pass::ALL {
            check(pass, 4, 1, 1, 1, 0x33);
            check(pass, 1, 1, 1, 1, 0x34);
        }
    }

    #[test]
    fn reduction_deeper_than_kc_blocks() {
        // accGrad reduces over S: push it past KC to hit the k-block
        // accumulate path; bprop reduces over f'
        check(Pass::AccGrad, 2, KC + 44, 4, 3, 0x44);
        check(Pass::Bprop, 2, 3, 4, KC + 7, 0x45);
    }

    #[test]
    fn big_enough_to_thread_matches_naive() {
        // clear PARALLEL_MACS so the scoped-thread path runs
        check(Pass::Fprop, 96, 8, 24, 8, 0x55);
    }

    #[test]
    fn conjugation_patterns_are_the_papers() {
        // one bin, tiny dims, independent hand-rolled formulas
        let (s, f, fo) = (2usize, 3usize, 2usize);
        let mut rng = Rng::new(0x66);
        let x = cvec(&mut rng, s * f);
        let w = cvec(&mut rng, fo * f);
        let go = cvec(&mut rng, s * fo);
        let mut ws = Workspace::new();

        let mut out = vec![C32::ZERO; s * fo];
        batched(Pass::Fprop, 1, s, f, fo, &x, &w, &mut out, &mut ws);
        for si in 0..s {
            for j in 0..fo {
                let mut want = C32::ZERO;
                for i in 0..f {
                    want += x[si * f + i] * w[j * f + i].conj();
                }
                assert!((out[si * fo + j] - want).abs() < 1e-4);
            }
        }

        let mut gx = vec![C32::ZERO; s * f];
        batched(Pass::Bprop, 1, s, f, fo, &go, &w, &mut gx, &mut ws);
        for si in 0..s {
            for i in 0..f {
                let mut want = C32::ZERO;
                for j in 0..fo {
                    want += go[si * fo + j] * w[j * f + i];
                }
                assert!((gx[si * f + i] - want).abs() < 1e-4);
            }
        }

        let mut gw = vec![C32::ZERO; fo * f];
        batched(Pass::AccGrad, 1, s, f, fo, &go, &x, &mut gw, &mut ws);
        for j in 0..fo {
            for i in 0..f {
                let mut want = C32::ZERO;
                for si in 0..s {
                    want += go[si * fo + j].conj() * x[si * f + i];
                }
                assert!((gw[j * f + i] - want).abs() < 1e-4);
            }
        }
    }

    /// Split a `C32` slice into planar planes (test-local helper).
    fn split(v: &[C32]) -> (Vec<f32>, Vec<f32>) {
        (v.iter().map(|c| c.re).collect(), v.iter().map(|c| c.im).collect())
    }

    #[test]
    fn planar_path_is_bitwise_the_interleaved_path() {
        // same panels, same microkernel, same order — the pack-from-
        // planar / store-planar path must agree exactly, not just within
        // tolerance, across all conjugation patterns and ragged shapes.
        // Holds at *every* dispatch tier: packing is exact data movement
        for (pass, bins, s, f, fo, seed) in [
            (Pass::Fprop, 5usize, 16usize, 16usize, 16usize, 0x91u64),
            (Pass::Bprop, 3, 3, 5, 7, 0x92),
            (Pass::AccGrad, 2, 5, 9, 17, 0x93),
            (Pass::AccGrad, 2, KC + 44, 4, 3, 0x94), // k-block accumulate
        ] {
            let sh = BinShape::of(pass, s, f, fo);
            let mut rng = Rng::new(seed);
            let a = cvec(&mut rng, bins * sh.a_len);
            let b = cvec(&mut rng, bins * sh.b_len);
            let mut want = vec![C32::ZERO; bins * sh.c_len];
            let mut ws = Workspace::new();
            batched(pass, bins, s, f, fo, &a, &b, &mut want, &mut ws);
            let (ar, ai) = split(&a);
            let (br, bi) = split(&b);
            let mut cr = vec![0f32; bins * sh.c_len];
            let mut ci = vec![0f32; bins * sh.c_len];
            batched_planar(pass, bins, s, f, fo, &ar, &ai, &br, &bi,
                           &mut cr, &mut ci, &mut ws);
            for (i, w) in want.iter().enumerate() {
                assert_eq!(cr[i], w.re, "{pass:?} elem {i} re");
                assert_eq!(ci[i], w.im, "{pass:?} elem {i} im");
            }
        }
    }

    #[test]
    fn planar_threaded_matches_naive() {
        // clear PARALLEL_MACS so the scoped-thread fan-out runs planar
        let (pass, bins, s, f, fo) = (Pass::Fprop, 96usize, 8, 24, 8);
        let sh = BinShape::of(pass, s, f, fo);
        let mut rng = Rng::new(0x95);
        let a = cvec(&mut rng, bins * sh.a_len);
        let b = cvec(&mut rng, bins * sh.b_len);
        let mut want = vec![C32::ZERO; bins * sh.c_len];
        batched_naive(pass, bins, s, f, fo, &a, &b, &mut want);
        let (ar, ai) = split(&a);
        let (br, bi) = split(&b);
        let mut cr = vec![0f32; bins * sh.c_len];
        let mut ci = vec![0f32; bins * sh.c_len];
        let mut ws = Workspace::new();
        batched_planar(pass, bins, s, f, fo, &ar, &ai, &br, &bi, &mut cr,
                       &mut ci, &mut ws);
        let tol = 1e-3 * (sh.k as f32).sqrt().max(1.0);
        for (i, w) in want.iter().enumerate() {
            let g = C32::new(cr[i], ci[i]);
            assert!((g - *w).abs() < tol, "elem {i}: {g:?} vs {w:?}");
        }
    }

    /// Every runnable FMA tier must agree with the scalar reference tile
    /// within accumulation tolerance, on shapes whose m/n/k straddle the
    /// ragged mr (4/6/8), nr (8/16) and KC tails — the tier-explicit
    /// seam ([`batched_with`]) pins the kernels directly, no dispatch
    /// state involved.
    #[test]
    fn fma_kernels_match_scalar_on_ragged_tails() {
        let scalar = Kernel::for_tier(SimdTier::Scalar);
        for tier in [SimdTier::Avx2, SimdTier::Avx512] {
            if simd::detected() < tier {
                eprintln!("skipping {tier}: not runnable on this host");
                continue;
            }
            let kern = Kernel::for_tier(tier);
            for (pass, bins, s, f, fo, seed) in [
                (Pass::Fprop, 2usize, 1usize, 7usize, 9usize, 0xC1u64),
                (Pass::Fprop, 1, 35, 16, 16, 0xC2),
                (Pass::Bprop, 2, 7, 9, 35, 0xC3),
                (Pass::Bprop, 1, 9, 1, 7, 0xC4),
                (Pass::AccGrad, 2, 35, 7, 9, 0xC5),
                (Pass::AccGrad, 1, KC + 9, 5, 7, 0xC6), // KC tail + accum
                (Pass::Fprop, 3, 13, KC + 1, 6, 0xC7),  // ragged k block
            ] {
                let sh = BinShape::of(pass, s, f, fo);
                let mut rng = Rng::new(seed);
                let a = cvec(&mut rng, bins * sh.a_len);
                let b = cvec(&mut rng, bins * sh.b_len);
                let mut ws = Workspace::new();
                let mut want = vec![C32::ZERO; bins * sh.c_len];
                batched_with(scalar, pass, bins, s, f, fo, &a, &b,
                             &mut want, &mut ws);
                let mut got = vec![C32::ZERO; bins * sh.c_len];
                batched_with(kern, pass, bins, s, f, fo, &a, &b,
                             &mut got, &mut ws);
                let tol = 1e-3 * (sh.k as f32).sqrt().max(1.0);
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert!((*g - *w).abs() < tol,
                            "{tier} {pass:?} s={s} f={f} fo={fo} \
                             elem {i}: {g:?} vs {w:?}");
                }
            }
        }
    }

    /// The scalar tier is the legacy kernel bit-for-bit: whatever tier
    /// dispatch would pick, forcing scalar must reproduce the exact
    /// bits of the pre-dispatch 4×8 tile (anchored here against the
    /// naive path only in tolerance, but against itself across entry
    /// points exactly — see the planar/f16 bitwise gates).
    #[test]
    fn scalar_tier_is_deterministic_across_entry_points() {
        let scalar = Kernel::for_tier(SimdTier::Scalar);
        let (pass, bins, s, f, fo) = (Pass::Fprop, 4usize, 9, 17, 5);
        let sh = BinShape::of(pass, s, f, fo);
        let mut rng = Rng::new(0xD1);
        let a = cvec(&mut rng, bins * sh.a_len);
        let b = cvec(&mut rng, bins * sh.b_len);
        let mut ws = Workspace::new();
        let mut c1 = vec![C32::ZERO; bins * sh.c_len];
        batched_with(scalar, pass, bins, s, f, fo, &a, &b, &mut c1,
                     &mut ws);
        let (ar, ai) = split(&a);
        let (br, bi) = split(&b);
        let mut cr = vec![0f32; bins * sh.c_len];
        let mut ci = vec![0f32; bins * sh.c_len];
        batched_planar_with(scalar, pass, bins, s, f, fo, &ar, &ai, &br,
                            &bi, &mut cr, &mut ci, &mut ws);
        for (i, w) in c1.iter().enumerate() {
            assert_eq!(cr[i].to_bits(), w.re.to_bits(), "elem {i} re");
            assert_eq!(ci[i].to_bits(), w.im.to_bits(), "elem {i} im");
        }
    }

    #[test]
    fn f16_b_path_is_bitwise_planar_on_representable_operands() {
        use crate::util::f16::{decode_slab, encode_slab};
        // encode B to f16 bits, then run (a) the f16 path on the bits and
        // (b) the f32 path on the decoded values: identical panels reach
        // the microkernel (hardware dequant is bitwise the software
        // decoder), so the products must agree bitwise — across every
        // conjugation pattern and a k-block accumulate shape
        for (pass, bins, s, f, fo, seed) in [
            (Pass::Fprop, 5usize, 16usize, 16usize, 16usize, 0xA1u64),
            (Pass::Bprop, 3, 3, 5, 7, 0xA2),
            (Pass::AccGrad, 2, 5, 9, 17, 0xA3),
            (Pass::Fprop, 96, 8, 24, 8, 0xA4), // threaded fan-out
        ] {
            let sh = BinShape::of(pass, s, f, fo);
            let mut rng = Rng::new(seed);
            let a = cvec(&mut rng, bins * sh.a_len);
            let b = cvec(&mut rng, bins * sh.b_len);
            let (ar, ai) = split(&a);
            let (br, bi) = split(&b);
            let (hbr, hbi) = (encode_slab(&br), encode_slab(&bi));
            let mut ws = Workspace::new();
            let mut want_r = vec![0f32; bins * sh.c_len];
            let mut want_i = vec![0f32; bins * sh.c_len];
            batched_planar(pass, bins, s, f, fo, &ar, &ai,
                           &decode_slab(&hbr), &decode_slab(&hbi),
                           &mut want_r, &mut want_i, &mut ws);
            let mut got_r = vec![0f32; bins * sh.c_len];
            let mut got_i = vec![0f32; bins * sh.c_len];
            batched_planar_f16b(pass, bins, s, f, fo, &ar, &ai, &hbr,
                                &hbi, &mut got_r, &mut got_i, &mut ws);
            for i in 0..bins * sh.c_len {
                assert_eq!(got_r[i].to_bits(), want_r[i].to_bits(),
                           "{pass:?} elem {i} re");
                assert_eq!(got_i[i].to_bits(), want_i[i].to_bits(),
                           "{pass:?} elem {i} im");
            }
        }
    }

    #[test]
    fn f16_b_quantization_error_is_small_and_bounded() {
        // unit-variance operands: the f16 B quantization perturbs each
        // product by ~EPS16 per term, so the output error is O(EPS16·√k)
        let (pass, bins, s, f, fo) = (Pass::Fprop, 4usize, 8, 16, 8);
        let sh = BinShape::of(pass, s, f, fo);
        let mut rng = Rng::new(0xB5);
        let a = cvec(&mut rng, bins * sh.a_len);
        let b = cvec(&mut rng, bins * sh.b_len);
        let (ar, ai) = split(&a);
        let (br, bi) = split(&b);
        let mut ws = Workspace::new();
        let mut want_r = vec![0f32; bins * sh.c_len];
        let mut want_i = vec![0f32; bins * sh.c_len];
        batched_planar(pass, bins, s, f, fo, &ar, &ai, &br, &bi,
                       &mut want_r, &mut want_i, &mut ws);
        let mut got_r = vec![0f32; bins * sh.c_len];
        let mut got_i = vec![0f32; bins * sh.c_len];
        use crate::util::f16::encode_slab;
        batched_planar_f16b(pass, bins, s, f, fo, &ar, &ai,
                            &encode_slab(&br), &encode_slab(&bi),
                            &mut got_r, &mut got_i, &mut ws);
        let bound = 16.0 * crate::util::f16::EPS16
            * (sh.k as f32).sqrt().max(1.0);
        let mut max_err = 0f32;
        for i in 0..bins * sh.c_len {
            max_err = max_err
                .max((got_r[i] - want_r[i]).abs())
                .max((got_i[i] - want_i[i]).abs());
        }
        assert!(max_err > 0.0, "f16 must actually quantize something");
        assert!(max_err < bound, "err {max_err} vs bound {bound}");
    }

    #[test]
    fn steady_state_takes_nothing_from_the_heap() {
        let (bins, s, f, fo) = (6usize, 4usize, 8usize, 8usize);
        let sh = BinShape::of(Pass::Fprop, s, f, fo);
        let mut rng = Rng::new(0x77);
        let a = cvec(&mut rng, bins * sh.a_len);
        let b = cvec(&mut rng, bins * sh.b_len);
        let mut c = vec![C32::ZERO; bins * sh.c_len];
        let mut ws = Workspace::new();
        batched(Pass::Fprop, bins, s, f, fo, &a, &b, &mut c, &mut ws);
        let allocs = ws.pool.allocations;
        let exps = ws.pool.expansions;
        for _ in 0..3 {
            batched(Pass::Fprop, bins, s, f, fo, &a, &b, &mut c, &mut ws);
        }
        assert_eq!(ws.pool.allocations, allocs);
        assert_eq!(ws.pool.expansions, exps);
        assert!(ws.pool.reuses >= 3);
    }
}
