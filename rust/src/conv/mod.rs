//! Host convolution engines — every comparator the paper measures,
//! rebuilt on the in-tree FFT substrate.
//!
//! Four engines share one problem vocabulary ([`ConvProblem`]) and one
//! tensor layout (row-major BDHW `Vec<f32>`, the paper's §3.1 format):
//!
//! * [`direct`]  — straightforward time-domain loops (the ccn2 analogue);
//! * [`im2col`]  — matrix unrolling + in-tree SGEMM (the cuDNN analogue);
//! * [`fft_conv`] — the Table-1 frequency pipeline in three flavours:
//!   `Vendor` (explicit padding, separate transposes, planner FFTs — the
//!   cuFFT-based implementation of §3), `Fbfft` (implicit padding, fused
//!   transposes, split-complex batch-lane SoA kernels with a planar
//!   handoff straight into the CGEMM — the §5 implementation) and
//!   `FbfftScalar` (the pre-SoA one-transform-at-a-time baseline), with
//!   per-stage timing for the Table-5 breakdown;
//! * [`tiled`]   — the §6 decomposition running `Fbfft` on small tiles;
//! * [`oaa`]     — Overlap-and-Add (Highlander & Rodriguez 1601.06815):
//!   fixed `tile × tile` patches convolved at the small basis
//!   `next_pow2(tile + k - 1)` with partial outputs overlap-added, the
//!   zero-allocation large-input/small-kernel engine (256²+ images,
//!   long 1-D signals) that reuses one cached weight spectrum across
//!   every tile.
//!
//! The frequency pipeline's hot stage lives in [`cgemm`]: a blocked,
//! multithreaded per-bin complex GEMM on planar re/im panels (packed
//! straight from the SoA planes in fbfft mode), with the zero-allocation
//! [`Workspace`] arena the passes thread through
//! `forward`/CGEMM/`inverse`. [`spectra`] caches the weight-operand
//! spectra across serve flushes (versioned, f16 planar slabs by
//! default) so steady-state serving skips the weight FFT entirely.
//!
//! All engines implement all three training passes and cross-check
//! against each other in `rust/tests/`.

pub mod cgemm;
pub mod direct;
pub mod fft_conv;
pub mod gemm;
pub mod im2col;
pub mod oaa;
pub mod problem;
pub mod spectra;
pub mod tiled;

pub use cgemm::Workspace;
pub use fft_conv::{BOperand, FftConvEngine, FftMode, Operands,
                   StageTimings};
pub use oaa::OaaEngine;
pub use problem::{ConvProblem, ConvProblemBuilder};
pub use spectra::{LayerSpectra, SpectrumCache, SpectrumPrecision,
                  SpectrumStats, WeightSpectrum};
