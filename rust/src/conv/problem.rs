//! The 5-D (plus rectangularity) problem vocabulary of the paper.

use crate::util::Json;

/// One convolutional-layer problem: the paper's `{S, f, f', n, k}` domain
/// (Table 2) generalized to rectangular inputs/kernels. `h, w` are padded
/// input sizes; outputs are valid-only (`yh × yw`), paper §2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ConvProblem {
    pub s: usize,
    pub f: usize,
    pub fo: usize,
    pub h: usize,
    pub w: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
}

impl ConvProblem {
    pub fn new(s: usize, f: usize, fo: usize, h: usize, w: usize,
               kh: usize, kw: usize) -> Self {
        let p = ConvProblem { s, f, fo, h, w, kh, kw, stride: 1 };
        p.validate();
        p
    }

    /// The paper's square shorthand: n = h = w, k = kh = kw.
    pub fn square(s: usize, f: usize, fo: usize, n: usize, k: usize) -> Self {
        Self::new(s, f, fo, n, n, k, k)
    }

    pub fn validate(&self) {
        assert!(self.kh <= self.h && self.kw <= self.w,
                "kernel {}x{} exceeds input {}x{}",
                self.kh, self.kw, self.h, self.w);
        assert!(self.s >= 1 && self.f >= 1 && self.fo >= 1
                && self.stride >= 1);
    }

    pub fn yh(&self) -> usize {
        (self.h - self.kh) / self.stride + 1
    }

    pub fn yw(&self) -> usize {
        (self.w - self.kw) / self.stride + 1
    }

    /// y-axis of Figures 1–6.
    pub fn problem_size(&self) -> usize {
        self.s * self.f * self.fo
    }

    /// Numerator of the TRED/s metric (Table 4 col. 7): time-domain
    /// equivalent reductions of one fprop.
    pub fn reductions(&self) -> u64 {
        (self.s * self.f * self.fo) as u64
            * (self.kh * self.kw) as u64
            * (self.yh() * self.yw()) as u64
    }

    // ----- tensor element counts (BDHW, row-major) -------------------------

    pub fn input_len(&self) -> usize {
        self.s * self.f * self.h * self.w
    }

    pub fn weight_len(&self) -> usize {
        self.fo * self.f * self.kh * self.kw
    }

    pub fn output_len(&self) -> usize {
        self.s * self.fo * self.yh() * self.yw()
    }

    /// Parse the `spec` object the AOT manifest carries (compile/specs.py
    /// `ConvSpec.to_json`).
    pub fn from_json(j: &Json) -> Option<ConvProblem> {
        let g = |k: &str| j.get(k)?.as_usize();
        let p = ConvProblem {
            s: g("s")?,
            f: g("f")?,
            fo: g("fo")?,
            h: g("h")?,
            w: g("w")?,
            kh: g("kh")?,
            kw: g("kw")?,
            stride: g("stride").unwrap_or(1),
        };
        Some(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_sizes_and_counts() {
        let p = ConvProblem::square(2, 3, 4, 9, 3);
        assert_eq!((p.yh(), p.yw()), (7, 7));
        assert_eq!(p.input_len(), 2 * 3 * 9 * 9);
        assert_eq!(p.weight_len(), 4 * 3 * 3 * 3);
        assert_eq!(p.output_len(), 2 * 4 * 7 * 7);
        assert_eq!(p.problem_size(), 24);
        assert_eq!(p.reductions(), 24 * 9 * 49);
    }

    #[test]
    #[should_panic(expected = "exceeds input")]
    fn rejects_kernel_larger_than_input() {
        ConvProblem::square(1, 1, 1, 3, 5);
    }

    #[test]
    fn from_manifest_json() {
        let j = Json::parse(
            r#"{"name":"x","s":2,"f":3,"fo":4,"h":9,"w":9,"kh":3,"kw":3,
                "stride":1}"#).unwrap();
        let p = ConvProblem::from_json(&j).unwrap();
        assert_eq!(p, ConvProblem::square(2, 3, 4, 9, 3));
    }
}
