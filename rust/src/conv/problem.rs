//! The 5-D (plus rectangularity) problem vocabulary of the paper.

use crate::util::Json;

/// One convolutional-layer problem: the paper's `{S, f, f', n, k}` domain
/// (Table 2) generalized to rectangular inputs/kernels. `h, w` are padded
/// input sizes; outputs are valid-only (`yh × yw`), paper §2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ConvProblem {
    pub s: usize,
    pub f: usize,
    pub fo: usize,
    pub h: usize,
    pub w: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
}

impl ConvProblem {
    /// The one checked construction path: every field defaults to 1, so
    /// degenerate shapes (1-D signals with `h = 1`, single-plane
    /// probes) read as what they omit, and `stride` — previously
    /// settable only by struct literal — goes through [`validate`]
    /// like everything else.
    ///
    /// [`validate`]: ConvProblem::validate
    pub fn builder() -> ConvProblemBuilder {
        ConvProblemBuilder {
            p: ConvProblem {
                s: 1, f: 1, fo: 1, h: 1, w: 1, kh: 1, kw: 1, stride: 1,
            },
        }
    }

    pub fn new(s: usize, f: usize, fo: usize, h: usize, w: usize,
               kh: usize, kw: usize) -> Self {
        Self::builder()
            .batch(s)
            .planes(f, fo)
            .hw(h, w)
            .kernel(kh, kw)
            .build()
    }

    /// The paper's square shorthand: n = h = w, k = kh = kw.
    pub fn square(s: usize, f: usize, fo: usize, n: usize, k: usize) -> Self {
        Self::new(s, f, fo, n, n, k, k)
    }

    pub fn validate(&self) {
        assert!(self.kh <= self.h && self.kw <= self.w,
                "kernel {}x{} exceeds input {}x{}",
                self.kh, self.kw, self.h, self.w);
        assert!(self.s >= 1 && self.f >= 1 && self.fo >= 1
                && self.stride >= 1);
    }

    pub fn yh(&self) -> usize {
        (self.h - self.kh) / self.stride + 1
    }

    pub fn yw(&self) -> usize {
        (self.w - self.kw) / self.stride + 1
    }

    /// y-axis of Figures 1–6.
    pub fn problem_size(&self) -> usize {
        self.s * self.f * self.fo
    }

    /// Numerator of the TRED/s metric (Table 4 col. 7): time-domain
    /// equivalent reductions of one fprop.
    pub fn reductions(&self) -> u64 {
        (self.s * self.f * self.fo) as u64
            * (self.kh * self.kw) as u64
            * (self.yh() * self.yw()) as u64
    }

    // ----- tensor element counts (BDHW, row-major) -------------------------

    pub fn input_len(&self) -> usize {
        self.s * self.f * self.h * self.w
    }

    pub fn weight_len(&self) -> usize {
        self.fo * self.f * self.kh * self.kw
    }

    pub fn output_len(&self) -> usize {
        self.s * self.fo * self.yh() * self.yw()
    }

    /// Parse the `spec` object the AOT manifest carries (compile/specs.py
    /// `ConvSpec.to_json`).
    pub fn from_json(j: &Json) -> Option<ConvProblem> {
        let g = |k: &str| j.get(k)?.as_usize();
        let p = ConvProblem {
            s: g("s")?,
            f: g("f")?,
            fo: g("fo")?,
            h: g("h")?,
            w: g("w")?,
            kh: g("kh")?,
            kw: g("kw")?,
            stride: g("stride").unwrap_or(1),
        };
        Some(p)
    }
}

/// Validating builder returned by [`ConvProblem::builder`]. Setters
/// take the axis vocabulary of the paper; [`build`] runs
/// [`ConvProblem::validate`], so a kernel larger than the input or a
/// zero anywhere panics here instead of deep inside an engine.
///
/// [`build`]: ConvProblemBuilder::build
#[derive(Clone, Copy, Debug)]
pub struct ConvProblemBuilder {
    p: ConvProblem,
}

impl ConvProblemBuilder {
    /// Minibatch size `S`. Default 1.
    pub fn batch(mut self, s: usize) -> Self {
        self.p.s = s;
        self
    }

    /// Input/output plane counts `f, f'`. Default 1 each.
    pub fn planes(mut self, f: usize, fo: usize) -> Self {
        self.p.f = f;
        self.p.fo = fo;
        self
    }

    /// Spatial input size. Default 1×1; use `hw(1, w)` for 1-D signals.
    pub fn hw(mut self, h: usize, w: usize) -> Self {
        self.p.h = h;
        self.p.w = w;
        self
    }

    /// Kernel size. Default 1×1; `kernel(1, kw)` for 1-D filters.
    pub fn kernel(mut self, kh: usize, kw: usize) -> Self {
        self.p.kh = kh;
        self.p.kw = kw;
        self
    }

    /// Output stride. Default 1 (the paper's §2 scope); FFT engines
    /// other than OaA fprop reject `stride > 1` at run time.
    pub fn stride(mut self, stride: usize) -> Self {
        self.p.stride = stride;
        self
    }

    /// Validate and produce the problem (panics on nonsense shapes,
    /// same contract as [`ConvProblem::validate`]).
    pub fn build(self) -> ConvProblem {
        self.p.validate();
        self.p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_sizes_and_counts() {
        let p = ConvProblem::square(2, 3, 4, 9, 3);
        assert_eq!((p.yh(), p.yw()), (7, 7));
        assert_eq!(p.input_len(), 2 * 3 * 9 * 9);
        assert_eq!(p.weight_len(), 4 * 3 * 3 * 3);
        assert_eq!(p.output_len(), 2 * 4 * 7 * 7);
        assert_eq!(p.problem_size(), 24);
        assert_eq!(p.reductions(), 24 * 9 * 49);
    }

    #[test]
    #[should_panic(expected = "exceeds input")]
    fn rejects_kernel_larger_than_input() {
        ConvProblem::square(1, 1, 1, 3, 5);
    }

    #[test]
    fn builder_routes_new_and_sets_stride() {
        let b = ConvProblem::builder()
            .batch(2)
            .planes(3, 4)
            .hw(9, 9)
            .kernel(3, 3)
            .build();
        assert_eq!(b, ConvProblem::square(2, 3, 4, 9, 3));
        let s2 = ConvProblem::builder()
            .hw(16, 16)
            .kernel(3, 3)
            .stride(2)
            .build();
        assert_eq!(s2.stride, 2);
        assert_eq!((s2.yh(), s2.yw()), (7, 7));
    }

    #[test]
    fn builder_accepts_1d_signal_shapes() {
        let p = ConvProblem::builder()
            .planes(2, 2)
            .hw(1, 4096)
            .kernel(1, 5)
            .build();
        assert_eq!((p.yh(), p.yw()), (1, 4092));
        assert_eq!(p.input_len(), 2 * 4096);
    }

    #[test]
    #[should_panic(expected = "exceeds input")]
    fn builder_rejects_kernel_larger_than_input() {
        ConvProblem::builder().hw(1, 3).kernel(2, 2).build();
    }

    #[test]
    fn from_manifest_json() {
        let j = Json::parse(
            r#"{"name":"x","s":2,"f":3,"fo":4,"h":9,"w":9,"kh":3,"kw":3,
                "stride":1}"#).unwrap();
        let p = ConvProblem::from_json(&j).unwrap();
        assert_eq!(p, ConvProblem::square(2, 3, 4, 9, 3));
    }
}
