//! Measurement + reporting substrate shared by the CLI and the benches
//! (criterion is unavailable offline; this is the in-tree harness).

use std::time::{Duration, Instant};

/// Time `f`, auto-scaling iteration count until the measurement window
/// exceeds `min_time` — the usual warmup + calibrate + measure protocol.
pub fn bench<F: FnMut()>(mut f: F, min_time: Duration) -> BenchResult {
    // warmup
    f();
    // calibrate
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let el = t0.elapsed();
        if el >= min_time.min(Duration::from_millis(50)) || iters > 1 << 20 {
            if el >= min_time {
                return BenchResult::from_total(el, iters);
            }
            // scale up to fill the window
            let scale = (min_time.as_secs_f64() / el.as_secs_f64().max(1e-9))
                .ceil() as u64;
            let final_iters = (iters * scale.max(2)).max(iters + 1);
            let t1 = Instant::now();
            for _ in 0..final_iters {
                f();
            }
            return BenchResult::from_total(t1.elapsed(), final_iters);
        }
        iters *= 2;
    }
}

#[derive(Clone, Copy, Debug)]
pub struct BenchResult {
    pub total: Duration,
    pub iters: u64,
}

impl BenchResult {
    fn from_total(total: Duration, iters: u64) -> Self {
        BenchResult { total, iters }
    }

    pub fn per_iter(&self) -> Duration {
        self.total / self.iters.max(1) as u32
    }

    pub fn secs_per_iter(&self) -> f64 {
        self.total.as_secs_f64() / self.iters.max(1) as f64
    }
}

/// Latency histogram with exact percentiles (stores samples; the serving
/// example produces thousands, not billions).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
    /// most recently recorded sample (percentile queries sort the
    /// sample buffer in place, so recency is tracked separately)
    last: f64,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, seconds: f64) {
        self.samples.push(seconds);
        self.last = seconds;
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN latency"));
            self.sorted = true;
        }
    }

    /// Exact percentile (nearest-rank), `q` in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        assert!(!self.samples.is_empty(), "empty histogram");
        self.ensure_sorted();
        let n = self.samples.len();
        let rank = ((q / 100.0) * n as f64).ceil().max(1.0) as usize;
        self.samples[rank.min(n) - 1]
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        *self.samples.last().expect("empty histogram")
    }

    /// Sum of every recorded sample (0.0 when empty) — turns a
    /// per-event histogram into a total, e.g. total weight-FFT seconds
    /// over a serve run.
    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    /// The most recently recorded sample (0.0 when empty). Unaffected
    /// by the in-place percentile sort; `merge` adopts the other
    /// histogram's recency when it has samples.
    pub fn last(&self) -> f64 {
        self.last
    }

    /// Fold another histogram's samples into this one (per-shard →
    /// aggregate reduction in the serving report).
    pub fn merge(&mut self, other: &Histogram) {
        self.samples.extend_from_slice(&other.samples);
        if !other.samples.is_empty() {
            self.last = other.last;
        }
        self.sorted = false;
    }

    /// The serving report's fixed percentile set in one pass. An empty
    /// histogram summarizes to all-zero (count 0) instead of panicking —
    /// a shard that served nothing is a report row, not a crash.
    pub fn summary(&mut self) -> Summary {
        if self.samples.is_empty() {
            return Summary::default();
        }
        Summary {
            count: self.len(),
            mean: self.mean(),
            p50: self.percentile(50.0),
            p95: self.percentile(95.0),
            p99: self.percentile(99.0),
            max: self.max(),
        }
    }
}

/// Percentile snapshot of one [`Histogram`] (values in the histogram's
/// own unit — seconds for latency, images for queue depth).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

/// Fixed-width markdown-ish table writer for the bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.headers);
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
        }
        out
    }
}

/// ASCII heatmap for the Figure-1–6 planes: rows = problem size buckets,
/// cols = output size, cell = speedup bucket glyph.
pub struct Heatmap {
    pub col_labels: Vec<String>,
    pub row_labels: Vec<String>,
    /// speedup values, row-major; NaN renders as blank
    pub cells: Vec<f64>,
}

impl Heatmap {
    /// Glyph ramp: cuDNN-favored '·-' through fbfft-favored '#@'.
    fn glyph(v: f64) -> char {
        if v.is_nan() {
            ' '
        } else if v < 0.5 {
            '.'
        } else if v < 1.0 {
            '-'
        } else if v < 2.0 {
            '+'
        } else if v < 4.0 {
            '*'
        } else if v < 8.0 {
            '#'
        } else {
            '@'
        }
    }

    pub fn render(&self, title: &str) -> String {
        let rl_w = self.row_labels.iter().map(|s| s.len()).max().unwrap_or(0);
        let mut out = format!("{title}\n");
        out.push_str(&format!(
            "{:rl_w$}  {}\n", "", self.col_labels.join(" "), rl_w = rl_w));
        let ncols = self.col_labels.len();
        for (r, label) in self.row_labels.iter().enumerate() {
            out.push_str(&format!("{label:>rl_w$}  "));
            for c in 0..ncols {
                let v = self.cells[r * ncols + c];
                let w = self.col_labels[c].len();
                out.push_str(&format!("{:^w$} ", Self::glyph(v), w = w));
            }
            out.push('\n');
        }
        out.push_str(
            "legend: . <0.5x  - <1x  + <2x  * <4x  # <8x  @ >=8x (speedup vs baseline)\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleepless_work() {
        // black_box inside the closure so the optimizer cannot fold the
        // work away (which collapses calibration to the iteration cap)
        let r = bench(|| {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i) * i);
            }
            std::hint::black_box(acc);
        }, Duration::from_millis(20));
        assert!(r.iters >= 1);
        assert!(r.total > Duration::ZERO);
        assert!(r.secs_per_iter() > 0.0);
        // per-iteration time must be plausible for ~1k multiplies
        assert!(r.secs_per_iter() < 1e-3, "{:?}", r.per_iter());
    }

    #[test]
    fn histogram_percentiles_exact() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.percentile(50.0), 50.0);
        assert_eq!(h.percentile(95.0), 95.0);
        assert_eq!(h.percentile(100.0), 100.0);
        assert_eq!(h.percentile(1.0), 1.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_merge_and_summary() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for i in 1..=50 {
            a.record(i as f64);
        }
        for i in 51..=100 {
            b.record(i as f64);
        }
        a.merge(&b);
        let s = a.summary();
        assert_eq!(s.count, 100);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p99, 99.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-9);
        // empty histograms summarize to zero, not panic
        assert_eq!(Histogram::new().summary(), Summary::default());
    }

    #[test]
    fn histogram_sum_and_last_survive_sorting() {
        let mut h = Histogram::new();
        assert_eq!(h.sum(), 0.0);
        assert_eq!(h.last(), 0.0);
        h.record(3.0);
        h.record(1.0);
        h.record(2.0);
        assert_eq!(h.sum(), 6.0);
        assert_eq!(h.last(), 2.0);
        // a percentile query sorts the buffer; recency must survive
        assert_eq!(h.percentile(100.0), 3.0);
        assert_eq!(h.last(), 2.0);
        // merge adopts the merged-in histogram's recency
        let mut other = Histogram::new();
        other.record(9.0);
        h.merge(&other);
        assert_eq!(h.last(), 9.0);
        assert_eq!(h.sum(), 15.0);
        h.merge(&Histogram::new());
        assert_eq!(h.last(), 9.0, "empty merge keeps recency");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["layer", "ms"]);
        t.row(vec!["L1".into(), "12.5".into()]);
        t.row(vec!["L2-long-name".into(), "3.1".into()]);
        let s = t.render();
        assert!(s.contains("| layer        | ms   |") || s.contains("L2-long-name"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn heatmap_glyph_ramp_is_monotone() {
        let gs: Vec<char> =
            [0.1, 0.7, 1.5, 3.0, 6.0, 20.0].iter()
            .map(|v| Heatmap::glyph(*v)).collect();
        assert_eq!(gs, vec!['.', '-', '+', '*', '#', '@']);
    }
}
