//! `fbfft-repro` — CLI front end for the fbfft reproduction.
//!
//! Every subcommand regenerates one artifact of the paper's evaluation
//! (DESIGN.md §5 maps them to tables/figures). Run with no arguments for
//! usage. Clap is unavailable offline; arguments are parsed by hand.

use std::process::ExitCode;

use fbfft_repro::coordinator::service::{Backend, Completion,
                                        EngineConfig, ServeEngine,
                                        ServeRequest};
use fbfft_repro::coordinator::NetPlan;
use fbfft_repro::reports;
use fbfft_repro::runtime::Runtime;
use fbfft_repro::trace;

const USAGE: &str = "\
fbfft-repro — reproduction of 'Fast Convolutional Nets With fbfft'

USAGE: fbfft-repro <COMMAND> [OPTIONS]

COMMANDS (one per paper artifact):
  sweep            Figures 1-6: 8,232-config speedup heatmaps (K40m model)
  sweep --measure  ... plus the measured PJRT anchor subset
  layers           Table 4: representative layers L1-L5 (model + measured)
  breakdown        Table 5: frequency-pipeline stage breakdown
  cnn-bench        Table 3: AlexNet + OverFeat-fast whole-CNN totals
  fft-bench --dim <1|2>   Figures 7-8: fbfft vs vendor FFT
  conv-compare     Sec 5.4: fbfft-conv vs vendor-FFT-conv grid
  tiling           Sec 6: tiled vs untiled decomposition
  autotune         Sec 3.4: strategy/basis autotuner demonstration
  train [--steps N]        e2e: train the demo CNN via train.step
  serve [--requests N] [--shards N]
                   serving demo: sharded engine + deadline batcher
                   (PJRT artifacts when present, host engines otherwise)
  cost-model       print the calibrated K40m model vs paper numbers

OPTIONS:
  --artifacts <dir>   artifacts directory (default: artifacts)
  --no-pjrt           skip PJRT-backed sections (model/host-only output)
  --shards <n>        serving worker-pool width (default: 4)
";

struct Args {
    cmd: String,
    artifacts: String,
    measure: bool,
    no_pjrt: bool,
    dim: usize,
    steps: usize,
    requests: usize,
    shards: usize,
}

fn parse_args() -> Option<Args> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first()?.clone();
    let mut a = Args {
        cmd,
        artifacts: "artifacts".into(),
        measure: false,
        no_pjrt: false,
        dim: 1,
        steps: 300,
        requests: 200,
        shards: 4,
    };
    let mut i = 1;
    while i < argv.len() {
        match argv[i].as_str() {
            "--artifacts" => {
                a.artifacts = argv.get(i + 1)?.clone();
                i += 2;
            }
            "--measure" => {
                a.measure = true;
                i += 1;
            }
            "--no-pjrt" => {
                a.no_pjrt = true;
                i += 1;
            }
            "--dim" => {
                a.dim = argv.get(i + 1)?.parse().ok()?;
                i += 2;
            }
            "--steps" => {
                a.steps = argv.get(i + 1)?.parse().ok()?;
                i += 2;
            }
            "--requests" => {
                a.requests = argv.get(i + 1)?.parse().ok()?;
                i += 2;
            }
            "--shards" => {
                a.shards = argv.get(i + 1)?.parse().ok()?;
                i += 2;
            }
            other => {
                eprintln!("unknown option {other}");
                return None;
            }
        }
    }
    Some(a)
}

fn open_rt(a: &Args) -> Option<Runtime> {
    if a.no_pjrt {
        return None;
    }
    match Runtime::open(&a.artifacts) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("note: PJRT runtime unavailable ({e:#}); \
                       continuing with model/host-only output");
            None
        }
    }
}

fn run(a: Args) -> anyhow::Result<()> {
    match a.cmd.as_str() {
        "sweep" => {
            println!("{}", reports::fig16_report());
            if a.measure {
                if let Some(rt) = open_rt(&a) {
                    println!("{}", reports::sweep::fig16_measured(&rt)?);
                }
            }
        }
        "layers" => {
            let rt = open_rt(&a);
            println!("{}", reports::table4_report(rt.as_ref())?);
        }
        "breakdown" => println!("{}", reports::table5_report()),
        "cnn-bench" => {
            let rt = open_rt(&a)
                .ok_or_else(|| anyhow::anyhow!("cnn-bench needs PJRT"))?;
            println!("{}", reports::table3_report(&rt)?);
        }
        "fft-bench" => {
            let rt = open_rt(&a);
            let r = match a.dim {
                1 => reports::fig7_report(rt.as_ref())?,
                2 => reports::fig8_report(rt.as_ref())?,
                d => anyhow::bail!("--dim must be 1 or 2, got {d}"),
            };
            println!("{r}");
        }
        "conv-compare" => {
            let rt = open_rt(&a)
                .ok_or_else(|| anyhow::anyhow!("conv-compare needs PJRT"))?;
            println!("{}", reports::sec54_report(&rt)?);
        }
        "tiling" => {
            let rt = open_rt(&a);
            println!("{}", reports::tiling_report(rt.as_ref())?);
        }
        "autotune" => println!("{}", reports::tables::autotune_report()),
        "cost-model" => {
            println!("{}", reports::table4_report(None)?);
        }
        "train" => {
            let rt = open_rt(&a)
                .ok_or_else(|| anyhow::anyhow!("train needs PJRT"))?;
            let (log, acc) = reports::trainer::train_and_eval(
                &rt, a.steps, 0xE2E)?;
            println!("{}", log.render_curve(20));
            println!("steps: {}  loss {:.4} -> {:.4}  {:.1} steps/s  \
                      accuracy {:.1}%",
                     log.steps, log.first(), log.last(),
                     log.steps_per_sec(), acc * 100.0);
        }
        "serve" => serve_demo(&a)?,
        other => {
            eprintln!("unknown command {other}\n{USAGE}");
            anyhow::bail!("bad command");
        }
    }
    Ok(())
}

fn serve_demo(a: &Args) -> anyhow::Result<()> {
    // serve through the sharded engine: the quickstart fprop layer on
    // PJRT artifacts when available, the AlexNet-style layer chain on
    // the strategy-cache host path otherwise
    let cfg = |capacity: usize| {
        EngineConfig::builder()
            .shards(a.shards.max(1))
            .capacity(capacity)
            .max_wait(std::time::Duration::from_millis(2))
            .default_deadline(std::time::Duration::from_millis(500))
            .build()
            .expect("demo config is valid")
    };
    let pj = fbfft_repro::conv::ConvProblem::square(2, 4, 4, 16, 3);
    let pjrt = if a.no_pjrt {
        Err(anyhow::anyhow!("--no-pjrt"))
    } else {
        ServeEngine::start_pjrt(a.artifacts.clone().into(),
                                "conv.quickstart.fbfft.fprop".into(),
                                pj, cfg(pj.s))
    };
    let (engine, capacity) = match pjrt {
        Ok(e) => {
            println!("serving PJRT artifacts on {} shards", a.shards);
            (e, pj.s)
        }
        Err(e) => {
            eprintln!("note: PJRT serving unavailable ({e:#}); \
                       serving the AlexNet-style chain on the \
                       host-engine backend");
            let net = NetPlan::alexnet_small(8);
            let cap = net.batch();
            (ServeEngine::start(Backend::Host, net, cfg(cap))?, cap)
        }
    };
    let trace = trace::request_trace(a.requests, 400.0, 0x5E);
    let (tx, rx) = std::sync::mpsc::channel::<Completion>();
    let t0 = std::time::Instant::now();
    let mut accepted = 0usize;
    for r in &trace {
        let wait = std::time::Duration::from_secs_f64(r.arrival_s)
            .saturating_sub(t0.elapsed());
        std::thread::sleep(wait);
        if engine.submit(ServeRequest { id: r.id,
                                        images: r.images.min(capacity),
                                        deadline: None,
                                        reply: tx.clone() }).is_ok() {
            accepted += 1;
        }
    }
    drop(tx);
    let mut done = 0usize;
    while done < accepted {
        match rx.recv_timeout(std::time::Duration::from_secs(5)) {
            Ok(_) => done += 1,
            Err(_) => break,
        }
    }
    let wall = t0.elapsed();
    let report = engine.shutdown();
    let json = reports::serve_json(&report, "open", false, wall);
    println!("{}", reports::serve_table(&json));
    anyhow::ensure!(done == accepted, "dropped {} accepted requests",
                    accepted - done);
    Ok(())
}

fn main() -> ExitCode {
    let Some(args) = parse_args() else {
        eprintln!("{USAGE}");
        return ExitCode::FAILURE;
    };
    match run(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
