//! The acceptance-threshold model: tolerances that *scale* with the
//! accumulation depth of the pass and the transform size of the engine,
//! replacing the hard-coded `1e-3`-style constants the seed tests used.
//!
//! Model: with unit-variance inputs, a reduction of depth `d` produces
//! outputs of magnitude ~√d and accumulates rounding noise of the same
//! √d order, so the absolute error of a faithful f32 engine grows like
//! `ε·d`. A frequency-domain engine additionally pays per-butterfly
//! rounding over `log₂n + 1` stages on operands of magnitude ~√n, and a
//! tiled engine sums per-tile results. The constants are deliberately
//! generous (an order of magnitude over observed error): the matrix is a
//! conformance gate, not a precision benchmark — a wrong conjugation,
//! layout or clip produces errors of *output magnitude*, thousands of
//! times past these thresholds.

use crate::conv::tiled::tile_fft_size;
use crate::conv::ConvProblem;
use crate::coordinator::Pass;

/// f32 unit roundoff.
pub const EPS32: f32 = f32::EPSILON;

/// Length of the reduction producing one output element of `pass`.
pub fn reduction_depth(p: &ConvProblem, pass: Pass) -> usize {
    match pass {
        Pass::Fprop => p.f * p.kh * p.kw,
        Pass::Bprop => p.fo * p.kh * p.kw,
        Pass::AccGrad => p.s * p.yh() * p.yw(),
    }
}

/// Absolute tolerance for a time-domain engine (direct, im2col).
pub fn time_domain(p: &ConvProblem, pass: Pass) -> f32 {
    let d = reduction_depth(p, pass) as f32;
    (32.0 * EPS32 * d).max(1e-5)
}

/// Absolute tolerance for a frequency-domain engine on basis `n_fft`.
/// The effective depth is at least `n²`: the pipeline's intermediates
/// carry the full transform-basis energy even when the conv reduction is
/// tiny (the paper's k-independence, mirrored in the rounding noise —
/// e.g. accGrad on a `k == h` shape reduces over a handful of elements
/// but still rides n²-energy spectra).
pub fn frequency(p: &ConvProblem, pass: Pass, n_fft: usize) -> f32 {
    let d = reduction_depth(p, pass).max(n_fft * n_fft) as f32;
    let n = n_fft as f32;
    let stages = n.log2().max(1.0) + 1.0;
    (32.0 * EPS32 * d * stages * n.sqrt()).max(2e-5)
}

/// Absolute tolerance for a frequency-domain engine whose **weight
/// spectrum is stored as f16** (the serving tier's cached slabs).
/// Quantizing the weight spectrum adds relative noise `EPS16` per
/// spectral value; propagated through the CGEMM reduction and the
/// (energy-preserving, `1/n²`-scaled) inverse transform it lands on the
/// output as ~`EPS16·√d` absolute — added on top of the f32 pipeline's
/// own budget, with the usual order-of-magnitude headroom (the gate
/// catches wrong-layout errors of *output magnitude*, thousands of
/// times larger).
pub fn frequency_f16(p: &ConvProblem, pass: Pass, n_fft: usize) -> f32 {
    let d = reduction_depth(p, pass).max(n_fft * n_fft) as f32;
    frequency(p, pass, n_fft) + 16.0 * crate::util::f16::EPS16 * d.sqrt()
}

/// Absolute tolerance for the tiled engine with output-tile size `d_tile`
/// (per-tile frequency error, accumulated over the resident tiles).
pub fn tiled(p: &ConvProblem, pass: Pass, d_tile: usize) -> f32 {
    let n_t = tile_fft_size(d_tile, p.kh, p.kw);
    let tiles =
        (p.yh().div_ceil(d_tile) * p.yw().div_ceil(d_tile)) as f32;
    frequency(p, pass, n_t) * (1.0 + tiles.sqrt())
}

/// Absolute tolerance for the Overlap-and-Add engine with output-tile
/// edge `tile`: the same per-tile-frequency × tile-accumulation model
/// as [`tiled`] (identical decomposition), except that the tile grid
/// covers the **stride-1** output extent — OaA computes the dense grid
/// and subsamples at scatter time, so a strided fprop's error rides the
/// dense tile count.
pub fn oaa(p: &ConvProblem, pass: Pass, tile: usize) -> f32 {
    let n_t = tile_fft_size(tile, p.kh, p.kw);
    let (yh1, yw1) = (p.h - p.kh + 1, p.w - p.kw + 1);
    let tiles = (yh1.div_ceil(tile) * yw1.div_ceil(tile)) as f32;
    frequency(p, pass, n_t) * (1.0 + tiles.sqrt())
}

/// Absolute tolerance for one forward transform of size `n` on
/// unit-variance input (the FFT edge tests): output magnitude ~√n,
/// rounding over the stage count, with headroom for Bluestein's larger
/// internal transform.
pub fn fft_abs(n: usize) -> f32 {
    let nf = n as f32;
    (128.0 * EPS32 * nf.sqrt() * (nf.log2().max(1.0) + 1.0)).max(1e-5)
}

/// ULP distance between two f32 values (0 for bit-identical numbers;
/// monotone in the real-line gap). The conformance matrix reports the
/// max over each {engine × pass} cell.
pub fn ulps(a: f32, b: f32) -> u64 {
    fn key(x: f32) -> i64 {
        // map the f32 line onto a monotone integer line
        let bits = x.to_bits() as i32 as i64;
        if bits < 0 {
            (i32::MIN as i64) - bits
        } else {
            bits
        }
    }
    (key(a) - key(b)).unsigned_abs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_tracks_pass() {
        let p = ConvProblem::square(4, 3, 5, 9, 3);
        assert_eq!(reduction_depth(&p, Pass::Fprop), 3 * 9);
        assert_eq!(reduction_depth(&p, Pass::Bprop), 5 * 9);
        assert_eq!(reduction_depth(&p, Pass::AccGrad), 4 * 49);
    }

    #[test]
    fn tolerances_scale_with_size() {
        let small = ConvProblem::square(1, 2, 2, 8, 3);
        let big = ConvProblem::square(16, 16, 16, 32, 5);
        assert!(time_domain(&big, Pass::Fprop)
                > time_domain(&small, Pass::Fprop));
        assert!(frequency(&big, Pass::Fprop, 32)
                > frequency(&small, Pass::Fprop, 8));
        assert!(frequency(&small, Pass::Fprop, 8)
                >= time_domain(&small, Pass::Fprop));
        assert!(fft_abs(256) > fft_abs(8));
    }

    #[test]
    fn tiled_adds_tile_accumulation() {
        // at the tile's own basis, the tiled budget exceeds the plain
        // frequency budget by the tile-accumulation factor
        let p = ConvProblem::square(2, 2, 2, 16, 3);
        let d_tile = 2; // 7x7 = 49 tiles
        let n_t = tile_fft_size(d_tile, p.kh, p.kw);
        assert!(tiled(&p, Pass::Fprop, d_tile)
                > 2.0 * frequency(&p, Pass::Fprop, n_t));
    }

    #[test]
    fn oaa_matches_tiled_model_at_stride_one() {
        let p = ConvProblem::square(2, 2, 2, 40, 3);
        assert_eq!(oaa(&p, Pass::Fprop, 8), tiled(&p, Pass::Fprop, 8));
        // strided problems keep the dense-grid tile count
        let s2 = ConvProblem::builder()
            .batch(2)
            .planes(2, 2)
            .hw(40, 40)
            .kernel(3, 3)
            .stride(2)
            .build();
        assert!(oaa(&s2, Pass::Fprop, 8) >= oaa(&p, Pass::Fprop, 8));
    }

    #[test]
    fn ulp_distance_basics() {
        assert_eq!(ulps(1.0, 1.0), 0);
        assert_eq!(ulps(1.0, f32::from_bits(1.0f32.to_bits() + 1)), 1);
        assert_eq!(ulps(0.0, -0.0), 0);
        assert!(ulps(-1.0, 1.0) > 1_000_000);
        assert_eq!(ulps(-1.5, -1.5), 0);
    }

    #[test]
    fn thresholds_are_small_relative_to_signal() {
        // magnitude of an fprop output is ~sqrt(depth); the tolerance
        // must stay a tiny fraction of it or the gate is meaningless
        let p = ConvProblem::square(16, 16, 16, 32, 5);
        let mag = (reduction_depth(&p, Pass::Fprop) as f32).sqrt();
        assert!(frequency(&p, Pass::Fprop, 32) < 0.01 * mag);
        assert!(time_domain(&p, Pass::Fprop) < 0.001 * mag);
        // the f16-slab budget is wider than f32's but still a small
        // fraction of the signal — the gate keeps its teeth
        let f16_tol = frequency_f16(&p, Pass::Fprop, 32);
        assert!(f16_tol > frequency(&p, Pass::Fprop, 32));
        assert!(f16_tol < 0.05 * mag, "{f16_tol} vs magnitude {mag}");
    }
}
