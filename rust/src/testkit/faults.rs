//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a small, seeded script of failures — "shard 1's
//! third flush panics", "the tenth staging-buffer checkout fails", "the
//! next strategy-cache load reads corrupt bytes" — threaded through the
//! shard workers, the [`BufferPool`](crate::coordinator::BufferPool)
//! and the cache load paths so every recovery path in the supervision
//! layer is exercised by *reproducible* tests and a CI chaos gate, not
//! by hoping production fails interestingly.
//!
//! Plans come from config ([`EngineConfig::faults`]
//! (crate::coordinator::EngineConfig)) or from the environment:
//!
//! ```text
//! FBFFT_FAULTS="shard1:panic@flush3,shard0:alloc_fail@10,corrupt_load@1"
//! ```
//!
//! Grammar: comma-separated `[shard<i>:][layer<j>:]<kind>@<occurrence>`,
//! where `<kind>` is one of `panic`, `nonfinite`, `alloc_fail`,
//! `corrupt_load` and `<occurrence>` is the 1-based index of the event
//! within the kind's scope (an optional alphabetic label such as
//! `flush3` or `take10` is accepted and ignored — only the digits
//! count). Scopes: `panic` counts flushes per shard, `nonfinite`
//! counts frequency-strategy layer launches per shard, `alloc_fail`
//! counts staging-pool checkouts per shard, `corrupt_load` counts
//! strategy-cache load attempts (engine-wide). Each spec fires at most
//! once; an unscoped spec fires on the first shard whose own counter
//! reaches the occurrence.
//!
//! The `layer<j>` qualifier scopes the occurrence to chain position
//! `j` of a net-level serve (0-based, matching the `NetPlan` layer
//! order): `shard0:layer1:panic@1` panics shard 0's first execution of
//! layer 1, *mid-chain*, after layer 0 already ran. Specs without a
//! layer qualifier keep their flush-level meaning — the per-flush
//! probe happens before any per-layer probe, so `shard0:panic@2` still
//! means "shard 0's second flush" exactly as before the qualifier
//! existed.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// The failure classes the serving stack knows how to survive.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// Panic inside a shard worker's flush (supervised by
    /// `catch_unwind`: the batch fails, the shard restarts).
    Panic,
    /// Plant a non-finite value into a frequency-strategy flush so the
    /// output scan trips and the problem demotes to the direct path.
    NonFinite,
    /// Fail a staging [`BufferPool`](crate::coordinator::BufferPool)
    /// checkout (panics inside the supervised flush region).
    AllocFail,
    /// Treat the next persisted strategy-cache file as corrupt, forcing
    /// the tolerant-load cold-start path.
    CorruptLoad,
}

impl FaultKind {
    fn parse(s: &str) -> Option<FaultKind> {
        match s {
            "panic" => Some(FaultKind::Panic),
            "nonfinite" => Some(FaultKind::NonFinite),
            "alloc_fail" => Some(FaultKind::AllocFail),
            "corrupt_load" => Some(FaultKind::CorruptLoad),
            _ => None,
        }
    }

    pub fn tag(&self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::NonFinite => "nonfinite",
            FaultKind::AllocFail => "alloc_fail",
            FaultKind::CorruptLoad => "corrupt_load",
        }
    }
}

/// One scripted failure: fire `kind` on occurrence `at` (1-based)
/// within `shard`'s scope (`None` = any shard / engine-wide),
/// optionally pinned to one chain position (`layer`).
#[derive(Debug)]
struct FaultSpec {
    shard: Option<usize>,
    layer: Option<usize>,
    kind: FaultKind,
    at: usize,
    fired: AtomicBool,
}

/// A deterministic script of failures, shared (`Arc`) between the
/// engine, its shard workers and their staging pools. Thread-safe;
/// every spec fires at most once.
#[derive(Debug, Default)]
pub struct FaultPlan {
    specs: Vec<FaultSpec>,
    /// occurrence counters per (kind, shard, layer) scope — bumped by
    /// every `fire` probe so the 1-based spec indices are
    /// deterministic per scope
    #[allow(clippy::type_complexity)]
    counts:
        Mutex<HashMap<(FaultKind, Option<usize>, Option<usize>), usize>>,
    injected: AtomicUsize,
}

impl FaultPlan {
    /// Parse a comma-separated fault script (see module docs for the
    /// grammar). Errors name the offending entry.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut specs = Vec::new();
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            specs.push(Self::parse_entry(entry)?);
        }
        if specs.is_empty() {
            return Err(format!("empty fault spec {spec:?}"));
        }
        Ok(FaultPlan { specs, ..Default::default() })
    }

    fn parse_entry(entry: &str) -> Result<FaultSpec, String> {
        let mut shard = None;
        let mut layer = None;
        let mut rest = entry;
        while let Some((scope, tail)) = rest.split_once(':') {
            if let Some(idx) = scope.strip_prefix("shard") {
                if shard.is_some() {
                    return Err(format!(
                        "duplicate shard scope in {entry:?}"));
                }
                shard = Some(idx.parse::<usize>().map_err(|_| {
                    format!("bad shard index {idx:?} in {entry:?}")
                })?);
            } else if let Some(idx) = scope.strip_prefix("layer") {
                if layer.is_some() {
                    return Err(format!(
                        "duplicate layer scope in {entry:?}"));
                }
                layer = Some(idx.parse::<usize>().map_err(|_| {
                    format!("bad layer index {idx:?} in {entry:?}")
                })?);
            } else {
                return Err(format!(
                    "bad scope {scope:?} in {entry:?} \
                     (want shard<N> or layer<N>)"));
            }
            rest = tail;
        }
        let (kind, occ) = rest.split_once('@').ok_or_else(|| {
            format!("missing @occurrence in {entry:?}")
        })?;
        let kind = FaultKind::parse(kind).ok_or_else(|| {
            format!("unknown fault kind {kind:?} in {entry:?} (want \
                     panic|nonfinite|alloc_fail|corrupt_load)")
        })?;
        // accept a labelled occurrence ("flush3", "take10") — only the
        // trailing digits carry meaning
        let digits =
            occ.trim_start_matches(|c: char| c.is_ascii_alphabetic());
        let at = digits.parse::<usize>().map_err(|_| {
            format!("bad occurrence {occ:?} in {entry:?}")
        })?;
        if at == 0 {
            return Err(format!("occurrence in {entry:?} is 1-based"));
        }
        Ok(FaultSpec { shard, layer, kind, at,
                       fired: AtomicBool::new(false) })
    }

    /// Read `FBFFT_FAULTS` from the environment. An unset or empty
    /// variable is `None`; a malformed script is reported and ignored
    /// (a typo'd chaos knob must never take serving down by itself).
    pub fn from_env() -> Option<Arc<FaultPlan>> {
        let spec = std::env::var("FBFFT_FAULTS").ok()?;
        let spec = spec.trim().to_string();
        if spec.is_empty() {
            return None;
        }
        match FaultPlan::parse(&spec) {
            Ok(p) => Some(Arc::new(p)),
            Err(e) => {
                eprintln!("serve: FBFFT_FAULTS ignored: {e}");
                None
            }
        }
    }

    /// Count one occurrence of `kind` in `shard`'s flush-level scope
    /// and report whether a scripted fault fires here. A spec fires
    /// exactly once (first matching probe wins); unmatched probes only
    /// advance the scope counter.
    pub fn fire(&self, kind: FaultKind, shard: Option<usize>) -> bool {
        self.probe(kind, shard, None)
    }

    /// Count one occurrence of `kind` at chain position `layer` in
    /// `shard`'s scope. Only `layer<j>`-qualified specs match this
    /// probe — unqualified specs keep their flush-level occurrence
    /// semantics through [`FaultPlan::fire`].
    pub fn fire_layer(&self, kind: FaultKind, shard: Option<usize>,
                      layer: usize) -> bool {
        self.probe(kind, shard, Some(layer))
    }

    fn probe(&self, kind: FaultKind, shard: Option<usize>,
             layer: Option<usize>) -> bool {
        let occurrence = {
            let mut counts = self
                .counts
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            let c = counts.entry((kind, shard, layer)).or_insert(0);
            *c += 1;
            *c
        };
        for spec in &self.specs {
            if spec.kind != kind
                || spec.at != occurrence
                || spec.layer != layer
            {
                continue;
            }
            if let Some(want) = spec.shard {
                if shard != Some(want) {
                    continue;
                }
            }
            if spec.fired.swap(true, Ordering::AcqRel) {
                continue;
            }
            self.injected.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// Faults actually injected so far (the CI chaos gate's
    /// `faults_injected` source of truth).
    pub fn injected(&self) -> usize {
        self.injected.load(Ordering::Relaxed)
    }

    /// Scripted specs in the plan.
    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Specs that have not fired yet (a finished chaos run should
    /// usually report 0 here — anything left means the script asked
    /// for events the run never produced).
    pub fn armed(&self) -> usize {
        self.specs
            .iter()
            .filter(|s| !s.fired.load(Ordering::Acquire))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_grammar() {
        let p = FaultPlan::parse(
            "shard1:panic@flush3, alloc_fail@10,corrupt_load@1")
            .unwrap();
        assert_eq!(p.len(), 3);
        assert_eq!(p.injected(), 0);
        assert_eq!(p.armed(), 3);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in ["", "panic", "panic@zero", "panic@0",
                    "worker1:panic@1", "explode@1", "shardx:panic@1",
                    "layerx:panic@1", "shard0:shard1:panic@1",
                    "layer0:layer1:panic@1"] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn parses_layer_qualified_specs() {
        let p = FaultPlan::parse(
            "shard0:layer1:panic@1,layer2:nonfinite@1")
            .unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p.armed(), 2);
    }

    #[test]
    fn layer_spec_matches_only_its_chain_position() {
        let p = FaultPlan::parse("shard0:layer1:panic@1").unwrap();
        assert!(!p.fire(FaultKind::Panic, Some(0)),
                "flush-level probes never match a layer spec");
        assert!(!p.fire_layer(FaultKind::Panic, Some(0), 0),
                "layer 0 is not layer 1");
        assert!(p.fire_layer(FaultKind::Panic, Some(0), 1));
        assert!(!p.fire_layer(FaultKind::Panic, Some(0), 1),
                "fired specs stay off");
        assert_eq!(p.injected(), 1);
    }

    #[test]
    fn unqualified_spec_ignores_layer_probes() {
        let p = FaultPlan::parse("shard0:panic@1").unwrap();
        assert!(!p.fire_layer(FaultKind::Panic, Some(0), 0),
                "per-layer probes never match a flush-level spec");
        assert!(p.fire(FaultKind::Panic, Some(0)),
                "flush-level occurrence 1 still fires");
    }

    #[test]
    fn fires_exactly_once_at_the_scripted_occurrence() {
        let p = FaultPlan::parse("shard0:panic@2").unwrap();
        assert!(!p.fire(FaultKind::Panic, Some(0)), "occurrence 1");
        assert!(p.fire(FaultKind::Panic, Some(0)), "occurrence 2 fires");
        assert!(!p.fire(FaultKind::Panic, Some(0)), "fired specs stay off");
        assert_eq!(p.injected(), 1);
        assert_eq!(p.armed(), 0);
    }

    #[test]
    fn shard_scope_isolates_counters() {
        let p = FaultPlan::parse("shard1:alloc_fail@1").unwrap();
        assert!(!p.fire(FaultKind::AllocFail, Some(0)),
                "shard 0 never matches a shard-1 spec");
        assert!(!p.fire(FaultKind::AllocFail, Some(0)));
        assert!(p.fire(FaultKind::AllocFail, Some(1)),
                "shard 1's own first occurrence fires");
        assert_eq!(p.injected(), 1);
    }

    #[test]
    fn kinds_do_not_cross_trigger() {
        let p = FaultPlan::parse("shard0:panic@1").unwrap();
        assert!(!p.fire(FaultKind::AllocFail, Some(0)));
        assert!(!p.fire(FaultKind::NonFinite, Some(0)));
        assert!(p.fire(FaultKind::Panic, Some(0)));
    }

    #[test]
    fn unscoped_spec_fires_on_first_scope_to_reach_it() {
        let p = FaultPlan::parse("corrupt_load@2").unwrap();
        assert!(!p.fire(FaultKind::CorruptLoad, None));
        assert!(p.fire(FaultKind::CorruptLoad, None));
        assert_eq!(p.injected(), 1);
    }
}
