//! f64 reference implementations — the independent oracle every engine
//! is conformance-tested against.
//!
//! Deliberately *not* shared with any engine under test: the three conv
//! passes are literal transcriptions of the paper's §2 summations in
//! gather form (the engines use scatter/blocked/threaded forms), and the
//! DFT is the O(n²) definition. All accumulation is f64, so the oracle's
//! own rounding error is negligible next to any f32 engine's.

use crate::conv::ConvProblem;

/// fprop oracle: `y[s,j,a,b] = Σ_{i,u,v} x[s,i,a·st+u,b·st+v] · w[j,i,u,v]`
/// (valid cross-correlation, stride honoured).
pub fn fprop64(p: &ConvProblem, x: &[f32], wei: &[f32]) -> Vec<f64> {
    assert_eq!(x.len(), p.input_len());
    assert_eq!(wei.len(), p.weight_len());
    let (yh, yw) = (p.yh(), p.yw());
    let mut y = vec![0f64; p.output_len()];
    for s in 0..p.s {
        for j in 0..p.fo {
            for a in 0..yh {
                for b in 0..yw {
                    let mut acc = 0f64;
                    for i in 0..p.f {
                        for u in 0..p.kh {
                            for v in 0..p.kw {
                                let xi = x[((s * p.f + i) * p.h
                                    + (a * p.stride + u)) * p.w
                                    + (b * p.stride + v)] as f64;
                                let wv = wei[((j * p.f + i) * p.kh + u)
                                    * p.kw + v] as f64;
                                acc += xi * wv;
                            }
                        }
                    }
                    y[((s * p.fo + j) * yh + a) * yw + b] = acc;
                }
            }
        }
    }
    y
}

/// bprop oracle (gather form): for each input-gradient pixel `(r, c)`,
/// `gx[s,i,r,c] = Σ_{j,u,v} go[s,j,r-u,c-v] · w[j,i,u,v]` over the taps
/// whose gradient index lands inside the valid output.
pub fn bprop64(p: &ConvProblem, go: &[f32], wei: &[f32]) -> Vec<f64> {
    assert_eq!(p.stride, 1, "strided bprop is out of oracle scope");
    assert_eq!(go.len(), p.output_len());
    assert_eq!(wei.len(), p.weight_len());
    let (yh, yw) = (p.yh(), p.yw());
    let mut gx = vec![0f64; p.input_len()];
    for s in 0..p.s {
        for i in 0..p.f {
            for r in 0..p.h {
                for c in 0..p.w {
                    let mut acc = 0f64;
                    for j in 0..p.fo {
                        for u in 0..p.kh {
                            if u > r || r - u >= yh {
                                continue;
                            }
                            for v in 0..p.kw {
                                if v > c || c - v >= yw {
                                    continue;
                                }
                                let g = go[((s * p.fo + j) * yh + (r - u))
                                    * yw + (c - v)] as f64;
                                let wv = wei[((j * p.f + i) * p.kh + u)
                                    * p.kw + v] as f64;
                                acc += g * wv;
                            }
                        }
                    }
                    gx[((s * p.f + i) * p.h + r) * p.w + c] = acc;
                }
            }
        }
    }
    gx
}

/// accGrad oracle:
/// `gw[j,i,u,v] = Σ_{s,a,b} go[s,j,a,b] · x[s,i,a+u,b+v]`.
pub fn accgrad64(p: &ConvProblem, go: &[f32], x: &[f32]) -> Vec<f64> {
    assert_eq!(p.stride, 1, "strided accGrad is out of oracle scope");
    assert_eq!(go.len(), p.output_len());
    assert_eq!(x.len(), p.input_len());
    let (yh, yw) = (p.yh(), p.yw());
    let mut gw = vec![0f64; p.weight_len()];
    for j in 0..p.fo {
        for i in 0..p.f {
            for u in 0..p.kh {
                for v in 0..p.kw {
                    let mut acc = 0f64;
                    for s in 0..p.s {
                        for a in 0..yh {
                            for b in 0..yw {
                                let g = go[((s * p.fo + j) * yh + a) * yw
                                    + b] as f64;
                                let xi = x[((s * p.f + i) * p.h + (a + u))
                                    * p.w + (b + v)] as f64;
                                acc += g * xi;
                            }
                        }
                    }
                    gw[((j * p.f + i) * p.kh + u) * p.kw + v] = acc;
                }
            }
        }
    }
    gw
}

/// Naive O(n²) DFT in pure f64 (`(re, im)` pairs). Forward sign
/// convention `e^{-2πi jk/n}`, unnormalized inverse. Deliberately a
/// separate definition from `fft::naive_dft` so the conformance oracle
/// shares no code with the substrate under test.
pub fn dft64(input: &[(f64, f64)], inverse: bool) -> Vec<(f64, f64)> {
    let n = input.len();
    let sign = if inverse { 2.0 } else { -2.0 };
    (0..n)
        .map(|k| {
            let mut re = 0f64;
            let mut im = 0f64;
            for (j, (xr, xi)) in input.iter().enumerate() {
                let ang = sign * std::f64::consts::PI * (j as f64)
                    * (k as f64) / (n as f64);
                let (s, c) = ang.sin_cos();
                re += xr * c - xi * s;
                im += xr * s + xi * c;
            }
            (re, im)
        })
        .collect()
}

/// One bin of the naive 2-D DFT of an `h × w` image zero-padded onto an
/// `n × n` basis: `Σ_{r,c} img[r,c] · e^{-2πi(kh·r + kw·c)/n}`.
pub fn dft2_bin64(img: &[f32], h: usize, w: usize, n: usize, kh: usize,
                  kw: usize) -> (f64, f64) {
    assert_eq!(img.len(), h * w);
    let mut re = 0f64;
    let mut im = 0f64;
    for r in 0..h {
        for c in 0..w {
            let ang = -2.0 * std::f64::consts::PI
                * ((kh * r) as f64 + (kw * c) as f64) / (n as f64);
            let (s, co) = ang.sin_cos();
            re += img[r * w + c] as f64 * co;
            im += img[r * w + c] as f64 * s;
        }
    }
    (re, im)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn identity_kernel_is_identity() {
        let p = ConvProblem::square(1, 2, 2, 5, 1);
        let mut rng = Rng::new(40);
        let x = rng.normal_vec(p.input_len());
        // w[j,i,0,0] = δ_{ij}
        let mut wei = vec![0f32; p.weight_len()];
        wei[0] = 1.0;
        wei[3] = 1.0;
        let y = fprop64(&p, &x, &wei);
        for (g, o) in y.iter().zip(&x) {
            assert!((g - *o as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn adjoint_identities_hold_to_f64_precision() {
        // ⟨fprop(x,w), go⟩ == ⟨x, bprop(go,w)⟩ == ⟨w, accgrad(go,x)⟩
        let p = ConvProblem::new(2, 3, 2, 7, 9, 3, 5);
        let mut rng = Rng::new(41);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let go = rng.normal_vec(p.output_len());
        let y = fprop64(&p, &x, &wei);
        let gx = bprop64(&p, &go, &wei);
        let gw = accgrad64(&p, &go, &x);
        let a: f64 = y.iter().zip(&go).map(|(u, v)| u * *v as f64).sum();
        let b: f64 = gx.iter().zip(&x).map(|(u, v)| u * *v as f64).sum();
        let c: f64 = gw.iter().zip(&wei).map(|(u, v)| u * *v as f64).sum();
        assert!((a - b).abs() < 1e-9 * a.abs().max(1.0), "{a} vs {b}");
        assert!((a - c).abs() < 1e-9 * a.abs().max(1.0), "{a} vs {c}");
    }

    #[test]
    fn strided_fprop_center_tap() {
        let mut p = ConvProblem::square(1, 1, 1, 7, 3);
        p.stride = 2;
        let x: Vec<f32> = (0..49).map(|i| i as f32).collect();
        let wei = vec![0., 0., 0., 0., 1., 0., 0., 0., 0.];
        let y = fprop64(&p, &x, &wei);
        assert_eq!(y, vec![8., 10., 12., 22., 24., 26., 36., 38., 40.]);
    }

    #[test]
    fn dft64_impulse_is_flat_and_inverse_round_trips() {
        let mut x = vec![(0f64, 0f64); 8];
        x[0] = (1.0, 0.0);
        for (re, im) in dft64(&x, false) {
            assert!((re - 1.0).abs() < 1e-12 && im.abs() < 1e-12);
        }
        let sig: Vec<(f64, f64)> =
            (0..9).map(|j| ((j as f64).sin(), (j as f64).cos())).collect();
        let f = dft64(&sig, false);
        let back = dft64(&f, true);
        for ((br, bi), (or, oi)) in back.iter().zip(&sig) {
            assert!((br / 9.0 - or).abs() < 1e-10);
            assert!((bi / 9.0 - oi).abs() < 1e-10);
        }
    }

    #[test]
    fn dft2_bin_dc_is_sum() {
        let img = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let (re, im) = dft2_bin64(&img, 2, 3, 8, 0, 0);
        assert!((re - 21.0).abs() < 1e-10 && im.abs() < 1e-10);
    }
}
