//! The conformance matrix: run every {engine × pass} pair against the
//! f64 oracle (and every engine against every other), reporting a
//! per-cell max-abs / max-ULP table gated by the `tolerance` model.

use crate::conv::{direct, im2col, tiled, BOperand, FftConvEngine,
                  FftMode, OaaEngine, Operands, Workspace};
use crate::coordinator::Pass;
use crate::metrics::Table;
use crate::util::Rng;

use super::cases::ConformanceCase;
use super::{oracle, tolerance};

/// The host engines under conformance test (`Fbfft` is the SoA
/// batch-lane path, `FbfftScalar` the pre-SoA baseline — both run so the
/// lane kernels are gated against the oracle *and* their scalar twin;
/// `Oaa` is the Overlap-and-Add decomposition, run by the dedicated
/// large-input suite rather than [`Engine::ALL`] because the full-pad
/// fbfft engines cannot even be constructed at its 256²+/4096-long
/// shapes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    Direct,
    Im2col,
    VendorFft,
    Fbfft,
    FbfftScalar,
    Tiled,
    Oaa,
}

impl Engine {
    pub const ALL: [Engine; 6] = [Engine::Direct, Engine::Im2col,
                                  Engine::VendorFft, Engine::Fbfft,
                                  Engine::FbfftScalar, Engine::Tiled];

    pub fn tag(&self) -> &'static str {
        match self {
            Engine::Direct => "direct",
            Engine::Im2col => "im2col",
            Engine::VendorFft => "vendor_fft",
            Engine::Fbfft => "fbfft",
            Engine::FbfftScalar => "fbfft_scalar",
            Engine::Tiled => "tiled",
            Engine::Oaa => "oaa",
        }
    }
}

/// The engine set for an Overlap-and-Add conformance case: the 5-engine
/// matrix [direct, im2col, vendor_fft, tiled, oaa]. The full-pad fbfft
/// engines are excluded (their basis cap is below the 256²+ inputs OaA
/// exists for), and on 1-D signal shapes the vendor engine drops out
/// too: padding a `1 × 4096` signal to a square `4096²` Fourier basis
/// is a ~128 MiB-per-plane allocation with no conformance value.
pub fn oaa_engine_set(case: &ConformanceCase) -> Vec<Engine> {
    let p = &case.problem;
    let mut set = vec![Engine::Direct, Engine::Im2col];
    if p.h > 1 && p.w > 1 {
        set.push(Engine::VendorFft);
    }
    set.push(Engine::Tiled);
    set.push(Engine::Oaa);
    set
}

/// One cell of the matrix: an engine's deviation from the oracle on one
/// pass, against its modelled tolerance.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    pub engine: Engine,
    pub pass: Pass,
    pub max_abs: f64,
    pub max_ulp: u64,
    pub tol: f32,
    pub ok: bool,
}

/// All engine × pass cells of one case, plus the cross-engine check.
#[derive(Clone, Debug)]
pub struct CaseReport {
    pub name: String,
    pub cells: Vec<Cell>,
    /// worst pairwise engine-vs-engine deviation over all passes
    pub cross_max: f64,
    pub cross_ok: bool,
}

impl CaseReport {
    pub fn ok(&self) -> bool {
        self.cross_ok && self.cells.iter().all(|c| c.ok)
    }

    pub fn cell(&self, engine: Engine, pass: Pass) -> &Cell {
        self.cells
            .iter()
            .find(|c| c.engine == engine && c.pass == pass)
            .expect("matrix covers every engine x pass")
    }
}

/// The whole suite's reports plus rendering.
#[derive(Clone, Debug, Default)]
pub struct SuiteReport {
    pub cases: Vec<CaseReport>,
}

impl SuiteReport {
    pub fn all_ok(&self) -> bool {
        self.cases.iter().all(CaseReport::ok)
    }

    /// Render the conformance matrix: one row per {case × engine}, one
    /// column per pass showing `max_abs (max_ulp)`, flagged when a cell
    /// exceeds its tolerance. Rows come from the cells a case actually
    /// ran — subset suites (the OaA 5-engine matrix) render without
    /// phantom rows.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "case", "engine", "fprop", "bprop", "accgrad", "status"]);
        for cr in &self.cases {
            for engine in case_engines(cr) {
                let fmt = |pass: Pass| {
                    let c = cr.cell(engine, pass);
                    let mark = if c.ok { "" } else { " !>tol" };
                    format!("{:.1e} ({}u){mark}", c.max_abs, c.max_ulp)
                };
                let ok = Pass::ALL
                    .iter()
                    .all(|p| cr.cell(engine, *p).ok);
                t.row(vec![
                    cr.name.clone(),
                    engine.tag().to_string(),
                    fmt(Pass::Fprop),
                    fmt(Pass::Bprop),
                    fmt(Pass::AccGrad),
                    if ok { "ok".into() } else { "FAIL".into() },
                ]);
            }
        }
        let failed: Vec<&str> = self
            .cases
            .iter()
            .filter(|c| !c.ok())
            .map(|c| c.name.as_str())
            .collect();
        format!(
            "conformance matrix: {} cases x {} engines x 3 passes \
             vs f64 oracle\n{}\ncross-engine max deviation: {:.2e}\n{}",
            self.cases.len(),
            self.cases
                .iter()
                .map(|c| case_engines(c).len())
                .max()
                .unwrap_or(0),
            t.render(),
            self.cases
                .iter()
                .map(|c| c.cross_max)
                .fold(0.0, worst),
            if failed.is_empty() {
                "all cells within tolerance".to_string()
            } else {
                format!("FAILED cases: {failed:?}")
            })
    }
}

/// The engines a report actually ran, in first-cell order.
fn case_engines(cr: &CaseReport) -> Vec<Engine> {
    let mut es = Vec::new();
    for c in &cr.cells {
        if !es.contains(&c.engine) {
            es.push(c.engine);
        }
    }
    es
}

/// NaN-propagating max: a NaN deviation must poison the cell (plain
/// `f64::max` silently ignores NaN, which would let an engine emitting
/// NaN pass the gate).
fn worst(acc: f64, d: f64) -> f64 {
    if d.is_nan() || d > acc {
        d
    } else {
        acc
    }
}

/// Max absolute deviation and max ULP distance of `got` vs the oracle.
fn compare(got: &[f32], want: &[f64]) -> (f64, u64) {
    assert_eq!(got.len(), want.len(), "output length mismatch");
    let mut max_abs = 0f64;
    let mut max_ulp = 0u64;
    for (g, w) in got.iter().zip(want) {
        max_abs = worst(max_abs, (*g as f64 - w).abs());
        max_ulp = max_ulp.max(tolerance::ulps(*g, *w as f32));
    }
    (max_abs, max_ulp)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (*x as f64 - *y as f64).abs())
        .fold(0.0, worst)
}

/// This engine's modelled tolerance for one pass of this case.
pub fn cell_tolerance(engine: Engine, case: &ConformanceCase, pass: Pass)
                      -> f32 {
    let p = &case.problem;
    match engine {
        Engine::Direct | Engine::Im2col => tolerance::time_domain(p, pass),
        Engine::VendorFft => tolerance::frequency(p, pass, case.vendor_basis),
        Engine::Fbfft | Engine::FbfftScalar => {
            tolerance::frequency(p, pass, case.fbfft_basis)
        }
        Engine::Tiled => tolerance::tiled(p, pass, case.tile),
        Engine::Oaa => tolerance::oaa(p, pass, case.oaa_tile),
    }
}

/// Run one case through every engine of [`Engine::ALL`] and every pass.
pub fn run_case(case: &ConformanceCase) -> CaseReport {
    run_case_with(case, &Engine::ALL)
}

/// Run one case through an explicit engine subset and every pass. The
/// OaA suite uses this with [`oaa_engine_set`]; `run_case` delegates
/// here with the classic six.
pub fn run_case_with(case: &ConformanceCase, engines: &[Engine])
                     -> CaseReport {
    let p = &case.problem;
    let mut rng = Rng::new(case.seed);
    let x = rng.normal_vec(p.input_len());
    let w = rng.normal_vec(p.weight_len());
    let go = rng.normal_vec(p.output_len());

    let want = [oracle::fprop64(p, &x, &w),
                oracle::bprop64(p, &go, &w),
                oracle::accgrad64(p, &go, &x)];

    let d = case.tile;

    // the FFT engines run through the production pass-typed `run` entry
    // point with ONE workspace shared across all engines and passes, so
    // the conformance gate also covers pooled-buffer reuse (a stale
    // buffer leaking between passes fails the oracle cells)
    let mut ws = Workspace::new();
    // one pass-typed driver covers both FFT engine families: `run` takes
    // the same `Operands` bundle on `FftConvEngine` and `OaaEngine`
    let run_fft = |run: &dyn Fn(Pass, Operands<'_>, &mut Workspace),
                   ws: &mut Workspace| -> [Vec<f32>; 3] {
        let mut y = vec![0f32; p.output_len()];
        let mut gx = vec![0f32; p.input_len()];
        let mut gw = vec![0f32; p.weight_len()];
        run(Pass::Fprop,
            Operands { problem: p, a: &x,
                       b: BOperand::Planes(&w), out: &mut y },
            ws);
        run(Pass::Bprop,
            Operands { problem: p, a: &go,
                       b: BOperand::Planes(&w), out: &mut gx },
            ws);
        run(Pass::AccGrad,
            Operands { problem: p, a: &go,
                       b: BOperand::Planes(&x), out: &mut gw },
            ws);
        [y, gx, gw]
    };
    let run_mode = |mode: FftMode, basis: usize, ws: &mut Workspace| {
        let eng = FftConvEngine::new(mode, basis);
        run_fft(&|pass, ops, ws| { eng.run(pass, ops, ws); }, ws)
    };

    // engines are constructed inside their arm: a 512² OaA case would
    // panic just *building* a full-pad fbfft engine it never runs
    let outputs: Vec<(Engine, [Vec<f32>; 3])> = engines
        .iter()
        .map(|&engine| {
            let outs = match engine {
                Engine::Direct => [direct::fprop(p, &x, &w),
                                   direct::bprop(p, &go, &w),
                                   direct::accgrad(p, &go, &x)],
                Engine::Im2col => [im2col::fprop(p, &x, &w),
                                   im2col::bprop(p, &go, &w),
                                   im2col::accgrad(p, &go, &x)],
                Engine::VendorFft =>
                    run_mode(FftMode::Vendor, case.vendor_basis, &mut ws),
                Engine::Fbfft =>
                    run_mode(FftMode::Fbfft, case.fbfft_basis, &mut ws),
                Engine::FbfftScalar => run_mode(
                    FftMode::FbfftScalar, case.fbfft_basis, &mut ws),
                Engine::Tiled => [tiled::fprop(p, &x, &w, d).0,
                                  tiled::bprop(p, &go, &w, d).0,
                                  tiled::accgrad(p, &go, &x, d).0],
                Engine::Oaa => {
                    let eng = OaaEngine::for_problem(p, case.oaa_tile);
                    run_fft(&|pass, ops, ws| { eng.run(pass, ops, ws); },
                            &mut ws)
                }
            };
            (engine, outs)
        })
        .collect();

    let mut cells = Vec::with_capacity(engines.len() * Pass::ALL.len());
    for (engine, outs) in &outputs {
        for (pi, pass) in Pass::ALL.iter().enumerate() {
            let tol = cell_tolerance(*engine, case, *pass);
            let (max_abs, max_ulp) = compare(&outs[pi], &want[pi]);
            cells.push(Cell {
                engine: *engine,
                pass: *pass,
                max_abs,
                max_ulp,
                tol,
                ok: max_abs <= tol as f64,
            });
        }
    }

    // cross-check engines against each other: two conforming engines may
    // drift apart by at most the sum of their budgets
    let mut cross_max = 0f64;
    let mut cross_ok = true;
    for (pi, pass) in Pass::ALL.iter().enumerate() {
        for i in 0..outputs.len() {
            for j in (i + 1)..outputs.len() {
                let dmax = max_abs_diff(&outputs[i].1[pi], &outputs[j].1[pi]);
                cross_max = worst(cross_max, dmax);
                let lim = cell_tolerance(outputs[i].0, case, *pass) as f64
                    + cell_tolerance(outputs[j].0, case, *pass) as f64;
                // NaN-safe: a NaN deviation must fail, not slip past `>`
                if dmax.is_nan() || dmax > lim {
                    cross_ok = false;
                }
            }
        }
    }

    CaseReport { name: case.name.clone(), cells, cross_max, cross_ok }
}

/// Run a whole suite of cases.
pub fn run_suite(cases: &[ConformanceCase]) -> SuiteReport {
    SuiteReport { cases: cases.iter().map(run_case).collect() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvProblem;
    use crate::testkit::cases::ConformanceCase;

    #[test]
    fn small_case_passes_every_cell() {
        let case = ConformanceCase::new(
            "unit-small", ConvProblem::square(2, 2, 2, 9, 3));
        let r = run_case(&case);
        assert_eq!(r.cells.len(), Engine::ALL.len() * Pass::ALL.len());
        assert!(r.ok(), "\n{}", SuiteReport { cases: vec![r] }.render());
    }

    #[test]
    fn prime_basis_case_takes_bluestein_and_passes() {
        let case = ConformanceCase::new(
            "unit-prime", ConvProblem::square(1, 2, 2, 11, 3))
            .with_vendor_basis(11);
        assert!(case.forces_bluestein());
        let r = run_case(&case);
        assert!(r.ok(), "\n{}", SuiteReport { cases: vec![r] }.render());
    }

    #[test]
    fn corrupted_output_is_flagged() {
        // compare() must see through a single flipped element
        let want = vec![1.0f64, 2.0, 3.0];
        let mut got = vec![1.0f32, 2.0, 3.0];
        let (abs0, ulp0) = compare(&got, &want);
        assert_eq!(abs0, 0.0);
        assert_eq!(ulp0, 0);
        got[1] = 2.5;
        let (abs1, ulp1) = compare(&got, &want);
        assert!((abs1 - 0.5).abs() < 1e-12);
        assert!(ulp1 > 1000);
    }

    #[test]
    fn nan_output_poisons_the_cell() {
        // plain f64::max would ignore NaN and report the engine "ok"
        let want = vec![1.0f64, 2.0];
        let got = vec![1.0f32, f32::NAN];
        let (abs, _) = compare(&got, &want);
        assert!(abs.is_nan()); // so the `max_abs <= tol` ok-gate fails
        assert!(max_abs_diff(&got, &[1.0, 2.0]).is_nan());
    }

    #[test]
    fn oaa_subset_runner_covers_the_five_engine_matrix() {
        let case = ConformanceCase::oaa(
            "unit-oaa", ConvProblem::square(1, 2, 2, 20, 3), 6);
        let engines = oaa_engine_set(&case);
        assert_eq!(engines.len(), 5);
        assert!(engines.contains(&Engine::Oaa));
        let r = run_case_with(&case, &engines);
        assert_eq!(r.cells.len(), 5 * 3);
        let rep = SuiteReport { cases: vec![r] };
        assert!(rep.all_ok(), "\n{}", rep.render());
        // subset rendering: an oaa row, no phantom full-pad fbfft rows
        let text = rep.render();
        assert!(text.contains("oaa"));
        assert!(!text.contains("fbfft_scalar"));
    }

    #[test]
    fn one_d_oaa_case_drops_the_vendor_engine() {
        let case = ConformanceCase::oaa(
            "unit-oaa-1d", ConvProblem::new(1, 1, 2, 1, 64, 1, 5), 12);
        let engines = oaa_engine_set(&case);
        assert!(!engines.contains(&Engine::VendorFft));
        let r = run_case_with(&case, &engines);
        assert!(r.ok(),
                "\n{}", SuiteReport { cases: vec![r] }.render());
    }

    #[test]
    fn report_renders_every_engine_row() {
        let case = ConformanceCase::new(
            "unit-render", ConvProblem::square(1, 1, 1, 6, 3));
        let rep = run_suite(std::slice::from_ref(&case));
        let text = rep.render();
        for e in Engine::ALL {
            assert!(text.contains(e.tag()), "missing row for {}", e.tag());
        }
        assert!(text.contains("unit-render"));
        assert!(rep.all_ok(), "\n{text}");
    }
}
