//! Deterministic problem generation for the conformance matrix: a fixed
//! adversarial set (the shapes that historically break FFT convolution
//! engines) plus seeded samples of the paper's Table-2 sweep space,
//! bounded to a CPU-friendly work budget.

use crate::conv::oaa;
use crate::conv::ConvProblem;
use crate::coordinator::autotuner::candidate_bases;
use crate::fft::{fbfft_host, is_smooth};
use crate::trace;
use crate::util::{hash64, Rng};

/// One conformance case: the problem plus every engine parameter the
/// matrix needs to run it (explicit, so a case can deliberately force a
/// degenerate or slow path).
#[derive(Clone, Debug)]
pub struct ConformanceCase {
    pub name: String,
    pub problem: ConvProblem,
    /// Fourier basis handed to the vendor engine. A prime or otherwise
    /// non-smooth basis forces the planner's Bluestein fallback.
    pub vendor_basis: usize,
    /// Power-of-two basis handed to the fbfft engine.
    pub fbfft_basis: usize,
    /// Output-tile size for the §6 tiled engine.
    pub tile: usize,
    /// Output-tile edge (on the stride-1 grid) for the Overlap-and-Add
    /// engine — only consulted when the case runs `Engine::Oaa`.
    pub oaa_tile: usize,
    /// Seed for the case's synthetic tensors (derived from the name, so
    /// renaming a case intentionally reshuffles its data).
    pub seed: u64,
}

impl ConformanceCase {
    /// Case with default engine parameters: smallest smooth vendor basis
    /// covering the input, next-pow-2 fbfft basis, ~2×2 output tiles.
    pub fn new(name: &str, problem: ConvProblem) -> ConformanceCase {
        let n = problem.h.max(problem.w);
        let fbfft_basis = n.next_power_of_two();
        assert!(fbfft_basis >= 2 && fbfft_basis <= fbfft_host::MAX_N,
                "{name}: input {n} outside fbfft's basis range");
        ConformanceCase {
            name: name.to_string(),
            problem,
            vendor_basis: candidate_bases(n)[0],
            fbfft_basis,
            tile: default_tile(&problem),
            oaa_tile: default_tile(&problem),
            seed: hash64(name.as_bytes()),
        }
    }

    /// Case for the Overlap-and-Add suite: unlike [`ConformanceCase::new`]
    /// the input may exceed the full-pad fbfft basis cap — these shapes
    /// (256²+, long 1-D signals) are exactly the regime OaA exists for,
    /// and the subset runner never constructs a full-pad fbfft engine
    /// for them. The stored `fbfft_basis` is the (possibly over-cap)
    /// next power of two, kept only for reporting.
    pub fn oaa(name: &str, problem: ConvProblem, oaa_tile: usize)
               -> ConformanceCase {
        assert!(oaa::tile_supported(oaa_tile, problem.kh, problem.kw),
                "{name}: OaA tile {oaa_tile} overflows the fbfft basis");
        let n = problem.h.max(problem.w);
        ConformanceCase {
            name: name.to_string(),
            problem,
            vendor_basis: candidate_bases(n)[0],
            fbfft_basis: n.next_power_of_two(),
            tile: default_tile(&problem),
            oaa_tile,
            seed: hash64(name.as_bytes()),
        }
    }

    /// Override the vendor basis (e.g. a prime size to force Bluestein).
    pub fn with_vendor_basis(mut self, n: usize) -> ConformanceCase {
        assert!(n >= self.problem.h.max(self.problem.w),
                "vendor basis must cover the input");
        self.vendor_basis = n;
        self
    }

    /// Override the tiled engine's output-tile size.
    pub fn with_tile(mut self, d: usize) -> ConformanceCase {
        assert!(d >= 1);
        self.tile = d;
        self
    }

    /// Override the Overlap-and-Add engine's output-tile edge.
    pub fn with_oaa_tile(mut self, t: usize) -> ConformanceCase {
        assert!(oaa::tile_supported(t, self.problem.kh, self.problem.kw),
                "OaA tile {t} overflows the fbfft basis");
        self.oaa_tile = t;
        self
    }

    /// Does this case exercise the planner's Bluestein path?
    pub fn forces_bluestein(&self) -> bool {
        !is_smooth(self.vendor_basis)
    }
}

/// Default output-tile size: split each axis roughly in two so the tiled
/// engine genuinely decomposes, degrading to one tile for tiny outputs.
fn default_tile(p: &ConvProblem) -> usize {
    p.yh().min(p.yw()).div_ceil(2).clamp(1, 8)
}

/// The hand-picked adversarial shapes:
///
/// * `k == h` — the output is a single pixel and the FFT "convolution"
///   degenerates to a pointwise reduction;
/// * `k == 1` — the kernel is a scalar per plane pair;
/// * prime input sizes run with a prime vendor basis — the planner must
///   take Bluestein, not mixed-radix;
/// * non-smooth (but composite) sizes — the other Bluestein trigger;
/// * rectangular problems, batch-heavy and plane-heavy aspect ratios,
///   and a kernel at the paper's 13×13 extreme.
pub fn adversarial_cases() -> Vec<ConformanceCase> {
    vec![
        ConformanceCase::new("adv-k-eq-h-pointwise",
                             ConvProblem::square(2, 3, 3, 5, 5)),
        ConformanceCase::new("adv-k1-scalar-kernel",
                             ConvProblem::square(2, 2, 2, 6, 1)),
        ConformanceCase::new("adv-prime-11",
                             ConvProblem::square(1, 2, 2, 11, 3))
            .with_vendor_basis(11),
        ConformanceCase::new("adv-prime-13-rect",
                             ConvProblem::new(1, 2, 3, 13, 13, 5, 3))
            .with_vendor_basis(13),
        ConformanceCase::new("adv-nonsmooth-22",
                             ConvProblem::square(1, 2, 2, 22, 3))
            .with_vendor_basis(22),
        ConformanceCase::new("adv-rect-8x10-k3x5",
                             ConvProblem::new(1, 2, 2, 8, 10, 3, 5)),
        ConformanceCase::new("adv-batch-heavy",
                             ConvProblem::square(8, 1, 2, 8, 3)),
        ConformanceCase::new("adv-plane-heavy",
                             ConvProblem::square(1, 8, 8, 10, 3)),
        ConformanceCase::new("adv-big-kernel-13",
                             ConvProblem::square(1, 2, 2, 16, 13)),
        ConformanceCase::new("adv-tile-stress",
                             ConvProblem::square(2, 2, 2, 16, 5))
            .with_tile(3),
    ]
}

/// Work budget for one sampled problem (time-domain reductions of one
/// fprop): keeps the full matrix runnable in seconds on CI hardware.
pub const MAX_REDUCTIONS: u64 = 3_000_000;

/// Seeded samples of the paper's Table-2 sweep space (sizes 8–128,
/// kernels 3–13, batch 1–128), rejection-bounded to a work budget so the
/// matrix stays CPU-testable. Deterministic for a given `(seed, count)`.
pub fn sampled_cases(seed: u64, count: usize) -> Vec<ConformanceCase> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(count);
    let mut draws = 0usize;
    while out.len() < count && draws < 100_000 {
        draws += 1;
        let p = trace::table2_sample(&mut rng);
        // CPU budget: bound both the arithmetic and the fbfft basis
        if p.reductions() > MAX_REDUCTIONS
            || p.h.max(p.w) > 64
            || p.s > 16
            || p.f > 16
            || p.fo > 16
        {
            continue;
        }
        let name = format!(
            "t2-s{}f{}fo{}x{}k{}", p.s, p.f, p.fo, p.h, p.kh);
        // the sampler can repeat a grid point; keep names unique
        if out.iter().any(|c: &ConformanceCase| c.name == name) {
            continue;
        }
        out.push(ConformanceCase::new(&name, p));
    }
    assert_eq!(out.len(), count,
               "table-2 sampler exhausted its draw budget");
    out
}

/// The default conformance suite: every adversarial case plus six
/// Table-2 samples — ≥ 10 problems, at least one Bluestein-path case,
/// every case exercising the tiled decomposition.
pub fn conformance_suite() -> Vec<ConformanceCase> {
    let mut cases = adversarial_cases();
    cases.extend(sampled_cases(0x7AB1E2, 6));
    cases
}

/// The Overlap-and-Add conformance suite: the large-input/small-kernel
/// regime the full-pad engines cannot reach — 256² and 512² images with
/// 3×3/5×5 kernels, plus a long 1-D signal (`h = 1, w = 4096`, the
/// audio/time-series shape of Highlander & Rodriguez §4). Channel
/// counts stay tiny so the suite is debug-runnable: the cells gate
/// *decomposition* correctness (tile boundaries, overlap windows,
/// spectrum reuse), which is channel-count independent. Tiles are
/// basis-filling (`basis − k + 1`, see [`oaa::basis_filling_tile`]) —
/// the production configuration the autotuner favours.
pub fn oaa_cases() -> Vec<ConformanceCase> {
    let t64 = |k: usize| oaa::basis_filling_tile(64, k, k);
    vec![
        ConformanceCase::oaa(
            "oaa-256-k3",
            ConvProblem::square(1, 2, 2, 256, 3), t64(3)),
        ConformanceCase::oaa(
            "oaa-256-k5",
            ConvProblem::square(2, 2, 2, 256, 5), t64(5)),
        // 512² exceeds the fbfft full-pad basis cap (MAX_N = 256):
        // constructible only through the OaA path
        ConformanceCase::oaa(
            "oaa-512-k3",
            ConvProblem::square(1, 1, 2, 512, 3), t64(3)),
        ConformanceCase::oaa(
            "oaa-512-k5",
            ConvProblem::square(1, 2, 1, 512, 5), t64(5)),
        // 1-D: the vendor engine drops out of the set (square-basis
        // padding of a 4096-long signal); the tiled engine runs at a
        // 1 × 8 output tile
        ConformanceCase::oaa(
            "oaa-1d-4096-k5",
            ConvProblem::new(1, 2, 2, 1, 4096, 1, 5),
            oaa::basis_filling_tile(64, 1, 5))
            .with_tile(8),
    ]
}

/// Random small problem for property tests (moved here from
/// `tests/prop.rs` so every test layer draws from one generator).
pub fn random_small_problem(rng: &mut Rng, max_hw: usize) -> ConvProblem {
    let kh = *rng.choice(&[1usize, 2, 3, 5]);
    let kw = *rng.choice(&[1usize, 2, 3, 5]);
    let h = rng.int_in(kh.max(2), max_hw);
    let w = rng.int_in(kw.max(2), max_hw);
    ConvProblem::new(rng.int_in(1, 3), rng.int_in(1, 4), rng.int_in(1, 4),
                     h, w, kh.min(h), kw.min(w))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adversarial_set_covers_the_claimed_paths() {
        let cases = adversarial_cases();
        assert!(cases.iter().any(|c| c.problem.kh == c.problem.h),
                "missing k == h case");
        assert!(cases.iter().any(|c| c.problem.kh == 1),
                "missing k == 1 case");
        assert!(cases.iter().filter(|c| c.forces_bluestein()).count() >= 2,
                "missing Bluestein cases");
        assert!(cases.iter().any(|c| c.problem.kh != c.problem.kw
                                     || c.problem.h != c.problem.w),
                "missing rectangular case");
        for c in &cases {
            c.problem.validate();
            assert!(c.vendor_basis >= c.problem.h.max(c.problem.w));
            assert!(c.fbfft_basis.is_power_of_two());
        }
    }

    #[test]
    fn sampling_is_deterministic_and_budgeted() {
        let a = sampled_cases(42, 5);
        let b = sampled_cases(42, 5);
        assert_eq!(a.len(), 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.problem, y.problem);
            assert_eq!(x.name, y.name);
        }
        for c in &a {
            assert!(c.problem.reductions() <= MAX_REDUCTIONS);
            assert!(c.problem.h.max(c.problem.w) <= 64);
            // sampled from the paper's axes
            assert!(trace::TABLE2_K.contains(&c.problem.kh));
            assert!(trace::TABLE2_Y.contains(&c.problem.yh()));
        }
    }

    #[test]
    fn suite_meets_the_acceptance_floor() {
        let suite = conformance_suite();
        assert!(suite.len() >= 10, "suite has {} cases", suite.len());
        assert!(suite.iter().any(|c| c.forces_bluestein()));
        // distinct names (report rows must be addressable)
        let mut names: Vec<&str> =
            suite.iter().map(|c| c.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), suite.len());
    }

    #[test]
    fn oaa_suite_covers_the_beyond_full_pad_regime() {
        use crate::conv::tiled::tile_fft_size;
        let cases = oaa_cases();
        assert!(cases.iter().any(
            |c| c.problem.h.max(c.problem.w) > fbfft_host::MAX_N),
            "missing a shape past the full-pad basis cap");
        assert!(cases.iter().any(
            |c| c.problem.h == 1 && c.problem.w >= 4096),
            "missing the long 1-D signal shape");
        assert!(cases.iter().any(|c| c.problem.kh == 3)
                && cases.iter().any(|c| c.problem.kh == 5
                                        || c.problem.kw == 5));
        for c in &cases {
            c.problem.validate();
            assert!(oaa::tile_supported(
                c.oaa_tile, c.problem.kh, c.problem.kw));
            // basis-filling tiles: the tile basis is hit exactly, no
            // round-up waste
            let n_t = tile_fft_size(c.oaa_tile, c.problem.kh, c.problem.kw);
            assert_eq!(
                n_t,
                c.oaa_tile + c.problem.kh.max(c.problem.kw) - 1,
                "{}: tile {} wastes basis {n_t}", c.name, c.oaa_tile);
        }
    }

    #[test]
    fn seeds_differ_between_cases() {
        let suite = conformance_suite();
        let mut seeds: Vec<u64> = suite.iter().map(|c| c.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), suite.len());
    }
}
