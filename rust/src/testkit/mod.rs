//! Conformance and verification substrate — the reusable layer every
//! test tier builds on, so each later perf/scale PR can prove it changed
//! nothing but speed.
//!
//! * [`oracle`] — f64 reference implementations of the three conv passes
//!   and the naive DFT, independent of every engine under test;
//! * [`cases`] — deterministic problem generation: adversarial shapes
//!   (k == h, prime sizes forcing Bluestein, non-smooth sizes,
//!   rectangular/batch-heavy/plane-heavy aspect ratios) plus seeded
//!   samples of the paper's Table-2 sweep space;
//! * [`tolerance`] — the acceptance-threshold model, scaling with
//!   accumulation depth and transform size instead of hard-coded
//!   constants, plus ULP distance for reporting;
//! * [`matrix`] — the conformance runner: every {engine × pass} pair
//!   (direct, im2col, vendor-FFT, fbfft, tiled — all three passes each)
//!   against the oracle and against each other, rendered as a per-cell
//!   max-abs / max-ULP table;
//! * [`faults`] — deterministic fault injection ([`FaultPlan`],
//!   `FBFFT_FAULTS`) driving the serving layer's supervision and
//!   degradation paths in reproducible chaos tests.
//!
//! `rust/tests/conformance.rs` runs the full matrix in CI; the engines'
//! own unit tests reuse the oracle and [`assert_close`].

pub mod cases;
pub mod faults;
pub mod matrix;
pub mod oracle;
pub mod tolerance;

pub use cases::{conformance_suite, ConformanceCase};
pub use faults::{FaultKind, FaultPlan};
pub use matrix::{run_case, run_suite, Engine, SuiteReport};

/// Assert two f32 slices agree elementwise within `tol`, with an
/// index-carrying panic message (the shared helper the engine unit tests
/// previously each duplicated).
#[track_caller]
pub fn assert_close(got: &[f32], want: &[f32], tol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!((g - w).abs() < tol,
                "idx {i}: {g} vs {w} (tol {tol})");
    }
}

/// Assert an f32 engine output matches an f64 oracle output within `tol`.
#[track_caller]
pub fn assert_close_oracle(got: &[f32], want: &[f64], tol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!((*g as f64 - w).abs() < tol as f64,
                "idx {i}: {g} vs {w} (tol {tol})");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assert_close_accepts_within_tol() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5);
        assert_close_oracle(&[1.0], &[1.0 + 1e-8], 1e-6);
    }

    #[test]
    #[should_panic(expected = "idx 1")]
    fn assert_close_reports_the_offending_index() {
        assert_close(&[1.0, 2.0], &[1.0, 3.0], 1e-3);
    }
}
