//! Table 3: whole-CNN timings (AlexNet / OverFeat-fast), three kernels ×
//! three passes through the network scheduler and PJRT artifacts.

use anyhow::Result;

use crate::coordinator::{LayerPlan, NetworkScheduler, Pass, Strategy};
use crate::metrics::Table;
use crate::runtime::Runtime;
use crate::trace;

/// Paper Table 3 totals (ms) for reference printing.
const PAPER: [(&str, &str, [f64; 4]); 6] = [
    ("alexnet", "cuFFT", [94.34, 96.69, 93.20, 284.23]),
    ("alexnet", "cuDNN", [147.32, 167.79, 153.96, 469.07]),
    ("alexnet", "ccn2", [99.03, 104.59, 103.29, 306.91]),
    ("overfeat", "cuFFT", [375.65, 460.48, 397.85, 1233.98]),
    ("overfeat", "cuDNN", [459.06, 634.26, 508.02, 1601.35]),
    ("overfeat", "ccn2", [398.87, 634.26, 450.82, 1282.80]),
];

/// Build the layer plans for one network under one strategy. conv1 is
/// strided, so it always runs the vendor path (exactly the paper's
/// setup: 'The first layer uses cuDNN for the cuFFT runs').
pub fn plans(net: &str, strategy: Strategy) -> Vec<LayerPlan> {
    let layers = match net {
        "alexnet" => trace::alexnet_layers(128),
        "overfeat" => trace::overfeat_fast_layers(128),
        other => panic!("unknown network {other}"),
    };
    layers
        .into_iter()
        .map(|(lname, paper)| {
            let p = trace::scale(&paper, 8, 4);
            let strat = if p.stride != 1 { Strategy::Vendor } else { strategy };
            LayerPlan {
                spec: format!("{net}.{lname}@_8"),
                problem: p,
                strategy: strat,
            }
        })
        .collect()
}

/// Table 3 at CPU scale: our three kernels are vendor (cuDNN analogue),
/// fbfft, and direct (ccn2 analogue).
pub fn table3_report(rt: &Runtime) -> Result<String> {
    let mut out = String::new();
    let mut t = Table::new(&[
        "network", "kernel", "fprop ms", "bprop ms", "accgrad ms",
        "total ms"]);
    for net in ["alexnet", "overfeat"] {
        for (strategy, label) in [(Strategy::Vendor, "vendor(cuDNN)"),
                                  (Strategy::Fbfft, "fbfft"),
                                  (Strategy::Direct, "direct(ccn2)")] {
            let mut sched = NetworkScheduler::new(rt, plans(net, strategy));
            sched.check_artifacts(&Pass::ALL)?;
            sched.warm(&Pass::ALL)?;
            let (f, b, a) = sched.run_all()?;
            let ms = |d: std::time::Duration| d.as_secs_f64() * 1e3;
            t.row(vec![
                net.to_string(),
                label.to_string(),
                format!("{:.2}", ms(f.total())),
                format!("{:.2}", ms(b.total())),
                format!("{:.2}", ms(a.total())),
                format!("{:.2}", ms(f.total() + b.total() + a.total())),
            ]);
        }
    }
    out.push_str(
        "Table 3: whole-CNN conv-layer totals (PJRT CPU, planes/8, S=4)\n");
    out.push_str(&t.render());
    out.push_str("\npaper (K40, ms):\n");
    let mut pt = Table::new(&["network", "kernel", "fprop", "bprop",
                              "accgrad", "total"]);
    for (net, k, v) in PAPER {
        pt.row(vec![net.into(), k.into(), v[0].to_string(),
                    v[1].to_string(), v[2].to_string(), v[3].to_string()]);
    }
    out.push_str(&pt.render());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_route_strided_conv1_to_vendor() {
        let p = plans("alexnet", Strategy::Fbfft);
        assert_eq!(p.len(), 5);
        assert_eq!(p[0].strategy, Strategy::Vendor);
        for l in &p[1..] {
            assert_eq!(l.strategy, Strategy::Fbfft);
        }
    }

    #[test]
    fn plan_spec_names_match_aot_scaling_convention() {
        let p = plans("overfeat", Strategy::Direct);
        assert_eq!(p[1].spec, "overfeat.conv2@_8");
        assert_eq!(p[1].problem.f, 12); // 96/8
    }
}
