//! The end-to-end training driver: iterate the AOT-compiled `train.step`
//! (fbfft convolutions in forward *and* backward via custom VJP) from
//! Rust on synthetic labeled data, logging the loss curve. Python never
//! runs — the whole training loop is PJRT executions of one module.

use anyhow::{anyhow, Result};

use crate::runtime::{HostTensor, Runtime};
use crate::trace::synthetic_batch;
use crate::util::{Json, Rng};

pub const PARAM_ORDER: [&str; 4] = ["conv1", "conv2", "dense_w", "dense_b"];

/// Loss trajectory + throughput of one training run.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub losses: Vec<f32>,
    pub steps: usize,
    pub seconds: f64,
}

impl TrainLog {
    pub fn first(&self) -> f32 {
        *self.losses.first().unwrap_or(&f32::NAN)
    }

    pub fn last(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }

    pub fn steps_per_sec(&self) -> f64 {
        self.steps as f64 / self.seconds.max(1e-9)
    }

    /// Render an ASCII loss curve (one row per log interval).
    pub fn render_curve(&self, points: usize) -> String {
        if self.losses.is_empty() {
            return "(no data)".into();
        }
        let max = self.losses.iter().cloned().fold(f32::MIN, f32::max);
        let stride = (self.losses.len() / points.max(1)).max(1);
        let mut out = String::new();
        for (i, l) in self.losses.iter().enumerate().step_by(stride) {
            let bar = ((l / max) * 50.0).round().max(0.0) as usize;
            out.push_str(&format!("step {i:>4}  loss {l:>8.4}  {}\n",
                                  "#".repeat(bar)));
        }
        out
    }
}

/// Train the demo CNN for `steps` SGD steps. Returns the loss log.
pub fn train_demo(rt: &Runtime, steps: usize, seed: u64) -> Result<TrainLog> {
    let entry = rt.manifest().require("train.step")?;
    let cfg = entry.meta.get("config").ok_or_else(|| {
        anyhow!("train.step missing config metadata")
    })?;
    let geti = |k: &str| -> Result<usize> {
        cfg.get(k)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("config missing {k}"))
    };
    let (s, c, hw, classes) =
        (geti("s")?, geti("c")?, geti("hw")?, geti("classes")?);

    // initial parameters from the AOT artifacts
    let mut params: Vec<HostTensor> = PARAM_ORDER
        .iter()
        .map(|k| rt.load_tensor(&format!("train.init.{k}")))
        .collect::<Result<_>>()?;

    let mut rng = Rng::new(seed);
    let mut log = TrainLog::default();
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let (x, y) = synthetic_batch(&mut rng, s, c, hw, classes);
        let mut inputs = params.clone();
        inputs.push(HostTensor::f32(x, &[s, c, hw, hw]));
        inputs.push(HostTensor::i32(y, &[s]));
        let mut outs = rt.execute("train.step", &inputs)?;
        if outs.len() != PARAM_ORDER.len() + 1 {
            return Err(anyhow!("train.step returned {} outputs", outs.len()));
        }
        let loss_t = outs.pop().unwrap();
        let loss = loss_t.as_f32()?[0];
        if !loss.is_finite() {
            return Err(anyhow!("loss diverged to {loss} at step {}",
                               log.steps));
        }
        params = outs;
        log.losses.push(loss);
        log.steps += 1;
    }
    log.seconds = t0.elapsed().as_secs_f64();
    Ok(log)
}

/// Classification accuracy of the current parameters on fresh synthetic
/// data, via the `train.logits` artifact.
pub fn eval_accuracy(rt: &Runtime, params: &[HostTensor], batches: usize,
                     seed: u64) -> Result<f64> {
    let entry = rt.manifest().require("train.logits")?;
    let cfg = entry.meta.get("config").unwrap();
    let s = cfg.get("s").and_then(Json::as_usize).unwrap();
    let c = cfg.get("c").and_then(Json::as_usize).unwrap();
    let hw = cfg.get("hw").and_then(Json::as_usize).unwrap();
    let classes = cfg.get("classes").and_then(Json::as_usize).unwrap();
    let mut rng = Rng::new(seed);
    let mut correct = 0usize;
    let mut total = 0usize;
    for _ in 0..batches {
        let (x, y) = synthetic_batch(&mut rng, s, c, hw, classes);
        let mut inputs = params.to_vec();
        inputs.push(HostTensor::f32(x, &[s, c, hw, hw]));
        let outs = rt.execute("train.logits", &inputs)?;
        let logits = outs[0].as_f32()?;
        for (b, label) in y.iter().enumerate() {
            let row = &logits[b * classes..(b + 1) * classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += usize::from(pred as i32 == *label);
            total += 1;
        }
    }
    Ok(correct as f64 / total as f64)
}

/// Re-run training and return the final parameters too (for eval).
pub fn train_and_eval(rt: &Runtime, steps: usize, seed: u64)
                      -> Result<(TrainLog, f64)> {
    // train_demo consumes params internally; repeat with param capture
    let entry = rt.manifest().require("train.step")?;
    let cfg = entry.meta.get("config").unwrap();
    let s = cfg.get("s").and_then(Json::as_usize).unwrap();
    let c = cfg.get("c").and_then(Json::as_usize).unwrap();
    let hw = cfg.get("hw").and_then(Json::as_usize).unwrap();
    let classes = cfg.get("classes").and_then(Json::as_usize).unwrap();
    let mut params: Vec<HostTensor> = PARAM_ORDER
        .iter()
        .map(|k| rt.load_tensor(&format!("train.init.{k}")))
        .collect::<Result<_>>()?;
    let mut rng = Rng::new(seed);
    let mut log = TrainLog::default();
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let (x, y) = synthetic_batch(&mut rng, s, c, hw, classes);
        let mut inputs = params.clone();
        inputs.push(HostTensor::f32(x, &[s, c, hw, hw]));
        inputs.push(HostTensor::i32(y, &[s]));
        let mut outs = rt.execute("train.step", &inputs)?;
        let loss = outs.pop().unwrap().as_f32()?[0];
        params = outs;
        log.losses.push(loss);
        log.steps += 1;
    }
    log.seconds = t0.elapsed().as_secs_f64();
    let acc = eval_accuracy(rt, &params, 8, seed + 1)?;
    Ok((log, acc))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_curve_renders() {
        let log = TrainLog {
            losses: vec![2.0, 1.5, 1.0, 0.5],
            steps: 4,
            seconds: 2.0,
        };
        assert_eq!(log.first(), 2.0);
        assert_eq!(log.last(), 0.5);
        assert_eq!(log.steps_per_sec(), 2.0);
        let curve = log.render_curve(4);
        assert!(curve.contains("step    0"));
        assert!(curve.contains("#"));
    }
}
