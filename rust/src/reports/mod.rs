//! Experiment report generators — one per table/figure in the paper's
//! evaluation (the DESIGN.md §5 index). The CLI subcommands, the bench
//! targets and EXPERIMENTS.md all run exactly these functions, so the
//! recorded numbers are regenerable by construction.

pub mod cnn;
pub mod fftbench;
pub mod serve;
pub mod sweep;
pub mod tables;
pub mod trainer;

use crate::util::{simd, threads, Json};

/// The `"host"` provenance block carried by every `BENCH_*.json`: the
/// numbers in a perf document mean nothing without the machine and the
/// dispatch tier they were measured under, so each document records the
/// detected CPU features, the tier actually dispatched (post
/// `FBFFT_SIMD` resolution), the worker count, and the `FBFFT_*`
/// environment knobs that shaped the run (absent knobs serialize as
/// `null` so "unset" and "empty" stay distinguishable).
pub fn host_meta() -> Json {
    let env = |k: &str| std::env::var(k)
        .map(|v| Json::str(&v))
        .unwrap_or(Json::Null);
    Json::obj(vec![
        ("cpu_features",
         Json::Arr(simd::detected_features().iter()
                       .map(|f| Json::str(f)).collect())),
        ("simd_tier", Json::str(simd::tier().tag())),
        ("simd_detected", Json::str(simd::detected().tag())),
        ("threads", Json::num(threads() as f64)),
        ("env", Json::obj(vec![
            ("FBFFT_SIMD", env("FBFFT_SIMD")),
            ("FBFFT_THREADS", env("FBFFT_THREADS")),
            ("FBFFT_FAULTS", env("FBFFT_FAULTS")),
        ])),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_meta_records_tier_and_threads() {
        let h = host_meta();
        let tier = h.get("simd_tier").and_then(Json::as_str).unwrap();
        assert!(simd::SimdTier::from_tag(tier).is_some(), "{tier}");
        let det = h.get("simd_detected").and_then(Json::as_str).unwrap();
        assert_eq!(det, simd::detected().tag());
        assert!(h.get("threads").unwrap().as_f64().unwrap() >= 1.0);
        let env = h.get("env").expect("env block");
        for k in ["FBFFT_SIMD", "FBFFT_THREADS", "FBFFT_FAULTS"] {
            assert!(env.get(k).is_some(), "missing env.{k}");
        }
        // round-trips through the in-tree parser (nulls included)
        let back = Json::parse(&h.to_string()).unwrap();
        assert_eq!(back.get("simd_tier").and_then(Json::as_str),
                   Some(tier));
    }
}

pub use cnn::table3_report;
pub use fftbench::{fig7_report, fig8_report};
pub use serve::{serve_json, serve_table};
pub use sweep::{fig16_report, sec54_report};
pub use tables::{breakdown_json, table4_report, table5_report,
                 tiling_report};
pub use trainer::{train_demo, TrainLog};
