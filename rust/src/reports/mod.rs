//! Experiment report generators — one per table/figure in the paper's
//! evaluation (the DESIGN.md §5 index). The CLI subcommands, the bench
//! targets and EXPERIMENTS.md all run exactly these functions, so the
//! recorded numbers are regenerable by construction.

pub mod cnn;
pub mod fftbench;
pub mod serve;
pub mod sweep;
pub mod tables;
pub mod trainer;

pub use cnn::table3_report;
pub use fftbench::{fig7_report, fig8_report};
pub use serve::{serve_json, serve_table};
pub use sweep::{fig16_report, sec54_report};
pub use tables::{breakdown_json, table4_report, table5_report,
                 tiling_report};
pub use trainer::{train_demo, TrainLog};
