//! The serving-engine report: `BENCH_serve.json` (machine-readable,
//! gated by CI's serve-smoke step) and the human table rendered from
//! that same document — the JSON is built first and the table reads
//! only it, so the two can never disagree (the `breakdown` pattern).
//!
//! Schema (version 5 — v4 plus the [`super::host_meta`] `host` block:
//! CPU features, the resolved SIMD dispatch tier, thread count and the
//! `FBFFT_*` env knobs, so a throughput number can never be read apart
//! from the machine/tier that produced it. v4 added the net-level
//! chain: one engine serves a whole
//! [`NetPlan`](crate::coordinator::NetPlan), so the document carries
//! the chain size, the end-to-end `states_per_sec` rate (images
//! through the *full chain* per wall second), the
//! submit/complete-overlap evidence counters, and one `per_layer` row
//! per chain position, merged across shards):
//!
//! ```text
//! { "version": 5, "bench": "serve", "mode": "closed"|"open",
//!   "host": {"cpu_features": [..], "simd_tier": t,
//!            "simd_detected": t, "threads": n, "env": {..}},
//!   "smoke": bool, "shards": N, "capacity": C, "pass": "fprop",
//!   "layers": L,                                // chain length
//!   "requests": n, "images": n, "launches": n,
//!   "completed": n, "requests_failed": n,       // ledger: == requests
//!   "rejected_deadline": n, "rejected_unavailable": n,
//!   "sla_miss": n, "launch_errors": n,
//!   "shard_restarts": n, "degraded_flushes": n,
//!   "faults_injected": n, "circuit_broken": n,  // shards tripped
//!   "wall_s": s, "throughput_img_s": r, "batch_fill": f,
//!   "busy_frac": f,
//!   "states_per_sec": r,       // images through the whole chain / s
//!   "pack_overlap_ns": n,      // host packing hidden behind layer
//!                              // execution (the submit/complete
//!                              // split's evidence counter)
//!   "pack_wait_ns": n,         // flush stalls waiting on the packer
//!   "weights_version": v,
//!   "spectra_hits": n, "spectra_misses": n, "spectra_invalidated": n,
//!   "weight_fft_ns": n,       // total weight-FFT time over the run
//!   "weight_fft_last_ns": n,  // most recent flush's weight-FFT time
//!                             // (0 on a spectrum hit — the CI gate)
//!   "cache": {"entries": n, "hits": n, "misses": n, "tunes": n,
//!             "load_warnings": n, "lock_recovered": n},
//!   "aggregate": {"count","mean_ms","p50_ms","p95_ms","p99_ms","max_ms"},
//!   "per_layer": [ {"layer","name","count","mean_ms","p50_ms",
//!                   "p95_ms","p99_ms","max_ms","spectra_hits",
//!                   "spectra_misses","spectra_invalidated",
//!                   "weight_fft_ns","degraded_flushes",
//!                   "launch_errors"} ],
//!   "per_shard": [ {"shard","requests","images","launches",
//!                   "completed","requests_failed","restarts",
//!                   "degraded_flushes","faults_injected",
//!                   "circuit_broken",
//!                   "flushes_full","flushes_timeout","flushes_drain",
//!                   "spectra_hits","spectra_misses",
//!                   "spectra_invalidated","weight_fft_ns","batch_fill",
//!                   "pack_overlap_ns","pack_wait_ns",
//!                   "queue_depth_p50","queue_depth_max",
//!                   "mean_ms","p50_ms","p95_ms","p99_ms","max_ms"} ] }
//! ```
//!
//! Chaos runs (`--faults`, `FBFFT_FAULTS`) may also carry an
//! `"overload"` block from the smoke-mode open-loop knee probe.

use std::time::Duration;

use crate::coordinator::service::EngineReport;
use crate::metrics::{Histogram, Table};
use crate::util::Json;

/// Latency summary of one histogram as a `*_ms` JSON object.
fn summary_ms(hist: &Histogram) -> Json {
    let mut h = hist.clone();
    let s = h.summary();
    Json::obj(vec![
        ("count", Json::num(s.count as f64)),
        ("mean_ms", Json::num(s.mean * 1e3)),
        ("p50_ms", Json::num(s.p50 * 1e3)),
        ("p95_ms", Json::num(s.p95 * 1e3)),
        ("p99_ms", Json::num(s.p99 * 1e3)),
        ("max_ms", Json::num(s.max * 1e3)),
    ])
}

/// Build the `BENCH_serve.json` document from a finished engine run.
pub fn serve_json(r: &EngineReport, mode: &str, smoke: bool,
                  wall: Duration) -> Json {
    let wall_s = wall.as_secs_f64();
    let mut per_shard = Vec::with_capacity(r.shards.len());
    for s in &r.shards {
        let mut depth = s.depth.clone();
        let d = depth.summary();
        let mut row = match summary_ms(&s.latency) {
            Json::Obj(m) => m,
            _ => unreachable!("summary_ms builds an object"),
        };
        row.insert("shard".into(), Json::num(s.shard as f64));
        row.insert("requests".into(), Json::num(s.requests as f64));
        row.insert("images".into(), Json::num(s.images as f64));
        row.insert("launches".into(), Json::num(s.launches as f64));
        row.insert("flushes_full".into(),
                   Json::num(s.flushes_full as f64));
        row.insert("flushes_timeout".into(),
                   Json::num(s.flushes_timeout as f64));
        row.insert("flushes_drain".into(),
                   Json::num(s.flushes_drain as f64));
        row.insert("completed".into(),
                   Json::num(s.requests_completed as f64));
        row.insert("requests_failed".into(),
                   Json::num(s.requests_failed as f64));
        row.insert("restarts".into(), Json::num(s.restarts as f64));
        row.insert("degraded_flushes".into(),
                   Json::num(s.degraded_flushes as f64));
        row.insert("faults_injected".into(),
                   Json::num(s.faults_injected as f64));
        row.insert("circuit_broken".into(),
                   Json::num(if s.circuit_broken { 1.0 } else { 0.0 }));
        row.insert("spectra_hits".into(),
                   Json::num(s.spectra_hits as f64));
        row.insert("spectra_misses".into(),
                   Json::num(s.spectra_misses as f64));
        row.insert("spectra_invalidated".into(),
                   Json::num(s.spectra_invalidated as f64));
        row.insert("weight_fft_ns".into(),
                   Json::num(s.weight_fft.sum() * 1e9));
        row.insert("batch_fill".into(), Json::num(s.batch_fill));
        row.insert("pack_overlap_ns".into(),
                   Json::num(s.pack_overlap.as_secs_f64() * 1e9));
        row.insert("pack_wait_ns".into(),
                   Json::num(s.pack_wait.as_secs_f64() * 1e9));
        row.insert("queue_depth_p50".into(), Json::num(d.p50));
        row.insert("queue_depth_max".into(), Json::num(d.max));
        per_shard.push(Json::Obj(row));
    }
    let mut per_layer = Vec::with_capacity(r.net.len());
    for (i, ls) in r.layer_stats().iter().enumerate() {
        let mut row = match summary_ms(&ls.latency) {
            Json::Obj(m) => m,
            _ => unreachable!("summary_ms builds an object"),
        };
        row.insert("layer".into(), Json::num(i as f64));
        row.insert("name".into(), Json::str(&ls.name));
        row.insert("spectra_hits".into(),
                   Json::num(ls.spectra_hits as f64));
        row.insert("spectra_misses".into(),
                   Json::num(ls.spectra_misses as f64));
        row.insert("spectra_invalidated".into(),
                   Json::num(ls.spectra_invalidated as f64));
        row.insert("weight_fft_ns".into(),
                   Json::num(ls.weight_fft.sum() * 1e9));
        row.insert("degraded_flushes".into(),
                   Json::num(ls.degraded as f64));
        row.insert("launch_errors".into(),
                   Json::num(ls.launch_errors as f64));
        per_layer.push(Json::Obj(row));
    }
    let weight_fft = r.weight_fft();
    Json::obj(vec![
        ("version", Json::num(5.0)),
        ("bench", Json::str("serve")),
        ("mode", Json::str(mode)),
        ("smoke", Json::Bool(smoke)),
        ("host", super::host_meta()),
        ("shards", Json::num(r.shards.len() as f64)),
        ("capacity", Json::num(r.capacity as f64)),
        ("pass", Json::str(r.pass.tag())),
        ("layers", Json::num(r.net.len() as f64)),
        ("requests", Json::num(r.requests() as f64)),
        ("images", Json::num(r.images() as f64)),
        ("launches", Json::num(r.launches() as f64)),
        ("completed", Json::num(r.requests_completed() as f64)),
        ("requests_failed", Json::num(r.requests_failed() as f64)),
        ("rejected_deadline", Json::num(r.rejected_deadline as f64)),
        ("rejected_unavailable",
         Json::num(r.rejected_unavailable as f64)),
        ("sla_miss", Json::num(r.sla_miss() as f64)),
        ("launch_errors", Json::num(r.launch_errors() as f64)),
        ("shard_restarts", Json::num(r.shard_restarts() as f64)),
        ("degraded_flushes", Json::num(r.degraded_flushes() as f64)),
        ("faults_injected", Json::num(r.faults_injected as f64)),
        ("circuit_broken", Json::num(r.circuit_broken() as f64)),
        ("wall_s", Json::num(wall_s)),
        ("throughput_img_s",
         Json::num(if wall_s > 0.0 {
             r.images() as f64 / wall_s
         } else {
             0.0
         })),
        ("batch_fill", Json::num(r.batch_fill())),
        ("busy_frac",
         Json::num(if wall_s > 0.0 {
             // busy is summed across shards; normalize by shard-seconds
             r.busy().as_secs_f64() / (wall_s * r.shards.len().max(1) as f64)
         } else {
             0.0
         })),
        // every served image traverses the whole chain, so the
        // end-to-end state rate is images per wall second
        ("states_per_sec",
         Json::num(if wall_s > 0.0 {
             r.images() as f64 / wall_s
         } else {
             0.0
         })),
        ("pack_overlap_ns",
         Json::num(r.pack_overlap().as_secs_f64() * 1e9)),
        ("pack_wait_ns", Json::num(r.pack_wait().as_secs_f64() * 1e9)),
        ("weights_version", Json::num(r.weights_version() as f64)),
        ("spectra_hits", Json::num(r.spectra_hits() as f64)),
        ("spectra_misses", Json::num(r.spectra_misses() as f64)),
        ("spectra_invalidated",
         Json::num(r.spectra_invalidated() as f64)),
        ("weight_fft_ns", Json::num(weight_fft.sum() * 1e9)),
        ("weight_fft_last_ns", Json::num(weight_fft.last() * 1e9)),
        ("cache", Json::obj(vec![
            ("entries", Json::num(r.cache.entries as f64)),
            ("hits", Json::num(r.cache.hits as f64)),
            ("misses", Json::num(r.cache.misses as f64)),
            ("tunes", Json::num(r.cache.tunes as f64)),
            ("load_warnings", Json::num(r.cache.load_warnings as f64)),
            ("lock_recovered",
             Json::num(r.cache.lock_recovered as f64)),
        ])),
        ("aggregate", summary_ms(&r.aggregate_latency())),
        ("per_layer", Json::Arr(per_layer)),
        ("per_shard", Json::Arr(per_shard)),
    ])
}

/// Render the human serving table from a `BENCH_serve.json` document:
/// one row per shard, one aggregate row, and a counters footer.
pub fn serve_table(j: &Json) -> String {
    let g = |e: &Json, k: &str| e.get(k).and_then(Json::as_f64)
        .unwrap_or(f64::NAN);
    let n = |e: &Json, k: &str| e.get(k).and_then(Json::as_usize)
        .unwrap_or(0);
    let ms = |v: f64| format!("{v:.2}");
    let mut t = Table::new(&[
        "shard", "reqs", "imgs", "launches", "fill", "depth p50/max",
        "p50 ms", "p95 ms", "p99 ms", "max ms"]);
    for s in j.get("per_shard").and_then(Json::as_arr).unwrap_or(&[]) {
        t.row(vec![
            format!("{}", n(s, "shard")),
            format!("{}", n(s, "requests")),
            format!("{}", n(s, "images")),
            format!("{}", n(s, "launches")),
            format!("{:.2}", g(s, "batch_fill")),
            format!("{:.0}/{:.0}", g(s, "queue_depth_p50"),
                    g(s, "queue_depth_max")),
            ms(g(s, "p50_ms")),
            ms(g(s, "p95_ms")),
            ms(g(s, "p99_ms")),
            ms(g(s, "max_ms")),
        ]);
    }
    if let Some(agg) = j.get("aggregate") {
        t.row(vec![
            "all".into(),
            format!("{}", n(j, "requests")),
            format!("{}", n(j, "images")),
            format!("{}", n(j, "launches")),
            format!("{:.2}", g(j, "batch_fill")),
            "-".into(),
            ms(g(agg, "p50_ms")),
            ms(g(agg, "p95_ms")),
            ms(g(agg, "p99_ms")),
            ms(g(agg, "max_ms")),
        ]);
    }
    // one row per chain position, from the merged per_layer block
    let mut lt = Table::new(&[
        "layer", "name", "flushes", "p50 ms", "p99 ms", "max ms",
        "spec hit/miss", "wfft ms", "degraded", "errors"]);
    for l in j.get("per_layer").and_then(Json::as_arr).unwrap_or(&[]) {
        lt.row(vec![
            format!("{}", n(l, "layer")),
            l.get("name").and_then(Json::as_str).unwrap_or("?").into(),
            format!("{}", n(l, "count")),
            ms(g(l, "p50_ms")),
            ms(g(l, "p99_ms")),
            ms(g(l, "max_ms")),
            format!("{}/{}", n(l, "spectra_hits"),
                    n(l, "spectra_misses")),
            format!("{:.2}", g(l, "weight_fft_ns") / 1e6),
            format!("{}", n(l, "degraded_flushes")),
            format!("{}", n(l, "launch_errors")),
        ]);
    }
    let cache = j.get("cache");
    let cn = |k: &str| cache.and_then(|c| c.get(k))
        .and_then(Json::as_usize).unwrap_or(0);
    let host = j.get("host");
    let hs = |k: &str| host.and_then(|h| h.get(k))
        .and_then(Json::as_str).unwrap_or("?");
    format!(
        "serve: {} mode, {} shards x capacity {} ({} pass, {} layers)\n\
         host: simd {} (detected {}), {:.0} threads\n\
         {}{}\
         throughput {:.0} img/s over {:.2}s wall, busy {:.0}%  \
         rejected {}  sla_miss {}\n\
         chain: {:.0} states/s end-to-end, pack overlap {:.2} ms \
         (wait {:.2} ms)\n\
         strategy cache: {} entries, {} hits / {} misses, {} tunes\n\
         weight spectra: v{}, {} hits / {} misses, {} invalidated, \
         weight-FFT {:.2} ms total ({:.0} ns last flush)\n\
         supervision: {} completed / {} failed, {} restarts, \
         {} degraded flushes, {} faults injected, \
         {} circuit-broken\n",
        j.get("mode").and_then(Json::as_str).unwrap_or("?"),
        n(j, "shards"), n(j, "capacity"),
        j.get("pass").and_then(Json::as_str).unwrap_or("?"),
        n(j, "layers"),
        hs("simd_tier"), hs("simd_detected"),
        host.and_then(|h| h.get("threads")).and_then(Json::as_f64)
            .unwrap_or(f64::NAN),
        t.render(), lt.render(),
        g(j, "throughput_img_s"), g(j, "wall_s"),
        g(j, "busy_frac") * 100.0,
        n(j, "rejected_deadline"), n(j, "sla_miss"),
        g(j, "states_per_sec"),
        g(j, "pack_overlap_ns") / 1e6, g(j, "pack_wait_ns") / 1e6,
        cn("entries"), cn("hits"), cn("misses"), cn("tunes"),
        n(j, "weights_version"), n(j, "spectra_hits"),
        n(j, "spectra_misses"), n(j, "spectra_invalidated"),
        g(j, "weight_fft_ns") / 1e6, g(j, "weight_fft_last_ns"),
        n(j, "completed"), n(j, "requests_failed"),
        n(j, "shard_restarts"), n(j, "degraded_flushes"),
        n(j, "faults_injected"), n(j, "circuit_broken"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::autotuner::CacheStats;
    use crate::coordinator::service::{LayerStats, ShardReport};
    use crate::coordinator::{NetPlan, Pass};

    fn sample_report() -> EngineReport {
        let net = NetPlan::alexnet_small(8);
        let mut shards = Vec::new();
        for i in 0..2usize {
            let mut s = ShardReport { shard: i, ..Default::default() };
            s.requests = 10 * (i + 1);
            s.images = 20 * (i + 1);
            s.launches = 5;
            s.batch_fill = 0.75;
            s.flushes_full = 3;
            s.flushes_timeout = 1;
            s.flushes_drain = 1;
            s.spectra_hits = 4;
            s.spectra_misses = 1;
            s.spectra_invalidated = i; // shard 1 saw one version bump
            s.weights_version = (i + 1) as u64;
            s.requests_completed = 10 * (i + 1) - i;
            s.requests_failed = i; // shard 1 failed one (panic)
            s.restarts = i;
            s.degraded_flushes = i;
            s.faults_injected = i;
            s.pack_overlap = Duration::from_micros(150);
            s.pack_wait = Duration::from_micros(30);
            // one miss paid the weight FFT, then four hits were free
            s.weight_fft.record(2e-3);
            for _ in 0..4 {
                s.weight_fft.record(0.0);
            }
            for k in 1..=10 {
                s.latency.record(k as f64 * 1e-3 * (i + 1) as f64);
                s.depth.record(k as f64);
            }
            // per-chain-position rows, one per net layer
            s.layers = net
                .layers()
                .iter()
                .enumerate()
                .map(|(li, l)| {
                    let mut ls = LayerStats {
                        name: l.name.clone(),
                        spectra_hits: 2,
                        spectra_misses: 1,
                        degraded: li, // layer 1+ saw a degraded flush
                        ..Default::default()
                    };
                    for _ in 0..5 {
                        ls.latency.record(1e-3 * (li + 1) as f64);
                    }
                    ls.weight_fft.record(1e-3);
                    ls
                })
                .collect();
            shards.push(s);
        }
        EngineReport {
            shards,
            rejected_deadline: 1,
            rejected_unavailable: 0,
            faults_injected: 1,
            cache: CacheStats { entries: 3, hits: 40, misses: 5,
                                tunes: 3, ..Default::default() },
            capacity: 8,
            pass: Pass::Fprop,
            net,
        }
    }

    #[test]
    fn json_has_gate_keys_and_consistent_totals() {
        let r = sample_report();
        let j = serve_json(&r, "closed", true,
                           Duration::from_millis(500));
        assert_eq!(j.get("version").unwrap().as_usize(), Some(5));
        // the host provenance block names the tier the run executed
        // under — serve numbers are not portable across tiers
        let host = j.get("host").expect("host block");
        assert_eq!(host.get("simd_tier").and_then(Json::as_str),
                   Some(crate::util::simd::tier().tag()));
        assert!(host.get("cpu_features").and_then(Json::as_arr)
                    .is_some());
        assert_eq!(j.get("requests").unwrap().as_usize(), Some(30));
        assert_eq!(j.get("images").unwrap().as_usize(), Some(60));
        assert_eq!(j.get("layers").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("rejected_deadline").unwrap().as_usize(),
                   Some(1));
        // the ledger: completed + failed == requests
        assert_eq!(j.get("completed").unwrap().as_usize(), Some(29));
        assert_eq!(j.get("requests_failed").unwrap().as_usize(),
                   Some(1));
        assert_eq!(j.get("shard_restarts").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("degraded_flushes").unwrap().as_usize(),
                   Some(1));
        assert_eq!(j.get("faults_injected").unwrap().as_usize(),
                   Some(1));
        assert_eq!(j.get("circuit_broken").unwrap().as_usize(), Some(0));
        assert_eq!(j.get("rejected_unavailable").unwrap().as_usize(),
                   Some(0));
        // the spectrum-cache gate keys: totals over both shards, the
        // newest served weights version, and the per-flush probe value
        assert_eq!(j.get("spectra_hits").unwrap().as_usize(), Some(8));
        assert_eq!(j.get("spectra_misses").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("spectra_invalidated").unwrap().as_usize(),
                   Some(1));
        assert_eq!(j.get("weights_version").unwrap().as_usize(),
                   Some(2));
        // two 2ms misses in total; the last recorded flush was a hit
        assert!((j.get("weight_fft_ns").unwrap().as_f64().unwrap()
                 - 4e6).abs() < 1.0);
        assert_eq!(j.get("weight_fft_last_ns").unwrap().as_f64(),
                   Some(0.0));
        let agg = j.get("aggregate").expect("aggregate block");
        for k in ["p50_ms", "p95_ms", "p99_ms", "max_ms", "mean_ms"] {
            assert!(agg.get(k).and_then(Json::as_f64).is_some(),
                    "missing aggregate {k}");
        }
        // aggregate p99 covers both shards: max sample is 20ms
        assert!((agg.get("max_ms").unwrap().as_f64().unwrap() - 20.0)
                    .abs() < 1e-9);
        let per = j.get("per_shard").unwrap().as_arr().unwrap();
        assert_eq!(per.len(), 2);
        for s in per {
            for k in ["p50_ms", "p99_ms", "batch_fill",
                      "queue_depth_max", "flushes_drain",
                      "spectra_hits", "spectra_misses",
                      "spectra_invalidated", "weight_fft_ns",
                      "completed", "requests_failed", "restarts",
                      "degraded_flushes", "faults_injected",
                      "circuit_broken", "pack_overlap_ns",
                      "pack_wait_ns"] {
                assert!(s.get(k).and_then(Json::as_f64).is_some(),
                        "missing per-shard {k}");
            }
        }
        let cache = j.get("cache").unwrap();
        for k in ["load_warnings", "lock_recovered"] {
            assert!(cache.get(k).and_then(Json::as_usize).is_some(),
                    "missing cache.{k}");
        }
        // throughput: 60 images / 0.5 s — and every image traverses
        // the whole chain, so states_per_sec matches
        assert!((j.get("throughput_img_s").unwrap().as_f64().unwrap()
                 - 120.0).abs() < 1e-6);
        assert!((j.get("states_per_sec").unwrap().as_f64().unwrap()
                 - 120.0).abs() < 1e-6);
        // two shards x 150us packing hidden behind execution
        assert!((j.get("pack_overlap_ns").unwrap().as_f64().unwrap()
                 - 300e3).abs() < 1.0);
        assert!((j.get("pack_wait_ns").unwrap().as_f64().unwrap()
                 - 60e3).abs() < 1.0);
        // one per_layer row per chain position, merged across shards
        let per_layer = j.get("per_layer").unwrap().as_arr().unwrap();
        assert_eq!(per_layer.len(), 3);
        for (i, l) in per_layer.iter().enumerate() {
            assert_eq!(l.get("layer").unwrap().as_usize(), Some(i));
            assert!(l.get("name").and_then(Json::as_str).is_some());
            // 2 shards x 5 flush samples each
            assert_eq!(l.get("count").unwrap().as_usize(), Some(10));
            assert_eq!(l.get("spectra_hits").unwrap().as_usize(),
                       Some(4));
            assert_eq!(l.get("spectra_misses").unwrap().as_usize(),
                       Some(2));
            assert_eq!(l.get("degraded_flushes").unwrap().as_usize(),
                       Some(2 * i));
            for k in ["mean_ms", "p50_ms", "p95_ms", "p99_ms", "max_ms",
                      "weight_fft_ns", "launch_errors"] {
                assert!(l.get(k).and_then(Json::as_f64).is_some(),
                        "missing per-layer {k}");
            }
        }
    }

    #[test]
    fn json_round_trips_and_table_renders() {
        let r = sample_report();
        let j = serve_json(&r, "open", false, Duration::from_secs(1));
        let parsed = Json::parse(&j.to_string()).expect("valid JSON");
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("serve"));
        let table = serve_table(&parsed);
        // 2 shard rows + aggregate row + header/rule
        assert!(table.lines().count() >= 6, "{table}");
        assert!(table.contains("all"));
        assert!(table.contains("strategy cache: 3 entries"));
        assert!(table.contains("weight spectra: v2, 8 hits / 2 misses"),
                "{table}");
        // the host line names the rendered run's dispatch tier
        assert!(table.contains(&format!(
            "host: simd {}", crate::util::simd::tier().tag())),
                "{table}");
        // the per-layer table names every chain position
        for name in ["conv1", "conv2", "conv3"] {
            assert!(table.contains(name), "missing layer row {name}");
        }
        assert!(table.contains("states/s"), "{table}");
    }
}
