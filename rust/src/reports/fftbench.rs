//! Figures 7–8: fbfft vs the vendor FFT, 1-D and 2-D, across transform
//! sizes and batch counts.
//!
//! Primary measurement: the host engines (`fft::fbfft_host` vs the
//! vendor-analogue planner used the way a black box forces — explicit
//! padded buffers, separate transpose). Secondary: the PJRT artifacts
//! (`fft1d.*` / `fft2d.*`), i.e. the Pallas kernel vs XLA's native FFT
//! through the runtime, when a `Runtime` is supplied.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::fft::{fbfft_host, plan, real, C32};
use crate::metrics::{bench, Table};
use crate::runtime::{HostTensor, Runtime};
use crate::util::Rng;

/// Vendor-style batched 1-D R2C: the caller materializes the zero-padded
/// buffer (cuFFT's §5.1 limitation), then transforms row by row through
/// the planner.
fn vendor_rfft_batch(input: &[f32], n_in: usize, n: usize, batch: usize,
                     out: &mut [C32]) {
    let nf = real::rfft_len(n);
    let mut padded = vec![0f32; n];
    for b in 0..batch {
        padded[..n_in].copy_from_slice(&input[b * n_in..(b + 1) * n_in]);
        padded[n_in..].fill(0.0);
        let f = real::rfft(&padded, n);
        out[b * nf..(b + 1) * nf].copy_from_slice(&f);
    }
}

/// Vendor-style batched 2-D R2C **plus** the explicit transposition the
/// pipeline needs afterwards (Figure 8's honest comparison; fbfft emits
/// the transposed layout for free).
fn vendor_rfft2_batch_transposed(input: &[f32], hw: usize, n: usize,
                                 batch: usize, out: &mut [C32]) {
    use crate::fft::fft2d::rfft2;
    let nf = real::rfft_len(n);
    for b in 0..batch {
        let f = rfft2(&input[b * hw * hw..(b + 1) * hw * hw], hw, hw, n);
        // transpose (kh, kw) -> (kw, kh, batch)
        for kh in 0..n {
            for kw in 0..nf {
                out[(kw * n + kh) * batch + b] = f[kh * nf + kw];
            }
        }
    }
}

const MIN_TIME: Duration = Duration::from_millis(60);

/// Figure 7: batched 1-D FFT, host engines.
pub fn fig7_report(rt: Option<&Runtime>) -> Result<String> {
    let mut t = Table::new(&[
        "n", "batch", "vendor ms", "fbfft ms", "speedup"]);
    let mut rng = Rng::new(0x717);
    for n in [8usize, 16, 32, 64, 128, 256] {
        for batch in [256usize, 4096, 16384] {
            let x = rng.normal_vec(batch * n);
            let nf = real::rfft_len(n);
            let mut out = vec![C32::ZERO; batch * nf];
            // warm the plan caches outside the timed region
            plan::cached(n / 2.max(1));
            let fb = fbfft_host::cached(n);
            let rv = bench(|| {
                vendor_rfft_batch(&x, n, n, batch, &mut out);
                std::hint::black_box(&out);
            }, MIN_TIME);
            let rf = bench(|| {
                fb.rfft_batch(&x, n, batch, &mut out);
                std::hint::black_box(&out);
            }, MIN_TIME);
            t.row(vec![
                n.to_string(),
                batch.to_string(),
                format!("{:.3}", rv.secs_per_iter() * 1e3),
                format!("{:.3}", rf.secs_per_iter() * 1e3),
                format!("{:.2}x", rv.secs_per_iter() / rf.secs_per_iter()),
            ]);
        }
    }
    let mut out = format!(
        "Figure 7: batched 1-D R2C FFT, fbfft vs vendor planner (host)\n{}",
        t.render());
    if let Some(rt) = rt {
        out.push_str(&pjrt_fft_table(rt, "fft1d.")?);
    }
    Ok(out)
}

/// Figure 8: batched 2-D FFT (transposed output), host engines.
pub fn fig8_report(rt: Option<&Runtime>) -> Result<String> {
    let mut t = Table::new(&[
        "n", "batch", "vendor+trans ms", "fbfft ms", "speedup"]);
    let mut rng = Rng::new(0x718);
    for n in [8usize, 16, 32, 64] {
        for batch in [64usize, 256, 1024] {
            let x = rng.normal_vec(batch * n * n);
            let nf = real::rfft_len(n);
            let mut out = vec![C32::ZERO; nf * n * batch];
            let fb = fbfft_host::cached(n);
            let rv = bench(|| {
                vendor_rfft2_batch_transposed(&x, n, n, batch, &mut out);
                std::hint::black_box(&out);
            }, MIN_TIME);
            let rf = bench(|| {
                fb.rfft2_batch_transposed(&x, n, n, batch, &mut out);
                std::hint::black_box(&out);
            }, MIN_TIME);
            t.row(vec![
                n.to_string(),
                batch.to_string(),
                format!("{:.3}", rv.secs_per_iter() * 1e3),
                format!("{:.3}", rf.secs_per_iter() * 1e3),
                format!("{:.2}x", rv.secs_per_iter() / rf.secs_per_iter()),
            ]);
        }
    }
    let mut out = format!(
        "Figure 8: batched 2-D R2C FFT with transposed output (host)\n{}",
        t.render());
    if let Some(rt) = rt {
        out.push_str(&pjrt_fft_table(rt, "fft2d.")?);
    }
    Ok(out)
}

/// The PJRT side: Pallas fbfft kernels vs XLA's native FFT, loaded from
/// the `fft1d.*` / `fft2d.*` artifacts.
fn pjrt_fft_table(rt: &Runtime, prefix: &str) -> Result<String> {
    let mut rows: Vec<(usize, usize, f64, f64)> = Vec::new();
    let entries: Vec<_> = rt
        .manifest()
        .with_prefix(prefix)
        .map(|e| (e.name.clone(), e.inputs[0].shape.clone(), e.meta.clone()))
        .collect();
    let mut rng = Rng::new(0x719);
    for (name, shape, meta) in &entries {
        let n = meta.get("n").and_then(|v| v.as_usize()).unwrap_or(0);
        let batch = meta.get("batch").and_then(|v| v.as_usize()).unwrap_or(0);
        let which = meta
            .get("which")
            .and_then(|v| v.as_str())
            .unwrap_or("?")
            .to_string();
        let x = HostTensor::f32(
            rng.normal_vec(shape.iter().product()), shape);
        rt.execute(name, std::slice::from_ref(&x))?; // warm/compile
        let reps = 3;
        let t0 = Instant::now();
        for _ in 0..reps {
            rt.execute(name, std::slice::from_ref(&x))?;
        }
        let secs = t0.elapsed().as_secs_f64() / reps as f64;
        if let Some(r) = rows.iter_mut().find(|r| r.0 == n && r.1 == batch) {
            if which == "fbfft" {
                r.3 = secs;
            } else {
                r.2 = secs;
            }
        } else if which == "fbfft" {
            rows.push((n, batch, f64::NAN, secs));
        } else {
            rows.push((n, batch, secs, f64::NAN));
        }
    }
    rows.sort_by_key(|r| (r.0, r.1));
    let mut t = Table::new(&["n", "batch", "vendor(XLA) ms", "pallas ms",
                             "ratio"]);
    for (n, b, v, f) in rows {
        t.row(vec![
            n.to_string(), b.to_string(),
            format!("{:.3}", v * 1e3), format!("{:.3}", f * 1e3),
            format!("{:.2}x", v / f),
        ]);
    }
    Ok(format!("\nPJRT (Pallas interpret vs XLA native FFT):\n{}",
               t.render()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vendor_batched_helpers_are_correct() {
        let mut rng = Rng::new(1);
        let (n, batch) = (16usize, 3usize);
        let x = rng.normal_vec(batch * n);
        let nf = real::rfft_len(n);
        let mut a = vec![C32::ZERO; batch * nf];
        let mut b = vec![C32::ZERO; batch * nf];
        vendor_rfft_batch(&x, n, n, batch, &mut a);
        fbfft_host::cached(n).rfft_batch(&x, n, batch, &mut b);
        for (u, v) in a.iter().zip(&b) {
            assert!((*u - *v).abs() < 1e-3);
        }
    }

    #[test]
    fn vendor_2d_transposed_matches_fbfft() {
        let mut rng = Rng::new(2);
        let (n, batch) = (8usize, 2usize);
        let x = rng.normal_vec(batch * n * n);
        let nf = real::rfft_len(n);
        let mut a = vec![C32::ZERO; nf * n * batch];
        let mut b = vec![C32::ZERO; nf * n * batch];
        vendor_rfft2_batch_transposed(&x, n, n, batch, &mut a);
        fbfft_host::cached(n).rfft2_batch_transposed(&x, n, n, batch, &mut b);
        for (u, v) in a.iter().zip(&b) {
            assert!((*u - *v).abs() < 1e-3);
        }
    }
}
