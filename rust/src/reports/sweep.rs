//! Figures 1–6 (the 8,232-configuration sweep) and §5.4 (fbfft-conv vs
//! vendor-FFT-conv).
//!
//! The full plane comes from the calibrated K40m model (`cost::model`);
//! the measured anchor subset runs real PJRT executables when a runtime
//! is supplied (artifacts `conv.swp.*`). Both are printed so the reader
//! can see model and measurement side by side.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::conv::ConvProblem;
use crate::cost::{CudnnModel, CufftConvModel};
use crate::metrics::{Heatmap, Table};
use crate::runtime::{HostTensor, Runtime};
use crate::trace;
use crate::util::Rng;

/// Buckets for the y axis (problem size S·f·f') of Figures 1–6.
const SIZE_BUCKETS: [(u64, &str); 8] = [
    (1 << 4, "<=2^4"),
    (1 << 8, "<=2^8"),
    (1 << 12, "<=2^12"),
    (1 << 16, "<=2^16"),
    (1 << 20, "<=2^20"),
    (1 << 22, "<=2^22"),
    (1 << 24, "<=2^24"),
    (u64::MAX, ">2^24"),
];

fn bucket(ps: u64) -> usize {
    SIZE_BUCKETS.iter().position(|(hi, _)| ps <= *hi).unwrap()
}

/// Model-predicted speedup heatmaps (one per kernel size, Figures 1–6)
/// over all 8,232 Table-2 configurations.
pub fn fig16_report() -> String {
    let dnn = CudnnModel::default();
    let fft = CufftConvModel::vendor();
    let grid = trace::table2_grid();
    let mut out = String::new();
    out.push_str("Figures 1-6: cuFFT-conv speedup over cuDNN (K40m model), \
                  8232 configs\n");
    out.push_str("rows: problem size S*f*f' | cols: output h/w\n\n");
    for &k in &trace::TABLE2_K {
        // average speedup per (bucket, y) cell
        let mut acc: BTreeMap<(usize, usize), (f64, usize)> = BTreeMap::new();
        for p in grid.iter().filter(|p| p.kh == k) {
            let s = dnn.time(p) / fft.autotuned_time(p);
            let key = (bucket(p.problem_size() as u64), p.yh());
            let e = acc.entry(key).or_insert((0.0, 0));
            e.0 += s;
            e.1 += 1;
        }
        let cols: Vec<usize> = trace::TABLE2_Y.to_vec();
        let rows: Vec<&str> =
            SIZE_BUCKETS.iter().rev().map(|(_, l)| *l).collect();
        let mut cells = vec![f64::NAN; rows.len() * cols.len()];
        for ((b, y), (sum, n)) in &acc {
            let r = SIZE_BUCKETS.len() - 1 - b;
            let c = cols.iter().position(|v| v == y).unwrap();
            cells[r * cols.len() + c] = sum / *n as f64;
        }
        let hm = Heatmap {
            col_labels: cols.iter().map(|c| format!("{c:>3}")).collect(),
            row_labels: rows.iter().map(|s| s.to_string()).collect(),
            cells,
        };
        out.push_str(&hm.render(&format!("-- Figure (k={k}) --")));
        out.push('\n');
    }
    // paper headline checks
    let mut max3 = 0f64;
    let mut max5 = 0f64;
    let mut max13 = 0f64;
    for p in &grid {
        let s = dnn.time(p) / fft.autotuned_time(p);
        match p.kh {
            3 => max3 = max3.max(s),
            5 => max5 = max5.max(s),
            13 => max13 = max13.max(s),
            _ => {}
        }
    }
    out.push_str(&format!(
        "headline: max speedup k=3: {max3:.2}x (paper 1.84x), \
         k=5: {max5:.2}x (paper 5.33x), k=13: {max13:.2}x (paper 23.54x)\n"));
    out
}

/// Measured anchor subset for Figures 1–6: the `conv.swp.*` artifacts
/// (vendor vs fbfft fprop) through the PJRT runtime.
pub fn fig16_measured(rt: &Runtime) -> Result<String> {
    let mut table = Table::new(&[
        "k", "y", "problem", "vendor ms", "fbfft ms", "speedup"]);
    let mut rng = Rng::new(0x516);
    for k in [3usize, 5, 9, 13] {
        for y in [4usize, 8, 16, 32] {
            let spec = format!("swp.k{k}.y{y}");
            let Some(e) = rt.manifest().conv(&spec, "vendor", "fprop")
            else { continue };
            let p = e.problem().expect("sweep artifact has spec");
            let mut times = Vec::new();
            for strat in ["vendor", "fbfft"] {
                let name = format!("conv.{spec}.{strat}.fprop");
                let x = rng.normal_vec(p.input_len());
                let w = rng.normal_vec(p.weight_len());
                let args = [
                    HostTensor::f32(x, &[p.s, p.f, p.h, p.w]),
                    HostTensor::f32(w, &[p.fo, p.f, p.kh, p.kw]),
                ];
                rt.execute_1f32(&name, &args)?; // warm
                let t0 = Instant::now();
                let reps = 3;
                for _ in 0..reps {
                    rt.execute_1f32(&name, &args)?;
                }
                times.push(t0.elapsed().as_secs_f64() / reps as f64);
            }
            table.row(vec![
                k.to_string(),
                y.to_string(),
                p.problem_size().to_string(),
                format!("{:.3}", times[0] * 1e3),
                format!("{:.3}", times[1] * 1e3),
                format!("{:.2}x", times[0] / times[1]),
            ]);
        }
    }
    Ok(format!(
        "Figures 1-6 measured anchor subset (PJRT CPU, S=f=f'=16):\n{}",
        table.render()))
}

/// §5.4: fbfft-conv vs vendor-FFT-conv over x ∈ {13..64}, measured via
/// PJRT artifacts (paper: overall mean speedup 1.51×, min 1.21×).
pub fn sec54_report(rt: &Runtime) -> Result<String> {
    let mut table = Table::new(&[
        "x", "pass", "vendor_fft ms", "fbfft ms", "speedup"]);
    let mut rng = Rng::new(0x54);
    let mut ratios = Vec::new();
    for x in [13usize, 16, 27, 32, 57, 64] {
        let spec = format!("s54.x{x}");
        let passes: &[&str] =
            if x <= 32 { &["fprop", "bprop", "accgrad"] } else { &["fprop"] };
        for pass in passes {
            let Some(e) = rt.manifest().conv(&spec, "fbfft", pass)
            else { continue };
            let p = e.problem().expect("spec");
            let mut times = Vec::new();
            for strat in ["vendor_fft", "fbfft"] {
                let name = format!("conv.{spec}.{strat}.{pass}");
                let args = build_pass_args(&p, pass, &mut rng);
                rt.execute_1f32(&name, &args)?; // warm
                let t0 = Instant::now();
                let reps = 3;
                for _ in 0..reps {
                    rt.execute_1f32(&name, &args)?;
                }
                times.push(t0.elapsed().as_secs_f64() / reps as f64);
            }
            let sp = times[0] / times[1];
            ratios.push(sp);
            table.row(vec![
                x.to_string(),
                pass.to_string(),
                format!("{:.3}", times[0] * 1e3),
                format!("{:.3}", times[1] * 1e3),
                format!("{sp:.2}x"),
            ]);
        }
    }
    let mean = ratios.iter().sum::<f64>() / ratios.len().max(1) as f64;
    let geo = (ratios.iter().map(|r| r.ln()).sum::<f64>()
        / ratios.len().max(1) as f64)
        .exp();
    let min = ratios.iter().copied().fold(f64::INFINITY, f64::min);
    Ok(format!(
        "Sec 5.4: fbfft-conv vs vendor-FFT-conv (PJRT CPU, p=16 scale)\n{}\n\
         mean speedup {mean:.2}x (paper 1.51x), geometric mean {geo:.2}x \
         (paper 1.49x), min {min:.2}x (paper 1.21x)\n",
        table.render()))
}

/// Build the two input tensors of a conv pass artifact.
pub fn build_pass_args(p: &ConvProblem, pass: &str, rng: &mut Rng)
                       -> [HostTensor; 2] {
    let x = || (vec![p.s, p.f, p.h, p.w], p.input_len());
    let w = || (vec![p.fo, p.f, p.kh, p.kw], p.weight_len());
    let go = || (vec![p.s, p.fo, p.yh(), p.yw()], p.output_len());
    let ((s1, n1), (s2, n2)) = match pass {
        "fprop" => (x(), w()),
        "bprop" => (go(), w()),
        "accgrad" => (go(), x()),
        other => panic!("unknown pass {other}"),
    };
    [HostTensor::f32(rng.normal_vec(n1), &s1),
     HostTensor::f32(rng.normal_vec(n2), &s2)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotone_and_total() {
        assert_eq!(bucket(1), 0);
        assert!(bucket(300) > bucket(10));
        assert_eq!(bucket(u64::MAX), SIZE_BUCKETS.len() - 1);
    }

    #[test]
    fn model_report_contains_all_kernel_sizes() {
        let r = fig16_report();
        for k in [3, 5, 7, 9, 11, 13] {
            assert!(r.contains(&format!("(k={k})")), "missing k={k}");
        }
        assert!(r.contains("headline"));
    }

    #[test]
    fn pass_args_shapes() {
        let p = ConvProblem::square(2, 3, 4, 9, 3);
        let mut rng = Rng::new(1);
        let [a, b] = build_pass_args(&p, "accgrad", &mut rng);
        assert_eq!(a.shape(), &[2, 4, 7, 7]);
        assert_eq!(b.shape(), &[2, 3, 9, 9]);
    }
}
