//! Table 4 (representative layers), Table 5 (stage breakdown) and the §6
//! tiling experiment.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::conv::{cgemm, tiled, ConvProblem, FftConvEngine, FftMode,
                  StageTimings, Workspace};
use crate::coordinator::autotuner::candidate_bases;
use crate::coordinator::Pass;
use crate::cost::{tred_per_sec, CudnnModel, CufftConvModel};
use crate::fft::real::rfft_len;
use crate::fft::C32;
use crate::metrics::Table;
use crate::runtime::Runtime;
use crate::trace;
use crate::util::{threads, Json, Rng};

use super::sweep::build_pass_args;

/// Paper's Table 4 speedups for reference printing.
const PAPER_T4: [(&str, [f64; 3]); 5] = [
    ("L1", [1.54, 2.30, 1.77]),
    ("L2", [7.64, 12.5, 8.85]),
    ("L3", [7.36, 14.5, 10.2]),
    ("L4", [3.10, 4.41, 3.86]),
    ("L5", [1.86, 1.40, 2.25]),
];

/// Table 4: model at paper scale, measurement at CPU scale.
pub fn table4_report(rt: Option<&Runtime>) -> Result<String> {
    let mut out = String::new();

    // -- model at paper scale ------------------------------------------------
    let dnn = CudnnModel::default();
    let fft = CufftConvModel::vendor();
    let mut t = Table::new(&[
        "layer", "model cuDNN ms", "model cuFFT ms", "model speedup",
        "paper speedup (f/b/a)", "model TRED/s"]);
    for (i, (name, p)) in trace::table4_layers().iter().enumerate() {
        let td = dnn.time(p);
        let tf = fft.autotuned_time(p);
        t.row(vec![
            name.to_string(),
            format!("{:.1}", td * 1e3),
            format!("{:.1}", tf * 1e3),
            format!("{:.2}x", td / tf),
            format!("{:.2}/{:.2}/{:.2}", PAPER_T4[i].1[0], PAPER_T4[i].1[1],
                    PAPER_T4[i].1[2]),
            format!("{:.2}", tred_per_sec(p, tf)),
        ]);
    }
    out.push_str("Table 4 (model, paper scale S=128):\n");
    out.push_str(&t.render());
    out.push('\n');

    // -- measured at CPU scale via PJRT artifacts ---------------------------
    if let Some(rt) = rt {
        let mut mt = Table::new(&[
            "layer", "pass", "vendor ms", "vendor_fft ms", "fbfft ms",
            "fbfft speedup vs vendor"]);
        let mut rng = Rng::new(0x7a4);
        for (name, paper) in trace::table4_layers() {
            let p = trace::scale(&paper, 8, 8);
            let spec = format!("{name}@_8");
            // aot names scaled specs "<name>@/8" with '/' -> '_'
            let spec = format!("T4.{}", spec.trim_start_matches("T4."));
            for pass in ["fprop", "bprop", "accgrad"] {
                let mut row = vec![name.to_string(), pass.to_string()];
                let mut times = Vec::new();
                for strat in ["vendor", "vendor_fft", "fbfft"] {
                    let art = format!("conv.{spec}.{strat}.{pass}");
                    if rt.manifest().get(&art).is_none() {
                        times.push(f64::NAN);
                        row.push("-".into());
                        continue;
                    }
                    let args = build_pass_args(&p, pass, &mut rng);
                    rt.execute_1f32(&art, &args)?; // warm
                    let reps = 3;
                    let t0 = Instant::now();
                    for _ in 0..reps {
                        rt.execute_1f32(&art, &args)?;
                    }
                    let secs = t0.elapsed().as_secs_f64() / reps as f64;
                    times.push(secs);
                    row.push(format!("{:.2}", secs * 1e3));
                }
                let sp = if times.len() == 3 && times[0].is_finite()
                    && times[2].is_finite()
                {
                    format!("{:.2}x", times[0] / times[2])
                } else {
                    "-".into()
                };
                row.push(sp);
                mt.row(row);
            }
        }
        out.push_str("Table 4 (measured, PJRT CPU, planes/8, S=8):\n");
        out.push_str(&mt.render());
    }
    Ok(out)
}

/// Table 5: per-stage breakdown of the frequency pipeline (host engines,
/// scaled layers), vendor vs SoA fbfft vs scalar fbfft side by side —
/// the TRANS columns vanish under fbfft (the paper's §5.1 point), and
/// the PACK column (interleaved↔planar conversion around the planar
/// CGEMM) additionally vanishes under the SoA batch-lane path.
pub fn table5_report() -> String {
    let mut t = Table::new(&[
        "layer", "pass", "mode", "FFT A", "TRANS A", "FFT B", "TRANS B",
        "CGEMM", "TRANS C", "IFFT C", "PACK", "total ms"]);
    let mut rng = Rng::new(0x75);
    for (name, paper) in trace::table4_layers() {
        let p = trace::scale(&paper, 16, 4);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let go = rng.normal_vec(p.output_len());
        for (mode, label) in [(FftMode::Vendor, "vendor"),
                              (FftMode::Fbfft, "fbfft"),
                              (FftMode::FbfftScalar, "fbfft_scalar")] {
            let n = p.h.max(p.w).next_power_of_two();
            let eng = FftConvEngine::new(mode, n);
            for pass in ["fprop", "bprop", "accgrad"] {
                let (_, st) = match pass {
                    "fprop" => eng.fprop(&p, &x, &wei),
                    "bprop" => eng.bprop(&p, &go, &wei),
                    _ => eng.accgrad(&p, &go, &x),
                };
                let ms = |d: std::time::Duration| {
                    format!("{:.3}", d.as_secs_f64() * 1e3)
                };
                t.row(vec![
                    name.to_string(), pass.to_string(), label.to_string(),
                    ms(st.fft_a), ms(st.trans_a), ms(st.fft_b),
                    ms(st.trans_b), ms(st.cgemm), ms(st.trans_c),
                    ms(st.ifft_c), ms(st.pack_total()), ms(st.total()),
                ]);
            }
        }
    }
    format!(
        "Table 5: frequency-pipeline stage breakdown \
         (host engines, planes/16, S=4):\n{}", t.render())
}

/// §6 tiling: untiled fbfft vs tiled at several d on a large-input /
/// small-kernel layer, host engines + optional PJRT artifacts.
pub fn tiling_report(rt: Option<&Runtime>) -> Result<String> {
    let p = ConvProblem::square(8, 16, 16, 57, 3);
    let mut rng = Rng::new(0x716);
    let x = rng.normal_vec(p.input_len());
    let wei = rng.normal_vec(p.weight_len());
    let mut t = Table::new(&["config", "basis", "host ms", "pjrt ms"]);

    let pjrt_time = |art: &str| -> Result<Option<f64>> {
        let Some(rt) = rt else { return Ok(None) };
        if rt.manifest().get(art).is_none() {
            return Ok(None);
        }
        let mut r2 = Rng::new(0x717);
        let args = build_pass_args(&p, "fprop", &mut r2);
        rt.execute_1f32(art, &args)?;
        let t0 = Instant::now();
        for _ in 0..3 {
            rt.execute_1f32(art, &args)?;
        }
        Ok(Some(t0.elapsed().as_secs_f64() / 3.0))
    };

    // untiled: basis = next_pow2(57) = 64
    let eng = FftConvEngine::fbfft_for(&p);
    let t0 = Instant::now();
    let _ = eng.fprop(&p, &x, &wei);
    let host_untiled = t0.elapsed().as_secs_f64();
    let pj = pjrt_time("conv.tile.x57.fbfft.fprop")?;
    t.row(vec![
        "untiled".into(), eng.n_fft.to_string(),
        format!("{:.2}", host_untiled * 1e3),
        pj.map(|s| format!("{:.2}", s * 1e3)).unwrap_or("-".into()),
    ]);
    for d in [4usize, 8, 16] {
        let t0 = Instant::now();
        let _ = tiled::fprop(&p, &x, &wei, d);
        let host = t0.elapsed().as_secs_f64();
        // d=4 inlines ~200 tile pipelines into one module — minutes of
        // XLA compile for no extra signal; PJRT timing for d>=8 only
        let pj = if d >= 8 {
            pjrt_time(&format!("conv.tile.x57.fbfft_tiled.fprop.d{d}"))?
        } else {
            None
        };
        t.row(vec![
            format!("tiled d={d}"),
            tiled::tile_fft_size(d, 3, 3).to_string(),
            format!("{:.2}", host * 1e3),
            pj.map(|s| format!("{:.2}", s * 1e3)).unwrap_or("-".into()),
        ]);
    }
    Ok(format!(
        "Sec 6 tiling (x=57, k=3, S=8, f=f'=16): cost O(n log n) -> \
         O(n log w)\n{}", t.render()))
}

/// Autotuner demonstration: basis search on the paper's L5 (the layer
/// where the tuner found 14 > 16, Table 4 note).
pub fn autotune_report() -> String {
    use crate::coordinator::{Autotuner, Pass};
    let mut out = String::new();
    let l5 = trace::scale(&trace::table4_layers()[4].1, 48, 4);
    out.push_str(&format!(
        "candidate bases for n=13 (paper: autotuner picked 14): {:?}\n",
        candidate_bases(13)));
    let mut tuner = Autotuner::new();
    tuner.reps = 1;
    let mut t = Table::new(&["problem", "pass", "winner", "basis", "ms"]);
    let probs = vec![
        ("L5/48", l5),
        ("small k=11", ConvProblem::square(4, 8, 8, 16, 11)),
        ("tiny k=3", ConvProblem::square(1, 2, 2, 8, 3)),
        ("big image k=3", ConvProblem::square(1, 2, 2, 33, 3)),
    ];
    for (name, p) in &probs {
        for pass in Pass::ALL {
            let c = tuner.tune(p, pass);
            t.row(vec![
                name.to_string(),
                pass.tag().into(),
                c.strategy.to_string(),
                c.n_fft.map(|n| n.to_string()).unwrap_or("-".into()),
                format!("{:.3}", c.seconds * 1e3),
            ]);
        }
    }
    out.push_str(&t.render());
    out
}

/// The fixed acceptance config the perf gate tracks across PRs
/// (Table-2-sized: S=16, f=f'=16, 32×32 input, k=5 → basis 32).
pub fn accept32_problem() -> ConvProblem {
    ConvProblem::square(16, 16, 16, 32, 5)
}

/// The large-input/small-kernel smoke shape the OaA perf gate tracks:
/// 144² with k=3 sits just past a power of two, so the full-pad fbfft
/// engine pays the round-up to basis 256 (4.5× the logical area) on
/// every stage while OaA covers the 142² output grid with nine
/// 64-basis tiles — the regime where overlap-add must win by a wide,
/// machine-independent margin.
pub fn oaa_smoke_problem() -> ConvProblem {
    ConvProblem::square(4, 8, 8, 144, 3)
}

/// Machine-readable per-stage pipeline breakdown, written by
/// `cargo bench --bench breakdown` as `BENCH_fftconv.json` so the perf
/// trajectory is tracked across PRs. Covers the scaled Table-4 layer
/// configs plus [`accept32_problem`], all three modes (`vendor`, the SoA
/// `fbfft`, the pre-SoA `fbfft_scalar` baseline), all three passes; each
/// entry also times the pre-blocking naive CGEMM on identically shaped
/// frequency slabs, so `cgemm_speedup` (naive / blocked, same data) is
/// the acceptance ratio. The `fft_ns` / `pack_ns` aggregates split the
/// transform time from the interleaved↔planar conversion time: the SoA
/// fbfft rows must show `pack_ns == 0` (planar handoff, pack elided) and
/// beat `fbfft_scalar`'s `fft_ns` (vectorized butterflies). `smoke`
/// restricts to the accept32 config with a single rep (the CI smoke run).
///
/// Schema version 3: the document gains the [`super::host_meta`] `host`
/// block (CPU features, dispatch tier, threads, `FBFFT_*` env) and each
/// entry records the `simd_tier` its measured pass executed under —
/// cross-tier timing comparisons are meaningless, so the perf gate
/// refuses to diff documents from different tiers.
///
/// Schema version 4: the [`oaa_smoke_problem`] config joins both the
/// smoke and full runs, measured under two modes — full-pad `fbfft` at
/// the rounded-up basis and `oaa` (tile entries carry a `tile` field) —
/// so the CI gate can assert overlap-add beats full-pad on the
/// large-input shape from the same document.
pub fn breakdown_json(smoke: bool) -> Json {
    use crate::conv::{oaa, OaaEngine};
    enum Eng {
        Full(FftConvEngine),
        Oaa(OaaEngine),
    }
    let reps = if smoke { 1usize } else { 3 };
    let mut configs: Vec<(String, ConvProblem)> = Vec::new();
    if !smoke {
        for (name, paper) in trace::table4_layers() {
            configs.push((format!("{name}/16"), trace::scale(&paper, 16, 4)));
        }
    }
    configs.push(("accept32".to_string(), accept32_problem()));
    configs.push(("oaa144".to_string(), oaa_smoke_problem()));

    let ns = |d: Duration| Json::num(d.as_secs_f64() * 1e9);
    let mut rng = Rng::new(0xBE9C);
    let mut entries = Vec::new();
    for (name, p) in &configs {
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let go = rng.normal_vec(p.output_len());
        let full_n = p.h.max(p.w).next_power_of_two();
        // the OaA config pits overlap-add against the full-pad fbfft it
        // must beat; the classic configs keep the three full-pad modes
        let engines: Vec<(&str, Eng)> = if name.starts_with("oaa") {
            let tile = oaa::basis_filling_tile(64, p.kh, p.kw);
            vec![
                ("fbfft",
                 Eng::Full(FftConvEngine::new(FftMode::Fbfft, full_n))),
                ("oaa", Eng::Oaa(OaaEngine::for_problem(p, tile))),
            ]
        } else {
            [(FftMode::Vendor, "vendor"), (FftMode::Fbfft, "fbfft"),
             (FftMode::FbfftScalar, "fbfft_scalar")]
                .into_iter()
                .map(|(mode, label)| {
                    (label, Eng::Full(FftConvEngine::new(mode, full_n)))
                })
                .collect()
        };
        for (label, eng) in &engines {
            let n = match eng {
                Eng::Full(e) => e.n_fft,
                Eng::Oaa(e) => e.n_fft(),
            };
            let bins = rfft_len(n) * n;
            let mut ws = Workspace::new();
            let mut yout = vec![0f32; p.output_len()];
            let mut gxout = vec![0f32; p.input_len()];
            let mut gwout = vec![0f32; p.weight_len()];
            for pass in Pass::ALL {
                // rep 0 warms the workspace; keep the fastest steady rep
                let mut best: Option<StageTimings> = None;
                for rep in 0..=reps {
                    let st = match eng {
                        Eng::Full(e) => match pass {
                            Pass::Fprop => e.fprop_into(
                                p, &x, &wei, &mut yout, &mut ws),
                            Pass::Bprop => e.bprop_into(
                                p, &go, &wei, &mut gxout, &mut ws),
                            Pass::AccGrad => e.accgrad_into(
                                p, &go, &x, &mut gwout, &mut ws),
                        },
                        Eng::Oaa(e) => match pass {
                            Pass::Fprop => e.fprop_into(
                                p, &x, &wei, &mut yout, &mut ws),
                            Pass::Bprop => e.bprop_into(
                                p, &go, &wei, &mut gxout, &mut ws),
                            Pass::AccGrad => e.accgrad_into(
                                p, &go, &x, &mut gwout, &mut ws),
                        },
                    };
                    let better = best
                        .map(|b| st.total() < b.total())
                        .unwrap_or(true);
                    if rep > 0 && better {
                        best = Some(st);
                    }
                }
                let st = best.expect("at least one timed rep");
                // naive-vs-blocked CGEMM on identically shaped slabs
                let sh = cgemm::BinShape::of(pass, p.s, p.f, p.fo);
                let fa: Vec<C32> = (0..bins * sh.a_len)
                    .map(|_| C32::new(rng.normal(), rng.normal()))
                    .collect();
                let fb: Vec<C32> = (0..bins * sh.b_len)
                    .map(|_| C32::new(rng.normal(), rng.normal()))
                    .collect();
                let mut fc = vec![C32::ZERO; bins * sh.c_len];
                // both sides discard rep 0 (first-touch pages, cold
                // caches) so the speedup compares steady vs steady
                let mut naive_lo = f64::INFINITY;
                for rep in 0..=reps {
                    let t0 = Instant::now();
                    cgemm::batched_naive(pass, bins, p.s, p.f, p.fo, &fa,
                                         &fb, &mut fc);
                    if rep > 0 {
                        naive_lo =
                            naive_lo.min(t0.elapsed().as_secs_f64());
                    }
                }
                let mut blocked_lo = f64::INFINITY;
                for rep in 0..=reps {
                    let t0 = Instant::now();
                    cgemm::batched(pass, bins, p.s, p.f, p.fo, &fa, &fb,
                                   &mut fc, &mut ws);
                    if rep > 0 {
                        blocked_lo =
                            blocked_lo.min(t0.elapsed().as_secs_f64());
                    }
                }
                let mut fields = vec![
                    ("layer", Json::str(name)),
                    ("pass", Json::str(pass.tag())),
                    ("mode", Json::str(label)),
                    ("simd_tier", Json::str(st.simd_tier.tag())),
                    ("n_fft", Json::num(n as f64)),
                    ("fft_a_ns", ns(st.fft_a)),
                    ("trans_a_ns", ns(st.trans_a)),
                    ("pack_a_ns", ns(st.pack_a)),
                    ("fft_b_ns", ns(st.fft_b)),
                    ("trans_b_ns", ns(st.trans_b)),
                    ("pack_b_ns", ns(st.pack_b)),
                    ("cgemm_ns", ns(st.cgemm)),
                    ("trans_c_ns", ns(st.trans_c)),
                    ("pack_c_ns", ns(st.pack_c)),
                    ("ifft_c_ns", ns(st.ifft_c)),
                    // the acceptance aggregates: transform time vs
                    // layout-conversion time (pack_ns == 0 in SoA fbfft)
                    ("fft_ns", ns(st.fft_total())),
                    ("pack_ns", ns(st.pack_total())),
                    ("total_ns", ns(st.total())),
                    ("cgemm_naive_ns", Json::num(naive_lo * 1e9)),
                    ("cgemm_blocked_ns", Json::num(blocked_lo * 1e9)),
                    ("cgemm_speedup", Json::num(naive_lo / blocked_lo)),
                ];
                if let Eng::Oaa(e) = eng {
                    fields.push(("tile", Json::num(e.tile as f64)));
                }
                entries.push(Json::obj(fields));
            }
        }
    }
    Json::obj(vec![
        ("version", Json::num(4.0)),
        ("threads", Json::num(threads() as f64)),
        ("smoke", Json::Bool(smoke)),
        ("host", super::host_meta()),
        ("entries", Json::Arr(entries)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_table4_renders_all_layers() {
        let r = table4_report(None).unwrap();
        for l in ["L1", "L2", "L3", "L4", "L5"] {
            assert!(r.contains(l));
        }
    }

    #[test]
    fn breakdown_json_smoke_has_all_cells() {
        let j = breakdown_json(true);
        let entries = j.get("entries").unwrap().as_arr().unwrap();
        // accept32 × 3 modes × 3 passes + oaa144 × 2 modes × 3 passes
        assert_eq!(entries.len(), 15);
        let mut saw_fbfft = 0;
        let mut saw_oaa = 0;
        let tier = crate::util::simd::tier().tag();
        for e in entries {
            let layer = e.get("layer").unwrap().as_str().unwrap();
            let mode = e.get("mode").unwrap().as_str().unwrap();
            assert!(layer == "accept32" || layer == "oaa144", "{layer}");
            // every entry names the tier its timings ran under
            assert_eq!(e.get("simd_tier").unwrap().as_str().unwrap(),
                       tier);
            assert!(e.get("cgemm_ns").unwrap().as_f64().unwrap() >= 0.0);
            assert!(e.get("cgemm_speedup").unwrap().as_f64().unwrap()
                    > 0.0);
            let total = e.get("total_ns").unwrap().as_f64().unwrap();
            assert!(total > 0.0);
            // the acceptance aggregates exist in every entry
            let fft = e.get("fft_ns").unwrap().as_f64().unwrap();
            let pack = e.get("pack_ns").unwrap().as_f64().unwrap();
            assert!(fft > 0.0);
            // the SoA fbfft rows prove the elided pack stage exactly
            if mode == "fbfft" {
                assert_eq!(pack, 0.0, "SoA fbfft must elide PACK");
                saw_fbfft += 1;
            }
            if mode == "oaa" {
                assert_eq!(layer, "oaa144");
                // OaA rides the SoA pipeline: pack stays elided, and
                // the entry names its tile at the small basis
                assert_eq!(pack, 0.0, "OaA must keep PACK elided");
                assert_eq!(e.get("tile").unwrap().as_usize(), Some(62));
                assert_eq!(e.get("n_fft").unwrap().as_usize(), Some(64));
                saw_oaa += 1;
            }
        }
        assert_eq!(saw_fbfft, 6,
                   "one SoA fbfft entry per pass per config");
        assert_eq!(saw_oaa, 3, "one OaA entry per pass");
        // the host provenance block travels with the document
        let host = j.get("host").expect("host block");
        assert_eq!(host.get("simd_tier").unwrap().as_str(), Some(tier));
        assert!(host.get("threads").unwrap().as_f64().unwrap() >= 1.0);
        // round-trips through the in-tree parser
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("version").unwrap().as_usize(), Some(4));
        assert!(back.get("host").is_some());
    }
}
