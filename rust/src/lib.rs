//! fbfft-repro — reproduction of *"Fast Convolutional Nets With fbfft: A
//! GPU Performance Evaluation"* (Vasilache et al., ICLR 2015) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! This crate is Layer 3: the coordinator that owns the event loop,
//! autotuning, buffer management, batching and benchmarking, plus every
//! substrate the paper depends on, rebuilt from scratch:
//!
//! * [`fft`] — a from-scratch FFT library (mixed-radix Cooley–Tukey,
//!   Bluestein, real transforms) and `fbfft_host`, the batched
//!   small-transform specialist embodying the paper's contribution;
//! * [`conv`] — time-domain and frequency-domain convolution engines for
//!   all three training passes (baselines + cross-checks);
//! * [`cost`] — the analytical performance model (FLOP counts, Table-1
//!   stage breakdown, K40m roofline, the TRED/s metric);
//! * [`trace`] — workload generation: Table 2's 8,232-config sweep,
//!   Table 4's layers, AlexNet/OverFeat tables, request traces;
//! * [`runtime`] — the PJRT bridge loading AOT-compiled HLO artifacts;
//! * [`coordinator`] — strategy autotuner (§3.4) with its persistent
//!   per-shape cache, buffer manager (§3.3), bulk-synchronous network
//!   scheduler, deadline-aware dynamic batcher, and the sharded
//!   multi-worker serving engine;
//! * [`metrics`] — timers, histograms and report writers shared by the
//!   benches.
//!
//! Python (Layers 1+2, under `python/`) runs only at build time; the
//! binary is self-contained once `artifacts/` exists.
//!
//! * [`testkit`] — the conformance/verification substrate: f64 oracles,
//!   adversarial + Table-2 case generation, the scaled tolerance model
//!   and the {engine × pass} conformance matrix.

// Numeric-kernel style: index loops mirror the paper's subscripts, and
// fixed-size transform types expose `len` without an `is_empty` notion.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::len_without_is_empty)]

pub mod conv;
pub mod coordinator;
pub mod cost;
pub mod fft;
pub mod metrics;
pub mod reports;
pub mod runtime;
pub mod testkit;
pub mod trace;
pub mod util;
