//! Dynamic request batching for the serving path: amortize one PJRT
//! launch over many small requests, the same economics the paper's
//! 'large batches, small feature planes' regime exploits.
//!
//! Policy: flush when the queued image count reaches the executable's
//! batch capacity, or when the most urgent queued request reaches its
//! flush-by deadline. The queue is kept in deadline order (stable for
//! equal deadlines, so plain `push` traffic stays FIFO): an urgent
//! request admitted behind a lax one rides the *next* flush, which is
//! what lets the sharded engine honor per-request SLAs. Requests never
//! reorder *within* a flush; a request larger than the capacity is split
//! across consecutive batches.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// One enqueued unit: `images` samples belonging to request `id`,
/// to be flushed no later than `deadline`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Pending {
    pub id: u64,
    pub images: usize,
    pub enqueued: Instant,
    pub deadline: Instant,
}

/// A flushed batch: (request id, image count) pairs in arrival order;
/// total images ≤ capacity.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Batch {
    pub parts: Vec<(u64, usize)>,
}

impl Batch {
    pub fn images(&self) -> usize {
        self.parts.iter().map(|(_, n)| n).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.parts.is_empty()
    }
}

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// images per executable launch (the artifact's S dimension)
    pub capacity: usize,
    /// flush the queue when the oldest request has waited this long
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { capacity: 16, max_wait: Duration::from_millis(5) }
    }
}

#[derive(Debug)]
pub struct Batcher {
    cfg: BatcherConfig,
    queue: VecDeque<Pending>,
    /// running image count over `queue`, kept in lockstep by
    /// `push_deadline` / `take_batch` so the per-poll fullness check is
    /// O(1) instead of an O(queue) recount
    queued: usize,
    /// counters for the serving report — every batch handed out is
    /// exactly one of full / timeout / drain, so
    /// `flushes_full + flushes_timeout + flushes_drain` equals the
    /// number of launches the batcher has fed
    pub flushes_full: usize,
    pub flushes_timeout: usize,
    pub flushes_drain: usize,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.capacity >= 1);
        Batcher { cfg, queue: VecDeque::new(), queued: 0,
                  flushes_full: 0, flushes_timeout: 0, flushes_drain: 0 }
    }

    /// Enqueue with the default flush-by deadline `now + max_wait`
    /// (pure batching traffic, FIFO by construction).
    pub fn push(&mut self, id: u64, images: usize, now: Instant) {
        let deadline = now + self.cfg.max_wait;
        self.push_deadline(id, images, now, deadline);
    }

    /// Enqueue with an explicit flush-by deadline (the admission path:
    /// the engine passes `min(now + max_wait, sla_deadline)`). Stable
    /// insertion sorted by deadline — equal deadlines keep arrival order.
    pub fn push_deadline(&mut self, id: u64, images: usize, now: Instant,
                         deadline: Instant) {
        assert!(images >= 1, "empty request");
        let p = Pending { id, images, enqueued: now, deadline };
        // insert after the last entry at least as urgent (usually the
        // back: deadlines grow with arrival time for uniform traffic)
        let at = self
            .queue
            .iter()
            .rposition(|q| q.deadline <= deadline)
            .map(|i| i + 1)
            .unwrap_or(0);
        self.queue.insert(at, p);
        self.queued += images;
    }

    pub fn queued_images(&self) -> usize {
        debug_assert_eq!(
            self.queued,
            self.queue.iter().map(|p| p.images).sum::<usize>(),
            "running image count out of sync with the queue"
        );
        self.queued
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Earliest deadline by which a flush must happen (None if empty).
    /// The queue is deadline-sorted, so this is the front entry's.
    pub fn deadline(&self) -> Option<Instant> {
        self.queue.front().map(|p| p.deadline)
    }

    /// Non-blocking poll: returns a batch if the policy says flush now.
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let full = self.queued_images() >= self.cfg.capacity;
        let expired = self
            .deadline()
            .map(|d| now >= d)
            .unwrap_or(false);
        if !full && !expired {
            return None;
        }
        if full {
            self.flushes_full += 1;
        } else {
            self.flushes_timeout += 1;
        }
        Some(self.take_batch())
    }

    /// Force-flush whatever is queued (shutdown path). Counted under
    /// `flushes_drain` when non-empty, so drained batches are not
    /// invisible to the `batches == Σ flushes` reconciliation.
    pub fn drain(&mut self) -> Batch {
        let batch = self.take_batch();
        if !batch.is_empty() {
            self.flushes_drain += 1;
        }
        batch
    }

    /// Pop up to one capacity's worth of images off the front of the
    /// queue (splitting an oversized request), keeping the running
    /// image count in sync. Callers attribute the flush to a counter.
    fn take_batch(&mut self) -> Batch {
        let mut batch = Batch::default();
        let mut room = self.cfg.capacity;
        while room > 0 {
            let Some(front) = self.queue.front_mut() else { break };
            let take = front.images.min(room);
            batch.parts.push((front.id, take));
            room -= take;
            self.queued -= take;
            if take == front.images {
                self.queue.pop_front();
            } else {
                front.images -= take; // split oversized request
            }
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(cap: usize, wait_ms: u64) -> BatcherConfig {
        BatcherConfig { capacity: cap,
                        max_wait: Duration::from_millis(wait_ms) }
    }

    #[test]
    fn flushes_when_full() {
        let mut b = Batcher::new(cfg(4, 1000));
        let t = Instant::now();
        b.push(1, 2, t);
        assert!(b.poll(t).is_none());
        b.push(2, 2, t);
        let batch = b.poll(t).expect("full flush");
        assert_eq!(batch.parts, vec![(1, 2), (2, 2)]);
        assert!(b.is_empty());
        assert_eq!(b.flushes_full, 1);
    }

    #[test]
    fn flushes_on_timeout() {
        let mut b = Batcher::new(cfg(64, 5));
        let t = Instant::now();
        b.push(7, 1, t);
        assert!(b.poll(t).is_none());
        let later = t + Duration::from_millis(6);
        let batch = b.poll(later).expect("timeout flush");
        assert_eq!(batch.parts, vec![(7, 1)]);
        assert_eq!(b.flushes_timeout, 1);
    }

    #[test]
    fn preserves_arrival_order_and_splits_oversized() {
        let mut b = Batcher::new(cfg(4, 1000));
        let t = Instant::now();
        b.push(1, 3, t);
        b.push(2, 6, t); // larger than capacity remainder AND capacity
        let first = b.poll(t).expect("flush");
        assert_eq!(first.parts, vec![(1, 3), (2, 1)]);
        // remaining 5 images of request 2
        assert_eq!(b.queued_images(), 5);
        let second = b.poll(t).expect("still full");
        assert_eq!(second.parts, vec![(2, 4)]);
        let third = b.drain();
        assert_eq!(third.parts, vec![(2, 1)]);
        assert!(b.is_empty());
    }

    #[test]
    fn batch_never_exceeds_capacity() {
        let mut b = Batcher::new(cfg(8, 0));
        let t = Instant::now();
        for id in 0..10 {
            b.push(id, 3, t);
        }
        while let Some(batch) = b.poll(t + Duration::from_millis(1)) {
            assert!(batch.images() <= 8);
            if b.is_empty() {
                break;
            }
        }
    }

    #[test]
    fn urgent_request_jumps_the_queue_but_not_mid_flush() {
        let mut b = Batcher::new(cfg(2, 1000));
        let t = Instant::now();
        // lax request first, urgent one second: the urgent one must lead
        b.push_deadline(1, 2, t, t + Duration::from_millis(500));
        b.push_deadline(2, 2, t, t + Duration::from_millis(5));
        assert_eq!(b.deadline(), Some(t + Duration::from_millis(5)));
        let first = b.poll(t).expect("full flush");
        assert_eq!(first.parts, vec![(2, 2)]);
        let second = b.poll(t).expect("still full");
        assert_eq!(second.parts, vec![(1, 2)]);
        // equal deadlines preserve arrival order (stable insert)
        let d = t + Duration::from_millis(9);
        b.push_deadline(3, 1, t, d);
        b.push_deadline(4, 1, t, d);
        let batch = b.poll(t).expect("full");
        assert_eq!(batch.parts, vec![(3, 1), (4, 1)]);
    }

    #[test]
    fn drain_counts_shutdown_flushes() {
        let mut b = Batcher::new(cfg(8, 1000));
        let t = Instant::now();
        assert!(b.drain().is_empty());
        assert_eq!(b.flushes_drain, 0, "empty drain is not a flush");
        b.push(1, 3, t);
        b.push(2, 2, t);
        let batch = b.drain();
        assert_eq!(batch.images(), 5);
        assert_eq!(b.flushes_drain, 1);
        assert_eq!(b.flushes_full + b.flushes_timeout, 0);
    }

    #[test]
    fn running_image_count_tracks_pushes_splits_and_drains() {
        let mut b = Batcher::new(cfg(4, 1000));
        let t = Instant::now();
        b.push(1, 3, t);
        // urgent oversized request jumps the queue and splits
        b.push_deadline(2, 6, t, t + Duration::from_millis(1));
        assert_eq!(b.queued_images(), 9);
        let first = b.poll(t).expect("full");
        assert_eq!(first.parts, vec![(2, 4)]);
        assert_eq!(b.queued_images(), 5);
        let second = b.poll(t).expect("still full");
        assert_eq!(second.parts, vec![(2, 2), (1, 2)]);
        assert_eq!(b.queued_images(), 1);
        assert_eq!(b.drain().parts, vec![(1, 1)]);
        assert_eq!(b.queued_images(), 0);
        assert!(b.is_empty());
        assert_eq!((b.flushes_full, b.flushes_timeout, b.flushes_drain),
                   (2, 0, 1));
    }

    #[test]
    fn deadline_tracks_oldest() {
        let mut b = Batcher::new(cfg(100, 10));
        let t0 = Instant::now();
        b.push(1, 1, t0);
        b.push(2, 1, t0 + Duration::from_millis(3));
        assert_eq!(b.deadline(), Some(t0 + Duration::from_millis(10)));
    }
}
