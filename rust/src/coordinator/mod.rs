//! The coordination layer — the paper's system-level machinery, which in
//! the original lives across the Torch integration and the CUDA
//! buffering/streaming code (§3.3–3.4):
//!
//! * [`strategy`]  — the convolution-strategy vocabulary and artifact
//!   naming shared with the AOT manifest;
//! * [`autotuner`] — §3.4's strategy selection: explore smooth Fourier
//!   basis sizes `2^a·3^b·5^c·7^d` and implementation choices, measure
//!   once per problem size, cache the winner (persistable);
//! * [`buffers`]   — §3.3's memory policy: one buffered copy per tensor
//!   role, auto-expanded and reused across layers;
//! * [`scheduler`] — bulk-synchronous whole-CNN execution through cached
//!   PJRT executables (the Table-3 harness);
//! * [`batcher`]   — deadline-aware dynamic request batching;
//! * [`service`]   — the sharded multi-worker serving engine
//!   ([`ServeEngine`]): one admission decision per request against the
//!   summed per-layer estimates of a [`NetPlan`] → least-loaded shard
//!   → per-shard batcher → whole-chain dispatch with pooled ping-pong
//!   activations and overlapped host-side packing, supervised
//!   (`catch_unwind` per flush with the failing layer recorded,
//!   [`ShardHealth`] circuit breaker, per-layer graceful degradation
//!   to the direct fallback). Single-shard PJRT serving is the same
//!   engine with `shards: 1` (`ServeEngine::start_pjrt`).

pub mod autotuner;
pub mod batcher;
pub mod buffers;
pub mod scheduler;
pub mod service;
pub mod strategy;

pub use autotuner::{Autotuner, CacheStats, Choice, StrategyCache};
pub use batcher::{Batch, Batcher, BatcherConfig};
pub use buffers::BufferPool;
pub use scheduler::{LayerPlan, NetLayer, NetPlan, NetworkScheduler,
                    PassTimings};
pub use service::{chain_outputs, Backend, Completion, EngineClient,
                  EngineConfig, EngineConfigBuilder, EngineReport,
                  LayerStats, ServeEngine, ServeFailure, ServeRequest,
                  ShardHealth, ShardReport, Ticket};
pub use strategy::{Pass, Strategy};
