//! The coordination layer — the paper's system-level machinery, which in
//! the original lives across the Torch integration and the CUDA
//! buffering/streaming code (§3.3–3.4):
//!
//! * [`strategy`]  — the convolution-strategy vocabulary and artifact
//!   naming shared with the AOT manifest;
//! * [`autotuner`] — §3.4's strategy selection: explore smooth Fourier
//!   basis sizes `2^a·3^b·5^c·7^d` and implementation choices, measure
//!   once per problem size, cache the winner (persistable);
//! * [`buffers`]   — §3.3's memory policy: one buffered copy per tensor
//!   role, auto-expanded and reused across layers;
//! * [`scheduler`] — bulk-synchronous whole-CNN execution through cached
//!   PJRT executables (the Table-3 harness);
//! * [`batcher`]   — deadline-aware dynamic request batching;
//! * [`service`]   — the sharded multi-worker serving engine
//!   ([`ServeEngine`]): admission → least-loaded shard → per-shard
//!   batcher → strategy-cache dispatch, supervised (`catch_unwind`
//!   per flush, [`ShardHealth`] circuit breaker, graceful degradation
//!   to the direct fallback), with the legacy single-shard
//!   [`ConvService`] wrapper on top.

pub mod autotuner;
pub mod batcher;
pub mod buffers;
pub mod scheduler;
pub mod service;
pub mod strategy;

pub use autotuner::{Autotuner, CacheStats, Choice, StrategyCache};
pub use batcher::{Batch, Batcher, BatcherConfig};
pub use buffers::BufferPool;
pub use scheduler::{LayerPlan, NetworkScheduler, PassTimings};
pub use service::{Completion, ConvService, EngineClient, EngineConfig,
                  EngineReport, ServeEngine, ServeError, ServeRequest,
                  ServiceReport, ShardHealth, ShardReport, SubmitError};
pub use strategy::{Pass, Strategy};
