//! Strategy + pass vocabulary, shared with the AOT manifest's naming
//! scheme (`conv.<spec>.<strategy>.<pass>`).

use std::fmt;

/// Which convolution implementation serves a layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// XLA's native convolution — the cuDNN-analogue vendor black box.
    Vendor,
    /// jnp.fft-based frequency convolution — the cuFFT-analogue.
    VendorFft,
    /// The Pallas fbfft pipeline (§5) — host twin runs the SoA
    /// batch-lane kernels.
    Fbfft,
    /// The pre-SoA scalar fbfft host path, kept as a tunable baseline.
    FbfftScalar,
    /// §6 tiling over fbfft with output-tile size d.
    FbfftTiled(usize),
    /// Overlap-and-Add fbfft (Highlander & Rodriguez 1601.06815):
    /// tile × tile input patches convolved at the small fixed basis
    /// `next_pow2(tile + k - 1)`, partial outputs overlap-added.
    FbfftOaA(usize),
    /// In-tree direct time-domain kernel (ccn2 analogue).
    Direct,
    /// In-tree matrix-unrolling kernel.
    Im2col,
}

impl Strategy {
    /// Manifest name component.
    pub fn tag(&self) -> String {
        match self {
            Strategy::Vendor => "vendor".into(),
            Strategy::VendorFft => "vendor_fft".into(),
            Strategy::Fbfft => "fbfft".into(),
            Strategy::FbfftScalar => "fbfft_scalar".into(),
            Strategy::FbfftTiled(d) => format!("fbfft_tiled.fprop.d{d}"),
            Strategy::FbfftOaA(t) => format!("fbfft_oaa.t{t}"),
            Strategy::Direct => "direct".into(),
            Strategy::Im2col => "im2col".into(),
        }
    }

    pub fn from_tag(tag: &str) -> Option<Strategy> {
        Some(match tag {
            "vendor" => Strategy::Vendor,
            "vendor_fft" => Strategy::VendorFft,
            "fbfft" => Strategy::Fbfft,
            "fbfft_scalar" => Strategy::FbfftScalar,
            "direct" => Strategy::Direct,
            "im2col" => Strategy::Im2col,
            t if t.starts_with("fbfft_tiled") => {
                let d = t.rsplit(".d").next()?.parse().ok()?;
                Strategy::FbfftTiled(d)
            }
            t if t.starts_with("fbfft_oaa") => {
                let tile = t.rsplit(".t").next()?.parse().ok()?;
                Strategy::FbfftOaA(tile)
            }
            _ => return None,
        })
    }

    /// Frequency-domain strategies can't serve strided layers (paper §2).
    pub fn supports_stride(&self, stride: usize) -> bool {
        stride == 1 || matches!(self, Strategy::Vendor)
    }

    /// The nearest strategy that has AOT artifacts behind it. The
    /// autotuner measures *host* engines, some of which have no compiled
    /// counterpart (`Direct`/`Im2col` are in-tree analogues of the
    /// vendor black box, `FbfftScalar` is a tuning baseline of the same
    /// fbfft pipeline) — when a tuned [`Choice`](super::Choice) drives a
    /// PJRT [`LayerPlan`](super::LayerPlan), map it onto the artifact
    /// family it stands in for.
    pub fn artifact_equivalent(&self) -> Strategy {
        match self {
            Strategy::Direct | Strategy::Im2col => Strategy::Vendor,
            Strategy::FbfftScalar => Strategy::Fbfft,
            // no OaA artifacts in aot.py yet: the host decomposition
            // stands in for the compiled full-pad fbfft family
            Strategy::FbfftOaA(_) => Strategy::Fbfft,
            s => *s,
        }
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.tag())
    }
}

/// The three training passes of §2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Pass {
    Fprop,
    Bprop,
    AccGrad,
}

impl Pass {
    pub const ALL: [Pass; 3] = [Pass::Fprop, Pass::Bprop, Pass::AccGrad];

    pub fn tag(&self) -> &'static str {
        match self {
            Pass::Fprop => "fprop",
            Pass::Bprop => "bprop",
            Pass::AccGrad => "accgrad",
        }
    }
}

impl fmt::Display for Pass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.tag())
    }
}

/// Manifest artifact name for (spec, strategy, pass).
pub fn artifact_name(spec: &str, strategy: Strategy, pass: Pass) -> String {
    match strategy {
        Strategy::FbfftTiled(d) => {
            // tiled artifacts exist for fprop only (see aot.py)
            format!("conv.{spec}.fbfft_tiled.{}.d{d}", pass.tag())
        }
        _ => format!("conv.{spec}.{}.{}", strategy.tag(), pass.tag()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_round_trip() {
        for s in [Strategy::Vendor, Strategy::VendorFft, Strategy::Fbfft,
                  Strategy::FbfftScalar, Strategy::Direct,
                  Strategy::Im2col, Strategy::FbfftTiled(8),
                  Strategy::FbfftOaA(32)] {
            assert_eq!(Strategy::from_tag(&s.tag()), Some(s));
        }
    }

    #[test]
    fn stride_gating() {
        assert!(Strategy::Vendor.supports_stride(4));
        assert!(!Strategy::Fbfft.supports_stride(4));
        assert!(Strategy::Fbfft.supports_stride(1));
        assert!(!Strategy::VendorFft.supports_stride(2));
    }

    #[test]
    fn artifact_equivalents_are_artifact_backed() {
        assert_eq!(Strategy::Direct.artifact_equivalent(), Strategy::Vendor);
        assert_eq!(Strategy::Im2col.artifact_equivalent(), Strategy::Vendor);
        assert_eq!(Strategy::FbfftScalar.artifact_equivalent(),
                   Strategy::Fbfft);
        assert_eq!(Strategy::FbfftTiled(8).artifact_equivalent(),
                   Strategy::FbfftTiled(8));
        assert_eq!(Strategy::FbfftOaA(32).artifact_equivalent(),
                   Strategy::Fbfft);
        assert_eq!(Strategy::VendorFft.artifact_equivalent(),
                   Strategy::VendorFft);
    }

    #[test]
    fn artifact_names_match_aot_convention() {
        assert_eq!(artifact_name("swp.k3.y8", Strategy::Fbfft, Pass::Fprop),
                   "conv.swp.k3.y8.fbfft.fprop");
        assert_eq!(artifact_name("tile.x57", Strategy::FbfftTiled(8),
                                 Pass::Fprop),
                   "conv.tile.x57.fbfft_tiled.fprop.d8");
    }
}
