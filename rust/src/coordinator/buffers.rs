//! §3.3's buffer policy: 'we keep one single buffered copy of each type
//! of tensor involved … automatically expanded as required and reused as
//! much as possible', tailored for bulk-synchronous layer execution.
//!
//! A [`BufferPool`] hands out role-keyed `f32` buffers. A role is e.g.
//! `"input"`, `"weight"`, `"freq_a"` — one live buffer per role, grown
//! monotonically to the high-water mark, never shrunk (matching the
//! paper's behaviour and its memory-pressure trade-off discussion in §6).

use std::collections::HashMap;

/// Role-keyed reusable buffer arena.
#[derive(Debug, Default)]
pub struct BufferPool {
    bufs: HashMap<String, Vec<f32>>,
    /// counters for the reuse-vs-allocation report
    pub allocations: usize,
    pub expansions: usize,
    pub reuses: usize,
}

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the buffer for `role`, expanded to at least `len` elements
    /// and zeroed over `[0, len)`. The same role always returns the same
    /// allocation (until expansion) — callers must not hold two mutable
    /// roles at once, which the borrow checker enforces structurally.
    pub fn get(&mut self, role: &str, len: usize) -> &mut [f32] {
        match self.bufs.entry(role.to_string()) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                let buf = e.get_mut();
                if buf.len() < len {
                    buf.resize(len, 0.0);
                    self.expansions += 1;
                } else {
                    self.reuses += 1;
                }
                let buf = e.into_mut();
                let s = &mut buf[..len];
                s.fill(0.0);
                s
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                self.allocations += 1;
                &mut e.insert(vec![0.0; len])[..len]
            }
        }
    }

    /// Capacity currently held for `role` (0 if never requested).
    pub fn capacity(&self, role: &str) -> usize {
        self.bufs.get(role).map(Vec::len).unwrap_or(0)
    }

    /// Total f32 elements held — the memory-pressure figure the paper
    /// trades against FFT-reuse opportunities (§6).
    pub fn total_elems(&self) -> usize {
        self.bufs.values().map(Vec::len).sum()
    }

    /// Number of distinct roles (the 'types of tensor involved').
    pub fn roles(&self) -> usize {
        self.bufs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_role_reuses_allocation() {
        let mut p = BufferPool::new();
        p.get("input", 100);
        p.get("input", 50);
        p.get("input", 100);
        assert_eq!(p.allocations, 1);
        assert_eq!(p.reuses, 2);
        assert_eq!(p.expansions, 0);
        assert_eq!(p.capacity("input"), 100);
    }

    #[test]
    fn grows_to_high_water_mark_and_stays() {
        let mut p = BufferPool::new();
        p.get("freq", 10);
        p.get("freq", 1000);
        p.get("freq", 10);
        assert_eq!(p.capacity("freq"), 1000);
        assert_eq!(p.expansions, 1);
    }

    #[test]
    fn buffers_are_zeroed_per_request() {
        let mut p = BufferPool::new();
        let b = p.get("x", 4);
        b.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let b2 = p.get("x", 4);
        assert_eq!(b2, &[0.0; 4]);
    }

    #[test]
    fn roles_are_independent() {
        let mut p = BufferPool::new();
        p.get("a", 16);
        p.get("b", 32);
        assert_eq!(p.roles(), 2);
        assert_eq!(p.total_elems(), 48);
        assert_eq!(p.allocations, 2);
    }
}
