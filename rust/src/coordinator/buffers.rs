//! §3.3's buffer policy: 'we keep one single buffered copy of each type
//! of tensor involved … automatically expanded as required and reused as
//! much as possible', tailored for bulk-synchronous layer execution.
//!
//! A [`BufferPool`] hands out role-keyed buffers. A role is e.g.
//! `"input"`, `"weight"`, `"freq.a"` — one live buffer per role, grown
//! monotonically to the high-water mark, never shrunk (matching the
//! paper's behaviour and its memory-pressure trade-off discussion in §6).
//!
//! Two access styles:
//!
//! * [`BufferPool::get`] — borrow in place. Simple, but the borrow pins
//!   the whole pool, so only one role can be live at a time.
//! * [`BufferPool::take`] / [`BufferPool::put`] (and the `_c32` pair) —
//!   check a buffer *out* of the pool and back *in*. The frequency
//!   pipeline holds several live tensors at once (two operand spectra,
//!   the product, FFT scratch, CGEMM packing panels), so its `Workspace`
//!   is built on this style. Capacity survives the round trip; after
//!   warmup a checkout is never an allocation (the `take` flavors
//!   zero-fill, the `take_raw` flavors hand back stale contents for
//!   roles the consumer fully overwrites — no memset on the hot path)
//!   — the `allocations` / `expansions` counters prove it in tests.

use std::collections::HashMap;
use std::sync::Arc;

use crate::fft::C32;
use crate::testkit::faults::{FaultKind, FaultPlan};

/// Role-keyed reusable buffer arena (`f32`, `C32` and split-complex
/// planar-pair planes).
#[derive(Debug, Default)]
pub struct BufferPool {
    bufs: HashMap<String, Vec<f32>>,
    bufs_c32: HashMap<String, Vec<C32>>,
    /// planar re/im pairs — the SoA frequency slabs; a dedicated map so
    /// a pair checkout is one lookup with no derived-key allocation
    bufs_pair: HashMap<String, (Vec<f32>, Vec<f32>)>,
    /// counters for the reuse-vs-allocation report
    pub allocations: usize,
    pub expansions: usize,
    pub reuses: usize,
    /// deterministic fault-injection hook: when armed, `take_raw`
    /// checkouts count as `AllocFail` occurrences for the scoped shard
    /// and a scripted occurrence panics — inside the serving engine
    /// the panic lands in the supervised flush region
    faults: Option<(Arc<FaultPlan>, Option<usize>)>,
    /// allocation failures this pool has injected (shard attribution
    /// for the serve report)
    pub faults_injected: usize,
}

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Arm the pool's fault-injection hook: `take_raw` checkouts become
    /// `AllocFail` occurrences scoped to `shard` (see
    /// [`FaultPlan::fire`]).
    pub fn set_faults(&mut self, plan: Arc<FaultPlan>,
                      shard: Option<usize>) {
        self.faults = Some((plan, shard));
    }

    /// Probe the fault plan for an injected allocation failure. Panics
    /// like a real failed allocation would; callers on the serving path
    /// are supervised (`catch_unwind`) and treat it as a shard crash.
    fn maybe_fail_alloc(&mut self) {
        if let Some((plan, shard)) = &self.faults {
            if plan.fire(FaultKind::AllocFail, *shard) {
                self.faults_injected += 1;
                panic!("injected allocation failure (FaultPlan, \
                        shard {shard:?})");
            }
        }
    }

    /// Fetch the buffer for `role`, expanded to at least `len` elements
    /// and zeroed over `[0, len)`. The same role always returns the same
    /// allocation (until expansion) — callers must not hold two mutable
    /// roles at once, which the borrow checker enforces structurally.
    pub fn get(&mut self, role: &str, len: usize) -> &mut [f32] {
        match self.bufs.entry(role.to_string()) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                let buf = e.get_mut();
                // an expansion is a real reallocation: key on *capacity*,
                // exactly as `take_raw` does — a role whose length was
                // truncated by an earlier smaller checkout but whose
                // capacity still covers `len` is a reuse
                if buf.capacity() < len {
                    self.expansions += 1;
                } else {
                    self.reuses += 1;
                }
                if buf.len() < len {
                    buf.resize(len, 0.0);
                }
                let buf = e.into_mut();
                let s = &mut buf[..len];
                s.fill(0.0);
                s
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                self.allocations += 1;
                &mut e.insert(vec![0.0; len])[..len]
            }
        }
    }

    /// Check an `f32` buffer out of the pool: `len` elements, all zero.
    /// Capacity from previous rounds is reused; return it with
    /// [`BufferPool::put`] so the next checkout stays allocation-free.
    pub fn take(&mut self, role: &str, len: usize) -> Vec<f32> {
        let mut buf = self.take_raw(role, len);
        buf.fill(0.0);
        buf
    }

    /// [`BufferPool::take`] without the zero-fill: contents are
    /// *unspecified* (stale data from the role's previous round). For
    /// roles every consumer fully overwrites — the frequency slabs, the
    /// transpose targets, FFT scratch, CGEMM packing panels — skipping
    /// the memset keeps multi-MB zeroing out of the timed hot stages.
    /// Only growth beyond the old length is zeroed (safe-Rust floor).
    pub fn take_raw(&mut self, role: &str, len: usize) -> Vec<f32> {
        self.maybe_fail_alloc();
        match self.bufs.remove(role) {
            Some(mut buf) => {
                if buf.capacity() < len {
                    self.expansions += 1;
                } else {
                    self.reuses += 1;
                }
                if buf.len() > len {
                    buf.truncate(len);
                } else {
                    buf.resize(len, 0.0);
                }
                buf
            }
            None => {
                self.allocations += 1;
                vec![0.0; len]
            }
        }
    }

    /// Check an `f32` buffer back in under `role`, keeping its capacity.
    pub fn put(&mut self, role: &str, buf: Vec<f32>) {
        self.bufs.insert(role.to_string(), buf);
    }

    /// [`BufferPool::take`] for the complex (frequency-domain) arena.
    pub fn take_c32(&mut self, role: &str, len: usize) -> Vec<C32> {
        let mut buf = self.take_c32_raw(role, len);
        buf.fill(C32::ZERO);
        buf
    }

    /// [`BufferPool::take_raw`] for the complex arena: unspecified
    /// (stale) contents, no memset on the steady-state path.
    pub fn take_c32_raw(&mut self, role: &str, len: usize) -> Vec<C32> {
        match self.bufs_c32.remove(role) {
            Some(mut buf) => {
                if buf.capacity() < len {
                    self.expansions += 1;
                } else {
                    self.reuses += 1;
                }
                if buf.len() > len {
                    buf.truncate(len);
                } else {
                    buf.resize(len, C32::ZERO);
                }
                buf
            }
            None => {
                self.allocations += 1;
                vec![C32::ZERO; len]
            }
        }
    }

    /// [`BufferPool::put`] for the complex arena.
    pub fn put_c32(&mut self, role: &str, buf: Vec<C32>) {
        self.bufs_c32.insert(role.to_string(), buf);
    }

    /// Planar (split-complex) checkout: one re and one im `f32` plane of
    /// `len` elements each under one role. The SoA frequency pipeline
    /// holds every spectrum as such a pair — same stale-contents
    /// contract as [`BufferPool::take_raw`], counted as one checkout.
    pub fn take_planar_raw(&mut self, role: &str,
                           len: usize) -> (Vec<f32>, Vec<f32>) {
        match self.bufs_pair.remove(role) {
            Some((mut re, mut im)) => {
                if re.capacity() < len || im.capacity() < len {
                    self.expansions += 1;
                } else {
                    self.reuses += 1;
                }
                for buf in [&mut re, &mut im] {
                    if buf.len() > len {
                        buf.truncate(len);
                    } else {
                        buf.resize(len, 0.0);
                    }
                }
                (re, im)
            }
            None => {
                self.allocations += 1;
                (vec![0.0; len], vec![0.0; len])
            }
        }
    }

    /// Check a planar re/im pair back in, keeping both capacities.
    pub fn put_planar(&mut self, role: &str, pair: (Vec<f32>, Vec<f32>)) {
        self.bufs_pair.insert(role.to_string(), pair);
    }

    /// Capacity currently held for an `f32` role (0 if never requested or
    /// currently checked out).
    pub fn capacity(&self, role: &str) -> usize {
        self.bufs.get(role).map(Vec::len).unwrap_or(0)
    }

    /// Total pool-resident elements (`f32` count; a `C32` counts as two)
    /// — the memory-pressure figure the paper trades against FFT-reuse
    /// opportunities (§6). Checked-out buffers are not counted until
    /// they are put back.
    pub fn total_elems(&self) -> usize {
        self.bufs.values().map(Vec::len).sum::<usize>()
            + 2 * self.bufs_c32.values().map(Vec::len).sum::<usize>()
            + self.bufs_pair.values()
                .map(|(re, im)| re.len() + im.len())
                .sum::<usize>()
    }

    /// Number of distinct roles (the 'types of tensor involved').
    pub fn roles(&self) -> usize {
        self.bufs.len() + self.bufs_c32.len() + self.bufs_pair.len()
    }

    /// Zero the reuse/allocation counters while keeping every buffer,
    /// so a caller can measure steady-state reuse in isolation: reset
    /// after warmup, run the hot phase, then assert `allocations == 0`
    /// (how `workspace_alloc.rs` proves the zero-allocation pipeline).
    pub fn reset_counters(&mut self) {
        self.allocations = 0;
        self.expansions = 0;
        self.reuses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_role_reuses_allocation() {
        let mut p = BufferPool::new();
        p.get("input", 100);
        p.get("input", 50);
        p.get("input", 100);
        assert_eq!(p.allocations, 1);
        assert_eq!(p.reuses, 2);
        assert_eq!(p.expansions, 0);
        assert_eq!(p.capacity("input"), 100);
    }

    #[test]
    fn grows_to_high_water_mark_and_stays() {
        let mut p = BufferPool::new();
        p.get("freq", 10);
        p.get("freq", 1000);
        p.get("freq", 10);
        assert_eq!(p.capacity("freq"), 1000);
        assert_eq!(p.expansions, 1);
    }

    #[test]
    fn buffers_are_zeroed_per_request() {
        let mut p = BufferPool::new();
        let b = p.get("x", 4);
        b.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let b2 = p.get("x", 4);
        assert_eq!(b2, &[0.0; 4]);
    }

    #[test]
    fn roles_are_independent() {
        let mut p = BufferPool::new();
        p.get("a", 16);
        p.get("b", 32);
        assert_eq!(p.roles(), 2);
        assert_eq!(p.total_elems(), 48);
        assert_eq!(p.allocations, 2);
    }

    #[test]
    fn take_put_round_trip_is_allocation_free() {
        let mut p = BufferPool::new();
        let b = p.take("scratch", 64);
        assert_eq!(b.len(), 64);
        p.put("scratch", b);
        assert_eq!(p.allocations, 1);
        // steady state: same role, same (or smaller) size → pure reuse
        for len in [64usize, 32, 64] {
            let b = p.take("scratch", len);
            assert!(b.iter().all(|v| *v == 0.0));
            p.put("scratch", b);
        }
        assert_eq!(p.allocations, 1);
        assert_eq!(p.expansions, 0);
        assert_eq!(p.reuses, 3);
    }

    #[test]
    fn take_zeroes_previous_contents() {
        let mut p = BufferPool::new();
        let mut b = p.take("x", 4);
        b.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        p.put("x", b);
        let b = p.take("x", 4);
        assert_eq!(&b[..], &[0.0; 4]);
    }

    #[test]
    fn take_raw_reuses_without_memset_but_zeroes_growth() {
        let mut p = BufferPool::new();
        let mut b = p.take_raw("hot", 4);
        b.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        p.put("hot", b);
        // same size: stale contents visible, no allocation
        let b = p.take_raw("hot", 4);
        assert_eq!(&b[..], &[1.0, 2.0, 3.0, 4.0]);
        p.put("hot", b);
        // shrink then regrow: the regrown tail is zeroed (safe floor)
        let b = p.take_raw("hot", 2);
        assert_eq!(&b[..], &[1.0, 2.0]);
        p.put("hot", b);
        let b = p.take_raw("hot", 4);
        assert_eq!(b.len(), 4);
        assert_eq!(&b[2..], &[0.0, 0.0]);
        p.put("hot", b);
        assert_eq!(p.allocations, 1);
        assert_eq!(p.expansions, 0);
        assert_eq!(p.reuses, 3);
        // the zeroing variant scrubs the same capacity
        let b = p.take("hot", 4);
        assert_eq!(&b[..], &[0.0; 4]);
    }

    #[test]
    fn get_counts_expansion_by_capacity_not_length() {
        let mut p = BufferPool::new();
        let b = p.take_raw("mix", 8);
        p.put("mix", b);
        // shrink the role's *length* via a smaller checkout …
        let b = p.take_raw("mix", 2);
        p.put("mix", b);
        p.reset_counters();
        // … then `get` at the original size: capacity 8 still covers
        // it, so this must count as a reuse, not an expansion
        let s = p.get("mix", 8);
        assert_eq!(s, &[0.0; 8]);
        assert_eq!(p.allocations, 0);
        assert_eq!(p.expansions, 0);
        assert_eq!(p.reuses, 1);
    }

    #[test]
    fn c32_arena_counts_and_reuses() {
        let mut p = BufferPool::new();
        let b = p.take_c32("freq", 8);
        assert_eq!(b.len(), 8);
        assert!(b.iter().all(|c| *c == C32::ZERO));
        p.put_c32("freq", b);
        let b = p.take_c32("freq", 8);
        p.put_c32("freq", b);
        assert_eq!(p.allocations, 1);
        assert_eq!(p.reuses, 1);
        assert_eq!(p.total_elems(), 16);
        assert_eq!(p.roles(), 1);
    }

    #[test]
    fn planar_pair_round_trip_reuses_and_zeroes_growth() {
        let mut p = BufferPool::new();
        let (mut re, im) = p.take_planar_raw("soa", 4);
        re.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        p.put_planar("soa", (re, im));
        assert_eq!(p.allocations, 1);
        // same size: stale contents visible, pure reuse
        let (re, im) = p.take_planar_raw("soa", 4);
        assert_eq!(&re[..], &[1.0, 2.0, 3.0, 4.0]);
        p.put_planar("soa", (re, im));
        // shrink then regrow: the regrown tail is zeroed
        let pair = p.take_planar_raw("soa", 2);
        p.put_planar("soa", pair);
        let (re, _im) = p.take_planar_raw("soa", 4);
        assert_eq!(&re[2..], &[0.0, 0.0]);
        assert_eq!(p.allocations, 1);
        assert_eq!(p.expansions, 0);
        assert_eq!(p.reuses, 3);
        assert_eq!(p.roles(), 0, "pair is checked out");
    }

    #[test]
    fn reset_counters_keeps_buffers() {
        let mut p = BufferPool::new();
        let b = p.take("warm", 64);
        p.put("warm", b);
        p.reset_counters();
        assert_eq!((p.allocations, p.expansions, p.reuses), (0, 0, 0));
        let b = p.take("warm", 64);
        p.put("warm", b);
        assert_eq!(p.allocations, 0, "buffer survived the reset");
        assert_eq!(p.reuses, 1);
    }

    #[test]
    fn armed_pool_fails_the_scripted_checkout_only() {
        let mut p = BufferPool::new();
        let plan = Arc::new(FaultPlan::parse("shard0:alloc_fail@2")
            .unwrap());
        p.set_faults(plan.clone(), Some(0));
        let b = p.take_raw("stage", 8); // occurrence 1: survives
        p.put("stage", b);
        let failed = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                p.take_raw("stage", 8) // occurrence 2: scripted failure
            }));
        assert!(failed.is_err(), "scripted checkout must panic");
        assert_eq!(p.faults_injected, 1);
        assert_eq!(plan.injected(), 1);
        // the spec fired once; later checkouts are healthy again
        let b = p.take_raw("stage", 8);
        assert_eq!(b.len(), 8);
    }

    #[test]
    fn f32_and_c32_roles_do_not_collide() {
        let mut p = BufferPool::new();
        let a = p.take("shared-name", 4);
        let b = p.take_c32("shared-name", 4);
        p.put("shared-name", a);
        p.put_c32("shared-name", b);
        assert_eq!(p.roles(), 2);
        assert_eq!(p.allocations, 2);
    }
}
