//! §3.4's autotuner: 'a strategy selection mechanism that runs once for
//! each problem size and caches the fastest strategy out of a few dozen
//! for later reuse'.
//!
//! The search space matches the paper's: every smooth Fourier basis size
//! `i ∈ [n, 2^⌈log2 n⌉]` with `i = 2^a·3^b·5^c·7^d` for the vendor FFT
//! path, the power-of-two bases for fbfft, the time-domain engines, and
//! (optionally) §6 tile sizes. Candidates are *measured*, not modeled —
//! the model lives in `cost::` for the full-plane extrapolation.
//!
//! The cache is keyed by the problem (the paper keys by problem size) and
//! persists as JSON so tuning survives process restarts. The persisted
//! document is stamped with the SIMD dispatch tier the measurements ran
//! under ([`crate::util::simd::tier`]): timings measured with the scalar
//! microkernels say nothing about the AVX2/AVX-512 ones (and vice
//! versa), so a warm load under a different tier degrades to a counted
//! cold start instead of serving stale decisions.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::conv::{direct, im2col, oaa, tiled, BOperand, ConvProblem,
                  FftConvEngine, FftMode, Operands, SpectrumPrecision,
                  Workspace};
use crate::fft::is_smooth;
use crate::util::{Json, Rng, SimdTier};

use super::strategy::{Pass, Strategy};

/// One tuned decision.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Choice {
    pub strategy: Strategy,
    /// Fourier basis (frequency strategies only)
    pub n_fft: Option<usize>,
    /// measured seconds per pass at tuning time
    pub seconds: f64,
}

/// The smooth candidate bases of §3.4: `i ∈ [n, 2^⌈log2 n⌉]`,
/// `i = 2^a·3^b·5^c·7^d`. When n is a power of two the space collapses to
/// that single point, exactly as the paper notes.
pub fn candidate_bases(n: usize) -> Vec<usize> {
    let hi = n.next_power_of_two();
    (n..=hi).filter(|i| is_smooth(*i)).collect()
}

/// The Overlap-and-Add tile candidates for a problem — the shared
/// [`oaa::tile_candidates`] sweep ({16, 32, 64} plus the basis-filling
/// tiles of bases {32, 64, 128}), re-exported under the tuner's naming
/// so tuning call sites read uniformly with [`candidate_bases`].
pub fn oaa_tile_candidates(p: &ConvProblem) -> Vec<usize> {
    oaa::tile_candidates(p)
}

#[derive(Debug, Default)]
pub struct Autotuner {
    cache: HashMap<(ConvProblem, Pass), Choice>,
    /// measurement repetitions per candidate
    pub reps: usize,
    /// include the §6 tiled candidates (fprop only)
    pub try_tiling: bool,
    /// include the Overlap-and-Add tile candidates
    /// ([`oaa_tile_candidates`]) — separate from `try_tiling`: the §6
    /// kernel-sized tiles explode into thousands of allocating calls at
    /// 256²+ inputs, exactly where the fixed OaA tiles are designed to
    /// run, so tests and large-shape tuning disable one without the
    /// other
    pub try_oaa: bool,
    /// time frequency candidates through the weight-spectrum cache at
    /// this precision (fprop/bprop): the serving engine amortizes the
    /// weight FFT away, so its tuner must measure flushes the same way
    /// or it would systematically under-rate the frequency strategies
    pub serve_spectra: Option<SpectrumPrecision>,
    /// persisted-state problems swallowed by the tolerant loader
    /// (corrupt JSON, unknown schema, malformed entries) — a warm start
    /// degraded to a (partial) cold start instead of an error
    pub load_warnings: usize,
}

impl Autotuner {
    pub fn new() -> Self {
        Autotuner { cache: HashMap::new(), reps: 3, try_tiling: true,
                    try_oaa: true, serve_spectra: None,
                    load_warnings: 0 }
    }

    pub fn cached(&self, p: &ConvProblem, pass: Pass) -> Option<Choice> {
        self.cache.get(&(*p, pass)).copied()
    }

    /// Insert a decision measured elsewhere (the [`StrategyCache`] tunes
    /// outside its lock and publishes the winner through this).
    pub fn insert(&mut self, p: &ConvProblem, pass: Pass, c: Choice) {
        self.cache.insert((*p, pass), c);
    }

    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }

    /// Tune (or return cached) the fastest host-engine strategy for one
    /// (problem, pass). Runs each candidate `reps` times on synthetic
    /// data and keeps the minimum — the paper's run-once-and-cache flow.
    pub fn tune(&mut self, p: &ConvProblem, pass: Pass) -> Choice {
        if let Some(c) = self.cached(p, pass) {
            return c;
        }
        let mut rng = Rng::new(0xA070 ^ p.problem_size() as u64);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let go = rng.normal_vec(p.output_len());

        let mut best: Option<Choice> = None;
        let mut consider = |c: Choice| {
            if best.map(|b| c.seconds < b.seconds).unwrap_or(true) {
                best = Some(c);
            }
        };

        let time_it = |f: &mut dyn FnMut()| -> f64 {
            let mut lo = f64::INFINITY;
            for _ in 0..self.reps.max(1) {
                let t0 = Instant::now();
                f();
                lo = lo.min(t0.elapsed().as_secs_f64());
            }
            lo
        };

        // time-domain candidates
        if p.stride == 1 || matches!(pass, Pass::Fprop) {
            let secs = time_it(&mut || {
                match pass {
                    Pass::Fprop => drop(direct::fprop(p, &x, &wei)),
                    Pass::Bprop => drop(direct::bprop(p, &go, &wei)),
                    Pass::AccGrad => drop(direct::accgrad(p, &go, &x)),
                };
            });
            consider(Choice { strategy: Strategy::Direct, n_fft: None,
                              seconds: secs });
            let secs = time_it(&mut || {
                match pass {
                    Pass::Fprop => drop(im2col::fprop(p, &x, &wei)),
                    Pass::Bprop => drop(im2col::bprop(p, &go, &wei)),
                    Pass::AccGrad => drop(im2col::accgrad(p, &go, &x)),
                };
            });
            consider(Choice { strategy: Strategy::Im2col, n_fft: None,
                              seconds: secs });
        }

        if p.stride == 1 {
            // FFT candidates run the production `_into` path against a
            // workspace shared across candidates, with one warmup rep —
            // so the cached Choice reflects steady-state (pool-reusing,
            // zero-allocation) per-pass cost, not first-call setup
            let mut ws = Workspace::new();
            let mut fft_out = vec![0f32; match pass {
                Pass::Fprop => p.output_len(),
                Pass::Bprop => p.input_len(),
                Pass::AccGrad => p.weight_len(),
            }];
            let reps = self.reps.max(1);
            // serving amortizes the weight FFT through the spectrum
            // cache, so when tuning for that tier the weight spectrum
            // is built once *outside* the timed region and the
            // candidates measure the spec-path flush cost instead
            let spec_precision = match (self.serve_spectra, pass) {
                (Some(prec), Pass::Fprop | Pass::Bprop) => Some(prec),
                _ => None,
            };
            let time_fft = |eng: &FftConvEngine,
                                ws: &mut Workspace,
                                out: &mut [f32]| -> f64 {
                let spec = spec_precision.map(|prec| {
                    eng.weight_spectrum(p, &wei, 0, prec, ws)
                });
                let mut lo = f64::INFINITY;
                for rep in 0..=reps {
                    let t0 = Instant::now();
                    match (&spec, pass) {
                        (Some(s), Pass::Fprop) => {
                            eng.fprop_spec_into(p, &x, s, out, ws);
                        }
                        (Some(s), Pass::Bprop) => {
                            eng.bprop_spec_into(p, &go, s, out, ws);
                        }
                        (None, Pass::Fprop) => {
                            eng.fprop_into(p, &x, &wei, out, ws);
                        }
                        (None, Pass::Bprop) => {
                            eng.bprop_into(p, &go, &wei, out, ws);
                        }
                        (_, Pass::AccGrad) => {
                            eng.accgrad_into(p, &go, &x, out, ws);
                        }
                    }
                    if rep > 0 {
                        lo = lo.min(t0.elapsed().as_secs_f64());
                    }
                }
                lo
            };
            // vendor-FFT candidates over the smooth bases. 1-D signals
            // are excluded: this host pipeline transforms at a *square*
            // basis, so a `1 × w` signal would pay a `w × w` transform
            // per plane (134 MB of spectrum at w = 4096) for an engine
            // that can never win — OaA serves long signals instead
            let one_d = p.h == 1 || p.w == 1;
            if !one_d {
                for n in candidate_bases(p.h.max(p.w)) {
                    let eng = FftConvEngine::new(FftMode::Vendor, n);
                    let secs = time_fft(&eng, &mut ws, &mut fft_out);
                    consider(Choice { strategy: Strategy::VendorFft,
                                      n_fft: Some(n), seconds: secs });
                }
            }
            // fbfft candidates (power-of-two basis): the SoA batch-lane
            // engine and the scalar baseline are tuned separately — the
            // lane mapping wins once the plane count covers the SIMD
            // width, the scalar path can still edge it out on tiny
            // batches, and the measured gap is the host analogue of the
            // paper's §5.4 transform-level comparison
            let n = p.h.max(p.w).next_power_of_two();
            if (2..=crate::fft::fbfft_host::MAX_N).contains(&n) {
                for (mode, strategy) in
                    [(FftMode::Fbfft, Strategy::Fbfft),
                     (FftMode::FbfftScalar, Strategy::FbfftScalar)] {
                    let eng = FftConvEngine::new(mode, n);
                    let secs = time_fft(&eng, &mut ws, &mut fft_out);
                    consider(Choice { strategy, n_fft: Some(n),
                                      seconds: secs });
                }
            }
            // §6 tiled candidates, kernel-sized tiles (fprop family)
            if self.try_tiling && p.kh.max(p.kw) * 4 < p.h.min(p.w) {
                for d in [p.kh.max(p.kw), 2 * p.kh.max(p.kw)] {
                    let secs = time_it(&mut || {
                        match pass {
                            Pass::Fprop => drop(tiled::fprop(p, &x, &wei, d)),
                            Pass::Bprop => drop(tiled::bprop(p, &go, &wei, d)),
                            Pass::AccGrad => drop(tiled::accgrad(p, &go, &x, d)),
                        };
                    });
                    consider(Choice {
                        strategy: Strategy::FbfftTiled(d),
                        n_fft: Some(tiled::tile_fft_size(d, p.kh, p.kw)),
                        seconds: secs,
                    });
                }
            }
            // Overlap-and-Add candidates: fixed small-basis tiles
            // batched through the fbfft pipeline. Timed like the other
            // frequency candidates — the production `run` path against
            // the shared workspace with a warmup rep, spec path when
            // tuning for the serving tier — so its Choice is the same
            // steady-state cost the cached strategies carry
            if self.try_oaa {
                for t in oaa_tile_candidates(p) {
                    let eng = oaa::OaaEngine::for_problem(p, t);
                    let spec = spec_precision.map(|prec| {
                        eng.inner().weight_spectrum(p, &wei, 0, prec,
                                                    &mut ws)
                    });
                    let a: &[f32] = match pass {
                        Pass::Fprop => &x,
                        _ => &go,
                    };
                    let mut lo = f64::INFINITY;
                    for rep in 0..=reps {
                        let b = match (&spec, pass) {
                            (Some(s), Pass::Fprop | Pass::Bprop) => {
                                BOperand::Spectrum(s)
                            }
                            (_, Pass::AccGrad) => BOperand::Planes(&x),
                            _ => BOperand::Planes(&wei),
                        };
                        let t0 = Instant::now();
                        eng.run(pass, Operands { problem: p, a, b,
                                                 out: &mut fft_out },
                                &mut ws);
                        if rep > 0 {
                            lo = lo.min(t0.elapsed().as_secs_f64());
                        }
                    }
                    consider(Choice {
                        strategy: Strategy::FbfftOaA(t),
                        n_fft: Some(eng.n_fft()),
                        seconds: lo,
                    });
                }
            }
        }

        let choice = best.expect("at least one candidate must run");
        self.cache.insert((*p, pass), choice);
        choice
    }

    /// Total time the tuner has spent measuring (for reporting).
    pub fn tune_many(&mut self, problems: &[ConvProblem], pass: Pass)
                     -> Duration {
        let t0 = Instant::now();
        for p in problems {
            self.tune(p, pass);
        }
        t0.elapsed()
    }

    // ----- persistence ----------------------------------------------------

    fn key_str(p: &ConvProblem, pass: Pass) -> String {
        format!("{}x{}x{}x{}x{}x{}x{}x{}:{}", p.s, p.f, p.fo, p.h, p.w,
                p.kh, p.kw, p.stride, pass.tag())
    }

    fn key_parse(s: &str) -> Option<(ConvProblem, Pass)> {
        let (dims, pass) = s.rsplit_once(':')?;
        let v: Vec<usize> =
            dims.split('x').map(|t| t.parse().ok()).collect::<Option<_>>()?;
        if v.len() != 8 {
            return None;
        }
        let mut p = ConvProblem::new(v[0], v[1], v[2], v[3], v[4], v[5], v[6]);
        p.stride = v[7];
        let pass = match pass {
            "fprop" => Pass::Fprop,
            "bprop" => Pass::Bprop,
            "accgrad" => Pass::AccGrad,
            _ => return None,
        };
        Some((p, pass))
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut entries = Vec::new();
        for ((p, pass), c) in &self.cache {
            entries.push(Json::obj(vec![
                ("key", Json::str(&Self::key_str(p, *pass))),
                ("strategy", Json::str(&c.strategy.tag())),
                ("n_fft", c.n_fft.map(|n| Json::num(n as f64))
                     .unwrap_or(Json::Null)),
                ("seconds", Json::num(c.seconds)),
            ]));
        }
        std::fs::write(path, Json::obj(vec![
            ("version", Json::num(2.0)),
            // the dispatch tier the cached timings were measured under —
            // checked at load, mismatches cold-start (see from_json_text)
            ("simd_tier",
             Json::str(crate::util::simd::tier().tag())),
            ("entries", Json::Arr(entries)),
        ]).to_string())
    }

    /// Warm-load a persisted cache. `None` only when the file cannot be
    /// read at all (missing path — an ordinary cold start); any *parse*
    /// problem degrades instead of failing: corrupt or truncated JSON
    /// and unknown schema versions return an empty tuner, malformed
    /// entries are skipped — each counted in `load_warnings` so the
    /// degradation is visible in reports, never silent.
    pub fn load(path: &Path) -> Option<Autotuner> {
        let text = std::fs::read_to_string(path).ok()?;
        Some(Self::from_json_text(&text))
    }

    /// The tolerant half of [`Autotuner::load`]: parse persisted cache
    /// text, swallowing corruption into `load_warnings` (a poisoned
    /// cache file must cost a re-tune, not an outage). Documents whose
    /// recorded SIMD tier differs from the active dispatch tier are
    /// *valid but stale* — they also degrade to a counted cold start,
    /// since every cached `seconds` was measured with different
    /// microkernels.
    pub fn from_json_text(text: &str) -> Autotuner {
        Self::from_json_text_for_tier(text, crate::util::simd::tier())
    }

    /// [`Autotuner::from_json_text`] with the comparison tier pinned —
    /// the testable seam (tests must not depend on the host's tier).
    fn from_json_text_for_tier(text: &str, tier: SimdTier) -> Autotuner {
        let mut t = Autotuner::new();
        let j = match Json::parse(text) {
            Ok(j) => j,
            Err(_) => {
                eprintln!("tuner cache: corrupt JSON; starting cold");
                t.load_warnings += 1;
                return t;
            }
        };
        match j.get("version").and_then(Json::as_usize) {
            Some(2) => {}
            Some(1) => {
                // pre-SIMD-dispatch schema: no tier recorded, so the
                // timings are not attributable — same cold start a tier
                // mismatch gets
                eprintln!("tuner cache: v1 document predates SIMD-tier \
                           stamping; starting cold");
                t.load_warnings += 1;
                return t;
            }
            v => {
                eprintln!("tuner cache: unknown schema version {v:?}; \
                           starting cold");
                t.load_warnings += 1;
                return t;
            }
        }
        match j.get("simd_tier").and_then(Json::as_str) {
            Some(tag) if tag == tier.tag() => {}
            Some(tag) => {
                eprintln!("tuner cache: tuned under SIMD tier '{tag}' \
                           but dispatching '{}'; timings are stale — \
                           starting cold", tier.tag());
                t.load_warnings += 1;
                return t;
            }
            None => {
                eprintln!("tuner cache: v2 document missing simd_tier; \
                           starting cold");
                t.load_warnings += 1;
                return t;
            }
        }
        let Some(entries) = j.get("entries").and_then(Json::as_arr)
        else {
            eprintln!("tuner cache: missing entries array; starting \
                       cold");
            t.load_warnings += 1;
            return t;
        };
        for e in entries {
            let parsed = (|| {
                let (p, pass) =
                    Self::key_parse(e.get("key")?.as_str()?)?;
                let strategy =
                    Strategy::from_tag(e.get("strategy")?.as_str()?)?;
                let n_fft = e.get("n_fft").and_then(Json::as_usize);
                let seconds = e.get("seconds")?.as_f64()?;
                Some(((p, pass), Choice { strategy, n_fft, seconds }))
            })();
            match parsed {
                Some((key, choice)) => {
                    t.cache.insert(key, choice);
                }
                None => {
                    eprintln!("tuner cache: skipping malformed entry");
                    t.load_warnings += 1;
                }
            }
        }
        t
    }
}

// ---------------------------------------------------------------------------
// StrategyCache — the serving engine's shared per-shape decision store
// ---------------------------------------------------------------------------

/// Counters describing how the cache has been used (surfaced in the
/// `reports::serve` table and `BENCH_serve.json`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub entries: usize,
    pub hits: usize,
    pub misses: usize,
    /// full tuner runs triggered by `ensure` misses
    pub tunes: usize,
    /// persisted-state problems swallowed by the tolerant warm-load
    pub load_warnings: usize,
    /// poisoned-lock recoveries (a shard panicked holding the tuner —
    /// the cache kept serving instead of wedging every shard)
    pub lock_recovered: usize,
}

/// Thread-safe, persistent per-`(ConvProblem, Pass)` strategy cache for
/// the serving hot path. Wraps an [`Autotuner`] behind a mutex:
///
/// * [`StrategyCache::lookup`] is the *admission/launch* path — a pure
///   map probe, never tunes, never blocks behind a measurement;
/// * [`StrategyCache::ensure`] is the *miss* path — it measures with a
///   throwaway tuner **outside** the lock (so concurrent shards keep
///   serving cached shapes) and publishes the winner;
/// * [`StrategyCache::persist`] writes the same JSON schema
///   `Autotuner::save`/`load` use, so a warm restart re-serves every
///   previously seen shape without re-tuning (§3.4's run-once economics
///   carried across process lifetimes).
#[derive(Debug)]
pub struct StrategyCache {
    tuner: Mutex<Autotuner>,
    path: Option<PathBuf>,
    dirty: AtomicBool,
    hits: AtomicUsize,
    misses: AtomicUsize,
    tunes: AtomicUsize,
    /// poisoned-lock recoveries (see [`CacheStats::lock_recovered`])
    lock_recovered: AtomicUsize,
    /// problems demoted to the direct fallback until the recorded
    /// instant (graceful degradation after a PJRT error or non-finite
    /// frequency output) — keyed with `s = 0` by the serving layer so
    /// one demotion covers every flush shape of the problem
    demoted: Mutex<HashMap<(ConvProblem, Pass), Instant>>,
    /// persisted-state problems swallowed at warm-load
    load_warnings: AtomicUsize,
    /// measurement repetitions for `ensure` misses
    pub reps: usize,
    /// include §6 tiled candidates when tuning on miss
    pub try_tiling: bool,
    /// include Overlap-and-Add tile candidates when tuning on miss
    pub try_oaa: bool,
    /// mirror of [`Autotuner::serve_spectra`] applied to miss-path tunes
    pub serve_spectra: Option<SpectrumPrecision>,
}

impl StrategyCache {
    /// Warm-load from `path` when it exists (otherwise start empty).
    /// `None` keeps the cache purely in-memory.
    pub fn open(path: Option<&Path>) -> StrategyCache {
        Self::open_with_faults(path, None)
    }

    /// [`StrategyCache::open`] with a fault-injection hook: a scripted
    /// `CorruptLoad` occurrence corrupts the persisted text before the
    /// tolerant parser sees it, exercising the real cold-start
    /// degradation path end to end.
    pub fn open_with_faults(
        path: Option<&Path>,
        faults: Option<&crate::testkit::faults::FaultPlan>,
    ) -> StrategyCache {
        let tuner = path
            .and_then(|p| {
                let mut text = std::fs::read_to_string(p).ok()?;
                if let Some(plan) = faults {
                    if plan.fire(
                        crate::testkit::faults::FaultKind::CorruptLoad,
                        None)
                    {
                        eprintln!("tuner cache: injected corrupt load \
                                   (FaultPlan)");
                        text.truncate(text.len() / 2);
                    }
                }
                Some(Autotuner::from_json_text(&text))
            })
            .unwrap_or_else(Autotuner::new);
        let load_warnings = tuner.load_warnings;
        StrategyCache {
            tuner: Mutex::new(tuner),
            path: path.map(Path::to_path_buf),
            dirty: AtomicBool::new(false),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            tunes: AtomicUsize::new(0),
            lock_recovered: AtomicUsize::new(0),
            demoted: Mutex::new(HashMap::new()),
            load_warnings: AtomicUsize::new(load_warnings),
            reps: 1,
            try_tiling: true,
            try_oaa: true,
            serve_spectra: None,
        }
    }

    /// Lock the tuner, recovering from poisoning: a shard that panicked
    /// while holding the lock must not wedge every other shard, and the
    /// guarded state (a plain decision map) stays valid across an
    /// unwound writer — worst case a racing insert is lost and the
    /// shape re-tunes once.
    fn tuner(&self) -> std::sync::MutexGuard<'_, Autotuner> {
        self.tuner.lock().unwrap_or_else(|poisoned| {
            self.lock_recovered.fetch_add(1, Ordering::Relaxed);
            eprintln!("tuner cache: recovered poisoned lock");
            poisoned.into_inner()
        })
    }

    /// Hot-path probe: the best known strategy for this shape, or `None`
    /// if never tuned. Never measures.
    pub fn lookup(&self, p: &ConvProblem, pass: Pass) -> Option<Choice> {
        let got = self.tuner().cached(p, pass);
        match got {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        got
    }

    /// Cached choice, tuning on miss. The measurement runs on a local
    /// tuner with the lock released; last writer wins if two threads race
    /// on the same shape (both measured the same candidates, so either
    /// result is valid).
    pub fn ensure(&self, p: &ConvProblem, pass: Pass) -> Choice {
        if let Some(c) = self.lookup(p, pass) {
            return c;
        }
        let mut t = Autotuner::new();
        t.reps = self.reps;
        t.try_tiling = self.try_tiling;
        t.try_oaa = self.try_oaa;
        t.serve_spectra = self.serve_spectra;
        let c = t.tune(p, pass);
        self.tunes.fetch_add(1, Ordering::Relaxed);
        self.tuner().insert(p, pass, c);
        self.dirty.store(true, Ordering::Release);
        c
    }

    /// Demote a problem to the direct fallback until `until` (graceful
    /// degradation: a PJRT runtime error or a non-finite frequency
    /// output buys the problem a cooldown on the always-correct path
    /// instead of crashing or serving garbage repeatedly).
    pub fn demote(&self, p: &ConvProblem, pass: Pass, until: Instant) {
        let mut map = self
            .demoted
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let slot = map.entry((*p, pass)).or_insert(until);
        *slot = (*slot).max(until);
    }

    /// Is the problem inside a demotion cooldown window? Expired
    /// windows are pruned on probe, so recovery needs no sweeper.
    pub fn is_demoted(&self, p: &ConvProblem, pass: Pass) -> bool {
        let mut map = self
            .demoted
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        match map.get(&(*p, pass)) {
            Some(until) if Instant::now() < *until => true,
            Some(_) => {
                map.remove(&(*p, pass));
                false
            }
            None => false,
        }
    }

    /// Record an *observed* launch time for a shape served by a fixed
    /// backend (the PJRT serving path, where no host tuner runs and the
    /// strategy is whatever the artifact compiled). Keeps the fastest
    /// observation — the same minimum-of-measurements semantics as
    /// [`Autotuner::tune`] — so deadline admission has a live launch
    /// estimate instead of `None` forever.
    pub fn observe(&self, p: &ConvProblem, pass: Pass,
                   strategy: Strategy, seconds: f64) {
        let mut t = self.tuner();
        let better = t
            .cached(p, pass)
            .map(|c| seconds < c.seconds)
            .unwrap_or(true);
        if better {
            t.insert(p, pass, Choice { strategy, n_fft: None, seconds });
            self.dirty.store(true, Ordering::Release);
        }
    }

    /// Write the cache back to its file if anything changed since the
    /// last persist. No-op for in-memory caches.
    pub fn persist(&self) -> std::io::Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        if !self.dirty.swap(false, Ordering::AcqRel) {
            return Ok(());
        }
        self.tuner().save(path)
    }

    pub fn len(&self) -> usize {
        self.tuner().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            entries: self.len(),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            tunes: self.tunes.load(Ordering::Relaxed),
            load_warnings: self.load_warnings.load(Ordering::Relaxed),
            lock_recovered: self.lock_recovered.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_bases_are_the_papers_space() {
        // n = 13 → smooth sizes in [13, 16]
        assert_eq!(candidate_bases(13), vec![14, 15, 16]);
        // power of two collapses to a single point (paper §3.4)
        assert_eq!(candidate_bases(16), vec![16]);
        assert_eq!(candidate_bases(27), vec![27, 28, 30, 32]);
        for n in candidate_bases(57) {
            assert!(is_smooth(n) && (57..=64).contains(&n));
        }
    }

    #[test]
    fn oaa_tile_candidates_sweep_pow2_and_basis_filling_tiles() {
        // large small-kernel shape: the full sweep — pow2 tiles plus
        // the basis-filling tiles of bases 32/64/128
        let p = ConvProblem::square(1, 2, 2, 256, 3);
        let c = oaa_tile_candidates(&p);
        for t in [16, 30, 32, 62, 64, 126] {
            assert!(c.contains(&t), "{t} missing from {c:?}");
        }
        // kernels near the input extent gate the sweep off entirely
        assert!(oaa_tile_candidates(
            &ConvProblem::square(1, 1, 1, 16, 5)).is_empty());
        // 1-D signals gate on the long axis and still sweep
        let line = ConvProblem::new(1, 1, 1, 1, 4096, 1, 5);
        let c = oaa_tile_candidates(&line);
        assert!(c.contains(&60), "basis-filling 64-tile: {c:?}");
        // tiles at or past the stride-1 output extent are degenerate
        // full-pad and are dropped
        for t in oaa_tile_candidates(&ConvProblem::square(1, 1, 1, 40, 3))
        {
            assert!(t < 38, "degenerate tile {t} kept");
        }
    }

    #[test]
    fn oaa_candidates_run_inside_the_tuning_contract() {
        let mut t = Autotuner::new();
        t.reps = 1;
        t.try_tiling = false;
        let p = ConvProblem::square(1, 2, 2, 48, 3);
        assert!(!oaa_tile_candidates(&p).is_empty());
        let c = t.tune(&p, Pass::Fprop);
        assert!(c.seconds.is_finite() && c.seconds > 0.0);
        assert_eq!(t.tune(&p, Pass::Fprop), c, "cached on reuse");
    }

    #[test]
    fn one_d_signals_never_tune_onto_the_square_basis_vendor_path() {
        // the vendor sweep is gated off for 1 × w signals (a square
        // basis would transform w × w per plane); the remaining
        // candidates must still produce a winner
        let mut t = Autotuner::new();
        t.reps = 1;
        t.try_tiling = false;
        let p = ConvProblem::new(1, 1, 1, 1, 64, 1, 3);
        let c = t.tune(&p, Pass::Fprop);
        assert_ne!(c.strategy, Strategy::VendorFft);
    }

    #[test]
    fn tune_caches_and_is_deterministic_on_reuse() {
        let mut t = Autotuner::new();
        t.reps = 1;
        t.try_tiling = false;
        let p = ConvProblem::square(1, 2, 2, 9, 3);
        let a = t.tune(&p, Pass::Fprop);
        let b = t.tune(&p, Pass::Fprop); // cached — identical
        assert_eq!(a, b);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn strided_problems_get_time_domain_only() {
        let mut t = Autotuner::new();
        t.reps = 1;
        let mut p = ConvProblem::square(1, 1, 1, 9, 3);
        p.stride = 2;
        let c = t.tune(&p, Pass::Fprop);
        assert!(matches!(c.strategy, Strategy::Direct | Strategy::Im2col));
    }

    #[test]
    fn persistence_round_trip() {
        let mut t = Autotuner::new();
        t.reps = 1;
        t.try_tiling = false;
        let p = ConvProblem::square(1, 2, 2, 9, 3);
        let a = t.tune(&p, Pass::Fprop);
        let tmp = std::env::temp_dir().join("fbfft_tuner_test.json");
        t.save(&tmp).unwrap();
        let t2 = Autotuner::load(&tmp).unwrap();
        assert_eq!(t2.cached(&p, Pass::Fprop), Some(a));
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn tuner_never_picks_a_dominated_strategy() {
        // The tuner's contract is picking the fastest *measured*
        // candidate — not a specific algorithm (on this host the
        // multithreaded im2col legitimately beats the single-threaded
        // FFT engine at some sizes where the K40m model says otherwise;
        // DESIGN.md §3). Assert the contract: the winner is at least as
        // fast as the plain direct engine, measured the same way.
        let mut t = Autotuner::new();
        t.reps = 3;
        t.try_tiling = false;
        let p = ConvProblem::square(16, 32, 32, 16, 13);
        let c = t.tune(&p, Pass::Fprop);
        let mut rng = crate::util::Rng::new(0xA070 ^ p.problem_size() as u64);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let mut lo = f64::INFINITY;
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            drop(direct::fprop(&p, &x, &wei));
            lo = lo.min(t0.elapsed().as_secs_f64());
        }
        // generous 2x slack for scheduler noise between the two runs
        assert!(c.seconds <= lo * 2.0,
                "tuned {:?} at {:.3}ms is slower than direct {:.3}ms",
                c.strategy, c.seconds * 1e3, lo * 1e3);
    }

    #[test]
    fn strategy_cache_lookup_never_tunes() {
        let cache = StrategyCache::open(None);
        let p = ConvProblem::square(1, 1, 1, 8, 3);
        assert_eq!(cache.lookup(&p, Pass::Fprop), None);
        let s = cache.stats();
        assert_eq!((s.entries, s.hits, s.misses, s.tunes), (0, 0, 1, 0));
    }

    #[test]
    fn strategy_cache_ensure_tunes_once_then_hits() {
        let mut cache = StrategyCache::open(None);
        cache.try_tiling = false;
        let p = ConvProblem::square(1, 2, 2, 9, 3);
        let a = cache.ensure(&p, Pass::Fprop);
        let b = cache.ensure(&p, Pass::Fprop);
        assert_eq!(a, b);
        let s = cache.stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.tunes, 1);
        assert!(s.hits >= 1, "second ensure must hit: {s:?}");
    }

    #[test]
    fn observe_keeps_the_fastest_measurement() {
        let cache = StrategyCache::open(None);
        let p = ConvProblem::square(2, 1, 1, 8, 3);
        cache.observe(&p, Pass::Fprop, Strategy::Vendor, 2e-3);
        cache.observe(&p, Pass::Fprop, Strategy::Vendor, 1e-3);
        cache.observe(&p, Pass::Fprop, Strategy::Vendor, 5e-3); // slower
        let c = cache.lookup(&p, Pass::Fprop).unwrap();
        assert_eq!(c.seconds, 1e-3);
        assert_eq!(c.strategy, Strategy::Vendor);
        assert_eq!(c.n_fft, None);
    }

    #[test]
    fn load_tolerates_garbage_bytes() {
        let tmp = std::env::temp_dir().join("fbfft_tuner_garbage.json");
        std::fs::write(&tmp, b"\x00\xffnot json{{{").unwrap();
        let t = Autotuner::load(&tmp).unwrap();
        assert!(t.is_empty(), "garbage must degrade to a cold start");
        assert!(t.load_warnings >= 1);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn load_tolerates_truncation_and_unknown_schema() {
        // truncated mid-document
        let t = Autotuner::from_json_text("{\"version\": 1, \"entr");
        assert!(t.is_empty() && t.load_warnings >= 1);
        // future schema version
        let t = Autotuner::from_json_text(
            "{\"version\": 99, \"entries\": []}");
        assert!(t.is_empty() && t.load_warnings >= 1);
        // malformed entry skipped, valid shape of document kept
        let t = Autotuner::from_json_text(
            "{\"version\": 1, \"entries\": [{\"key\": \"nope\"}]}");
        assert!(t.is_empty() && t.load_warnings >= 1);
    }

    #[test]
    fn saved_cache_records_the_dispatch_tier() {
        let mut t = Autotuner::new();
        let p = ConvProblem::square(1, 2, 2, 9, 3);
        let choice = Choice { strategy: Strategy::Direct, n_fft: None,
                              seconds: 1e-3 };
        t.insert(&p, Pass::Fprop, choice);
        let tmp = std::env::temp_dir().join("fbfft_tuner_tier_test.json");
        t.save(&tmp).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        std::fs::remove_file(&tmp).ok();
        let tier = crate::util::simd::tier();
        assert!(text.contains("\"simd_tier\""), "{text}");
        assert!(text.contains(tier.tag()), "{text}");
        // same tier: full warm load, no warnings
        let warm = Autotuner::from_json_text_for_tier(&text, tier);
        assert_eq!(warm.cached(&p, Pass::Fprop), Some(choice));
        assert_eq!(warm.load_warnings, 0);
        // different tier: the document is valid but its timings are
        // stale — counted cold start, entries dropped
        let other = if tier == SimdTier::Scalar {
            SimdTier::Avx2
        } else {
            SimdTier::Scalar
        };
        let cold = Autotuner::from_json_text_for_tier(&text, other);
        assert!(cold.is_empty(),
                "tier mismatch must not warm-load entries");
        assert_eq!(cold.load_warnings, 1);
    }

    #[test]
    fn v1_and_tierless_documents_cold_start() {
        // pre-dispatch schema: structurally fine, but no tier recorded
        let t = Autotuner::from_json_text(
            "{\"version\": 1, \"entries\": []}");
        assert!(t.is_empty() && t.load_warnings >= 1);
        // v2 claiming the schema but missing the stamp
        let t = Autotuner::from_json_text(
            "{\"version\": 2, \"entries\": []}");
        assert!(t.is_empty() && t.load_warnings >= 1);
    }

    #[test]
    fn corrupt_load_fault_forces_cold_start() {
        use crate::testkit::faults::FaultPlan;
        let tmp = std::env::temp_dir()
            .join("fbfft_tuner_corrupt_fault.json");
        std::fs::remove_file(&tmp).ok();
        let p = ConvProblem::square(1, 2, 2, 9, 3);
        {
            let mut cache = StrategyCache::open(Some(&tmp));
            cache.try_tiling = false;
            cache.ensure(&p, Pass::Fprop);
            cache.persist().unwrap();
        }
        let plan = FaultPlan::parse("corrupt_load@1").unwrap();
        let cold = StrategyCache::open_with_faults(Some(&tmp),
                                                   Some(&plan));
        assert_eq!(plan.injected(), 1);
        let s = cold.stats();
        assert_eq!(s.entries, 0,
                   "corrupted file must not warm-load entries");
        assert!(s.load_warnings >= 1, "degradation must be counted");
        // the untouched file still warm-loads on the next open
        let warm = StrategyCache::open(Some(&tmp));
        assert_eq!(warm.stats().entries, 1);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn poisoned_tuner_lock_recovers() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        use std::sync::Arc;
        let cache = Arc::new(StrategyCache::open(None));
        let p = ConvProblem::square(1, 1, 1, 8, 3);
        cache.observe(&p, Pass::Fprop, Strategy::Vendor, 1e-3);
        // poison the tuner mutex by panicking while holding it
        let c2 = Arc::clone(&cache);
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _guard = c2.tuner();
            panic!("poison");
        }));
        // the cache keeps serving and counts the recovery
        assert_eq!(cache.lookup(&p, Pass::Fprop).map(|c| c.seconds),
                   Some(1e-3));
        assert!(cache.stats().lock_recovered >= 1);
    }

    #[test]
    fn demotion_window_expires() {
        let cache = StrategyCache::open(None);
        let p = ConvProblem::square(0, 2, 2, 9, 3);
        assert!(!cache.is_demoted(&p, Pass::Fprop));
        cache.demote(&p, Pass::Fprop,
                     Instant::now() + Duration::from_secs(60));
        assert!(cache.is_demoted(&p, Pass::Fprop));
        assert!(!cache.is_demoted(&p, Pass::Bprop),
                "demotion is per-pass");
        // an already-expired window reads as not demoted and is pruned
        let q = ConvProblem::square(0, 1, 1, 8, 3);
        cache.demote(&q, Pass::Fprop, Instant::now());
        std::thread::sleep(Duration::from_millis(1));
        assert!(!cache.is_demoted(&q, Pass::Fprop));
    }

    #[test]
    fn strategy_cache_warm_loads_from_disk() {
        let tmp = std::env::temp_dir().join("fbfft_strategy_cache_test.json");
        std::fs::remove_file(&tmp).ok();
        let p = ConvProblem::square(1, 2, 2, 9, 3);
        let choice;
        {
            let mut cache = StrategyCache::open(Some(&tmp));
            cache.try_tiling = false;
            choice = cache.ensure(&p, Pass::Fprop);
            cache.persist().unwrap();
        }
        // a fresh cache over the same file serves the shape without tuning
        let warm = StrategyCache::open(Some(&tmp));
        assert_eq!(warm.lookup(&p, Pass::Fprop), Some(choice));
        assert_eq!(warm.stats().tunes, 0);
        // persist with nothing dirty is a no-op (file mtime aside, no error)
        warm.persist().unwrap();
        std::fs::remove_file(&tmp).ok();
    }
}
