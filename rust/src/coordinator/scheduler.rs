//! Bulk-synchronous whole-CNN execution through cached PJRT executables —
//! the harness behind Table 3 (AlexNet / OverFeat-fast totals).
//!
//! A network is an ordered list of [`LayerPlan`]s. For each pass the
//! scheduler walks the layers (forward order for fprop, reverse for the
//! gradients, matching real training), feeds activations through the
//! buffer pool's single-copy roles, and accumulates per-layer timings.
//! 'This behavior is tailored for a bulk synchronous execution of layers
//! on a GPU' (§3.3) — here, of PJRT executables on the CPU client.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::conv::ConvProblem;
use crate::runtime::{HostTensor, Runtime};
use crate::util::Rng;

use super::autotuner::StrategyCache;
use super::strategy::{artifact_name, Pass, Strategy};

/// One layer's execution plan: which artifact serves each pass.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    /// spec name as used in artifact names (e.g. "alexnet.conv2@_8")
    pub spec: String,
    pub problem: ConvProblem,
    pub strategy: Strategy,
}

impl LayerPlan {
    pub fn artifact(&self, pass: Pass) -> String {
        artifact_name(&self.spec, self.strategy, pass)
    }

    /// Build a plan from the persistent strategy cache: the tuned winner
    /// for `pass`, mapped onto its artifact-backed equivalent (strided
    /// layers and never-tuned shapes fall back to the vendor black box —
    /// the same conv1 treatment as the paper's Table 3).
    pub fn tuned(spec: impl Into<String>, problem: ConvProblem,
                 cache: &StrategyCache, pass: Pass) -> LayerPlan {
        let strategy = cache
            .lookup(&problem, pass)
            .map(|c| c.strategy.artifact_equivalent())
            .filter(|s| s.supports_stride(problem.stride))
            .unwrap_or(Strategy::Vendor);
        LayerPlan { spec: spec.into(), problem, strategy }
    }
}

/// Per-layer, per-pass wall-clock (the Table-3 rows).
#[derive(Clone, Debug, Default)]
pub struct PassTimings {
    pub per_layer: Vec<(String, Duration)>,
}

impl PassTimings {
    pub fn total(&self) -> Duration {
        self.per_layer.iter().map(|(_, d)| *d).sum()
    }
}

pub struct NetworkScheduler<'rt> {
    rt: &'rt Runtime,
    layers: Vec<LayerPlan>,
    rng: Rng,
}

impl<'rt> NetworkScheduler<'rt> {
    pub fn new(rt: &'rt Runtime, layers: Vec<LayerPlan>) -> Self {
        NetworkScheduler { rt, layers, rng: Rng::new(0x5EED) }
    }

    pub fn layers(&self) -> &[LayerPlan] {
        &self.layers
    }

    /// Verify every required artifact exists before running (fail fast —
    /// a half-benchmarked network is worse than an error).
    pub fn check_artifacts(&self, passes: &[Pass]) -> Result<()> {
        for l in &self.layers {
            for pass in passes {
                let name = l.artifact(*pass);
                if self.rt.manifest().get(&name).is_none() {
                    bail!("missing artifact {name}; re-run `make artifacts`");
                }
            }
        }
        Ok(())
    }

    /// Pre-compile all executables (excluded from timed runs).
    pub fn warm(&self, passes: &[Pass]) -> Result<()> {
        for l in &self.layers {
            for pass in passes {
                self.rt.executable(&l.artifact(*pass))?;
            }
        }
        Ok(())
    }

    /// Forward pass through the whole stack. Each layer consumes the
    /// previous layer's activation when shapes chain (they do for the
    /// CNN tables after pooling is folded into the specs as input sizes);
    /// otherwise a fresh synthetic activation of the right shape is drawn
    /// — timing is what Table 3 measures, not semantics.
    pub fn fprop(&mut self) -> Result<PassTimings> {
        let mut t = PassTimings::default();
        let mut carry: Option<(Vec<f32>, Vec<usize>)> = None;
        for l in &self.layers {
            let p = &l.problem;
            let in_shape = vec![p.s, p.f, p.h, p.w];
            let x = match carry.take() {
                Some((data, shape)) if shape == in_shape => data,
                _ => self.rng.normal_vec(p.input_len()),
            };
            let wei = self.rng.normal_vec(p.weight_len());
            let t0 = Instant::now();
            let (out, out_shape) = self.rt.execute_1f32(
                &l.artifact(Pass::Fprop),
                &[HostTensor::f32(x, &in_shape),
                  HostTensor::f32(wei, &[p.fo, p.f, p.kh, p.kw])])?;
            t.per_layer.push((l.spec.clone(), t0.elapsed()));
            carry = Some((out, out_shape));
        }
        Ok(t)
    }

    /// Gradient passes, reverse layer order (bprop chains gradients;
    /// accGrad consumes the same gradient plus a synthetic activation).
    pub fn backward(&mut self, pass: Pass) -> Result<PassTimings> {
        assert!(matches!(pass, Pass::Bprop | Pass::AccGrad));
        let mut t = PassTimings::default();
        let mut carry: Option<(Vec<f32>, Vec<usize>)> = None;
        for l in self.layers.iter().rev() {
            let p = &l.problem;
            // strided vendor-only layers skip FFT gradient artifacts when
            // absent (the paper's Table 3 runs conv1 through cuDNN too)
            let name = l.artifact(pass);
            if self.rt.manifest().get(&name).is_none() {
                bail!("missing artifact {name}");
            }
            let go_shape = vec![p.s, p.fo, p.yh(), p.yw()];
            let go = match carry.take() {
                Some((d, s)) if s == go_shape => d,
                _ => self.rng.normal_vec(p.output_len()),
            };
            let (second, second_shape) = match pass {
                Pass::Bprop => (self.rng.normal_vec(p.weight_len()),
                                vec![p.fo, p.f, p.kh, p.kw]),
                _ => (self.rng.normal_vec(p.input_len()),
                      vec![p.s, p.f, p.h, p.w]),
            };
            let t0 = Instant::now();
            let (out, out_shape) = self.rt.execute_1f32(
                &name,
                &[HostTensor::f32(go, &go_shape),
                  HostTensor::f32(second, &second_shape)])?;
            t.per_layer.push((l.spec.clone(), t0.elapsed()));
            if pass == Pass::Bprop {
                // gradient w.r.t. input feeds the next (shallower) layer
                carry = Some((out, out_shape));
            }
        }
        t.per_layer.reverse();
        Ok(t)
    }

    /// Run all three passes and return (fprop, bprop, accgrad) timings —
    /// one Table-3 row group.
    pub fn run_all(&mut self) -> Result<(PassTimings, PassTimings,
                                         PassTimings)> {
        let f = self.fprop()?;
        let b = self.backward(Pass::Bprop)?;
        let a = self.backward(Pass::AccGrad)?;
        Ok((f, b, a))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_plan_names_match_manifest_convention() {
        let l = LayerPlan {
            spec: "alexnet.conv2@_8".into(),
            problem: ConvProblem::square(4, 8, 24, 31, 5),
            strategy: Strategy::Fbfft,
        };
        assert_eq!(l.artifact(Pass::Fprop),
                   "conv.alexnet.conv2@_8.fbfft.fprop");
        assert_eq!(l.artifact(Pass::AccGrad),
                   "conv.alexnet.conv2@_8.fbfft.accgrad");
    }

    #[test]
    fn tuned_plan_maps_host_strategies_to_artifacts() {
        use crate::coordinator::autotuner::StrategyCache;
        let cache = StrategyCache::open(None);
        // never-tuned shape → vendor fallback
        let p = ConvProblem::square(2, 2, 2, 9, 3);
        let plan = LayerPlan::tuned("l0", p, &cache, Pass::Fprop);
        assert_eq!(plan.strategy, Strategy::Vendor);
        // a tuned host-only winner maps onto its artifact family
        let c = cache.ensure(&p, Pass::Fprop);
        let plan = LayerPlan::tuned("l0", p, &cache, Pass::Fprop);
        assert_eq!(plan.strategy, c.strategy.artifact_equivalent());
        assert!(plan.strategy.supports_stride(p.stride));
        // strided layers stay vendor regardless of the cache
        let mut q = p;
        q.stride = 2;
        let plan = LayerPlan::tuned("l1", q, &cache, Pass::Fprop);
        assert_eq!(plan.strategy, Strategy::Vendor);
    }

    #[test]
    fn pass_timings_total() {
        let t = PassTimings {
            per_layer: vec![
                ("a".into(), Duration::from_millis(2)),
                ("b".into(), Duration::from_millis(3)),
            ],
        };
        assert_eq!(t.total(), Duration::from_millis(5));
    }
}
