//! Bulk-synchronous whole-CNN execution through cached PJRT executables —
//! the harness behind Table 3 (AlexNet / OverFeat-fast totals).
//!
//! A network is an ordered list of [`LayerPlan`]s. For each pass the
//! scheduler walks the layers (forward order for fprop, reverse for the
//! gradients, matching real training), feeds activations through the
//! buffer pool's single-copy roles, and accumulates per-layer timings.
//! 'This behavior is tailored for a bulk synchronous execution of layers
//! on a GPU' (§3.3) — here, of PJRT executables on the CPU client.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::conv::ConvProblem;
use crate::runtime::{HostTensor, Runtime};
use crate::util::Rng;

use super::autotuner::StrategyCache;
use super::strategy::{artifact_name, Pass, Strategy};

/// One layer's execution plan: which artifact serves each pass.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    /// spec name as used in artifact names (e.g. "alexnet.conv2@_8")
    pub spec: String,
    pub problem: ConvProblem,
    pub strategy: Strategy,
}

impl LayerPlan {
    pub fn artifact(&self, pass: Pass) -> String {
        artifact_name(&self.spec, self.strategy, pass)
    }

    /// Build a plan from the persistent strategy cache: the tuned winner
    /// for `pass`, mapped onto its artifact-backed equivalent (strided
    /// layers and never-tuned shapes fall back to the vendor black box —
    /// the same conv1 treatment as the paper's Table 3).
    pub fn tuned(spec: impl Into<String>, problem: ConvProblem,
                 cache: &StrategyCache, pass: Pass) -> LayerPlan {
        let strategy = cache
            .lookup(&problem, pass)
            .map(|c| c.strategy.artifact_equivalent())
            .filter(|s| s.supports_stride(problem.stride))
            .unwrap_or(Strategy::Vendor);
        LayerPlan { spec: spec.into(), problem, strategy }
    }
}

/// One chain position of a [`NetPlan`]: a display name for reports
/// plus the layer's convolution shape at the plan's nominal batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetLayer {
    pub name: String,
    pub problem: ConvProblem,
}

impl NetLayer {
    pub fn new(name: impl Into<String>, problem: ConvProblem)
               -> NetLayer {
        NetLayer { name: name.into(), problem }
    }
}

/// An ordered whole-CNN serving plan: the net-level counterpart of a
/// single `ConvProblem`. Shard workers execute the chain in order,
/// feeding each layer's output `(s, fo, yh, yw)` to the next layer's
/// input `(s, f, h, w)` through pooled activation slabs; admission
/// prices a request against the *sum* of the layers' cached launch
/// estimates ([`NetPlan::estimate`]), so one accepted deadline covers
/// the request's full trip through the stack — the regime the paper's
/// Table 3/4 whole-CNN totals actually measure.
///
/// Construction validates the chain: every adjacent pair must agree on
/// batch size and on output→input shape, so a mis-specified net fails
/// at plan time, not mid-flush.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetPlan {
    layers: Vec<NetLayer>,
}

impl NetPlan {
    pub fn new(layers: Vec<NetLayer>) -> Result<NetPlan, String> {
        if layers.is_empty() {
            return Err("a NetPlan needs at least one layer".into());
        }
        for w in layers.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            let (p, q) = (&a.problem, &b.problem);
            if p.s != q.s {
                return Err(format!(
                    "batch mismatch {} -> {}: {} vs {}",
                    a.name, b.name, p.s, q.s));
            }
            if p.fo != q.f || p.yh() != q.h || p.yw() != q.w {
                return Err(format!(
                    "shape break {} -> {}: output [{}, {}, {}, {}] \
                     does not feed input [{}, {}, {}, {}]",
                    a.name, b.name, p.s, p.fo, p.yh(), p.yw(),
                    q.s, q.f, q.h, q.w));
            }
        }
        Ok(NetPlan { layers })
    }

    /// The 1-layer degenerate plan — exactly the pre-net serving
    /// behavior, used by `start_host`/`start_pjrt` shims.
    pub fn single(problem: ConvProblem) -> NetPlan {
        NetPlan {
            layers: vec![NetLayer::new("conv", problem)],
        }
    }

    /// A 5-layer AlexNet-style stride-1 chain at batch `s` (Table-4
    /// shape progression scaled to host-executable sizes: 5×5 stem,
    /// 3×3 tail, channels 3→8→12→12→12→8 over 32²→18² spatial).
    pub fn alexnet(s: usize) -> NetPlan {
        let l = |name: &str, f, fo, n, k| {
            NetLayer::new(name, ConvProblem::square(s, f, fo, n, k))
        };
        NetPlan::new(vec![
            l("conv1", 3, 8, 32, 5),
            l("conv2", 8, 12, 28, 5),
            l("conv3", 12, 12, 24, 3),
            l("conv4", 12, 12, 22, 3),
            l("conv5", 12, 8, 20, 3),
        ])
        .expect("alexnet chain is shape-consistent")
    }

    /// The smoke-sized 3-layer chain (CI's default `--net` workload):
    /// same chained-3×3 structure, ~20k MACs per image.
    pub fn alexnet_small(s: usize) -> NetPlan {
        let l = |name: &str, f, fo, n, k| {
            NetLayer::new(name, ConvProblem::square(s, f, fo, n, k))
        };
        NetPlan::new(vec![
            l("conv1", 2, 4, 12, 3),
            l("conv2", 4, 4, 10, 3),
            l("conv3", 4, 2, 8, 3),
        ])
        .expect("alexnet_small chain is shape-consistent")
    }

    pub fn layers(&self) -> &[NetLayer] {
        &self.layers
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// The plan's nominal batch size (every layer agrees by
    /// construction).
    pub fn batch(&self) -> usize {
        self.layers[0].problem.s
    }

    /// First layer's input slab length at `imgs` images.
    pub fn input_len(&self, imgs: usize) -> usize {
        let p = &self.layers[0].problem;
        ConvProblem { s: imgs, ..*p }.input_len()
    }

    /// Last layer's output slab length at `imgs` images.
    pub fn output_len(&self, imgs: usize) -> usize {
        let p = &self.layers[self.layers.len() - 1].problem;
        ConvProblem { s: imgs, ..*p }.output_len()
    }

    /// Admission price of one `imgs`-image trip through the whole
    /// chain: the sum of each layer's cached launch estimate at that
    /// flush shape. Untuned layers price as zero (optimistic admission
    /// — the same contract the single-problem engine always had).
    pub fn estimate(&self, cache: &StrategyCache, pass: Pass,
                    imgs: usize) -> Duration {
        self.layers
            .iter()
            .map(|l| {
                let q = ConvProblem { s: imgs, ..l.problem };
                cache
                    .lookup(&q, pass)
                    .map(|c| Duration::from_secs_f64(c.seconds))
                    .unwrap_or(Duration::ZERO)
            })
            .sum()
    }
}

/// Per-layer, per-pass wall-clock (the Table-3 rows).
#[derive(Clone, Debug, Default)]
pub struct PassTimings {
    pub per_layer: Vec<(String, Duration)>,
}

impl PassTimings {
    pub fn total(&self) -> Duration {
        self.per_layer.iter().map(|(_, d)| *d).sum()
    }
}

pub struct NetworkScheduler<'rt> {
    rt: &'rt Runtime,
    layers: Vec<LayerPlan>,
    rng: Rng,
}

impl<'rt> NetworkScheduler<'rt> {
    pub fn new(rt: &'rt Runtime, layers: Vec<LayerPlan>) -> Self {
        NetworkScheduler { rt, layers, rng: Rng::new(0x5EED) }
    }

    pub fn layers(&self) -> &[LayerPlan] {
        &self.layers
    }

    /// Verify every required artifact exists before running (fail fast —
    /// a half-benchmarked network is worse than an error).
    pub fn check_artifacts(&self, passes: &[Pass]) -> Result<()> {
        for l in &self.layers {
            for pass in passes {
                let name = l.artifact(*pass);
                if self.rt.manifest().get(&name).is_none() {
                    bail!("missing artifact {name}; re-run `make artifacts`");
                }
            }
        }
        Ok(())
    }

    /// Pre-compile all executables (excluded from timed runs).
    pub fn warm(&self, passes: &[Pass]) -> Result<()> {
        for l in &self.layers {
            for pass in passes {
                self.rt.executable(&l.artifact(*pass))?;
            }
        }
        Ok(())
    }

    /// Forward pass through the whole stack. Each layer consumes the
    /// previous layer's activation when shapes chain (they do for the
    /// CNN tables after pooling is folded into the specs as input sizes);
    /// otherwise a fresh synthetic activation of the right shape is drawn
    /// — timing is what Table 3 measures, not semantics.
    pub fn fprop(&mut self) -> Result<PassTimings> {
        let mut t = PassTimings::default();
        let mut carry: Option<(Vec<f32>, Vec<usize>)> = None;
        for l in &self.layers {
            let p = &l.problem;
            let in_shape = vec![p.s, p.f, p.h, p.w];
            let x = match carry.take() {
                Some((data, shape)) if shape == in_shape => data,
                _ => self.rng.normal_vec(p.input_len()),
            };
            let wei = self.rng.normal_vec(p.weight_len());
            let t0 = Instant::now();
            let (out, out_shape) = self.rt.execute_1f32(
                &l.artifact(Pass::Fprop),
                &[HostTensor::f32(x, &in_shape),
                  HostTensor::f32(wei, &[p.fo, p.f, p.kh, p.kw])])?;
            t.per_layer.push((l.spec.clone(), t0.elapsed()));
            carry = Some((out, out_shape));
        }
        Ok(t)
    }

    /// Gradient passes, reverse layer order (bprop chains gradients;
    /// accGrad consumes the same gradient plus a synthetic activation).
    pub fn backward(&mut self, pass: Pass) -> Result<PassTimings> {
        assert!(matches!(pass, Pass::Bprop | Pass::AccGrad));
        let mut t = PassTimings::default();
        let mut carry: Option<(Vec<f32>, Vec<usize>)> = None;
        for l in self.layers.iter().rev() {
            let p = &l.problem;
            // strided vendor-only layers skip FFT gradient artifacts when
            // absent (the paper's Table 3 runs conv1 through cuDNN too)
            let name = l.artifact(pass);
            if self.rt.manifest().get(&name).is_none() {
                bail!("missing artifact {name}");
            }
            let go_shape = vec![p.s, p.fo, p.yh(), p.yw()];
            let go = match carry.take() {
                Some((d, s)) if s == go_shape => d,
                _ => self.rng.normal_vec(p.output_len()),
            };
            let (second, second_shape) = match pass {
                Pass::Bprop => (self.rng.normal_vec(p.weight_len()),
                                vec![p.fo, p.f, p.kh, p.kw]),
                _ => (self.rng.normal_vec(p.input_len()),
                      vec![p.s, p.f, p.h, p.w]),
            };
            let t0 = Instant::now();
            let (out, out_shape) = self.rt.execute_1f32(
                &name,
                &[HostTensor::f32(go, &go_shape),
                  HostTensor::f32(second, &second_shape)])?;
            t.per_layer.push((l.spec.clone(), t0.elapsed()));
            if pass == Pass::Bprop {
                // gradient w.r.t. input feeds the next (shallower) layer
                carry = Some((out, out_shape));
            }
        }
        t.per_layer.reverse();
        Ok(t)
    }

    /// Run all three passes and return (fprop, bprop, accgrad) timings —
    /// one Table-3 row group.
    pub fn run_all(&mut self) -> Result<(PassTimings, PassTimings,
                                         PassTimings)> {
        let f = self.fprop()?;
        let b = self.backward(Pass::Bprop)?;
        let a = self.backward(Pass::AccGrad)?;
        Ok((f, b, a))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_plan_names_match_manifest_convention() {
        let l = LayerPlan {
            spec: "alexnet.conv2@_8".into(),
            problem: ConvProblem::square(4, 8, 24, 31, 5),
            strategy: Strategy::Fbfft,
        };
        assert_eq!(l.artifact(Pass::Fprop),
                   "conv.alexnet.conv2@_8.fbfft.fprop");
        assert_eq!(l.artifact(Pass::AccGrad),
                   "conv.alexnet.conv2@_8.fbfft.accgrad");
    }

    #[test]
    fn tuned_plan_maps_host_strategies_to_artifacts() {
        use crate::coordinator::autotuner::StrategyCache;
        let cache = StrategyCache::open(None);
        // never-tuned shape → vendor fallback
        let p = ConvProblem::square(2, 2, 2, 9, 3);
        let plan = LayerPlan::tuned("l0", p, &cache, Pass::Fprop);
        assert_eq!(plan.strategy, Strategy::Vendor);
        // a tuned host-only winner maps onto its artifact family
        let c = cache.ensure(&p, Pass::Fprop);
        let plan = LayerPlan::tuned("l0", p, &cache, Pass::Fprop);
        assert_eq!(plan.strategy, c.strategy.artifact_equivalent());
        assert!(plan.strategy.supports_stride(p.stride));
        // strided layers stay vendor regardless of the cache
        let mut q = p;
        q.stride = 2;
        let plan = LayerPlan::tuned("l1", q, &cache, Pass::Fprop);
        assert_eq!(plan.strategy, Strategy::Vendor);
    }

    #[test]
    fn net_plan_validates_the_chain() {
        // the shipped chains are consistent
        for plan in [NetPlan::alexnet(4), NetPlan::alexnet_small(4)] {
            assert!(plan.len() >= 3);
            assert_eq!(plan.batch(), 4);
            for w in plan.layers().windows(2) {
                let (p, q) = (&w[0].problem, &w[1].problem);
                assert_eq!((p.fo, p.yh(), p.yw()), (q.f, q.h, q.w));
            }
        }
        // a broken chain names the offending pair
        let err = NetPlan::new(vec![
            NetLayer::new("a", ConvProblem::square(2, 2, 4, 8, 3)),
            NetLayer::new("b", ConvProblem::square(2, 4, 2, 9, 3)),
        ])
        .unwrap_err();
        assert!(err.contains("a -> b"), "{err}");
        // batch mismatch is its own error
        let err = NetPlan::new(vec![
            NetLayer::new("a", ConvProblem::square(2, 2, 4, 8, 3)),
            NetLayer::new("b", ConvProblem::square(3, 4, 2, 6, 3)),
        ])
        .unwrap_err();
        assert!(err.contains("batch mismatch"), "{err}");
        assert!(NetPlan::new(vec![]).is_err());
    }

    #[test]
    fn net_plan_estimate_sums_cached_layer_costs() {
        use crate::coordinator::autotuner::StrategyCache;
        let cache = StrategyCache::open(None);
        let plan = NetPlan::alexnet_small(4);
        assert_eq!(plan.estimate(&cache, Pass::Fprop, 4),
                   Duration::ZERO,
                   "untuned layers price as zero");
        for l in plan.layers() {
            cache.observe(&l.problem, Pass::Fprop, Strategy::Direct,
                          0.010);
        }
        let est = plan.estimate(&cache, Pass::Fprop, 4);
        let want = Duration::from_millis(10) * plan.len() as u32;
        assert!(est >= want - Duration::from_millis(1)
                    && est <= want + Duration::from_millis(1),
                "estimate {est:?} should sum the per-layer 10ms \
                 observations, want ~{want:?}");
    }

    #[test]
    fn pass_timings_total() {
        let t = PassTimings {
            per_layer: vec![
                ("a".into(), Duration::from_millis(2)),
                ("b".into(), Duration::from_millis(3)),
            ],
        };
        assert_eq!(t.total(), Duration::from_millis(5));
    }
}
