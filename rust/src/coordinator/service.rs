//! The serving layer: a sharded multi-worker engine behind a
//! deadline-aware dynamic batcher.
//!
//! Architecture (tokio is unavailable offline; std threads + channels
//! implement the same event loop):
//!
//! ```text
//!        submit_images() -> Ticket     mpsc            worker pool
//!  clients ──────────▶ admission ───────────▶ shard 0 [packer thread | Batcher|Workspace|BufferPool|Runtime?]
//!            Σ layer    │ least-loaded        shard 1 [packer thread | Batcher|Workspace|BufferPool|Runtime?]
//!            estimates  │ routing      ···    shard N [packer thread | Batcher|Workspace|BufferPool|Runtime?]
//!                       ▼
//!              StrategyCache (shared, persistent JSON)
//! ```
//!
//! * **Net-level plans** ([`NetPlan`]): an engine serves an ordered
//!   chain of per-layer [`ConvProblem`]s, not one shape. Every flushed
//!   batch makes the whole trip — layer *i*'s output slab feeds layer
//!   *i+1*'s input through pooled activation roles (`serve.act0` /
//!   `serve.act1` ping-pong, zero steady-state allocation) — so one
//!   admission decision covers the regime the paper's Table 3/4
//!   whole-CNN totals actually measure. The 1-layer plan
//!   ([`NetPlan::single`]) is exactly the old behavior.
//! * **Admission** ([`EngineClient::submit_images`] → [`Ticket`], or
//!   the raw [`EngineClient::submit`]): requests carry an SLA deadline
//!   (or inherit the engine default). A request whose deadline cannot
//!   cover the *sum* of the chain's cached per-layer launch estimates
//!   is rejected up front ([`ServeFailure::DeadlineUnmeetable`],
//!   `rejected_deadline` in the report) instead of wasting a batch
//!   slot; accepted requests go to the live shard with the fewest
//!   queued images (round-robin tie-break).
//! * **Workers, split into submit/complete halves**: each shard is one
//!   `std::thread` owning its own [`Batcher`], [`Workspace`], staging
//!   [`BufferPool`], per-layer weights (§3.3 buffered copies) and
//!   per-layer spectrum caches ([`LayerSpectra`]), and — in PJRT mode —
//!   its own [`Runtime`]. A companion **packer thread** fills batch
//!   *k+1*'s synthetic payload slab while the worker runs batch *k*'s
//!   layer chain (two slabs rotate); the hidden host-side packing time
//!   is the report's `pack_overlap` counter. An idle worker parks on
//!   its channel *indefinitely*; only a non-empty batcher arms
//!   `recv_timeout` with the earliest flush-by deadline.
//! * **Strategy cache** ([`StrategyCache`]): every flush of `b` images
//!   runs layer `l` as the problem `{s: b, ..l}`; the worker looks each
//!   shape up and runs the best known [`Strategy`] — the §3.4 tuner
//!   populates the cache once per shape (persisted as JSON, warm-loaded
//!   at startup) so the steady-state hot path never re-tunes.
//! * **Metrics**: per-shard *and per-layer* latency [`Histogram`]s,
//!   batch-fill ratio, SLA misses and flush counters, merged into the
//!   aggregate view by [`EngineReport`] and rendered by
//!   [`reports::serve`](crate::reports::serve) (schema v4: per-layer
//!   rows + end-to-end `states_per_sec`).
//! * **Supervision**: every flush runs under `catch_unwind`. A panic
//!   fails the in-flight batch with error [`Completion`]s (exactly-once
//!   is preserved — a hung client is worse than a served error) carrying
//!   [`ServeFailure::ShardPanic`] *with the chain position that blew up*
//!   (`layer: Some(i)` for a mid-chain panic), is recorded in the
//!   shared [`ShardHealth`] table, and the shard rebuilds its
//!   flush-local state (workspace, staging pool, spectrum entries) with
//!   exponential backoff. A shard that keeps flapping trips a circuit
//!   breaker: it is marked dead, admission re-routes to the survivors,
//!   and the dead shard drains its channel as a dead-letter queue so
//!   racing submissions fail fast instead of hanging. Degradation
//!   ladder for bad *outputs* (PJRT launch errors, non-finite frequency
//!   results): the offending layer demotes to the direct fallback for a
//!   cooldown window via [`StrategyCache::demote`], failing/degrading
//!   exactly the in-flight batch. Faults are injectable
//!   deterministically through a [`FaultPlan`] (`FBFFT_FAULTS`,
//!   `[shard<i>:][layer<j>:]kind@occ`) for chaos tests.
//!
//! The single-shard PJRT use case is `ServeEngine::start_pjrt` (or
//! `Backend::Pjrt` + `NetPlan::single` + `EngineConfig::builder()`)
//! — the old `ConvService` wrapper is gone.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::conv::{direct, im2col, tiled, ConvProblem, FftConvEngine,
                  FftMode, LayerSpectra, SpectrumCache, SpectrumPrecision,
                  Workspace};
use crate::metrics::Histogram;
use crate::runtime::{HostTensor, Runtime};
use crate::testkit::faults::{FaultKind, FaultPlan};
use crate::util::Rng;

use super::autotuner::{CacheStats, Choice, StrategyCache};
use super::batcher::{Batch, Batcher, BatcherConfig};
use super::buffers::BufferPool;
use super::scheduler::NetPlan;
use super::strategy::{Pass, Strategy};

/// A conv inference request: `images` samples for the served layer.
pub struct ServeRequest {
    pub id: u64,
    pub images: usize,
    /// SLA deadline for the reply; `None` inherits the engine default.
    pub deadline: Option<Instant>,
    /// sent back exactly once, when every image has been served
    pub reply: Sender<Completion>,
}

#[derive(Clone, Copy, Debug)]
pub struct Completion {
    pub id: u64,
    pub images: usize,
    pub latency: Duration,
    /// images in the last flushed batch this request rode in (0 when
    /// the request failed — it never rode a completed batch)
    pub batch_images: usize,
    /// which shard served the request
    pub shard: usize,
    /// whether the reply beat the request's SLA deadline
    pub deadline_met: bool,
    /// `Some` when the request was *failed* rather than served — the
    /// shard panicked with the request in flight, or was circuit-broken
    /// with it still queued. Exactly-once still holds: a failed request
    /// gets exactly one completion, carrying the error.
    pub error: Option<ServeFailure>,
}

/// The single error vocabulary of the serving tier, split along the
/// request lifecycle:
///
/// * **Admission failures** — returned as `Err` by
///   [`EngineClient::submit`] / [`submit_images`]
///   (`EngineClient::submit_images`): *nothing was enqueued* and no
///   completion will ever arrive. Variants:
///   [`DeadlineUnmeetable`](ServeFailure::DeadlineUnmeetable),
///   [`Unavailable`](ServeFailure::Unavailable).
/// * **Completion failures** — delivered inside the request's exactly
///   one [`Completion`] (its `error` field): the request was accepted
///   but could not be served. Variants:
///   [`ShardPanic`](ServeFailure::ShardPanic),
///   [`ShardUnavailable`](ServeFailure::ShardUnavailable).
///
/// One enum (rather than the historical `SubmitError`/`ServeError`
/// pair) means callers match a single vocabulary and `?` works across
/// both halves; the lifecycle split is documented per variant instead
/// of encoded in the type system.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeFailure {
    /// Admission: the deadline cannot cover the sum of the chain's
    /// cached per-layer launch estimates.
    DeadlineUnmeetable,
    /// Admission: no live shard exists to take the request (every
    /// shard dead).
    Unavailable,
    /// Completion: the owning shard panicked with the request's batch
    /// in flight. `layer` is the chain position that was executing
    /// (`None` when the panic hit outside the layer chain — e.g. a
    /// flush-level injected panic or a staging checkout).
    ShardPanic { layer: Option<usize> },
    /// Completion: the owning shard was circuit-broken (dead) with the
    /// request queued behind the break.
    ShardUnavailable,
}

impl std::fmt::Display for ServeFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeFailure::DeadlineUnmeetable => {
                write!(f, "deadline unmeetable")
            }
            ServeFailure::Unavailable => write!(f, "no live shard"),
            ServeFailure::ShardPanic { layer: Some(i) } => {
                write!(f, "shard panicked at layer {i}")
            }
            ServeFailure::ShardPanic { layer: None } => {
                write!(f, "shard panicked")
            }
            ServeFailure::ShardUnavailable => {
                write!(f, "shard unavailable")
            }
        }
    }
}

impl std::error::Error for ServeFailure {}

/// Live health of one shard, shared between its worker (writer) and
/// every [`EngineClient`] (readers routing around dead shards).
#[derive(Debug)]
pub struct ShardHealth {
    alive: AtomicBool,
    restarts: AtomicUsize,
    consecutive_failures: AtomicUsize,
    last_error: Mutex<Option<String>>,
}

impl Default for ShardHealth {
    fn default() -> Self {
        ShardHealth {
            alive: AtomicBool::new(true),
            restarts: AtomicUsize::new(0),
            consecutive_failures: AtomicUsize::new(0),
            last_error: Mutex::new(None),
        }
    }
}

impl ShardHealth {
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Acquire)
    }

    /// Supervised restarts so far (rebuild-after-panic events).
    pub fn restarts(&self) -> usize {
        self.restarts.load(Ordering::Relaxed)
    }

    pub fn consecutive_failures(&self) -> usize {
        self.consecutive_failures.load(Ordering::Relaxed)
    }

    pub fn last_error(&self) -> Option<String> {
        self.last_error
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    fn mark_dead(&self) {
        self.alive.store(false, Ordering::Release);
    }

    /// Record one flush failure; returns the new consecutive count.
    fn record_failure(&self, msg: &str) -> usize {
        *self.last_error.lock().unwrap_or_else(|e| e.into_inner()) =
            Some(msg.to_string());
        self.consecutive_failures.fetch_add(1, Ordering::Relaxed) + 1
    }

    fn record_restart(&self) {
        self.restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// A clean flush resets the flap counter (the breaker only trips on
    /// *consecutive* failures).
    fn record_success(&self) {
        self.consecutive_failures.store(0, Ordering::Relaxed);
    }
}

/// How the worker pool executes a flushed batch — the first argument
/// of the one public entry point, [`ServeEngine::start`].
#[derive(Clone, Debug)]
pub enum Backend {
    /// In-tree host engines dispatched through the strategy cache.
    Host,
    /// One PJRT runtime per worker, serving a fixed AOT artifact
    /// (single-layer plans only).
    Pjrt { dir: PathBuf, artifact: String },
}

/// Engine-wide configuration (per-shard knobs live in [`BatcherConfig`]).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// worker-pool width (N shards, one OS thread each)
    pub shards: usize,
    pub batcher: BatcherConfig,
    /// SLA budget applied to requests that carry no explicit deadline
    pub default_deadline: Duration,
    /// which training pass the engine serves (fprop for inference)
    pub pass: Pass,
    /// strategy-cache warm-load/persist location (`None` = in-memory)
    pub tuner_path: Option<PathBuf>,
    /// measurement repetitions when a flush shape misses the cache
    pub tuner_reps: usize,
    /// tune the {1, capacity}-image shapes before accepting traffic
    pub warm: bool,
    /// storage precision of the per-shard weight-spectrum cache
    /// (default: f16 unless `FBFFT_SPECTRA=f32`)
    pub spectra: SpectrumPrecision,
    /// bypass the tuner and serve every flush with this strategy —
    /// the deterministic-probe escape hatch (bench smoke, CI gates)
    pub force_strategy: Option<Strategy>,
    /// base sleep before a supervised shard rebuild; doubles per
    /// consecutive failure (capped at 500ms)
    pub restart_backoff: Duration,
    /// consecutive flush failures that trip the circuit breaker and
    /// mark the shard dead
    pub max_consecutive_failures: usize,
    /// how long a problem stays demoted to the direct fallback after a
    /// PJRT error or non-finite frequency output
    pub degrade_cooldown: Duration,
    /// deterministic fault script for chaos tests; `None` falls back to
    /// `FBFFT_FAULTS` in the environment (unset = no faults)
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            shards: 4,
            batcher: BatcherConfig::default(),
            default_deadline: Duration::from_secs(1),
            pass: Pass::Fprop,
            tuner_path: None,
            tuner_reps: 1,
            warm: true,
            spectra: SpectrumPrecision::default(),
            force_strategy: None,
            restart_backoff: Duration::from_millis(10),
            max_consecutive_failures: 3,
            degrade_cooldown: Duration::from_secs(5),
            faults: None,
        }
    }
}

impl EngineConfig {
    /// A validating builder over the defaults — the config struct has
    /// grown a field per subsystem (batching, tuning, spectra,
    /// supervision, chaos), and literal structs kept copying stale
    /// values between the bench, the CLI and the tests. Every setter
    /// documents its default; [`EngineConfigBuilder::build`] rejects
    /// nonsensical values instead of letting a zero-shard engine limp
    /// into a worker panic.
    pub fn builder() -> EngineConfigBuilder {
        EngineConfigBuilder { cfg: EngineConfig::default() }
    }
}

/// Builder returned by [`EngineConfig::builder`].
#[derive(Clone, Debug)]
pub struct EngineConfigBuilder {
    cfg: EngineConfig,
}

impl EngineConfigBuilder {
    /// Worker-pool width. Default: 4.
    pub fn shards(mut self, n: usize) -> Self {
        self.cfg.shards = n;
        self
    }

    /// Per-shard batch capacity in images. Default:
    /// [`BatcherConfig::default`]'s capacity.
    pub fn capacity(mut self, images: usize) -> Self {
        self.cfg.batcher.capacity = images;
        self
    }

    /// Longest a queued request waits before a partial batch flushes.
    /// Default: [`BatcherConfig::default`]'s max_wait.
    pub fn max_wait(mut self, d: Duration) -> Self {
        self.cfg.batcher.max_wait = d;
        self
    }

    /// SLA applied to requests with no explicit deadline. Default: 1s.
    pub fn default_deadline(mut self, d: Duration) -> Self {
        self.cfg.default_deadline = d;
        self
    }

    /// Which training pass the engine serves. Default: fprop.
    /// Multi-layer plans serve fprop only (enforced at
    /// [`ServeEngine::start`]).
    pub fn pass(mut self, pass: Pass) -> Self {
        self.cfg.pass = pass;
        self
    }

    /// Strategy-cache warm-load/persist path. Default: `None`
    /// (in-memory only).
    pub fn tuner_path(mut self, path: impl Into<PathBuf>) -> Self {
        self.cfg.tuner_path = Some(path.into());
        self
    }

    /// Measurement repetitions on a tuner cache miss. Default: 1.
    pub fn tuner_reps(mut self, reps: usize) -> Self {
        self.cfg.tuner_reps = reps;
        self
    }

    /// Tune the {1, capacity}-image shapes of every layer before
    /// accepting traffic. Default: true.
    pub fn warm(mut self, warm: bool) -> Self {
        self.cfg.warm = warm;
        self
    }

    /// Storage precision of the per-shard weight-spectrum caches.
    /// Default: f16 unless `FBFFT_SPECTRA=f32`.
    pub fn spectra(mut self, precision: SpectrumPrecision) -> Self {
        self.cfg.spectra = precision;
        self
    }

    /// Bypass the tuner and serve every flush with this strategy (the
    /// deterministic-probe escape hatch). Default: `None`.
    pub fn force_strategy(mut self, strategy: Strategy) -> Self {
        self.cfg.force_strategy = Some(strategy);
        self
    }

    /// Base sleep before a supervised shard rebuild; doubles per
    /// consecutive failure, capped at 500ms. Default: 10ms.
    pub fn restart_backoff(mut self, d: Duration) -> Self {
        self.cfg.restart_backoff = d;
        self
    }

    /// Consecutive flush failures that trip the circuit breaker.
    /// Default: 3.
    pub fn max_consecutive_failures(mut self, n: usize) -> Self {
        self.cfg.max_consecutive_failures = n;
        self
    }

    /// How long a layer stays demoted to the direct fallback after a
    /// PJRT error or non-finite frequency output. Default: 5s.
    pub fn degrade_cooldown(mut self, d: Duration) -> Self {
        self.cfg.degrade_cooldown = d;
        self
    }

    /// Deterministic fault script for chaos tests. Default: `None`
    /// (falls back to `FBFFT_FAULTS` in the environment).
    pub fn faults(mut self, plan: Arc<FaultPlan>) -> Self {
        self.cfg.faults = Some(plan);
        self
    }

    /// Validate and produce the config. Errors name the offending
    /// knob: zero shards/capacity/reps, a zero breaker threshold, or a
    /// zero batching window would each wedge or panic the engine at
    /// runtime — fail here instead.
    pub fn build(self) -> Result<EngineConfig, String> {
        let c = &self.cfg;
        if c.shards == 0 {
            return Err("shards must be >= 1".into());
        }
        if c.batcher.capacity == 0 {
            return Err("capacity must be >= 1 image".into());
        }
        if c.batcher.max_wait == Duration::ZERO {
            return Err("max_wait must be nonzero".into());
        }
        if c.default_deadline == Duration::ZERO {
            return Err("default_deadline must be nonzero".into());
        }
        if c.tuner_reps == 0 {
            return Err("tuner_reps must be >= 1".into());
        }
        if c.max_consecutive_failures == 0 {
            return Err("max_consecutive_failures must be >= 1".into());
        }
        Ok(self.cfg)
    }
}

/// One accepted request on its way to a shard.
struct Accepted {
    id: u64,
    images: usize,
    enqueued: Instant,
    /// batcher flush-by deadline: `min(enqueued + max_wait, sla)`
    flush_by: Instant,
    /// the request's SLA deadline (reply-by)
    sla: Instant,
    reply: Sender<Completion>,
}

enum Msg {
    Req(Accepted),
    /// install a new weight tensor for chain position `layer` under
    /// `version`, invalidating exactly that layer's cached spectra
    Weights { layer: usize, version: u64, weights: Arc<Vec<f32>> },
    Shutdown,
}

/// Per-chain-position statistics inside a [`ShardReport`] (and, merged
/// across shards, the schema-v4 `per_layer` report rows).
#[derive(Clone, Debug, Default)]
pub struct LayerStats {
    /// layer name from the [`NetPlan`]
    pub name: String,
    /// per-flush wall-clock of this layer alone, seconds
    pub latency: Histogram,
    /// per-flush weight-FFT seconds (frequency launches; zero on
    /// spectrum hits)
    pub weight_fft: Histogram,
    pub spectra_hits: usize,
    pub spectra_misses: usize,
    pub spectra_invalidated: usize,
    /// flushes this layer served on the degraded (direct-fallback) rung
    pub degraded: usize,
    /// non-finite outputs / failed launches attributed to this layer
    pub launch_errors: usize,
}

impl LayerStats {
    fn named(name: &str) -> LayerStats {
        LayerStats { name: name.to_string(), ..Default::default() }
    }

    /// Fold another shard's stats for the same chain position in.
    pub fn merge(&mut self, other: &LayerStats) {
        self.latency.merge(&other.latency);
        self.weight_fft.merge(&other.weight_fft);
        self.spectra_hits += other.spectra_hits;
        self.spectra_misses += other.spectra_misses;
        self.spectra_invalidated += other.spectra_invalidated;
        self.degraded += other.degraded;
        self.launch_errors += other.launch_errors;
    }
}

/// Per-shard statistics returned by the worker at shutdown.
#[derive(Clone, Debug, Default)]
pub struct ShardReport {
    pub shard: usize,
    /// accepted requests routed here
    pub requests: usize,
    pub images: usize,
    pub launches: usize,
    pub busy: Duration,
    pub flushes_full: usize,
    pub flushes_timeout: usize,
    /// shutdown-path drains — `flushes_full + flushes_timeout +
    /// flushes_drain == launches` reconciles every batch
    pub flushes_drain: usize,
    /// weight-spectrum cache counters (tentpole: steady-state hits)
    pub spectra_hits: usize,
    pub spectra_misses: usize,
    pub spectra_invalidated: usize,
    /// per-flush weight-FFT seconds (frequency-strategy launches only;
    /// zero samples on spectrum hits — `sum`/`last` feed the report)
    pub weight_fft: Histogram,
    /// weights version the shard was serving at shutdown
    pub weights_version: u64,
    /// completions delivered after their SLA deadline
    pub sla_miss: usize,
    /// failed backend launches (their requests complete anyway — a
    /// hung client is worse than a served error)
    pub launch_errors: usize,
    /// requests that received a *success* completion — with
    /// `requests_failed` this extends the flush ledger to
    /// `completed + failed == requests` per shard
    pub requests_completed: usize,
    /// requests that received an *error* completion (shard panic or
    /// circuit break; still exactly one completion each)
    pub requests_failed: usize,
    /// supervised rebuilds after a flush panic
    pub restarts: usize,
    /// flushes served on the degraded (direct-fallback) rung of the
    /// ladder — demotion cooldowns and PJRT fallbacks
    pub degraded_flushes: usize,
    /// scripted faults this shard actually injected
    pub faults_injected: usize,
    /// the circuit breaker tripped: the shard died flapping and its
    /// traffic re-routed to the survivors
    pub circuit_broken: bool,
    /// message of the shard's most recent flush failure
    pub last_error: Option<String>,
    /// reply latency per completed request, seconds
    pub latency: Histogram,
    /// queued images sampled at each admission
    pub depth: Histogram,
    /// mean flushed-images / capacity over all launches
    pub batch_fill: f64,
    /// per-chain-position latency/spectra/degradation breakdown
    pub layers: Vec<LayerStats>,
    /// payload-packing time hidden behind layer execution by the
    /// submit/complete split (packer filled batch k+1 while the chain
    /// ran batch k) — `> 0` is the evidence the halves actually overlap
    pub pack_overlap: Duration,
    /// time the flush path stalled waiting on the packer (the
    /// non-overlapped remainder)
    pub pack_wait: Duration,
    /// staging-pool heap checkouts over the shard's whole life — the
    /// chained steady state allocates once per activation role and
    /// then only reuses (see `workspace_alloc.rs`); counters reset
    /// with the pool on a supervised restart
    pub stage_allocations: usize,
    pub stage_expansions: usize,
    pub stage_reuses: usize,
}

/// Aggregate view over all shards plus engine-level counters.
#[derive(Clone, Debug)]
pub struct EngineReport {
    pub shards: Vec<ShardReport>,
    /// requests refused at admission (deadline unmeetable)
    pub rejected_deadline: usize,
    /// requests refused at admission because no live shard existed
    pub rejected_unavailable: usize,
    /// scripted faults injected engine-wide (the [`FaultPlan`]'s own
    /// count — includes engine-level faults such as `corrupt_load`
    /// that no shard counter sees)
    pub faults_injected: usize,
    pub cache: CacheStats,
    pub capacity: usize,
    pub pass: Pass,
    /// the chain the engine served (layer names key the per-layer rows)
    pub net: NetPlan,
}

impl EngineReport {
    pub fn requests(&self) -> usize {
        self.shards.iter().map(|s| s.requests).sum()
    }

    pub fn images(&self) -> usize {
        self.shards.iter().map(|s| s.images).sum()
    }

    pub fn launches(&self) -> usize {
        self.shards.iter().map(|s| s.launches).sum()
    }

    pub fn busy(&self) -> Duration {
        self.shards.iter().map(|s| s.busy).sum()
    }

    pub fn flushes_full(&self) -> usize {
        self.shards.iter().map(|s| s.flushes_full).sum()
    }

    pub fn flushes_timeout(&self) -> usize {
        self.shards.iter().map(|s| s.flushes_timeout).sum()
    }

    pub fn flushes_drain(&self) -> usize {
        self.shards.iter().map(|s| s.flushes_drain).sum()
    }

    pub fn spectra_hits(&self) -> usize {
        self.shards.iter().map(|s| s.spectra_hits).sum()
    }

    pub fn spectra_misses(&self) -> usize {
        self.shards.iter().map(|s| s.spectra_misses).sum()
    }

    pub fn spectra_invalidated(&self) -> usize {
        self.shards.iter().map(|s| s.spectra_invalidated).sum()
    }

    /// Newest weights version any shard was serving (every shard
    /// converges to it once the bump broadcast drains).
    pub fn weights_version(&self) -> u64 {
        self.shards.iter().map(|s| s.weights_version).max().unwrap_or(0)
    }

    /// All shards' per-flush weight-FFT samples merged.
    pub fn weight_fft(&self) -> Histogram {
        let mut h = Histogram::new();
        for s in &self.shards {
            h.merge(&s.weight_fft);
        }
        h
    }

    pub fn sla_miss(&self) -> usize {
        self.shards.iter().map(|s| s.sla_miss).sum()
    }

    pub fn launch_errors(&self) -> usize {
        self.shards.iter().map(|s| s.launch_errors).sum()
    }

    pub fn requests_completed(&self) -> usize {
        self.shards.iter().map(|s| s.requests_completed).sum()
    }

    pub fn requests_failed(&self) -> usize {
        self.shards.iter().map(|s| s.requests_failed).sum()
    }

    pub fn shard_restarts(&self) -> usize {
        self.shards.iter().map(|s| s.restarts).sum()
    }

    pub fn degraded_flushes(&self) -> usize {
        self.shards.iter().map(|s| s.degraded_flushes).sum()
    }

    /// Shards whose circuit breaker tripped.
    pub fn circuit_broken(&self) -> usize {
        self.shards.iter().filter(|s| s.circuit_broken).count()
    }

    /// Packing time hidden behind layer execution, summed over shards.
    pub fn pack_overlap(&self) -> Duration {
        self.shards.iter().map(|s| s.pack_overlap).sum()
    }

    /// Flush-path stalls waiting on the packer, summed over shards.
    pub fn pack_wait(&self) -> Duration {
        self.shards.iter().map(|s| s.pack_wait).sum()
    }

    /// Staging-pool heap checkouts summed over shards (zero-alloc
    /// steady state: bounded by roles × shards, never by flushes).
    pub fn stage_allocations(&self) -> usize {
        self.shards.iter().map(|s| s.stage_allocations).sum()
    }

    pub fn stage_expansions(&self) -> usize {
        self.shards.iter().map(|s| s.stage_expansions).sum()
    }

    pub fn stage_reuses(&self) -> usize {
        self.shards.iter().map(|s| s.stage_reuses).sum()
    }

    /// Per-chain-position stats merged across shards (the schema-v4
    /// `per_layer` rows). Shards that died before reporting layer
    /// stats simply contribute nothing.
    pub fn layer_stats(&self) -> Vec<LayerStats> {
        let mut merged: Vec<LayerStats> = self
            .net
            .layers()
            .iter()
            .map(|l| LayerStats::named(&l.name))
            .collect();
        for s in &self.shards {
            for (i, ls) in s.layers.iter().enumerate() {
                if let Some(m) = merged.get_mut(i) {
                    m.merge(ls);
                }
            }
        }
        merged
    }

    /// All shards' latency samples merged (the aggregate percentiles).
    pub fn aggregate_latency(&self) -> Histogram {
        let mut h = Histogram::new();
        for s in &self.shards {
            h.merge(&s.latency);
        }
        h
    }

    /// Launch-weighted mean batch-fill ratio across shards.
    pub fn batch_fill(&self) -> f64 {
        let launches = self.launches();
        if launches == 0 {
            return 0.0;
        }
        self.shards
            .iter()
            .map(|s| s.batch_fill * s.launches as f64)
            .sum::<f64>()
            / launches as f64
    }
}

/// A pending reply handle returned by [`EngineClient::submit_images`]:
/// wraps the completion channel so callers stop hand-constructing
/// `Sender<Completion>` pairs.
///
/// The request resolves to exactly one [`Completion`] — success *or*
/// failure (a failed request's completion carries the
/// [`ServeFailure`] in its `error` field, so ledgers and latency are
/// still readable). [`Ticket::wait`] returns `Err` only when no
/// completion can ever arrive (the engine was torn down with the
/// ticket outstanding).
pub struct Ticket {
    id: u64,
    rx: Receiver<Completion>,
}

impl Ticket {
    /// The engine-assigned request id (matches `Completion::id`).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the request's completion arrives.
    /// `Err(ServeFailure::Unavailable)` when the engine was shut down
    /// with the ticket outstanding — otherwise the completion itself,
    /// whose `error` field reports per-request failures.
    pub fn wait(&self) -> std::result::Result<Completion, ServeFailure> {
        self.rx.recv().map_err(|_| ServeFailure::Unavailable)
    }

    /// Like [`Ticket::wait`] with a bound on the block.
    pub fn wait_timeout(&self, timeout: Duration)
                        -> std::result::Result<Completion, ServeFailure> {
        self.rx
            .recv_timeout(timeout)
            .map_err(|_| ServeFailure::Unavailable)
    }

    /// Non-blocking poll: `Some` once the completion has landed.
    pub fn try_wait(&self) -> Option<Completion> {
        self.rx.try_recv().ok()
    }
}

/// Cheap, cloneable submission handle — one per client thread. Holds
/// the shard senders, the shared depth gauges and the strategy cache;
/// admission runs entirely on the calling thread.
#[derive(Clone)]
pub struct EngineClient {
    txs: Vec<Sender<Msg>>,
    depths: Vec<Arc<AtomicUsize>>,
    health: Arc<Vec<ShardHealth>>,
    rejected: Arc<AtomicUsize>,
    rejected_unavailable: Arc<AtomicUsize>,
    rr: Arc<AtomicUsize>,
    seq: Arc<AtomicU64>,
    weights_versions: Arc<Vec<AtomicU64>>,
    cache: Arc<StrategyCache>,
    net: Arc<NetPlan>,
    pass: Pass,
    capacity: usize,
    default_deadline: Duration,
    max_wait: Duration,
}

impl EngineClient {
    /// Submit `images` samples for one trip through the whole chain and
    /// get a [`Ticket`] for the reply — the ergonomic form of
    /// [`EngineClient::submit`] (which remains public for callers that
    /// multiplex many requests onto one channel, like the bench's
    /// open-loop mode). `deadline: None` inherits the engine default.
    pub fn submit_images(&self, images: usize,
                         deadline: Option<Instant>)
                         -> std::result::Result<Ticket, ServeFailure> {
        let (tx, rx) = mpsc::channel();
        let id = self.seq.fetch_add(1, Ordering::Relaxed);
        self.submit(ServeRequest { id, images, deadline, reply: tx })?;
        Ok(Ticket { id, rx })
    }

    /// Admit (or reject) a request. `Err` — with nothing sent on
    /// `reply` — when the deadline cannot cover the summed cached
    /// launch estimates of the chain at the request's own flush shape
    /// ([`ServeFailure::DeadlineUnmeetable`]) or when every shard is
    /// dead ([`ServeFailure::Unavailable`]). Accepted requests are
    /// routed to the least-loaded *live* shard and receive exactly one
    /// [`Completion`] — success or error. Submissions must not race
    /// [`ServeEngine::shutdown`]: stop every client first (an accepted
    /// request whose send lands after the worker's final drain would be
    /// dropped).
    ///
    /// Panics on a zero-image request (same contract as
    /// [`Batcher::push`]) — asserting here keeps the panic on the
    /// caller's thread instead of poisoning a shard worker.
    pub fn submit(&self, req: ServeRequest)
                  -> std::result::Result<(), ServeFailure> {
        assert!(req.images >= 1, "empty request");
        let now = Instant::now();
        let sla = req.deadline.unwrap_or(now + self.default_deadline);
        let est = self.net.estimate(
            &self.cache, self.pass, req.images.min(self.capacity));
        if now + est > sla {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ServeFailure::DeadlineUnmeetable);
        }
        // least queued images among *live* shards wins; the start point
        // rotates so ties spread. A send that still fails (worker gone
        // without marking itself dead) marks the shard dead and retries
        // the survivors — the alive set shrinks, so this terminates.
        let images = req.images;
        let n = self.txs.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let mut msg = Msg::Req(Accepted {
            id: req.id,
            images,
            enqueued: now,
            flush_by: sla.min(now + self.max_wait),
            sla,
            reply: req.reply,
        });
        loop {
            let mut best: Option<usize> = None;
            let mut best_depth = usize::MAX;
            for i in 0..n {
                let s = (start + i) % n;
                if !self.health[s].is_alive() {
                    continue;
                }
                let d = self.depths[s].load(Ordering::Relaxed);
                if d < best_depth {
                    best = Some(s);
                    best_depth = d;
                }
            }
            let Some(best) = best else {
                self.rejected_unavailable.fetch_add(1, Ordering::Relaxed);
                return Err(ServeFailure::Unavailable);
            };
            self.depths[best].fetch_add(images, Ordering::Relaxed);
            match self.txs[best].send(msg) {
                Ok(()) => return Ok(()),
                Err(e) => {
                    self.depths[best].fetch_sub(images, Ordering::Relaxed);
                    self.health[best].mark_dead();
                    msg = e.0;
                }
            }
        }
    }

    /// Install a new weight tensor for chain position `layer` across
    /// every live shard, invalidating exactly that layer's cached
    /// spectra. The bump is zero-downtime: each worker applies it
    /// between flushes, so batches flushed before the message arrives
    /// ride the old version and every later flush serves (and
    /// re-transforms once, lazily) the new one. Returns the layer's new
    /// `weights_version`; `Err(Unavailable)` when no shard could take
    /// the bump.
    ///
    /// Panics when `layer` is out of range or `weights` does not match
    /// that layer's weight tensor (`fo·f·kh·kw` elements) — same
    /// caller-thread contract as [`EngineClient::submit`].
    pub fn update_layer_weights(&self, layer: usize, weights: Vec<f32>)
                                -> std::result::Result<u64, ServeFailure> {
        let layers = self.net.layers();
        assert!(layer < layers.len(), "layer {layer} out of range");
        assert_eq!(weights.len(), layers[layer].problem.weight_len(),
                   "weight tensor shape mismatch for layer {layer}");
        let version = self.weights_versions[layer]
            .fetch_add(1, Ordering::Relaxed)
            + 1;
        let shared = Arc::new(weights);
        let mut delivered = 0usize;
        for (s, tx) in self.txs.iter().enumerate() {
            let msg = Msg::Weights {
                layer,
                version,
                weights: shared.clone(),
            };
            if tx.send(msg).is_ok() {
                delivered += 1;
            } else {
                self.health[s].mark_dead();
            }
        }
        if delivered == 0 {
            return Err(ServeFailure::Unavailable);
        }
        Ok(version)
    }

    /// [`update_layer_weights`](EngineClient::update_layer_weights) for
    /// chain position 0 — the single-layer engine's historical surface.
    pub fn update_weights(&self, weights: Vec<f32>)
                          -> std::result::Result<u64, ServeFailure> {
        self.update_layer_weights(0, weights)
    }

    /// The version layer `layer`'s next flush-after-drain will serve
    /// (starts at 1).
    pub fn layer_weights_version(&self, layer: usize) -> u64 {
        self.weights_versions[layer].load(Ordering::Relaxed)
    }

    /// Layer 0's weights version (historical single-layer surface).
    pub fn weights_version(&self) -> u64 {
        self.layer_weights_version(0)
    }

    /// The chain this engine serves.
    pub fn net(&self) -> &NetPlan {
        &self.net
    }

    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Live per-shard health (alive flag, restart and failure counts).
    pub fn health(&self) -> &[ShardHealth] {
        &self.health
    }
}

/// Handle to the running sharded engine; `shutdown` flushes and joins.
pub struct ServeEngine {
    client: EngineClient,
    workers: Vec<JoinHandle<ShardReport>>,
    cache: Arc<StrategyCache>,
    faults: Option<Arc<FaultPlan>>,
}

struct WorkerCtx {
    shard: usize,
    backend: Backend,
    net: Arc<NetPlan>,
    pass: Pass,
    batcher_cfg: BatcherConfig,
    cache: Arc<StrategyCache>,
    spectra: SpectrumPrecision,
    force: Option<Strategy>,
    depth: Arc<AtomicUsize>,
    health: Arc<Vec<ShardHealth>>,
    faults: Option<Arc<FaultPlan>>,
    restart_backoff: Duration,
    max_consecutive_failures: usize,
    degrade_cooldown: Duration,
    rx: Receiver<Msg>,
    ready: Sender<std::result::Result<(), String>>,
}

impl ServeEngine {
    /// Serve a single conv layer with the in-tree host engines — the
    /// historical surface, now a [`NetPlan::single`] shim over
    /// [`ServeEngine::start`].
    pub fn start_host(problem: ConvProblem, cfg: EngineConfig)
                      -> Result<ServeEngine> {
        Self::start(Backend::Host, NetPlan::single(problem), cfg)
    }

    /// Serve a fixed single-layer AOT artifact — a shim over
    /// [`ServeEngine::start`] with `Backend::Pjrt`.
    pub fn start_pjrt(artifacts_dir: PathBuf, artifact: String,
                      problem: ConvProblem, cfg: EngineConfig)
                      -> Result<ServeEngine> {
        Self::start(Backend::Pjrt { dir: artifacts_dir, artifact },
                    NetPlan::single(problem), cfg)
    }

    /// The one entry point: serve `net` on `backend` under `cfg`.
    /// Host backends execute the whole chain per flush; PJRT backends
    /// serve single-layer plans only (every worker owns its own
    /// [`Runtime`] — the client is not `Send` — so startup compiles the
    /// executable once per shard and surfaces any failure here).
    /// Multi-layer plans serve fprop only: gradient passes chain in
    /// *reverse* layer order with different operand pairings, which is
    /// [`NetworkScheduler::backward`]
    /// (crate::coordinator::NetworkScheduler)'s job, not a serving
    /// path.
    pub fn start(backend: Backend, net: NetPlan, cfg: EngineConfig)
                 -> Result<ServeEngine> {
        assert!(cfg.shards >= 1, "engine needs at least one shard");
        if net.len() > 1 && cfg.pass != Pass::Fprop {
            return Err(anyhow!(
                "multi-layer plans serve fprop only (got {:?})",
                cfg.pass));
        }
        if let Backend::Pjrt { .. } = &backend {
            if net.len() != 1 {
                return Err(anyhow!(
                    "PJRT backend serves single-layer plans only \
                     ({} layers given)", net.len()));
            }
            if cfg.batcher.capacity > net.batch() {
                return Err(anyhow!(
                    "batcher capacity {} exceeds artifact batch S={}",
                    cfg.batcher.capacity, net.batch()));
            }
        }
        let net = Arc::new(net);
        let faults = cfg.faults.clone().or_else(FaultPlan::from_env);
        let mut cache = StrategyCache::open_with_faults(
            cfg.tuner_path.as_deref(), faults.as_deref());
        cache.reps = cfg.tuner_reps.max(1);
        // host serving of the weight-carrying passes runs through the
        // spectrum cache, so tune frequency candidates the same way —
        // the measured Choice then reflects steady-state (cached-weight)
        // flush cost, not the one-time weight FFT
        cache.serve_spectra = if matches!(backend, Backend::Host)
            && matches!(cfg.pass, Pass::Fprop | Pass::Bprop)
        {
            Some(cfg.spectra)
        } else {
            None
        };
        let cache = Arc::new(cache);
        // warm-tune the shapes every steady flush produces (full batches
        // and singletons, per layer); restarts hit the persisted entries
        if cfg.warm && matches!(backend, Backend::Host) {
            for l in net.layers() {
                if l.problem.stride != 1 {
                    continue;
                }
                for s in [1, cfg.batcher.capacity] {
                    cache.ensure(&ConvProblem { s, ..l.problem },
                                 cfg.pass);
                }
            }
            cache.persist().ok(); // best-effort; shutdown retries
        }
        let (ready_tx, ready_rx) =
            mpsc::channel::<std::result::Result<(), String>>();
        let health: Arc<Vec<ShardHealth>> = Arc::new(
            (0..cfg.shards).map(|_| ShardHealth::default()).collect());
        let mut txs = Vec::with_capacity(cfg.shards);
        let mut depths = Vec::with_capacity(cfg.shards);
        let mut workers = Vec::with_capacity(cfg.shards);
        for shard in 0..cfg.shards {
            let (tx, rx) = mpsc::channel::<Msg>();
            let depth = Arc::new(AtomicUsize::new(0));
            let ctx = WorkerCtx {
                shard,
                backend: backend.clone(),
                net: net.clone(),
                pass: cfg.pass,
                batcher_cfg: cfg.batcher,
                cache: cache.clone(),
                spectra: cfg.spectra,
                force: cfg.force_strategy,
                depth: depth.clone(),
                health: health.clone(),
                faults: faults.clone(),
                restart_backoff: cfg.restart_backoff,
                max_consecutive_failures: cfg.max_consecutive_failures,
                degrade_cooldown: cfg.degrade_cooldown,
                rx,
                ready: ready_tx.clone(),
            };
            workers.push(std::thread::spawn(move || worker_main(ctx)));
            txs.push(tx);
            depths.push(depth);
        }
        drop(ready_tx);
        let mut failure: Option<String> = None;
        for _ in 0..cfg.shards {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    failure = Some(e);
                    break;
                }
                Err(_) => {
                    failure = Some("worker died during startup".into());
                    break;
                }
            }
        }
        if let Some(e) = failure {
            drop(txs); // disconnect: healthy workers drain and exit
            for w in workers {
                w.join().ok();
            }
            return Err(anyhow!("serve engine startup: {e}"));
        }
        let client = EngineClient {
            txs,
            depths,
            health,
            rejected: Arc::new(AtomicUsize::new(0)),
            rejected_unavailable: Arc::new(AtomicUsize::new(0)),
            rr: Arc::new(AtomicUsize::new(0)),
            seq: Arc::new(AtomicU64::new(1)),
            weights_versions: Arc::new(
                (0..net.len()).map(|_| AtomicU64::new(1)).collect()),
            cache: cache.clone(),
            net,
            pass: cfg.pass,
            capacity: cfg.batcher.capacity,
            default_deadline: cfg.default_deadline,
            max_wait: cfg.batcher.max_wait,
        };
        Ok(ServeEngine { client, workers, cache, faults })
    }

    /// A cloneable submission handle for multi-threaded load.
    pub fn client(&self) -> EngineClient {
        self.client.clone()
    }

    /// Admit a request from the engine owner's thread. See
    /// [`EngineClient::submit`].
    pub fn submit(&self, req: ServeRequest)
                  -> std::result::Result<(), ServeFailure> {
        self.client.submit(req)
    }

    /// Submit and get a [`Ticket`]. See
    /// [`EngineClient::submit_images`].
    pub fn submit_images(&self, images: usize,
                         deadline: Option<Instant>)
                         -> std::result::Result<Ticket, ServeFailure> {
        self.client.submit_images(images, deadline)
    }

    /// Install new layer-0 weights across the pool. See
    /// [`EngineClient::update_weights`].
    pub fn update_weights(&self, weights: Vec<f32>)
                          -> std::result::Result<u64, ServeFailure> {
        self.client.update_weights(weights)
    }

    /// Install new weights for one chain position. See
    /// [`EngineClient::update_layer_weights`].
    pub fn update_layer_weights(&self, layer: usize, weights: Vec<f32>)
                                -> std::result::Result<u64, ServeFailure> {
        self.client.update_layer_weights(layer, weights)
    }

    /// The chain this engine serves.
    pub fn net(&self) -> &NetPlan {
        self.client.net()
    }

    /// Live per-shard health. See [`EngineClient::health`].
    pub fn health(&self) -> &[ShardHealth] {
        self.client.health()
    }

    pub fn cache(&self) -> &StrategyCache {
        &self.cache
    }

    pub fn shards(&self) -> usize {
        self.workers.len()
    }

    /// Flush outstanding work, join every worker, persist the strategy
    /// cache, and return the merged report. Never propagates a worker
    /// panic: a worker that somehow died outside its supervised flush
    /// region yields an empty report for its shard instead of taking
    /// the caller down.
    pub fn shutdown(self) -> EngineReport {
        let ServeEngine { client, workers, cache, faults } = self;
        for tx in &client.txs {
            tx.send(Msg::Shutdown).ok();
        }
        let mut shards: Vec<ShardReport> = workers
            .into_iter()
            .enumerate()
            .map(|(i, w)| {
                w.join().unwrap_or_else(|_| {
                    eprintln!("serve: shard {i} worker died outside \
                               supervision; reporting empty");
                    ShardReport { shard: i, ..Default::default() }
                })
            })
            .collect();
        shards.sort_by_key(|r| r.shard);
        cache.persist().ok();
        let shard_faults: usize =
            shards.iter().map(|s| s.faults_injected).sum();
        EngineReport {
            shards,
            rejected_deadline: client.rejected.load(Ordering::Relaxed),
            rejected_unavailable: client
                .rejected_unavailable
                .load(Ordering::Relaxed),
            faults_injected: faults
                .map(|f| f.injected())
                .unwrap_or(shard_faults),
            cache: cache.stats(),
            capacity: client.capacity,
            pass: client.pass,
            net: (*client.net).clone(),
        }
    }
}

/// One request's reply-tracking state while any of its parts are queued
/// or in flight on the shard.
struct PendingReply {
    id: u64,
    remaining: usize,
    total: usize,
    enqueued: Instant,
    sla: Instant,
    reply: Sender<Completion>,
}

/// What one supervised flush produced (the `Ok` side of `catch_unwind`).
struct FlushOutcome {
    /// weight-FFT time actually spent (frequency strategies through the
    /// spectrum cache)
    wfft: Option<Duration>,
    /// served on the degraded (direct-fallback) rung of the ladder
    degraded: bool,
    /// the primary backend launch failed (PJRT error, non-finite output)
    launch_error: bool,
    /// scripted faults injected inside the flush
    injected: usize,
}

/// Best-effort human-readable panic payload.
fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "shard worker panicked".to_string()
    }
}

/// Deliver completions for every part of `batch`. With `error: None`
/// this is the success path (a split request completes only when its
/// last part lands); with `Some(err)` every request with a part in the
/// batch fails *entirely* — exactly one error completion, and later
/// flushes of its other parts find no pending entry (harmless).
fn complete_batch(batch: &Batch, pending: &mut Vec<PendingReply>,
                  report: &mut ShardReport, shard: usize, imgs: usize,
                  error: Option<ServeFailure>) {
    let now = Instant::now();
    for (id, n) in &batch.parts {
        let Some(pos) = pending.iter().position(|p| p.id == *id) else {
            continue;
        };
        if error.is_none() {
            pending[pos].remaining =
                pending[pos].remaining.saturating_sub(*n);
            if pending[pos].remaining > 0 {
                continue; // split request: more parts ride later batches
            }
        }
        let p = pending.remove(pos);
        let latency = now.duration_since(p.enqueued);
        match error {
            None => {
                let met = now <= p.sla;
                if !met {
                    report.sla_miss += 1;
                }
                report.latency.record(latency.as_secs_f64());
                report.requests_completed += 1;
                p.reply
                    .send(Completion {
                        id: p.id,
                        images: p.total,
                        latency,
                        batch_images: imgs,
                        shard,
                        deadline_met: met,
                        error: None,
                    })
                    .ok();
            }
            Some(err) => {
                report.requests_failed += 1;
                p.reply
                    .send(Completion {
                        id: p.id,
                        images: p.total,
                        latency,
                        batch_images: 0,
                        shard,
                        deadline_met: false,
                        error: Some(err),
                    })
                    .ok();
            }
        }
    }
}

fn worker_main(ctx: WorkerCtx) -> ShardReport {
    let WorkerCtx { shard, backend, net, pass, batcher_cfg, cache,
                    spectra: spectra_precision, force, depth, health,
                    faults, restart_backoff, max_consecutive_failures,
                    degrade_cooldown, rx, ready } = ctx;
    let my_health = &health[shard];
    // backend setup runs before the readiness handshake so compile
    // failures surface from ServeEngine::start
    let rt = match &backend {
        Backend::Host => {
            ready.send(Ok(())).ok();
            None
        }
        Backend::Pjrt { dir, artifact } => {
            match Runtime::open(dir)
                .and_then(|rt| rt.executable(artifact).map(|_| rt))
            {
                Ok(rt) => {
                    ready.send(Ok(())).ok();
                    Some(rt)
                }
                Err(e) => {
                    ready.send(Err(format!("{e:#}"))).ok();
                    return ShardReport { shard, ..Default::default() };
                }
            }
        }
    };
    drop(ready);

    let mut batcher = Batcher::new(batcher_cfg);
    let capacity = batcher_cfg.capacity;
    let mut pending: Vec<PendingReply> = Vec::new();
    let mut report = ShardReport { shard, ..Default::default() };
    let mut rng = Rng::new(0xC0FFEE ^ shard as u64);
    let mut ws = Workspace::new();
    let mut stage = BufferPool::new();
    if let Some(f) = &faults {
        stage.set_faults(f.clone(), Some(shard));
    }
    // every layer's weights live on the shard (one buffered copy each,
    // §3.3), alongside the per-layer spectra transformed from them —
    // keyed by per-layer versions so a bump invalidates exactly the
    // bumped layer's stale entries
    let mut weights: Vec<Vec<f32>> = net
        .layers()
        .iter()
        .map(|l| rng.normal_vec(l.problem.weight_len()))
        .collect();
    let mut versions: Vec<u64> = vec![1; net.len()];
    let mut spectra = LayerSpectra::new(net.len(), spectra_precision);
    report.weights_version = versions[0];
    report.layers = net
        .layers()
        .iter()
        .map(|l| LayerStats::named(&l.name))
        .collect();
    // ---- submit half: the packer thread ---------------------------
    // the synthetic payload of batch k+1 is packed while the chain
    // runs batch k: two capacity-sized slabs rotate between the
    // packer and the flush path, and the fill time hidden behind
    // compute lands in `pack_overlap`
    let pack_len = match pass {
        Pass::Fprop => net.input_len(capacity),
        Pass::Bprop => net.output_len(capacity),
        Pass::AccGrad => {
            net.output_len(capacity) + net.input_len(capacity)
        }
    };
    let (job_tx, job_rx) = mpsc::channel::<Vec<f32>>();
    let (packed_tx, packed_rx) =
        mpsc::channel::<(Vec<f32>, Duration)>();
    let pack_seed = 0xFACADE ^ shard as u64;
    let packer = std::thread::spawn(move || {
        let mut prng = Rng::new(pack_seed);
        while let Ok(mut buf) = job_rx.recv() {
            let t0 = Instant::now();
            for v in buf.iter_mut() {
                *v = prng.normal();
            }
            if packed_tx.send((buf, t0.elapsed())).is_err() {
                break;
            }
        }
    });
    job_tx.send(vec![0f32; pack_len]).ok();
    let mut spare: Option<Vec<f32>> = Some(vec![0f32; pack_len]);
    let mut fill_sum = 0f64;
    let mut done = false;
    loop {
        // ---- receive phase --------------------------------------------
        let mut msgs: Vec<Msg> = Vec::new();
        // a backlog of a full batch must flush now — don't sleep on the
        // deadline when the capacity policy already says launch
        let backlog_full = batcher.queued_images() >= capacity;
        if !done && !backlog_full {
            if batcher.is_empty() {
                // idle: park on the channel indefinitely — the batcher
                // has no deadline to honor, so there is nothing to poll
                match rx.recv() {
                    Ok(m) => msgs.push(m),
                    Err(_) => done = true,
                }
            } else {
                // work queued: sleep until the earliest flush-by moment
                let timeout = batcher
                    .deadline()
                    .map(|d| d.saturating_duration_since(Instant::now()))
                    .unwrap_or(Duration::ZERO);
                match rx.recv_timeout(timeout) {
                    Ok(m) => msgs.push(m),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => done = true,
                }
            }
        }
        // drain whatever else already arrived without blocking — also
        // after shutdown, so requests already queued behind the
        // shutdown message still complete (submissions must not *race*
        // shutdown, though: see EngineClient::submit)
        while let Ok(m) = rx.try_recv() {
            msgs.push(m);
        }
        for m in msgs {
            match m {
                Msg::Req(a) => {
                    batcher.push_deadline(a.id, a.images, a.enqueued,
                                          a.flush_by);
                    pending.push(PendingReply {
                        id: a.id,
                        remaining: a.images,
                        total: a.images,
                        enqueued: a.enqueued,
                        sla: a.sla,
                        reply: a.reply,
                    });
                    report.requests += 1;
                    report.images += a.images;
                    report.depth.record(batcher.queued_images() as f64);
                }
                Msg::Weights { layer, version, weights: w } => {
                    // applied between flushes: already-flushed batches
                    // rode the old version, everything later serves the
                    // new one (bumps can arrive reordered only relative
                    // to newer bumps — never regress). Only the bumped
                    // layer's spectra invalidate.
                    if version > versions[layer] {
                        weights[layer].clear();
                        weights[layer].extend_from_slice(&w);
                        versions[layer] = version;
                        spectra.bump(layer, &net.layers()[layer].problem,
                                     version);
                        report.weights_version = versions[0];
                    }
                }
                Msg::Shutdown => done = true,
            }
        }
        // ---- flush phase ----------------------------------------------
        let batch = if done {
            let b = batcher.drain();
            if b.is_empty() {
                break;
            }
            b
        } else {
            match batcher.poll(Instant::now()) {
                Some(b) => b,
                None => continue,
            }
        };
        let imgs = batch.images();
        // ---- complete half: collect the pre-packed payload ----------
        // the packer filled this slab while the previous chain ran;
        // whatever fill time the stall did not expose was overlapped
        let w0 = Instant::now();
        let (mut payload, fill) = match packed_rx.recv() {
            Ok(p) => p,
            Err(_) => {
                // packer gone (teardown race): pack inline, no overlap
                let mut buf = spare
                    .take()
                    .unwrap_or_else(|| vec![0f32; pack_len]);
                for v in buf.iter_mut() {
                    *v = rng.normal();
                }
                (buf, Duration::ZERO)
            }
        };
        let wait = w0.elapsed();
        report.pack_wait += wait;
        report.pack_overlap += fill.saturating_sub(wait);
        // hand the packer the spare slab: batch k+1 packs while the
        // chain below runs batch k
        if let Some(buf) = spare.take() {
            job_tx.send(buf).ok();
        }
        // the scripted-panic probe counts this flush *before* the
        // supervised region so the occurrence index is deterministic
        // even when the launch itself panics for another reason
        let inject_panic = faults
            .as_ref()
            .map_or(false,
                    |f| f.fire(FaultKind::Panic, Some(shard)));
        // which chain position is executing — read back after a panic
        // so the failure records the layer it hit
        let in_layer: Cell<Option<usize>> = Cell::new(None);
        let t0 = Instant::now();
        // ---- supervised region ----------------------------------------
        // Everything that can panic — backend launches, staging-pool
        // checkouts, spectrum transforms — runs under catch_unwind. A
        // panic must fail this batch (error completions, exactly-once),
        // never the whole engine.
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if inject_panic {
                panic!("injected shard panic (FaultPlan, shard {shard})");
            }
            match &rt {
                Some(rt) => {
                    let Backend::Pjrt { artifact, .. } = &backend else {
                        unreachable!("runtime without PJRT backend")
                    };
                    let problem = net.layers()[0].problem;
                    // demotion is keyed batch-size-normalized so one
                    // bad launch covers every flush shape
                    let dkey = ConvProblem { s: 0, ..problem };
                    if cache.is_demoted(&dkey, pass) {
                        // cooldown: serve the host direct fallback
                        let mut o = run_chain(
                            &cache, Some(Strategy::Direct), pass, &net,
                            imgs, &weights, &versions, &mut spectra,
                            &mut payload, &mut stage, &mut ws, None,
                            shard, degrade_cooldown, &mut report.layers,
                            &in_layer, None);
                        o.degraded = true;
                        o
                    } else if launch_pjrt(rt, artifact, &problem, imgs,
                                          &payload, &weights[0]) {
                        FlushOutcome { wfft: None, degraded: false,
                                       launch_error: false, injected: 0 }
                    } else {
                        // PJRT runtime error (already logged): demote
                        // the problem and serve this flush on the host
                        // direct fallback instead of dropping it
                        cache.demote(&dkey, pass,
                                     Instant::now() + degrade_cooldown);
                        let mut o = run_chain(
                            &cache, Some(Strategy::Direct), pass, &net,
                            imgs, &weights, &versions, &mut spectra,
                            &mut payload, &mut stage, &mut ws, None,
                            shard, degrade_cooldown, &mut report.layers,
                            &in_layer, None);
                        o.degraded = true;
                        o.launch_error = true;
                        o
                    }
                }
                None => run_chain(&cache, force, pass, &net, imgs,
                                  &weights, &versions, &mut spectra,
                                  &mut payload, &mut stage, &mut ws,
                                  faults.as_deref(), shard,
                                  degrade_cooldown, &mut report.layers,
                                  &in_layer, None),
            }
        }));
        let elapsed = t0.elapsed();
        spare = Some(payload);
        report.launches += 1;
        report.busy += elapsed;
        fill_sum += imgs as f64 / capacity as f64;
        depth.fetch_sub(imgs, Ordering::Relaxed);
        match outcome {
            Ok(o) => {
                report.faults_injected += o.injected;
                if let Some(d) = o.wfft {
                    report.weight_fft.record(d.as_secs_f64());
                }
                if o.degraded {
                    report.degraded_flushes += 1;
                }
                if o.launch_error {
                    report.launch_errors += 1;
                }
                if !o.launch_error && !o.degraded && rt.is_some() {
                    // no host tuner runs for a compiled artifact; feed
                    // measured launch times back so deadline admission
                    // has an estimate (clean launches only — fallback
                    // timings would poison the estimate)
                    cache.observe(
                        &ConvProblem { s: imgs,
                                       ..net.layers()[0].problem },
                        pass, Strategy::Vendor, elapsed.as_secs_f64());
                }
                my_health.record_success();
                complete_batch(&batch, &mut pending, &mut report, shard,
                               imgs, None);
            }
            Err(cause) => {
                let msg = panic_msg(cause.as_ref());
                let layer = in_layer.get();
                eprintln!("serve: shard {shard} flush panicked: {msg}");
                if inject_panic {
                    report.faults_injected += 1;
                }
                report.launch_errors += 1;
                if let Some(i) = layer {
                    if let Some(ls) = report.layers.get_mut(i) {
                        ls.launch_errors += 1;
                    }
                }
                // the batch is gone from the batcher: fail its requests
                // with error completions (exactly-once — a hung client
                // is worse than a served error), recording the chain
                // position that blew up
                complete_batch(&batch, &mut pending, &mut report, shard,
                               imgs,
                               Some(ServeFailure::ShardPanic { layer }));
                let consecutive = my_health.record_failure(&msg);
                report.last_error = Some(msg);
                if consecutive >= max_consecutive_failures {
                    // ---- circuit breaker --------------------------------
                    // flapping: mark the shard dead so admission routes
                    // around it, fail everything still queued, then
                    // dead-letter the channel until shutdown
                    my_health.mark_dead();
                    report.circuit_broken = true;
                    eprintln!("serve: shard {shard} circuit-broken \
                               after {consecutive} consecutive failures");
                    loop {
                        let b = batcher.drain();
                        if b.is_empty() {
                            break;
                        }
                        let n = b.images();
                        report.launches += 1; // ledger: drains count
                        fill_sum += n as f64 / capacity as f64;
                        depth.fetch_sub(n, Ordering::Relaxed);
                        complete_batch(
                            &b, &mut pending, &mut report, shard, n,
                            Some(ServeFailure::ShardUnavailable));
                    }
                    for p in pending.drain(..) {
                        report.requests_failed += 1;
                        p.reply
                            .send(Completion {
                                id: p.id,
                                images: p.total,
                                latency: p.enqueued.elapsed(),
                                batch_images: 0,
                                shard,
                                deadline_met: false,
                                error:
                                    Some(ServeFailure::ShardUnavailable),
                            })
                            .ok();
                    }
                    // dead-letter: racing submissions fail fast instead
                    // of hanging their clients (skipped when shutdown
                    // already arrived — nothing more can be sent)
                    while !done {
                        match rx.recv() {
                            Ok(Msg::Req(a)) => {
                                depth.fetch_sub(a.images,
                                                Ordering::Relaxed);
                                report.requests += 1;
                                report.images += a.images;
                                report.requests_failed += 1;
                                a.reply
                                    .send(Completion {
                                        id: a.id,
                                        images: a.images,
                                        latency: a.enqueued.elapsed(),
                                        batch_images: 0,
                                        shard,
                                        deadline_met: false,
                                        error: Some(
                                            ServeFailure::ShardUnavailable,
                                        ),
                                    })
                                    .ok();
                            }
                            Ok(Msg::Weights { .. }) => {}
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    }
                    break;
                }
                // ---- supervised restart -----------------------------
                // rebuild every piece of flush-local state the panic
                // could have left inconsistent (workspace scratch,
                // checked-out staging buffers, half-built spectra);
                // the batcher and pending queue were outside the
                // supervised region and stay live
                report.restarts += 1;
                my_health.record_restart();
                report.faults_injected += stage.faults_injected;
                spectra.clear();
                ws = Workspace::new();
                stage = BufferPool::new();
                if let Some(f) = &faults {
                    stage.set_faults(f.clone(), Some(shard));
                }
                let backoff = restart_backoff
                    * (1u32 << (consecutive.min(6) as u32 - 1));
                std::thread::sleep(
                    backoff.min(Duration::from_millis(500)));
                continue;
            }
        }
    }
    // stop the packer (disconnect its job channel) and reap it —
    // nothing is in flight once the flush loop has exited
    drop(job_tx);
    while packed_rx.try_recv().is_ok() {}
    packer.join().ok();
    report.flushes_full = batcher.flushes_full;
    report.flushes_timeout = batcher.flushes_timeout;
    report.flushes_drain = batcher.flushes_drain;
    // LayerSpectra::clear keeps its counters across supervised
    // restarts, so plain assignment still accounts for pre-crash work
    report.spectra_hits = spectra.hits();
    report.spectra_misses = spectra.misses();
    report.spectra_invalidated = spectra.invalidated();
    for (i, ls) in report.layers.iter_mut().enumerate() {
        let st = spectra.layer_stats(i);
        ls.spectra_hits = st.hits;
        ls.spectra_misses = st.misses;
        ls.spectra_invalidated = st.invalidated;
    }
    report.faults_injected += stage.faults_injected;
    report.stage_allocations = stage.allocations;
    report.stage_expansions = stage.expansions;
    report.stage_reuses = stage.reuses;
    if report.launches > 0 {
        report.batch_fill = fill_sum / report.launches as f64;
    }
    report
}

/// One PJRT launch: pad the flushed images to the artifact batch S.
/// The payload slab was filled by the packer thread; only the live
/// prefix is copied into the launch literal.
fn launch_pjrt(rt: &Runtime, artifact: &str, p: &ConvProblem,
               imgs: usize, payload: &[f32], weights: &[f32]) -> bool {
    // PJRT literals consume their Vec, so this path allocates per launch
    let mut x = vec![0f32; p.input_len()];
    let live = imgs * p.f * p.h * p.w;
    x[..live].copy_from_slice(&payload[..live]);
    let result = rt.execute_1f32(
        artifact,
        &[HostTensor::f32(x, &[p.s, p.f, p.h, p.w]),
          HostTensor::f32(weights.to_vec(),
                          &[p.fo, p.f, p.kh, p.kw])]);
    if let Err(e) = result {
        eprintln!("serve: launch failed: {e:#}");
        return false;
    }
    true
}

/// The two pooled ping-pong activation roles: layer `i` writes its
/// output into `ACT_ROLES[i % 2]`, which layer `i + 1` reads as input
/// while writing into the other slab. Allocation-free after warmup.
const ACT_ROLES: [&str; 2] = ["serve.act0", "serve.act1"];

/// Execute one admitted flush through every layer of `net` on the host
/// engines. Layer `i`'s output becomes layer `i + 1`'s input through a
/// pair of pooled ping-pong activation slabs ([`ACT_ROLES`]). Each
/// layer looks its flush shape up in the strategy cache independently
/// (tuning once on first sight) and serves weight spectra from its own
/// positional cache in `spectra`.
///
/// Degradation ladder, now per layer: a layer inside a demotion
/// cooldown serves the direct fallback; a frequency layer whose output
/// scans non-finite demotes that layer's problem (cooldown keyed
/// batch-size-normalized, `s = 0`) and re-serves *that layer* on
/// direct — downstream layers still consume a healthy activation.
/// `in_layer` tracks the chain position so a panic anywhere in the
/// chain can be attributed to the layer it happened in after
/// `catch_unwind`. The returned [`FlushOutcome`] sums weight-FFT time
/// across layers and ORs the degraded/launch-error flags.
///
/// Scripted faults: unqualified `nonfinite` entries count per flush
/// (probed once, at the first frequency non-demoted layer);
/// `layer<j>`-qualified entries are probed at every chain position.
/// `capture` (tests only) collects each layer's output.
#[allow(clippy::too_many_arguments)]
fn run_chain(cache: &StrategyCache, force: Option<Strategy>, pass: Pass,
             net: &NetPlan, imgs: usize, weights: &[Vec<f32>],
             versions: &[u64], spectra: &mut LayerSpectra,
             payload: &mut [f32], stage: &mut BufferPool,
             ws: &mut Workspace, faults: Option<&FaultPlan>,
             shard: usize, cooldown: Duration,
             layers: &mut [LayerStats], in_layer: &Cell<Option<usize>>,
             mut capture: Option<&mut Vec<Vec<f32>>>)
             -> FlushOutcome {
    let mut outcome = FlushOutcome { wfft: None, degraded: false,
                                     launch_error: false, injected: 0 };
    if pass == Pass::AccGrad {
        // accGrad pairs the gradient with an activation, not weights;
        // the packer stages both in one slab — [grad_out at capacity |
        // activation at capacity]. Single-layer only (enforced at
        // start()).
        let p = &net.layers()[0].problem;
        let q = ConvProblem { s: imgs, ..*p };
        let dkey = ConvProblem { s: 0, ..*p };
        in_layer.set(Some(0));
        if let Some(plan) = faults {
            if plan.fire_layer(FaultKind::Panic, Some(shard), 0) {
                panic!("injected shard panic (layer 0, shard {shard})");
            }
        }
        let t0 = Instant::now();
        let mut choice = match force {
            Some(strategy) =>
                Choice { strategy, n_fft: None, seconds: 0.0 },
            None => cache.ensure(&q, pass),
        };
        let fallback = Choice { strategy: Strategy::Direct,
                                n_fft: None, seconds: 0.0 };
        let frequency = matches!(
            choice.strategy,
            Strategy::VendorFft | Strategy::Fbfft
                | Strategy::FbfftScalar);
        let mut degraded = false;
        if frequency && cache.is_demoted(&dkey, pass) {
            choice = fallback;
            degraded = true;
        }
        // split the packed slab into its gradient/activation halves
        // (packed at capacity; only the live prefixes are consumed)
        let out1 = net.output_len(1);
        let in1 = net.input_len(1);
        let offset = (payload.len() / (out1 + in1)) * out1;
        let (a_part, b_part) = payload.split_at_mut(offset);
        let a = &mut a_part[..q.output_len()];
        let b = &b_part[..q.input_len()];
        let mut planted: Option<f32> = None;
        if frequency && !degraded {
            if let Some(plan) = faults {
                // both probes always run so occurrence counters
                // advance deterministically
                let flush_probe =
                    plan.fire(FaultKind::NonFinite, Some(shard));
                let layer_probe =
                    plan.fire_layer(FaultKind::NonFinite, Some(shard), 0);
                if flush_probe || layer_probe {
                    outcome.injected += 1;
                    planted = Some(a[0]);
                    a[0] = f32::NAN;
                }
            }
        }
        let mut out = stage.take_raw(ACT_ROLES[0], q.weight_len());
        let (_, finite) =
            run_strategy_into(&choice, &q, pass, a, b, None, &mut out,
                              ws);
        if !finite {
            cache.demote(&dkey, pass, Instant::now() + cooldown);
            eprintln!("serve: non-finite {:?} output on shard {shard} \
                       (layer {}); demoting to direct",
                      choice.strategy, net.layers()[0].name);
            // undo the planted value — the NaN must not leak into the
            // always-correct fallback result
            if let Some(prev) = planted.take() {
                a[0] = prev;
            }
            run_strategy_into(&fallback, &q, pass, a, b, None, &mut out,
                              ws);
            degraded = true;
            outcome.launch_error = true;
            layers[0].launch_errors += 1;
        }
        if let Some(cap) = capture.as_mut() {
            cap.push(out.to_vec());
        }
        stage.put(ACT_ROLES[0], out);
        if degraded {
            outcome.degraded = true;
            layers[0].degraded += 1;
        }
        layers[0].latency.record(t0.elapsed());
        in_layer.set(None);
        return outcome;
    }
    let n_layers = net.len();
    let mut carry: Option<Vec<f32>> = None;
    let mut wfft_total = Duration::ZERO;
    let mut saw_wfft = false;
    // the per-flush nonfinite probe fires at most once per flush (on
    // the first frequency, non-demoted layer) so unqualified
    // `nonfinite@N` specs keep counting flushes, not chain positions
    let mut freq_probed = false;
    for i in 0..n_layers {
        in_layer.set(Some(i));
        if let Some(plan) = faults {
            if plan.fire_layer(FaultKind::Panic, Some(shard), i) {
                panic!("injected shard panic (layer {i}, shard \
                        {shard})");
            }
        }
        let t0 = Instant::now();
        let p = &net.layers()[i].problem;
        let q = ConvProblem { s: imgs, ..*p };
        // demotion is keyed batch-size-normalized (s = 0) so one bad
        // output covers every flush shape of the layer at once
        let dkey = ConvProblem { s: 0, ..*p };
        let mut choice = match force {
            // deterministic probe: serve the forced strategy at its
            // default basis without consulting the tuner
            Some(strategy) =>
                Choice { strategy, n_fft: None, seconds: 0.0 },
            None => cache.ensure(&q, pass),
        };
        let fallback = Choice { strategy: Strategy::Direct,
                                n_fft: None, seconds: 0.0 };
        let frequency = matches!(
            choice.strategy,
            Strategy::VendorFft | Strategy::Fbfft
                | Strategy::FbfftScalar);
        let mut degraded = false;
        if frequency && cache.is_demoted(&dkey, pass) {
            choice = fallback;
            degraded = true;
        }
        let a_len = match pass {
            Pass::Fprop => q.input_len(),
            Pass::Bprop | Pass::AccGrad => q.output_len(),
        };
        // layer 0 consumes the packed payload; later layers consume
        // the previous layer's pooled output slab
        let a_buf: &mut [f32] = match carry.as_mut() {
            Some(prev) => &mut prev[..a_len],
            None => &mut payload[..a_len],
        };
        let mut planted: Option<f32> = None;
        if frequency && !degraded {
            if let Some(plan) = faults {
                // both probes always run so occurrence counters
                // advance deterministically
                let flush_probe = !freq_probed
                    && plan.fire(FaultKind::NonFinite, Some(shard));
                let layer_probe =
                    plan.fire_layer(FaultKind::NonFinite, Some(shard),
                                    i);
                if flush_probe || layer_probe {
                    outcome.injected += 1;
                    planted = Some(a_buf[0]);
                    a_buf[0] = f32::NAN;
                }
            }
            freq_probed = true;
        }
        let out_len = match pass {
            Pass::Fprop => q.output_len(),
            Pass::Bprop | Pass::AccGrad => q.input_len(),
        };
        let role = ACT_ROLES[i % 2];
        let mut out = stage.take_raw(role, out_len);
        let (wfft, finite) = run_strategy_into(
            &choice, &q, pass, a_buf, &weights[i],
            Some((spectra.layer(i), versions[i])), &mut out, ws);
        if !finite {
            cache.demote(&dkey, pass, Instant::now() + cooldown);
            eprintln!("serve: non-finite {:?} output on shard {shard} \
                       (layer {}); demoting to direct",
                      choice.strategy, net.layers()[i].name);
            // re-serve this layer on the always-correct path with the
            // planted value undone (the NaN must not leak into the
            // fallback result)
            if let Some(prev) = planted.take() {
                a_buf[0] = prev;
            }
            run_strategy_into(&fallback, &q, pass, a_buf, &weights[i],
                              None, &mut out, ws);
            degraded = true;
            outcome.launch_error = true;
            layers[i].launch_errors += 1;
        } else if let Some(d) = wfft {
            wfft_total += d;
            saw_wfft = true;
            layers[i].weight_fft.record(d.as_secs_f64());
        }
        if degraded {
            outcome.degraded = true;
            layers[i].degraded += 1;
        }
        layers[i].latency.record(t0.elapsed());
        if let Some(cap) = capture.as_mut() {
            cap.push(out.to_vec());
        }
        // layer i-1's slab (same parity as i+1) is fully consumed:
        // hand it back so layer i+1 can take it as its output
        if let Some(prev) = carry.take() {
            stage.put(ACT_ROLES[(i + 1) % 2], prev);
        }
        carry = Some(out);
    }
    if let Some(last) = carry.take() {
        stage.put(ACT_ROLES[(n_layers - 1) % 2], last);
    }
    in_layer.set(None);
    if saw_wfft {
        outcome.wfft = Some(wfft_total);
    }
    outcome
}

/// Run `input` through every layer of `net` with `weights`, returning
/// each layer's output (test/oracle surface over the same [`run_chain`]
/// the shard workers execute, minus faults and degradation state).
/// `force` pins every layer to one strategy; `None` tunes through a
/// fresh in-memory cache.
pub fn chain_outputs(net: &NetPlan, imgs: usize, input: &[f32],
                     weights: &[Vec<f32>], force: Option<Strategy>)
                     -> Vec<Vec<f32>> {
    let cache = StrategyCache::open(None);
    let mut spectra =
        LayerSpectra::new(net.len(), SpectrumPrecision::F32);
    let mut stage = BufferPool::new();
    let mut ws = Workspace::new();
    let mut layers: Vec<LayerStats> =
        net.layers().iter().map(|l| LayerStats::named(&l.name)).collect();
    let versions = vec![1u64; net.len()];
    let in_layer = Cell::new(None);
    let mut payload = input.to_vec();
    let mut captured = Vec::new();
    run_chain(&cache, force, Pass::Fprop, net, imgs, weights, &versions,
              &mut spectra, &mut payload, &mut stage, &mut ws, None, 0,
              Duration::from_secs(1), &mut layers, &in_layer,
              Some(&mut captured));
    captured
}

/// Dispatch one layer's pass through its tuned strategy, writing the
/// result into `out`. `a`/`b` follow each engine's own operand order:
/// (x, weights) for fprop, (grad_output, weights) for bprop,
/// (grad_output, x) for accGrad. When `b` is the weight tensor the
/// caller passes the layer's spectrum cache and its live
/// `weights_version`; frequency strategies then serve from the cached
/// spectrum — skipping the weight pad+FFT on a hit — and the
/// `Option<Duration>` is the weight-FFT time actually spent. The bool
/// is the output-health verdict: frequency outputs are scanned for
/// non-finite values (the paper's frequency path is where numerical
/// blowups surface); the time-domain engines always report healthy.
#[allow(clippy::too_many_arguments)]
fn run_strategy_into(choice: &Choice, q: &ConvProblem, pass: Pass,
                     a: &[f32], b: &[f32],
                     spectra: Option<(&mut SpectrumCache, u64)>,
                     out: &mut [f32], ws: &mut Workspace)
                     -> (Option<Duration>, bool) {
    match choice.strategy {
        Strategy::VendorFft | Strategy::Fbfft | Strategy::FbfftScalar => {
            let mode = match choice.strategy {
                Strategy::VendorFft => FftMode::Vendor,
                Strategy::Fbfft => FftMode::Fbfft,
                _ => FftMode::FbfftScalar,
            };
            let n = choice
                .n_fft
                .unwrap_or_else(|| q.h.max(q.w).next_power_of_two());
            let eng = FftConvEngine::new(mode, n);
            let wfft = match (pass, spectra) {
                (Pass::Fprop, Some((spectra, version))) => {
                    let (spec, took) =
                        spectra.ensure(&eng, q, b, version, ws);
                    eng.fprop_spec_into(q, a, spec, out, ws);
                    Some(took)
                }
                (Pass::Bprop, Some((spectra, version))) => {
                    let (spec, took) =
                        spectra.ensure(&eng, q, b, version, ws);
                    eng.bprop_spec_into(q, a, spec, out, ws);
                    Some(took)
                }
                (Pass::Fprop, None) => {
                    eng.fprop_into(q, a, b, out, ws);
                    None
                }
                (Pass::Bprop, None) => {
                    eng.bprop_into(q, a, b, out, ws);
                    None
                }
                (Pass::AccGrad, _) => {
                    eng.accgrad_into(q, a, b, out, ws);
                    None
                }
            };
            let finite = out.iter().all(|v| v.is_finite());
            (wfft, finite)
        }
        // the vendor black box has no host twin; direct is its analogue
        Strategy::Direct | Strategy::Vendor => {
            let r = match pass {
                Pass::Fprop => direct::fprop(q, a, b),
                Pass::Bprop => direct::bprop(q, a, b),
                Pass::AccGrad => direct::accgrad(q, a, b),
            };
            out.copy_from_slice(&r);
            (None, true)
        }
        Strategy::Im2col => {
            let r = match pass {
                Pass::Fprop => im2col::fprop(q, a, b),
                Pass::Bprop => im2col::bprop(q, a, b),
                Pass::AccGrad => im2col::accgrad(q, a, b),
            };
            out.copy_from_slice(&r);
            (None, true)
        }
        Strategy::FbfftTiled(d) => {
            let r = match pass {
                Pass::Fprop => tiled::fprop(q, a, b, d),
                Pass::Bprop => tiled::bprop(q, a, b, d),
                Pass::AccGrad => tiled::accgrad(q, a, b, d),
            };
            out.copy_from_slice(&r);
            (None, true)
        }
    }
}

#[cfg(test)]
mod tests {
    // PJRT-backed behaviour is covered by rust/tests/integration.rs;
    // the host-backend engine is exercised end-to-end (multi-shard soak,
    // admission, batcher paths) in rust/tests/serve.rs. Here: report
    // arithmetic and the admission fast-paths.
    use super::*;

    #[test]
    fn engine_report_aggregates_across_shards() {
        let mut a = ShardReport { shard: 0, ..Default::default() };
        a.requests = 3;
        a.images = 7;
        a.launches = 2;
        a.batch_fill = 0.5;
        a.latency.record(0.010);
        let mut b = ShardReport { shard: 1, ..Default::default() };
        b.requests = 1;
        b.images = 2;
        b.launches = 1;
        b.batch_fill = 1.0;
        b.latency.record(0.030);
        let r = EngineReport {
            shards: vec![a, b],
            rejected_deadline: 4,
            rejected_unavailable: 0,
            faults_injected: 0,
            cache: CacheStats::default(),
            capacity: 8,
            pass: Pass::Fprop,
            net: NetPlan::single(ConvProblem::square(8, 1, 1, 8, 3)),
        };
        assert_eq!(r.requests(), 4);
        assert_eq!(r.images(), 9);
        assert_eq!(r.launches(), 3);
        let mut agg = r.aggregate_latency();
        assert_eq!(agg.len(), 2);
        assert_eq!(agg.summary().max, 0.030);
        // launch-weighted fill: (0.5·2 + 1.0·1) / 3
        assert!((r.batch_fill() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn expired_deadline_is_rejected_at_admission() {
        let p = ConvProblem::square(4, 1, 1, 8, 3);
        let engine = ServeEngine::start_host(
            p,
            EngineConfig {
                shards: 2,
                batcher: BatcherConfig {
                    capacity: 4,
                    max_wait: Duration::from_millis(1),
                },
                warm: false,
                ..Default::default()
            })
            .expect("host engine always starts");
        let (tx, rx) = mpsc::channel::<Completion>();
        let expired = Instant::now() - Duration::from_millis(1);
        let accepted = engine.submit(ServeRequest {
            id: 1,
            images: 1,
            deadline: Some(expired),
            reply: tx.clone(),
        });
        assert_eq!(accepted, Err(ServeFailure::DeadlineUnmeetable),
                   "expired deadline must be rejected");
        let accepted = engine.submit(ServeRequest {
            id: 2,
            images: 1,
            deadline: None,
            reply: tx,
        });
        assert!(accepted.is_ok());
        let c = rx.recv_timeout(Duration::from_secs(30))
            .expect("accepted request completes");
        assert_eq!(c.id, 2);
        assert_eq!(c.images, 1);
        let report = engine.shutdown();
        assert_eq!(report.rejected_deadline, 1);
        assert_eq!(report.requests(), 1);
        assert_eq!(report.images(), 1);
    }
}
