//! The serving layer: a sharded multi-worker engine behind a
//! deadline-aware dynamic batcher.
//!
//! Architecture (tokio is unavailable offline; std threads + channels
//! implement the same event loop):
//!
//! ```text
//!            submit()                 mpsc            worker pool
//!  clients ──────────▶ admission ───────────▶ shard 0 [Batcher|Workspace|BufferPool|Runtime?]
//!            deadline   │ least-loaded        shard 1 [Batcher|Workspace|BufferPool|Runtime?]
//!            check      │ routing      ···    shard N [Batcher|Workspace|BufferPool|Runtime?]
//!                       ▼
//!              StrategyCache (shared, persistent JSON)
//! ```
//!
//! * **Admission** ([`EngineClient::submit`]): requests carry an SLA
//!   deadline (or inherit the engine default). A request whose deadline
//!   cannot cover even the cached launch estimate for its own shape is
//!   rejected up front (`rejected_deadline` in the report) instead of
//!   wasting a batch slot; accepted requests go to the shard with the
//!   fewest queued images (round-robin tie-break).
//! * **Workers**: each shard is one `std::thread` owning its own
//!   [`Batcher`], [`Workspace`], staging [`BufferPool`], RNG, one
//!   buffered weights copy (§3.3), and — in PJRT mode — its own
//!   [`Runtime`]. An idle worker parks on its channel *indefinitely*;
//!   only a non-empty batcher arms `recv_timeout` with the earliest
//!   flush-by deadline (no idle spinning).
//! * **Strategy cache** ([`StrategyCache`]): every flush of `b` images
//!   is the problem `{s: b, ..served}`; the worker looks the shape up
//!   and runs the best known [`Strategy`] — the §3.4 tuner populates
//!   the cache once per shape (persisted as JSON, warm-loaded at
//!   startup) so the steady-state hot path never re-tunes.
//! * **Metrics**: per-shard latency/queue-depth [`Histogram`]s,
//!   batch-fill ratio, SLA misses and flush counters, merged into the
//!   aggregate view by [`EngineReport`] and rendered by
//!   [`reports::serve`](crate::reports::serve).
//! * **Supervision**: every flush runs under `catch_unwind`. A panic
//!   fails the in-flight batch with error [`Completion`]s (exactly-once
//!   is preserved — a hung client is worse than a served error), is
//!   recorded in the shared [`ShardHealth`] table, and the shard
//!   rebuilds its flush-local state (workspace, staging pool, spectrum
//!   entries) with exponential backoff. A shard that keeps flapping
//!   trips a circuit breaker: it is marked dead, admission re-routes to
//!   the survivors, and the dead shard drains its channel as a
//!   dead-letter queue so racing submissions fail fast instead of
//!   hanging. Degradation ladder for bad *outputs* (PJRT launch errors,
//!   non-finite frequency results): the problem demotes to the direct
//!   fallback for a cooldown window via
//!   [`StrategyCache::demote`]. Faults are injectable deterministically
//!   through a [`FaultPlan`] (`FBFFT_FAULTS`) for chaos tests.
//!
//! [`ConvService`] survives as the single-shard PJRT wrapper the
//! original examples were written against.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::conv::{direct, im2col, tiled, ConvProblem, FftConvEngine,
                  FftMode, SpectrumCache, SpectrumPrecision, Workspace};
use crate::metrics::Histogram;
use crate::runtime::{HostTensor, Runtime};
use crate::testkit::faults::{FaultKind, FaultPlan};
use crate::util::Rng;

use super::autotuner::{CacheStats, Choice, StrategyCache};
use super::batcher::{Batch, Batcher, BatcherConfig};
use super::buffers::BufferPool;
use super::strategy::{Pass, Strategy};

/// A conv inference request: `images` samples for the served layer.
pub struct ServeRequest {
    pub id: u64,
    pub images: usize,
    /// SLA deadline for the reply; `None` inherits the engine default.
    pub deadline: Option<Instant>,
    /// sent back exactly once, when every image has been served
    pub reply: Sender<Completion>,
}

#[derive(Clone, Copy, Debug)]
pub struct Completion {
    pub id: u64,
    pub images: usize,
    pub latency: Duration,
    /// images in the last flushed batch this request rode in (0 when
    /// the request failed — it never rode a completed batch)
    pub batch_images: usize,
    /// which shard served the request
    pub shard: usize,
    /// whether the reply beat the request's SLA deadline
    pub deadline_met: bool,
    /// `Some` when the request was *failed* rather than served — the
    /// shard panicked with the request in flight, or was circuit-broken
    /// with it still queued. Exactly-once still holds: a failed request
    /// gets exactly one completion, carrying the error.
    pub error: Option<ServeError>,
}

/// Why a request's completion is an error instead of a result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// the owning shard panicked with the request's batch in flight
    ShardPanic,
    /// the owning shard was circuit-broken (dead) with the request
    /// queued behind the break
    ShardUnavailable,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::ShardPanic => write!(f, "shard panicked"),
            ServeError::ShardUnavailable => write!(f, "shard unavailable"),
        }
    }
}

/// Why admission refused a request up front (nothing was enqueued and
/// no completion will arrive).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// the deadline cannot cover the cached launch estimate
    DeadlineUnmeetable,
    /// no live shard exists to take the request (every shard dead)
    Unavailable,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::DeadlineUnmeetable =>
                write!(f, "deadline unmeetable"),
            SubmitError::Unavailable => write!(f, "no live shard"),
        }
    }
}

/// Live health of one shard, shared between its worker (writer) and
/// every [`EngineClient`] (readers routing around dead shards).
#[derive(Debug)]
pub struct ShardHealth {
    alive: AtomicBool,
    restarts: AtomicUsize,
    consecutive_failures: AtomicUsize,
    last_error: Mutex<Option<String>>,
}

impl Default for ShardHealth {
    fn default() -> Self {
        ShardHealth {
            alive: AtomicBool::new(true),
            restarts: AtomicUsize::new(0),
            consecutive_failures: AtomicUsize::new(0),
            last_error: Mutex::new(None),
        }
    }
}

impl ShardHealth {
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Acquire)
    }

    /// Supervised restarts so far (rebuild-after-panic events).
    pub fn restarts(&self) -> usize {
        self.restarts.load(Ordering::Relaxed)
    }

    pub fn consecutive_failures(&self) -> usize {
        self.consecutive_failures.load(Ordering::Relaxed)
    }

    pub fn last_error(&self) -> Option<String> {
        self.last_error
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    fn mark_dead(&self) {
        self.alive.store(false, Ordering::Release);
    }

    /// Record one flush failure; returns the new consecutive count.
    fn record_failure(&self, msg: &str) -> usize {
        *self.last_error.lock().unwrap_or_else(|e| e.into_inner()) =
            Some(msg.to_string());
        self.consecutive_failures.fetch_add(1, Ordering::Relaxed) + 1
    }

    fn record_restart(&self) {
        self.restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// A clean flush resets the flap counter (the breaker only trips on
    /// *consecutive* failures).
    fn record_success(&self) {
        self.consecutive_failures.store(0, Ordering::Relaxed);
    }
}

/// How the worker pool executes a flushed batch.
#[derive(Clone, Debug)]
enum Backend {
    /// In-tree host engines dispatched through the strategy cache.
    Host,
    /// One PJRT runtime per worker, serving a fixed AOT artifact.
    Pjrt { dir: PathBuf, artifact: String },
}

/// Engine-wide configuration (per-shard knobs live in [`BatcherConfig`]).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// worker-pool width (N shards, one OS thread each)
    pub shards: usize,
    pub batcher: BatcherConfig,
    /// SLA budget applied to requests that carry no explicit deadline
    pub default_deadline: Duration,
    /// which training pass the engine serves (fprop for inference)
    pub pass: Pass,
    /// strategy-cache warm-load/persist location (`None` = in-memory)
    pub tuner_path: Option<PathBuf>,
    /// measurement repetitions when a flush shape misses the cache
    pub tuner_reps: usize,
    /// tune the {1, capacity}-image shapes before accepting traffic
    pub warm: bool,
    /// storage precision of the per-shard weight-spectrum cache
    /// (default: f16 unless `FBFFT_SPECTRA=f32`)
    pub spectra: SpectrumPrecision,
    /// bypass the tuner and serve every flush with this strategy —
    /// the deterministic-probe escape hatch (bench smoke, CI gates)
    pub force_strategy: Option<Strategy>,
    /// base sleep before a supervised shard rebuild; doubles per
    /// consecutive failure (capped at 500ms)
    pub restart_backoff: Duration,
    /// consecutive flush failures that trip the circuit breaker and
    /// mark the shard dead
    pub max_consecutive_failures: usize,
    /// how long a problem stays demoted to the direct fallback after a
    /// PJRT error or non-finite frequency output
    pub degrade_cooldown: Duration,
    /// deterministic fault script for chaos tests; `None` falls back to
    /// `FBFFT_FAULTS` in the environment (unset = no faults)
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            shards: 4,
            batcher: BatcherConfig::default(),
            default_deadline: Duration::from_secs(1),
            pass: Pass::Fprop,
            tuner_path: None,
            tuner_reps: 1,
            warm: true,
            spectra: SpectrumPrecision::default(),
            force_strategy: None,
            restart_backoff: Duration::from_millis(10),
            max_consecutive_failures: 3,
            degrade_cooldown: Duration::from_secs(5),
            faults: None,
        }
    }
}

/// One accepted request on its way to a shard.
struct Accepted {
    id: u64,
    images: usize,
    enqueued: Instant,
    /// batcher flush-by deadline: `min(enqueued + max_wait, sla)`
    flush_by: Instant,
    /// the request's SLA deadline (reply-by)
    sla: Instant,
    reply: Sender<Completion>,
}

enum Msg {
    Req(Accepted),
    /// install a new weight tensor under `version`, invalidating the
    /// shard's cached spectra of the served problem
    Weights { version: u64, weights: Arc<Vec<f32>> },
    Shutdown,
}

/// Per-shard statistics returned by the worker at shutdown.
#[derive(Clone, Debug, Default)]
pub struct ShardReport {
    pub shard: usize,
    /// accepted requests routed here
    pub requests: usize,
    pub images: usize,
    pub launches: usize,
    pub busy: Duration,
    pub flushes_full: usize,
    pub flushes_timeout: usize,
    /// shutdown-path drains — `flushes_full + flushes_timeout +
    /// flushes_drain == launches` reconciles every batch
    pub flushes_drain: usize,
    /// weight-spectrum cache counters (tentpole: steady-state hits)
    pub spectra_hits: usize,
    pub spectra_misses: usize,
    pub spectra_invalidated: usize,
    /// per-flush weight-FFT seconds (frequency-strategy launches only;
    /// zero samples on spectrum hits — `sum`/`last` feed the report)
    pub weight_fft: Histogram,
    /// weights version the shard was serving at shutdown
    pub weights_version: u64,
    /// completions delivered after their SLA deadline
    pub sla_miss: usize,
    /// failed backend launches (their requests complete anyway — a
    /// hung client is worse than a served error)
    pub launch_errors: usize,
    /// requests that received a *success* completion — with
    /// `requests_failed` this extends the flush ledger to
    /// `completed + failed == requests` per shard
    pub requests_completed: usize,
    /// requests that received an *error* completion (shard panic or
    /// circuit break; still exactly one completion each)
    pub requests_failed: usize,
    /// supervised rebuilds after a flush panic
    pub restarts: usize,
    /// flushes served on the degraded (direct-fallback) rung of the
    /// ladder — demotion cooldowns and PJRT fallbacks
    pub degraded_flushes: usize,
    /// scripted faults this shard actually injected
    pub faults_injected: usize,
    /// the circuit breaker tripped: the shard died flapping and its
    /// traffic re-routed to the survivors
    pub circuit_broken: bool,
    /// message of the shard's most recent flush failure
    pub last_error: Option<String>,
    /// reply latency per completed request, seconds
    pub latency: Histogram,
    /// queued images sampled at each admission
    pub depth: Histogram,
    /// mean flushed-images / capacity over all launches
    pub batch_fill: f64,
}

/// Aggregate view over all shards plus engine-level counters.
#[derive(Clone, Debug)]
pub struct EngineReport {
    pub shards: Vec<ShardReport>,
    /// requests refused at admission (deadline unmeetable)
    pub rejected_deadline: usize,
    /// requests refused at admission because no live shard existed
    pub rejected_unavailable: usize,
    /// scripted faults injected engine-wide (the [`FaultPlan`]'s own
    /// count — includes engine-level faults such as `corrupt_load`
    /// that no shard counter sees)
    pub faults_injected: usize,
    pub cache: CacheStats,
    pub capacity: usize,
    pub pass: Pass,
}

impl EngineReport {
    pub fn requests(&self) -> usize {
        self.shards.iter().map(|s| s.requests).sum()
    }

    pub fn images(&self) -> usize {
        self.shards.iter().map(|s| s.images).sum()
    }

    pub fn launches(&self) -> usize {
        self.shards.iter().map(|s| s.launches).sum()
    }

    pub fn busy(&self) -> Duration {
        self.shards.iter().map(|s| s.busy).sum()
    }

    pub fn flushes_full(&self) -> usize {
        self.shards.iter().map(|s| s.flushes_full).sum()
    }

    pub fn flushes_timeout(&self) -> usize {
        self.shards.iter().map(|s| s.flushes_timeout).sum()
    }

    pub fn flushes_drain(&self) -> usize {
        self.shards.iter().map(|s| s.flushes_drain).sum()
    }

    pub fn spectra_hits(&self) -> usize {
        self.shards.iter().map(|s| s.spectra_hits).sum()
    }

    pub fn spectra_misses(&self) -> usize {
        self.shards.iter().map(|s| s.spectra_misses).sum()
    }

    pub fn spectra_invalidated(&self) -> usize {
        self.shards.iter().map(|s| s.spectra_invalidated).sum()
    }

    /// Newest weights version any shard was serving (every shard
    /// converges to it once the bump broadcast drains).
    pub fn weights_version(&self) -> u64 {
        self.shards.iter().map(|s| s.weights_version).max().unwrap_or(0)
    }

    /// All shards' per-flush weight-FFT samples merged.
    pub fn weight_fft(&self) -> Histogram {
        let mut h = Histogram::new();
        for s in &self.shards {
            h.merge(&s.weight_fft);
        }
        h
    }

    pub fn sla_miss(&self) -> usize {
        self.shards.iter().map(|s| s.sla_miss).sum()
    }

    pub fn launch_errors(&self) -> usize {
        self.shards.iter().map(|s| s.launch_errors).sum()
    }

    pub fn requests_completed(&self) -> usize {
        self.shards.iter().map(|s| s.requests_completed).sum()
    }

    pub fn requests_failed(&self) -> usize {
        self.shards.iter().map(|s| s.requests_failed).sum()
    }

    pub fn shard_restarts(&self) -> usize {
        self.shards.iter().map(|s| s.restarts).sum()
    }

    pub fn degraded_flushes(&self) -> usize {
        self.shards.iter().map(|s| s.degraded_flushes).sum()
    }

    /// Shards whose circuit breaker tripped.
    pub fn circuit_broken(&self) -> usize {
        self.shards.iter().filter(|s| s.circuit_broken).count()
    }

    /// All shards' latency samples merged (the aggregate percentiles).
    pub fn aggregate_latency(&self) -> Histogram {
        let mut h = Histogram::new();
        for s in &self.shards {
            h.merge(&s.latency);
        }
        h
    }

    /// Launch-weighted mean batch-fill ratio across shards.
    pub fn batch_fill(&self) -> f64 {
        let launches = self.launches();
        if launches == 0 {
            return 0.0;
        }
        self.shards
            .iter()
            .map(|s| s.batch_fill * s.launches as f64)
            .sum::<f64>()
            / launches as f64
    }
}

/// Cheap, cloneable submission handle — one per client thread. Holds
/// the shard senders, the shared depth gauges and the strategy cache;
/// admission runs entirely on the calling thread.
#[derive(Clone)]
pub struct EngineClient {
    txs: Vec<Sender<Msg>>,
    depths: Vec<Arc<AtomicUsize>>,
    health: Arc<Vec<ShardHealth>>,
    rejected: Arc<AtomicUsize>,
    rejected_unavailable: Arc<AtomicUsize>,
    rr: Arc<AtomicUsize>,
    weights_version: Arc<AtomicU64>,
    cache: Arc<StrategyCache>,
    problem: ConvProblem,
    pass: Pass,
    capacity: usize,
    default_deadline: Duration,
    max_wait: Duration,
}

impl EngineClient {
    /// Admit (or reject) a request. `Err` — with nothing sent on
    /// `reply` — when the deadline cannot cover the cached launch
    /// estimate for the request's own shape
    /// ([`SubmitError::DeadlineUnmeetable`]) or when every shard is
    /// dead ([`SubmitError::Unavailable`]). Accepted requests are
    /// routed to the least-loaded *live* shard and receive exactly one
    /// [`Completion`] — success or error. Submissions must not race
    /// [`ServeEngine::shutdown`]: stop every client first (an accepted
    /// request whose send lands after the worker's final drain would be
    /// dropped).
    ///
    /// Panics on a zero-image request (same contract as
    /// [`Batcher::push`]) — asserting here keeps the panic on the
    /// caller's thread instead of poisoning a shard worker.
    pub fn submit(&self, req: ServeRequest)
                  -> std::result::Result<(), SubmitError> {
        assert!(req.images >= 1, "empty request");
        let now = Instant::now();
        let sla = req.deadline.unwrap_or(now + self.default_deadline);
        let shape = ConvProblem {
            s: req.images.min(self.capacity),
            ..self.problem
        };
        let est = self
            .cache
            .lookup(&shape, self.pass)
            .map(|c| Duration::from_secs_f64(c.seconds))
            .unwrap_or(Duration::ZERO);
        if now + est > sla {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::DeadlineUnmeetable);
        }
        // least queued images among *live* shards wins; the start point
        // rotates so ties spread. A send that still fails (worker gone
        // without marking itself dead) marks the shard dead and retries
        // the survivors — the alive set shrinks, so this terminates.
        let images = req.images;
        let n = self.txs.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let mut msg = Msg::Req(Accepted {
            id: req.id,
            images,
            enqueued: now,
            flush_by: sla.min(now + self.max_wait),
            sla,
            reply: req.reply,
        });
        loop {
            let mut best: Option<usize> = None;
            let mut best_depth = usize::MAX;
            for i in 0..n {
                let s = (start + i) % n;
                if !self.health[s].is_alive() {
                    continue;
                }
                let d = self.depths[s].load(Ordering::Relaxed);
                if d < best_depth {
                    best = Some(s);
                    best_depth = d;
                }
            }
            let Some(best) = best else {
                self.rejected_unavailable.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::Unavailable);
            };
            self.depths[best].fetch_add(images, Ordering::Relaxed);
            match self.txs[best].send(msg) {
                Ok(()) => return Ok(()),
                Err(e) => {
                    self.depths[best].fetch_sub(images, Ordering::Relaxed);
                    self.health[best].mark_dead();
                    msg = e.0;
                }
            }
        }
    }

    /// Install a new weight tensor across every live shard and
    /// invalidate the cached weight spectra built from the old one. The
    /// bump is zero-downtime: each worker applies it between flushes,
    /// so batches flushed before the message arrives ride the old
    /// version and every later flush serves (and re-transforms once,
    /// lazily) the new one. Returns the new `weights_version`;
    /// `Err(Unavailable)` when no shard could take the bump.
    ///
    /// Panics when `weights` does not match the served problem's weight
    /// tensor (`fo·f·kh·kw` elements) — same caller-thread contract as
    /// [`EngineClient::submit`].
    pub fn update_weights(&self, weights: Vec<f32>)
                          -> std::result::Result<u64, SubmitError> {
        assert_eq!(weights.len(), self.problem.weight_len(),
                   "weight tensor shape mismatch");
        let version =
            self.weights_version.fetch_add(1, Ordering::Relaxed) + 1;
        let shared = Arc::new(weights);
        let mut delivered = 0usize;
        for (s, tx) in self.txs.iter().enumerate() {
            let msg = Msg::Weights { version, weights: shared.clone() };
            if tx.send(msg).is_ok() {
                delivered += 1;
            } else {
                self.health[s].mark_dead();
            }
        }
        if delivered == 0 {
            return Err(SubmitError::Unavailable);
        }
        Ok(version)
    }

    /// The version the next flush-after-drain will serve (starts at 1).
    pub fn weights_version(&self) -> u64 {
        self.weights_version.load(Ordering::Relaxed)
    }

    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Live per-shard health (alive flag, restart and failure counts).
    pub fn health(&self) -> &[ShardHealth] {
        &self.health
    }
}

/// Handle to the running sharded engine; `shutdown` flushes and joins.
pub struct ServeEngine {
    client: EngineClient,
    workers: Vec<JoinHandle<ShardReport>>,
    cache: Arc<StrategyCache>,
    faults: Option<Arc<FaultPlan>>,
}

struct WorkerCtx {
    shard: usize,
    backend: Backend,
    problem: ConvProblem,
    pass: Pass,
    batcher_cfg: BatcherConfig,
    cache: Arc<StrategyCache>,
    spectra: SpectrumPrecision,
    force: Option<Strategy>,
    depth: Arc<AtomicUsize>,
    health: Arc<Vec<ShardHealth>>,
    faults: Option<Arc<FaultPlan>>,
    restart_backoff: Duration,
    max_consecutive_failures: usize,
    degrade_cooldown: Duration,
    rx: Receiver<Msg>,
    ready: Sender<std::result::Result<(), String>>,
}

impl ServeEngine {
    /// Serve with the in-tree host engines — available everywhere (no
    /// artifacts or PJRT backend needed). Each flush dispatches through
    /// the strategy cache.
    pub fn start_host(problem: ConvProblem, cfg: EngineConfig)
                      -> Result<ServeEngine> {
        Self::start(Backend::Host, problem, cfg)
    }

    /// Serve a fixed AOT artifact; every worker owns its own PJRT
    /// [`Runtime`] (the client is not `Send`), so startup compiles the
    /// executable once per shard and surfaces any failure here.
    pub fn start_pjrt(artifacts_dir: PathBuf, artifact: String,
                      problem: ConvProblem, cfg: EngineConfig)
                      -> Result<ServeEngine> {
        if cfg.batcher.capacity > problem.s {
            return Err(anyhow!(
                "batcher capacity {} exceeds artifact batch S={}",
                cfg.batcher.capacity, problem.s));
        }
        Self::start(Backend::Pjrt { dir: artifacts_dir, artifact },
                    problem, cfg)
    }

    fn start(backend: Backend, problem: ConvProblem, cfg: EngineConfig)
             -> Result<ServeEngine> {
        assert!(cfg.shards >= 1, "engine needs at least one shard");
        let faults = cfg.faults.clone().or_else(FaultPlan::from_env);
        let mut cache = StrategyCache::open_with_faults(
            cfg.tuner_path.as_deref(), faults.as_deref());
        cache.reps = cfg.tuner_reps.max(1);
        // host serving of the weight-carrying passes runs through the
        // spectrum cache, so tune frequency candidates the same way —
        // the measured Choice then reflects steady-state (cached-weight)
        // flush cost, not the one-time weight FFT
        cache.serve_spectra = if matches!(backend, Backend::Host)
            && matches!(cfg.pass, Pass::Fprop | Pass::Bprop)
        {
            Some(cfg.spectra)
        } else {
            None
        };
        let cache = Arc::new(cache);
        // warm-tune the shapes every steady flush produces (full batches
        // and singletons); restarts hit the persisted entries instead
        if cfg.warm && matches!(backend, Backend::Host)
            && problem.stride == 1
        {
            for s in [1, cfg.batcher.capacity] {
                cache.ensure(&ConvProblem { s, ..problem }, cfg.pass);
            }
            cache.persist().ok(); // best-effort; shutdown retries
        }
        let (ready_tx, ready_rx) =
            mpsc::channel::<std::result::Result<(), String>>();
        let health: Arc<Vec<ShardHealth>> = Arc::new(
            (0..cfg.shards).map(|_| ShardHealth::default()).collect());
        let mut txs = Vec::with_capacity(cfg.shards);
        let mut depths = Vec::with_capacity(cfg.shards);
        let mut workers = Vec::with_capacity(cfg.shards);
        for shard in 0..cfg.shards {
            let (tx, rx) = mpsc::channel::<Msg>();
            let depth = Arc::new(AtomicUsize::new(0));
            let ctx = WorkerCtx {
                shard,
                backend: backend.clone(),
                problem,
                pass: cfg.pass,
                batcher_cfg: cfg.batcher,
                cache: cache.clone(),
                spectra: cfg.spectra,
                force: cfg.force_strategy,
                depth: depth.clone(),
                health: health.clone(),
                faults: faults.clone(),
                restart_backoff: cfg.restart_backoff,
                max_consecutive_failures: cfg.max_consecutive_failures,
                degrade_cooldown: cfg.degrade_cooldown,
                rx,
                ready: ready_tx.clone(),
            };
            workers.push(std::thread::spawn(move || worker_main(ctx)));
            txs.push(tx);
            depths.push(depth);
        }
        drop(ready_tx);
        let mut failure: Option<String> = None;
        for _ in 0..cfg.shards {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    failure = Some(e);
                    break;
                }
                Err(_) => {
                    failure = Some("worker died during startup".into());
                    break;
                }
            }
        }
        if let Some(e) = failure {
            drop(txs); // disconnect: healthy workers drain and exit
            for w in workers {
                w.join().ok();
            }
            return Err(anyhow!("serve engine startup: {e}"));
        }
        let client = EngineClient {
            txs,
            depths,
            health,
            rejected: Arc::new(AtomicUsize::new(0)),
            rejected_unavailable: Arc::new(AtomicUsize::new(0)),
            rr: Arc::new(AtomicUsize::new(0)),
            weights_version: Arc::new(AtomicU64::new(1)),
            cache: cache.clone(),
            problem,
            pass: cfg.pass,
            capacity: cfg.batcher.capacity,
            default_deadline: cfg.default_deadline,
            max_wait: cfg.batcher.max_wait,
        };
        Ok(ServeEngine { client, workers, cache, faults })
    }

    /// A cloneable submission handle for multi-threaded load.
    pub fn client(&self) -> EngineClient {
        self.client.clone()
    }

    /// Admit a request from the engine owner's thread. See
    /// [`EngineClient::submit`].
    pub fn submit(&self, req: ServeRequest)
                  -> std::result::Result<(), SubmitError> {
        self.client.submit(req)
    }

    /// Install new weights across the pool. See
    /// [`EngineClient::update_weights`].
    pub fn update_weights(&self, weights: Vec<f32>)
                          -> std::result::Result<u64, SubmitError> {
        self.client.update_weights(weights)
    }

    /// Live per-shard health. See [`EngineClient::health`].
    pub fn health(&self) -> &[ShardHealth] {
        self.client.health()
    }

    pub fn cache(&self) -> &StrategyCache {
        &self.cache
    }

    pub fn shards(&self) -> usize {
        self.workers.len()
    }

    /// Flush outstanding work, join every worker, persist the strategy
    /// cache, and return the merged report. Never propagates a worker
    /// panic: a worker that somehow died outside its supervised flush
    /// region yields an empty report for its shard instead of taking
    /// the caller down.
    pub fn shutdown(self) -> EngineReport {
        let ServeEngine { client, workers, cache, faults } = self;
        for tx in &client.txs {
            tx.send(Msg::Shutdown).ok();
        }
        let mut shards: Vec<ShardReport> = workers
            .into_iter()
            .enumerate()
            .map(|(i, w)| {
                w.join().unwrap_or_else(|_| {
                    eprintln!("serve: shard {i} worker died outside \
                               supervision; reporting empty");
                    ShardReport { shard: i, ..Default::default() }
                })
            })
            .collect();
        shards.sort_by_key(|r| r.shard);
        cache.persist().ok();
        let shard_faults: usize =
            shards.iter().map(|s| s.faults_injected).sum();
        EngineReport {
            shards,
            rejected_deadline: client.rejected.load(Ordering::Relaxed),
            rejected_unavailable: client
                .rejected_unavailable
                .load(Ordering::Relaxed),
            faults_injected: faults
                .map(|f| f.injected())
                .unwrap_or(shard_faults),
            cache: cache.stats(),
            capacity: client.capacity,
            pass: client.pass,
        }
    }
}

/// One request's reply-tracking state while any of its parts are queued
/// or in flight on the shard.
struct PendingReply {
    id: u64,
    remaining: usize,
    total: usize,
    enqueued: Instant,
    sla: Instant,
    reply: Sender<Completion>,
}

/// What one supervised flush produced (the `Ok` side of `catch_unwind`).
struct FlushOutcome {
    /// weight-FFT time actually spent (frequency strategies through the
    /// spectrum cache)
    wfft: Option<Duration>,
    /// served on the degraded (direct-fallback) rung of the ladder
    degraded: bool,
    /// the primary backend launch failed (PJRT error, non-finite output)
    launch_error: bool,
    /// scripted faults injected inside the flush
    injected: usize,
}

/// Best-effort human-readable panic payload.
fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "shard worker panicked".to_string()
    }
}

/// Deliver completions for every part of `batch`. With `error: None`
/// this is the success path (a split request completes only when its
/// last part lands); with `Some(err)` every request with a part in the
/// batch fails *entirely* — exactly one error completion, and later
/// flushes of its other parts find no pending entry (harmless).
fn complete_batch(batch: &Batch, pending: &mut Vec<PendingReply>,
                  report: &mut ShardReport, shard: usize, imgs: usize,
                  error: Option<ServeError>) {
    let now = Instant::now();
    for (id, n) in &batch.parts {
        let Some(pos) = pending.iter().position(|p| p.id == *id) else {
            continue;
        };
        if error.is_none() {
            pending[pos].remaining =
                pending[pos].remaining.saturating_sub(*n);
            if pending[pos].remaining > 0 {
                continue; // split request: more parts ride later batches
            }
        }
        let p = pending.remove(pos);
        let latency = now.duration_since(p.enqueued);
        match error {
            None => {
                let met = now <= p.sla;
                if !met {
                    report.sla_miss += 1;
                }
                report.latency.record(latency.as_secs_f64());
                report.requests_completed += 1;
                p.reply
                    .send(Completion {
                        id: p.id,
                        images: p.total,
                        latency,
                        batch_images: imgs,
                        shard,
                        deadline_met: met,
                        error: None,
                    })
                    .ok();
            }
            Some(err) => {
                report.requests_failed += 1;
                p.reply
                    .send(Completion {
                        id: p.id,
                        images: p.total,
                        latency,
                        batch_images: 0,
                        shard,
                        deadline_met: false,
                        error: Some(err),
                    })
                    .ok();
            }
        }
    }
}

fn worker_main(ctx: WorkerCtx) -> ShardReport {
    let WorkerCtx { shard, backend, problem, pass, batcher_cfg, cache,
                    spectra: spectra_precision, force, depth, health,
                    faults, restart_backoff, max_consecutive_failures,
                    degrade_cooldown, rx, ready } = ctx;
    let my_health = &health[shard];
    // backend setup runs before the readiness handshake so compile
    // failures surface from ServeEngine::start
    let rt = match &backend {
        Backend::Host => {
            ready.send(Ok(())).ok();
            None
        }
        Backend::Pjrt { dir, artifact } => {
            match Runtime::open(dir)
                .and_then(|rt| rt.executable(artifact).map(|_| rt))
            {
                Ok(rt) => {
                    ready.send(Ok(())).ok();
                    Some(rt)
                }
                Err(e) => {
                    ready.send(Err(format!("{e:#}"))).ok();
                    return ShardReport { shard, ..Default::default() };
                }
            }
        }
    };
    drop(ready);

    let mut batcher = Batcher::new(batcher_cfg);
    let capacity = batcher_cfg.capacity;
    let mut pending: Vec<PendingReply> = Vec::new();
    let mut report = ShardReport { shard, ..Default::default() };
    let mut rng = Rng::new(0xC0FFEE ^ shard as u64);
    let mut ws = Workspace::new();
    let mut stage = BufferPool::new();
    if let Some(f) = &faults {
        stage.set_faults(f.clone(), Some(shard));
    }
    // the layer's weights live on the shard (one buffered copy, §3.3),
    // alongside the spectra transformed from them — keyed by the
    // version so a bump invalidates exactly the stale entries
    let mut weights = rng.normal_vec(problem.weight_len());
    let mut weights_version: u64 = 1;
    let mut spectra = SpectrumCache::new(spectra_precision);
    report.weights_version = weights_version;
    let mut fill_sum = 0f64;
    let mut done = false;
    loop {
        // ---- receive phase --------------------------------------------
        let mut msgs: Vec<Msg> = Vec::new();
        // a backlog of a full batch must flush now — don't sleep on the
        // deadline when the capacity policy already says launch
        let backlog_full = batcher.queued_images() >= capacity;
        if !done && !backlog_full {
            if batcher.is_empty() {
                // idle: park on the channel indefinitely — the batcher
                // has no deadline to honor, so there is nothing to poll
                match rx.recv() {
                    Ok(m) => msgs.push(m),
                    Err(_) => done = true,
                }
            } else {
                // work queued: sleep until the earliest flush-by moment
                let timeout = batcher
                    .deadline()
                    .map(|d| d.saturating_duration_since(Instant::now()))
                    .unwrap_or(Duration::ZERO);
                match rx.recv_timeout(timeout) {
                    Ok(m) => msgs.push(m),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => done = true,
                }
            }
        }
        // drain whatever else already arrived without blocking — also
        // after shutdown, so requests already queued behind the
        // shutdown message still complete (submissions must not *race*
        // shutdown, though: see EngineClient::submit)
        while let Ok(m) = rx.try_recv() {
            msgs.push(m);
        }
        for m in msgs {
            match m {
                Msg::Req(a) => {
                    batcher.push_deadline(a.id, a.images, a.enqueued,
                                          a.flush_by);
                    pending.push(PendingReply {
                        id: a.id,
                        remaining: a.images,
                        total: a.images,
                        enqueued: a.enqueued,
                        sla: a.sla,
                        reply: a.reply,
                    });
                    report.requests += 1;
                    report.images += a.images;
                    report.depth.record(batcher.queued_images() as f64);
                }
                Msg::Weights { version, weights: w } => {
                    // applied between flushes: already-flushed batches
                    // rode the old version, everything later serves the
                    // new one (bumps can arrive reordered only relative
                    // to newer bumps — never regress)
                    if version > weights_version {
                        weights.clear();
                        weights.extend_from_slice(&w);
                        weights_version = version;
                        spectra.bump(&problem, version);
                        report.weights_version = version;
                    }
                }
                Msg::Shutdown => done = true,
            }
        }
        // ---- flush phase ----------------------------------------------
        let batch = if done {
            let b = batcher.drain();
            if b.is_empty() {
                break;
            }
            b
        } else {
            match batcher.poll(Instant::now()) {
                Some(b) => b,
                None => continue,
            }
        };
        let imgs = batch.images();
        // the scripted-panic probe counts this flush *before* the
        // supervised region so the occurrence index is deterministic
        // even when the launch itself panics for another reason
        let inject_panic = faults
            .as_ref()
            .map_or(false,
                    |f| f.fire(FaultKind::Panic, Some(shard)));
        let t0 = Instant::now();
        // ---- supervised region ----------------------------------------
        // Everything that can panic — backend launches, staging-pool
        // checkouts, spectrum transforms — runs under catch_unwind. A
        // panic must fail this batch (error completions, exactly-once),
        // never the whole engine.
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if inject_panic {
                panic!("injected shard panic (FaultPlan, shard {shard})");
            }
            match &rt {
                Some(rt) => {
                    let Backend::Pjrt { artifact, .. } = &backend else {
                        unreachable!("runtime without PJRT backend")
                    };
                    // demotion is keyed batch-size-normalized so one
                    // bad launch covers every flush shape
                    let dkey = ConvProblem { s: 0, ..problem };
                    if cache.is_demoted(&dkey, pass) {
                        // cooldown: serve the host direct fallback
                        let mut o = launch_host(
                            &cache, Some(Strategy::Direct), pass,
                            &problem, imgs, &weights, weights_version,
                            &mut spectra, &mut rng, &mut stage, &mut ws,
                            None, shard, degrade_cooldown);
                        o.degraded = true;
                        o
                    } else if launch_pjrt(rt, artifact, &problem, imgs,
                                          &weights, &mut rng) {
                        FlushOutcome { wfft: None, degraded: false,
                                       launch_error: false, injected: 0 }
                    } else {
                        // PJRT runtime error (already logged): demote
                        // the problem and serve this flush on the host
                        // direct fallback instead of dropping it
                        cache.demote(&dkey, pass,
                                     Instant::now() + degrade_cooldown);
                        let mut o = launch_host(
                            &cache, Some(Strategy::Direct), pass,
                            &problem, imgs, &weights, weights_version,
                            &mut spectra, &mut rng, &mut stage, &mut ws,
                            None, shard, degrade_cooldown);
                        o.degraded = true;
                        o.launch_error = true;
                        o
                    }
                }
                None => launch_host(&cache, force, pass, &problem, imgs,
                                    &weights, weights_version,
                                    &mut spectra, &mut rng, &mut stage,
                                    &mut ws, faults.as_deref(), shard,
                                    degrade_cooldown),
            }
        }));
        let elapsed = t0.elapsed();
        report.launches += 1;
        report.busy += elapsed;
        fill_sum += imgs as f64 / capacity as f64;
        depth.fetch_sub(imgs, Ordering::Relaxed);
        match outcome {
            Ok(o) => {
                report.faults_injected += o.injected;
                if let Some(d) = o.wfft {
                    report.weight_fft.record(d.as_secs_f64());
                }
                if o.degraded {
                    report.degraded_flushes += 1;
                }
                if o.launch_error {
                    report.launch_errors += 1;
                }
                if !o.launch_error && !o.degraded && rt.is_some() {
                    // no host tuner runs for a compiled artifact; feed
                    // measured launch times back so deadline admission
                    // has an estimate (clean launches only — fallback
                    // timings would poison the estimate)
                    cache.observe(&ConvProblem { s: imgs, ..problem },
                                  pass, Strategy::Vendor,
                                  elapsed.as_secs_f64());
                }
                my_health.record_success();
                complete_batch(&batch, &mut pending, &mut report, shard,
                               imgs, None);
            }
            Err(payload) => {
                let msg = panic_msg(payload.as_ref());
                eprintln!("serve: shard {shard} flush panicked: {msg}");
                if inject_panic {
                    report.faults_injected += 1;
                }
                report.launch_errors += 1;
                // the batch is gone from the batcher: fail its requests
                // with error completions (exactly-once — a hung client
                // is worse than a served error)
                complete_batch(&batch, &mut pending, &mut report, shard,
                               imgs, Some(ServeError::ShardPanic));
                let consecutive = my_health.record_failure(&msg);
                report.last_error = Some(msg);
                if consecutive >= max_consecutive_failures {
                    // ---- circuit breaker --------------------------------
                    // flapping: mark the shard dead so admission routes
                    // around it, fail everything still queued, then
                    // dead-letter the channel until shutdown
                    my_health.mark_dead();
                    report.circuit_broken = true;
                    eprintln!("serve: shard {shard} circuit-broken \
                               after {consecutive} consecutive failures");
                    loop {
                        let b = batcher.drain();
                        if b.is_empty() {
                            break;
                        }
                        let n = b.images();
                        report.launches += 1; // ledger: drains count
                        fill_sum += n as f64 / capacity as f64;
                        depth.fetch_sub(n, Ordering::Relaxed);
                        complete_batch(
                            &b, &mut pending, &mut report, shard, n,
                            Some(ServeError::ShardUnavailable));
                    }
                    for p in pending.drain(..) {
                        report.requests_failed += 1;
                        p.reply
                            .send(Completion {
                                id: p.id,
                                images: p.total,
                                latency: p.enqueued.elapsed(),
                                batch_images: 0,
                                shard,
                                deadline_met: false,
                                error: Some(ServeError::ShardUnavailable),
                            })
                            .ok();
                    }
                    // dead-letter: racing submissions fail fast instead
                    // of hanging their clients (skipped when shutdown
                    // already arrived — nothing more can be sent)
                    while !done {
                        match rx.recv() {
                            Ok(Msg::Req(a)) => {
                                depth.fetch_sub(a.images,
                                                Ordering::Relaxed);
                                report.requests += 1;
                                report.images += a.images;
                                report.requests_failed += 1;
                                a.reply
                                    .send(Completion {
                                        id: a.id,
                                        images: a.images,
                                        latency: a.enqueued.elapsed(),
                                        batch_images: 0,
                                        shard,
                                        deadline_met: false,
                                        error: Some(
                                            ServeError::ShardUnavailable),
                                    })
                                    .ok();
                            }
                            Ok(Msg::Weights { .. }) => {}
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    }
                    break;
                }
                // ---- supervised restart -----------------------------
                // rebuild every piece of flush-local state the panic
                // could have left inconsistent (workspace scratch,
                // checked-out staging buffers, half-built spectra);
                // the batcher and pending queue were outside the
                // supervised region and stay live
                report.restarts += 1;
                my_health.record_restart();
                report.faults_injected += stage.faults_injected;
                spectra.clear();
                ws = Workspace::new();
                stage = BufferPool::new();
                if let Some(f) = &faults {
                    stage.set_faults(f.clone(), Some(shard));
                }
                let backoff = restart_backoff
                    * (1u32 << (consecutive.min(6) as u32 - 1));
                std::thread::sleep(
                    backoff.min(Duration::from_millis(500)));
                continue;
            }
        }
    }
    report.flushes_full = batcher.flushes_full;
    report.flushes_timeout = batcher.flushes_timeout;
    report.flushes_drain = batcher.flushes_drain;
    // SpectrumCache::clear keeps its counters across supervised
    // restarts, so plain assignment still accounts for pre-crash work
    report.spectra_hits = spectra.hits;
    report.spectra_misses = spectra.misses;
    report.spectra_invalidated = spectra.invalidated;
    report.faults_injected += stage.faults_injected;
    if report.launches > 0 {
        report.batch_fill = fill_sum / report.launches as f64;
    }
    report
}

/// One PJRT launch: pad the flushed images to the artifact batch S.
fn launch_pjrt(rt: &Runtime, artifact: &str, p: &ConvProblem,
               imgs: usize, weights: &[f32], rng: &mut Rng) -> bool {
    // PJRT literals consume their Vec, so this path allocates per launch
    let mut x = vec![0f32; p.input_len()];
    let live = imgs * p.f * p.h * p.w;
    for v in x[..live].iter_mut() {
        *v = rng.normal();
    }
    let result = rt.execute_1f32(
        artifact,
        &[HostTensor::f32(x, &[p.s, p.f, p.h, p.w]),
          HostTensor::f32(weights.to_vec(),
                          &[p.fo, p.f, p.kh, p.kw])]);
    if let Err(e) = result {
        eprintln!("serve: launch failed: {e:#}");
        return false;
    }
    true
}

/// One host-engine launch of a `imgs`-image batch: look the flush shape
/// up in the strategy cache (tuning once on first sight) and dispatch
/// the winner through the shard's workspace. Operand staging is pooled
/// (allocation-free after warmup); the frequency engines also write
/// their output through the pool, while the time-domain engines
/// allocate their result by API design (no redundant pooled copy is
/// layered on top).
///
/// Degradation ladder: a problem inside a demotion cooldown serves the
/// direct fallback instead of its tuned frequency strategy; a
/// frequency flush whose output scans non-finite demotes the problem
/// (cooldown keyed batch-size-normalized, `s = 0`) and re-serves the
/// flush on direct. The returned [`FlushOutcome`] carries the
/// weight-FFT time actually spent (`Some(ZERO)` on a spectrum hit —
/// the steady state), the degraded/launch-error flags, and any
/// scripted `nonfinite` faults injected.
#[allow(clippy::too_many_arguments)]
fn launch_host(cache: &StrategyCache, force: Option<Strategy>, pass: Pass,
               p: &ConvProblem, imgs: usize, weights: &[f32],
               version: u64, spectra: &mut SpectrumCache, rng: &mut Rng,
               stage: &mut BufferPool, ws: &mut Workspace,
               faults: Option<&FaultPlan>, shard: usize,
               cooldown: Duration)
               -> FlushOutcome {
    let q = ConvProblem { s: imgs, ..*p };
    // demotion is keyed batch-size-normalized (s = 0) so one bad
    // output covers every flush shape of the problem at once
    let dkey = ConvProblem { s: 0, ..*p };
    let mut outcome = FlushOutcome { wfft: None, degraded: false,
                                     launch_error: false, injected: 0 };
    let mut choice = match force {
        // deterministic probe: serve the forced strategy at its default
        // basis without consulting (or populating) the tuner
        Some(strategy) => Choice { strategy, n_fft: None, seconds: 0.0 },
        None => cache.ensure(&q, pass),
    };
    let fallback =
        Choice { strategy: Strategy::Direct, n_fft: None, seconds: 0.0 };
    let frequency = matches!(
        choice.strategy,
        Strategy::VendorFft | Strategy::Fbfft | Strategy::FbfftScalar);
    if frequency && cache.is_demoted(&dkey, pass) {
        choice = fallback;
        outcome.degraded = true;
    }
    // the "payload": a fresh synthetic operand per flush
    let a_len = match pass {
        Pass::Fprop => q.input_len(),
        Pass::Bprop | Pass::AccGrad => q.output_len(),
    };
    let mut a = stage.take_raw("serve.a", a_len);
    for v in a.iter_mut() {
        *v = rng.normal();
    }
    if frequency && !outcome.degraded {
        if let Some(plan) = faults {
            if plan.fire(FaultKind::NonFinite, Some(shard)) {
                outcome.injected += 1;
                a[0] = f32::NAN;
            }
        }
    }
    match pass {
        Pass::AccGrad => {
            // accGrad pairs the gradient with an activation, not weights
            let mut b = stage.take_raw("serve.b", q.input_len());
            for v in b.iter_mut() {
                *v = rng.normal();
            }
            let (_, finite) =
                run_strategy(&choice, &q, pass, &a, &b, None, stage, ws);
            if !finite {
                cache.demote(&dkey, pass, Instant::now() + cooldown);
                eprintln!("serve: non-finite {:?} output on shard \
                           {shard}; demoting to direct",
                          choice.strategy);
                for v in a.iter_mut() {
                    *v = rng.normal();
                }
                run_strategy(&fallback, &q, pass, &a, &b, None, stage,
                             ws);
                outcome.degraded = true;
                outcome.launch_error = true;
            }
            stage.put("serve.b", b);
        }
        _ => {
            let (wfft, finite) =
                run_strategy(&choice, &q, pass, &a, weights,
                             Some((spectra, version)), stage, ws);
            if !finite {
                cache.demote(&dkey, pass, Instant::now() + cooldown);
                eprintln!("serve: non-finite {:?} output on shard \
                           {shard}; demoting to direct",
                          choice.strategy);
                // re-serve the flush on the always-correct path with a
                // regenerated operand (the bad values must not leak
                // into the fallback result)
                for v in a.iter_mut() {
                    *v = rng.normal();
                }
                run_strategy(&fallback, &q, pass, &a, weights, None,
                             stage, ws);
                outcome.degraded = true;
                outcome.launch_error = true;
            } else {
                outcome.wfft = wfft;
            }
        }
    }
    stage.put("serve.a", a);
    outcome
}

/// Dispatch one pass through the tuned strategy. `a`/`b` follow each
/// engine's own operand order: (x, weights) for fprop, (grad_output,
/// weights) for bprop, (grad_output, x) for accGrad. When `b` is the
/// weight tensor the caller passes the shard's spectrum cache and the
/// live `weights_version`; frequency strategies then serve from the
/// cached spectrum — skipping the weight pad+FFT on a hit — and the
/// `Option<Duration>` is the weight-FFT time actually spent. The bool
/// is the output-health verdict: frequency outputs are scanned for
/// non-finite values (the paper's frequency path is where numerical
/// blowups surface); the time-domain engines always report healthy.
#[allow(clippy::too_many_arguments)]
fn run_strategy(choice: &Choice, q: &ConvProblem, pass: Pass, a: &[f32],
                b: &[f32], spectra: Option<(&mut SpectrumCache, u64)>,
                stage: &mut BufferPool, ws: &mut Workspace)
                -> (Option<Duration>, bool) {
    match choice.strategy {
        Strategy::VendorFft | Strategy::Fbfft | Strategy::FbfftScalar => {
            let out_len = match pass {
                Pass::Fprop => q.output_len(),
                Pass::Bprop => q.input_len(),
                Pass::AccGrad => q.weight_len(),
            };
            let mut out = stage.take_raw("serve.out", out_len);
            let mode = match choice.strategy {
                Strategy::VendorFft => FftMode::Vendor,
                Strategy::Fbfft => FftMode::Fbfft,
                _ => FftMode::FbfftScalar,
            };
            let n = choice
                .n_fft
                .unwrap_or_else(|| q.h.max(q.w).next_power_of_two());
            let eng = FftConvEngine::new(mode, n);
            let wfft = match (pass, spectra) {
                (Pass::Fprop, Some((spectra, version))) => {
                    let (spec, took) =
                        spectra.ensure(&eng, q, b, version, ws);
                    eng.fprop_spec_into(q, a, spec, &mut out, ws);
                    Some(took)
                }
                (Pass::Bprop, Some((spectra, version))) => {
                    let (spec, took) =
                        spectra.ensure(&eng, q, b, version, ws);
                    eng.bprop_spec_into(q, a, spec, &mut out, ws);
                    Some(took)
                }
                (Pass::Fprop, None) => {
                    eng.fprop_into(q, a, b, &mut out, ws);
                    None
                }
                (Pass::Bprop, None) => {
                    eng.bprop_into(q, a, b, &mut out, ws);
                    None
                }
                (Pass::AccGrad, _) => {
                    eng.accgrad_into(q, a, b, &mut out, ws);
                    None
                }
            };
            let finite = out.iter().all(|v| v.is_finite());
            stage.put("serve.out", out);
            (wfft, finite)
        }
        // the vendor black box has no host twin; direct is its analogue
        Strategy::Direct | Strategy::Vendor => {
            let _ = match pass {
                Pass::Fprop => direct::fprop(q, a, b),
                Pass::Bprop => direct::bprop(q, a, b),
                Pass::AccGrad => direct::accgrad(q, a, b),
            };
            (None, true)
        }
        Strategy::Im2col => {
            let _ = match pass {
                Pass::Fprop => im2col::fprop(q, a, b),
                Pass::Bprop => im2col::bprop(q, a, b),
                Pass::AccGrad => im2col::accgrad(q, a, b),
            };
            (None, true)
        }
        Strategy::FbfftTiled(d) => {
            let _ = match pass {
                Pass::Fprop => tiled::fprop(q, a, b, d),
                Pass::Bprop => tiled::bprop(q, a, b, d),
                Pass::AccGrad => tiled::accgrad(q, a, b, d),
            };
            (None, true)
        }
    }
}

// ---------------------------------------------------------------------------
// Legacy single-shard PJRT wrapper
// ---------------------------------------------------------------------------

/// Aggregate statistics returned at shutdown (legacy surface).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceReport {
    pub requests: usize,
    pub images: usize,
    pub launches: usize,
    pub busy: Duration,
    pub flushes_full: usize,
    pub flushes_timeout: usize,
}

/// The original single-worker PJRT service, now a one-shard
/// [`ServeEngine`] (same admission loop, same report shape).
pub struct ConvService {
    engine: ServeEngine,
}

impl ConvService {
    /// Serve the named fprop artifact from `artifacts_dir`.
    pub fn start(artifacts_dir: PathBuf, artifact: String,
                 problem: ConvProblem, cfg: BatcherConfig)
                 -> Result<ConvService> {
        let engine = ServeEngine::start_pjrt(
            artifacts_dir,
            artifact,
            problem,
            EngineConfig {
                shards: 1,
                batcher: cfg,
                // the legacy API has no SLA concept: never reject
                default_deadline: Duration::from_secs(3600),
                warm: false,
                ..Default::default()
            })?;
        Ok(ConvService { engine })
    }

    pub fn submit(&self, req: ServeRequest) {
        let accepted = self.engine.submit(req);
        debug_assert!(accepted.is_ok(), "legacy service never rejects");
    }

    /// Flush outstanding work and join the worker.
    pub fn shutdown(self) -> ServiceReport {
        let r = self.engine.shutdown();
        ServiceReport {
            requests: r.requests(),
            images: r.images(),
            launches: r.launches(),
            busy: r.busy(),
            flushes_full: r.flushes_full(),
            flushes_timeout: r.flushes_timeout(),
        }
    }
}

#[cfg(test)]
mod tests {
    // PJRT-backed behaviour is covered by rust/tests/integration.rs;
    // the host-backend engine is exercised end-to-end (multi-shard soak,
    // admission, batcher paths) in rust/tests/serve.rs. Here: report
    // arithmetic and the admission fast-paths.
    use super::*;

    #[test]
    fn report_defaults_are_zero() {
        let r = ServiceReport::default();
        assert_eq!(r.requests + r.images + r.launches, 0);
        assert_eq!(r.busy, Duration::ZERO);
    }

    #[test]
    fn engine_report_aggregates_across_shards() {
        let mut a = ShardReport { shard: 0, ..Default::default() };
        a.requests = 3;
        a.images = 7;
        a.launches = 2;
        a.batch_fill = 0.5;
        a.latency.record(0.010);
        let mut b = ShardReport { shard: 1, ..Default::default() };
        b.requests = 1;
        b.images = 2;
        b.launches = 1;
        b.batch_fill = 1.0;
        b.latency.record(0.030);
        let r = EngineReport {
            shards: vec![a, b],
            rejected_deadline: 4,
            rejected_unavailable: 0,
            faults_injected: 0,
            cache: CacheStats::default(),
            capacity: 8,
            pass: Pass::Fprop,
        };
        assert_eq!(r.requests(), 4);
        assert_eq!(r.images(), 9);
        assert_eq!(r.launches(), 3);
        let mut agg = r.aggregate_latency();
        assert_eq!(agg.len(), 2);
        assert_eq!(agg.summary().max, 0.030);
        // launch-weighted fill: (0.5·2 + 1.0·1) / 3
        assert!((r.batch_fill() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn expired_deadline_is_rejected_at_admission() {
        let p = ConvProblem::square(4, 1, 1, 8, 3);
        let engine = ServeEngine::start_host(
            p,
            EngineConfig {
                shards: 2,
                batcher: BatcherConfig {
                    capacity: 4,
                    max_wait: Duration::from_millis(1),
                },
                warm: false,
                ..Default::default()
            })
            .expect("host engine always starts");
        let (tx, rx) = mpsc::channel::<Completion>();
        let expired = Instant::now() - Duration::from_millis(1);
        let accepted = engine.submit(ServeRequest {
            id: 1,
            images: 1,
            deadline: Some(expired),
            reply: tx.clone(),
        });
        assert_eq!(accepted, Err(SubmitError::DeadlineUnmeetable),
                   "expired deadline must be rejected");
        let accepted = engine.submit(ServeRequest {
            id: 2,
            images: 1,
            deadline: None,
            reply: tx,
        });
        assert!(accepted.is_ok());
        let c = rx.recv_timeout(Duration::from_secs(30))
            .expect("accepted request completes");
        assert_eq!(c.id, 2);
        assert_eq!(c.images, 1);
        let report = engine.shutdown();
        assert_eq!(report.rejected_deadline, 1);
        assert_eq!(report.requests(), 1);
        assert_eq!(report.images(), 1);
    }
}
