//! The serving loop: a worker thread owns the PJRT runtime and drains a
//! request channel through the dynamic batcher into executable launches.
//! (tokio is unavailable offline; std threads + channels implement the
//! same event loop — the worker parks on the channel with a timeout equal
//! to the batcher's next deadline.)

use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::conv::ConvProblem;
use crate::runtime::{HostTensor, Runtime};
use crate::util::Rng;

use super::batcher::{Batcher, BatcherConfig};

/// A conv inference request: `images` samples for the served layer.
pub struct ServeRequest {
    pub id: u64,
    pub images: usize,
    /// sent back on completion: (id, images, latency)
    pub reply: Sender<Completion>,
}

#[derive(Clone, Copy, Debug)]
pub struct Completion {
    pub id: u64,
    pub images: usize,
    pub latency: Duration,
    /// images in the flushed batch this request rode in (batching factor)
    pub batch_images: usize,
}

/// Handle to a running service; drop after `shutdown` to join.
pub struct ConvService {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<ServiceReport>>,
}

enum Msg {
    Req(ServeRequest, Instant),
    Shutdown,
}

/// Aggregate statistics returned at shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceReport {
    pub requests: usize,
    pub images: usize,
    pub launches: usize,
    pub busy: Duration,
    pub flushes_full: usize,
    pub flushes_timeout: usize,
}

impl ConvService {
    /// Serve the named fprop artifact from `artifacts_dir`. The PJRT
    /// client is not `Send`, so the worker thread owns the whole runtime;
    /// a handshake channel surfaces startup (compile) failures.
    pub fn start(artifacts_dir: PathBuf, artifact: String,
                 problem: ConvProblem, cfg: BatcherConfig)
                 -> Result<ConvService> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let art = artifact.clone();
        let worker = std::thread::spawn(move || {
            let rt = match Runtime::open(&artifacts_dir)
                .and_then(|rt| rt.executable(&art).map(|_| rt))
            {
                Ok(rt) => {
                    ready_tx.send(Ok(())).ok();
                    rt
                }
                Err(e) => {
                    ready_tx.send(Err(format!("{e:#}"))).ok();
                    return ServiceReport::default();
                }
            };
            serve_loop(rt, art, problem, cfg, rx)
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("service worker died during startup"))?
            .map_err(|e| anyhow!("service startup: {e}"))?;
        Ok(ConvService { tx, worker: Some(worker) })
    }

    pub fn submit(&self, req: ServeRequest) {
        self.tx
            .send(Msg::Req(req, Instant::now()))
            .expect("service worker gone");
    }

    /// Flush outstanding work and join the worker.
    pub fn shutdown(mut self) -> ServiceReport {
        self.tx.send(Msg::Shutdown).ok();
        self.worker
            .take()
            .expect("double shutdown")
            .join()
            .expect("worker panicked")
    }
}

fn serve_loop(rt: Runtime, artifact: String, problem: ConvProblem,
              cfg: BatcherConfig, rx: Receiver<Msg>) -> ServiceReport {
    let mut batcher = Batcher::new(cfg);
    let mut pending: Vec<(u64, usize, Instant, Sender<Completion>)> =
        Vec::new();
    let mut report = ServiceReport::default();
    let mut rng = Rng::new(0xC0FFEE);
    // the layer's weights live on the service (one copy, §3.3)
    let weights = rng.normal_vec(problem.weight_len());
    let mut done = false;
    while !done || !batcher.is_empty() {
        // wait for work or the batcher's deadline
        if !done {
            let timeout = batcher
                .deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(Duration::from_millis(50));
            match rx.recv_timeout(timeout) {
                Ok(Msg::Req(r, t)) => {
                    batcher.push(r.id, r.images, t);
                    pending.push((r.id, r.images, t, r.reply));
                    report.requests += 1;
                    report.images += r.images;
                }
                Ok(Msg::Shutdown) => done = true,
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => done = true,
            }
        }
        let flush = if done {
            let b = batcher.drain();
            if b.is_empty() { None } else { Some(b) }
        } else {
            batcher.poll(Instant::now())
        };
        let Some(batch) = flush else { continue };
        // assemble the padded minibatch and launch
        let t0 = Instant::now();
        let imgs = batch.images();
        let mut x = rng.normal_vec(imgs * problem.f * problem.h * problem.w);
        x.resize(problem.input_len(), 0.0); // zero-pad to artifact batch S
        let result = rt.execute_1f32(
            &artifact,
            &[HostTensor::f32(x, &[problem.s, problem.f, problem.h,
                                   problem.w]),
              HostTensor::f32(weights.clone(),
                              &[problem.fo, problem.f, problem.kh,
                                problem.kw])]);
        let elapsed = t0.elapsed();
        report.launches += 1;
        report.busy += elapsed;
        if let Err(e) = result {
            eprintln!("serve: launch failed: {e:#}");
            continue;
        }
        // complete every request that rode in this batch
        for (id, n) in &batch.parts {
            // a request may be split across batches; complete the part
            if let Some(pos) = pending.iter().position(|(pid, _, _, _)|
                                                       pid == id) {
                let (_, total, t_in, reply) = &pending[pos];
                let latency = t0.elapsed() + t0.duration_since(*t_in);
                reply
                    .send(Completion { id: *id, images: *n,
                                       latency, batch_images: imgs })
                    .ok();
                if *n >= *total {
                    pending.remove(pos);
                } else {
                    pending[pos].1 -= n;
                }
            }
        }
    }
    report.flushes_full = batcher.flushes_full;
    report.flushes_timeout = batcher.flushes_timeout;
    report
}

#[cfg(test)]
mod tests {
    // The service needs real artifacts; its end-to-end behaviour is
    // covered by rust/tests/integration.rs and examples/conv_server.rs.
    // Here we only pin the report arithmetic.
    use super::*;

    #[test]
    fn report_defaults_are_zero() {
        let r = ServiceReport::default();
        assert_eq!(r.requests + r.images + r.launches, 0);
        assert_eq!(r.busy, Duration::ZERO);
    }
}
