//! The serving layer: a sharded multi-worker engine behind a
//! deadline-aware dynamic batcher.
//!
//! Architecture (tokio is unavailable offline; std threads + channels
//! implement the same event loop):
//!
//! ```text
//!            submit()                 mpsc            worker pool
//!  clients ──────────▶ admission ───────────▶ shard 0 [Batcher|Workspace|BufferPool|Runtime?]
//!            deadline   │ least-loaded        shard 1 [Batcher|Workspace|BufferPool|Runtime?]
//!            check      │ routing      ···    shard N [Batcher|Workspace|BufferPool|Runtime?]
//!                       ▼
//!              StrategyCache (shared, persistent JSON)
//! ```
//!
//! * **Admission** ([`EngineClient::submit`]): requests carry an SLA
//!   deadline (or inherit the engine default). A request whose deadline
//!   cannot cover even the cached launch estimate for its own shape is
//!   rejected up front (`rejected_deadline` in the report) instead of
//!   wasting a batch slot; accepted requests go to the shard with the
//!   fewest queued images (round-robin tie-break).
//! * **Workers**: each shard is one `std::thread` owning its own
//!   [`Batcher`], [`Workspace`], staging [`BufferPool`], RNG, one
//!   buffered weights copy (§3.3), and — in PJRT mode — its own
//!   [`Runtime`]. An idle worker parks on its channel *indefinitely*;
//!   only a non-empty batcher arms `recv_timeout` with the earliest
//!   flush-by deadline (no idle spinning).
//! * **Strategy cache** ([`StrategyCache`]): every flush of `b` images
//!   is the problem `{s: b, ..served}`; the worker looks the shape up
//!   and runs the best known [`Strategy`] — the §3.4 tuner populates
//!   the cache once per shape (persisted as JSON, warm-loaded at
//!   startup) so the steady-state hot path never re-tunes.
//! * **Metrics**: per-shard latency/queue-depth [`Histogram`]s,
//!   batch-fill ratio, SLA misses and flush counters, merged into the
//!   aggregate view by [`EngineReport`] and rendered by
//!   [`reports::serve`](crate::reports::serve).
//!
//! [`ConvService`] survives as the single-shard PJRT wrapper the
//! original examples were written against.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::conv::{direct, im2col, tiled, ConvProblem, FftConvEngine,
                  FftMode, SpectrumCache, SpectrumPrecision, Workspace};
use crate::metrics::Histogram;
use crate::runtime::{HostTensor, Runtime};
use crate::util::Rng;

use super::autotuner::{CacheStats, Choice, StrategyCache};
use super::batcher::{Batcher, BatcherConfig};
use super::buffers::BufferPool;
use super::strategy::{Pass, Strategy};

/// A conv inference request: `images` samples for the served layer.
pub struct ServeRequest {
    pub id: u64,
    pub images: usize,
    /// SLA deadline for the reply; `None` inherits the engine default.
    pub deadline: Option<Instant>,
    /// sent back exactly once, when every image has been served
    pub reply: Sender<Completion>,
}

#[derive(Clone, Copy, Debug)]
pub struct Completion {
    pub id: u64,
    pub images: usize,
    pub latency: Duration,
    /// images in the last flushed batch this request rode in
    pub batch_images: usize,
    /// which shard served the request
    pub shard: usize,
    /// whether the reply beat the request's SLA deadline
    pub deadline_met: bool,
}

/// How the worker pool executes a flushed batch.
#[derive(Clone, Debug)]
enum Backend {
    /// In-tree host engines dispatched through the strategy cache.
    Host,
    /// One PJRT runtime per worker, serving a fixed AOT artifact.
    Pjrt { dir: PathBuf, artifact: String },
}

/// Engine-wide configuration (per-shard knobs live in [`BatcherConfig`]).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// worker-pool width (N shards, one OS thread each)
    pub shards: usize,
    pub batcher: BatcherConfig,
    /// SLA budget applied to requests that carry no explicit deadline
    pub default_deadline: Duration,
    /// which training pass the engine serves (fprop for inference)
    pub pass: Pass,
    /// strategy-cache warm-load/persist location (`None` = in-memory)
    pub tuner_path: Option<PathBuf>,
    /// measurement repetitions when a flush shape misses the cache
    pub tuner_reps: usize,
    /// tune the {1, capacity}-image shapes before accepting traffic
    pub warm: bool,
    /// storage precision of the per-shard weight-spectrum cache
    /// (default: f16 unless `FBFFT_SPECTRA=f32`)
    pub spectra: SpectrumPrecision,
    /// bypass the tuner and serve every flush with this strategy —
    /// the deterministic-probe escape hatch (bench smoke, CI gates)
    pub force_strategy: Option<Strategy>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            shards: 4,
            batcher: BatcherConfig::default(),
            default_deadline: Duration::from_secs(1),
            pass: Pass::Fprop,
            tuner_path: None,
            tuner_reps: 1,
            warm: true,
            spectra: SpectrumPrecision::default(),
            force_strategy: None,
        }
    }
}

/// One accepted request on its way to a shard.
struct Accepted {
    id: u64,
    images: usize,
    enqueued: Instant,
    /// batcher flush-by deadline: `min(enqueued + max_wait, sla)`
    flush_by: Instant,
    /// the request's SLA deadline (reply-by)
    sla: Instant,
    reply: Sender<Completion>,
}

enum Msg {
    Req(Accepted),
    /// install a new weight tensor under `version`, invalidating the
    /// shard's cached spectra of the served problem
    Weights { version: u64, weights: Arc<Vec<f32>> },
    Shutdown,
}

/// Per-shard statistics returned by the worker at shutdown.
#[derive(Clone, Debug, Default)]
pub struct ShardReport {
    pub shard: usize,
    /// accepted requests routed here
    pub requests: usize,
    pub images: usize,
    pub launches: usize,
    pub busy: Duration,
    pub flushes_full: usize,
    pub flushes_timeout: usize,
    /// shutdown-path drains — `flushes_full + flushes_timeout +
    /// flushes_drain == launches` reconciles every batch
    pub flushes_drain: usize,
    /// weight-spectrum cache counters (tentpole: steady-state hits)
    pub spectra_hits: usize,
    pub spectra_misses: usize,
    pub spectra_invalidated: usize,
    /// per-flush weight-FFT seconds (frequency-strategy launches only;
    /// zero samples on spectrum hits — `sum`/`last` feed the report)
    pub weight_fft: Histogram,
    /// weights version the shard was serving at shutdown
    pub weights_version: u64,
    /// completions delivered after their SLA deadline
    pub sla_miss: usize,
    /// failed backend launches (their requests complete anyway — a
    /// hung client is worse than a served error)
    pub launch_errors: usize,
    /// reply latency per completed request, seconds
    pub latency: Histogram,
    /// queued images sampled at each admission
    pub depth: Histogram,
    /// mean flushed-images / capacity over all launches
    pub batch_fill: f64,
}

/// Aggregate view over all shards plus engine-level counters.
#[derive(Clone, Debug)]
pub struct EngineReport {
    pub shards: Vec<ShardReport>,
    /// requests refused at admission (deadline unmeetable)
    pub rejected_deadline: usize,
    pub cache: CacheStats,
    pub capacity: usize,
    pub pass: Pass,
}

impl EngineReport {
    pub fn requests(&self) -> usize {
        self.shards.iter().map(|s| s.requests).sum()
    }

    pub fn images(&self) -> usize {
        self.shards.iter().map(|s| s.images).sum()
    }

    pub fn launches(&self) -> usize {
        self.shards.iter().map(|s| s.launches).sum()
    }

    pub fn busy(&self) -> Duration {
        self.shards.iter().map(|s| s.busy).sum()
    }

    pub fn flushes_full(&self) -> usize {
        self.shards.iter().map(|s| s.flushes_full).sum()
    }

    pub fn flushes_timeout(&self) -> usize {
        self.shards.iter().map(|s| s.flushes_timeout).sum()
    }

    pub fn flushes_drain(&self) -> usize {
        self.shards.iter().map(|s| s.flushes_drain).sum()
    }

    pub fn spectra_hits(&self) -> usize {
        self.shards.iter().map(|s| s.spectra_hits).sum()
    }

    pub fn spectra_misses(&self) -> usize {
        self.shards.iter().map(|s| s.spectra_misses).sum()
    }

    pub fn spectra_invalidated(&self) -> usize {
        self.shards.iter().map(|s| s.spectra_invalidated).sum()
    }

    /// Newest weights version any shard was serving (every shard
    /// converges to it once the bump broadcast drains).
    pub fn weights_version(&self) -> u64 {
        self.shards.iter().map(|s| s.weights_version).max().unwrap_or(0)
    }

    /// All shards' per-flush weight-FFT samples merged.
    pub fn weight_fft(&self) -> Histogram {
        let mut h = Histogram::new();
        for s in &self.shards {
            h.merge(&s.weight_fft);
        }
        h
    }

    pub fn sla_miss(&self) -> usize {
        self.shards.iter().map(|s| s.sla_miss).sum()
    }

    pub fn launch_errors(&self) -> usize {
        self.shards.iter().map(|s| s.launch_errors).sum()
    }

    /// All shards' latency samples merged (the aggregate percentiles).
    pub fn aggregate_latency(&self) -> Histogram {
        let mut h = Histogram::new();
        for s in &self.shards {
            h.merge(&s.latency);
        }
        h
    }

    /// Launch-weighted mean batch-fill ratio across shards.
    pub fn batch_fill(&self) -> f64 {
        let launches = self.launches();
        if launches == 0 {
            return 0.0;
        }
        self.shards
            .iter()
            .map(|s| s.batch_fill * s.launches as f64)
            .sum::<f64>()
            / launches as f64
    }
}

/// Cheap, cloneable submission handle — one per client thread. Holds
/// the shard senders, the shared depth gauges and the strategy cache;
/// admission runs entirely on the calling thread.
#[derive(Clone)]
pub struct EngineClient {
    txs: Vec<Sender<Msg>>,
    depths: Vec<Arc<AtomicUsize>>,
    rejected: Arc<AtomicUsize>,
    rr: Arc<AtomicUsize>,
    weights_version: Arc<AtomicU64>,
    cache: Arc<StrategyCache>,
    problem: ConvProblem,
    pass: Pass,
    capacity: usize,
    default_deadline: Duration,
    max_wait: Duration,
}

impl EngineClient {
    /// Admit (or reject) a request. Returns `false` — and sends nothing
    /// on `reply` — when the deadline cannot cover the cached launch
    /// estimate for the request's own shape. Accepted requests are
    /// routed to the least-loaded shard and receive exactly one
    /// [`Completion`]. Submissions must not race
    /// [`ServeEngine::shutdown`]: stop every client first (an accepted
    /// request whose send lands after the worker's final drain would be
    /// dropped).
    ///
    /// Panics on a zero-image request (same contract as
    /// [`Batcher::push`]) — asserting here keeps the panic on the
    /// caller's thread instead of poisoning a shard worker.
    pub fn submit(&self, req: ServeRequest) -> bool {
        assert!(req.images >= 1, "empty request");
        let now = Instant::now();
        let sla = req.deadline.unwrap_or(now + self.default_deadline);
        let shape = ConvProblem {
            s: req.images.min(self.capacity),
            ..self.problem
        };
        let est = self
            .cache
            .lookup(&shape, self.pass)
            .map(|c| Duration::from_secs_f64(c.seconds))
            .unwrap_or(Duration::ZERO);
        if now + est > sla {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        // least queued images wins; start point rotates so ties spread
        let n = self.txs.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let mut best = start;
        let mut best_depth = usize::MAX;
        for i in 0..n {
            let s = (start + i) % n;
            let d = self.depths[s].load(Ordering::Relaxed);
            if d < best_depth {
                best = s;
                best_depth = d;
            }
        }
        self.depths[best].fetch_add(req.images, Ordering::Relaxed);
        self.txs[best]
            .send(Msg::Req(Accepted {
                id: req.id,
                images: req.images,
                enqueued: now,
                flush_by: sla.min(now + self.max_wait),
                sla,
                reply: req.reply,
            }))
            .expect("serve shard worker gone");
        true
    }

    /// Install a new weight tensor across every shard and invalidate the
    /// cached weight spectra built from the old one. The bump is
    /// zero-downtime: each worker applies it between flushes, so batches
    /// flushed before the message arrives ride the old version and every
    /// later flush serves (and re-transforms once, lazily) the new one.
    /// Returns the new `weights_version`.
    ///
    /// Panics when `weights` does not match the served problem's weight
    /// tensor (`fo·f·kh·kw` elements) — same caller-thread contract as
    /// [`EngineClient::submit`].
    pub fn update_weights(&self, weights: Vec<f32>) -> u64 {
        assert_eq!(weights.len(), self.problem.weight_len(),
                   "weight tensor shape mismatch");
        let version =
            self.weights_version.fetch_add(1, Ordering::Relaxed) + 1;
        let shared = Arc::new(weights);
        for tx in &self.txs {
            tx.send(Msg::Weights { version, weights: shared.clone() })
                .expect("serve shard worker gone");
        }
        version
    }

    /// The version the next flush-after-drain will serve (starts at 1).
    pub fn weights_version(&self) -> u64 {
        self.weights_version.load(Ordering::Relaxed)
    }

    pub fn shards(&self) -> usize {
        self.txs.len()
    }
}

/// Handle to the running sharded engine; `shutdown` flushes and joins.
pub struct ServeEngine {
    client: EngineClient,
    workers: Vec<JoinHandle<ShardReport>>,
    cache: Arc<StrategyCache>,
}

struct WorkerCtx {
    shard: usize,
    backend: Backend,
    problem: ConvProblem,
    pass: Pass,
    batcher_cfg: BatcherConfig,
    cache: Arc<StrategyCache>,
    spectra: SpectrumPrecision,
    force: Option<Strategy>,
    depth: Arc<AtomicUsize>,
    rx: Receiver<Msg>,
    ready: Sender<std::result::Result<(), String>>,
}

impl ServeEngine {
    /// Serve with the in-tree host engines — available everywhere (no
    /// artifacts or PJRT backend needed). Each flush dispatches through
    /// the strategy cache.
    pub fn start_host(problem: ConvProblem, cfg: EngineConfig)
                      -> Result<ServeEngine> {
        Self::start(Backend::Host, problem, cfg)
    }

    /// Serve a fixed AOT artifact; every worker owns its own PJRT
    /// [`Runtime`] (the client is not `Send`), so startup compiles the
    /// executable once per shard and surfaces any failure here.
    pub fn start_pjrt(artifacts_dir: PathBuf, artifact: String,
                      problem: ConvProblem, cfg: EngineConfig)
                      -> Result<ServeEngine> {
        if cfg.batcher.capacity > problem.s {
            return Err(anyhow!(
                "batcher capacity {} exceeds artifact batch S={}",
                cfg.batcher.capacity, problem.s));
        }
        Self::start(Backend::Pjrt { dir: artifacts_dir, artifact },
                    problem, cfg)
    }

    fn start(backend: Backend, problem: ConvProblem, cfg: EngineConfig)
             -> Result<ServeEngine> {
        assert!(cfg.shards >= 1, "engine needs at least one shard");
        let mut cache = StrategyCache::open(cfg.tuner_path.as_deref());
        cache.reps = cfg.tuner_reps.max(1);
        // host serving of the weight-carrying passes runs through the
        // spectrum cache, so tune frequency candidates the same way —
        // the measured Choice then reflects steady-state (cached-weight)
        // flush cost, not the one-time weight FFT
        cache.serve_spectra = if matches!(backend, Backend::Host)
            && matches!(cfg.pass, Pass::Fprop | Pass::Bprop)
        {
            Some(cfg.spectra)
        } else {
            None
        };
        let cache = Arc::new(cache);
        // warm-tune the shapes every steady flush produces (full batches
        // and singletons); restarts hit the persisted entries instead
        if cfg.warm && matches!(backend, Backend::Host)
            && problem.stride == 1
        {
            for s in [1, cfg.batcher.capacity] {
                cache.ensure(&ConvProblem { s, ..problem }, cfg.pass);
            }
            cache.persist().ok(); // best-effort; shutdown retries
        }
        let (ready_tx, ready_rx) =
            mpsc::channel::<std::result::Result<(), String>>();
        let mut txs = Vec::with_capacity(cfg.shards);
        let mut depths = Vec::with_capacity(cfg.shards);
        let mut workers = Vec::with_capacity(cfg.shards);
        for shard in 0..cfg.shards {
            let (tx, rx) = mpsc::channel::<Msg>();
            let depth = Arc::new(AtomicUsize::new(0));
            let ctx = WorkerCtx {
                shard,
                backend: backend.clone(),
                problem,
                pass: cfg.pass,
                batcher_cfg: cfg.batcher,
                cache: cache.clone(),
                spectra: cfg.spectra,
                force: cfg.force_strategy,
                depth: depth.clone(),
                rx,
                ready: ready_tx.clone(),
            };
            workers.push(std::thread::spawn(move || worker_main(ctx)));
            txs.push(tx);
            depths.push(depth);
        }
        drop(ready_tx);
        let mut failure: Option<String> = None;
        for _ in 0..cfg.shards {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    failure = Some(e);
                    break;
                }
                Err(_) => {
                    failure = Some("worker died during startup".into());
                    break;
                }
            }
        }
        if let Some(e) = failure {
            drop(txs); // disconnect: healthy workers drain and exit
            for w in workers {
                w.join().ok();
            }
            return Err(anyhow!("serve engine startup: {e}"));
        }
        let client = EngineClient {
            txs,
            depths,
            rejected: Arc::new(AtomicUsize::new(0)),
            rr: Arc::new(AtomicUsize::new(0)),
            weights_version: Arc::new(AtomicU64::new(1)),
            cache: cache.clone(),
            problem,
            pass: cfg.pass,
            capacity: cfg.batcher.capacity,
            default_deadline: cfg.default_deadline,
            max_wait: cfg.batcher.max_wait,
        };
        Ok(ServeEngine { client, workers, cache })
    }

    /// A cloneable submission handle for multi-threaded load.
    pub fn client(&self) -> EngineClient {
        self.client.clone()
    }

    /// Admit a request from the engine owner's thread. See
    /// [`EngineClient::submit`].
    pub fn submit(&self, req: ServeRequest) -> bool {
        self.client.submit(req)
    }

    /// Install new weights across the pool. See
    /// [`EngineClient::update_weights`].
    pub fn update_weights(&self, weights: Vec<f32>) -> u64 {
        self.client.update_weights(weights)
    }

    pub fn cache(&self) -> &StrategyCache {
        &self.cache
    }

    pub fn shards(&self) -> usize {
        self.workers.len()
    }

    /// Flush outstanding work, join every worker, persist the strategy
    /// cache, and return the merged report.
    pub fn shutdown(self) -> EngineReport {
        let ServeEngine { client, workers, cache } = self;
        for tx in &client.txs {
            tx.send(Msg::Shutdown).ok();
        }
        let mut shards: Vec<ShardReport> = workers
            .into_iter()
            .map(|w| w.join().expect("serve worker panicked"))
            .collect();
        shards.sort_by_key(|r| r.shard);
        cache.persist().ok();
        EngineReport {
            shards,
            rejected_deadline: client.rejected.load(Ordering::Relaxed),
            cache: cache.stats(),
            capacity: client.capacity,
            pass: client.pass,
        }
    }
}

fn worker_main(ctx: WorkerCtx) -> ShardReport {
    let WorkerCtx { shard, backend, problem, pass, batcher_cfg, cache,
                    spectra: spectra_precision, force, depth, rx,
                    ready } = ctx;
    // backend setup runs before the readiness handshake so compile
    // failures surface from ServeEngine::start
    let rt = match &backend {
        Backend::Host => {
            ready.send(Ok(())).ok();
            None
        }
        Backend::Pjrt { dir, artifact } => {
            match Runtime::open(dir)
                .and_then(|rt| rt.executable(artifact).map(|_| rt))
            {
                Ok(rt) => {
                    ready.send(Ok(())).ok();
                    Some(rt)
                }
                Err(e) => {
                    ready.send(Err(format!("{e:#}"))).ok();
                    return ShardReport { shard, ..Default::default() };
                }
            }
        }
    };
    drop(ready);

    struct PendingReply {
        id: u64,
        remaining: usize,
        total: usize,
        enqueued: Instant,
        sla: Instant,
        reply: Sender<Completion>,
    }

    let mut batcher = Batcher::new(batcher_cfg);
    let capacity = batcher_cfg.capacity;
    let mut pending: Vec<PendingReply> = Vec::new();
    let mut report = ShardReport { shard, ..Default::default() };
    let mut rng = Rng::new(0xC0FFEE ^ shard as u64);
    let mut ws = Workspace::new();
    let mut stage = BufferPool::new();
    // the layer's weights live on the shard (one buffered copy, §3.3),
    // alongside the spectra transformed from them — keyed by the
    // version so a bump invalidates exactly the stale entries
    let mut weights = rng.normal_vec(problem.weight_len());
    let mut weights_version: u64 = 1;
    let mut spectra = SpectrumCache::new(spectra_precision);
    report.weights_version = weights_version;
    let mut fill_sum = 0f64;
    let mut done = false;
    loop {
        // ---- receive phase --------------------------------------------
        let mut msgs: Vec<Msg> = Vec::new();
        // a backlog of a full batch must flush now — don't sleep on the
        // deadline when the capacity policy already says launch
        let backlog_full = batcher.queued_images() >= capacity;
        if !done && !backlog_full {
            if batcher.is_empty() {
                // idle: park on the channel indefinitely — the batcher
                // has no deadline to honor, so there is nothing to poll
                match rx.recv() {
                    Ok(m) => msgs.push(m),
                    Err(_) => done = true,
                }
            } else {
                // work queued: sleep until the earliest flush-by moment
                let timeout = batcher
                    .deadline()
                    .map(|d| d.saturating_duration_since(Instant::now()))
                    .unwrap_or(Duration::ZERO);
                match rx.recv_timeout(timeout) {
                    Ok(m) => msgs.push(m),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => done = true,
                }
            }
        }
        // drain whatever else already arrived without blocking — also
        // after shutdown, so requests already queued behind the
        // shutdown message still complete (submissions must not *race*
        // shutdown, though: see EngineClient::submit)
        while let Ok(m) = rx.try_recv() {
            msgs.push(m);
        }
        for m in msgs {
            match m {
                Msg::Req(a) => {
                    batcher.push_deadline(a.id, a.images, a.enqueued,
                                          a.flush_by);
                    pending.push(PendingReply {
                        id: a.id,
                        remaining: a.images,
                        total: a.images,
                        enqueued: a.enqueued,
                        sla: a.sla,
                        reply: a.reply,
                    });
                    report.requests += 1;
                    report.images += a.images;
                    report.depth.record(batcher.queued_images() as f64);
                }
                Msg::Weights { version, weights: w } => {
                    // applied between flushes: already-flushed batches
                    // rode the old version, everything later serves the
                    // new one (bumps can arrive reordered only relative
                    // to newer bumps — never regress)
                    if version > weights_version {
                        weights.clear();
                        weights.extend_from_slice(&w);
                        weights_version = version;
                        spectra.bump(&problem, version);
                        report.weights_version = version;
                    }
                }
                Msg::Shutdown => done = true,
            }
        }
        // ---- flush phase ----------------------------------------------
        let batch = if done {
            let b = batcher.drain();
            if b.is_empty() {
                break;
            }
            b
        } else {
            match batcher.poll(Instant::now()) {
                Some(b) => b,
                None => continue,
            }
        };
        let imgs = batch.images();
        let t0 = Instant::now();
        let ok = match &rt {
            Some(rt) => {
                let Backend::Pjrt { artifact, .. } = &backend else {
                    unreachable!("runtime without PJRT backend")
                };
                launch_pjrt(rt, artifact, &problem, imgs, &weights,
                            &mut rng)
            }
            None => {
                let wfft = launch_host(&cache, force, pass, &problem,
                                       imgs, &weights, weights_version,
                                       &mut spectra, &mut rng,
                                       &mut stage, &mut ws);
                if let Some(d) = wfft {
                    report.weight_fft.record(d.as_secs_f64());
                }
                true
            }
        };
        let elapsed = t0.elapsed();
        report.launches += 1;
        report.busy += elapsed;
        fill_sum += imgs as f64 / capacity as f64;
        depth.fetch_sub(imgs, Ordering::Relaxed);
        if !ok {
            // the launch failed (PJRT error, already logged): the batch
            // is gone from the batcher, so still complete its parts —
            // a hung client is worse than a served error
            report.launch_errors += 1;
        } else if rt.is_some() {
            // no host tuner runs for a compiled artifact; feed measured
            // launch times back so deadline admission has an estimate
            cache.observe(&ConvProblem { s: imgs, ..problem }, pass,
                          Strategy::Vendor, elapsed.as_secs_f64());
        }
        // ---- completion phase -----------------------------------------
        let now = Instant::now();
        for (id, n) in &batch.parts {
            let Some(pos) = pending.iter().position(|p| p.id == *id)
            else {
                continue;
            };
            pending[pos].remaining =
                pending[pos].remaining.saturating_sub(*n);
            if pending[pos].remaining > 0 {
                continue; // split request: more parts ride later batches
            }
            let p = pending.remove(pos);
            let latency = now.duration_since(p.enqueued);
            let met = now <= p.sla;
            if !met {
                report.sla_miss += 1;
            }
            report.latency.record(latency.as_secs_f64());
            p.reply
                .send(Completion {
                    id: p.id,
                    images: p.total,
                    latency,
                    batch_images: imgs,
                    shard,
                    deadline_met: met,
                })
                .ok();
        }
    }
    report.flushes_full = batcher.flushes_full;
    report.flushes_timeout = batcher.flushes_timeout;
    report.flushes_drain = batcher.flushes_drain;
    report.spectra_hits = spectra.hits;
    report.spectra_misses = spectra.misses;
    report.spectra_invalidated = spectra.invalidated;
    if report.launches > 0 {
        report.batch_fill = fill_sum / report.launches as f64;
    }
    report
}

/// One PJRT launch: pad the flushed images to the artifact batch S.
fn launch_pjrt(rt: &Runtime, artifact: &str, p: &ConvProblem,
               imgs: usize, weights: &[f32], rng: &mut Rng) -> bool {
    // PJRT literals consume their Vec, so this path allocates per launch
    let mut x = vec![0f32; p.input_len()];
    let live = imgs * p.f * p.h * p.w;
    for v in x[..live].iter_mut() {
        *v = rng.normal();
    }
    let result = rt.execute_1f32(
        artifact,
        &[HostTensor::f32(x, &[p.s, p.f, p.h, p.w]),
          HostTensor::f32(weights.to_vec(),
                          &[p.fo, p.f, p.kh, p.kw])]);
    if let Err(e) = result {
        eprintln!("serve: launch failed: {e:#}");
        return false;
    }
    true
}

/// One host-engine launch of a `imgs`-image batch: look the flush shape
/// up in the strategy cache (tuning once on first sight) and dispatch
/// the winner through the shard's workspace. Operand staging is pooled
/// (allocation-free after warmup); the frequency engines also write
/// their output through the pool, while the time-domain engines
/// allocate their result by API design (no redundant pooled copy is
/// layered on top). Returns the weight-FFT time the launch actually
/// spent when the flush served a frequency strategy from the spectrum
/// cache (`Some(ZERO)` on a hit — the steady state), `None` otherwise.
#[allow(clippy::too_many_arguments)]
fn launch_host(cache: &StrategyCache, force: Option<Strategy>, pass: Pass,
               p: &ConvProblem, imgs: usize, weights: &[f32],
               version: u64, spectra: &mut SpectrumCache, rng: &mut Rng,
               stage: &mut BufferPool, ws: &mut Workspace)
               -> Option<Duration> {
    let q = ConvProblem { s: imgs, ..*p };
    let choice = match force {
        // deterministic probe: serve the forced strategy at its default
        // basis without consulting (or populating) the tuner
        Some(strategy) => Choice { strategy, n_fft: None, seconds: 0.0 },
        None => cache.ensure(&q, pass),
    };
    // the "payload": a fresh synthetic operand per flush
    let a_len = match pass {
        Pass::Fprop => q.input_len(),
        Pass::Bprop | Pass::AccGrad => q.output_len(),
    };
    let mut a = stage.take_raw("serve.a", a_len);
    for v in a.iter_mut() {
        *v = rng.normal();
    }
    let wfft = match pass {
        Pass::AccGrad => {
            // accGrad pairs the gradient with an activation, not weights
            let mut b = stage.take_raw("serve.b", q.input_len());
            for v in b.iter_mut() {
                *v = rng.normal();
            }
            run_strategy(&choice, &q, pass, &a, &b, None, stage, ws);
            stage.put("serve.b", b);
            None
        }
        _ => run_strategy(&choice, &q, pass, &a, weights,
                          Some((spectra, version)), stage, ws),
    };
    stage.put("serve.a", a);
    wfft
}

/// Dispatch one pass through the tuned strategy. `a`/`b` follow each
/// engine's own operand order: (x, weights) for fprop, (grad_output,
/// weights) for bprop, (grad_output, x) for accGrad. When `b` is the
/// weight tensor the caller passes the shard's spectrum cache and the
/// live `weights_version`; frequency strategies then serve from the
/// cached spectrum — skipping the weight pad+FFT on a hit — and the
/// return value is the weight-FFT time actually spent.
#[allow(clippy::too_many_arguments)]
fn run_strategy(choice: &Choice, q: &ConvProblem, pass: Pass, a: &[f32],
                b: &[f32], spectra: Option<(&mut SpectrumCache, u64)>,
                stage: &mut BufferPool, ws: &mut Workspace)
                -> Option<Duration> {
    match choice.strategy {
        Strategy::VendorFft | Strategy::Fbfft | Strategy::FbfftScalar => {
            let out_len = match pass {
                Pass::Fprop => q.output_len(),
                Pass::Bprop => q.input_len(),
                Pass::AccGrad => q.weight_len(),
            };
            let mut out = stage.take_raw("serve.out", out_len);
            let mode = match choice.strategy {
                Strategy::VendorFft => FftMode::Vendor,
                Strategy::Fbfft => FftMode::Fbfft,
                _ => FftMode::FbfftScalar,
            };
            let n = choice
                .n_fft
                .unwrap_or_else(|| q.h.max(q.w).next_power_of_two());
            let eng = FftConvEngine::new(mode, n);
            let wfft = match (pass, spectra) {
                (Pass::Fprop, Some((spectra, version))) => {
                    let (spec, took) =
                        spectra.ensure(&eng, q, b, version, ws);
                    eng.fprop_spec_into(q, a, spec, &mut out, ws);
                    Some(took)
                }
                (Pass::Bprop, Some((spectra, version))) => {
                    let (spec, took) =
                        spectra.ensure(&eng, q, b, version, ws);
                    eng.bprop_spec_into(q, a, spec, &mut out, ws);
                    Some(took)
                }
                (Pass::Fprop, None) => {
                    eng.fprop_into(q, a, b, &mut out, ws);
                    None
                }
                (Pass::Bprop, None) => {
                    eng.bprop_into(q, a, b, &mut out, ws);
                    None
                }
                (Pass::AccGrad, _) => {
                    eng.accgrad_into(q, a, b, &mut out, ws);
                    None
                }
            };
            stage.put("serve.out", out);
            wfft
        }
        // the vendor black box has no host twin; direct is its analogue
        Strategy::Direct | Strategy::Vendor => {
            let _ = match pass {
                Pass::Fprop => direct::fprop(q, a, b),
                Pass::Bprop => direct::bprop(q, a, b),
                Pass::AccGrad => direct::accgrad(q, a, b),
            };
            None
        }
        Strategy::Im2col => {
            let _ = match pass {
                Pass::Fprop => im2col::fprop(q, a, b),
                Pass::Bprop => im2col::bprop(q, a, b),
                Pass::AccGrad => im2col::accgrad(q, a, b),
            };
            None
        }
        Strategy::FbfftTiled(d) => {
            let _ = match pass {
                Pass::Fprop => tiled::fprop(q, a, b, d),
                Pass::Bprop => tiled::bprop(q, a, b, d),
                Pass::AccGrad => tiled::accgrad(q, a, b, d),
            };
            None
        }
    }
}

// ---------------------------------------------------------------------------
// Legacy single-shard PJRT wrapper
// ---------------------------------------------------------------------------

/// Aggregate statistics returned at shutdown (legacy surface).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceReport {
    pub requests: usize,
    pub images: usize,
    pub launches: usize,
    pub busy: Duration,
    pub flushes_full: usize,
    pub flushes_timeout: usize,
}

/// The original single-worker PJRT service, now a one-shard
/// [`ServeEngine`] (same admission loop, same report shape).
pub struct ConvService {
    engine: ServeEngine,
}

impl ConvService {
    /// Serve the named fprop artifact from `artifacts_dir`.
    pub fn start(artifacts_dir: PathBuf, artifact: String,
                 problem: ConvProblem, cfg: BatcherConfig)
                 -> Result<ConvService> {
        let engine = ServeEngine::start_pjrt(
            artifacts_dir,
            artifact,
            problem,
            EngineConfig {
                shards: 1,
                batcher: cfg,
                // the legacy API has no SLA concept: never reject
                default_deadline: Duration::from_secs(3600),
                warm: false,
                ..Default::default()
            })?;
        Ok(ConvService { engine })
    }

    pub fn submit(&self, req: ServeRequest) {
        let accepted = self.engine.submit(req);
        debug_assert!(accepted, "legacy service never rejects");
    }

    /// Flush outstanding work and join the worker.
    pub fn shutdown(self) -> ServiceReport {
        let r = self.engine.shutdown();
        ServiceReport {
            requests: r.requests(),
            images: r.images(),
            launches: r.launches(),
            busy: r.busy(),
            flushes_full: r.flushes_full(),
            flushes_timeout: r.flushes_timeout(),
        }
    }
}

#[cfg(test)]
mod tests {
    // PJRT-backed behaviour is covered by rust/tests/integration.rs;
    // the host-backend engine is exercised end-to-end (multi-shard soak,
    // admission, batcher paths) in rust/tests/serve.rs. Here: report
    // arithmetic and the admission fast-paths.
    use super::*;

    #[test]
    fn report_defaults_are_zero() {
        let r = ServiceReport::default();
        assert_eq!(r.requests + r.images + r.launches, 0);
        assert_eq!(r.busy, Duration::ZERO);
    }

    #[test]
    fn engine_report_aggregates_across_shards() {
        let mut a = ShardReport { shard: 0, ..Default::default() };
        a.requests = 3;
        a.images = 7;
        a.launches = 2;
        a.batch_fill = 0.5;
        a.latency.record(0.010);
        let mut b = ShardReport { shard: 1, ..Default::default() };
        b.requests = 1;
        b.images = 2;
        b.launches = 1;
        b.batch_fill = 1.0;
        b.latency.record(0.030);
        let r = EngineReport {
            shards: vec![a, b],
            rejected_deadline: 4,
            cache: CacheStats::default(),
            capacity: 8,
            pass: Pass::Fprop,
        };
        assert_eq!(r.requests(), 4);
        assert_eq!(r.images(), 9);
        assert_eq!(r.launches(), 3);
        let mut agg = r.aggregate_latency();
        assert_eq!(agg.len(), 2);
        assert_eq!(agg.summary().max, 0.030);
        // launch-weighted fill: (0.5·2 + 1.0·1) / 3
        assert!((r.batch_fill() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn expired_deadline_is_rejected_at_admission() {
        let p = ConvProblem::square(4, 1, 1, 8, 3);
        let engine = ServeEngine::start_host(
            p,
            EngineConfig {
                shards: 2,
                batcher: BatcherConfig {
                    capacity: 4,
                    max_wait: Duration::from_millis(1),
                },
                warm: false,
                ..Default::default()
            })
            .expect("host engine always starts");
        let (tx, rx) = mpsc::channel::<Completion>();
        let expired = Instant::now() - Duration::from_millis(1);
        let accepted = engine.submit(ServeRequest {
            id: 1,
            images: 1,
            deadline: Some(expired),
            reply: tx.clone(),
        });
        assert!(!accepted, "expired deadline must be rejected");
        let accepted = engine.submit(ServeRequest {
            id: 2,
            images: 1,
            deadline: None,
            reply: tx,
        });
        assert!(accepted);
        let c = rx.recv_timeout(Duration::from_secs(30))
            .expect("accepted request completes");
        assert_eq!(c.id, 2);
        assert_eq!(c.images, 1);
        let report = engine.shutdown();
        assert_eq!(report.rejected_deadline, 1);
        assert_eq!(report.requests(), 1);
        assert_eq!(report.images(), 1);
    }
}
