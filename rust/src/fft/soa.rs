//! Split-complex (SoA) batch-lane FFT kernels — the CPU image of the
//! paper's §5 warp mapping.
//!
//! The Pallas/fbfft design point this module transplants: map the *batch*
//! dimension across parallel lanes so one butterfly instruction stream
//! executes simultaneously for many transforms (paper §5: one transform
//! per warp, batch across threads). On the host that means:
//!
//! * **split-complex planes** — `re[]` / `im[]` as separate flat `f32`
//!   slices (Zlateski et al., arXiv:1809.07851: CPU FFT convolutions live
//!   or die by SIMD-friendly SoA layouts), so no interleave shuffles sit
//!   between loads and FMAs;
//! * **batch-innermost layout** — element `j` of transform `b` lives at
//!   `j * batch + b`, so every butterfly's inner loop runs over a flat
//!   contiguous lane slice the compiler autovectorizes;
//! * **loop-invariant twiddles** — within one butterfly the twiddle is a
//!   pair of scalar broadcasts, hoisted out of the lane loop;
//! * **[`LANES`]-wide passes** — the lane loops process `LANES = 8`
//!   transforms per pass through fixed-size arrays (one AVX2 register of
//!   `f32`), with a scalar tail for ragged batches.
//!
//! The kernels reuse [`FbfftPlan`]'s cached bit-reversal and stage-major
//! twiddle tables. The butterfly lane pass dispatches on the runtime
//! [`SimdTier`] (`util::simd`): the **scalar tier** follows the exact
//! operation order of the scalar [`FbfftPlan::cfft_in_place`] path — a
//! lane of the batched transform is bit-identical to one scalar
//! transform — while the **AVX2/AVX-512 tiers** fuse the twiddle
//! multiply into `fmsub`/`fmadd` pairs (different rounding, gated by
//! `testkit::tolerance` instead of bitwise equality). Within any one
//! tier a lane's result is independent of its batch position (the FMA
//! tails mirror the vector contraction via `f32::mul_add`), so the
//! pipeline's batch-chunking invariants stay bitwise.

use super::complex::C32;
use super::fbfft_host::FbfftPlan;
use super::real::rfft_len;
use crate::util::simd::{self, SimdTier};

/// Transforms processed per vectorized pass of the lane loops (the rest
/// of a ragged batch takes the scalar tail). Eight `f32` lanes = one
/// 256-bit SIMD register.
pub const LANES: usize = 8;

/// Scalar-tier butterfly over one lane slice:
/// `(top, bot) <- (top + w·bot, top - w·bot)` for all `batch` lanes,
/// with the twiddle `(wr, wi)` broadcast. `LANES` at a time + tail —
/// the pre-dispatch reference arithmetic, kept bit-identical (separate
/// mul/sub, no fused contraction).
#[inline(always)]
fn butterfly_lanes(tr_: &mut [f32], ti_: &mut [f32], br_: &mut [f32],
                   bi_: &mut [f32], wr: f32, wi: f32, batch: usize) {
    let (tr_, ti_) = (&mut tr_[..batch], &mut ti_[..batch]);
    let (br_, bi_) = (&mut br_[..batch], &mut bi_[..batch]);
    let mut b = 0;
    while b + LANES <= batch {
        for l in 0..LANES {
            let i = b + l;
            let vr = br_[i] * wr - bi_[i] * wi;
            let vi = br_[i] * wi + bi_[i] * wr;
            let ur = tr_[i];
            let ui = ti_[i];
            tr_[i] = ur + vr;
            ti_[i] = ui + vi;
            br_[i] = ur - vr;
            bi_[i] = ui - vi;
        }
        b += LANES;
    }
    while b < batch {
        let vr = br_[b] * wr - bi_[b] * wi;
        let vi = br_[b] * wi + bi_[b] * wr;
        let ur = tr_[b];
        let ui = ti_[b];
        tr_[b] = ur + vr;
        ti_[b] = ui + vi;
        br_[b] = ur - vr;
        bi_[b] = ui - vi;
        b += 1;
    }
}

/// Scalar tail of the FMA tiers, lanes `[b, batch)`: `f32::mul_add`
/// mirrors the vector bodies' `vfmsub`/`vfmadd` contraction exactly
/// (both are correctly-rounded fused ops), so a lane's result is
/// **independent of its position in the batch** — the bitwise
/// phase-split / batch-chunking invariants the threaded pipeline relies
/// on keep holding within each tier.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn butterfly_tail_fma(tr_: &mut [f32], ti_: &mut [f32], br_: &mut [f32],
                      bi_: &mut [f32], wr: f32, wi: f32, mut b: usize,
                      batch: usize) {
    while b < batch {
        let vr = br_[b].mul_add(wr, -(bi_[b] * wi));
        let vi = br_[b].mul_add(wi, bi_[b] * wr);
        let ur = tr_[b];
        let ui = ti_[b];
        tr_[b] = ur + vr;
        ti_[b] = ui + vi;
        br_[b] = ur - vr;
        bi_[b] = ui - vi;
        b += 1;
    }
}

/// AVX2+FMA butterfly: `v = w·bot` as `fmsub`/`fmadd` pairs (twiddle
/// broadcast hoisted by the caller of the lane loop), eight lanes per
/// step, position-independent FMA tail.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn butterfly_lanes_avx2(tr_: &mut [f32], ti_: &mut [f32],
                               br_: &mut [f32], bi_: &mut [f32], wr: f32,
                               wi: f32, batch: usize) {
    use std::arch::x86_64::*;
    debug_assert!(tr_.len() >= batch && ti_.len() >= batch
                  && br_.len() >= batch && bi_.len() >= batch);
    let wrv = _mm256_set1_ps(wr);
    let wiv = _mm256_set1_ps(wi);
    let mut b = 0;
    while b + 8 <= batch {
        let brv = _mm256_loadu_ps(br_.as_ptr().add(b));
        let biv = _mm256_loadu_ps(bi_.as_ptr().add(b));
        let vr = _mm256_fmsub_ps(brv, wrv, _mm256_mul_ps(biv, wiv));
        let vi = _mm256_fmadd_ps(brv, wiv, _mm256_mul_ps(biv, wrv));
        let ur = _mm256_loadu_ps(tr_.as_ptr().add(b));
        let ui = _mm256_loadu_ps(ti_.as_ptr().add(b));
        _mm256_storeu_ps(tr_.as_mut_ptr().add(b), _mm256_add_ps(ur, vr));
        _mm256_storeu_ps(ti_.as_mut_ptr().add(b), _mm256_add_ps(ui, vi));
        _mm256_storeu_ps(br_.as_mut_ptr().add(b), _mm256_sub_ps(ur, vr));
        _mm256_storeu_ps(bi_.as_mut_ptr().add(b), _mm256_sub_ps(ui, vi));
        b += 8;
    }
    butterfly_tail_fma(tr_, ti_, br_, bi_, wr, wi, b, batch);
}

/// AVX-512F butterfly: sixteen lanes per step, remainder through the
/// AVX2 body + FMA tail (per-lane arithmetic identical at every width).
#[cfg(all(target_arch = "x86_64", fbfft_avx512))]
#[target_feature(enable = "avx512f,avx2,fma")]
unsafe fn butterfly_lanes_avx512(tr_: &mut [f32], ti_: &mut [f32],
                                 br_: &mut [f32], bi_: &mut [f32],
                                 wr: f32, wi: f32, batch: usize) {
    use std::arch::x86_64::*;
    debug_assert!(tr_.len() >= batch && ti_.len() >= batch
                  && br_.len() >= batch && bi_.len() >= batch);
    let wrv = _mm512_set1_ps(wr);
    let wiv = _mm512_set1_ps(wi);
    let mut b = 0;
    while b + 16 <= batch {
        let brv = _mm512_loadu_ps(br_.as_ptr().add(b));
        let biv = _mm512_loadu_ps(bi_.as_ptr().add(b));
        let vr = _mm512_fmsub_ps(brv, wrv, _mm512_mul_ps(biv, wiv));
        let vi = _mm512_fmadd_ps(brv, wiv, _mm512_mul_ps(biv, wrv));
        let ur = _mm512_loadu_ps(tr_.as_ptr().add(b));
        let ui = _mm512_loadu_ps(ti_.as_ptr().add(b));
        _mm512_storeu_ps(tr_.as_mut_ptr().add(b), _mm512_add_ps(ur, vr));
        _mm512_storeu_ps(ti_.as_mut_ptr().add(b), _mm512_add_ps(ui, vi));
        _mm512_storeu_ps(br_.as_mut_ptr().add(b), _mm512_sub_ps(ur, vr));
        _mm512_storeu_ps(bi_.as_mut_ptr().add(b), _mm512_sub_ps(ui, vi));
        b += 16;
    }
    butterfly_lanes_avx2(&mut tr_[b..batch], &mut ti_[b..batch],
                         &mut br_[b..batch], &mut bi_[b..batch], wr, wi,
                         batch - b);
}

/// Tier dispatch for one butterfly lane pass. The `tier` is resolved
/// once per transform at the public entry points and threaded down, so
/// worker threads never re-resolve mid-pipeline.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn butterfly_dispatch(tier: SimdTier, tr_: &mut [f32], ti_: &mut [f32],
                      br_: &mut [f32], bi_: &mut [f32], wr: f32, wi: f32,
                      batch: usize) {
    match tier {
        SimdTier::Scalar => {
            butterfly_lanes(tr_, ti_, br_, bi_, wr, wi, batch)
        }
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => {
            // SAFETY: the Avx2 tier is only ever selected when runtime
            // detection confirmed avx2+fma (`simd::tier()` caps at
            // `simd::detected()`).
            unsafe {
                butterfly_lanes_avx2(tr_, ti_, br_, bi_, wr, wi, batch)
            }
        }
        #[cfg(all(target_arch = "x86_64", fbfft_avx512))]
        SimdTier::Avx512 => {
            // SAFETY: as above — the Avx512 tier requires detected
            // avx512f (and the toolchain gate this arm compiles under).
            unsafe {
                butterfly_lanes_avx512(tr_, ti_, br_, bi_, wr, wi, batch)
            }
        }
        #[allow(unreachable_patterns)]
        _ => butterfly_lanes(tr_, ti_, br_, bi_, wr, wi, batch),
    }
}

/// Batched in-place complex FFT over split-complex planes: `re`/`im` hold
/// `n × batch` values, element `j` of transform `b` at `j·batch + b`
/// (batch innermost). Iterative radix-2 DIT with the plan's cached LUTs —
/// the batched twin of [`FbfftPlan::cfft_in_place`], one whole batch per
/// butterfly pass.
pub fn cfft_batch(plan: &FbfftPlan, re: &mut [f32], im: &mut [f32],
                  batch: usize, inverse: bool) {
    cfft_batch_with(plan, re, im, batch, inverse, simd::tier());
}

/// [`cfft_batch`] with an explicit dispatch tier — the internal seam the
/// forced-tier conformance tests pin kernels against. `tier` must not
/// exceed [`simd::detected`].
pub(crate) fn cfft_batch_with(plan: &FbfftPlan, re: &mut [f32],
                              im: &mut [f32], batch: usize, inverse: bool,
                              tier: SimdTier) {
    let n = plan.len();
    assert_eq!(re.len(), n * batch, "re plane length");
    assert_eq!(im.len(), n * batch, "im plane length");
    if batch == 0 {
        return;
    }
    // bit-reversal permutation of whole lane rows
    for i in 0..n {
        let j = plan.bitrev(i);
        if i < j {
            let (rl, rh) = re.split_at_mut(j * batch);
            rl[i * batch..i * batch + batch]
                .swap_with_slice(&mut rh[..batch]);
            let (il, ih) = im.split_at_mut(j * batch);
            il[i * batch..i * batch + batch]
                .swap_with_slice(&mut ih[..batch]);
        }
    }
    let log2n = n.trailing_zeros();
    let mut tw_off = 0usize;
    for s in 0..log2n {
        let half = 1usize << s;
        let m = half << 1;
        let mut base = 0;
        while base < n {
            for j in 0..half {
                let w = plan.twiddle(tw_off + j, inverse);
                // rows base+j and base+j+half never alias
                let top = (base + j) * batch;
                let bot = (base + j + half) * batch;
                let (rl, rh) = re.split_at_mut(bot);
                let (il, ih) = im.split_at_mut(bot);
                butterfly_dispatch(tier, &mut rl[top..top + batch],
                                   &mut il[top..top + batch],
                                   &mut rh[..batch], &mut ih[..batch],
                                   w.re, w.im, batch);
            }
            base += m;
        }
        tw_off += half;
    }
}

/// Hermitian unpack of a §5.2 pair-packed spectrum, one bin `k` over all
/// lanes: given `Z = A + iB` (two real signals packed re/im),
/// `A[k] = (Z[k] + conj(Z[n-k]))/2` into `(ar, ai)` and, when `b_out` is
/// `Some`, `B[k] = -i·(Z[k] - conj(Z[n-k]))/2` into it.
#[allow(clippy::too_many_arguments)]
#[inline]
pub(crate) fn unpack_pair_bin(zr_k: &[f32], zi_k: &[f32], zr_m: &[f32],
                              zi_m: &[f32], ar: &mut [f32], ai: &mut [f32],
                              b_out: Option<(&mut [f32], &mut [f32])>,
                              batch: usize) {
    for b in 0..batch {
        let (kr, ki) = (zr_k[b], zi_k[b]);
        let (mr, mi) = (zr_m[b], -zi_m[b]); // conj(Z[n-k])
        ar[b] = 0.5 * (kr + mr);
        ai[b] = 0.5 * (ki + mi);
    }
    if let Some((br, bi)) = b_out {
        for b in 0..batch {
            let (kr, ki) = (zr_k[b], zi_k[b]);
            let (mr, mi) = (zr_m[b], -zi_m[b]);
            // -i·(Z - conj(Zm))/2 = (im-part, -re-part)/2
            br[b] = 0.5 * (ki - mi);
            bi[b] = -0.5 * (kr - mr);
        }
    }
}

/// Batched 1-D R2C in SoA form with implicit zero padding and the §5.2
/// two-reals-in-one-complex pack across consecutive batch rows: `input`
/// is `batch × n_in` row-major (`n_in ≤ n`), the output planes hold the
/// **bin-major** `(n/2+1) × batch` layout (`out[k·batch + b]`). `work_*`
/// are caller scratch of `n · ⌈batch/2⌉` (dirty contents fine).
#[allow(clippy::too_many_arguments)]
pub fn rfft_batch_soa(plan: &FbfftPlan, input: &[f32], n_in: usize,
                      batch: usize, out_re: &mut [f32],
                      out_im: &mut [f32], work_re: &mut [f32],
                      work_im: &mut [f32]) {
    let n = plan.len();
    assert!(n_in <= n, "n_in {n_in} exceeds plan size {n}");
    assert_eq!(input.len(), batch * n_in);
    let nf = rfft_len(n);
    assert_eq!(out_re.len(), nf * batch);
    assert_eq!(out_im.len(), nf * batch);
    if batch == 0 {
        return;
    }
    let pairs = batch.div_ceil(2);
    assert!(work_re.len() >= n * pairs && work_im.len() >= n * pairs,
            "work scratch too small");
    let work_re = &mut work_re[..n * pairs];
    let work_im = &mut work_im[..n * pairs];
    // lane load: pair (2p, 2p+1) → (re, im); implicit padding past n_in
    for j in 0..n_in {
        let wr = &mut work_re[j * pairs..(j + 1) * pairs];
        let wi = &mut work_im[j * pairs..(j + 1) * pairs];
        for p in 0..pairs {
            wr[p] = input[2 * p * n_in + j];
            wi[p] = if 2 * p + 1 < batch {
                input[(2 * p + 1) * n_in + j]
            } else {
                0.0
            };
        }
    }
    if n_in < n {
        work_re[n_in * pairs..].fill(0.0);
        work_im[n_in * pairs..].fill(0.0);
    }
    cfft_batch(plan, work_re, work_im, pairs, false);
    // Hermitian unpack, lane p → batch rows 2p (A) and 2p+1 (B),
    // written straight into the strided output (no temporaries — the
    // contiguous-lane form of this math lives in [`unpack_pair_bin`])
    for k in 0..nf {
        let m = (n - k) % n;
        let zr_k = &work_re[k * pairs..(k + 1) * pairs];
        let zi_k = &work_im[k * pairs..(k + 1) * pairs];
        let zr_m = &work_re[m * pairs..(m + 1) * pairs];
        let zi_m = &work_im[m * pairs..(m + 1) * pairs];
        let or = &mut out_re[k * batch..(k + 1) * batch];
        let oi = &mut out_im[k * batch..(k + 1) * batch];
        for p in 0..pairs {
            let (kr, ki) = (zr_k[p], zi_k[p]);
            let (mr, mi) = (zr_m[p], -zi_m[p]); // conj(Z[n-k])
            // A[k] = (Z[k] + conj(Z[n-k])) / 2
            or[2 * p] = 0.5 * (kr + mr);
            oi[2 * p] = 0.5 * (ki + mi);
            if 2 * p + 1 < batch {
                // B[k] = -i · (Z[k] - conj(Z[n-k])) / 2
                or[2 * p + 1] = 0.5 * (ki - mi);
                oi[2 * p + 1] = -0.5 * (kr - mr);
            }
        }
    }
}

/// Inverse of [`rfft_batch_soa`]: bin-major `(n/2+1) × batch` planes in,
/// normalized real rows out (`batch × clip` row-major), pairwise-packed.
/// `work_*` are caller scratch of `n · ⌈batch/2⌉`.
#[allow(clippy::too_many_arguments)]
pub fn irfft_batch_soa(plan: &FbfftPlan, spec_re: &[f32], spec_im: &[f32],
                       batch: usize, clip: usize, out: &mut [f32],
                       work_re: &mut [f32], work_im: &mut [f32]) {
    let n = plan.len();
    let nf = rfft_len(n);
    assert!(clip <= n);
    assert_eq!(spec_re.len(), nf * batch);
    assert_eq!(spec_im.len(), nf * batch);
    assert_eq!(out.len(), batch * clip);
    if batch == 0 {
        return;
    }
    let pairs = batch.div_ceil(2);
    assert!(work_re.len() >= n * pairs && work_im.len() >= n * pairs,
            "work scratch too small");
    let work_re = &mut work_re[..n * pairs];
    let work_im = &mut work_im[..n * pairs];
    // rebuild Z = A + i·B on the full circle via Hermitian extension
    for k in 0..n {
        let wr = &mut work_re[k * pairs..(k + 1) * pairs];
        let wi = &mut work_im[k * pairs..(k + 1) * pairs];
        let (src, sign) = if k < nf {
            (k, 1.0f32)
        } else {
            (n - k, -1.0) // conj(A), conj(B): flips both im parts
        };
        let sr = &spec_re[src * batch..(src + 1) * batch];
        let si = &spec_im[src * batch..(src + 1) * batch];
        for p in 0..pairs {
            let (a_re, a_im) = (sr[2 * p], sign * si[2 * p]);
            let (b_re, b_im) = if 2 * p + 1 < batch {
                (sr[2 * p + 1], sign * si[2 * p + 1])
            } else {
                (0.0, 0.0)
            };
            // Z = A + i·B  (with A/B already conjugated past nf)
            wr[p] = a_re - b_im;
            wi[p] = a_im + b_re;
        }
    }
    cfft_batch(plan, work_re, work_im, pairs, true);
    let scale = 1.0 / n as f32;
    for j in 0..clip {
        let wr = &work_re[j * pairs..(j + 1) * pairs];
        let wi = &work_im[j * pairs..(j + 1) * pairs];
        for p in 0..pairs {
            out[2 * p * clip + j] = wr[p] * scale;
            if 2 * p + 1 < batch {
                out[(2 * p + 1) * clip + j] = wi[p] * scale;
            }
        }
    }
}

/// Split an interleaved `C32` slice into planar re/im planes. Pure data
/// movement — the shuffle kernel and the scalar loop are bitwise
/// interchangeable, so this dispatches freely on the active tier.
pub fn split_complex(src: &[C32], re: &mut [f32], im: &mut [f32]) {
    assert_eq!(src.len(), re.len());
    assert_eq!(src.len(), im.len());
    #[cfg(target_arch = "x86_64")]
    if simd::tier() >= SimdTier::Avx2 {
        // SAFETY: avx2 detected (tier never exceeds detection).
        unsafe { split_complex_avx2(src, re, im) };
        return;
    }
    split_complex_scalar(src, re, im);
}

fn split_complex_scalar(src: &[C32], re: &mut [f32], im: &mut [f32]) {
    for ((s, r), i) in src.iter().zip(re.iter_mut()).zip(im.iter_mut()) {
        *r = s.re;
        *i = s.im;
    }
}

/// De-interleave eight `C32` per step: two 256-bit loads, `shuffle_ps`
/// to gather the even/odd 32-bit slots per 128-bit half, one cross-lane
/// `permute4x64` to restore order. `C32` is `#[repr(C)]`, so the slice
/// is exactly the interleaved `[re, im]` f32 stream.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn split_complex_avx2(src: &[C32], re: &mut [f32],
                             im: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = src.len();
    let p = src.as_ptr() as *const f32;
    let mut i = 0;
    while i + 8 <= n {
        let lo = _mm256_loadu_ps(p.add(2 * i)); // r0 i0 r1 i1|r2 i2 r3 i3
        let hi = _mm256_loadu_ps(p.add(2 * i + 8));
        // per-half even/odd gather: r0 r1 r4 r5 | r2 r3 r6 r7
        let rq = _mm256_shuffle_ps(lo, hi, 0b10_00_10_00);
        let iq = _mm256_shuffle_ps(lo, hi, 0b11_01_11_01);
        // reorder the 64-bit quarters [0,2,1,3] → sequential lanes
        let rv = _mm256_castpd_ps(
            _mm256_permute4x64_pd(_mm256_castps_pd(rq), 0b11_01_10_00));
        let iv = _mm256_castpd_ps(
            _mm256_permute4x64_pd(_mm256_castps_pd(iq), 0b11_01_10_00));
        _mm256_storeu_ps(re.as_mut_ptr().add(i), rv);
        _mm256_storeu_ps(im.as_mut_ptr().add(i), iv);
        i += 8;
    }
    split_complex_scalar(&src[i..], &mut re[i..], &mut im[i..]);
}

/// Re-interleave planar re/im planes into a `C32` slice (exact at every
/// tier, like [`split_complex`]).
pub fn interleave_complex(re: &[f32], im: &[f32], dst: &mut [C32]) {
    assert_eq!(dst.len(), re.len());
    assert_eq!(dst.len(), im.len());
    #[cfg(target_arch = "x86_64")]
    if simd::tier() >= SimdTier::Avx2 {
        // SAFETY: avx2 detected (tier never exceeds detection).
        unsafe { interleave_complex_avx2(re, im, dst) };
        return;
    }
    interleave_complex_scalar(re, im, dst);
}

fn interleave_complex_scalar(re: &[f32], im: &[f32], dst: &mut [C32]) {
    for ((d, r), i) in dst.iter_mut().zip(re.iter()).zip(im.iter()) {
        *d = C32::new(*r, *i);
    }
}

/// Interleave eight `C32` per step: `unpacklo/hi_ps` pair re/im within
/// each 128-bit half, `permute2f128` stitches the halves into the two
/// sequential output registers.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn interleave_complex_avx2(re: &[f32], im: &[f32],
                                  dst: &mut [C32]) {
    use std::arch::x86_64::*;
    let n = dst.len();
    let p = dst.as_mut_ptr() as *mut f32;
    let mut i = 0;
    while i + 8 <= n {
        let rv = _mm256_loadu_ps(re.as_ptr().add(i)); // r0..r3 | r4..r7
        let iv = _mm256_loadu_ps(im.as_ptr().add(i));
        let un_lo = _mm256_unpacklo_ps(rv, iv); // r0 i0 r1 i1|r4 i4 r5 i5
        let un_hi = _mm256_unpackhi_ps(rv, iv); // r2 i2 r3 i3|r6 i6 r7 i7
        let lo = _mm256_permute2f128_ps(un_lo, un_hi, 0x20);
        let hi = _mm256_permute2f128_ps(un_lo, un_hi, 0x31);
        _mm256_storeu_ps(p.add(2 * i), lo);
        _mm256_storeu_ps(p.add(2 * i + 8), hi);
        i += 8;
    }
    interleave_complex_scalar(&re[i..], &im[i..], &mut dst[i..]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::real::rfft;

    fn rand_real(len: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..len)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s as f64 / u64::MAX as f64) as f32 * 2.0 - 1.0
            })
            .collect()
    }

    /// A lane of the batched kernel at the **scalar tier** must be
    /// *bitwise* identical to the scalar plan transform — same LUTs,
    /// same operation order. (The FMA tiers change rounding and are
    /// gated by tolerance below, not bitwise.)
    #[test]
    fn cfft_batch_lane_is_bitwise_scalar() {
        for n in [8usize, 32, 256] {
            for batch in [1usize, LANES - 1, LANES, LANES + 1,
                          4 * LANES + 3] {
                let plan = FbfftPlan::new(n);
                let re0 = rand_real(n * batch, 1 + n as u64);
                let im0 = rand_real(n * batch, 2 + batch as u64);
                for inverse in [false, true] {
                    let mut re = re0.clone();
                    let mut im = im0.clone();
                    cfft_batch_with(&plan, &mut re, &mut im, batch,
                                    inverse, SimdTier::Scalar);
                    for b in 0..batch {
                        let mut buf: Vec<C32> = (0..n)
                            .map(|j| C32::new(re0[j * batch + b],
                                              im0[j * batch + b]))
                            .collect();
                        plan.cfft_in_place(&mut buf, inverse);
                        for (j, v) in buf.iter().enumerate() {
                            assert_eq!(re[j * batch + b], v.re,
                                       "n={n} b={b} j={j} re");
                            assert_eq!(im[j * batch + b], v.im,
                                       "n={n} b={b} j={j} im");
                        }
                    }
                }
            }
        }
    }

    /// Every runnable FMA tier stays within the FFT tolerance model of
    /// the scalar reference, on LANES-unaligned batches (1, 7, 9, 35) —
    /// the fused contraction moves bits, not values.
    #[test]
    fn fma_tiers_match_scalar_within_fft_tolerance() {
        for tier in [SimdTier::Avx2, SimdTier::Avx512] {
            if simd::detected() < tier {
                eprintln!("skipping {tier}: not runnable on this host");
                continue;
            }
            for n in [8usize, 32, 256] {
                for batch in [1usize, 7, 9, 35] {
                    let plan = FbfftPlan::new(n);
                    let re0 = rand_real(n * batch, 11 + n as u64);
                    let im0 = rand_real(n * batch, 13 + batch as u64);
                    for inverse in [false, true] {
                        let mut sr = re0.clone();
                        let mut si = im0.clone();
                        cfft_batch_with(&plan, &mut sr, &mut si, batch,
                                        inverse, SimdTier::Scalar);
                        let mut vr = re0.clone();
                        let mut vi = im0.clone();
                        cfft_batch_with(&plan, &mut vr, &mut vi, batch,
                                        inverse, tier);
                        let tol = crate::testkit::tolerance::fft_abs(n);
                        for i in 0..n * batch {
                            assert!((sr[i] - vr[i]).abs() < tol
                                    && (si[i] - vi[i]).abs() < tol,
                                    "{tier} n={n} batch={batch} \
                                     inverse={inverse} i={i}");
                        }
                    }
                }
            }
        }
    }

    /// Within one tier a lane's bits must not depend on how the batch
    /// was grouped — the threaded pipeline splits batches into chunks
    /// and asserts bitwise phase-split equality, so the FMA tails must
    /// mirror the vector bodies' contraction exactly.
    #[test]
    fn lane_results_are_independent_of_batch_grouping_per_tier() {
        let n = 32usize;
        let batch = 35usize;
        let re0 = rand_real(n * batch, 77);
        let im0 = rand_real(n * batch, 78);
        let plan = FbfftPlan::new(n);
        for tier in [SimdTier::Scalar, SimdTier::Avx2, SimdTier::Avx512] {
            if simd::detected() < tier {
                continue;
            }
            let mut full_re = re0.clone();
            let mut full_im = im0.clone();
            cfft_batch_with(&plan, &mut full_re, &mut full_im, batch,
                            false, tier);
            // re-run each column group as its own narrow batch
            for (b0, bn) in [(0usize, 3usize), (3, 8), (11, 16), (27, 8)]
            {
                let mut cr = vec![0f32; n * bn];
                let mut ci = vec![0f32; n * bn];
                for j in 0..n {
                    for l in 0..bn {
                        cr[j * bn + l] = re0[j * batch + b0 + l];
                        ci[j * bn + l] = im0[j * batch + b0 + l];
                    }
                }
                cfft_batch_with(&plan, &mut cr, &mut ci, bn, false,
                                tier);
                for j in 0..n {
                    for l in 0..bn {
                        assert_eq!(cr[j * bn + l],
                                   full_re[j * batch + b0 + l],
                                   "{tier} chunk ({b0},{bn}) j={j} \
                                    l={l} re");
                        assert_eq!(ci[j * bn + l],
                                   full_im[j * batch + b0 + l],
                                   "{tier} chunk ({b0},{bn}) j={j} \
                                    l={l} im");
                    }
                }
            }
        }
    }

    /// The shuffle kernels are pure data movement: whatever tier is
    /// active, split/interleave must agree bitwise with the scalar
    /// loops, including ragged tails.
    #[test]
    fn shuffles_are_bitwise_exact_at_the_active_tier() {
        for len in [1usize, 7, 8, 9, 16, 35] {
            let src: Vec<C32> = (0..len)
                .map(|i| C32::new(i as f32 + 0.5, -(i as f32) - 0.25))
                .collect();
            let mut re = vec![0f32; len];
            let mut im = vec![0f32; len];
            split_complex(&src, &mut re, &mut im);
            let mut want_re = vec![0f32; len];
            let mut want_im = vec![0f32; len];
            split_complex_scalar(&src, &mut want_re, &mut want_im);
            assert_eq!(re, want_re, "len={len}");
            assert_eq!(im, want_im, "len={len}");
            let mut back = vec![C32::ZERO; len];
            interleave_complex(&re, &im, &mut back);
            assert_eq!(back, src, "len={len}");
        }
    }

    #[test]
    fn rfft_batch_soa_matches_planner() {
        for n in [8usize, 16, 64] {
            for batch in [1usize, 5, LANES, LANES + 1] {
                let plan = FbfftPlan::new(n);
                let nf = rfft_len(n);
                let x = rand_real(batch * n, 3 + n as u64);
                let mut or = vec![0f32; nf * batch];
                let mut oi = vec![0f32; nf * batch];
                let pairs = batch.div_ceil(2);
                let mut wr = vec![0f32; n * pairs];
                let mut wi = vec![0f32; n * pairs];
                rfft_batch_soa(&plan, &x, n, batch, &mut or, &mut oi,
                               &mut wr, &mut wi);
                for b in 0..batch {
                    let want = rfft(&x[b * n..(b + 1) * n], n);
                    for (k, w) in want.iter().enumerate() {
                        let g = C32::new(or[k * batch + b],
                                         oi[k * batch + b]);
                        assert!((g - *w).abs() < 2e-3 * (n as f32).sqrt(),
                                "n={n} batch={batch} b={b} k={k}");
                    }
                }
            }
        }
    }

    #[test]
    fn irfft_batch_soa_round_trip_with_clip() {
        let (n, batch, clip) = (32usize, 7usize, 20usize);
        let plan = FbfftPlan::new(n);
        let nf = rfft_len(n);
        let x = rand_real(batch * n, 9);
        let mut sr = vec![0f32; nf * batch];
        let mut si = vec![0f32; nf * batch];
        let pairs = batch.div_ceil(2);
        let mut wr = vec![7f32; n * pairs]; // dirty scratch is fine
        let mut wi = vec![-7f32; n * pairs];
        rfft_batch_soa(&plan, &x, n, batch, &mut sr, &mut si, &mut wr,
                       &mut wi);
        let mut back = vec![0f32; batch * clip];
        irfft_batch_soa(&plan, &sr, &si, batch, clip, &mut back, &mut wr,
                        &mut wi);
        for b in 0..batch {
            for j in 0..clip {
                assert!((back[b * clip + j] - x[b * n + j]).abs() < 1e-3,
                        "b={b} j={j}");
            }
        }
    }

    #[test]
    fn split_and_interleave_round_trip() {
        let src: Vec<C32> =
            (0..37).map(|i| C32::new(i as f32, -(i as f32))).collect();
        let mut re = vec![0f32; src.len()];
        let mut im = vec![0f32; src.len()];
        split_complex(&src, &mut re, &mut im);
        let mut back = vec![C32::ZERO; src.len()];
        interleave_complex(&re, &im, &mut back);
        assert_eq!(src, back);
    }
}
