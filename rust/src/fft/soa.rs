//! Split-complex (SoA) batch-lane FFT kernels — the CPU image of the
//! paper's §5 warp mapping.
//!
//! The Pallas/fbfft design point this module transplants: map the *batch*
//! dimension across parallel lanes so one butterfly instruction stream
//! executes simultaneously for many transforms (paper §5: one transform
//! per warp, batch across threads). On the host that means:
//!
//! * **split-complex planes** — `re[]` / `im[]` as separate flat `f32`
//!   slices (Zlateski et al., arXiv:1809.07851: CPU FFT convolutions live
//!   or die by SIMD-friendly SoA layouts), so no interleave shuffles sit
//!   between loads and FMAs;
//! * **batch-innermost layout** — element `j` of transform `b` lives at
//!   `j * batch + b`, so every butterfly's inner loop runs over a flat
//!   contiguous lane slice the compiler autovectorizes;
//! * **loop-invariant twiddles** — within one butterfly the twiddle is a
//!   pair of scalar broadcasts, hoisted out of the lane loop;
//! * **[`LANES`]-wide passes** — the lane loops process `LANES = 8`
//!   transforms per pass through fixed-size arrays (one AVX2 register of
//!   `f32`), with a scalar tail for ragged batches.
//!
//! The kernels reuse [`FbfftPlan`]'s cached bit-reversal and stage-major
//! twiddle tables, and follow the exact operation order of the scalar
//! [`FbfftPlan::cfft_in_place`] path — a lane of the batched transform is
//! arithmetically identical to one scalar transform, so the conformance
//! gap between the two paths is pure reassociation-free floating point.

use super::complex::C32;
use super::fbfft_host::FbfftPlan;
use super::real::rfft_len;

/// Transforms processed per vectorized pass of the lane loops (the rest
/// of a ragged batch takes the scalar tail). Eight `f32` lanes = one
/// 256-bit SIMD register.
pub const LANES: usize = 8;

/// `dst[i] = a[i] op b[i]`-style butterfly over one lane slice:
/// `(top, bot) <- (top + w·bot, top - w·bot)` for all `batch` lanes,
/// with the twiddle `(wr, wi)` broadcast. `LANES` at a time + tail.
#[inline(always)]
fn butterfly_lanes(tr_: &mut [f32], ti_: &mut [f32], br_: &mut [f32],
                   bi_: &mut [f32], wr: f32, wi: f32, batch: usize) {
    let (tr_, ti_) = (&mut tr_[..batch], &mut ti_[..batch]);
    let (br_, bi_) = (&mut br_[..batch], &mut bi_[..batch]);
    let mut b = 0;
    while b + LANES <= batch {
        for l in 0..LANES {
            let i = b + l;
            let vr = br_[i] * wr - bi_[i] * wi;
            let vi = br_[i] * wi + bi_[i] * wr;
            let ur = tr_[i];
            let ui = ti_[i];
            tr_[i] = ur + vr;
            ti_[i] = ui + vi;
            br_[i] = ur - vr;
            bi_[i] = ui - vi;
        }
        b += LANES;
    }
    while b < batch {
        let vr = br_[b] * wr - bi_[b] * wi;
        let vi = br_[b] * wi + bi_[b] * wr;
        let ur = tr_[b];
        let ui = ti_[b];
        tr_[b] = ur + vr;
        ti_[b] = ui + vi;
        br_[b] = ur - vr;
        bi_[b] = ui - vi;
        b += 1;
    }
}

/// Batched in-place complex FFT over split-complex planes: `re`/`im` hold
/// `n × batch` values, element `j` of transform `b` at `j·batch + b`
/// (batch innermost). Iterative radix-2 DIT with the plan's cached LUTs —
/// the batched twin of [`FbfftPlan::cfft_in_place`], one whole batch per
/// butterfly pass.
pub fn cfft_batch(plan: &FbfftPlan, re: &mut [f32], im: &mut [f32],
                  batch: usize, inverse: bool) {
    let n = plan.len();
    assert_eq!(re.len(), n * batch, "re plane length");
    assert_eq!(im.len(), n * batch, "im plane length");
    if batch == 0 {
        return;
    }
    // bit-reversal permutation of whole lane rows
    for i in 0..n {
        let j = plan.bitrev(i);
        if i < j {
            let (rl, rh) = re.split_at_mut(j * batch);
            rl[i * batch..i * batch + batch]
                .swap_with_slice(&mut rh[..batch]);
            let (il, ih) = im.split_at_mut(j * batch);
            il[i * batch..i * batch + batch]
                .swap_with_slice(&mut ih[..batch]);
        }
    }
    let log2n = n.trailing_zeros();
    let mut tw_off = 0usize;
    for s in 0..log2n {
        let half = 1usize << s;
        let m = half << 1;
        let mut base = 0;
        while base < n {
            for j in 0..half {
                let w = plan.twiddle(tw_off + j, inverse);
                // rows base+j and base+j+half never alias
                let top = (base + j) * batch;
                let bot = (base + j + half) * batch;
                let (rl, rh) = re.split_at_mut(bot);
                let (il, ih) = im.split_at_mut(bot);
                butterfly_lanes(&mut rl[top..top + batch],
                                &mut il[top..top + batch],
                                &mut rh[..batch], &mut ih[..batch],
                                w.re, w.im, batch);
            }
            base += m;
        }
        tw_off += half;
    }
}

/// Hermitian unpack of a §5.2 pair-packed spectrum, one bin `k` over all
/// lanes: given `Z = A + iB` (two real signals packed re/im),
/// `A[k] = (Z[k] + conj(Z[n-k]))/2` into `(ar, ai)` and, when `b_out` is
/// `Some`, `B[k] = -i·(Z[k] - conj(Z[n-k]))/2` into it.
#[allow(clippy::too_many_arguments)]
#[inline]
pub(crate) fn unpack_pair_bin(zr_k: &[f32], zi_k: &[f32], zr_m: &[f32],
                              zi_m: &[f32], ar: &mut [f32], ai: &mut [f32],
                              b_out: Option<(&mut [f32], &mut [f32])>,
                              batch: usize) {
    for b in 0..batch {
        let (kr, ki) = (zr_k[b], zi_k[b]);
        let (mr, mi) = (zr_m[b], -zi_m[b]); // conj(Z[n-k])
        ar[b] = 0.5 * (kr + mr);
        ai[b] = 0.5 * (ki + mi);
    }
    if let Some((br, bi)) = b_out {
        for b in 0..batch {
            let (kr, ki) = (zr_k[b], zi_k[b]);
            let (mr, mi) = (zr_m[b], -zi_m[b]);
            // -i·(Z - conj(Zm))/2 = (im-part, -re-part)/2
            br[b] = 0.5 * (ki - mi);
            bi[b] = -0.5 * (kr - mr);
        }
    }
}

/// Batched 1-D R2C in SoA form with implicit zero padding and the §5.2
/// two-reals-in-one-complex pack across consecutive batch rows: `input`
/// is `batch × n_in` row-major (`n_in ≤ n`), the output planes hold the
/// **bin-major** `(n/2+1) × batch` layout (`out[k·batch + b]`). `work_*`
/// are caller scratch of `n · ⌈batch/2⌉` (dirty contents fine).
#[allow(clippy::too_many_arguments)]
pub fn rfft_batch_soa(plan: &FbfftPlan, input: &[f32], n_in: usize,
                      batch: usize, out_re: &mut [f32],
                      out_im: &mut [f32], work_re: &mut [f32],
                      work_im: &mut [f32]) {
    let n = plan.len();
    assert!(n_in <= n, "n_in {n_in} exceeds plan size {n}");
    assert_eq!(input.len(), batch * n_in);
    let nf = rfft_len(n);
    assert_eq!(out_re.len(), nf * batch);
    assert_eq!(out_im.len(), nf * batch);
    if batch == 0 {
        return;
    }
    let pairs = batch.div_ceil(2);
    assert!(work_re.len() >= n * pairs && work_im.len() >= n * pairs,
            "work scratch too small");
    let work_re = &mut work_re[..n * pairs];
    let work_im = &mut work_im[..n * pairs];
    // lane load: pair (2p, 2p+1) → (re, im); implicit padding past n_in
    for j in 0..n_in {
        let wr = &mut work_re[j * pairs..(j + 1) * pairs];
        let wi = &mut work_im[j * pairs..(j + 1) * pairs];
        for p in 0..pairs {
            wr[p] = input[2 * p * n_in + j];
            wi[p] = if 2 * p + 1 < batch {
                input[(2 * p + 1) * n_in + j]
            } else {
                0.0
            };
        }
    }
    if n_in < n {
        work_re[n_in * pairs..].fill(0.0);
        work_im[n_in * pairs..].fill(0.0);
    }
    cfft_batch(plan, work_re, work_im, pairs, false);
    // Hermitian unpack, lane p → batch rows 2p (A) and 2p+1 (B),
    // written straight into the strided output (no temporaries — the
    // contiguous-lane form of this math lives in [`unpack_pair_bin`])
    for k in 0..nf {
        let m = (n - k) % n;
        let zr_k = &work_re[k * pairs..(k + 1) * pairs];
        let zi_k = &work_im[k * pairs..(k + 1) * pairs];
        let zr_m = &work_re[m * pairs..(m + 1) * pairs];
        let zi_m = &work_im[m * pairs..(m + 1) * pairs];
        let or = &mut out_re[k * batch..(k + 1) * batch];
        let oi = &mut out_im[k * batch..(k + 1) * batch];
        for p in 0..pairs {
            let (kr, ki) = (zr_k[p], zi_k[p]);
            let (mr, mi) = (zr_m[p], -zi_m[p]); // conj(Z[n-k])
            // A[k] = (Z[k] + conj(Z[n-k])) / 2
            or[2 * p] = 0.5 * (kr + mr);
            oi[2 * p] = 0.5 * (ki + mi);
            if 2 * p + 1 < batch {
                // B[k] = -i · (Z[k] - conj(Z[n-k])) / 2
                or[2 * p + 1] = 0.5 * (ki - mi);
                oi[2 * p + 1] = -0.5 * (kr - mr);
            }
        }
    }
}

/// Inverse of [`rfft_batch_soa`]: bin-major `(n/2+1) × batch` planes in,
/// normalized real rows out (`batch × clip` row-major), pairwise-packed.
/// `work_*` are caller scratch of `n · ⌈batch/2⌉`.
#[allow(clippy::too_many_arguments)]
pub fn irfft_batch_soa(plan: &FbfftPlan, spec_re: &[f32], spec_im: &[f32],
                       batch: usize, clip: usize, out: &mut [f32],
                       work_re: &mut [f32], work_im: &mut [f32]) {
    let n = plan.len();
    let nf = rfft_len(n);
    assert!(clip <= n);
    assert_eq!(spec_re.len(), nf * batch);
    assert_eq!(spec_im.len(), nf * batch);
    assert_eq!(out.len(), batch * clip);
    if batch == 0 {
        return;
    }
    let pairs = batch.div_ceil(2);
    assert!(work_re.len() >= n * pairs && work_im.len() >= n * pairs,
            "work scratch too small");
    let work_re = &mut work_re[..n * pairs];
    let work_im = &mut work_im[..n * pairs];
    // rebuild Z = A + i·B on the full circle via Hermitian extension
    for k in 0..n {
        let wr = &mut work_re[k * pairs..(k + 1) * pairs];
        let wi = &mut work_im[k * pairs..(k + 1) * pairs];
        let (src, sign) = if k < nf {
            (k, 1.0f32)
        } else {
            (n - k, -1.0) // conj(A), conj(B): flips both im parts
        };
        let sr = &spec_re[src * batch..(src + 1) * batch];
        let si = &spec_im[src * batch..(src + 1) * batch];
        for p in 0..pairs {
            let (a_re, a_im) = (sr[2 * p], sign * si[2 * p]);
            let (b_re, b_im) = if 2 * p + 1 < batch {
                (sr[2 * p + 1], sign * si[2 * p + 1])
            } else {
                (0.0, 0.0)
            };
            // Z = A + i·B  (with A/B already conjugated past nf)
            wr[p] = a_re - b_im;
            wi[p] = a_im + b_re;
        }
    }
    cfft_batch(plan, work_re, work_im, pairs, true);
    let scale = 1.0 / n as f32;
    for j in 0..clip {
        let wr = &work_re[j * pairs..(j + 1) * pairs];
        let wi = &work_im[j * pairs..(j + 1) * pairs];
        for p in 0..pairs {
            out[2 * p * clip + j] = wr[p] * scale;
            if 2 * p + 1 < batch {
                out[(2 * p + 1) * clip + j] = wi[p] * scale;
            }
        }
    }
}

/// Split an interleaved `C32` slice into planar re/im planes.
pub fn split_complex(src: &[C32], re: &mut [f32], im: &mut [f32]) {
    assert_eq!(src.len(), re.len());
    assert_eq!(src.len(), im.len());
    for ((s, r), i) in src.iter().zip(re.iter_mut()).zip(im.iter_mut()) {
        *r = s.re;
        *i = s.im;
    }
}

/// Re-interleave planar re/im planes into a `C32` slice.
pub fn interleave_complex(re: &[f32], im: &[f32], dst: &mut [C32]) {
    assert_eq!(dst.len(), re.len());
    assert_eq!(dst.len(), im.len());
    for ((d, r), i) in dst.iter_mut().zip(re.iter()).zip(im.iter()) {
        *d = C32::new(*r, *i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::real::rfft;

    fn rand_real(len: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..len)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s as f64 / u64::MAX as f64) as f32 * 2.0 - 1.0
            })
            .collect()
    }

    /// A lane of the batched kernel must be *bitwise* identical to the
    /// scalar plan transform — same LUTs, same operation order.
    #[test]
    fn cfft_batch_lane_is_bitwise_scalar() {
        for n in [8usize, 32, 256] {
            for batch in [1usize, LANES - 1, LANES, LANES + 1,
                          4 * LANES + 3] {
                let plan = FbfftPlan::new(n);
                let re0 = rand_real(n * batch, 1 + n as u64);
                let im0 = rand_real(n * batch, 2 + batch as u64);
                for inverse in [false, true] {
                    let mut re = re0.clone();
                    let mut im = im0.clone();
                    cfft_batch(&plan, &mut re, &mut im, batch, inverse);
                    for b in 0..batch {
                        let mut buf: Vec<C32> = (0..n)
                            .map(|j| C32::new(re0[j * batch + b],
                                              im0[j * batch + b]))
                            .collect();
                        plan.cfft_in_place(&mut buf, inverse);
                        for (j, v) in buf.iter().enumerate() {
                            assert_eq!(re[j * batch + b], v.re,
                                       "n={n} b={b} j={j} re");
                            assert_eq!(im[j * batch + b], v.im,
                                       "n={n} b={b} j={j} im");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn rfft_batch_soa_matches_planner() {
        for n in [8usize, 16, 64] {
            for batch in [1usize, 5, LANES, LANES + 1] {
                let plan = FbfftPlan::new(n);
                let nf = rfft_len(n);
                let x = rand_real(batch * n, 3 + n as u64);
                let mut or = vec![0f32; nf * batch];
                let mut oi = vec![0f32; nf * batch];
                let pairs = batch.div_ceil(2);
                let mut wr = vec![0f32; n * pairs];
                let mut wi = vec![0f32; n * pairs];
                rfft_batch_soa(&plan, &x, n, batch, &mut or, &mut oi,
                               &mut wr, &mut wi);
                for b in 0..batch {
                    let want = rfft(&x[b * n..(b + 1) * n], n);
                    for (k, w) in want.iter().enumerate() {
                        let g = C32::new(or[k * batch + b],
                                         oi[k * batch + b]);
                        assert!((g - *w).abs() < 2e-3 * (n as f32).sqrt(),
                                "n={n} batch={batch} b={b} k={k}");
                    }
                }
            }
        }
    }

    #[test]
    fn irfft_batch_soa_round_trip_with_clip() {
        let (n, batch, clip) = (32usize, 7usize, 20usize);
        let plan = FbfftPlan::new(n);
        let nf = rfft_len(n);
        let x = rand_real(batch * n, 9);
        let mut sr = vec![0f32; nf * batch];
        let mut si = vec![0f32; nf * batch];
        let pairs = batch.div_ceil(2);
        let mut wr = vec![7f32; n * pairs]; // dirty scratch is fine
        let mut wi = vec![-7f32; n * pairs];
        rfft_batch_soa(&plan, &x, n, batch, &mut sr, &mut si, &mut wr,
                       &mut wi);
        let mut back = vec![0f32; batch * clip];
        irfft_batch_soa(&plan, &sr, &si, batch, clip, &mut back, &mut wr,
                        &mut wi);
        for b in 0..batch {
            for j in 0..clip {
                assert!((back[b * clip + j] - x[b * n + j]).abs() < 1e-3,
                        "b={b} j={j}");
            }
        }
    }

    #[test]
    fn split_and_interleave_round_trip() {
        let src: Vec<C32> =
            (0..37).map(|i| C32::new(i as f32, -(i as f32))).collect();
        let mut re = vec![0f32; src.len()];
        let mut im = vec![0f32; src.len()];
        split_complex(&src, &mut re, &mut im);
        let mut back = vec![C32::ZERO; src.len()];
        interleave_complex(&re, &im, &mut back);
        assert_eq!(src, back);
    }
}
